"""Benchmark harness — one function per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows followed by human-readable tables.
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import numpy as np


def bench_fig1() -> list[str]:
    """Fig. 1: SDR + per-iteration rates for the three sparsities."""
    from paper_repro import EPS_LIST, run_fig1
    rows = []
    for eps in EPS_LIST:
        t0 = time.time()
        fig = run_fig1(eps)
        dt = (time.time() - t0) * 1e6
        print(f"--- eps={eps} (T={fig['T']}) ---")
        print("  SE SDR      :", np.round(fig["se_sdr"], 2))
        print("  AMP sim SDR :", np.round(fig["centralized_sdr"], 2))
        print("  BT sim SDR  :", np.round(fig["bt_sdr_sim"], 2))
        print("  BT rates    :", np.round(fig["bt_rates_sim"], 2))
        print("  DP sim SDR  :", np.round(fig["dp_sdr_sim"], 2))
        print("  DP rates(RD):", np.round(fig["dp_rates_rd"], 2))
        rows.append(f"fig1_eps{eps},{dt:.0f},"
                    f"T={fig['T']};centralized_final={fig['centralized_sdr'][-1]:.2f}dB;"
                    f"bt_final={fig['bt_sdr_sim'][-1]:.2f}dB;"
                    f"dp_final={fig['dp_sdr_sim'][-1]:.2f}dB;"
                    f"bt_max_rate={np.max(fig['bt_rates_sim']):.2f}b")
    return rows


def bench_table1() -> list[str]:
    """Table 1: total bits/element, ours vs paper."""
    from paper_repro import PAPER_TABLE1, run_table1
    rows = []
    print(f"{'eps':>5s} {'T':>3s} {'BT-RD':>14s} {'BT-ECSQ':>14s} "
          f"{'DP-RD':>14s} {'DP-ECSQ':>14s}  (ours/paper)")
    for r in run_table1():
        p = PAPER_TABLE1[r["eps"]]
        print(f"{r['eps']:5.2f} {r['T']:3d} "
              f"{r['bt_rd_total']:6.2f}/{p['bt_rd']:6.2f} "
              f"{r['bt_ecsq_total']:6.2f}/{p['bt_ecsq']:6.2f} "
              f"{r['dp_rd_total']:6.2f}/{p['dp_rd']:6.2f} "
              f"{r['dp_ecsq_total']:6.2f}/{p['dp_ecsq']:6.2f}")
        rows.append(
            f"table1_eps{r['eps']},{r['runtime_s']*1e6:.0f},"
            f"bt_rd={r['bt_rd_total']:.2f};bt_ecsq={r['bt_ecsq_total']:.2f};"
            f"dp_rd={r['dp_rd_total']:.2f};dp_ecsq={r['dp_ecsq_total']:.2f};"
            f"dp_sdr_gap={r['centralized_final_sdr']-r['dp_final_sdr']:.2f}dB")
    return rows


def bench_ablation() -> list[str]:
    """Rate-allocation policy ablation (DP vs uniform vs front/back-loaded)."""
    from bench_ablation import run_ablation
    rows = []
    for name, v in run_ablation().items():
        print(f"{name:14s} SDR {v['final_sdr']:6.2f} dB  "
              f"({v['bits_spent']:.1f} bits/elem)")
        rows.append(f"ablation_{name},0,sdr={v['final_sdr']:.2f}dB;"
                    f"bits={v['bits_spent']:.1f}")
    return rows


def bench_engine() -> list[str]:
    """Unified-engine benchmark: host-loop vs scan-compiled vs vmap-batched.

    The scan variant eliminates the per-iteration host sync of the legacy
    mp_amp loop; the batched variant amortizes dispatch over >=32 instances
    (the serving scenario). Reported per-instance us and MSE agreement.
    """
    import jax
    from repro.core.amp import sample_problem
    from repro.core.denoisers import BernoulliGauss
    from repro.core.engine import (AmpEngine, EcsqTransport, EngineConfig,
                                   FixedSchedule)
    from repro.core.state_evolution import CSProblem

    import jax.numpy as jnp
    prior = BernoulliGauss(eps=0.1)
    prob = CSProblem(n=2048, m=1024, prior=prior)
    t_iter, p, batch = 10, 8, 32
    deltas = np.full(t_iter, 0.05, np.float32)
    # one shared sensing matrix, B consistent measurement vectors from it
    _, a_shared, y0 = sample_problem(jax.random.PRNGKey(0), prob.n, prob.m,
                                     prior, prob.sigma_e2)
    ys = [y0]
    for i in range(1, batch):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(100 + i), 3)
        support = jax.random.bernoulli(k1, prior.eps, (prob.n,))
        s_i = jnp.where(support, jax.random.normal(k2, (prob.n,)), 0.0)
        e_i = np.sqrt(prob.sigma_e2) * jax.random.normal(k3, (prob.m,))
        ys.append(np.asarray(a_shared @ np.asarray(s_i) + np.asarray(e_i),
                             np.float32))
    ys = np.stack(ys)

    engine = AmpEngine(
        prior, EngineConfig(n_proc=p, n_iter=t_iter, collect_symbols=False,
                            collect_xs=False),
        EcsqTransport(), FixedSchedule(deltas))

    def timeit(fn, reps):
        fn()  # warmup / compile
        t0 = time.time()
        for _ in range(reps):
            fn()
        return (time.time() - t0) / reps * 1e6

    us_host = timeit(lambda: engine.solve_host_loop(ys[0], a_shared), 3)
    us_scan = timeit(lambda: engine.solve(ys[0], a_shared), 3)
    us_batch = timeit(lambda: engine.solve_many(ys, a_shared), 3) / batch

    x_scan = engine.solve(ys[0], a_shared).x
    x_host = engine.solve_host_loop(ys[0], a_shared).x
    agree = float(np.abs(x_scan - x_host).max())
    print(f"host-loop : {us_host:9.0f} us/solve")
    print(f"scan      : {us_scan:9.0f} us/solve   ({us_host / us_scan:.2f}x)")
    print(f"batched   : {us_batch:9.0f} us/solve   ({us_host / us_batch:.2f}x,"
          f" B={batch})")
    print(f"scan vs host max|dx| = {agree:.2e}")
    return [
        f"engine_host_loop,{us_host:.0f},T={t_iter};P={p}",
        f"engine_scan,{us_scan:.0f},speedup_vs_host={us_host / us_scan:.2f}x",
        f"engine_batched,{us_batch:.0f},B={batch};"
        f"speedup_vs_host={us_host / us_batch:.2f}x;max_dx={agree:.2e}",
    ]


def bench_compressed_psum() -> list[str]:
    """Microbenchmark: compressed vs exact psum (CPU wall time + error)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.core.compression import QuantConfig, compressed_psum

    n_dev = jax.device_count()
    if n_dev < 2:
        return ["compressed_psum,0,skipped_single_device"]
    mesh = jax.make_mesh((n_dev,), ("d",))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(n_dev, 1 << 16)).astype(np.float32))
    rows = []
    for bits in (8, 4):
        fn = jax.jit(shard_map(
            lambda v: compressed_psum(v[0], "d", QuantConfig(bits=bits))[0][None],
            mesh=mesh, in_specs=P("d", None), out_specs=P("d", None),
            axis_names={"d"}, check=False))
        out = np.asarray(fn(x))[0]
        t0 = time.time()
        for _ in range(5):
            fn(x)[0].block_until_ready()
        dt = (time.time() - t0) / 5 * 1e6
        ref = np.asarray(x).sum(0)
        rel = float(np.abs(out - ref).max() / np.abs(ref).max())
        print(f"int{bits}: rel_err={rel:.2e} {dt:.0f}us/call")
        rows.append(f"compressed_psum_int{bits},{dt:.0f},rel_err={rel:.2e};"
                    f"wire_reduction={'4x' if bits == 8 else '8x'}")
    return rows


def bench_roofline() -> list[str]:
    """Roofline table from dry-run artifacts (if present)."""
    from roofline import format_table, load_cells
    ddir = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "..", "results", "dryrun")
    if not os.path.isdir(ddir):
        return ["roofline,0,no_dryrun_artifacts"]
    rows = load_cells(ddir)
    print(format_table(rows))
    out = []
    for r in rows:
        if "compute_s" in r:
            out.append(f"roofline_{r['arch']}_{r['shape']},0,"
                       f"dominant={r['dominant']};frac={r['roofline_frac']:.3f}")
    return out


def main() -> None:
    all_rows: list[str] = []
    print("=== Fig. 1 reproduction (SDR + rates per iteration) ===")
    all_rows += bench_fig1()
    print("\n=== Table 1 reproduction (total bits/element) ===")
    all_rows += bench_table1()
    print("\n=== rate-allocation ablation (eps=0.05, R=2T) ===")
    all_rows += bench_ablation()
    print("\n=== unified engine (host-loop vs scan vs batched) ===")
    all_rows += bench_engine()
    print("\n=== compressed psum microbenchmark ===")
    all_rows += bench_compressed_psum()
    print("\n=== roofline (from dry-run artifacts) ===")
    all_rows += bench_roofline()
    print("\nname,us_per_call,derived")
    for r in all_rows:
        print(r)


if __name__ == "__main__":
    main()
