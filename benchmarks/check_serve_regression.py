"""Non-blocking serving-perf regression check for CI.

Compares a freshly measured ``BENCH_serve.json`` against the committed
baseline and prints a GitHub Actions ``::warning::`` annotation when the
stream p50 latency regresses by more than ``--threshold`` (default 25%)
or a batched speedup drops below the baseline by the same margin.
Measured wire bytes (the ``wire`` section) get a tighter 10% band:
byte counts are deterministic at fixed config — drift there is an
accounting change, not runner jitter.

Always exits 0: CI wall-clock on shared runners is jittery, so this
surfaces drift on the PR without turning noise into a red build. The
archived artifacts carry the full trajectory for offline comparison.

  python benchmarks/check_serve_regression.py \
      --baseline /tmp/bench_serve_baseline.json --fresh BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_serve.json (snapshot before the "
                         "bench overwrites it)")
    ap.add_argument("--fresh", default="BENCH_serve.json",
                    help="just-measured BENCH_serve.json")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="relative regression that triggers a warning")
    args = ap.parse_args()

    try:
        with open(args.baseline) as f:
            base = json.load(f)
        with open(args.fresh) as f:
            fresh = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"::notice::serve-bench comparison skipped: {e}")
        return 0

    warnings = []

    b_lat, f_lat = base.get("latency") or {}, fresh.get("latency") or {}
    b50, f50 = b_lat.get("p50_ms"), f_lat.get("p50_ms")
    if b50 and f50:
        rel = f50 / b50 - 1.0
        line = (f"stream p50 {f50:.2f} ms vs baseline {b50:.2f} ms "
                f"({rel:+.0%}, commit {base.get('commit', '?')})")
        if rel > args.threshold:
            warnings.append(f"p50 latency regressed: {line}")
        else:
            print(f"serve-bench: {line}")

    b_sp = {row["batch"]: row["speedup"] for row in base.get("batched", [])}
    for row in fresh.get("batched", []):
        b = row["batch"]
        if b not in b_sp or b_sp[b] <= 0:
            continue
        rel = row["speedup"] / b_sp[b] - 1.0
        line = (f"B={b} speedup {row['speedup']:.2f}x vs baseline "
                f"{b_sp[b]:.2f}x ({rel:+.0%})")
        if rel < -args.threshold:
            warnings.append(f"batched speedup regressed: {line}")
        else:
            print(f"serve-bench: {line}")

    b_wire, f_wire = base.get("wire") or {}, fresh.get("wire") or {}
    same_cfg = all(b_wire.get(k) == f_wire.get(k)
                   for k in ("n", "m", "p", "t", "batch", "erasure"))
    for variant in ("clean", "retransmit", "rate_up"):
        bb = (b_wire.get(variant) or {}).get("bytes_on_wire")
        fb = (f_wire.get(variant) or {}).get("bytes_on_wire")
        if not (same_cfg and bb and fb):
            continue
        rel = fb / bb - 1.0
        line = (f"{variant} bytes-on-wire {fb:.0f} vs baseline {bb:.0f} "
                f"({rel:+.0%})")
        if abs(rel) > 0.10:
            warnings.append(f"wire bytes drifted beyond 10% at fixed "
                            f"config (accounting change?): {line}")
        else:
            print(f"serve-bench: {line}")

    ssc = (f_lat or {}).get("steady_state_compiles")
    if ssc:
        warnings.append(f"steady-state stream triggered {ssc} recompiles "
                        f"(prewarm should cover the whole menu)")

    # telemetry plane (DESIGN.md §12): all advisory. Drift p95 above the
    # alert line (DRIFT_ALERT = 1.0 in repro.telemetry.drift) means the
    # SE predictions no longer describe realized solves — a modeling or
    # rating bug, not runner jitter. Incomplete span trees mean a
    # dispatch path stopped stamping its stages. The overhead budget
    # (<=2% at B=32, deployment config) is re-checked here so the
    # archived bench surfaces a creeping hot-path cost on the PR.
    # p95 threshold is 2x the per-request alert line: at the bench's
    # small N the drift tail is heavy with finite-size realization
    # noise (p95 ~1.2 on a healthy run), while a systematic modeling
    # bug shifts the whole distribution decades up the log scale.
    d95 = (f_lat or {}).get("se_drift_p95")
    if d95 is not None and d95 > 2.0:
        warnings.append(f"SE-drift p95 {d95:.2f} above 2x the "
                        f"drift-alert line over "
                        f"{f_lat.get('monitored_requests')} monitored "
                        f"requests (mis-modeled operating point?)")
    bad_spans = (f_lat or {}).get("incomplete_spans")
    if bad_spans:
        warnings.append(f"{bad_spans} requests returned incomplete or "
                        f"non-monotonic span trees (must be 0)")
    f_tel = fresh.get("telemetry_overhead") or {}
    ovh = f_tel.get("overhead_frac")
    if ovh is not None and ovh > 0.02:
        lean = f_tel.get("overhead_frac_lean", 0.0) * 100
        warnings.append(f"telemetry overhead {ovh * 100:.2f}% above the "
                        f"2% B=32 budget (lean {lean:.2f}%)")

    # cluster tier (DESIGN.md §11): aggregate throughput drift at same
    # host count, plus the hard invariants (zero steady-state recompiles,
    # router cost imbalance within 2x on a homogeneous stream)
    b_cl, f_cl = base.get("cluster") or {}, fresh.get("cluster") or {}
    b_agg, f_agg = b_cl.get("req_s_cluster"), f_cl.get("req_s_cluster")
    if b_agg and f_agg and b_cl.get("hosts") == f_cl.get("hosts"):
        rel = f_agg / b_agg - 1.0
        line = (f"{f_cl['hosts']}-host aggregate {f_agg:.1f} req/s vs "
                f"baseline {b_agg:.1f} req/s ({rel:+.0%}, weak scaling "
                f"{f_cl.get('weak_scaling', 0):.2f}x)")
        if rel < -args.threshold:
            warnings.append(f"cluster throughput regressed: {line}")
        else:
            print(f"serve-bench: {line}")
    if f_cl.get("steady_state_compiles"):
        warnings.append(f"cluster ran {f_cl['steady_state_compiles']} "
                        f"steady-state recompiles after prewarm")
    imb = f_cl.get("imbalance")
    if imb is not None and imb > 2.0:
        warnings.append(f"cluster router cost imbalance {imb:.2f}x "
                        f"exceeds 2x on a homogeneous stream")

    # fault-tolerance drill (DESIGN.md §13): lost requests and non-
    # identical replays are correctness (always warn); recovery latency
    # and retry cost compare against baseline when both runs drilled
    b_ch, f_ch = base.get("chaos") or {}, fresh.get("chaos") or {}
    if f_ch:
        lost = (f_ch.get("admitted", 0) - f_ch.get("completed", 0)
                + f_ch.get("lost", 0))
        if lost:
            warnings.append(f"chaos drill lost {lost} request(s) "
                            f"(zero-loss failover is the gate)")
        if f_ch.get("bitwise_max_abs_diff"):
            warnings.append(f"chaos failover replays differ from "
                            f"single-host by max|dx|="
                            f"{f_ch['bitwise_max_abs_diff']:.2e} "
                            f"(must be bit-identical)")
        b95, f95 = b_ch.get("recovery_p95_ms"), f_ch.get("recovery_p95_ms")
        if b95 and f95 and b_ch.get("hosts") == f_ch.get("hosts"):
            rel = f95 / b95 - 1.0
            line = (f"recovery p95 {f95:.1f} ms vs baseline {b95:.1f} ms "
                    f"({rel:+.0%}, {f_ch.get('retries_per_request', 0):.2f} "
                    f"retries/req)")
            # recovery includes a replayed solve: give it double headroom
            if rel > 2 * args.threshold:
                warnings.append(f"failover recovery regressed: {line}")
            else:
                print(f"serve-bench: {line}")
        elif f95:
            print(f"serve-bench: recovery p95 {f95:.1f} ms "
                  f"({f_ch.get('retries_per_request', 0):.2f} retries/req, "
                  f"no baseline drill to compare)")

    for w in warnings:
        print(f"::warning::{w}")
    if not warnings:
        print("serve-bench: no regressions beyond "
              f"{args.threshold:.0%} threshold")
    return 0   # advisory only — never fail the build on wall-clock noise


if __name__ == "__main__":
    sys.exit(main())
