"""Serving-layer benchmark: batching and mesh-placement throughput.

Three measurements (DESIGN.md §5-§6):

  * batched vs sequential — the same B CS requests solved one
    ``AmpEngine.solve`` at a time vs one ``SolveService`` dispatch
    (ISSUE 2 acceptance: >=5x at B=32 on CPU), and
  * data-parallel placement — the same bucket load through a service
    whose batch axis is sharded across ``--devices`` mesh devices
    (compare req/s against a ``--devices 1`` run; ISSUE 3 acceptance:
    >=3x at 8 devices on a multi-core host), and
  * processor-sharded placement — one large single request whose P maps
    onto the mesh axis, exact wire vs int8 compressed wire.

Results print as a table and are written machine-readable to
``BENCH_serve.json`` (req/s, per-placement timings, compiled-bucket
count) so CI can archive the perf trajectory.

  PYTHONPATH=src python benchmarks/bench_serve.py [--smoke] [--devices 8]

``--devices K`` forces K host-platform devices (set XLA_FLAGS before the
first jax import; run once with K=1 and once with K=8 to compare).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))


def make_load(n: int, m: int, p: int, t: int, b: int, eps: float = 0.1,
              layout: str | None = "row"):
    import jax
    import numpy as np
    from repro.core.amp import sample_problem
    from repro.core.denoisers import BernoulliGauss
    from repro.core.state_evolution import CSProblem
    from repro.serving import SolveRequest

    prior = BernoulliGauss(eps=eps)
    prob = CSProblem(n=n, m=m, prior=prior, snr_db=20.0)
    deltas = np.full(t, 0.05, np.float32)
    deltas[0] = np.inf
    reqs, s0s = [], []
    for i in range(b):
        s0, a, y = sample_problem(jax.random.PRNGKey(i), n, m, prior,
                                  prob.sigma_e2)
        reqs.append(SolveRequest(y=y, a=a, prior=prior, n_proc=p, n_iter=t,
                                 policy="fixed", deltas=deltas,
                                 layout=layout))
        s0s.append(s0)
    return prior, deltas, reqs, s0s


def best_of(fn, reps: int):
    # min over reps: robust to noisy-neighbor jitter on shared hosts
    best, out = float("inf"), None
    for _ in range(reps):
        t0 = time.time()
        res = fn()
        best = min(best, time.time() - t0)
        out = res
    return best, out


def bench_width(n: int, m: int, p: int, t: int, b: int, reps: int):
    """Batched service vs one-solve-at-a-time, single device."""
    import numpy as np
    from repro.core.engine import (AmpEngine, EcsqTransport, EngineConfig,
                                   FixedSchedule)
    from repro.serving import BucketPolicy, SolveService

    prior, deltas, reqs, s0s = make_load(n, m, p, t, b)

    # sequential baseline: one engine (compile shared across requests),
    # one dispatch per request
    eng = AmpEngine(prior,
                    EngineConfig(n_proc=p, n_iter=t, collect_symbols=False,
                                 collect_xs=False),
                    EcsqTransport(), FixedSchedule(deltas))
    eng.solve(reqs[0].y, reqs[0].a)  # warmup/compile

    def run_seq():
        return [eng.solve(r.y, r.a) for r in reqs]

    dt_seq, seq_res = best_of(run_seq, reps)

    # batched service: everything lands in one bucket -> one solve_het call
    # (quanta sized to the load so the bucket pads nothing; the default
    # 256-element quantum would double the padded compute at N=128)
    svc = SolveService(policy=BucketPolicy(max_batch=max(b, 1),
                                           n_quantum=64, mp_quantum=8),
                       rate_accounting=False)
    svc.solve(reqs)  # warmup/compile
    dt_svc, svc_res = best_of(lambda: svc.solve(reqs), reps)

    # correctness spot check: batched == sequential estimates
    max_mse_diff = max(
        float(np.mean((sr.x - br.x) ** 2))
        for sr, br in zip(seq_res, svc_res))
    return dt_seq, dt_svc, max_mse_diff


def bench_data_parallel(n: int, m: int, p: int, t: int, b: int, reps: int,
                        devices: int):
    """One bucket of B small requests through the placement dispatcher:
    batch axis sharded over the mesh when devices > 1, local otherwise."""
    from repro.launch.mesh import make_serve_mesh
    from repro.serving import BucketPolicy, SolveService
    from repro.serving.buckets import round_up

    _, _, reqs, _ = make_load(n, m, p, t, b)
    # pin the mesh to the requested device count even if the host exposes
    # more (a pre-set XLA_FLAGS would otherwise mislabel the measurement);
    # max_batch must be a device multiple for data-parallel dispatch
    mesh = make_serve_mesh(devices) if devices > 1 else None
    svc = SolveService(policy=BucketPolicy(max_batch=round_up(max(b, devices),
                                                              devices),
                                           n_quantum=64, mp_quantum=8),
                       rate_accounting=False, mesh=mesh)
    res = svc.solve(reqs)  # warmup/compile
    placement = res[0].bucket.placement
    dt, _ = best_of(lambda: svc.solve(reqs), reps)
    return dt, placement, len(svc._engines)


def bench_proc_sharded(n: int, m: int, p: int, t: int, reps: int,
                       devices: int):
    """One large single request: processor-sharded over the mesh (exact
    and int8-compressed wire) when devices > 1, local otherwise."""
    from repro.launch.mesh import make_serve_mesh
    from repro.serving import BucketPolicy, SolveService
    from repro.serving.buckets import round_up

    _, _, reqs, _ = make_load(n, m, p, t, 1)
    req = reqs[0]
    mesh = make_serve_mesh(devices) if devices > 1 else None
    max_batch = round_up(128, devices)
    out = {}
    for transport in ("ecsq", "block8"):
        svc = SolveService(policy=BucketPolicy(shard_elems=1,
                                               max_batch=max_batch),
                           rate_accounting=False, mesh=mesh)
        r = dataclass_replace(req, transport=transport,
                              policy="lossless", deltas=None)
        res, = svc.solve([r])  # warmup/compile
        dt, _ = best_of(lambda: svc.solve([r]), reps)
        out[transport] = {"seconds": dt, "placement": res.bucket.placement}
    return out


def bench_col_bucket(n: int, m: int, p: int, t: int, b: int, reps: int,
                     devices: int):
    """A tall-N bucket (auto-routed to the C-MP-AMP column layout,
    DESIGN.md §7) through the same dispatcher: layout routing must not
    cost throughput relative to a row bucket of the same element count."""
    from repro.launch.mesh import make_serve_mesh
    from repro.serving import BucketPolicy, SolveService
    from repro.serving.buckets import round_up

    _, _, reqs, s0s = make_load(n, m, p, t, b, eps=0.02, layout=None)
    mesh = make_serve_mesh(devices) if devices > 1 else None
    svc = SolveService(policy=BucketPolicy(max_batch=round_up(max(b, devices),
                                                              devices),
                                           n_quantum=64, mp_quantum=8),
                       rate_accounting=False, mesh=mesh)
    res = svc.solve(reqs)  # warmup/compile
    assert res[0].bucket.layout == "col", res[0].bucket
    import numpy as np
    mse = float(np.mean([r.mse(s) for r, s in zip(res, s0s)]))
    dt, _ = best_of(lambda: svc.solve(reqs), reps)
    return dt, res[0].bucket.placement, mse


def dataclass_replace(req, **kw):
    import dataclasses
    return dataclasses.replace(req, request_id=-1, **kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller problem + widths, fewer reps (CI sanity)")
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--devices", type=int, default=1,
                    help="force this many host-platform devices (mesh "
                         "placements activate above 1)")
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="machine-readable output path ('' disables)")
    args = ap.parse_args()

    # forcing more host devices than cores measures thread contention, not
    # data-parallel scaling (ROADMAP open item): clamp and say so, so
    # BENCH_serve.json numbers are always from a real-parallelism config
    cores = os.cpu_count() or 1
    if args.devices > cores:
        print(f"WARNING: --devices {args.devices} exceeds the "
              f"{cores} available cores; clamping to {cores} so the "
              f"benchmark measures scaling, not oversubscription")
        args.devices = cores

    if args.devices > 1:
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()

    import jax  # first jax import happens after XLA_FLAGS is set

    assert jax.device_count() >= args.devices, \
        (jax.device_count(), args.devices)

    from roofline import git_commit  # benchmarks/ is the script dir

    report = {"devices": args.devices, "smoke": bool(args.smoke),
              "backend": jax.default_backend(), "commit": git_commit(),
              "jax_device_count": jax.device_count(), "batched": [],
              "data_parallel": {}, "proc_sharded": {}}

    # the serving regime: many small per-user recoveries, where a single
    # solve is per-dispatch/per-op overhead-bound and batching amortizes it
    n, m, p, t = 128, 64, 4, 8
    if args.smoke:
        widths, reps = (1, 8, 32), 3
    else:
        widths, reps = (1, 8, 32, 128), args.reps

    print(f"problem: N={n} M={m} P={p} T={t}  (ECSQ fixed schedule, CPU="
          f"{jax.default_backend() == 'cpu'})")
    print(f"{'B':>4s} {'seq req/s':>10s} {'svc req/s':>10s} "
          f"{'speedup':>8s} {'max mse diff':>13s}")
    speedups = {}
    for b in widths:
        dt_seq, dt_svc, dmse = bench_width(n, m, p, t, b, reps)
        sp = dt_seq / dt_svc
        speedups[b] = sp
        print(f"{b:4d} {b / dt_seq:10.1f} {b / dt_svc:10.1f} "
              f"{sp:7.2f}x {dmse:13.2e}")
        report["batched"].append({
            "batch": b, "seq_req_s": b / dt_seq, "svc_req_s": b / dt_svc,
            "speedup": sp, "max_mse_diff": dmse})

    # data-parallel placement: a compute-bound bucket where sharding the
    # batch across devices pays (the tiny dispatch-bound load above would
    # only measure collective overhead)
    ndp, mdp, bdp = (512, 128, 8) if args.smoke else (2048, 512, 32)
    dt_dp, placement, n_buckets = bench_data_parallel(
        ndp, mdp, p, t, bdp, max(2, reps // 2), args.devices)
    print(f"\ndata-parallel bucket: N={ndp} M={mdp} B={bdp} "
          f"placement={placement} devices={args.devices}: "
          f"{bdp / dt_dp:.1f} req/s")
    report["data_parallel"] = {
        "n": ndp, "m": mdp, "batch": bdp, "placement": placement,
        "req_s": bdp / dt_dp, "seconds": dt_dp,
        "compiled_buckets": n_buckets}

    # processor-sharded placement: one large request, the mesh axis as the
    # paper's P, exact vs compressed wire
    nps, mps, pps = (2048, 512, 8) if args.smoke else (8192, 2048, 8)
    proc = bench_proc_sharded(nps, mps, pps, t, max(2, reps // 2),
                              args.devices)
    for tr, row in proc.items():
        print(f"proc-sharded single:  N={nps} M={mps} P={pps} wire={tr} "
              f"placement={row['placement']}: {row['seconds']*1e3:.1f} ms")
    report["proc_sharded"] = {"n": nps, "m": mps, "p": pps, **proc}

    # column-layout bucket: tall-N requests auto-routed to C-MP-AMP
    # (DESIGN.md §7) through the same dispatcher
    ncb, mcb, bcb = (1024, 128, 8) if args.smoke else (4096, 512, 16)
    dt_cb, placement_cb, mse_cb = bench_col_bucket(
        ncb, mcb, p, t, bcb, max(2, reps // 2), args.devices)
    print(f"column bucket:        N={ncb} M={mcb} B={bcb} "
          f"placement={placement_cb} layout=col: {bcb / dt_cb:.1f} req/s "
          f"(mse {mse_cb:.2e})")
    report["col_bucket"] = {
        "n": ncb, "m": mcb, "batch": bcb, "placement": placement_cb,
        "req_s": bcb / dt_cb, "seconds": dt_cb, "mse": mse_cb}

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"\nwrote {args.json}")

    if 32 in speedups and speedups[32] < 5.0:
        print(f"WARNING: B=32 speedup {speedups[32]:.2f}x below the 5x "
              f"acceptance target")
        # --smoke is a CI sanity check on shared runners: surface the
        # number, never turn wall-clock jitter into a red build
        return 0 if args.smoke else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
