"""Serving-layer benchmark: batching, placement, and hot-path latency.

Measurements (DESIGN.md §5-§6, hot path §9):

  * batched vs sequential — the same B CS requests solved one
    ``AmpEngine.solve`` at a time vs one ``SolveService`` dispatch
    (>=2x at B=32 on CPU under honest interleaved timing — the historic
    5x figure compared against an under-warmed sequential baseline;
    ISSUE 6 acceptance: >=1x at B=1 with prewarm + the singleton fast
    path), and
  * request latency percentiles — a prewarmed continuous-batching stream
    timed per request (submit -> result), p50/p95/p99 plus the service's
    operand-cache / compile counters, and
  * data-parallel placement — the same bucket load through a service
    whose batch axis is sharded across ``--devices`` mesh devices
    (compare req/s against a ``--devices 1`` run; ISSUE 3 acceptance:
    >=3x at 8 devices on a multi-core host), and
  * processor-sharded placement — one large single request whose P maps
    onto the mesh axis, exact wire vs int8 compressed wire, and
  * measured wire bytes — a ``measure_wire`` bucket whose per-round
    symbol streams are actually rANS-coded host-side (DESIGN.md §10):
    measured payload vs the model entropy H_Q, bytes-on-wire /
    time-on-air / energy columns, and (with ``--erasure``) the same load
    over a lossy link under both recovery policies, and
  * telemetry plane (DESIGN.md §12) — B=32 throughput with the metrics /
    span / drift instrumentation on vs off (ISSUE 9 acceptance: <=2%
    overhead), SE-drift percentiles + incomplete-span-tree counts on the
    latency stream, and per-frame TCP round-trips over a loopback
    ``BackendServer`` leg in the cluster section.

Timing methodology (shared with ``bench_kernels.py``): explicit warmup
first (compiles and cache fills excluded), then min over ``--reps``
rounds with the compared variants interleaved round-robin inside each
round — noisy-neighbor phases on shared CI boxes hit every variant
equally, which is what the pre-overhaul single-shot loop got wrong
(seq req/s swung 5x between rows of one config).

Results print as a table and are written machine-readable to
``BENCH_serve.json`` (req/s, latency percentiles, cache/compile
counters, per-placement timings) so CI can archive the perf trajectory
and diff p50 against the committed baseline.

  PYTHONPATH=src python benchmarks/bench_serve.py [--smoke] [--devices 8]
                                                  [--no-prewarm]

``--devices K`` forces K host-platform devices (set XLA_FLAGS before the
first jax import; run once with K=1 and once with K=8 to compare).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))


def make_load(n: int, m: int, p: int, t: int, b: int, eps: float = 0.1,
              layout: str | None = "row"):
    import jax
    import numpy as np
    from repro.core.amp import sample_problem
    from repro.core.denoisers import BernoulliGauss
    from repro.core.state_evolution import CSProblem
    from repro.serving import SolveRequest

    prior = BernoulliGauss(eps=eps)
    prob = CSProblem(n=n, m=m, prior=prior, snr_db=20.0)
    deltas = np.full(t, 0.05, np.float32)
    deltas[0] = np.inf
    reqs, s0s = [], []
    for i in range(b):
        s0, a, y = sample_problem(jax.random.PRNGKey(i), n, m, prior,
                                  prob.sigma_e2)
        reqs.append(SolveRequest(y=y, a=a, prior=prior, n_proc=p, n_iter=t,
                                 policy="fixed", deltas=deltas,
                                 layout=layout))
        s0s.append(s0)
    return prior, deltas, reqs, s0s


def time_variants(ops: dict, reps: int, inner: int = 1) -> dict:
    """Seconds per call per variant: explicit warmup, then min over
    ``reps`` rounds with variants interleaved round-robin within each
    round (same methodology as ``bench_kernels.py``)."""
    results = {k: fn() for k, fn in ops.items()}   # warmup / compile
    best = {k: float("inf") for k in ops}
    for _ in range(reps):
        for k, fn in ops.items():
            t0 = time.perf_counter()
            for _ in range(inner):
                results[k] = fn()
            best[k] = min(best[k], (time.perf_counter() - t0) / inner)
    return best, results


def best_of(fn, reps: int):
    """Single-variant min-over-reps (placement benches: nothing to
    interleave against). Callers warm up explicitly first."""
    best, out = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        res = fn()
        best = min(best, time.perf_counter() - t0)
        out = res
    return best, out


def bench_width(n: int, m: int, p: int, t: int, b: int, reps: int,
                prewarm: bool):
    """Batched service vs one-solve-at-a-time, single device,
    interleaved round-robin timing."""
    import numpy as np
    from repro.core.engine import (AmpEngine, EcsqTransport, EngineConfig,
                                   FixedSchedule)
    from repro.serving import BucketPolicy, PrewarmSpec, SolveService

    prior, deltas, reqs, s0s = make_load(n, m, p, t, b)

    # sequential baseline: one engine (compile shared across requests),
    # one dispatch per request
    eng = AmpEngine(prior,
                    EngineConfig(n_proc=p, n_iter=t, collect_symbols=False,
                                 collect_xs=False),
                    EcsqTransport(), FixedSchedule(deltas))

    # batched service: everything lands in one bucket -> one solve_het call
    # (quanta sized to the load so the bucket pads nothing; the default
    # 256-element quantum would double the padded compute at N=128)
    svc = SolveService(policy=BucketPolicy(max_batch=max(b, 1),
                                           n_quantum=64, mp_quantum=8),
                       rate_accounting=False)
    if prewarm:
        svc.prewarm([PrewarmSpec(n=n, m=m, n_proc=p, n_iter=t,
                                 policy="fixed", prior=prior,
                                 batch_widths=(b,))])

    times, results = time_variants(
        {"seq": lambda: [eng.solve(r.y, r.a) for r in reqs],
         "svc": lambda: svc.solve(reqs)}, reps)

    # correctness spot check: batched == sequential estimates
    max_mse_diff = max(
        float(np.mean((sr.x - br.x) ** 2))
        for sr, br in zip(results["seq"], results["svc"]))
    return times["seq"], times["svc"], max_mse_diff


def bench_latency(n: int, m: int, p: int, t: int, n_req: int, reps: int,
                  prewarm: bool):
    """End-to-end request latency (submit -> result) through a prewarmed
    continuous-batching stream; percentiles over all reps pooled, plus
    the service's hot-path counters and the telemetry plane's health
    columns (SE-drift percentile, incomplete span trees — DESIGN.md
    §12)."""
    import numpy as np
    from repro.serving import BucketPolicy, PrewarmSpec, SolveService
    from repro.telemetry import DRIFT_ALERT, missing_spans, spans_monotonic

    prior, _, reqs, _ = make_load(n, m, p, t, n_req)
    svc = SolveService(policy=BucketPolicy(max_batch=16, n_quantum=64,
                                           mp_quantum=8),
                       rate_accounting=False)
    if prewarm:
        svc.prewarm([PrewarmSpec(n=n, m=m, n_proc=p, n_iter=t,
                                 policy="fixed", prior=prior)])
    list(svc.stream(iter(reqs)))          # warmup (compiles + cache fill)
    compiles_warm = svc.compile_count()

    lats, steady = [], []
    for _ in range(reps):
        base = svc._next_id
        tsub = []

        def feed():
            for r in reqs:
                tsub.append(time.perf_counter())
                yield dataclass_replace(r)

        for res in svc.stream(feed()):
            lats.append(time.perf_counter() - tsub[res.request_id - base])
            steady.append(res)

    lats_ms = np.asarray(lats) * 1e3
    drifts = [r.se_drift for r in steady
              if r.se_drift is not None and np.isfinite(r.se_drift)]
    incomplete = sum(1 for r in steady
                     if missing_spans(r.spans)
                     or not spans_monotonic(r.spans))
    stats = svc.stats()
    return {
        "n": n, "m": m, "p": p, "t": t, "n_req": n_req, "reps": reps,
        "prewarm": prewarm,
        "p50_ms": float(np.percentile(lats_ms, 50)),
        "p95_ms": float(np.percentile(lats_ms, 95)),
        "p99_ms": float(np.percentile(lats_ms, 99)),
        "mean_ms": float(lats_ms.mean()),
        "steady_state_compiles": svc.compile_count() - compiles_warm,
        # telemetry health (DESIGN.md §12): drift is advisory at this
        # small N (heavy-tailed finite-size realization noise, see
        # tests/test_telemetry.py), incomplete span trees must be 0
        "se_drift_p95": (float(np.percentile(drifts, 95))
                         if drifts else None),
        "se_drift_median": (float(np.median(drifts)) if drifts else None),
        "se_drift_alerts": int(sum(1 for d in drifts if d > DRIFT_ALERT)),
        "monitored_requests": len(drifts),
        "incomplete_spans": int(incomplete),
    }, stats


def bench_telemetry_overhead(n: int, m: int, p: int, t: int, b: int,
                             reps: int, prewarm: bool):
    """Telemetry-plane cost on the hot path (DESIGN.md §12): the same
    B-request bucket through one prewarmed service with the telemetry
    flag toggled between solves. Acceptance (ISSUE 9): <=2% throughput
    overhead at B=32 in the deployment configuration (the SolveService
    defaults ``ClusterService``/``amp_serve`` construct backends with,
    i.e. rate accounting on). The dispatch-only lean config every other
    section of this bench uses (``rate_accounting=False``) is reported
    alongside as ``*_lean`` — the same absolute delta over a ~4x smaller
    baseline — so the per-batch telemetry cost stays visible rather
    than hidden by the denominator.

    Methodology: one instance, flag toggled at runtime — two separately
    constructed services differ by up to ~250us/solve from memory/
    program layout alone, swamping the signal. Strictly alternating
    on/off pairs (order flipped every pair), each leg averaged over a
    short inner loop (per-solve jitter suppressed before differencing),
    and the *median* of per-pair deltas — unlike min-over-reps, paired
    medians cancel machine-load drift between the two variants, which
    at a ~100us/batch signal dwarfs it on a shared box."""
    import statistics

    from repro.serving import BucketPolicy, PrewarmSpec, SolveService

    prior, _, reqs, _ = make_load(n, m, p, t, b)
    # the per-pair delta is a ~100-300us signal under ms-scale load
    # jitter: the median needs a deep pair pool to stabilize run-to-run
    pairs = max(reps, 60)
    inner = 3

    def measure(rate_accounting: bool):
        svc = SolveService(policy=BucketPolicy(max_batch=max(b, 1),
                                               n_quantum=64, mp_quantum=8),
                           rate_accounting=rate_accounting, telemetry=True)
        if prewarm:
            svc.prewarm([PrewarmSpec(n=n, m=m, n_proc=p, n_iter=t,
                                     policy="fixed", prior=prior,
                                     batch_widths=(b,))])
        for _ in range(3):                     # warmup: compiles + caches
            svc.telemetry = True
            svc.solve(reqs)
            svc.telemetry = False
            svc.solve(reqs)
        deltas, offs = [], []
        for i in range(pairs):
            order = (True, False) if i % 2 == 0 else (False, True)
            tt = {}
            for on in order:
                svc.telemetry = on
                t0 = time.perf_counter()
                for _ in range(inner):
                    svc.solve(reqs)
                tt[on] = (time.perf_counter() - t0) / inner
            offs.append(tt[False])
            deltas.append(tt[True] - tt[False])
        return statistics.median(offs), statistics.median(deltas)

    t_off, d_med = measure(rate_accounting=True)
    t_off_l, d_med_l = measure(rate_accounting=False)
    return {
        "batch": b, "pairs": pairs, "inner": inner, "prewarm": prewarm,
        "config": "deployment (rate_accounting=True)",
        "req_s_on": b / (t_off + d_med), "req_s_off": b / t_off,
        "overhead_s": d_med,
        "overhead_frac": d_med / t_off,
        "overhead_s_lean": d_med_l,
        "overhead_frac_lean": d_med_l / t_off_l,
    }


def bench_tcp_rtt(n: int, m: int, p: int, t: int, b: int, prewarm: bool):
    """Per-frame TCP round-trips over a loopback ``BackendServer`` leg
    (DESIGN.md §12): the codec + socket overhead a remote host adds per
    frame kind, measured on the same prewarmed submit/flush path the
    cluster section routes. Two passes; the window holds both, so the
    percentiles cover warm steady state plus the cold first submit."""
    from repro.serving import BucketPolicy, PrewarmSpec, SolveService
    from repro.serving.frontend import (BackendServer, LocalBackend,
                                        TcpBackend)

    prior, _, reqs, _ = make_load(n, m, p, t, b)
    policy = BucketPolicy(max_batch=max(b, 1), n_quantum=64, mp_quantum=8)
    server = BackendServer(LocalBackend(
        "loop0", SolveService(policy=policy, rate_accounting=False)))
    server.start()
    tcp = TcpBackend((server.host, server.port), "loop0")
    try:
        if prewarm:
            tcp.prewarm([PrewarmSpec(n=n, m=m, n_proc=p, n_iter=t,
                                     policy="fixed", prior=prior,
                                     batch_widths=(b,))])
        for _ in range(2):     # pass 2 is warm: compiles + cache filled
            for r in reqs:
                tcp.submit(dataclass_replace(r))
            tcp.flush()
        tcp.metrics()          # exercise the metrics frame kind too
        return tcp.rtt_stats()
    finally:
        tcp.shutdown_server()
        tcp.close()
        server.stop()


def bench_data_parallel(n: int, m: int, p: int, t: int, b: int, reps: int,
                        devices: int):
    """One bucket of B small requests through the placement dispatcher:
    batch axis sharded over the mesh when devices > 1, local otherwise."""
    from repro.launch.mesh import make_serve_mesh
    from repro.serving import BucketPolicy, SolveService
    from repro.serving.buckets import round_up

    _, _, reqs, _ = make_load(n, m, p, t, b)
    # pin the mesh to the requested device count even if the host exposes
    # more (a pre-set XLA_FLAGS would otherwise mislabel the measurement);
    # max_batch must be a device multiple for data-parallel dispatch
    mesh = make_serve_mesh(devices) if devices > 1 else None
    svc = SolveService(policy=BucketPolicy(max_batch=round_up(max(b, devices),
                                                              devices),
                                           n_quantum=64, mp_quantum=8),
                       rate_accounting=False, mesh=mesh)
    res = svc.solve(reqs)  # warmup/compile
    placement = res[0].bucket.placement
    dt, _ = best_of(lambda: svc.solve(reqs), reps)
    return dt, placement, len(svc._engines)


def bench_proc_sharded(n: int, m: int, p: int, t: int, reps: int,
                       devices: int):
    """One large single request: processor-sharded over the mesh (exact
    and int8-compressed wire) when devices > 1, local otherwise."""
    from repro.launch.mesh import make_serve_mesh
    from repro.serving import BucketPolicy, SolveService
    from repro.serving.buckets import round_up

    _, _, reqs, _ = make_load(n, m, p, t, 1)
    req = reqs[0]
    mesh = make_serve_mesh(devices) if devices > 1 else None
    max_batch = round_up(128, devices)
    out = {}
    for transport in ("ecsq", "block8"):
        svc = SolveService(policy=BucketPolicy(shard_elems=1,
                                               max_batch=max_batch),
                           rate_accounting=False, mesh=mesh)
        r = dataclass_replace(req, transport=transport,
                              policy="lossless", deltas=None)
        res, = svc.solve([r])  # warmup/compile
        dt, _ = best_of(lambda: svc.solve([r]), reps)
        out[transport] = {"seconds": dt, "placement": res.bucket.placement}
    return out


def bench_col_bucket(n: int, m: int, p: int, t: int, b: int, reps: int,
                     devices: int):
    """A tall-N bucket (auto-routed to the C-MP-AMP column layout,
    DESIGN.md §7) through the same dispatcher: layout routing must not
    cost throughput relative to a row bucket of the same element count."""
    from repro.launch.mesh import make_serve_mesh
    from repro.serving import BucketPolicy, SolveService
    from repro.serving.buckets import round_up

    _, _, reqs, s0s = make_load(n, m, p, t, b, eps=0.02, layout=None)
    mesh = make_serve_mesh(devices) if devices > 1 else None
    svc = SolveService(policy=BucketPolicy(max_batch=round_up(max(b, devices),
                                                              devices),
                                           n_quantum=64, mp_quantum=8),
                       rate_accounting=False, mesh=mesh)
    res = svc.solve(reqs)  # warmup/compile
    assert res[0].bucket.layout == "col", res[0].bucket
    import numpy as np
    mse = float(np.mean([r.mse(s) for r, s in zip(res, s0s)]))
    dt, _ = best_of(lambda: svc.solve(reqs), reps)
    return dt, res[0].bucket.placement, mse


def bench_wire(n: int, m: int, p: int, t: int, b: int, reps: int,
               erasure: float):
    """Measured-wire accounting (DESIGN.md §10): every request opts into
    ``measure_wire``; the clean pass pins measured rANS payload against
    the model entropy, the lossy pass (``erasure > 0``) reports the byte
    cost of each recovery policy on the same masks."""
    import numpy as np
    from repro.serving import BucketPolicy, SolveService

    _, _, reqs, s0s = make_load(n, m, p, t, b)
    svc = SolveService(policy=BucketPolicy(max_batch=max(b, 1),
                                           n_quantum=64, mp_quantum=8))

    def run(rate, recovery):
        wreqs = [dataclass_replace(r, measure_wire=True, erasure_rate=rate,
                                   erasure_seed=i, recovery=recovery)
                 for i, r in enumerate(reqs)]
        svc.solve(wreqs)                   # warmup/compile
        dt, res = best_of(lambda: svc.solve(wreqs), reps)
        row = {
            "seconds": dt,
            "mse": float(np.mean([r.mse(s)
                                  for r, s in zip(res, s0s)])),
            "bytes_on_wire": float(np.mean([r.bytes_on_wire
                                            for r in res])),
            "payload_bytes": float(np.mean([r.payload_bytes
                                            for r in res])),
            "time_on_air_s": float(np.mean([r.time_on_air_s
                                            for r in res])),
            "energy_j": float(np.mean([r.energy_j for r in res])),
        }
        # delivered-rate model bytes (H_Q per element per processor) —
        # the number the measured rANS payload must land within ~5% of;
        # reported rates are on-the-wire, so undo the recovery factor
        from repro.core.rate_alloc import erasure_rate_factors
        _, _, wire_f = erasure_rate_factors(rate, recovery)
        model = []
        for r in res:
            fin = np.isfinite(r.rates) & (r.rates > 0)
            delivered = r.rates[fin].sum() / wire_f
            lossless = float((~fin).sum()) * 32.0
            model.append((delivered * p + lossless * p) * n / 8.0)
        row["model_payload_bytes"] = float(np.mean(model))
        row["payload_vs_model"] = (row["payload_bytes"]
                                   / row["model_payload_bytes"])
        return row

    out = {"clean": run(0.0, "retransmit")}
    if erasure > 0.0:
        out["retransmit"] = run(erasure, "retransmit")
        out["rate_up"] = run(erasure, "rate_up")
    return out


def bench_cluster(n: int, m: int, p: int, t: int, b: int, reps: int,
                  hosts: int, prewarm: bool):
    """Multi-host elastic serving plane (DESIGN.md §11), emulated on one
    box: a ``ClusterService`` over ``hosts`` in-process backends vs a
    single ``SolveService`` on the same total device count.

    Single-core emulation methodology: the box cannot run two hosts'
    XLA programs genuinely in parallel, so the bench *routes* the full
    stream through the real cluster router (``partition``), times each
    host's share in isolation, and reports

        cluster wall = max over hosts of (share wall) + routing overhead

    — the wall a real 2-host deployment would see, assuming hosts
    compute concurrently (they do: separate processes, separate
    devices) and the router is the only serial stage (it is: routing is
    pure bookkeeping, measured here as the min over reps of a warm
    ``partition`` pass — steady-state routing cost, not first-call dict
    setup). The baseline and every host share are timed interleaved
    round-robin in the same rep loop (``time_variants``): timing them
    in separate sequential loops lets a few percent of box-load drift
    masquerade as a scaling loss. Aggregate req/s and weak scaling
    derive from that wall. A full ``ClusterService.solve`` pass then
    pins bit-identity against the single-host results and the
    zero-steady-state-compile invariant.
    """
    import numpy as np
    from repro.serving import (BucketPolicy, ClusterService, PrewarmSpec,
                               RouterPolicy, SolveService)

    prior, _, reqs, _ = make_load(n, m, p, t, b)
    policy = BucketPolicy(max_batch=8, n_quantum=64, mp_quantum=8)
    menu = [PrewarmSpec(n=n, m=m, n_proc=p, n_iter=t, policy="fixed",
                        prior=prior, batch_widths=(8,))]

    # single-host baseline: same policy, same prewarm, whole stream
    svc = SolveService(policy=policy, rate_accounting=False)
    if prewarm:
        svc.prewarm(menu)
    base_res = svc.solve(reqs)                    # warmup + reference

    # cluster: every bucket replicated on every host (min_replicas) so
    # the least-loaded router spreads one bucket's traffic — the regime
    # the weak-scaling claim is about
    cl = ClusterService(n_hosts=hosts, policy=policy,
                        router_policy=RouterPolicy(min_replicas=hosts),
                        rate_accounting=False)
    if prewarm:
        cl.prewarm(menu)

    shares = cl.partition(reqs)                   # cold pass fixes shares
    route_overhead, _ = best_of(lambda: cl.partition(reqs), reps)

    for hid, share in shares.items():             # warmup per host
        cl.backends[hid].service.solve(share)
    compiles_warm = cl.compile_count()

    ops = {"1host": lambda: svc.solve(reqs)}
    for hid, share in shares.items():
        ops[hid] = (lambda be=cl.backends[hid], sh=share:
                    be.service.solve(sh))
    walls, _ = time_variants(ops, reps)
    wall_1 = walls["1host"]
    host_walls = {hid: walls[hid] for hid in shares}
    wall_cluster = max(host_walls.values()) + route_overhead

    # bit-identity: the routed stream through the full frontend must
    # reproduce the single-host results exactly (same padded batch
    # width -> same compiled program; vmap lanes are independent)
    cl_res = cl.solve(reqs)
    max_dx = max(float(np.max(np.abs(cr.x - br.x)))
                 for cr, br in zip(cl_res, base_res))

    rt = cl.router.stats()
    return {
        "hosts": hosts, "n": n, "m": m, "p": p, "t": t, "batch": b,
        "max_batch": policy.max_batch, "prewarm": prewarm,
        "req_s_1host": b / wall_1,
        "req_s_cluster": b / wall_cluster,
        "weak_scaling": wall_1 / wall_cluster,
        "per_host_req_s": {hid: len(shares[hid]) / w
                           for hid, w in host_walls.items()},
        "share_sizes": {hid: len(s) for hid, s in shares.items()},
        "route_overhead_s": route_overhead,
        "imbalance": rt["imbalance"],
        "steady_state_compiles": cl.compile_count() - compiles_warm,
        "bitwise_max_abs_diff": max_dx,
        "methodology": "emulated hosts on one box: stream routed by the "
                       "real ClusterRouter (partition), baseline and "
                       "host shares timed interleaved round-robin, "
                       "cluster wall = max host wall + steady-state "
                       "routing overhead (min over warm partitions)",
    }


def bench_chaos(n: int, m: int, p: int, t: int, b: int, hosts: int,
                prewarm: bool):
    """Failure-injection drill (DESIGN.md §13): the same stream through
    a cluster whose last host is killed mid-stream by a deterministic
    ``FaultPlan``, measuring what fault tolerance costs and proving
    what it preserves — zero lost requests, bit-identical replays, and
    the detect -> recovered latency distribution. The kill lands on the
    victim's 5th submit, stranding requests in an open partial batch
    (the hardest case: failover must re-form the group on survivors at
    the same padded width)."""
    import numpy as np
    from repro.serving import (BucketPolicy, ChaosBackend, ClusterService,
                               FaultPlan, LocalBackend, PrewarmSpec,
                               RouterPolicy, SolveService)

    prior, _, reqs, _ = make_load(n, m, p, t, b)
    policy = BucketPolicy(max_batch=8, n_quantum=64, mp_quantum=8)
    menu = [PrewarmSpec(n=n, m=m, n_proc=p, n_iter=t, policy="fixed",
                        prior=prior, batch_widths=(8,))]

    ref = SolveService(policy=policy, rate_accounting=False)
    if prewarm:
        ref.prewarm(menu)
    base_res = ref.solve(reqs)

    victim = f"host{hosts - 1}"
    backends = [LocalBackend(f"host{i}",
                             SolveService(policy=policy,
                                          rate_accounting=False))
                for i in range(hosts - 1)]
    backends.append(ChaosBackend(
        LocalBackend(victim, SolveService(policy=policy,
                                          rate_accounting=False)),
        FaultPlan.kill_at(5)))
    cl = ClusterService(
        backends=backends, policy=policy,
        router_policy=RouterPolicy(min_replicas=hosts, suspect_after=1,
                                   dead_after=2, retry_limit=2,
                                   retry_backoff_s=0.0))
    if prewarm:
        cl.prewarm(menu)

    t0 = time.perf_counter()
    got = sorted(cl.solve(reqs), key=lambda r: r.request_id)
    wall = time.perf_counter() - t0

    max_dx = max(float(np.max(np.abs(cr.x - br.x)))
                 for cr, br in zip(got, base_res))
    st = cl.stats()
    rec = st["recovery"] or {}
    out = {
        "hosts": hosts, "batch": b, "victim": victim,
        "fault_plan": "kill_at(5)",
        "completed": len(got), "admitted": len(reqs),
        "lost": st["lost"], "failovers": st["failovers"],
        "retries": st["retries"],
        "retries_per_request": st["retries"] / max(1, len(reqs)),
        "host_states": st["host_states"],
        "recovery_p50_ms": rec.get("p50_ms"),
        "recovery_p95_ms": rec.get("p95_ms"),
        "recovered": rec.get("count", 0),
        "wall_s": wall,
        "bitwise_max_abs_diff": max_dx,
    }
    cl.close()
    return out


def dataclass_replace(req, **kw):
    import dataclasses
    return dataclasses.replace(req, request_id=-1, **kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller problem + widths, fewer reps (CI sanity)")
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--devices", type=int, default=1,
                    help="force this many host-platform devices (mesh "
                         "placements activate above 1)")
    ap.add_argument("--erasure", type=float, default=0.0,
                    help="packet-drop rate for the measured-wire section "
                         "(runs both recovery policies at this rate)")
    ap.add_argument("--hosts", type=int, default=2,
                    help="emulated host count for the cluster section "
                         "(DESIGN.md §11); 1 skips it")
    ap.add_argument("--chaos", action="store_true",
                    help="run the failure-injection drill: kill one "
                         "emulated host mid-stream and report recovery "
                         "latency + zero-loss counters (DESIGN.md §13)")
    ap.add_argument("--no-prewarm", dest="prewarm", action="store_false",
                    help="skip SolveService.prewarm (measures cold-ish "
                         "services; compiles still leave the timed region "
                         "via the warmup pass)")
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="machine-readable output path ('' disables)")
    args = ap.parse_args()

    # forcing more host devices than cores measures thread contention, not
    # data-parallel scaling (ROADMAP open item): clamp and say so, so
    # BENCH_serve.json numbers are always from a real-parallelism config
    cores = os.cpu_count() or 1
    if args.devices > cores:
        print(f"WARNING: --devices {args.devices} exceeds the "
              f"{cores} available cores; clamping to {cores} so the "
              f"benchmark measures scaling, not oversubscription")
        args.devices = cores

    if args.devices > 1:
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()

    import jax  # first jax import happens after XLA_FLAGS is set

    assert jax.device_count() >= args.devices, \
        (jax.device_count(), args.devices)

    from roofline import git_commit  # benchmarks/ is the script dir

    report = {"devices": args.devices, "smoke": bool(args.smoke),
              "backend": jax.default_backend(), "commit": git_commit(),
              "jax_device_count": jax.device_count(),
              "methodology": {
                  "timing": "warmup excluded; min over reps with variants "
                            "interleaved round-robin per round",
                  "prewarm": bool(args.prewarm)},
              "batched": [], "latency": {}, "counters": {},
              "data_parallel": {}, "proc_sharded": {}}

    # the serving regime: many small per-user recoveries, where a single
    # solve is per-dispatch/per-op overhead-bound and batching amortizes it
    n, m, p, t = 128, 64, 4, 8
    if args.smoke:
        widths, reps = (1, 8, 32), 3
    else:
        widths, reps = (1, 8, 32, 128), args.reps

    print(f"problem: N={n} M={m} P={p} T={t}  (ECSQ fixed schedule, CPU="
          f"{jax.default_backend() == 'cpu'}, prewarm={args.prewarm})")
    print(f"{'B':>4s} {'seq req/s':>10s} {'svc req/s':>10s} "
          f"{'speedup':>8s} {'max mse diff':>13s}")
    speedups = {}
    for b in widths:
        dt_seq, dt_svc, dmse = bench_width(n, m, p, t, b, reps,
                                           args.prewarm)
        sp = dt_seq / dt_svc
        speedups[b] = sp
        print(f"{b:4d} {b / dt_seq:10.1f} {b / dt_svc:10.1f} "
              f"{sp:7.2f}x {dmse:13.2e}")
        report["batched"].append({
            "batch": b, "seq_req_s": b / dt_seq, "svc_req_s": b / dt_svc,
            "speedup": sp, "max_mse_diff": dmse})

    # telemetry-plane overhead at the acceptance batch width (ISSUE 9):
    # one prewarmed service, telemetry flag toggled, paired medians
    tel = bench_telemetry_overhead(n, m, p, t, 32, reps, args.prewarm)
    print(f"\ntelemetry overhead (B=32): on {tel['req_s_on']:.1f} req/s  "
          f"off {tel['req_s_off']:.1f} req/s  "
          f"({tel['overhead_frac'] * 100:+.2f}% deployment, "
          f"{tel['overhead_frac_lean'] * 100:+.2f}% lean dispatch-only)")
    report["telemetry_overhead"] = tel

    # hot-path latency percentiles through a prewarmed stream (ISSUE 6)
    n_req, lat_reps = (48, 2) if args.smoke else (96, 4)
    latency, counters = bench_latency(n, m, p, t, n_req, lat_reps,
                                      args.prewarm)
    print(f"\nlatency (stream, B<=16): p50 {latency['p50_ms']:.2f} ms  "
          f"p95 {latency['p95_ms']:.2f} ms  p99 {latency['p99_ms']:.2f} ms  "
          f"steady-state compiles {latency['steady_state_compiles']}")
    print(f"telemetry health: se-drift median "
          f"{latency['se_drift_median']:.3f} / p95 "
          f"{latency['se_drift_p95']:.3f} "
          f"({latency['se_drift_alerts']} alert(s) over "
          f"{latency['monitored_requests']} monitored), "
          f"{latency['incomplete_spans']} incomplete span trees")
    oc = counters["operand_cache"]
    print(f"operand cache: {oc['hits']} hits / {oc['misses']} misses / "
          f"{oc['evictions']} evictions ({oc['bytes'] / 1024:.0f} KiB); "
          f"compiles {counters['compiles']['total']}; singleton dispatches "
          f"{counters['singleton_dispatches']}")
    report["latency"] = latency
    report["counters"] = counters

    # data-parallel placement: a compute-bound bucket where sharding the
    # batch across devices pays (the tiny dispatch-bound load above would
    # only measure collective overhead)
    ndp, mdp, bdp = (512, 128, 8) if args.smoke else (2048, 512, 32)
    dt_dp, placement, n_buckets = bench_data_parallel(
        ndp, mdp, p, t, bdp, max(2, reps // 2), args.devices)
    print(f"\ndata-parallel bucket: N={ndp} M={mdp} B={bdp} "
          f"placement={placement} devices={args.devices}: "
          f"{bdp / dt_dp:.1f} req/s")
    report["data_parallel"] = {
        "n": ndp, "m": mdp, "batch": bdp, "placement": placement,
        "req_s": bdp / dt_dp, "seconds": dt_dp,
        "compiled_buckets": n_buckets}

    # processor-sharded placement: one large request, the mesh axis as the
    # paper's P, exact vs compressed wire
    nps, mps, pps = (2048, 512, 8) if args.smoke else (8192, 2048, 8)
    proc = bench_proc_sharded(nps, mps, pps, t, max(2, reps // 2),
                              args.devices)
    for tr, row in proc.items():
        print(f"proc-sharded single:  N={nps} M={mps} P={pps} wire={tr} "
              f"placement={row['placement']}: {row['seconds']*1e3:.1f} ms")
    report["proc_sharded"] = {"n": nps, "m": mps, "p": pps, **proc}

    # column-layout bucket: tall-N requests auto-routed to C-MP-AMP
    # (DESIGN.md §7) through the same dispatcher
    ncb, mcb, bcb = (1024, 128, 8) if args.smoke else (4096, 512, 16)
    dt_cb, placement_cb, mse_cb = bench_col_bucket(
        ncb, mcb, p, t, bcb, max(2, reps // 2), args.devices)
    print(f"column bucket:        N={ncb} M={mcb} B={bcb} "
          f"placement={placement_cb} layout=col: {bcb / dt_cb:.1f} req/s "
          f"(mse {mse_cb:.2e})")
    report["col_bucket"] = {
        "n": ncb, "m": mcb, "batch": bcb, "placement": placement_cb,
        "req_s": bcb / dt_cb, "seconds": dt_cb, "mse": mse_cb}

    # cluster tier (DESIGN.md §11): weak scaling across emulated hosts,
    # bit-identity vs single-host, zero steady-state recompiles
    if args.hosts > 1:
        bcl = 32 if args.smoke else 64
        cluster = bench_cluster(n, m, p, t, bcl, max(2, reps // 2),
                                args.hosts, args.prewarm)
        print(f"\ncluster ({args.hosts} emulated hosts, B={bcl}, "
              f"max_batch={cluster['max_batch']}):")
        print(f"  1-host {cluster['req_s_1host']:.1f} req/s -> cluster "
              f"{cluster['req_s_cluster']:.1f} req/s "
              f"({cluster['weak_scaling']:.2f}x weak scaling, route "
              f"overhead {cluster['route_overhead_s']*1e3:.2f} ms)")
        print(f"  shares {cluster['share_sizes']}  imbalance "
              f"{cluster['imbalance']:.2f}x  steady-state compiles "
              f"{cluster['steady_state_compiles']}  max|dx| "
              f"{cluster['bitwise_max_abs_diff']:.1e}")
        # measured per-frame TCP round-trips on a loopback BackendServer
        # leg (DESIGN.md §12): what a real remote host adds per frame kind
        rtt = bench_tcp_rtt(n, m, p, t, bcl, args.prewarm)
        line = "  ".join(f"{op}: p50 {s['p50_ms']:.2f}ms "
                         f"p95 {s['p95_ms']:.2f}ms (n={s['count']})"
                         for op, s in rtt.items())
        print(f"  loopback frame rtt  {line}")
        cluster["tcp_rtt"] = rtt
        report["cluster"] = cluster

    # chaos drill (DESIGN.md §13): kill one emulated host mid-stream;
    # the gate is zero lost requests and bit-identical failover replays,
    # the measurement is recovery latency + retry cost
    if args.chaos and args.hosts > 1:
        bch = 16 if args.smoke else 32
        chaos = bench_chaos(n, m, p, t, bch, args.hosts, args.prewarm)
        print(f"\nchaos ({args.hosts} hosts, B={bch}, "
              f"{chaos['fault_plan']} on {chaos['victim']}):")
        print(f"  {chaos['completed']}/{chaos['admitted']} completed, "
              f"{chaos['lost']} lost, {chaos['failovers']} failover(s), "
              f"{chaos['retries']} retries "
              f"({chaos['retries_per_request']:.2f}/req)")
        rec_p50 = chaos["recovery_p50_ms"]
        rec_p95 = chaos["recovery_p95_ms"]
        print(f"  recovery p50 "
              f"{-1.0 if rec_p50 is None else rec_p50:.1f} ms  p95 "
              f"{-1.0 if rec_p95 is None else rec_p95:.1f} ms "
              f"(n={chaos['recovered']})  max|dx| "
              f"{chaos['bitwise_max_abs_diff']:.1e}  states "
              f"{chaos['host_states']}")
        report["chaos"] = chaos

    # measured wire bytes (DESIGN.md §10): rANS payload vs model entropy,
    # plus the lossy-link byte cost per recovery policy at --erasure.
    # Config is smoke-independent: byte counts are deterministic, so the
    # CI smoke run compares directly against the committed full baseline
    bwire = 8
    wire = bench_wire(n, m, p, t, bwire, max(2, reps // 2), args.erasure)
    print(f"\nmeasured wire (B={bwire}, erasure={args.erasure}):")
    print(f"{'variant':>12s} {'payload B':>10s} {'model B':>10s} "
          f"{'ratio':>6s} {'wire B':>10s} {'energy J':>9s} {'mse':>9s}")
    for name, row in wire.items():
        print(f"{name:>12s} {row['payload_bytes']:10.0f} "
              f"{row['model_payload_bytes']:10.0f} "
              f"{row['payload_vs_model']:6.3f} {row['bytes_on_wire']:10.0f} "
              f"{row['energy_j']:9.2e} {row['mse']:9.2e}")
    report["wire"] = {"n": n, "m": m, "p": p, "t": t, "batch": bwire,
                      "erasure": args.erasure, **wire}

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"\nwrote {args.json}")

    failures = []
    # 2x re-baselined under the interleaved methodology (a fully warmed
    # sequential loop runs ~2.5x faster than the old per-variant timing
    # credited it; B=32 measures 2.3-2.9x on 2-8 core CPU)
    if 32 in speedups and speedups[32] < 2.0:
        failures.append(f"B=32 speedup {speedups[32]:.2f}x below the 2x "
                        f"acceptance target")
    if args.prewarm and 1 in speedups and speedups[1] < 1.0:
        failures.append(f"B=1 speedup {speedups[1]:.2f}x below the 1x "
                        f"acceptance target (prewarm + singleton fast "
                        f"path, ISSUE 6)")
    if tel["overhead_frac"] > 0.02:
        failures.append(f"telemetry overhead "
                        f"{tel['overhead_frac'] * 100:.2f}% above the 2% "
                        f"B=32 acceptance budget (ISSUE 9)")
    if latency["incomplete_spans"] != 0:
        failures.append(f"{latency['incomplete_spans']} requests returned "
                        f"incomplete span trees (must be 0)")
    if "cluster" in report:
        cl = report["cluster"]
        if cl["hosts"] == 2 and cl["weak_scaling"] < 1.8:
            failures.append(f"cluster weak scaling "
                            f"{cl['weak_scaling']:.2f}x below the 1.8x "
                            f"2-host acceptance target (ISSUE 8)")
        if args.prewarm and cl["steady_state_compiles"] != 0:
            failures.append(f"cluster ran "
                            f"{cl['steady_state_compiles']} steady-state "
                            f"compiles after prewarm (must be 0)")
        if cl["bitwise_max_abs_diff"] != 0.0:
            failures.append(f"cluster results differ from single-host by "
                            f"max|dx|={cl['bitwise_max_abs_diff']:.2e} "
                            f"(must be bit-identical)")
    if "chaos" in report:
        ch = report["chaos"]
        if ch["lost"] != 0 or ch["completed"] != ch["admitted"]:
            failures.append(f"chaos drill lost "
                            f"{ch['admitted'] - ch['completed']} "
                            f"request(s) (must be 0)")
        if ch["bitwise_max_abs_diff"] != 0.0:
            failures.append(f"chaos failover replays differ from "
                            f"single-host by max|dx|="
                            f"{ch['bitwise_max_abs_diff']:.2e} "
                            f"(must be bit-identical)")
        if ch["retries"] == 0:
            failures.append("chaos drill recorded no retries despite "
                            "killing a host")
    for msg in failures:
        print(f"WARNING: {msg}")
    # --smoke is a CI sanity check on shared runners: surface the
    # number, never turn wall-clock jitter into a red build
    return 0 if (args.smoke or not failures) else 1


if __name__ == "__main__":
    sys.exit(main())
