"""Serving-layer benchmark: batched SolveService vs sequential solves.

For each batch width B, solves the same B CS requests two ways:

  * sequential — one ``AmpEngine.solve`` per request (the pre-serving code
    path: compiled scan, no per-iteration host sync, but one dispatch per
    request), and
  * service    — one ``SolveService`` call, i.e. a single vmapped
    ``solve_het`` dispatch over the whole bucket.

Reports requests/s and the batched/sequential speedup (ISSUE 2 acceptance:
>=5x at B=32 on CPU).

  PYTHONPATH=src python benchmarks/bench_serve.py [--smoke]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import jax
import numpy as np

from repro.core.amp import sample_problem
from repro.core.denoisers import BernoulliGauss
from repro.core.engine import (AmpEngine, EcsqTransport, EngineConfig,
                               FixedSchedule)
from repro.core.state_evolution import CSProblem
from repro.serving import BucketPolicy, SolveRequest, SolveService


def make_load(n: int, m: int, p: int, t: int, b: int, eps: float = 0.1):
    prior = BernoulliGauss(eps=eps)
    prob = CSProblem(n=n, m=m, prior=prior, snr_db=20.0)
    deltas = np.full(t, 0.05, np.float32)
    deltas[0] = np.inf
    reqs, s0s = [], []
    for i in range(b):
        s0, a, y = sample_problem(jax.random.PRNGKey(i), n, m, prior,
                                  prob.sigma_e2)
        reqs.append(SolveRequest(y=y, a=a, prior=prior, n_proc=p, n_iter=t,
                                 policy="fixed", deltas=deltas))
        s0s.append(s0)
    return prior, deltas, reqs, s0s


def bench_width(n: int, m: int, p: int, t: int, b: int, reps: int):
    prior, deltas, reqs, s0s = make_load(n, m, p, t, b)

    # sequential baseline: one engine (compile shared across requests),
    # one dispatch per request
    eng = AmpEngine(prior,
                    EngineConfig(n_proc=p, n_iter=t, collect_symbols=False,
                                 collect_xs=False),
                    EcsqTransport(), FixedSchedule(deltas))
    eng.solve(reqs[0].y, reqs[0].a)  # warmup/compile

    def run_seq():
        return [eng.solve(r.y, r.a) for r in reqs]

    def best_of(fn):
        # min over reps: robust to noisy-neighbor jitter on shared hosts
        best, out = float("inf"), None
        for _ in range(reps):
            t0 = time.time()
            res = fn()
            best = min(best, time.time() - t0)
            out = res
        return best, out

    dt_seq, seq_res = best_of(run_seq)

    # batched service: everything lands in one bucket -> one solve_het call
    # (quanta sized to the load so the bucket pads nothing; the default
    # 256-element quantum would double the padded compute at N=128)
    svc = SolveService(policy=BucketPolicy(max_batch=max(b, 1),
                                           n_quantum=64, mp_quantum=8),
                       rate_accounting=False)
    svc.solve(reqs)  # warmup/compile
    dt_svc, svc_res = best_of(lambda: svc.solve(reqs))

    # correctness spot check: batched == sequential estimates
    max_mse_diff = max(
        float(np.mean((sr.x - br.x) ** 2))
        for sr, br in zip(seq_res, svc_res))
    return dt_seq, dt_svc, max_mse_diff


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller problem + widths, 1 rep (CI sanity)")
    ap.add_argument("--reps", type=int, default=10)
    args = ap.parse_args()

    # the serving regime: many small per-user recoveries, where a single
    # solve is per-dispatch/per-op overhead-bound and batching amortizes it
    n, m, p, t = 128, 64, 4, 8
    if args.smoke:
        widths, reps = (1, 8, 32), 3
    else:
        widths, reps = (1, 8, 32, 128), args.reps

    print(f"problem: N={n} M={m} P={p} T={t}  (ECSQ fixed schedule, CPU="
          f"{jax.default_backend() == 'cpu'})")
    print(f"{'B':>4s} {'seq req/s':>10s} {'svc req/s':>10s} "
          f"{'speedup':>8s} {'max mse diff':>13s}")
    rows = []
    speedups = {}
    for b in widths:
        dt_seq, dt_svc, dmse = bench_width(n, m, p, t, b, reps)
        sp = dt_seq / dt_svc
        speedups[b] = sp
        print(f"{b:4d} {b / dt_seq:10.1f} {b / dt_svc:10.1f} "
              f"{sp:7.2f}x {dmse:13.2e}")
        rows.append(f"serve_b{b},{dt_svc / b * 1e6:.0f},"
                    f"speedup_vs_seq={sp:.2f}x;max_mse_diff={dmse:.2e}")

    print("\nname,us_per_request,derived")
    for r in rows:
        print(r)
    if 32 in speedups and speedups[32] < 5.0:
        print(f"WARNING: B=32 speedup {speedups[32]:.2f}x below the 5x "
              f"acceptance target")
        # --smoke is a CI sanity check on shared runners: surface the
        # number, never turn wall-clock jitter into a red build
        return 0 if args.smoke else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
