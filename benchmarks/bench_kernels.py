"""LC kernel-suite benchmark: the machine-readable kernel perf trajectory.

Times the fused AMP local-computation step per (layout x batch x P) cell
in three variants (DESIGN.md §8):

  * ``vmap_ref``  — the pre-v2 baseline: per-processor LC ``vmap``ed over
    P (and again over the batch), sum-of-squares reduction separate;
  * ``batched``   — the v2 engine path: one batched-grid fused op over
    the whole (B, P) stack (on CPU the XLA-compiled batched reference,
    on TPU the compiled Pallas kernels);
  * ``interpret`` — the Pallas kernels through the interpreter (the CI
    parity path; orders of magnitude slower, timed for trend only).

Each cell reports achieved GB/s for the batched variant against the
``roofline.lc_bytes`` HBM model (A read exactly twice per step) and the
memory-bound time floor at the backend's bandwidth estimate
(``--bw`` overrides). Results land in ``BENCH_kernels.json`` with
backend / device / commit provenance so CI can archive the trajectory
alongside ``BENCH_serve.json``.

  PYTHONPATH=src python benchmarks/bench_kernels.py [--smoke] [--bw BPS]

Acceptance tracking: the compiled batched path must beat the
per-processor vmap baseline on the (row, B=8, P=4) cell; a miss prints a
warning (and fails a non-smoke run, mirroring bench_serve).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from roofline import BW_BY_BACKEND, git_commit, lc_bytes  # noqa: E402


def time_variants(ops: dict, reps: int, inner: int = 3) -> dict:
    """Seconds per call per variant: min over ``reps`` rounds, variants
    interleaved round-robin within each round so noisy-neighbor phases on
    shared CI boxes hit every variant equally."""
    for fn in ops.values():
        fn()  # warmup / compile
    best = {k: float("inf") for k in ops}
    for _ in range(reps):
        for k, fn in ops.items():
            t0 = time.perf_counter()
            for _ in range(inner):
                fn()
            best[k] = min(best[k], (time.perf_counter() - t0) / inner)
    return best


def make_row_ops(b: int, p: int, m: int, n: int, interpret_cells: bool):
    """(vmap_ref, batched, interpret|None) jitted row-LC steps + operands."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.amp_fused.ops import amp_local_grid, pad_row_shards
    from repro.kernels.amp_fused.ref import (amp_local_ref_grid,
                                             amp_local_ref_vmap)

    rng = np.random.default_rng(b * 131 + p)
    mp_ = m // p
    a = jnp.asarray(rng.normal(size=(b, p, mp_, n)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(b, n)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(b, p, mp_)).astype(np.float32))
    z = jnp.asarray(rng.normal(size=(b, p, mp_)).astype(np.float32))

    vb = jax.jit(jax.vmap(
        lambda a_, x_, y_, z_: amp_local_ref_vmap(a_, x_, y_, z_, 0.3, p)))
    bb = jax.jit(jax.vmap(
        lambda a_, x_, y_, z_: amp_local_ref_grid(a_, x_, y_, z_, 0.3, p)))
    if jax.default_backend() == "tpu":
        bb = jax.jit(jax.vmap(
            lambda a_, x_, y_, z_: amp_local_grid(
                a_, x_, y_, z_, 0.3, p, use_pallas=True)))

    block = lambda r: jax.block_until_ready(r)
    ops = {"vmap_ref": lambda: block(vb(a, x, y, z)),
           "batched": lambda: block(bb(a, x, y, z))}
    if interpret_cells:
        ap, yp = pad_row_shards(a, y)
        zp = jnp.pad(z, ((0, 0), (0, 0), (0, ap.shape[-2] - mp_)))
        xp = jnp.pad(x, ((0, 0), (0, ap.shape[-1] - n)))
        ib = jax.jit(jax.vmap(
            lambda a_, x_, y_, z_: amp_local_grid(
                a_, x_, y_, z_, 0.3, p, use_pallas=True, interpret=True)))
        ops["interpret"] = lambda: block(ib(ap, xp, yp, zp))
    return ops


def make_col_ops(b: int, p: int, m: int, n: int, interpret_cells: bool):
    """Column-layout per-round LC: residual pass + fused inner step."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.amp_fused.ops import (col_inner_step, col_residual,
                                             pad_col_shards)
    from repro.kernels.amp_fused.ref import (col_inner_step_ref,
                                             col_residual_ref)

    rng = np.random.default_rng(b * 173 + p)
    np_ = n // p
    a = jnp.asarray(rng.normal(size=(b, p, m, np_)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(b, p, np_)).astype(np.float32) * 0.1)
    z = jnp.asarray(rng.normal(size=(b, p, m)).astype(np.float32))
    g = jnp.asarray(rng.normal(size=(b, m)).astype(np.float32))
    mask = jnp.ones((np_,), jnp.float32)
    pri = (200.0, 0.1, 0.0, 1.0)  # m_eff, eps, mu_s, sigma_s2

    def step_ref(a_, x_, z_, g_):
        r = col_residual_ref(a_, x_)
        xn, c, _ = col_inner_step_ref(a_, x_, x_, z_, g_, mask, *pri, False)
        return r, xn, c

    def step_vmap(a_, x_, z_, g_):
        # per-processor vmap baseline: one column block at a time
        r = jax.vmap(lambda ap, xp_: ap @ xp_)(a_, x_)
        xn, c, _ = jax.vmap(
            lambda ap, xp_, zp: col_inner_step_ref(
                ap[None], xp_[None], xp_[None], zp[None], g_, mask, *pri,
                False))(a_, x_, z_)
        return r, xn, c

    def step_pallas(interpret):
        def f(a_, x_, z_, g_):
            r = col_residual(a_, x_, use_pallas=True, interpret=interpret)
            xn, c, _ = col_inner_step(a_, x_, x_, z_, g_, mask, *pri,
                                      update_z=False, use_pallas=True,
                                      interpret=interpret)
            return r, xn, c
        return f

    vb = jax.jit(jax.vmap(step_vmap))
    bb = jax.jit(jax.vmap(step_pallas(False)
                          if jax.default_backend() == "tpu" else step_ref))
    block = lambda r: jax.block_until_ready(r)
    ops = {"vmap_ref": lambda: block(vb(a, x, z, g)),
           "batched": lambda: block(bb(a, x, z, g))}
    if interpret_cells:
        apad, gpad = pad_col_shards(a, g)
        zpad = jnp.pad(z, ((0, 0), (0, 0), (0, apad.shape[-2] - m)))
        ib = jax.jit(jax.vmap(step_pallas(True)))
        ops["interpret"] = lambda: block(ib(apad, x, zpad, gpad))
    return ops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes, fewer reps, interpret on the "
                         "smallest cells only (CI)")
    ap.add_argument("--reps", type=int, default=7)
    ap.add_argument("--bw", type=float, default=None,
                    help="memory bandwidth for the roofline bound "
                         "(default: per-backend estimate)")
    ap.add_argument("--json", default="BENCH_kernels.json",
                    help="machine-readable output path ('' disables)")
    args = ap.parse_args()

    import jax

    backend = jax.default_backend()
    bw = args.bw or BW_BY_BACKEND.get(backend, BW_BY_BACKEND["cpu"])
    # smoke keeps the full problem size (at M=256-class shapes the B=8
    # cells are dispatch-dominated and the vmap-vs-batched gap drowns in
    # jitter) but trims the cell grid and reps for CI wall-clock
    if args.smoke:
        m, n, reps = 512, 2048, 4
        batches, procs = (1, 8), (1, 4)
    else:
        m, n, reps = 512, 2048, args.reps
        batches, procs = (1, 8, 32), (1, 4, 8)

    report = {
        "backend": backend, "devices": jax.device_count(),
        "commit": git_commit(), "smoke": bool(args.smoke),
        "m": m, "n": n, "bw_model": bw, "cells": [],
    }
    print(f"LC kernel suite: M={m} N={n} backend={backend} "
          f"bw_model={bw/1e9:.0f} GB/s")
    hdr = (f"{'layout':>6s} {'B':>3s} {'P':>3s} {'vmap_ref':>10s} "
           f"{'batched':>10s} {'speedup':>8s} {'GB/s':>7s} {'roofl%':>7s} "
           f"{'interpret':>10s}")
    print(hdr)
    print("-" * len(hdr))

    target = None
    for layout in ("row", "col"):
        make = make_row_ops if layout == "row" else make_col_ops
        for b in batches:
            for p in procs:
                # interpret timings only on the smallest cells: the
                # interpreter is ~100x off, trend not throughput
                interp = (b * p <= 8) if args.smoke else (b * p <= 32)
                ops = make(b, p, m, n, interp)
                cell = {"layout": layout, "batch": b, "p": p}
                for name, dt in time_variants(ops, reps).items():
                    cell[f"{name}_s"] = dt
                bytes_ = lc_bytes(m, n, batch=b)
                cell["speedup"] = cell["vmap_ref_s"] / cell["batched_s"]
                cell["achieved_gbps"] = bytes_ / cell["batched_s"] / 1e9
                cell["roofline_frac"] = (bytes_ / bw) / cell["batched_s"]
                report["cells"].append(cell)
                if layout == "row" and b == 8 and p == 4:
                    target = cell
                it = cell.get("interpret_s")
                print(f"{layout:>6s} {b:3d} {p:3d} "
                      f"{cell['vmap_ref_s']*1e3:9.3f}ms "
                      f"{cell['batched_s']*1e3:9.3f}ms "
                      f"{cell['speedup']:7.2f}x "
                      f"{cell['achieved_gbps']:7.1f} "
                      f"{100*cell['roofline_frac']:6.1f}% "
                      + (f"{it*1e3:9.1f}ms" if it else f"{'—':>10s}"))

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"\nwrote {args.json}")

    if target is not None and target["speedup"] < 1.0:
        print(f"WARNING: batched path {target['speedup']:.2f}x vs the "
              f"vmap baseline on the (row, B=8, P=4) cell — below the "
              f"acceptance target (>1x)")
        # smoke runs on shared CI runners surface the number without
        # turning wall-clock jitter into a red build
        return 0 if args.smoke else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
