"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms per (arch x shape), single-pod 16x16 mesh (256 chips), TPU v5e:
    compute    = dot_FLOPs_per_device / 197e12        [s]
    memory     = HBM_bytes_per_device / 819e9         [s]
    collective = wire_bytes_per_device / 50e9         [s]
(dry-run quantities are per-device already — SPMD HLO shapes are local).

MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill) / 2*N*B (decode), N = active
params; the ratio MODEL_FLOPS/HLO_FLOPs exposes remat/dispatch overhead.
"""
from __future__ import annotations

import glob
import json
import os
import subprocess

from repro.configs import get_config, shape_for

PEAK_FLOPS = 197e12   # bf16 / chip
HBM_BW = 819e9        # B/s / chip
ICI_BW = 50e9         # B/s / link (conservative single-link)
CHIPS = 256

# Host-memory bandwidth estimate for CPU runs of the kernel benchmark
# (benchmarks/bench_kernels.py): a single DDR4/DDR5 channel pair on a CI
# box. Only used to contextualize achieved GB/s — override with --bw.
HOST_BW = 25e9

BW_BY_BACKEND = {"tpu": HBM_BW, "cpu": HOST_BW, "gpu": 2e12}


def git_commit() -> str:
    """Short HEAD hash for benchmark-JSON provenance, ``-dirty``-suffixed
    when the working tree has uncommitted changes — local pre-commit runs
    must stay distinguishable from CI post-commit runs in the archived
    trajectory. The tracked benchmark JSONs themselves are ignored by the
    dirtiness check (CI regenerates them in-place before uploading)."""
    cwd = os.path.dirname(os.path.abspath(__file__))
    try:
        head = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10, cwd=cwd,
        ).stdout.strip()
        if not head:
            return "unknown"
        status = subprocess.run(
            ["git", "status", "--porcelain"],
            capture_output=True, text=True, timeout=10, cwd=cwd,
        ).stdout.splitlines()
        dirty = [l for l in status
                 if not l.split()[-1].startswith("BENCH_")]
        return head + ("-dirty" if dirty else "")
    except Exception:
        return "unknown"


def lc_bytes(m: int, n: int, batch: int = 1, a_bytes: int = 4,
             vec_bytes: int = 4) -> float:
    """HBM bytes moved by one fused AMP LC step (either layout).

    The sensing operand dominates: both the row LC (z-pass + f-pass) and
    the column per-round step (residual pass + message pass) read A
    exactly twice — the information-theoretic minimum for the two
    contraction orders (DESIGN.md §8). Vector traffic (y, z in; z', f
    out; x in) is the small additive term. ``a_bytes=2`` models bf16
    A-streaming (``EngineConfig.a_dtype``).
    """
    a_traffic = 2.0 * m * n * a_bytes
    vec_traffic = (4.0 * m + 3.0 * n) * vec_bytes
    return batch * (a_traffic + vec_traffic)


def lc_roofline_seconds(m: int, n: int, batch: int = 1, a_bytes: int = 4,
                        bw: float = HBM_BW) -> float:
    """Memory-bound time floor for one LC step at bandwidth ``bw``."""
    return lc_bytes(m, n, batch, a_bytes) / bw


def model_flops_per_device(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = shape_for(shape_name)
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        total = 6.0 * n_active * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        total = 2.0 * n_active * shape.global_batch * shape.seq_len
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / CHIPS


def load_cells(dryrun_dir: str, mesh: str = "pod1") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, f"*_{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("skipped"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "skipped": True, "reason": rec.get("reason", "")})
            continue
        if not rec.get("ok"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "failed": True, "error": rec.get("error", "")})
            continue
        compute = rec["dot_flops_per_device"] / PEAK_FLOPS
        memory = rec["hbm_bytes_per_device"] / HBM_BW
        coll = rec["wire_bytes_per_device"] / ICI_BW
        dominant = max(("compute", compute), ("memory", memory),
                       ("collective", coll), key=lambda kv: kv[1])
        mf = model_flops_per_device(rec["arch"], rec["shape"])
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"],
            "compute_s": compute, "memory_s": memory, "collective_s": coll,
            "dominant": dominant[0],
            "bound_s": dominant[1],
            "roofline_frac": compute / dominant[1] if dominant[1] else 0.0,
            "model_flops_per_dev": mf,
            "useful_ratio": mf / rec["dot_flops_per_device"]
            if rec["dot_flops_per_device"] else 0.0,
            "memory_gb_per_dev": (rec["memory"].get("argument_bytes", 0)
                                  + rec["memory"].get("temp_bytes", 0)) / 2**30
            if isinstance(rec.get("memory"), dict) else None,
        })
    return rows


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
           f"{'collect':>9s} {'bound':>10s} {'roofl%':>7s} {'useful%':>8s} "
           f"{'mem GB':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("skipped"):
            lines.append(f"{r['arch']:22s} {r['shape']:12s} "
                         f"{'— skipped (' + r['reason'][:40] + ')':s}")
            continue
        if r.get("failed"):
            lines.append(f"{r['arch']:22s} {r['shape']:12s} FAILED: "
                         f"{r['error'][:60]}")
            continue
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:9.4f} "
            f"{r['memory_s']:9.4f} {r['collective_s']:9.4f} "
            f"{r['dominant']:>10s} {100*r['roofline_frac']:6.1f}% "
            f"{100*min(r['useful_ratio'],9.99):7.1f}% "
            f"{r['memory_gb_per_dev']:7.2f}" if r.get("memory_gb_per_dev")
            is not None else
            f"{r['arch']:22s} {r['shape']:12s} (no memory data)")
    return "\n".join(lines)


def main(dryrun_dir: str = "results/dryrun"):
    rows = load_cells(dryrun_dir)
    print(format_table(rows))
    return rows


if __name__ == "__main__":
    main()
