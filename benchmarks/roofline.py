"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms per (arch x shape), single-pod 16x16 mesh (256 chips), TPU v5e:
    compute    = dot_FLOPs_per_device / 197e12        [s]
    memory     = HBM_bytes_per_device / 819e9         [s]
    collective = wire_bytes_per_device / 50e9         [s]
(dry-run quantities are per-device already — SPMD HLO shapes are local).

MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill) / 2*N*B (decode), N = active
params; the ratio MODEL_FLOPS/HLO_FLOPs exposes remat/dispatch overhead.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import get_config, shape_for

PEAK_FLOPS = 197e12   # bf16 / chip
HBM_BW = 819e9        # B/s / chip
ICI_BW = 50e9         # B/s / link (conservative single-link)
CHIPS = 256


def model_flops_per_device(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = shape_for(shape_name)
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        total = 6.0 * n_active * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        total = 2.0 * n_active * shape.global_batch * shape.seq_len
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / CHIPS


def load_cells(dryrun_dir: str, mesh: str = "pod1") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, f"*_{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("skipped"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "skipped": True, "reason": rec.get("reason", "")})
            continue
        if not rec.get("ok"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "failed": True, "error": rec.get("error", "")})
            continue
        compute = rec["dot_flops_per_device"] / PEAK_FLOPS
        memory = rec["hbm_bytes_per_device"] / HBM_BW
        coll = rec["wire_bytes_per_device"] / ICI_BW
        dominant = max(("compute", compute), ("memory", memory),
                       ("collective", coll), key=lambda kv: kv[1])
        mf = model_flops_per_device(rec["arch"], rec["shape"])
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"],
            "compute_s": compute, "memory_s": memory, "collective_s": coll,
            "dominant": dominant[0],
            "bound_s": dominant[1],
            "roofline_frac": compute / dominant[1] if dominant[1] else 0.0,
            "model_flops_per_dev": mf,
            "useful_ratio": mf / rec["dot_flops_per_device"]
            if rec["dot_flops_per_device"] else 0.0,
            "memory_gb_per_dev": (rec["memory"].get("argument_bytes", 0)
                                  + rec["memory"].get("temp_bytes", 0)) / 2**30
            if isinstance(rec.get("memory"), dict) else None,
        })
    return rows


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
           f"{'collect':>9s} {'bound':>10s} {'roofl%':>7s} {'useful%':>8s} "
           f"{'mem GB':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("skipped"):
            lines.append(f"{r['arch']:22s} {r['shape']:12s} "
                         f"{'— skipped (' + r['reason'][:40] + ')':s}")
            continue
        if r.get("failed"):
            lines.append(f"{r['arch']:22s} {r['shape']:12s} FAILED: "
                         f"{r['error'][:60]}")
            continue
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:9.4f} "
            f"{r['memory_s']:9.4f} {r['collective_s']:9.4f} "
            f"{r['dominant']:>10s} {100*r['roofline_frac']:6.1f}% "
            f"{100*min(r['useful_ratio'],9.99):7.1f}% "
            f"{r['memory_gb_per_dev']:7.2f}" if r.get("memory_gb_per_dev")
            is not None else
            f"{r['arch']:22s} {r['shape']:12s} (no memory data)")
    return "\n".join(lines)


def main(dryrun_dir: str = "results/dryrun"):
    rows = load_cells(dryrun_dir)
    print(format_table(rows))
    return rows


if __name__ == "__main__":
    main()
