"""Paper reproduction benchmarks: Fig. 1 and Table 1 of Han et al. 2016.

Setup (paper Sec. 4): N=10,000, M=3,000 (kappa=0.3), P=30 processors,
SNR=20 dB, Bernoulli-Gaussian prior with eps in {0.03, 0.05, 0.10},
mu_s=0, sigma_s=1. T = SE steady-state horizon (8/10/20).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.amp import amp_solve, sample_problem
from repro.core.denoisers import BernoulliGauss, make_mmse_interp
from repro.core.engine import DPSchedule
from repro.core.mp_amp import MPAMPConfig, mp_amp_solve
from repro.core.rate_alloc import BTController, bt_schedule_offline, dp_allocate
from repro.core.rate_distortion import RDModel
from repro.core.state_evolution import (PAPER_T, CSProblem, sdr,
                                        se_trajectory, steady_state_iters)

EPS_LIST = (0.03, 0.05, 0.10)
N_PROC = 30
BT_C_RATIO = 1.005   # calibrated (EXPERIMENTS.md §Paper-validation)
BT_R_MAX = 6.0

_CACHE: dict = {}


def _ctx(eps: float):
    if eps in _CACHE:
        return _CACHE[eps]
    prob = CSProblem(prior=BernoulliGauss(eps=eps))
    rd = RDModel(prob.prior)
    mmse_fn = make_mmse_interp(prob.prior)
    t_star = PAPER_T[eps]  # paper's own horizons (see state_evolution.PAPER_T)
    _CACHE[eps] = (prob, rd, mmse_fn, t_star)
    return _CACHE[eps]


def mse_to_sdr(prob, mse):
    return 10 * np.log10(prob.prior.second_moment / np.maximum(mse, 1e-30))


def run_fig1(eps: float, seed: int = 0) -> dict:
    """All curves of one Fig. 1 column: SE, centralized sim, BT sim, DP sim."""
    prob, rd, mmse_fn, t_star = _ctx(eps)
    out: dict = {"eps": eps, "T": t_star}

    # (a) centralized SE (offline) + centralized AMP (simulated)
    traj = se_trajectory(prob, t_star, mmse_fn=mmse_fn)
    out["se_sdr"] = sdr(traj[1:], prob)
    s0, a, y = sample_problem(jax.random.PRNGKey(seed), prob.n, prob.m,
                              prob.prior, prob.sigma_e2)
    cen = amp_solve(y, a, prob.prior, t_star, s0=s0)
    out["centralized_sdr"] = mse_to_sdr(prob, cen.mse)

    # (b) BT-MP-AMP: offline RD prediction + online ECSQ simulation
    bt_rates_rd, bt_sigma = bt_schedule_offline(
        prob, N_PROC, t_star, BT_C_RATIO, BT_R_MAX, "rd", rd, mmse_fn)
    out["bt_rates_rd"] = bt_rates_rd
    out["bt_sdr_rd"] = sdr(bt_sigma[1:], prob)
    ctrl = BTController(prob, N_PROC, t_star, BT_C_RATIO, BT_R_MAX,
                        rate_model="ecsq", mmse_fn=mmse_fn)
    bt_sim = mp_amp_solve(y, a, prob.prior, MPAMPConfig(N_PROC, t_star),
                          ctrl, s0=s0)
    out["bt_sdr_sim"] = mse_to_sdr(prob, bt_sim.mse)
    out["bt_rates_sim"] = bt_sim.rates_empirical

    # (c) DP-MP-AMP: offline DP (RD model) + ECSQ simulation
    dp = dp_allocate(prob, N_PROC, t_star, 2.0 * t_star, rd=rd,
                     mmse_fn=mmse_fn)
    out["dp_rates_rd"] = dp.rates
    out["dp_sdr_rd"] = sdr(dp.sigma2_d[1:], prob)
    # ECSQ implementation: quantizer bins sized to hit the DP distortions
    # predicted offline (paper: "+0.255 bits"); entropy measured empirically.
    deltas = DPSchedule(dp, rd, N_PROC).deltas
    dp_sim = mp_amp_solve(y, a, prob.prior, MPAMPConfig(N_PROC, t_star),
                          deltas, s0=s0, sigma2_for_model=dp.sigma2_d[:-1])
    out["dp_sdr_sim"] = mse_to_sdr(prob, dp_sim.mse)
    out["dp_rates_sim"] = dp_sim.rates_empirical
    return out


def run_table1() -> list[dict]:
    """Table 1: total bits/element for BT/DP x RD-prediction/ECSQ-sim."""
    rows = []
    for eps in EPS_LIST:
        t0 = time.time()
        fig = run_fig1(eps)
        rows.append({
            "eps": eps, "T": fig["T"],
            "bt_rd_total": float(np.sum(fig["bt_rates_rd"])),
            "bt_ecsq_total": float(np.sum(fig["bt_rates_sim"])),
            "dp_rd_total": float(np.sum(fig["dp_rates_rd"])),
            "dp_ecsq_total": float(np.sum(fig["dp_rates_sim"])),
            "bt_final_sdr": float(fig["bt_sdr_sim"][-1]),
            "dp_final_sdr": float(fig["dp_sdr_sim"][-1]),
            "centralized_final_sdr": float(fig["centralized_sdr"][-1]),
            "runtime_s": round(time.time() - t0, 1),
        })
    return rows


PAPER_TABLE1 = {  # reference values from the paper
    0.03: {"T": 8, "bt_rd": 33.82, "bt_ecsq": 36.09, "dp_rd": 16.0, "dp_ecsq": 18.04},
    0.05: {"T": 10, "bt_rd": 46.43, "bt_ecsq": 49.19, "dp_rd": 20.0, "dp_ecsq": 22.55},
    0.10: {"T": 20, "bt_rd": 96.16, "bt_ecsq": 101.50, "dp_rd": 40.0, "dp_ecsq": 45.10},
}
