"""Ablation: rate-allocation policies under a fixed total budget.

Compares, at eps=0.05, T=10, R=2T bits total, all through the same quantized
MP-AMP simulation:
  * DP (paper Sec. 3.4, optimal),
  * uniform (2 bits every iteration),
  * front-loaded (budget spent in the first half),
  * back-loaded (budget spent in the second half),
and BT (unbudgeted heuristic) as the reference point. This isolates the
paper's claim that *allocation across iterations* — not just quantization —
is where the DP savings come from.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.amp import sample_problem
from repro.core.denoisers import BernoulliGauss, make_mmse_interp
from repro.core.mp_amp import MPAMPConfig, mp_amp_solve
from repro.core.rate_alloc import dp_allocate
from repro.core.rate_distortion import RDModel
from repro.core.state_evolution import CSProblem


def run_ablation(eps: float = 0.05, t: int = 10, seed: int = 0):
    prob = CSProblem(prior=BernoulliGauss(eps=eps))
    rd = RDModel(prob.prior)
    mm = make_mmse_interp(prob.prior)
    p = 30
    s0, a, y = sample_problem(jax.random.PRNGKey(seed), prob.n, prob.m,
                              prob.prior, prob.sigma_e2)
    sdr = lambda mse: 10 * np.log10(prob.prior.second_moment / mse)

    r_total = 2.0 * t
    dp = dp_allocate(prob, p, t, r_total, rd=rd, mmse_fn=mm)

    def schedule_to_deltas(rates):
        # predict the sigma trajectory under this schedule, then size bins
        sig = [prob.sigma0_2]
        deltas = []
        for rt in rates:
            sq2 = float(rd.distortion_msg(max(rt, 1e-6), sig[-1], p))
            deltas.append(np.sqrt(12.0 * max(sq2, 1e-30)))
            sig.append(prob.sigma_e2 + float(mm(sig[-1] + p * sq2)) / prob.kappa)
        return np.asarray(deltas), np.asarray(sig[:-1])

    half = t // 2
    policies = {
        "dp_optimal": dp.rates,
        "uniform": np.full(t, r_total / t),
        "front_loaded": np.concatenate([np.full(half, r_total / half),
                                        np.zeros(t - half)]),
        "back_loaded": np.concatenate([np.zeros(t - half),
                                       np.full(half, r_total / half)]),
    }
    out = {}
    for name, rates in policies.items():
        deltas, sig_pred = schedule_to_deltas(rates)
        res = mp_amp_solve(y, a, prob.prior, MPAMPConfig(p, t), deltas,
                           s0=s0, sigma2_for_model=sig_pred)
        out[name] = {"final_sdr": float(sdr(res.mse[-1])),
                     "bits_spent": float(res.total_bits_empirical)}
    return out


if __name__ == "__main__":
    for k, v in run_ablation().items():
        print(f"{k:14s} SDR {v['final_sdr']:6.2f} dB  "
              f"({v['bits_spent']:.1f} bits/elem)")
