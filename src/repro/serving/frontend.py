"""Cluster frontend: admission, host backends, and the user-facing
``ClusterService`` (DESIGN.md §11).

The frontend half of the frontend/scheduler/backend split. A
``ClusterService`` owns

  * a set of **backends** — each one a ``SolveService`` on its own host
    (``LocalBackend`` in-process, e.g. one per emulated host on a dev
    box, or ``TcpBackend`` speaking the no-pickle ``serving.codec`` frame
    protocol to a ``BackendServer`` in another ``jax.distributed``
    process),
  * the **scheduler** (``serving.router``): a ``ClusterRouter`` placing
    placement-agnostic bucket keys onto hosts by load × shape, and an
    ``Autoscaler`` moving per-bucket replica counts from demand EWMAs
    scraped out of each backend's ``Batcher.take_demand`` window,
  * **admission**: global request ids, per-host outstanding-cost caps
    (shed with ``Overloaded`` when every replica of a bucket is
    saturated), and the id rewrite between backend-local and global
    request ids.

The per-host dispatch-ahead overlap is untouched — each backend's
``SolveService`` still launches engine calls asynchronously and the
frontend only ``poll``s materialized results — so the cluster tier adds
routing, not synchronization, to the hot path.

Cross-host byte traffic is exactly the codec frames: requests/results
never pickle, and the measured ``bytes_on_wire`` accounting of
DESIGN.md §10 stays per-request inside each backend.
"""
from __future__ import annotations

import dataclasses
import math
import socket
import struct
import threading
import time
from collections import deque

from ..core.state_evolution import se_trajectory
from ..telemetry import MetricsRegistry, merge_snapshots, prometheus_text
from ..telemetry.metrics import HOST_STATES, RECOVERY_BUCKETS
from ..telemetry.spans import now as _tnow
from ..telemetry.spans import span as _tspan
from ..telemetry.spans import tag_host
from .buckets import BucketPolicy
from .codec import (CodecError, bucket_from_dict, bucket_to_dict,
                    decode_metrics, decode_request, decode_result,
                    encode_metrics, encode_request, encode_result,
                    spec_from_dict, spec_to_dict)
from .router import (Autoscaler, ClusterRouter, HostInfo, Overloaded,
                     RouterPolicy, routing_key, shape_cost)
from .service import PrewarmSpec, SolveService
from .wire import (BackendError, BackendUnavailable, FrameError,
                   RemoteRequestError, pack_error, recv_frame, remote_error,
                   send_frame)

__all__ = ["LocalBackend", "BackendServer", "TcpBackend", "ClusterService",
           "ShedLadder", "Overloaded", "BackendError", "BackendUnavailable",
           "RemoteRequestError"]

import json


class LocalBackend:
    """One in-process host: a ``SolveService`` (its own engines, operand
    cache, batcher — and, on a real deployment, its own device mesh)
    behind the backend interface the frontend routes to."""

    def __init__(self, host_id: str, service: SolveService):
        self.host_id = host_id
        self.service = service

    @property
    def n_devices(self) -> int:
        return self.service.n_devices

    def submit(self, req) -> int:
        return self.service.submit(req)

    def poll(self) -> list:
        return self.service.poll()

    def flush(self) -> list:
        return self.service.flush()

    def take_demand(self) -> dict:
        return self.service.take_demand()

    def prewarm(self, menu) -> dict:
        return self.service.prewarm(menu)

    def stats(self) -> dict:
        return self.service.stats()

    def compile_count(self) -> int:
        return self.service.compile_count()

    def metrics(self) -> dict:
        return self.service.metrics()

    def ping(self) -> bool:
        """Health probe (DESIGN.md §13): in-process backends are alive by
        construction — the interesting implementation is TcpBackend's."""
        return True

    def close(self) -> None:
        pass


# -- TCP transport (codec frames over serving.wire frames) -------------------
#
# Frame protocol lives in ``serving.wire`` (send_frame/recv_frame + the
# typed error frames). Result lists nest as
# u32 count | (u32 len | result-frame)*.

_OPS = (b"S", b"P", b"F", b"D", b"W", b"T", b"C", b"N", b"Q", b"M",
        b"H", b"X")


def _pack_results(results) -> bytes:
    frames = [encode_result(r) for r in results]
    return b"".join([struct.pack("<I", len(frames))]
                    + [struct.pack("<I", len(f)) + f for f in frames])


def _unpack_results(body: bytes) -> list:
    """Decode a nested result-list body; every truncation or bad length
    raises ``CodecError`` instead of surfacing as a struct/index crash —
    a corrupt reply must read as a protocol failure, never hang or
    half-deserialize."""
    if len(body) < 4:
        raise CodecError("truncated result list (no count)")
    (count,) = struct.unpack("<I", body[:4])
    off, out = 4, []
    for i in range(count):
        if len(body) < off + 4:
            raise CodecError(f"truncated result list at entry {i}")
        (ln,) = struct.unpack("<I", body[off:off + 4])
        off += 4
        if len(body) < off + ln:
            raise CodecError(f"truncated result frame {i}")
        out.append(decode_result(body[off:off + ln]))
        off += ln
    if off != len(body):
        raise CodecError(f"{len(body) - off} trailing bytes in result list")
    return out


class _Die(Exception):
    """Raised by the ``X`` op: abrupt server death for chaos drills —
    the connection closes with NO reply frame, exactly what a crashed
    process looks like from the frontend."""


class BackendServer:
    """Serves one ``LocalBackend`` over TCP to a remote frontend. One
    frontend connection at a time (the cluster has exactly one router);
    runs on a daemon thread via ``start()``. The ``Q`` op (or ``stop()``)
    shuts it down.

    Fault model (DESIGN.md §13): per-request failures (a bad request,
    a solve raising) reply with a typed error frame carrying the remote
    traceback and the connection survives; backend-fatal conditions
    (resource exhaustion, a desynced frame stream, a frontend that went
    silent past ``idle_timeout_s``) close the connection — the listener
    keeps accepting, so a restarted frontend can reconnect."""

    #: per-request errors keep the connection; these close it
    FATAL_ERRORS = (MemoryError,)

    def __init__(self, backend: LocalBackend, host: str = "127.0.0.1",
                 port: int = 0, idle_timeout_s: float = 300.0):
        self.backend = backend
        self.idle_timeout_s = float(idle_timeout_s)
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(1)
        self.host, self.port = self._sock.getsockname()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.frames_served = 0

    def start(self) -> threading.Thread:
        th = threading.Thread(target=self.serve_forever,
                              name=f"backend-{self.backend.host_id}",
                              daemon=True)
        self._thread = th
        th.start()
        return th

    def stop(self) -> None:
        self._stop.set()
        try:
            self._sock.close()
        except OSError:
            pass

    def serve_forever(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                break   # listener closed by stop()
            with conn:
                try:
                    self._serve_conn(conn)
                except _Die:
                    self.stop()   # chaos kill: no reply, no cleanup frame
                    break
                except (ConnectionError, OSError):
                    continue   # frontend went away; await the next one
        try:
            self._sock.close()
        except OSError:
            pass

    def _serve_conn(self, conn) -> None:
        # a frontend that dies mid-frame must not pin the (single-
        # connection) server forever: time out and await the next one
        if self.idle_timeout_s > 0:
            conn.settimeout(self.idle_timeout_s)
        while not self._stop.is_set():
            try:
                op, body = recv_frame(conn)
            except FrameError as e:
                # desynced stream: nothing after this frame can be
                # trusted — tell the peer (best effort) and drop the
                # connection so it reconnects clean
                try:
                    send_frame(conn, b"E", pack_error(e, fatal=True))
                except OSError:
                    pass
                return
            try:
                reply = self._dispatch(op, body)
            except _Die:
                raise
            except self.FATAL_ERRORS as e:
                try:
                    send_frame(conn, b"E", pack_error(e, fatal=True))
                except OSError:
                    pass
                return
            except Exception as e:   # per-request: typed frame, carry on
                send_frame(conn, b"E", pack_error(e, fatal=False))
                self.frames_served += 1
                continue
            send_frame(conn, b"R", reply)
            self.frames_served += 1
            if op == b"Q":
                self.stop()
                return

    def _dispatch(self, op: bytes, body: bytes) -> bytes:
        b = self.backend
        if op == b"S":
            return struct.pack("<q", b.submit(decode_request(body)))
        if op == b"P":
            return _pack_results(b.poll())
        if op == b"F":
            return _pack_results(b.flush())
        if op == b"D":
            return json.dumps([[bucket_to_dict(k), v]
                               for k, v in b.take_demand().items()]).encode()
        if op == b"W":
            menu = [spec_from_dict(d) for d in json.loads(body)]
            return json.dumps(b.prewarm(menu)).encode()
        if op == b"T":
            return json.dumps(b.stats()).encode()
        if op == b"C":
            return json.dumps(b.compile_count()).encode()
        if op == b"N":
            return json.dumps(b.n_devices).encode()
        if op == b"M":
            # per-host metrics ride the no-pickle codec as their own
            # frame kind (DESIGN.md §12); the frontend merges them
            return encode_metrics(b.host_id, b.metrics())
        if op == b"H":
            # health probe: proves the serve loop is responsive, not
            # just that the TCP stack accepts connections
            return b"ok"
        if op == b"X":
            raise _Die()
        if op == b"Q":
            return b"ok"
        raise ValueError(f"unknown op {op!r}")


class TcpBackend:
    """Frontend-side proxy for a ``BackendServer`` in another process
    (typically another ``jax.distributed`` host). Thread-safe: one
    request/reply in flight per connection.

    Fault handling (DESIGN.md §13): connect and recv both honor
    configurable timeouts — a half-dead peer fails the call with
    ``BackendUnavailable`` within ``recv_timeout_s`` instead of hanging
    forever — and every connection-level failure drops the socket, so
    the next call reconnects (a recovered host rejoins without a new
    proxy object). Remote error frames rebuild as typed exceptions
    (``RemoteRequestError`` with the remote traceback, or
    ``BackendUnavailable`` for backend-fatal replies).

    Every frame's round-trip (send -> reply parsed off the socket) is
    timed into a per-op sliding window — the measured TCP routing
    overhead the ROADMAP asked for (``rtt_stats``; surfaced in cluster
    metrics and ``BENCH_serve.json``'s ``tcp_rtt`` columns)."""

    RTT_WINDOW = 4096   # samples kept per op (bounded memory under load)

    def __init__(self, address: "tuple[str, int]", host_id: str,
                 connect_timeout_s: float = 10.0,
                 recv_timeout_s: float = 120.0):
        self.host_id = host_id
        self.address = tuple(address)
        self.connect_timeout_s = float(connect_timeout_s)
        self.recv_timeout_s = float(recv_timeout_s)
        self._sock = None
        self._lock = threading.Lock()
        self._rtt: dict = {}
        try:
            self.n_devices = int(self._call(b"N", json.loads))
        except BaseException:
            # don't leak the connected socket when the handshake fails
            self.close()
            raise

    def _ensure_sock(self):
        """Connected socket, reconnecting after a dropped one (recovered
        hosts rejoin on the next call). Caller holds ``_lock``."""
        if self._sock is None:
            try:
                sock = socket.create_connection(
                    self.address, timeout=self.connect_timeout_s)
            except OSError as e:
                raise BackendUnavailable(
                    f"backend {self.host_id} connect "
                    f"{self.address}: {e}") from e
            sock.settimeout(self.recv_timeout_s or None)
            self._sock = sock
        return self._sock

    def _drop_sock(self) -> None:
        sock, self._sock = self._sock, None
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass

    def _call(self, op: bytes, parse, body: bytes = b""):
        t0 = time.perf_counter()
        with self._lock:
            sock = self._ensure_sock()
            try:
                send_frame(sock, op, body)
                status, reply = recv_frame(sock)
            except FrameError as e:
                # desynced reply stream: the connection is unusable
                self._drop_sock()
                raise BackendUnavailable(
                    f"backend {self.host_id}: {e}") from e
            except (OSError, ConnectionError) as e:
                # timeout, reset, refused — a dying or unreachable host;
                # finally-style cleanup so the fd never leaks
                self._drop_sock()
                kind = "timed out" if isinstance(e, TimeoutError) else str(e)
                raise BackendUnavailable(
                    f"backend {self.host_id} {op.decode()!s}: "
                    f"{kind}") from e
            dq = self._rtt.get(op)
            if dq is None:
                dq = self._rtt[op] = deque(maxlen=self.RTT_WINDOW)
            dq.append(time.perf_counter() - t0)
        if status == b"E":
            err = remote_error(self.host_id, reply)
            if isinstance(err, BackendUnavailable):
                with self._lock:
                    self._drop_sock()   # server said fatal: it closed too
            raise err
        if status != b"R":
            with self._lock:
                self._drop_sock()
            raise BackendUnavailable(
                f"backend {self.host_id}: bad reply status {status!r}")
        try:
            return parse(reply)
        except (ValueError, KeyError, struct.error) as e:
            # CodecError included (it is a ValueError): a reply that
            # fails to parse is a corrupt peer, not a caller bug
            raise BackendUnavailable(
                f"backend {self.host_id}: corrupt {op.decode()!s} "
                f"reply: {e}") from e

    def rtt_stats(self) -> dict:
        """Per-op frame round-trip latency over the sliding window:
        ``{op: {count, p50_ms, p95_ms, max_ms}}`` (op is the one-byte
        frame opcode, e.g. "S" submit / "P" poll)."""
        with self._lock:
            windows = {op: list(dq) for op, dq in self._rtt.items()}
        out = {}
        for op, xs in sorted(windows.items()):
            if not xs:
                continue
            xs.sort()
            n = len(xs)
            out[op.decode()] = {
                "count": n,
                "p50_ms": xs[n // 2] * 1e3,
                "p95_ms": xs[min(n - 1, int(math.ceil(0.95 * n)) - 1)] * 1e3,
                "max_ms": xs[-1] * 1e3,
            }
        return out

    def submit(self, req) -> int:
        return self._call(b"S", lambda b: struct.unpack("<q", b)[0],
                          encode_request(req))

    def poll(self) -> list:
        return self._call(b"P", _unpack_results)

    def flush(self) -> list:
        return self._call(b"F", _unpack_results)

    def take_demand(self) -> dict:
        pairs = self._call(b"D", json.loads)
        return {bucket_from_dict(d): v for d, v in pairs}

    def prewarm(self, menu) -> dict:
        body = json.dumps([spec_to_dict(s) for s in menu]).encode()
        return self._call(b"W", json.loads, body)

    def stats(self) -> dict:
        return self._call(b"T", json.loads)

    def compile_count(self) -> int:
        return int(self._call(b"C", json.loads))

    def metrics(self) -> dict:
        _host, snap = self._call(b"M", decode_metrics)
        return snap

    def ping(self) -> bool:
        """Health probe: one ``H`` frame through the serve loop. Raises
        ``BackendUnavailable`` (within the configured timeouts) when the
        host is unreachable, hung, or desynced."""
        return self._call(b"H", lambda b: b) == b"ok"

    def shutdown_server(self) -> None:
        try:
            self._call(b"Q", lambda b: b)
        except (BackendError, RuntimeError, OSError, ConnectionError):
            pass

    def kill_server(self) -> None:
        """Chaos drill: make the remote die abruptly (``X`` op — the
        server closes without replying, like a crash). Fire-and-forget."""
        with self._lock:
            if self._sock is not None:
                try:
                    send_frame(self._sock, b"X")
                except OSError:
                    pass
            self._drop_sock()

    def close(self) -> None:
        with self._lock:
            self._drop_sock()


# -- graceful degradation (DESIGN.md §13) ------------------------------------

class ShedLadder:
    """Overload response as a ladder, cheapest fidelity first.

    The paper's premise is that fidelity is a *schedulable* trade — so
    under sustained overload the frontend should spend rate before it
    spends correctness, and spend correctness (with a quote) before it
    sheds:

      level 0  full fidelity
      level 1  strip extras: ``measure_wire`` accounting off (the rANS
               coding tail is pure observability cost)
      level 2  degrade the schedule: halve the iteration budget (and a
               DP bit budget with it) — SE quotes the predicted final
               MSE at both budgets *before* the cut, so the degradation
               is priced, not silent
      level 3  shed (``Overloaded`` propagates to the caller)

    Escalation: ``up_after`` sheds inside ``window_s`` raise the level;
    a full calm window with no sheds lowers it one step. Deterministic
    under an injected clock (tests drive it synthetically). Off by
    default (``RouterPolicy.shed_ladder``) — degradation changes
    results, so it must be an explicit operator choice."""

    def __init__(self, window_s: float = 2.0, up_after: int = 3,
                 clock=time.monotonic):
        self.window_s = float(window_s)
        self.up_after = max(1, int(up_after))
        self.clock = clock
        self.level = 0
        self._shed_times: deque = deque(maxlen=256)
        self._last_shed = -math.inf
        self._quotes: dict = {}   # SE quote memo per operating point

    def record_shed(self, now: float | None = None) -> int:
        """One Overloaded event; escalates after ``up_after`` in-window
        sheds. Returns the (possibly new) level."""
        now = self.clock() if now is None else now
        self._last_shed = now
        self._shed_times.append(now)
        horizon = now - self.window_s
        while self._shed_times and self._shed_times[0] < horizon:
            self._shed_times.popleft()
        if len(self._shed_times) >= self.up_after and self.level < 3:
            self.level += 1
            self._shed_times.clear()
        return self.level

    def relax(self, now: float | None = None) -> int:
        """Called on clean admissions: one calm ``window_s`` with no
        sheds steps the ladder back down."""
        now = self.clock() if now is None else now
        if self.level > 0 and now - self._last_shed >= self.window_s:
            self.level -= 1
            self._last_shed = now   # each step down needs its own window
        return self.level

    def _quote(self, req, t_deg: int) -> "tuple[float, float]":
        """SE-predicted final MSE at the full and degraded iteration
        budgets (memoized per operating point — the quote must not make
        overload worse)."""
        key = (req.n, req.m, req.snr_db, float(req.prior.eps),
               float(req.prior.mu_s), float(req.prior.sigma_s),
               req.n_iter, t_deg)
        hit = self._quotes.get(key)
        if hit is None:
            prob = req.problem()
            full = float(se_trajectory(prob, req.n_iter)[-1])
            deg = float(se_trajectory(prob, t_deg)[-1])
            hit = self._quotes[key] = (full, deg)
        return hit

    def apply(self, req) -> "tuple[object, dict | None]":
        """Degrade one request per the current level. Returns the
        (possibly replaced) request and a quote dict (None at level 0 /
        nothing to strip). Level 3 does not mutate — the shed itself
        happens at admission."""
        if self.level <= 0:
            return req, None
        changed: dict = {}
        if req.measure_wire:
            changed["measure_wire"] = False
        if self.level >= 2 and req.n_iter > 2:
            t_deg = max(2, (req.n_iter + 1) // 2)
            full, deg = self._quote(req, t_deg)
            changed["n_iter"] = t_deg
            if req.deltas is not None:
                changed["deltas"] = req.deltas[:t_deg]
            if req.policy == "dp" and req.dp_total_bits:
                changed["dp_total_bits"] = max(
                    1, math.ceil(req.dp_total_bits / 2))
            quote = {"level": self.level, "n_iter_full": req.n_iter,
                     "n_iter": t_deg, "mse_full": full, "mse_degraded": deg,
                     "mse_ratio": deg / max(full, 1e-300)}
        elif changed:
            quote = {"level": self.level, "stripped": sorted(changed)}
        else:
            return req, None
        return dataclasses.replace(req, **changed), quote


@dataclasses.dataclass
class _Flight:
    """Frontend-side ownership record of one routed request — everything
    needed to re-admit it bit-identically if its host dies."""

    gid: int                      # global request id (stable across retries)
    cost: float                   # routed shape cost (returned on complete)
    req: object                   # caller's template, for replay
    key: object                   # routing key
    t_submit: float               # monotonic submit time (latency/hedging)
    attempts: int = 0             # re-admissions so far
    t_detect: float | None = None  # failure-detection time (recovery clock)
    hedged: bool = False          # a duplicate copy is (or was) in flight


# -- the cluster service ----------------------------------------------------

class ClusterService:
    """Multi-host elastic serving plane: ``SolveService`` semantics
    (submit/solve/stream/flush) over a set of host backends, with
    load × shape routing and per-bucket replica autoscaling.

    ``backends=None`` builds ``n_hosts`` in-process emulated hosts, each
    its own ``SolveService`` (shared ``BucketPolicy`` — routing keys must
    agree structurally with every backend's bucketing; heterogeneous
    policies across hosts would route a request to a bucket the backend
    then shapes differently). Row and column buckets ride the same
    router: the routing key carries the layout axis, so tall C-MP-AMP
    requests and wide row requests each scale their own replicas.

    Routing is batch-affine: a bucket's filling partial batch stays on
    one host (the ``_fill`` hint to ``ClusterRouter.route``), so
    cross-host routing happens at batch granularity — every dispatch
    runs at the width the single-host service would have used, which is
    what makes cluster results bit-identical to it, and load balancing
    happens between batches, not inside them.

    Autoscaling is scrape-driven: ``scrape()`` drains every backend's
    demand window into the autoscaler and applies its events (scale-up
    prewarms the bucket's exemplar spec on the new host before traffic
    lands there). With ``RouterPolicy.scrape_every_s > 0`` submits
    trigger scrapes automatically; the default is manual (deterministic
    for tests and benches).
    """

    def __init__(self, backends: list | None = None, n_hosts: int = 1,
                 policy: BucketPolicy | None = None,
                 router_policy: RouterPolicy | None = None,
                 service_factory=None, **service_kwargs):
        self.policy = policy or BucketPolicy()
        if backends is None:
            factory = service_factory or (
                lambda i: SolveService(policy=self.policy,
                                       **service_kwargs))
            backends = [LocalBackend(f"host{i}", factory(i))
                        for i in range(max(1, n_hosts))]
        self.backends = {b.host_id: b for b in backends}
        assert len(self.backends) == len(backends), "duplicate host ids"
        self.router_policy = router_policy or RouterPolicy()
        self.router = ClusterRouter(
            [HostInfo(b.host_id, b.n_devices) for b in backends],
            self.router_policy)
        self.autoscaler = Autoscaler(self.router, self.router_policy)
        self._next_id = 0
        # (host_id, backend-local id) -> _Flight: the frontend OWNS every
        # admitted request until its result is delivered — ownership is
        # what makes failover possible (DESIGN.md §13)
        self._inflight: dict = {}
        self._completed: list = []
        # fault tolerance (DESIGN.md §13)
        self._fail_counts: dict = {}   # host -> consecutive conn failures
        self._fail_events: dict = {}   # host -> cumulative conn failures
        self._revived: set = set()     # hosts ever declared dead (stale-
        #                                result tolerance in _absorb)
        self._zombies: dict = {}       # (host, local) -> cost: losing
        #                                hedge copies, completed on arrival
        self._gid_refs: dict = {}      # gid -> {(host, local)} hedge copies
        self._lat: dict = {}           # routing key -> completion latencies
        self._recovery_s: list = []    # detect -> replayed-result latency
        self._lost_gids: set = set()
        self.retries = 0               # re-admissions (submit + failover)
        self.failovers = 0             # hosts declared dead
        self.hedges = 0
        self.lost = 0                  # admitted but never completed
        self.degraded = 0              # requests the shed ladder touched
        self.shed_quotes: list = []    # SE quotes for degraded requests
        self._specs: dict = {}      # routing key -> exemplar PrewarmSpec
        # (host_id, routing key) -> open-partial-batch depth, counted
        # mod max_batch (a group dispatches exactly when it fills): the
        # batch-affinity hint for the router, reset when flush closes
        # every open group
        self._fill: dict = {}
        self._last_scrape = time.monotonic()
        self.shed_count = 0
        self.submitted = 0
        # telemetry (DESIGN.md §12): mirrors the backends' flag so a
        # telemetry-off cluster carries zero span/metric overhead; the
        # frontend registry holds the router/admission/TCP-RTT series and
        # merges with per-host snapshots in ``metrics()``
        self.telemetry = bool(service_kwargs.get("telemetry", True))
        self._registry = None
        if self.telemetry:
            self._registry = MetricsRegistry()
            self._registry.collect(self._collect_frontend)
        # autoscaler scrape loop (daemon thread, ``start_scraper``)
        self._scrape_thread: threading.Thread | None = None
        self._scrape_stop: threading.Event | None = None
        self.scrape_errors: list = []
        # graceful degradation ladder (opt-in: degradation changes
        # results, so it must be an explicit operator choice)
        self._ladder = (ShedLadder()
                        if self.router_policy.shed_ladder else None)

    # -- intake --------------------------------------------------------------

    def _routing_key(self, req):
        return routing_key(req, self.policy)

    def _open_batch_host(self, key) -> str | None:
        """The replica holding this bucket's fullest open partial batch
        (None when every group is empty or just dispatched): routing
        there first keeps one filling batch on one host — continuous
        batching across hosts would otherwise shear groups apart as
        completions drain the load signal mid-stream."""
        best_fill, best = 0, None
        for hid in self.router.replicas(key):
            f = self._fill.get((hid, key), 0)
            if f > best_fill:
                best_fill, best = f, hid
        return best

    def _bump_fill(self, host_id: str, key) -> None:
        f = (self._fill.get((host_id, key), 0) + 1) % self.policy.max_batch
        self._fill[(host_id, key)] = f

    def _remember_spec(self, key, req) -> None:
        if key not in self._specs:
            self._specs[key] = PrewarmSpec(
                n=req.n, m=req.m, n_proc=req.n_proc, n_iter=req.n_iter,
                policy=req.policy, transport=req.transport,
                layout=req.layout, snr_db=req.snr_db, prior=req.prior)

    def _unbump_fill(self, host_id: str, key) -> None:
        """Exact inverse of ``_bump_fill`` (mod ``max_batch``) — a submit
        the backend never accepted opened no group slot."""
        f = self._fill.get((host_id, key))
        if f is not None:
            self._fill[(host_id, key)] = (f - 1) % self.policy.max_batch

    def _place(self, req, key, cost, t_admit: float, *, gid=None,
               attempts: int = 0, t_detect=None, retry: bool = False):
        """Route + forward one request, retrying across hosts on
        connection-level failure (``BackendUnavailable``): the failed
        host is charged a failure (walking healthy -> suspect -> dead),
        its routed cost and fill slot are returned, and after a linear
        backoff the request routes again with that host excluded.
        ``RemoteRequestError`` (the request's own fault) propagates
        without retry — replaying a bad request elsewhere just fails
        elsewhere. Returns the global id (allocated on first successful
        placement so shed/failed submits leave no gid gap)."""
        rp = self.router_policy
        avoid: set = set()
        tries = 0
        while True:
            t_route = _tnow() if self.telemetry else 0.0
            host_id = self.router.route(key, cost,
                                        prefer=self._open_batch_host(key),
                                        avoid=frozenset(avoid))
            self._bump_fill(host_id, key)
            # the backend assigns its own local id: hand it a fresh copy
            # so the caller's template (replayed verbatim on failover)
            # and our global numbering stay untouched
            fwd = dataclasses.replace(req, request_id=-1)
            if self.telemetry:
                # frontend spans travel WITH the request (codec header)
                # and come back on the result; the backend appends its
                # own with host=None, which ``_absorb`` tags with the
                # routed host. Replays carry a "retry" span; the span
                # list must still END with "route" (the service keys its
                # handoff stamp on it).
                base = list(req.spans or [])
                if retry or tries > 0:
                    base.append(_tspan("retry", t_admit, t_route,
                                       host="frontend"))
                fwd.spans = base + [
                    _tspan("admit", t_admit, t_route, host="frontend"),
                    _tspan("route", t_route, host="frontend")]
            try:
                local = self.backends[host_id].submit(fwd)
            except RemoteRequestError:
                self._unbump_fill(host_id, key)
                self.router.complete(host_id, cost)
                raise
            except BackendUnavailable as e:
                self._unbump_fill(host_id, key)
                self.router.complete(host_id, cost)
                self._note_failure(host_id, e)
                avoid.add(host_id)
                tries += 1
                self.retries += 1
                if tries > max(0, rp.retry_limit):
                    raise BackendUnavailable(
                        f"submit failed on {tries} host(s): {e}") from e
                if rp.retry_backoff_s > 0:
                    time.sleep(rp.retry_backoff_s * tries)
                continue
            self._note_ok(host_id)
            if gid is None:
                gid = self._next_id
                self._next_id += 1
            self._inflight[(host_id, local)] = _Flight(
                gid=gid, cost=cost, req=req, key=key,
                t_submit=time.monotonic(), attempts=attempts,
                t_detect=t_detect)
            return gid

    def submit(self, req) -> int:
        """Route one request to a backend host; returns its *global*
        request id (backend-local ids never escape). Raises
        ``Overloaded`` when every live replica of the request's bucket
        is at the admission cap — the shed path; ``shed_count`` tracks
        it (and escalates the shed ladder when one is enabled). A host
        that fails the submit is retried around (``_place``)."""
        t_admit = _tnow() if self.telemetry else 0.0
        quote = None
        if self._ladder is not None:
            req, quote = self._ladder.apply(req)
        key = self._routing_key(req)
        cost = shape_cost(key)
        self._remember_spec(key, req)
        try:
            gid = self._place(req, key, cost, t_admit)
        except Overloaded:
            self.shed_count += 1
            if self._ladder is not None:
                self._ladder.record_shed()
            raise
        if quote is not None:
            self.degraded += 1
            self.shed_quotes.append(quote)
        elif self._ladder is not None:
            self._ladder.relax()
        self.submitted += 1
        if (self.router_policy.scrape_every_s > 0.0
                and self._scrape_thread is None):
            # piggyback scraping only when no daemon scraper owns the tick
            now = time.monotonic()
            if now - self._last_scrape >= self.router_policy.scrape_every_s:
                self.check_health()
                self.scrape(now)
        return gid

    # -- failure detection & recovery (DESIGN.md §13) ------------------------

    def _note_ok(self, host_id: str) -> None:
        """A successful call resets the consecutive-failure count and
        heals a suspect host (dead hosts revive only via
        ``check_health`` — one good frame is not proof of life)."""
        if self._fail_counts.get(host_id):
            self._fail_counts[host_id] = 0
        if self.router.host_state(host_id) == "suspect":
            self.router.mark_healthy(host_id)

    def _note_failure(self, host_id: str, exc) -> str:
        """Charge one connection-level failure and walk the host state
        machine: ``suspect_after`` consecutive failures lose routing
        ties, ``dead_after`` evict the host and fail its in-flight
        requests over. Per-request errors never land here — they say
        nothing about the host. Returns the resulting state."""
        n = self._fail_counts.get(host_id, 0) + 1
        self._fail_counts[host_id] = n
        self._fail_events[host_id] = self._fail_events.get(host_id, 0) + 1
        rp = self.router_policy
        state = self.router.host_state(host_id)
        if state == "dead":
            return state
        if n >= max(1, rp.dead_after):
            self._declare_dead(host_id)
            return "dead"
        if n >= max(1, rp.suspect_after):
            self.router.mark_suspect(host_id)
            return "suspect"
        return state

    def _declare_dead(self, host_id: str) -> None:
        """Evict a host and recover its work: the router drops it from
        every replica set and zeroes its outstanding cost; its stranded
        flights re-admit on survivors in original admission order — so
        full groups re-form at the same padded widths and the replayed
        results are bit-identical to the originals."""
        t_detect = time.monotonic()
        t_pc = _tnow() if self.telemetry else 0.0
        self.router.mark_dead(host_id)
        self.failovers += 1
        self._revived.add(host_id)
        b = self.backends.get(host_id)
        if b is not None:
            try:
                b.close()   # drop the dead socket; revival reconnects
            except Exception:  # noqa: BLE001 — already dead
                pass
        # losing hedge copies on the dead host will never arrive
        for hk in [k for k in self._zombies if k[0] == host_id]:
            del self._zombies[hk]
        # its open partial batches are gone with it
        for fk in [k for k in self._fill if k[0] == host_id]:
            del self._fill[fk]
        stranded = sorted(
            ((hk, fl) for hk, fl in self._inflight.items()
             if hk[0] == host_id),
            key=lambda kv: kv[1].gid)
        for hk, fl in stranded:
            del self._inflight[hk]
            refs = self._gid_refs.get(fl.gid)
            if refs is not None:
                refs.discard(hk)
                if refs:
                    continue        # a hedged copy survives elsewhere
                del self._gid_refs[fl.gid]
            self._readmit(fl, t_detect, t_pc)

    def _readmit(self, fl: _Flight, t_detect: float, t_pc: float) -> None:
        """Replay one stranded flight on a surviving host (same gid,
        same request template -> same bucket program -> same bits);
        past the retry limit, or with nowhere live to go, it is lost —
        counted, never silently dropped."""
        rp = self.router_policy
        if fl.attempts >= max(0, rp.retry_limit):
            self.lost += 1
            self._lost_gids.add(fl.gid)
            return
        self.retries += 1
        try:
            self._place(fl.req, fl.key, fl.cost, t_pc, gid=fl.gid,
                        attempts=fl.attempts + 1, t_detect=t_detect,
                        retry=True)
        except (Overloaded, BackendError):
            self.lost += 1
            self._lost_gids.add(fl.gid)

    def check_health(self) -> dict:
        """Probe every backend once (the ``H`` health frame / local
        no-op). Successes reset failure counts, heal suspects, and
        revive dead hosts; failures walk the state machine — so a dead
        peer is detected within ``dead_after`` probe intervals even
        with no traffic in flight. The scraper daemon drives this every
        tick; tests and ``amp_serve`` call it directly. Returns
        ``{host_id: state}``."""
        for host_id, b in list(self.backends.items()):
            try:
                ok = b.ping()
            except BackendError as e:
                self._note_failure(host_id, e)
                continue
            except Exception as e:  # noqa: BLE001 — a broken backend
                self._note_failure(host_id, BackendUnavailable(repr(e)))
                continue
            if not ok:
                self._note_failure(
                    host_id, BackendUnavailable("bad health reply"))
                continue
            if self.router.host_state(host_id) == "dead":
                self.router.mark_healthy(host_id)   # revival
            self._fail_counts[host_id] = 0
            self._note_ok(host_id)
        return self.router.host_states()

    def _hedge_tail(self) -> None:
        """Tail-latency hedging (``RouterPolicy.hedge_p99_mult`` > 0):
        an in-flight request stuck past mult x its bucket's p99
        completion latency is duplicated onto a different live host;
        the first copy to finish wins and the loser is dropped on
        arrival (``_zombies``). Targets slow/suspect hosts without
        waiting for the dead threshold. Off by default: the winning
        copy may have batched at a different width, so hedging trades
        strict determinism for tail latency."""
        mult = self.router_policy.hedge_p99_mult
        if mult <= 0.0:
            return
        now = time.monotonic()
        for hk, fl in list(self._inflight.items()):
            if fl.hedged or fl.gid in self._gid_refs:
                continue
            dq = self._lat.get(fl.key)
            if not dq or len(dq) < 8:
                continue            # no latency signal yet
            xs = sorted(dq)
            p99 = xs[min(len(xs) - 1, math.ceil(0.99 * len(xs)) - 1)]
            if now - fl.t_submit < mult * p99:
                continue
            host_id = hk[0]
            try:
                other = self.router.route(fl.key, fl.cost,
                                          avoid=frozenset({host_id}))
            except Overloaded:
                continue            # nowhere to hedge to
            fwd = dataclasses.replace(fl.req, request_id=-1)
            if self.telemetry:
                t_route = _tnow()
                fwd.spans = list(fl.req.spans or []) + [
                    _tspan("retry", t_route, t_route, host="frontend"),
                    _tspan("admit", t_route, t_route, host="frontend"),
                    _tspan("route", t_route, host="frontend")]
            try:
                local = self.backends[other].submit(fwd)
            except BackendError as e:
                self.router.complete(other, fl.cost)
                if isinstance(e, BackendUnavailable):
                    self._note_failure(other, e)
                continue
            fl.hedged = True
            dup = _Flight(gid=fl.gid, cost=fl.cost, req=fl.req,
                          key=fl.key, t_submit=now,
                          attempts=fl.attempts + 1,
                          t_detect=fl.t_detect, hedged=True)
            self._inflight[(other, local)] = dup
            self._gid_refs[fl.gid] = {hk, (other, local)}
            self.hedges += 1

    def _absorb(self, host_id: str, results) -> None:
        """Rewrite backend-local ids to global ids, return the routed
        cost to the router, buffer globally. Hedge-aware: the first copy
        of a hedged gid wins and its siblings become zombies (completed
        for cost accounting, dropped on arrival); a host that was
        declared dead may deliver results for flights already failed
        over — those are dropped (their cost was zeroed at eviction)."""
        now = time.monotonic()
        for res in results:
            hk = (host_id, res.request_id)
            zcost = self._zombies.pop(hk, None)
            if zcost is not None:
                # late duplicate of an already-delivered hedged request
                self.router.complete(host_id, zcost)
                continue
            fl = self._inflight.pop(hk, None)
            if fl is None:
                assert host_id in self._revived, \
                    f"backend {host_id} returned unknown id {res.request_id}"
                continue
            refs = self._gid_refs.pop(fl.gid, None)
            if refs is not None:
                for other in refs:
                    if other == hk:
                        continue
                    dup = self._inflight.pop(other, None)
                    if dup is not None:
                        self._zombies[other] = dup.cost
            self.router.complete(host_id, fl.cost)
            dq = self._lat.get(fl.key)
            if dq is None:
                dq = self._lat[fl.key] = deque(maxlen=512)
            dq.append(now - fl.t_submit)
            if fl.t_detect is not None:
                # recovery latency: failure detected -> replayed result
                rec = now - fl.t_detect
                self._recovery_s.append(rec)
                if self._registry is not None:
                    self._registry.histogram(
                        "amp_recovery_seconds",
                        "Failure detected -> re-admitted request completed",
                        buckets=RECOVERY_BUCKETS).observe(rec)
            spans = (tag_host(res.spans, host_id)
                     if self.telemetry and res.spans else res.spans)
            self._completed.append(
                dataclasses.replace(res, request_id=fl.gid, spans=spans))

    def _poll_all(self) -> None:
        """Poll every live backend into ``_completed``; a backend whose
        connection fails is charged (and possibly declared dead, failing
        its flights over) instead of killing the whole poll."""
        for host_id, b in list(self.backends.items()):
            if self.router.host_state(host_id) == "dead":
                continue
            try:
                self._absorb(host_id, b.poll())
            except BackendUnavailable as e:
                self._note_failure(host_id, e)

    def _flush_all(self) -> None:
        """Flush every live backend, re-flushing survivors after any
        failover: a mid-flush death re-admits its stranded flights into
        open groups on live hosts, which then need their own flush. The
        round bound covers the worst case of every host taking
        ``dead_after`` failures to die, one per round."""
        rp = self.router_policy
        max_rounds = 2 + max(1, rp.dead_after) * max(1, len(self.backends))
        for _ in range(max_rounds):
            clean = True
            for host_id, b in list(self.backends.items()):
                if self.router.host_state(host_id) == "dead":
                    continue
                try:
                    self._absorb(host_id, b.flush())
                except BackendUnavailable as e:
                    self._note_failure(host_id, e)
                    clean = False
            live_pending = any(
                self.router.host_state(hk[0]) != "dead"
                for hk in self._inflight)
            if clean and not live_pending:
                return

    def poll(self) -> list:
        """Collect materialized results from every live backend (no
        forced dispatch of partial batches)."""
        self._hedge_tail()
        self._poll_all()
        out, self._completed = self._completed, []
        return out

    def flush(self) -> list:
        """Dispatch every backend's stragglers; return all buffered
        results. Survives backend deaths mid-flush (their in-flight
        requests replay on live hosts and flush again)."""
        self._hedge_tail()
        self._flush_all()
        self._fill.clear()          # flush closed every open group
        out, self._completed = self._completed, []
        return out

    def solve(self, reqs) -> list:
        """Submit + flush; results in submission order (``SolveService``
        semantics: foreign buffered results stay for their consumer).
        Raises ``BackendUnavailable`` if any admitted request was lost —
        a partial answer must never look like a complete one."""
        ids = [self.submit(r) for r in reqs]
        own = set(ids)
        by_id = {}
        for r in self.flush():
            if r.request_id in own:
                by_id[r.request_id] = r
            else:
                self._completed.append(r)
        missing = [i for i in ids if i not in by_id]
        if missing:
            raise BackendUnavailable(
                f"{len(missing)} request(s) lost after retries: "
                f"gids {missing[:8]}")
        return [by_id[i] for i in ids]

    def stream(self, reqs):
        """Continuous batching across hosts: each submit polls every
        live backend, so a bucket batch completing on any host yields
        immediately; stragglers flush when the input ends. Lost
        requests (host death past the retry limit) simply never yield —
        callers needing all-or-nothing use ``solve``."""
        own = set()

        def take_own():
            keep = []
            for r in self._completed:
                if r.request_id in own:
                    yield r
                else:
                    keep.append(r)
            self._completed = keep

        for r in reqs:
            own.add(self.submit(r))
            self._hedge_tail()
            self._poll_all()
            if self._completed:
                yield from take_own()
        self._flush_all()
        self._fill.clear()
        yield from take_own()

    def partition(self, reqs) -> dict:
        """Route a request list without executing it: ``{host_id:
        [requests]}`` in routed order. The weak-scaling bench uses this
        to time each emulated host's share in isolation. Routed costs
        stay outstanding until the whole list is placed — completing
        each immediately would zero the load signal between requests
        and funnel every tie to the first host — then all return to the
        router. Planning only: batch-affinity fill and the router's
        served counters are restored afterwards, so repeated partitions
        (the bench times warm passes) leave no trace in ``stats()``.
        Runs under the router lock end-to-end: the save/route/restore
        sequence must be atomic against a concurrent scraper thread or
        another submitting thread, or the restored counters would erase
        their updates."""
        shares: dict = {hid: [] for hid in self.backends}
        placed = []
        with self.router.lock:
            saved_fill = dict(self._fill)  # planning only: no group opens
            saved_served = dict(self.router._served)
            saved_cost = dict(self.router._served_cost)
            for req in reqs:
                key = self._routing_key(req)
                cost = shape_cost(key)
                self._remember_spec(key, req)
                host_id = self.router.route(
                    key, cost, prefer=self._open_batch_host(key))
                self._bump_fill(host_id, key)
                placed.append((host_id, cost))
                shares[host_id].append(req)
            for host_id, cost in placed:
                self.router.complete(host_id, cost)
            self._fill = saved_fill
            self.router._served = saved_served
            self.router._served_cost = saved_cost
        return shares

    # -- elasticity ----------------------------------------------------------

    def scrape(self, now: float | None = None) -> list:
        """One autoscaler tick: drain every backend's demand window,
        fold it into the EWMAs, apply the scaling events (scale-up
        prewarms the bucket's exemplar spec on the new host). Returns
        the applied events."""
        now = time.monotonic() if now is None else now
        self._last_scrape = now
        deltas: dict = {}
        for host_id, b in list(self.backends.items()):
            if self.router.host_state(host_id) == "dead":
                continue
            try:
                dem = b.take_demand()
            except BackendUnavailable as e:
                self._note_failure(host_id, e)
                continue
            for k, v in dem.items():
                rk = dataclasses.replace(k, placement="local")
                deltas[rk] = deltas.get(rk, 0) + v
        self.autoscaler.observe(deltas, now)
        events = self.autoscaler.step(now)
        for kind, key, host_id in events:
            if kind != "scale_up":
                continue
            spec = self._specs.get(key)
            if spec is not None:
                try:
                    self.backends[host_id].prewarm([spec])
                except BackendUnavailable as e:
                    self._note_failure(host_id, e)
                    continue
                self.router.mark_warm(host_id, key)
        return events

    def start_scraper(self, interval_s: float | None = None) \
            -> threading.Thread:
        """Run the autoscaler scrape loop on a daemon thread at a real
        interval (the production shape — ``amp_serve`` uses this instead
        of piggybacking scrapes on submits). Idempotent; ``stop_scraper``
        or ``close`` shuts it down cleanly (the thread exits within one
        interval). Scrape exceptions are recorded on ``scrape_errors``
        and the loop keeps going — a transient backend hiccup must not
        kill autoscaling."""
        if self._scrape_thread is not None and self._scrape_thread.is_alive():
            return self._scrape_thread
        interval = (interval_s if interval_s is not None
                    else self.router_policy.scrape_every_s) or 1.0
        stop = self._scrape_stop = threading.Event()

        def loop() -> None:
            while not stop.wait(interval):
                try:
                    self.check_health()   # the heartbeat rides the tick
                    self.scrape()
                except Exception as e:  # noqa: BLE001 — keep scraping
                    self.scrape_errors.append(repr(e))

        th = threading.Thread(target=loop, name="cluster-scraper",
                              daemon=True)
        self._scrape_thread = th
        th.start()
        return th

    def stop_scraper(self, timeout: float = 5.0) -> None:
        """Signal the scrape loop to exit and join it."""
        if self._scrape_stop is not None:
            self._scrape_stop.set()
        th = self._scrape_thread
        if th is not None and th.is_alive():
            th.join(timeout)
        self._scrape_thread = None

    def prewarm(self, menu, hosts: list | None = None) -> dict:
        """Prewarm a traffic menu on every backend (or a named subset)
        and mark the (host, bucket) pairs warm for the router.
        ``PrewarmSpec`` carries the same structural fields as a request,
        so ``routing_key`` applies to it directly."""
        menu = list(menu)
        targets = hosts if hosts is not None else list(self.backends)
        reports = {}
        for host_id in targets:
            reports[host_id] = self.backends[host_id].prewarm(menu)
            for spec in menu:
                key = routing_key(spec, self.policy)
                self._specs.setdefault(key, spec)
                self.router.mark_warm(host_id, key)
        return reports

    # -- observability -------------------------------------------------------

    def compile_count(self) -> int:
        n = 0
        for hid, b in self.backends.items():
            if self.router.host_state(hid) == "dead":
                continue
            try:
                n += b.compile_count()
            except BackendError:
                pass
        return n

    def recovery_stats(self) -> dict:
        """Failover recovery latency (failure detected -> replayed
        result delivered), in ms. Empty dict when nothing failed over."""
        xs = sorted(self._recovery_s)
        if not xs:
            return {}

        def pct(q: float) -> float:
            return xs[min(len(xs) - 1, math.ceil(q * len(xs)) - 1)]

        return {
            "count": len(xs),
            "p50_ms": 1e3 * pct(0.50),
            "p95_ms": 1e3 * pct(0.95),
            "max_ms": 1e3 * xs[-1],
        }

    def stats(self) -> dict:
        out = {
            "submitted": self.submitted,
            "shed": self.shed_count,
            "inflight": len(self._inflight),
            "retries": self.retries,
            "failovers": self.failovers,
            "hedges": self.hedges,
            "lost": self.lost,
            "degraded": self.degraded,
            "host_states": self.router.host_states(),
            "recovery": self.recovery_stats(),
            "router": self.router.stats(),
            "autoscaler": self.autoscaler.stats(),
            "hosts": {},
        }
        for hid, b in self.backends.items():
            if self.router.host_state(hid) == "dead":
                out["hosts"][hid] = {"state": "dead"}
                continue
            try:
                out["hosts"][hid] = b.stats()
            except BackendError:
                out["hosts"][hid] = {"state": self.router.host_state(hid)}
        if self._ladder is not None:
            out["shed_ladder_level"] = self._ladder.level
        return out

    def rtt_stats(self) -> dict:
        """Per-host TCP frame round-trip stats (``TcpBackend.rtt_stats``;
        empty for in-process backends — there is no wire to time)."""
        return {hid: b.rtt_stats() for hid, b in self.backends.items()
                if hasattr(b, "rtt_stats")}

    def _collect_frontend(self, reg: MetricsRegistry) -> None:
        """Frontend-plane collector: admission counters, router load,
        autoscaler events, and TCP frame RTTs — all pulled at snapshot
        time from state that already has its own locks."""
        reg.counter("amp_cluster_submitted_total",
                    "Requests admitted by the frontend").set_total(
                        self.submitted)
        reg.counter("amp_cluster_shed_total",
                    "Requests shed at the admission cap").set_total(
                        self.shed_count)
        reg.gauge("amp_cluster_inflight",
                  "Requests routed but not yet completed").set(
                      len(self._inflight))
        rs = self.router.stats()
        out_g = reg.gauge("amp_router_outstanding_cost",
                          "Outstanding cost-weighted work", ("host",))
        srv_c = reg.counter("amp_router_served_total",
                            "Requests routed per host", ("host",))
        for hid, v in rs["outstanding"].items():
            out_g.set(v, host=hid)
        for hid, v in rs["served"].items():
            srv_c.set_total(v, host=hid)
        imb = rs["imbalance"]
        reg.gauge("amp_router_imbalance",
                  "Cost-weighted served-share max/min").set(
                      imb if math.isfinite(imb) else -1.0)
        # fault-tolerance plane (DESIGN.md §13)
        reg.counter("amp_failover_total",
                    "Hosts declared dead (in-flight failed over)"
                    ).set_total(self.failovers)
        reg.counter("amp_retry_total",
                    "Request re-admissions (submit retries + failover "
                    "replays)").set_total(self.retries)
        reg.counter("amp_hedge_total",
                    "Hedged duplicate submissions").set_total(self.hedges)
        reg.counter("amp_lost_requests_total",
                    "Admitted requests lost after retries (must stay 0)"
                    ).set_total(self.lost)
        reg.counter("amp_degraded_total",
                    "Requests degraded by the shed ladder"
                    ).set_total(self.degraded)
        hb = reg.counter("amp_heartbeat_failures_total",
                         "Connection-level failures per host", ("host",))
        for hid, n in self._fail_events.items():
            hb.set_total(n, host=hid)
        stg = reg.gauge(
            "amp_host_state",
            "Host state index into (healthy, suspect, dead, draining)",
            ("host",))
        for hid, st in self.router.host_states().items():
            stg.set(HOST_STATES.index(st), host=hid)
        if self._ladder is not None:
            reg.gauge("amp_shed_ladder_level",
                      "Graceful-degradation ladder level (0-3)"
                      ).set(self._ladder.level)
        events = self.autoscaler.stats()["events"]
        ev_c = reg.counter("amp_autoscaler_events_total",
                           "Applied scaling events", ("kind",))
        for kind in ("scale_up", "scale_down"):
            ev_c.set_total(sum(1 for e in events if e[0] == kind),
                           kind=kind)
        for hid, per_op in self.rtt_stats().items():
            cnt = reg.counter("amp_tcp_frames_total",
                              "TCP frames in the RTT window",
                              ("host", "op"))
            p50 = reg.gauge("amp_tcp_rtt_p50_seconds",
                            "Frame round-trip p50", ("host", "op"))
            p95 = reg.gauge("amp_tcp_rtt_p95_seconds",
                            "Frame round-trip p95", ("host", "op"))
            for op, s in per_op.items():
                cnt.set_total(s["count"], host=hid, op=op)
                p50.set(s["p50_ms"] / 1e3, host=hid, op=op)
                p95.set(s["p95_ms"] / 1e3, host=hid, op=op)

    def metrics(self) -> dict:
        """Cluster-wide metrics: every backend's snapshot (fetched over
        the codec's metrics frame for TCP backends) merged with the
        frontend's own registry, one ``host`` label per series
        (DESIGN.md §12)."""
        if self._registry is None:
            return {"metrics": []}
        snaps = [("frontend", self._registry.snapshot())]
        for hid, b in self.backends.items():
            if self.router.host_state(hid) == "dead":
                continue
            try:
                snap = b.metrics()
            except BackendError:
                continue    # a dying host must not break the scrape
            if snap.get("metrics"):
                snaps.append((hid, snap))
        return merge_snapshots(snaps)

    def metrics_text(self) -> str:
        """``metrics()`` rendered as Prometheus text exposition format."""
        return prometheus_text(self.metrics())

    def close(self, shutdown_remote: bool = False) -> None:
        self.stop_scraper()
        for b in self.backends.values():
            if shutdown_remote and isinstance(b, TcpBackend):
                b.shutdown_server()
            b.close()
