"""Cluster frontend: admission, host backends, and the user-facing
``ClusterService`` (DESIGN.md §11).

The frontend half of the frontend/scheduler/backend split. A
``ClusterService`` owns

  * a set of **backends** — each one a ``SolveService`` on its own host
    (``LocalBackend`` in-process, e.g. one per emulated host on a dev
    box, or ``TcpBackend`` speaking the no-pickle ``serving.codec`` frame
    protocol to a ``BackendServer`` in another ``jax.distributed``
    process),
  * the **scheduler** (``serving.router``): a ``ClusterRouter`` placing
    placement-agnostic bucket keys onto hosts by load × shape, and an
    ``Autoscaler`` moving per-bucket replica counts from demand EWMAs
    scraped out of each backend's ``Batcher.take_demand`` window,
  * **admission**: global request ids, per-host outstanding-cost caps
    (shed with ``Overloaded`` when every replica of a bucket is
    saturated), and the id rewrite between backend-local and global
    request ids.

The per-host dispatch-ahead overlap is untouched — each backend's
``SolveService`` still launches engine calls asynchronously and the
frontend only ``poll``s materialized results — so the cluster tier adds
routing, not synchronization, to the hot path.

Cross-host byte traffic is exactly the codec frames: requests/results
never pickle, and the measured ``bytes_on_wire`` accounting of
DESIGN.md §10 stays per-request inside each backend.
"""
from __future__ import annotations

import dataclasses
import math
import socket
import struct
import threading
import time
from collections import deque

from ..telemetry import MetricsRegistry, merge_snapshots, prometheus_text
from ..telemetry.spans import now as _tnow
from ..telemetry.spans import span as _tspan
from ..telemetry.spans import tag_host
from .buckets import BucketPolicy
from .codec import (bucket_from_dict, bucket_to_dict, decode_metrics,
                    decode_request, decode_result, encode_metrics,
                    encode_request, encode_result, spec_from_dict,
                    spec_to_dict)
from .router import (Autoscaler, ClusterRouter, HostInfo, Overloaded,
                     RouterPolicy, routing_key, shape_cost)
from .service import PrewarmSpec, SolveService

__all__ = ["LocalBackend", "BackendServer", "TcpBackend", "ClusterService",
           "Overloaded"]

import json


class LocalBackend:
    """One in-process host: a ``SolveService`` (its own engines, operand
    cache, batcher — and, on a real deployment, its own device mesh)
    behind the backend interface the frontend routes to."""

    def __init__(self, host_id: str, service: SolveService):
        self.host_id = host_id
        self.service = service

    @property
    def n_devices(self) -> int:
        return self.service.n_devices

    def submit(self, req) -> int:
        return self.service.submit(req)

    def poll(self) -> list:
        return self.service.poll()

    def flush(self) -> list:
        return self.service.flush()

    def take_demand(self) -> dict:
        return self.service.take_demand()

    def prewarm(self, menu) -> dict:
        return self.service.prewarm(menu)

    def stats(self) -> dict:
        return self.service.stats()

    def compile_count(self) -> int:
        return self.service.compile_count()

    def metrics(self) -> dict:
        return self.service.metrics()

    def close(self) -> None:
        pass


# -- TCP transport (codec frames, no pickle) --------------------------------
#
# Frame: u32 length | 1-byte op | body. Replies: u32 length | 1-byte
# status (b"R" ok / b"E" error) | body. Result lists nest as
# u32 count | (u32 len | result-frame)*.

_OPS = (b"S", b"P", b"F", b"D", b"W", b"T", b"C", b"N", b"Q", b"M")


def _recv_exact(sock, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("peer closed mid-frame")
        buf += chunk
    return buf


def _send_frame(sock, op: bytes, body: bytes = b"") -> None:
    sock.sendall(struct.pack("<I", len(body) + 1) + op + body)


def _recv_frame(sock) -> "tuple[bytes, bytes]":
    (ln,) = struct.unpack("<I", _recv_exact(sock, 4))
    payload = _recv_exact(sock, ln)
    return payload[:1], payload[1:]


def _pack_results(results) -> bytes:
    frames = [encode_result(r) for r in results]
    return b"".join([struct.pack("<I", len(frames))]
                    + [struct.pack("<I", len(f)) + f for f in frames])


def _unpack_results(body: bytes) -> list:
    (count,) = struct.unpack("<I", body[:4])
    off, out = 4, []
    for _ in range(count):
        (ln,) = struct.unpack("<I", body[off:off + 4])
        off += 4
        out.append(decode_result(body[off:off + ln]))
        off += ln
    return out


class BackendServer:
    """Serves one ``LocalBackend`` over TCP to a remote frontend. One
    frontend connection at a time (the cluster has exactly one router);
    runs on a daemon thread via ``start()``. The ``Q`` op (or ``stop()``)
    shuts it down."""

    def __init__(self, backend: LocalBackend, host: str = "127.0.0.1",
                 port: int = 0):
        self.backend = backend
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(1)
        self.host, self.port = self._sock.getsockname()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def start(self) -> threading.Thread:
        th = threading.Thread(target=self.serve_forever,
                              name=f"backend-{self.backend.host_id}",
                              daemon=True)
        self._thread = th
        th.start()
        return th

    def stop(self) -> None:
        self._stop.set()
        try:
            self._sock.close()
        except OSError:
            pass

    def serve_forever(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                break   # listener closed by stop()
            with conn:
                try:
                    self._serve_conn(conn)
                except (ConnectionError, OSError):
                    continue   # frontend went away; await the next one
        try:
            self._sock.close()
        except OSError:
            pass

    def _serve_conn(self, conn) -> None:
        while not self._stop.is_set():
            op, body = _recv_frame(conn)
            try:
                reply = self._dispatch(op, body)
            except Exception as e:   # surface backend errors to the router
                _send_frame(conn, b"E", repr(e).encode())
                continue
            _send_frame(conn, b"R", reply)
            if op == b"Q":
                self.stop()
                return

    def _dispatch(self, op: bytes, body: bytes) -> bytes:
        b = self.backend
        if op == b"S":
            return struct.pack("<q", b.submit(decode_request(body)))
        if op == b"P":
            return _pack_results(b.poll())
        if op == b"F":
            return _pack_results(b.flush())
        if op == b"D":
            return json.dumps([[bucket_to_dict(k), v]
                               for k, v in b.take_demand().items()]).encode()
        if op == b"W":
            menu = [spec_from_dict(d) for d in json.loads(body)]
            return json.dumps(b.prewarm(menu)).encode()
        if op == b"T":
            return json.dumps(b.stats()).encode()
        if op == b"C":
            return json.dumps(b.compile_count()).encode()
        if op == b"N":
            return json.dumps(b.n_devices).encode()
        if op == b"M":
            # per-host metrics ride the no-pickle codec as their own
            # frame kind (DESIGN.md §12); the frontend merges them
            return encode_metrics(b.host_id, b.metrics())
        if op == b"Q":
            return b"ok"
        raise ValueError(f"unknown op {op!r}")


class TcpBackend:
    """Frontend-side proxy for a ``BackendServer`` in another process
    (typically another ``jax.distributed`` host). Thread-safe: one
    request/reply in flight per connection.

    Every frame's round-trip (send -> reply parsed off the socket) is
    timed into a per-op sliding window — the measured TCP routing
    overhead the ROADMAP asked for (``rtt_stats``; surfaced in cluster
    metrics and ``BENCH_serve.json``'s ``tcp_rtt`` columns)."""

    RTT_WINDOW = 4096   # samples kept per op (bounded memory under load)

    def __init__(self, address: "tuple[str, int]", host_id: str):
        self.host_id = host_id
        self._sock = socket.create_connection(address, timeout=120.0)
        self._lock = threading.Lock()
        self._rtt: dict = {}
        self.n_devices = int(self._call(b"N", json.loads))

    def _call(self, op: bytes, parse, body: bytes = b""):
        t0 = time.perf_counter()
        with self._lock:
            _send_frame(self._sock, op, body)
            status, reply = _recv_frame(self._sock)
            dq = self._rtt.get(op)
            if dq is None:
                dq = self._rtt[op] = deque(maxlen=self.RTT_WINDOW)
            dq.append(time.perf_counter() - t0)
        if status == b"E":
            raise RuntimeError(
                f"backend {self.host_id}: {reply.decode(errors='replace')}")
        return parse(reply)

    def rtt_stats(self) -> dict:
        """Per-op frame round-trip latency over the sliding window:
        ``{op: {count, p50_ms, p95_ms, max_ms}}`` (op is the one-byte
        frame opcode, e.g. "S" submit / "P" poll)."""
        with self._lock:
            windows = {op: list(dq) for op, dq in self._rtt.items()}
        out = {}
        for op, xs in sorted(windows.items()):
            if not xs:
                continue
            xs.sort()
            n = len(xs)
            out[op.decode()] = {
                "count": n,
                "p50_ms": xs[n // 2] * 1e3,
                "p95_ms": xs[min(n - 1, int(math.ceil(0.95 * n)) - 1)] * 1e3,
                "max_ms": xs[-1] * 1e3,
            }
        return out

    def submit(self, req) -> int:
        return self._call(b"S", lambda b: struct.unpack("<q", b)[0],
                          encode_request(req))

    def poll(self) -> list:
        return self._call(b"P", _unpack_results)

    def flush(self) -> list:
        return self._call(b"F", _unpack_results)

    def take_demand(self) -> dict:
        pairs = self._call(b"D", json.loads)
        return {bucket_from_dict(d): v for d, v in pairs}

    def prewarm(self, menu) -> dict:
        body = json.dumps([spec_to_dict(s) for s in menu]).encode()
        return self._call(b"W", json.loads, body)

    def stats(self) -> dict:
        return self._call(b"T", json.loads)

    def compile_count(self) -> int:
        return int(self._call(b"C", json.loads))

    def metrics(self) -> dict:
        _host, snap = self._call(b"M", decode_metrics)
        return snap

    def shutdown_server(self) -> None:
        try:
            self._call(b"Q", lambda b: b)
        except (RuntimeError, OSError, ConnectionError):
            pass

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass


# -- the cluster service ----------------------------------------------------

class ClusterService:
    """Multi-host elastic serving plane: ``SolveService`` semantics
    (submit/solve/stream/flush) over a set of host backends, with
    load × shape routing and per-bucket replica autoscaling.

    ``backends=None`` builds ``n_hosts`` in-process emulated hosts, each
    its own ``SolveService`` (shared ``BucketPolicy`` — routing keys must
    agree structurally with every backend's bucketing; heterogeneous
    policies across hosts would route a request to a bucket the backend
    then shapes differently). Row and column buckets ride the same
    router: the routing key carries the layout axis, so tall C-MP-AMP
    requests and wide row requests each scale their own replicas.

    Routing is batch-affine: a bucket's filling partial batch stays on
    one host (the ``_fill`` hint to ``ClusterRouter.route``), so
    cross-host routing happens at batch granularity — every dispatch
    runs at the width the single-host service would have used, which is
    what makes cluster results bit-identical to it, and load balancing
    happens between batches, not inside them.

    Autoscaling is scrape-driven: ``scrape()`` drains every backend's
    demand window into the autoscaler and applies its events (scale-up
    prewarms the bucket's exemplar spec on the new host before traffic
    lands there). With ``RouterPolicy.scrape_every_s > 0`` submits
    trigger scrapes automatically; the default is manual (deterministic
    for tests and benches).
    """

    def __init__(self, backends: list | None = None, n_hosts: int = 1,
                 policy: BucketPolicy | None = None,
                 router_policy: RouterPolicy | None = None,
                 service_factory=None, **service_kwargs):
        self.policy = policy or BucketPolicy()
        if backends is None:
            factory = service_factory or (
                lambda i: SolveService(policy=self.policy,
                                       **service_kwargs))
            backends = [LocalBackend(f"host{i}", factory(i))
                        for i in range(max(1, n_hosts))]
        self.backends = {b.host_id: b for b in backends}
        assert len(self.backends) == len(backends), "duplicate host ids"
        self.router_policy = router_policy or RouterPolicy()
        self.router = ClusterRouter(
            [HostInfo(b.host_id, b.n_devices) for b in backends],
            self.router_policy)
        self.autoscaler = Autoscaler(self.router, self.router_policy)
        self._next_id = 0
        # (host_id, backend-local id) -> (global id, routed cost)
        self._inflight: dict = {}
        self._completed: list = []
        self._specs: dict = {}      # routing key -> exemplar PrewarmSpec
        # (host_id, routing key) -> open-partial-batch depth, counted
        # mod max_batch (a group dispatches exactly when it fills): the
        # batch-affinity hint for the router, reset when flush closes
        # every open group
        self._fill: dict = {}
        self._last_scrape = time.monotonic()
        self.shed_count = 0
        self.submitted = 0
        # telemetry (DESIGN.md §12): mirrors the backends' flag so a
        # telemetry-off cluster carries zero span/metric overhead; the
        # frontend registry holds the router/admission/TCP-RTT series and
        # merges with per-host snapshots in ``metrics()``
        self.telemetry = bool(service_kwargs.get("telemetry", True))
        self._registry = None
        if self.telemetry:
            self._registry = MetricsRegistry()
            self._registry.collect(self._collect_frontend)
        # autoscaler scrape loop (daemon thread, ``start_scraper``)
        self._scrape_thread: threading.Thread | None = None
        self._scrape_stop: threading.Event | None = None
        self.scrape_errors: list = []

    # -- intake --------------------------------------------------------------

    def _routing_key(self, req):
        return routing_key(req, self.policy)

    def _open_batch_host(self, key) -> str | None:
        """The replica holding this bucket's fullest open partial batch
        (None when every group is empty or just dispatched): routing
        there first keeps one filling batch on one host — continuous
        batching across hosts would otherwise shear groups apart as
        completions drain the load signal mid-stream."""
        best_fill, best = 0, None
        for hid in self.router.replicas(key):
            f = self._fill.get((hid, key), 0)
            if f > best_fill:
                best_fill, best = f, hid
        return best

    def _bump_fill(self, host_id: str, key) -> None:
        f = (self._fill.get((host_id, key), 0) + 1) % self.policy.max_batch
        self._fill[(host_id, key)] = f

    def _remember_spec(self, key, req) -> None:
        if key not in self._specs:
            self._specs[key] = PrewarmSpec(
                n=req.n, m=req.m, n_proc=req.n_proc, n_iter=req.n_iter,
                policy=req.policy, transport=req.transport,
                layout=req.layout, snr_db=req.snr_db, prior=req.prior)

    def submit(self, req) -> int:
        """Route one request to a backend host; returns its *global*
        request id (backend-local ids never escape). Raises
        ``Overloaded`` when every replica of the request's bucket is at
        the admission cap — the shed path; ``shed_count`` tracks it."""
        t_admit = _tnow() if self.telemetry else 0.0
        key = self._routing_key(req)
        cost = shape_cost(key)
        self._remember_spec(key, req)
        t_route = _tnow() if self.telemetry else 0.0
        try:
            host_id = self.router.route(key, cost,
                                        prefer=self._open_batch_host(key))
        except Overloaded:
            self.shed_count += 1
            raise
        self._bump_fill(host_id, key)
        # the backend assigns its own local id: hand it a fresh copy so
        # the caller's template (and our global numbering) stay untouched
        fwd = dataclasses.replace(req, request_id=-1)
        if self.telemetry:
            # frontend spans travel WITH the request (codec header) and
            # come back on the result; the backend appends its own with
            # host=None, which ``_absorb`` tags with the routed host
            fwd.spans = list(req.spans or []) + [
                _tspan("admit", t_admit, t_route, host="frontend"),
                _tspan("route", t_route, host="frontend")]
        local = self.backends[host_id].submit(fwd)
        gid = self._next_id
        self._next_id += 1
        self._inflight[(host_id, local)] = (gid, cost)
        self.submitted += 1
        if (self.router_policy.scrape_every_s > 0.0
                and self._scrape_thread is None):
            # piggyback scraping only when no daemon scraper owns the tick
            now = time.monotonic()
            if now - self._last_scrape >= self.router_policy.scrape_every_s:
                self.scrape(now)
        return gid

    def _absorb(self, host_id: str, results) -> None:
        """Rewrite backend-local ids to global ids, return the routed
        cost to the router, buffer globally."""
        for res in results:
            entry = self._inflight.pop((host_id, res.request_id), None)
            assert entry is not None, \
                f"backend {host_id} returned unknown id {res.request_id}"
            gid, cost = entry
            self.router.complete(host_id, cost)
            spans = (tag_host(res.spans, host_id)
                     if self.telemetry and res.spans else res.spans)
            self._completed.append(
                dataclasses.replace(res, request_id=gid, spans=spans))

    def poll(self) -> list:
        """Collect materialized results from every backend (no forced
        dispatch of partial batches)."""
        for host_id, b in self.backends.items():
            self._absorb(host_id, b.poll())
        out, self._completed = self._completed, []
        return out

    def flush(self) -> list:
        """Dispatch every backend's stragglers; return all buffered
        results."""
        for host_id, b in self.backends.items():
            self._absorb(host_id, b.flush())
        self._fill.clear()          # flush closed every open group
        out, self._completed = self._completed, []
        return out

    def solve(self, reqs) -> list:
        """Submit + flush; results in submission order (``SolveService``
        semantics: foreign buffered results stay for their consumer)."""
        ids = [self.submit(r) for r in reqs]
        own = set(ids)
        by_id = {}
        for r in self.flush():
            if r.request_id in own:
                by_id[r.request_id] = r
            else:
                self._completed.append(r)
        return [by_id[i] for i in ids]

    def stream(self, reqs):
        """Continuous batching across hosts: each submit polls its routed
        backend, so a bucket batch completing on any host yields
        immediately; stragglers flush when the input ends."""
        own = set()

        def take_own():
            keep = []
            for r in self._completed:
                if r.request_id in own:
                    yield r
                else:
                    keep.append(r)
            self._completed = keep

        for r in reqs:
            own.add(self.submit(r))
            for host_id, b in self.backends.items():
                self._absorb(host_id, b.poll())
            if self._completed:
                yield from take_own()
        for host_id, b in self.backends.items():
            self._absorb(host_id, b.flush())
        self._fill.clear()
        yield from take_own()

    def partition(self, reqs) -> dict:
        """Route a request list without executing it: ``{host_id:
        [requests]}`` in routed order. The weak-scaling bench uses this
        to time each emulated host's share in isolation. Routed costs
        stay outstanding until the whole list is placed — completing
        each immediately would zero the load signal between requests
        and funnel every tie to the first host — then all return to the
        router. Planning only: batch-affinity fill and the router's
        served counters are restored afterwards, so repeated partitions
        (the bench times warm passes) leave no trace in ``stats()``.
        Runs under the router lock end-to-end: the save/route/restore
        sequence must be atomic against a concurrent scraper thread or
        another submitting thread, or the restored counters would erase
        their updates."""
        shares: dict = {hid: [] for hid in self.backends}
        placed = []
        with self.router.lock:
            saved_fill = dict(self._fill)  # planning only: no group opens
            saved_served = dict(self.router._served)
            saved_cost = dict(self.router._served_cost)
            for req in reqs:
                key = self._routing_key(req)
                cost = shape_cost(key)
                self._remember_spec(key, req)
                host_id = self.router.route(
                    key, cost, prefer=self._open_batch_host(key))
                self._bump_fill(host_id, key)
                placed.append((host_id, cost))
                shares[host_id].append(req)
            for host_id, cost in placed:
                self.router.complete(host_id, cost)
            self._fill = saved_fill
            self.router._served = saved_served
            self.router._served_cost = saved_cost
        return shares

    # -- elasticity ----------------------------------------------------------

    def scrape(self, now: float | None = None) -> list:
        """One autoscaler tick: drain every backend's demand window,
        fold it into the EWMAs, apply the scaling events (scale-up
        prewarms the bucket's exemplar spec on the new host). Returns
        the applied events."""
        now = time.monotonic() if now is None else now
        self._last_scrape = now
        deltas: dict = {}
        for b in self.backends.values():
            for k, v in b.take_demand().items():
                rk = dataclasses.replace(k, placement="local")
                deltas[rk] = deltas.get(rk, 0) + v
        self.autoscaler.observe(deltas, now)
        events = self.autoscaler.step(now)
        for kind, key, host_id in events:
            if kind != "scale_up":
                continue
            spec = self._specs.get(key)
            if spec is not None:
                self.backends[host_id].prewarm([spec])
                self.router.mark_warm(host_id, key)
        return events

    def start_scraper(self, interval_s: float | None = None) \
            -> threading.Thread:
        """Run the autoscaler scrape loop on a daemon thread at a real
        interval (the production shape — ``amp_serve`` uses this instead
        of piggybacking scrapes on submits). Idempotent; ``stop_scraper``
        or ``close`` shuts it down cleanly (the thread exits within one
        interval). Scrape exceptions are recorded on ``scrape_errors``
        and the loop keeps going — a transient backend hiccup must not
        kill autoscaling."""
        if self._scrape_thread is not None and self._scrape_thread.is_alive():
            return self._scrape_thread
        interval = (interval_s if interval_s is not None
                    else self.router_policy.scrape_every_s) or 1.0
        stop = self._scrape_stop = threading.Event()

        def loop() -> None:
            while not stop.wait(interval):
                try:
                    self.scrape()
                except Exception as e:  # noqa: BLE001 — keep scraping
                    self.scrape_errors.append(repr(e))

        th = threading.Thread(target=loop, name="cluster-scraper",
                              daemon=True)
        self._scrape_thread = th
        th.start()
        return th

    def stop_scraper(self, timeout: float = 5.0) -> None:
        """Signal the scrape loop to exit and join it."""
        if self._scrape_stop is not None:
            self._scrape_stop.set()
        th = self._scrape_thread
        if th is not None and th.is_alive():
            th.join(timeout)
        self._scrape_thread = None

    def prewarm(self, menu, hosts: list | None = None) -> dict:
        """Prewarm a traffic menu on every backend (or a named subset)
        and mark the (host, bucket) pairs warm for the router.
        ``PrewarmSpec`` carries the same structural fields as a request,
        so ``routing_key`` applies to it directly."""
        menu = list(menu)
        targets = hosts if hosts is not None else list(self.backends)
        reports = {}
        for host_id in targets:
            reports[host_id] = self.backends[host_id].prewarm(menu)
            for spec in menu:
                key = routing_key(spec, self.policy)
                self._specs.setdefault(key, spec)
                self.router.mark_warm(host_id, key)
        return reports

    # -- observability -------------------------------------------------------

    def compile_count(self) -> int:
        return sum(b.compile_count() for b in self.backends.values())

    def stats(self) -> dict:
        return {
            "submitted": self.submitted,
            "shed": self.shed_count,
            "inflight": len(self._inflight),
            "router": self.router.stats(),
            "autoscaler": self.autoscaler.stats(),
            "hosts": {hid: b.stats() for hid, b in self.backends.items()},
        }

    def rtt_stats(self) -> dict:
        """Per-host TCP frame round-trip stats (``TcpBackend.rtt_stats``;
        empty for in-process backends — there is no wire to time)."""
        return {hid: b.rtt_stats() for hid, b in self.backends.items()
                if isinstance(b, TcpBackend)}

    def _collect_frontend(self, reg: MetricsRegistry) -> None:
        """Frontend-plane collector: admission counters, router load,
        autoscaler events, and TCP frame RTTs — all pulled at snapshot
        time from state that already has its own locks."""
        reg.counter("amp_cluster_submitted_total",
                    "Requests admitted by the frontend").set_total(
                        self.submitted)
        reg.counter("amp_cluster_shed_total",
                    "Requests shed at the admission cap").set_total(
                        self.shed_count)
        reg.gauge("amp_cluster_inflight",
                  "Requests routed but not yet completed").set(
                      len(self._inflight))
        rs = self.router.stats()
        out_g = reg.gauge("amp_router_outstanding_cost",
                          "Outstanding cost-weighted work", ("host",))
        srv_c = reg.counter("amp_router_served_total",
                            "Requests routed per host", ("host",))
        for hid, v in rs["outstanding"].items():
            out_g.set(v, host=hid)
        for hid, v in rs["served"].items():
            srv_c.set_total(v, host=hid)
        imb = rs["imbalance"]
        reg.gauge("amp_router_imbalance",
                  "Cost-weighted served-share max/min").set(
                      imb if math.isfinite(imb) else -1.0)
        events = self.autoscaler.stats()["events"]
        ev_c = reg.counter("amp_autoscaler_events_total",
                           "Applied scaling events", ("kind",))
        for kind in ("scale_up", "scale_down"):
            ev_c.set_total(sum(1 for e in events if e[0] == kind),
                           kind=kind)
        for hid, per_op in self.rtt_stats().items():
            cnt = reg.counter("amp_tcp_frames_total",
                              "TCP frames in the RTT window",
                              ("host", "op"))
            p50 = reg.gauge("amp_tcp_rtt_p50_seconds",
                            "Frame round-trip p50", ("host", "op"))
            p95 = reg.gauge("amp_tcp_rtt_p95_seconds",
                            "Frame round-trip p95", ("host", "op"))
            for op, s in per_op.items():
                cnt.set_total(s["count"], host=hid, op=op)
                p50.set(s["p50_ms"] / 1e3, host=hid, op=op)
                p95.set(s["p95_ms"] / 1e3, host=hid, op=op)

    def metrics(self) -> dict:
        """Cluster-wide metrics: every backend's snapshot (fetched over
        the codec's metrics frame for TCP backends) merged with the
        frontend's own registry, one ``host`` label per series
        (DESIGN.md §12)."""
        if self._registry is None:
            return {"metrics": []}
        snaps = [("frontend", self._registry.snapshot())]
        for hid, b in self.backends.items():
            snap = b.metrics()
            if snap.get("metrics"):
                snaps.append((hid, snap))
        return merge_snapshots(snaps)

    def metrics_text(self) -> str:
        """``metrics()`` rendered as Prometheus text exposition format."""
        return prometheus_text(self.metrics())

    def close(self, shutdown_remote: bool = False) -> None:
        self.stop_scraper()
        for b in self.backends.values():
            if shutdown_remote and isinstance(b, TcpBackend):
                b.shutdown_server()
            b.close()
