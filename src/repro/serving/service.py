"""AMP solve service: heterogeneous requests -> bucketed batched engine
calls -> per-request results with realized-rate accounting (DESIGN.md §5).

One ``SolveService`` owns a compile cache of ``AmpEngine``s (one per
``BucketKey``), a table cache of per-operating-point BT controllers, and a
``Batcher``. Requests may differ in *everything* the paper varies — shape
(N, M), processor count P, prior sparsity, SNR, iteration budget T, and
rate policy (lossless / fixed schedule / offline DP / online BT) — and the
service still executes them as a handful of vmapped ``solve_het`` calls:
structural parameters select the bucket, everything else rides as
per-instance operands (``HetParams``).

On a multi-device mesh (pass ``mesh=make_serve_mesh()``) the service places
buckets across the devices (DESIGN.md §6): small-request buckets run
*data-parallel* (batch axis sharded over the mesh, processors emulated
per-device), large single requests run *processor-sharded* (the mesh axis
is the paper's P; fusion is a compressed collective on the wire) and
dispatch immediately instead of queuing behind a batch. Dispatch is
ahead-of-results: engine calls launch asynchronously and materialize only
when a consumer pulls, so host-side padding/prep of the next batch overlaps
device compute.

Usage::

    svc = SolveService()
    results = svc.solve([SolveRequest(y=y, a=a, prior=prior, policy="bt"),
                         SolveRequest(y=y2, a=a2, n_iter=6, policy="fixed",
                                      deltas=np.full(6, 0.05)), ...])

or streaming (continuous batching)::

    for res in svc.stream(request_iter):
        ...  # results arrive per request as each bucket batch completes
"""
from __future__ import annotations

import dataclasses
import math
import operator
import threading
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from ..core.denoisers import BernoulliGauss
from ..core.engine import (AmpEngine, BlockQuantTransport, BTRateControl,
                           BTTables, ColBTTables, ColDPSchedule,
                           ColumnBTRateControl, ColumnPartition,
                           CompressedPsumTransport, EcsqTransport,
                           EngineConfig, ErasureSpec, HetParams, PsumFusion,
                           RowPartition, pad_bt_tables, split_problem_cols,
                           stack_bt_tables)
from ..core.quantize import ecsq_entropy, message_mixture, residual_mixture
from ..core.rate_alloc import (dp_allocate, dp_allocate_col,
                               erasure_rate_factors, stack_schedules)
from ..core.rate_distortion import RDModel
from ..core.state_evolution import CSProblem
from ..telemetry import (DRIFT_ALERT, DRIFT_BUCKETS, MetricsRegistry,
                         prometheus_text, se_drift, se_drift_batch)
from ..telemetry.spans import now as _tnow
from ..telemetry.spans import span as _tspan
from .batcher import Batcher
from .buckets import (BucketKey, BucketPolicy, batch_width_ladder,
                      bucket_for, pad_batch_size, placement_for, round_up)
from .operand_cache import OperandCache, fingerprint
from .wire import WireModel, measure_wire

__all__ = ["SolveRequest", "SolveResult", "SolveService", "PrewarmSpec"]


@dataclasses.dataclass
class SolveRequest:
    """One CS recovery request: y = A s0 + e, recover s0.

    ``policy`` selects the rate control:
      * ``"lossless"`` — exact fusion (the paper's 32-bit baseline),
      * ``"fixed"``    — caller-provided per-iteration bin sizes ``deltas``,
      * ``"dp"``       — offline-optimal DP allocation for ``dp_total_bits``
                         (paper Sec. 3.4); ``deltas`` may be pre-computed,
                         otherwise the service runs ``dp_allocate`` (the
                         RD model table is disk-cached per prior),
      * ``"bt"``       — online back-tracking (paper Sec. 3.3); in-graph
                         tables are built once per operating point
                         (prior, SNR, kappa, P, T) and cached.

    ``layout`` selects the partition scheme (DESIGN.md §7): ``None``
    routes by aspect ratio (``placement_for``), ``"row"``/``"col"``
    force one.  Column requests need N divisible by P (each processor
    owns an equal signal slice); every policy above works in either
    layout — the service builds the matching controller family
    (``dp_allocate_col`` / ``ColumnBTRateControl`` for column buckets).

    ``erasure_rate`` > 0 subjects the request's fusion packets to
    per-round, per-processor loss (``erasure_model``: i.i.d.
    ``"bernoulli"`` or bursty ``"gilbert"`` with mean burst
    ``erasure_burst``; the mask is drawn deterministically from
    ``erasure_seed``).  ``recovery`` selects the bit-accounting
    discipline the allocators plan for — ``"retransmit"`` (lost bits are
    re-sent, shrinking the payload budget) or ``"rate_up"`` (survivors
    spend the dropped share) — see ``rate_alloc``.  Erasure requests run
    the het program family (no singleton fast path).

    ``measure_wire`` opts the request into measured-bytes accounting:
    the engine traces the quantizer symbol streams and the service
    rANS-codes them host-side (``serving.wire``), reporting
    ``bytes_on_wire`` / ``time_on_air_s`` / ``energy_j`` on the result.
    Unsupported on the processor-sharded placement (symbols live
    per-device there).
    """

    y: np.ndarray
    a: np.ndarray
    prior: BernoulliGauss = dataclasses.field(default_factory=BernoulliGauss)
    snr_db: float = 20.0
    n_proc: int = 10
    n_iter: int = 8
    policy: str = "lossless"
    deltas: np.ndarray | None = None      # fixed / precomputed dp
    dp_total_bits: float | None = None    # dp (default 2.0 * n_iter)
    bt_c_ratio: float = 1.005
    bt_r_max: float = 6.0
    transport: str = "ecsq"               # "ecsq" | "block8" | "block4"
    layout: str | None = None             # None = auto | "row" | "col"
    erasure_rate: float = 0.0             # per-packet loss probability
    erasure_model: str = "bernoulli"      # "bernoulli" | "gilbert"
    erasure_burst: float = 4.0            # mean burst length (gilbert)
    erasure_seed: int = 0                 # mask draw (deterministic)
    recovery: str = "retransmit"          # "retransmit" | "rate_up"
    measure_wire: bool = False            # rANS-code symbol streams and
    #                                       report measured wire bytes
    a_id: str | None = None               # stable caller-managed identity of
    #                                       ``a`` for the operand cache; when
    #                                       set it replaces the content hash
    #                                       (the caller vouches the bytes
    #                                       behind one id never change)
    request_id: int = -1                  # assigned at submit
    spans: list | None = None             # telemetry trace spans
    #                                       ([name, host, t0, t1] lists,
    #                                       telemetry/spans.py); the
    #                                       cluster frontend stamps
    #                                       admit/route here and the
    #                                       backend appends its own

    @property
    def n(self) -> int:
        return self.a.shape[1]

    @property
    def m(self) -> int:
        return self.a.shape[0]

    def problem(self) -> CSProblem:
        return CSProblem(n=self.n, m=self.m, prior=self.prior,
                         snr_db=self.snr_db)


@dataclasses.dataclass
class SolveResult:
    """Per-request output, unpadded back to the request's own (N, T).

    ``rates`` is the per-iteration coding rate *per processor, in the
    layout's own wire unit* — bits per signal element for row buckets
    (the fusion exchanges length-N messages), bits per *measurement* for
    column buckets (length-M residual contributions; ``bucket.layout``
    disambiguates, and mixed-stream consumers must not sum across
    layouts).  The value is the BT controller's in-graph decision for
    ``policy="bt"``, the analytic ECSQ entropy H_Q of the model payload
    distribution (message mixture row-wise, residual Gaussian
    column-wise) for finite fixed/DP bins, the fixed wire width (bits +
    amortized bf16 scale) for block transports, and +inf for
    lossless-fusion iterations (untracked, excluded from ``total_bits`` —
    same convention as ``MPAMPResult``).
    """

    request_id: int
    x: np.ndarray             # (N,) final estimate
    sigma2_hat: np.ndarray    # (T,) plug-in variances: post-LC (row) /
    #                           post-fusion ||g||^2/M incl. quant (col)
    deltas: np.ndarray        # (T,) realized bin sizes (inf = lossless)
    extra_var: np.ndarray     # (T,) transport-injected variance P*sigma_Q^2
    rates: np.ndarray         # (T,) bits/elem (row) | bits/meas (col), /proc
    #                           on-the-wire under the recovery policy
    #                           (== delivered when erasure_rate = 0)
    total_bits: float         # sum of finite per-iteration rates
    bucket: BucketKey         # where this request was executed
    batch_size: int           # real requests in the executed batch
    bytes_on_wire: float | None = None   # measured rANS bytes incl. table/
    #                                      header/retransmit (measure_wire)
    payload_bytes: float | None = None   # measured rANS payload only — the
    #                                      number comparable to model H_Q
    time_on_air_s: float | None = None   # bytes_on_wire / link rate
    energy_j: float | None = None        # time_on_air * tx power
    se_drift: float | None = None        # mean |ln(realized/SE predicted)|
    #                                      per-iteration variance drift
    #                                      (telemetry/drift.py); None when
    #                                      telemetry is off
    spans: list | None = None            # completed trace spans
    #                                      (admit..complete) for this
    #                                      request

    def mse(self, s0: np.ndarray) -> float:
        return float(np.mean((self.x - np.asarray(s0)) ** 2))

    @property
    def tracked(self) -> bool:
        """Whether ``total_bits`` is a real measurement: False when no
        iteration reported a finite rate (all-lossless fusion), in which
        case the 0.0 total means "untracked", not "zero bits"."""
        return bool(np.isfinite(self.rates).any())


@dataclasses.dataclass(frozen=True)
class PrewarmSpec:
    """One entry of a prewarm menu (DESIGN.md §9): the structural shape of
    expected traffic. ``SolveService.prewarm`` expands each spec into its
    bucket x batch-width grid and AOT-compiles every program so steady-state
    requests never block on XLA.

    ``policy`` picks the compiled program family: "lossless"/"fixed"/"dp"
    share the has_bt=False program, "bt" compiles the in-graph-controller
    variant (and warms the BT table cache for (prior, snr_db) — streams
    mixing BT and non-BT traffic should list both). "dp" additionally warms
    the DP/RD allocation caches, which builds an RD table on first sight of
    a prior — only list it when that cost belongs in startup.

    ``batch_widths=None`` compiles the full ``batch_width_ladder`` of the
    service policy; pass an explicit tuple to narrow startup cost."""

    n: int
    m: int
    n_proc: int = 10
    n_iter: int = 8
    policy: str = "lossless"
    transport: str = "ecsq"
    layout: str | None = None
    snr_db: float = 20.0
    prior: BernoulliGauss = dataclasses.field(default_factory=BernoulliGauss)
    batch_widths: tuple | None = None


_TRANSPORTS = {
    "ecsq": EcsqTransport,
    "block8": lambda: BlockQuantTransport(bits=8, block=512),
    "block4": lambda: BlockQuantTransport(bits=4, block=512),
}

# processor-sharded engines fuse on the device links instead: the same wire
# format, executed as a collective (DESIGN.md §6)
_SHARDED_TRANSPORTS = {
    "ecsq": lambda axis: PsumFusion(axis=axis, local=EcsqTransport()),
    "block8": lambda axis: CompressedPsumTransport(axis=axis, bits=8,
                                                   block=512),
    "block4": lambda axis: CompressedPsumTransport(axis=axis, bits=4,
                                                   block=512),
}


# a dispatched-but-unmaterialized engine call (dispatch-ahead): calling it
# materializes the device results into SolveResults
_Pending = Callable[[], "list[SolveResult]"]

# sentinel: _finish_telemetry computes the drift itself (singleton /
# proc-sharded paths); the batched path passes a precomputed value
_COMPUTE = object()

# the operating-point fields that must agree across a bucket group for the
# vectorized drift path (one C-level multi-attr fetch per request beats six
# Python attribute reads on the hot path)
_DRIFT_ATTRS = operator.attrgetter("n_iter", "n", "m", "snr_db",
                                   "erasure_rate")


class SolveService:
    """Shape-bucketed continuous batching over ``AmpEngine.solve_het``,
    with mesh-aware bucket placement when a device mesh is provided."""

    def __init__(self, policy: BucketPolicy | None = None,
                 collect_xs: bool = False, rate_accounting: bool = True,
                 use_kernel: bool | None = None,
                 kernel_interpret: bool = False,
                 mesh=None, mesh_axis: str = "data",
                 operand_cache_bytes: int = 256 << 20,
                 singleton_fastpath: bool = True,
                 donate: bool = True,
                 wire_model: WireModel | None = None,
                 telemetry: bool = True):
        self.policy = policy or BucketPolicy()
        self.collect_xs = collect_xs
        self.rate_accounting = rate_accounting
        self.use_kernel = use_kernel
        self.kernel_interpret = kernel_interpret
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self.n_devices = 1 if mesh is None else mesh.shape[mesh_axis]
        if self.n_devices > 1:
            # data-parallel dispatch pads batches to a device multiple, so
            # max_batch must be one too or the documented compile-width cap
            # would be silently exceeded
            assert self.policy.max_batch % self.n_devices == 0, \
                f"max_batch={self.policy.max_batch} must be a multiple of " \
                f"the mesh device count ({self.n_devices})"
        self.wire_model = wire_model or WireModel()
        self._batcher = Batcher(self.policy)
        self._engines: dict[BucketKey, AmpEngine] = {}
        # symbol-tracing twins of the bucket engines for measured-wire
        # requests: a different trace pytree means a different compiled
        # program family, so they must not share the plain engines' caches
        self._wire_engines: dict[BucketKey, AmpEngine] = {}
        self._bt_cache: dict = {}
        self._rd_cache: dict = {}
        self._completed: list[SolveResult] = []
        self._pending: list[_Pending] = []
        self._next_id = 0
        # hot-path state (DESIGN.md §9): device-resident A shards keyed by
        # content fingerprint (0 bytes disables), plain-dispatch routing for
        # lone row requests, and operand donation on the batched engines
        self._opcache = (OperandCache(operand_cache_bytes)
                         if operand_cache_bytes > 0 else None)
        self.singleton_fastpath = singleton_fastpath
        self.donate = donate
        self._single_engines: dict = {}
        self._singleton_dispatches = 0
        self._prewarm_report: dict | None = None
        self._prewarm_thread: threading.Thread | None = None
        # guards id assignment and engine-map mutation against a background
        # prewarm thread racing foreground submits
        self._lock = threading.RLock()
        # telemetry plane (DESIGN.md §12): event-driven histograms/counters
        # on the request path plus a pull-time collector over the sources
        # that already keep their own atomic counters (engine, operand
        # cache, batcher). ``telemetry=False`` strips every hot-path write
        # — the bench's overhead baseline.
        self.telemetry = telemetry
        self._registry = None
        # per-layout label-bound metric children (metrics._Child): the
        # dispatch tails bump these without re-resolving label keys
        self._children: dict = {}
        if telemetry:
            reg = self._registry = MetricsRegistry()
            self._m_requests = reg.counter(
                "amp_requests_total",
                "Requests admitted (counted at group dispatch)",
                ("layout",))
            self._h_latency = reg.histogram(
                "amp_request_latency_seconds",
                "Admit -> result-finalized latency", ("layout",))
            self._h_batch_wait = reg.histogram(
                "amp_batch_wait_seconds",
                "Admit -> bucket batch dispatch wait", ("layout",))
            self._h_drift = reg.histogram(
                "amp_se_drift",
                "Per-request SE drift: mean |ln(realized/predicted)| "
                "per-iteration variance", ("layout",),
                buckets=DRIFT_BUCKETS)
            self._m_drift_alerts = reg.counter(
                "amp_se_drift_alerts_total",
                f"Requests whose SE drift exceeded {DRIFT_ALERT}",
                ("layout",))
            reg.collect(self._collect_metrics)

    # -- request intake ------------------------------------------------------

    def submit(self, req: SolveRequest) -> int:
        """Queue one request; a full bucket group dispatches immediately
        (results buffered until ``flush``/``stream`` hands them out).
        Processor-sharded requests dispatch at once — they consume the
        whole mesh, so queuing them behind a batch buys nothing."""
        t_admit = _tnow() if self.telemetry else 0.0
        req = self._prepare(req)
        key = self._key_for(req)
        if self.telemetry:
            # forwarded requests (cluster handoff) get their admit span
            # appended to an own copy of the list — the frontend's decoded
            # request must not see backend appends. Local requests stash
            # only the admit timestamp; the dispatch tails build the span
            # (one float attr beats a list build on the hot path, and
            # amp_requests_total is likewise bumped per dispatched group).
            sp = req.spans
            if sp:
                req.spans = [*sp, ["admit", None, t_admit, _tnow()]]
            else:
                req._t_admit = t_admit
        if key.placement == "proc":
            self._pending.append(self._dispatch_bucket(key, [req]))
            return req.request_id
        full = self._batcher.add(key, req)
        if full is not None:
            self._pending.append(self._dispatch_bucket(*full))
        return req.request_id

    def _collect_pending(self):
        """Materialize every dispatched batch into ``_completed`` (FIFO)."""
        pending, self._pending = self._pending, []
        for finalize in pending:
            self._completed.extend(finalize())

    def poll(self) -> list[SolveResult]:
        """Materialize every *already dispatched* batch and hand back all
        buffered results — without forcing partially-filled bucket groups
        to dispatch (unlike ``flush``). The cluster frontend's per-submit
        collection hook: full batches stream out as they complete while
        stragglers keep accumulating toward their batch width."""
        self._collect_pending()
        out, self._completed = self._completed, []
        return out

    def flush(self) -> list[SolveResult]:
        """Dispatch all pending groups; return every buffered result."""
        # dispatch everything first, then materialize: the engine calls
        # overlap on device while the host pads the next group's operands
        for key, group in self._batcher.drain():
            self._pending.append(self._dispatch_bucket(key, group))
        self._collect_pending()
        out, self._completed = self._completed, []
        return out

    def solve(self, reqs) -> list[SolveResult]:
        """Submit + flush; results in submission order. Results belonging
        to earlier ``submit`` calls that this flush happened to complete
        stay buffered for their own ``flush``/``stream`` consumer."""
        ids = [self.submit(r) for r in reqs]
        own = set(ids)
        by_id = {}
        for r in self.flush():
            if r.request_id in own:
                by_id[r.request_id] = r
            else:
                self._completed.append(r)
        return [by_id[i] for i in ids]

    def stream(self, reqs):
        """Continuous batching: yield results per request as each bucket
        batch completes; stragglers flush when the input is exhausted.
        Like ``solve``, results belonging to other consumers' earlier
        ``submit`` calls stay buffered for them."""
        own = set()

        def take_own():
            keep = []
            for r in self._completed:
                if r.request_id in own:
                    yield r
                else:
                    keep.append(r)
            self._completed = keep

        for r in reqs:
            own.add(self.submit(r))
            # materialize whatever submit dispatched: stream's contract is
            # per-batch yield timing, so collection here is blocking (the
            # dispatch itself already ran async during submit)
            self._collect_pending()
            if self._completed:
                yield from take_own()
        for key, group in self._batcher.drain():
            self._pending.append(self._dispatch_bucket(key, group))
        self._collect_pending()
        yield from take_own()

    # -- internals -----------------------------------------------------------

    def _prepare(self, req: SolveRequest,
                 assign_id: bool = True) -> SolveRequest:
        if req.request_id >= 0:
            # template reuse: resubmitting an already-served request object
            # must not alias two queue entries onto one id (cold path) —
            # and must not inherit the previous serve's trace spans. A
            # span list ending in "route" is not stale: it's a cluster
            # frontend's in-flight handoff (admit+route stamped just
            # before forwarding), which the backend must extend.
            fwd = bool(req.spans) and req.spans[-1][0] == "route"
            req = dataclasses.replace(
                req, spans=req.spans if fwd else None)
        # id assignment mutates in place: dataclasses.replace would copy the
        # request row on the hot path for no benefit; prewarm's dummy
        # requests skip it so the id sequence stays a pure submission
        # counter (callers index their own bookkeeping by it)
        if assign_id:
            with self._lock:
                req.request_id = self._next_id
                self._next_id += 1
        assert req.policy in ("lossless", "fixed", "dp", "bt"), req.policy
        assert req.transport in _TRANSPORTS, req.transport
        if req.transport != "ecsq":
            # block transports fix the rate by wire width and ignore the
            # controller's bin size — an ECSQ rate policy would be silently
            # unenforced (and its rate accounting fiction)
            assert req.policy == "lossless", \
                f"policy={req.policy!r} has no effect under " \
                f"transport={req.transport!r}; use policy='lossless'"
        assert req.layout in (None, "row", "col"), req.layout
        assert 0.0 <= req.erasure_rate < 1.0, req.erasure_rate
        assert req.erasure_model in ("bernoulli", "gilbert"), \
            req.erasure_model
        assert req.recovery in ("retransmit", "rate_up"), req.recovery
        if req.layout is None:
            # pin the auto-routed layout on our copy so every later stage
            # (bucket key, operands, rate accounting) agrees — via replace,
            # not mutation: the caller's template must stay layout=None
            # (another service with a different col_aspect may route it
            # differently)
            req = dataclasses.replace(
                req, layout=placement_for(req.n, req.m, req.n_proc,
                                          self.n_devices, self.policy)[1])
        if req.layout == "col":
            assert req.n % req.n_proc == 0, \
                f"N={req.n} not divisible by P={req.n_proc} (column layout)"
        else:
            assert req.m % req.n_proc == 0, \
                f"M={req.m} not divisible by P={req.n_proc}"
        if req.policy == "fixed":
            assert req.deltas is not None, "fixed policy needs deltas"
            assert len(req.deltas) == req.n_iter
        if req.policy == "dp" and req.deltas is None:
            req = dataclasses.replace(req, deltas=self._dp_deltas(req))
        return req

    def _key_for(self, req: SolveRequest) -> BucketKey:
        placement, _ = placement_for(req.n, req.m, req.n_proc,
                                     self.n_devices, self.policy)
        return bucket_for(req.n, req.m, req.n_proc, req.n_iter,
                          req.transport, self.policy, placement, req.layout)

    def _engine(self, key: BucketKey, wire: bool = False) -> AmpEngine:
        # data-parallel buckets reuse the local engine object: the sharding
        # lives on the operands, and jit re-specializes the same callable
        ekey = (key if key.placement == "proc"
                else dataclasses.replace(key, placement="local"))
        assert not (wire and key.placement == "proc"), \
            "measured-wire accounting needs host-visible symbol streams; " \
            "the processor-sharded placement keeps them per-device " \
            "(engine.py collect_symbols contract)"
        cache = self._wire_engines if wire else self._engines
        with self._lock:
            eng = cache.get(ekey)
            if eng is None:
                cfg = EngineConfig(
                    n_proc=key.n_proc, n_iter=key.t_max,
                    use_kernel=self.use_kernel,
                    kernel_interpret=self.kernel_interpret,
                    collect_symbols=wire, collect_xs=self.collect_xs,
                    layout=(ColumnPartition(n_inner=1) if key.layout == "col"
                            else RowPartition()),
                    # batched operands are per-flush temporaries -> donate;
                    # the proc placement's jit donates only y (engine.py):
                    # its A may be a cache-resident buffer
                    donate=self.donate)
                if ekey.placement == "proc":
                    transport = _SHARDED_TRANSPORTS[key.transport](
                        self.mesh_axis)
                else:
                    transport = _TRANSPORTS[key.transport]()
                eng = AmpEngine(BernoulliGauss(), cfg, transport)
                cache[ekey] = eng
        return eng

    def _single_engine(self, req: SolveRequest) -> AmpEngine:
        """True-dims plain engine for the singleton fast path. Keyed on
        everything ``_scan_fn`` closes over (the prior lives on the engine
        here, unlike the het path where it rides as an operand)."""
        skey = (req.n, req.m, req.n_proc, req.n_iter, req.transport,
                req.prior)
        with self._lock:
            eng = self._single_engines.get(skey)
            if eng is None:
                cfg = EngineConfig(
                    n_proc=req.n_proc, n_iter=req.n_iter,
                    use_kernel=self.use_kernel,
                    kernel_interpret=self.kernel_interpret,
                    collect_symbols=False, collect_xs=self.collect_xs)
                # donate=False: this path runs on cache-resident operands
                eng = AmpEngine(req.prior, cfg,
                                _TRANSPORTS[req.transport]())
                self._single_engines[skey] = eng
        return eng

    def _dp_deltas(self, req: SolveRequest) -> np.ndarray:
        """Offline DP allocation realized as ECSQ bin sizes (DPSchedule /
        ColDPSchedule for column requests).

        Under erasure the allocators plan for the request's recovery
        policy; the realized bins then encode the *delivered* per-survivor
        rates (allocated * survivor_boost), which is what the quantizers
        on the surviving packets actually spend."""
        from ..core.engine import DPSchedule
        prob = req.problem()
        r_total = (req.dp_total_bits if req.dp_total_bits is not None
                   else 2.0 * req.n_iter)
        _, boost, _ = erasure_rate_factors(req.erasure_rate, req.recovery)
        if req.layout == "col":
            dp = dp_allocate_col(prob, req.n_proc, req.n_iter, r_total,
                                 erasure_rate=req.erasure_rate,
                                 recovery=req.recovery)
            if boost != 1.0:
                dp = dataclasses.replace(dp, rates=dp.rates * boost)
            return ColDPSchedule(dp, prob, req.n_proc).deltas
        rd = self._rd_cache.get(req.prior)
        if rd is None:
            rd = self._rd_cache[req.prior] = RDModel(req.prior)
        dp = dp_allocate(prob, req.n_proc, req.n_iter, r_total, rd=rd,
                         erasure_rate=req.erasure_rate,
                         recovery=req.recovery)
        if boost != 1.0:
            dp = dataclasses.replace(dp, rates=dp.rates * boost)
        return DPSchedule(dp, rd, req.n_proc).deltas

    def _bt_tables(self, req: SolveRequest, t_max: int):
        """Padded in-graph tables for one operating point, memoized per
        (operating point, t_max) so repeated/pad-slot requests share one
        object — which keeps ``stack_bt_tables``'s zero-copy fast path.
        Column requests get ``ColumnBTRateControl`` tables."""
        key = (req.prior, round(req.snr_db, 6), req.n, req.m, req.n_proc,
               req.n_iter, req.bt_c_ratio, req.bt_r_max, req.layout,
               req.erasure_rate, req.recovery)
        padded = self._bt_cache.get((key, t_max))
        if padded is None:
            ctrl = self._bt_cache.get(key)
            if ctrl is None:
                if req.layout == "col":
                    ctrl = ColumnBTRateControl(
                        req.problem(), req.n_proc, req.n_iter,
                        req.bt_c_ratio, req.bt_r_max,
                        erasure_rate=req.erasure_rate,
                        recovery=req.recovery)
                else:
                    ctrl = BTRateControl(req.problem(), req.n_proc,
                                         req.n_iter, req.bt_c_ratio,
                                         req.bt_r_max, "ecsq",
                                         erasure_rate=req.erasure_rate,
                                         recovery=req.recovery)
                self._bt_cache[key] = ctrl
            padded = pad_bt_tables(ctrl.tables, t_max)
            self._bt_cache[(key, t_max)] = padded
        return padded

    def _drop_mask(self, req: SolveRequest,
                   n_proc: int | None = None) -> np.ndarray | None:
        """The (n_iter, P) erasure mask of one request, or None when the
        link is lossless. Deterministic in the request's erasure fields,
        so dispatch (operand build) and result finalization (retransmit
        byte accounting) independently reconstruct the same draw."""
        if req.erasure_rate == 0.0:
            return None
        spec = ErasureSpec(rate=req.erasure_rate, model=req.erasure_model,
                           burst_len=req.erasure_burst,
                           seed=req.erasure_seed)
        return spec.sample_mask(req.n_iter, n_proc or req.n_proc)

    def _fingerprint(self, req: SolveRequest):
        """Operand-cache identity of a request's A: the caller-vouched
        ``a_id`` when set, else the content hash (in-place mutation of a
        caller's array is then a miss, never a stale hit)."""
        return req.a_id if req.a_id is not None else fingerprint(req.a)

    def _pad_a_one(self, key: BucketKey, r: SolveRequest) -> np.ndarray:
        """Host-side pad of one request's A into its bucket shard shape:
        (P, mp_pad, n_pad) row / (P, m_pad, np_pad) col (docstring of
        ``_het_operands`` for the padding semantics)."""
        p, mp_pad, n_pad = key.n_proc, key.mp_pad, key.n_pad
        if key.layout == "col":
            buf = np.zeros((p, mp_pad, n_pad // p), np.float32)
            buf[:, :r.m, :r.n // p] = split_problem_cols(
                np.asarray(r.a, np.float32), p)
        else:
            mp = r.m // p
            buf = np.zeros((p, mp_pad, n_pad), np.float32)
            buf[:, :mp, :r.n] = np.asarray(r.a, np.float32).reshape(
                p, mp, r.n)
        return buf

    def _a_slice(self, key: BucketKey, r: SolveRequest, eng: AmpEngine):
        """Device-resident padded A shards for one request: built (pad +
        dtype cast + upload) once per (fingerprint, bucket shard shape) and
        reused across batches and streams. The cached buffer is never
        donated (engine.py wires donation onto the stacked temporaries
        only), so reuse is safe."""
        ck = (key.layout, self._fingerprint(r), key.n_proc, key.mp_pad,
              key.n_pad, eng.cfg.a_dtype)
        build = lambda: jnp.asarray(self._pad_a_one(key, r),
                                    eng.cfg.a_jdtype)
        if self._opcache is None:
            return build()
        return self._opcache.get(ck, build)

    def _a_batch(self, key: BucketKey, batch: list, eng: AmpEngine,
                 use_cache: bool = True):
        """Batch A operand: a device-side stack over cache-resident shards
        (a pad slot repeating a real request hits the same entry), or the
        legacy host-assembled numpy block when the cache is off —
        including prewarm, whose all-zero dummies must not pollute it."""
        if self._opcache is not None and use_cache:
            return jnp.stack([self._a_slice(key, r, eng) for r in batch])
        return np.stack([self._pad_a_one(key, r) for r in batch])

    def _y_and_params(self, key: BucketKey, batch: list):
        """Per-flush (small) operands: padded y and the per-instance
        ``HetParams``. Unlike A these change with every request, so they
        are host-built fresh and donated into the program."""
        p, mp_pad, t_max = key.n_proc, key.mp_pad, key.t_max
        b = len(batch)
        is_col = key.layout == "col"
        if is_col:
            y_b = np.zeros((b, mp_pad), np.float32)
        else:
            y_b = np.zeros((b, p, mp_pad), np.float32)
        scheds, tacts, mreals, nreals = [], [], [], []
        eps, mus, sss, use_bt, tables = [], [], [], [], []
        for i, r in enumerate(batch):
            if is_col:
                y_b[i, :r.m] = np.asarray(r.y, np.float32)
            else:
                mp = r.m // p
                y_b[i, :, :mp] = np.asarray(r.y, np.float32).reshape(p, mp)
            if r.policy in ("fixed", "dp"):
                scheds.append(np.asarray(r.deltas, np.float32))
            else:  # lossless / bt: schedule operand unused or all-lossless
                scheds.append(np.full(r.n_iter, np.inf, np.float32))
            tacts.append(r.n_iter)
            mreals.append(r.m)
            nreals.append(r.n)
            eps.append(r.prior.eps)
            mus.append(r.prior.mu_s)
            sss.append(r.prior.sigma_s)
            if r.policy == "bt":
                use_bt.append(True)
                tables.append(self._bt_tables(r, t_max))
            else:
                use_bt.append(False)
                tables.append(ColBTTables.dummy(t_max) if is_col
                              else BTTables.dummy(t_max))

        # erasure masks ride as a (B, T, P) operand only when some request
        # in the batch actually loses packets — drop=None keeps the
        # pre-erasure operand avals and compiled programs byte-identical.
        # Lossless co-batched requests get all-zero masks (a numeric no-op
        # through the survivor-rescale/reset paths). On the
        # processor-sharded placement the mask axis is the mesh device.
        drops = None
        if any(r.erasure_rate > 0.0 for r in batch):
            p_mask = self.n_devices if key.placement == "proc" else p
            drops = np.zeros((b, t_max, p_mask), np.float32)
            for i, r in enumerate(batch):
                m = self._drop_mask(r, p_mask)
                if m is not None:
                    drops[i, :r.n_iter] = m

        params = HetParams(
            sched=stack_schedules(scheds, t_max),
            t_active=np.asarray(tacts, np.int32),
            m_real=np.asarray(mreals, np.float32),
            n_real=np.asarray(nreals, np.int32),
            eps=np.asarray(eps, np.float32),
            mu_s=np.asarray(mus, np.float32),
            sigma_s=np.asarray(sss, np.float32),
            use_bt=np.asarray(use_bt),
            bt=stack_bt_tables(tables),
            drop=drops,
        )
        return y_b, params, any(use_bt)

    def _het_operands(self, key: BucketKey, batch: list,
                      use_cache: bool = True):
        """Pad one request group into the engine's het operands.

        Row buckets: a (B, P, mp_pad, n_pad) row shards + y (B, P, mp_pad).
        Column buckets: a (B, P, m_pad, np_pad) column shards (each
        processor's real columns padded within its own slice, mirroring
        the row layout's per-shard row padding) + the shared y (B, m_pad).
        """
        a_b = self._a_batch(key, batch, self._engine(key), use_cache)
        y_b, params, has_bt = self._y_and_params(key, batch)
        return a_b, y_b, params, has_bt

    def _dispatch_bucket(self, key: BucketKey, reqs: list) -> _Pending:
        """Launch one bucket group on its placement; materialization is
        deferred to the returned ``_Pending.finalize``."""
        if key.placement == "proc":
            return self._dispatch_proc(key, reqs)
        if len(reqs) == 1 and self._singleton_ok(key, reqs[0]):
            return self._dispatch_singleton(key, reqs[0])

        b_real = len(reqs)
        b_pad = pad_batch_size(b_real, self.policy)
        if key.placement == "data":
            # the batch axis shards over the mesh: pad to a device multiple
            b_pad = round_up(b_pad, self.n_devices)
        # fill pad slots by repeating real requests (their results are
        # dropped); keeps every instance numerically benign — and on the
        # cached path a pad slot is an operand-cache hit, not a rebuild
        batch = [reqs[i % b_real] for i in range(b_pad)]
        # a measured-wire request anywhere in the group routes the whole
        # batch onto the symbol-tracing engine twin (same math, bigger
        # trace); pure streams of either kind never double-compile
        wire = any(r.measure_wire for r in reqs)
        eng = self._engine(key, wire)
        t_op0 = _tnow() if self.telemetry else 0.0
        a_b = self._a_batch(key, batch, eng)
        y_b, params, has_bt = self._y_and_params(key, batch)
        if key.placement == "data":
            shard = NamedSharding(self.mesh, PartitionSpec(self.mesh_axis))
            a_b, y_b, params = jax.device_put((a_b, y_b, params), shard)
        t_c0 = _tnow() if self.telemetry else 0.0
        # a_b/y_b are per-flush temporaries: the donating engine consumes
        # them (the cached per-request shards behind the stack survive)
        x_outs = eng.dispatch_het(a_b, y_b, params, has_bt=has_bt)

        def finalize() -> list[SolveResult]:
            trace = eng.trace_of(x_outs)
            shared = self._batch_spans(t_op0, t_c0)
            if not self.telemetry or wire:
                # measured-wire groups keep the per-request tail (their
                # wire_measure span interleaves result assembly); with
                # telemetry off there is no tail at all
                return [self._result_one(key, r, trace, i, b_real,
                                         shared_spans=shared)
                        for i, r in enumerate(reqs)]
            t_fin0 = _tnow()
            out = [self._result_one(key, r, trace, i, b_real, defer=True)
                   for i, r in enumerate(reqs)]
            self._batch_tail(key, reqs, out, shared, trace, t_fin0)
            return out

        return finalize

    def _layout_children(self, layout: str) -> dict:
        """Label-bound metric handles for one layout, resolved once."""
        ch = self._children.get(layout)
        if ch is None:
            ch = self._children[layout] = {
                "requests": self._m_requests.labels(layout=layout),
                "latency": self._h_latency.labels(layout=layout),
                "batch_wait": self._h_batch_wait.labels(layout=layout),
                "drift": self._h_drift.labels(layout=layout),
                "alerts": self._m_drift_alerts.labels(layout=layout),
            }
        return ch

    def _batch_tail(self, key: BucketKey, reqs: list, results: list,
                    shared: list, trace, t_fin0: float) -> None:
        """Telemetry tail for one batched group in a single warm pass:
        spans assembled per request with the operands/compute/complete
        spans shared verbatim (the batch is the unit of execution, so
        its finalization is one ``complete`` span), the drift-path
        uniformity check folded into the same loop, histograms fed by
        one bulk observe per metric. Replaces B per-request
        ``_finish_telemetry`` calls on the hot path — the <=2% overhead
        budget (DESIGN.md §12)."""
        ch = self._layout_children(key.layout)
        t_end = _tnow()
        sh0 = shared[0][2]
        op_s, cp_s = shared
        co_s = ["complete", None, t_fin0, t_end]
        lats: list = []
        waits: list = []
        lat_add, wait_add = lats.append, waits.append
        r0 = reqs[0]
        v0 = _DRIFT_ATTRS(r0)
        p0 = r0.prior
        uniform = True
        for r, res in zip(reqs, results):
            rs = r.spans
            if rs:
                t_a = rs[-1][3]
                spans = [*rs, ["batch_wait", None, t_a, sh0],
                         op_s, cp_s, co_s]
                if rs[0][0] == "admit":
                    lat_add(t_end - rs[0][2])
            else:
                # local request: submit stashed only the admit timestamp
                # (an instant span); batch_wait covers submit -> dispatch
                t_a = getattr(r, "_t_admit", sh0)
                spans = [["admit", None, t_a, t_a],
                         ["batch_wait", None, t_a, sh0], op_s, cp_s, co_s]
                lat_add(t_end - t_a)
            res.spans = spans
            wait_add(sh0 - t_a)
            if uniform and not (r.prior is p0 and _DRIFT_ATTRS(r) == v0):
                uniform = False
        ch["requests"].inc(len(reqs))
        if lats:
            ch["latency"].observe_many(lats)
        ch["batch_wait"].observe_many(waits)
        self._drift_tail(key, reqs, results, trace, uniform, ch)

    def _drift_tail(self, key: BucketKey, reqs: list, results: list,
                    trace, uniform: bool, ch: dict) -> None:
        """SE drift for a whole bucket group (DESIGN.md §12), written
        onto the already-built results. A group uniform in operating
        point — the steady-stream common case — pays one vectorized
        masked log-ratio pass (one memoized prediction lookup per
        distinct realized schedule, see ``se_drift_batch``); mixed
        groups fall back to the per-request memoized path. Amortizing
        here is what keeps enabled telemetry inside the <=2% overhead
        budget (``BENCH_serve.json`` telemetry_overhead)."""
        layout = "col" if key.layout == "col" else "row"
        r0 = reqs[0]
        dr: list = []
        dr_add = dr.append
        isfin = math.isfinite
        try:
            if uniform:
                t = r0.n_iter
                s2 = np.asarray(trace.sigma2_hat)[:len(reqs), :t]
                ev = np.asarray(trace.extra_var)[:len(reqs), :t]
                sched = ev[0] if np.array_equiv(ev[:1], ev) else ev
                drifts = se_drift_batch(
                    r0.problem(), s2, sched, layout=layout,
                    n_proc=r0.n_proc, erasure_rate=r0.erasure_rate)
                for res, d in zip(results, drifts.tolist()):
                    if isfin(d):
                        res.se_drift = d
                        dr_add(d)
            else:
                s2_all = np.asarray(trace.sigma2_hat)
                ev_all = np.asarray(trace.extra_var)
                for i, (r, res) in enumerate(zip(reqs, results)):
                    try:
                        d, _ = se_drift(r.problem(), s2_all[i, :r.n_iter],
                                        ev_all[i, :r.n_iter], layout=layout,
                                        n_proc=r.n_proc,
                                        erasure_rate=r.erasure_rate)
                    except Exception:
                        continue
                    if isfin(d):
                        res.se_drift = d
                        dr_add(d)
        except Exception:
            # the monitor is advisory: a drift failure never fails a solve
            return
        if dr:
            ch["drift"].observe_many(dr)
            n_alert = sum(1 for d in dr if d > DRIFT_ALERT)
            if n_alert:
                ch["alerts"].inc(n_alert)

    def _batch_spans(self, t_op0: float, t_c0: float) -> list | None:
        """Batch-level spans stamped at finalize time: operand build/
        upload (t_op0 -> dispatch) and device compute (dispatch -> trace
        materialized). Shared verbatim by every request in the batch —
        the batch is the unit of execution."""
        if not self.telemetry:
            return None
        t_done = _tnow()
        return [_tspan("operands", t_op0, t_c0),
                _tspan("compute", t_c0, t_done)]

    def _singleton_ok(self, key: BucketKey, r: SolveRequest) -> bool:
        """Whether a lone request may skip batch padding + het-operand
        assembly and run the plain true-dims ``dispatch_single`` program
        (DESIGN.md §9). BT stays on the het path (its controller is the
        in-graph het table machinery); col stays batched (no plain
        single-dispatch entry point); erasure and measured-wire requests
        stay on the het path too (drop operands and symbol tracing are
        het-program features)."""
        return (self.singleton_fastpath and key.placement == "local"
                and key.layout == "row" and r.policy != "bt"
                and r.erasure_rate == 0.0 and not r.measure_wire)

    def _dispatch_singleton(self, key: BucketKey, r: SolveRequest) \
            -> _Pending:
        """Singleton fast path: true-dims solve on a plain engine, A from
        the operand cache, schedule riding as the ``sched`` operand. No
        bucket padding, no HetParams stack, no donation (A is
        cache-resident)."""
        eng = self._single_engine(r)
        self._singleton_dispatches += 1
        t_op0 = _tnow() if self.telemetry else 0.0
        ck = ("single", self._fingerprint(r), r.n_proc,
              eng.cfg.kernel_on, eng.cfg.a_dtype)
        # _split row-splits + tile-aligns + casts; cache the result so a
        # repeated-A stream pays it once
        build = lambda: eng._split(np.zeros(r.m, np.float32), r.a)[0]
        a_p = build() if self._opcache is None \
            else self._opcache.get(ck, build)
        p = r.n_proc
        mp = r.m // p
        y_p = np.asarray(r.y, np.float32).reshape(p, mp)
        mp_pad = a_p.shape[1]
        if mp_pad != mp:   # kernel-path tile alignment
            y_p = np.pad(y_p, ((0, 0), (0, mp_pad - mp)))
        if r.policy in ("fixed", "dp"):
            sched = np.asarray(r.deltas, np.float32)
        else:
            sched = np.full(r.n_iter, np.inf, np.float32)
        t_c0 = _tnow() if self.telemetry else 0.0
        x_outs = eng.dispatch_single(a_p, y_p, r.m, r.n, sched=sched)

        def finalize() -> list[SolveResult]:
            trace = eng.trace_of(x_outs)
            return [self._result_one(key, r, trace, None, 1,
                                     shared_spans=self._batch_spans(
                                         t_op0, t_c0))]

        return finalize

    def _dispatch_proc(self, key: BucketKey, reqs: list) -> _Pending:
        """Processor-sharded placement: each request owns the whole mesh for
        one ``dispatch_sharded`` call (still padded to the bucket shape, so
        the compile cache stays bounded). A rides from the operand cache —
        for these mesh-sized matrices the once-per-fingerprint pad+upload
        is the dominant saving; the sharded jit donates only y."""
        eng = self._engine(key)
        t_op0 = _tnow() if self.telemetry else 0.0
        dispatched = []
        for r in reqs:
            assert not r.measure_wire, \
                "measure_wire is unsupported on the processor-sharded " \
                "placement (symbols stay per-device); pin layout/shape " \
                "to a local or data-parallel bucket"
            a_p = self._a_slice(key, r, eng)
            y_b, params, has_bt = self._y_and_params(key, [r])
            hp = jax.tree.map(lambda v: np.asarray(v)[0], params)
            t_c0 = _tnow() if self.telemetry else 0.0
            dispatched.append((eng.dispatch_sharded(
                a_p, y_b[0], hp, self.mesh, has_bt=has_bt), t_c0))

        def finalize() -> list[SolveResult]:
            return [self._result_one(key, r, eng.trace_of(x_outs), None, 1,
                                     shared_spans=self._batch_spans(
                                         t_op0, t_c0))
                    for r, (x_outs, t_c0) in zip(reqs, dispatched)]

        return finalize

    def _result_one(self, key: BucketKey, r: SolveRequest, trace,
                    i: int | None, batch_size: int,
                    shared_spans: list | None = None,
                    drift=_COMPUTE, defer: bool = False) -> SolveResult:
        """Unpad one request's slice of a trace (``i=None``: unbatched
        processor-sharded trace). ``defer=True`` (the batched hot path)
        skips the per-request telemetry tail: the caller has the drift
        precomputed and assembles spans + histograms for the whole group
        in ``_batch_tail``."""
        t_fin0 = _tnow() if self.telemetry and not defer else 0.0
        t = r.n_iter
        sel = (lambda a: a[:t]) if i is None else (lambda a: a[i, :t])
        x_pad = trace.x if i is None else trace.x[i]
        if key.layout == "col":
            # per-slice column padding: real columns are the leading
            # n/P entries of each processor's slice
            p = key.n_proc
            x = x_pad.reshape(p, key.n_pad // p)[:, :r.n // p].reshape(-1)
        else:
            x = x_pad[:r.n]
        s2 = sel(trace.sigma2_hat)
        deltas = sel(trace.deltas)
        extra_var = sel(trace.extra_var)
        rates = self._rates(r, s2, deltas, sel(trace.rates), extra_var)
        finite = np.isfinite(rates)
        wire = None
        wire_span = None
        if r.measure_wire and trace.symbols is not None:
            syms = trace.symbols if i is None else trace.symbols[i]
            # payload = length-N messages (row) / length-M residual
            # contributions (col); padding columns quantize zeros
            n_elem = r.m if key.layout == "col" else r.n
            t_w0 = _tnow() if self.telemetry else 0.0
            wire = measure_wire(syms[:t, :, :n_elem], deltas, n_elem,
                                drop=self._drop_mask(r),
                                recovery=r.recovery,
                                model=self.wire_model)
            if self.telemetry:
                wire_span = _tspan("wire_measure", t_w0)
        if defer:
            # batched hot path: _batch_tail/_drift_tail fill spans and
            # se_drift for the whole group after the listcomp
            drift, spans = None, None
        else:
            drift, spans = self._finish_telemetry(
                key, r, s2, extra_var, t_fin0, shared_spans, wire_span,
                drift=drift)
        return SolveResult(
            request_id=r.request_id,
            x=x.copy(),
            sigma2_hat=s2.copy(), deltas=deltas.copy(),
            extra_var=extra_var.copy(), rates=rates,
            total_bits=float(rates[finite].sum()),
            bucket=key, batch_size=batch_size,
            bytes_on_wire=None if wire is None else wire["bytes_on_wire"],
            payload_bytes=None if wire is None else wire["payload_bytes"],
            time_on_air_s=None if wire is None else wire["time_on_air_s"],
            energy_j=None if wire is None else wire["energy_j"],
            se_drift=drift, spans=spans,
        )

    def _finish_telemetry(self, key: BucketKey, r: SolveRequest, s2,
                          extra_var, t_fin0: float,
                          shared_spans: list | None,
                          wire_span: list | None, drift=_COMPUTE):
        """Per-request telemetry tail for the singleton / proc-sharded /
        measured-wire paths (the batched hot path uses ``_batch_tail``
        instead): SE drift vs the operating point's prediction (memoized
        — telemetry/drift.py) plus span assembly (batch_wait derived from
        the admit span's end to the group's operand-build start) and the
        latency/drift histograms."""
        if not self.telemetry:
            return None, None
        ch = self._layout_children(key.layout)
        ch["requests"].inc()
        if drift is _COMPUTE:
            try:
                drift, _ = se_drift(
                    r.problem(), s2, extra_var,
                    layout="col" if key.layout == "col" else "row",
                    n_proc=r.n_proc, erasure_rate=r.erasure_rate)
            except Exception:
                # a drift failure must never fail the solve: the monitor
                # is advisory (NaN drift shows up in the histogram's
                # absence)
                drift = None
            if drift is not None and not math.isfinite(drift):
                drift = None
        if drift is not None:
            ch["drift"].observe(drift)
            if drift > DRIFT_ALERT:
                ch["alerts"].inc()
        spans = list(r.spans or [])
        if not spans:
            # local request: submit stashed only the admit timestamp
            t_a = getattr(r, "_t_admit", None)
            if t_a is not None:
                spans = [["admit", None, t_a, t_a]]
        if shared_spans:
            t_admit_end = spans[-1][3] if spans else shared_spans[0][2]
            spans.append(["batch_wait", None, t_admit_end,
                          shared_spans[0][2]])
            spans.extend(shared_spans)
            ch["batch_wait"].observe(shared_spans[0][2] - t_admit_end)
        if wire_span is not None:
            spans.append(wire_span)
        t_tail0 = wire_span[3] if wire_span is not None else t_fin0
        t_end = _tnow()
        spans.append(["complete", None, t_tail0, t_end])
        if spans and spans[0][0] == "admit":
            ch["latency"].observe(t_end - spans[0][2])
        return drift, spans

    def _rates(self, req: SolveRequest, s2, deltas, bt_rates,
               extra_var) -> np.ndarray:
        """Realized-rate accounting for one request (see SolveResult).

        Column requests model the quantized payload as the residual
        contribution's Gaussian (``residual_mixture``): the payload of
        round t is built from the estimate after round t-1, whose block
        MSE reads off *this* round's plug-in,
        d^{t-1} = kappa * (v̂_t - sigma_e^2 - P sigma_Q^2_t).  Round 0
        exchanges all-zero contributions — 0 bits at any bin size — and
        is counted as 0.0 whenever the request is rate-tracked at all
        (a fully lossless request stays untracked, all-inf).

        Under erasure the reported rates are *on-the-wire*: the delivered
        model rate times the recovery policy's wire factor (retransmit
        re-sends dropped packets, rate_up's allocated slot rate is what
        each slot transmits) — ``erasure_rate_factors``. Exactly the
        delivered rate on a lossless link.
        """
        rates = self._rates_delivered(req, s2, deltas, bt_rates, extra_var)
        if req.erasure_rate > 0.0:
            _, _, wire_f = erasure_rate_factors(req.erasure_rate,
                                                req.recovery)
            fin = np.isfinite(rates)
            rates = np.where(fin, rates * wire_f, rates)
        return rates

    def _rates_delivered(self, req: SolveRequest, s2, deltas, bt_rates,
                         extra_var) -> np.ndarray:
        if req.policy == "bt":
            return np.asarray(bt_rates, np.float64)
        if req.transport != "ecsq":
            # block transports spend a fixed wire rate every iteration:
            # `bits` per element plus a bf16 scale per block
            tp = _TRANSPORTS[req.transport]()
            rates = np.full(req.n_iter, tp.bits + 16.0 / tp.block)
            if req.layout == "col":
                rates[0] = 0.0   # zero contributions: nothing on the wire
            return rates
        rates = np.full(req.n_iter, np.inf)
        if not self.rate_accounting:
            return rates
        prob = req.problem() if req.layout == "col" else None
        sm = req.prior.second_moment
        for t in range(1 if req.layout == "col" else 0, req.n_iter):
            d = float(deltas[t])
            if not math.isfinite(d):
                continue
            if req.layout == "col":
                d_blk = prob.kappa * (float(s2[t]) - prob.sigma_e2
                                      - float(extra_var[t]))
                mix = residual_mixture(req.prior,
                                       min(max(d_blk, 1e-12), sm),
                                       prob.kappa, req.n_proc)
            else:
                mix = message_mixture(req.prior, float(s2[t]), req.n_proc)
            rates[t] = float(ecsq_entropy(d, mix)[0])
        if req.layout == "col" and np.isfinite(rates[1:]).any():
            rates[0] = 0.0
        return rates

    # -- AOT prewarm + observability (DESIGN.md §9) --------------------------

    def _spec_request(self, spec: PrewarmSpec) -> SolveRequest:
        """Dummy request with the spec's structural shape (zero operands:
        compilation keys on avals, not values)."""
        deltas = (np.full(spec.n_iter, np.inf, np.float32)
                  if spec.policy == "fixed" else None)
        return SolveRequest(
            y=np.zeros(spec.m, np.float32),
            a=np.zeros((spec.m, spec.n), np.float32),
            prior=spec.prior, snr_db=spec.snr_db, n_proc=spec.n_proc,
            n_iter=spec.n_iter, policy=spec.policy, deltas=deltas,
            transport=spec.transport, layout=spec.layout)

    def prewarm(self, menu, background: bool = False):
        """AOT-compile the bucket x batch-width grid for a traffic menu of
        ``PrewarmSpec``s, so steady-state requests never block on XLA.

        Blocking by default (returns the report dict); with
        ``background=True`` compilation runs on a daemon thread (returns
        the ``Thread``; traffic may flow immediately and converges to
        zero-compile as programs land — per-engine compile locks serialize
        against foreground dispatches of the same program). The report is
        surfaced on ``stats()["prewarm"]`` either way.

        Dummy operands bypass the operand cache (zero-A entries would
        poison it) and compiled programs key on operand avals, so runtime
        traffic of the same structural shape reuses them exactly.
        """
        menu = list(menu)
        if background:
            th = threading.Thread(target=self._prewarm_run, args=(menu,),
                                  name="solve-prewarm", daemon=True)
            self._prewarm_thread = th
            th.start()
            return th
        return self._prewarm_run(menu)

    def _prewarm_run(self, menu: list) -> dict:
        t0 = time.perf_counter()
        programs, buckets = 0, set()
        for spec in menu:
            req = self._prepare(self._spec_request(spec), assign_id=False)
            key = self._key_for(req)
            buckets.add(str(key))
            eng = self._engine(key)
            if key.placement == "proc":
                a_b, y_b, params, has_bt = self._het_operands(
                    key, [req], use_cache=False)
                hp = jax.tree.map(lambda v: np.asarray(v)[0], params)
                eng.dispatch_sharded(a_b[0], y_b[0], hp, self.mesh,
                                     has_bt=has_bt, compile_only=True)
                programs += 1
                continue
            widths = spec.batch_widths
            if widths is None:
                widths = batch_width_ladder(
                    self.policy,
                    self.n_devices if key.placement == "data" else 1)
            for w in widths:
                w = pad_batch_size(min(int(w), self.policy.max_batch),
                                   self.policy)
                if key.placement == "data":
                    w = round_up(w, self.n_devices)
                a_b, y_b, params, has_bt = self._het_operands(
                    key, [req] * w, use_cache=False)
                if key.placement == "data":
                    shard = NamedSharding(self.mesh,
                                          PartitionSpec(self.mesh_axis))
                    a_b, y_b, params = jax.device_put((a_b, y_b, params),
                                                      shard)
                eng.dispatch_het(a_b, y_b, params, has_bt=has_bt,
                                 compile_only=True)
                programs += 1
            if self._singleton_ok(key, req):
                seng = self._single_engine(req)
                a_p, y_p = seng._split(req.y, req.a)
                sched = (req.deltas if req.policy in ("fixed", "dp")
                         else np.full(req.n_iter, np.inf, np.float32))
                seng.dispatch_single(a_p, y_p, req.m, req.n, sched=sched,
                                     compile_only=True)
                programs += 1
        report = {"programs": programs, "buckets": sorted(buckets),
                  "seconds": time.perf_counter() - t0}
        self._prewarm_report = report
        return report

    def compile_count(self) -> int:
        """Total XLA compiles across every engine this service owns (het
        bucket engines and singleton fast-path engines). Flat after
        prewarm under steady-state traffic — the zero-recompile
        invariant tests pin. Each engine's count is read through its
        ``counters()`` snapshot (taken under the engine's compile lock),
        so a background prewarm thread mid-compile is counted either
        fully or not at all — never half."""
        with self._lock:
            engines = (list(self._engines.values())
                       + list(self._wire_engines.values())
                       + list(self._single_engines.values()))
        return sum(e.counters()["compiles"] for e in engines)

    def _collect_metrics(self, reg: MetricsRegistry) -> None:
        """Snapshot-time collector: mirror the sources that already keep
        their own atomic counters into the registry (no hot-path writes —
        the ≤2% telemetry-overhead budget, DESIGN.md §12)."""
        st = self.stats()
        comp = reg.counter("amp_engine_compiles_total",
                           "XLA compiles per bucket engine", ("bucket",))
        disp = reg.counter("amp_engine_dispatches_total",
                           "Engine dispatches per bucket", ("bucket",))
        for label, v in st["compiles"]["by_bucket"].items():
            comp.set_total(v, bucket=label)
        for label, v in st["dispatches"]["by_bucket"].items():
            disp.set_total(v, bucket=label)
        reg.counter("amp_singleton_dispatches_total",
                    "Singleton fast-path dispatches").set_total(
                        st["singleton_dispatches"])
        dem = reg.counter("amp_bucket_demand_total",
                          "Requests ever admitted per bucket", ("bucket",))
        for k, v in st["bucket_demand"].items():
            dem.set_total(v, bucket=k)
        oc = st["operand_cache"]
        if oc is not None:
            for name in ("hits", "misses", "evictions"):
                reg.counter(f"amp_operand_cache_{name}_total",
                            f"Operand cache {name}").set_total(oc[name])
            reg.gauge("amp_operand_cache_bytes",
                      "Operand cache resident bytes").set(oc["bytes"])
            reg.gauge("amp_operand_cache_entries",
                      "Operand cache entries").set(oc["entries"])

    def metrics(self) -> dict:
        """Atomic JSON-able metrics snapshot (DESIGN.md §12): event-driven
        request/latency/drift series plus the pulled engine/cache/demand
        counters. Empty when constructed with ``telemetry=False``."""
        if self._registry is None:
            return {"metrics": []}
        return self._registry.snapshot()

    def metrics_text(self) -> str:
        """``metrics()`` rendered as Prometheus text exposition format."""
        return prometheus_text(self.metrics())

    def demand(self) -> dict:
        """Lifetime per-bucket admission counts (``Batcher.demand``)."""
        return self._batcher.demand()

    def take_demand(self) -> dict:
        """Per-bucket admissions since the previous take — the cluster
        autoscaler's scrape window (``Batcher.take_demand``)."""
        return self._batcher.take_demand()

    def stats(self) -> dict:
        """Hot-path observability: operand-cache counters, per-bucket
        compile/dispatch counts, singleton fast-path traffic, per-bucket
        demand (requests ever admitted), and the last prewarm report.

        The whole aggregation runs under the service lock and reads each
        engine through its atomic ``counters()`` snapshot: a concurrent
        background ``prewarm`` thread (which mutates the engine maps and
        bumps compile counters mid-flight) can therefore never produce a
        torn report where ``compiles.total`` disagrees with the engines
        that exist or demand counts reflect a different instant than the
        compile counts they are read next to."""
        with self._lock:
            engines = ([(k, e, "") for k, e in self._engines.items()]
                       + [(k, e, "/wire")
                          for k, e in self._wire_engines.items()])
            singles = list(self._single_engines.items())
            by_bucket = {}
            dispatches = {}
            for key, eng, tag in engines:
                label = (f"{key.layout}/{key.placement}/n{key.n_pad}"
                         f"/mp{key.mp_pad}/p{key.n_proc}/t{key.t_max}"
                         f"/{key.transport}{tag}")
                c = eng.counters()
                by_bucket[label] = c["compiles"]
                dispatches[label] = c["dispatches"]
            for (n, m, p, t, transport, _prior), eng in singles:
                label = f"single/n{n}/m{m}/p{p}/t{t}/{transport}"
                c = eng.counters()
                by_bucket[label] = c["compiles"]
                dispatches[label] = c["dispatches"]
            demand = self._batcher.demand()
            singleton_dispatches = self._singleton_dispatches
            prewarm_report = self._prewarm_report
            opstats = (self._opcache.stats()
                       if self._opcache is not None else None)
        return {
            "operand_cache": opstats,
            "compiles": {"total": sum(by_bucket.values()),
                         "by_bucket": by_bucket},
            "dispatches": {"total": sum(dispatches.values()),
                           "by_bucket": dispatches},
            "singleton_dispatches": singleton_dispatches,
            "bucket_demand": {str(k): v for k, v in demand.items()},
            "prewarm": prewarm_report,
        }
