"""Bytes on the wire: measured rANS accounting and the TCP frame
transport (DESIGN.md §10, §13).

Two layers share this module because they share one concern — what
actually crosses a link:

  * **Measured wire-byte accounting** (below): the paper's rate numbers
    are model entropies H_Q, "achievable through entropy coding". When a
    request opts in (``SolveRequest.measure_wire``), each round's
    per-processor quantizer symbol stream from the engine trace is
    actually rANS-coded (``core.entropy_code.RansCodec``, static
    per-stream model) host-side and the *measured* byte count is
    reported next to the model rate.
  * **TCP frame transport** (bottom half): the length-prefixed frame
    protocol ``TcpBackend``/``BackendServer`` speak, hardened for the
    fault model of DESIGN.md §13 — bounded frame sizes, timeouts honored
    through ``recv_exact``, and typed error frames that carry the remote
    traceback plus a per-request vs backend-fatal distinction, so the
    router can tell a bad request from a dying host.

Accounting per (round, processor) packet:

  * coded rounds (finite bin size): rANS payload bytes + the model cost of
    shipping the static table (12-bit quantized frequencies per alphabet
    symbol + a 4-byte symbol offset) + the link-layer header,
  * lossless rounds: raw fixed-width payload (``WireModel.lossless_bits``
    per element — the paper's 32-bit baseline) + header; no table.

Erasure interacts through the recovery policy: a dropped packet *was
transmitted* (its bytes and airtime are spent either way), and under
``"retransmit"`` it crosses the wire a second time next round, so its
bytes are counted twice.  Under ``"rate_up"`` nothing is re-sent — the
loss is absorbed by the survivors' finer bins, which the measured payload
bytes already reflect.

The time-on-air / energy model is deliberately simple (bytes / link rate,
times radio power): enough to rank transports and recovery policies, not
a radio simulation.
"""
from __future__ import annotations

import dataclasses
import json
import struct
import traceback as _traceback

import numpy as np

from ..core.entropy_code import RansCodec
from .codec import CodecError

__all__ = ["WireModel", "measure_wire",
           "FrameError", "BackendError", "BackendUnavailable",
           "RemoteRequestError", "MAX_FRAME_BYTES",
           "recv_exact", "send_frame", "recv_frame",
           "pack_error", "remote_error"]

_FREQ_BITS = 12   # rANS quantized-frequency width (entropy_code._SCALE_BITS)


@dataclasses.dataclass(frozen=True)
class WireModel:
    """Link parameters for the time-on-air / energy estimate."""

    bitrate_bps: float = 1e6      # link throughput
    tx_power_w: float = 0.1       # radio power while transmitting
    overhead_bytes: float = 8.0   # per-packet header (seq + length + crc)
    lossless_bits: float = 32.0   # wire width of an uncoded lossless round


def measure_wire(symbols, deltas, n_elem: int, drop=None,
                 recovery: str = "retransmit",
                 model: WireModel | None = None) -> dict:
    """rANS-code one request's symbol trace and account the wire bytes.

    ``symbols`` is the engine trace slice (T, P, L_pad) of quantizer
    indices (midtread, so integers around 0), ``deltas`` the (T,) realized
    bin sizes (non-finite = lossless round), ``n_elem`` the real payload
    length (N for row messages, M for column residual contributions —
    padding beyond it is sliced off).  ``drop`` is the (T, P) erasure mask
    actually applied (None = lossless link).

    Returns a dict with

      * ``payload_bytes``  — rANS payload only (the number comparable to
        the model entropy: ``H_Q * n_elem / 8`` per packet),
      * ``bytes_on_wire``  — payload + table + headers, with retransmitted
        packets double-counted under ``recovery="retransmit"``,
      * ``bytes_by_round`` — (T,) single-transmission bytes per round,
      * ``time_on_air_s``, ``energy_j`` — from the ``WireModel``.
    """
    model = model or WireModel()
    symbols = np.asarray(symbols)
    assert symbols.ndim == 3, symbols.shape
    t_n, p_n = symbols.shape[0], symbols.shape[1]
    assert n_elem <= symbols.shape[2], (n_elem, symbols.shape)
    pkt = np.zeros((t_n, p_n))          # full packet bytes, one transmission
    payload = np.zeros((t_n, p_n))      # rANS payload bytes only
    for t in range(t_n):
        if not np.isfinite(float(deltas[t])):
            raw = model.lossless_bits * n_elem / 8.0
            pkt[t, :] = raw + model.overhead_bytes
            payload[t, :] = raw
            continue
        for pi in range(p_n):
            stream = symbols[t, pi, :n_elem].astype(np.int64)
            shifted = stream - stream.min()
            counts = np.bincount(shifted)
            body = len(RansCodec(counts).encode(shifted))
            table = len(counts) * _FREQ_BITS / 8.0 + 4.0  # freqs + offset
            payload[t, pi] = body
            pkt[t, pi] = body + table + model.overhead_bytes
    total = float(pkt.sum())
    if drop is not None and recovery == "retransmit":
        # a dropped packet is re-sent next round: same bytes, twice on air
        d = np.asarray(drop, np.float64)[:t_n, :p_n]
        total += float((pkt * d).sum())
    time_s = total * 8.0 / model.bitrate_bps
    return {
        "payload_bytes": float(payload.sum()),
        "bytes_on_wire": total,
        "bytes_by_round": pkt.sum(axis=1),
        "time_on_air_s": time_s,
        "energy_j": time_s * model.tx_power_w,
    }


# -- TCP frame transport (codec frames, no pickle) ---------------------------
#
# Frame: u32 length | 1-byte op | body. Replies: u32 length | 1-byte
# status (b"R" ok / b"E" error) | body. Error bodies are JSON
# ``{type, msg, traceback, fatal}`` (``pack_error``); ``fatal`` marks
# backend-level failures where the server closes the connection —
# everything else is a per-request error the connection survives.

# A solve frame is one request's (M, N) float32 operand plus headers:
# far under a GiB for any real bucket. Anything bigger is a desynced or
# hostile stream, and rejecting it *before* the allocate-and-recv loop is
# what keeps a corrupt length prefix from looking like a hung peer.
MAX_FRAME_BYTES = 1 << 30


class FrameError(CodecError):
    """Malformed frame at the transport layer (bad length, empty frame,
    truncated nesting). The stream is desynced: the connection cannot be
    trusted afterwards — callers must drop it, not resync."""


class BackendError(RuntimeError):
    """Base of the typed backend failure hierarchy the router consumes."""


class BackendUnavailable(BackendError):
    """Connection-level failure: refused, reset, timed out, or a desynced
    stream. Signals a *dying host* — counts toward the suspect/dead
    threshold and triggers failover of in-flight requests."""


class RemoteRequestError(BackendError):
    """The backend rejected or failed *this request* but the connection
    (and the host) survive. Carries the remote traceback so the failure
    is debuggable from the frontend. Does NOT count toward host death."""

    def __init__(self, host_id: str, remote_type: str, msg: str,
                 remote_traceback: str = ""):
        self.host_id = host_id
        self.remote_type = remote_type
        self.remote_traceback = remote_traceback
        detail = f"\n--- remote traceback ---\n{remote_traceback}" \
            if remote_traceback else ""
        super().__init__(f"backend {host_id}: {remote_type}: {msg}{detail}")


def recv_exact(sock, n: int) -> bytes:
    """Read exactly ``n`` bytes. Honors the socket's configured timeout
    (``TimeoutError`` propagates — a half-dead peer must not hang the
    caller forever); raises ``ConnectionError`` on mid-frame close."""
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(n - got)
        if not chunk:
            raise ConnectionError("peer closed mid-frame")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def send_frame(sock, op: bytes, body: bytes = b"") -> None:
    sock.sendall(struct.pack("<I", len(body) + 1) + op + body)


def recv_frame(sock) -> "tuple[bytes, bytes]":
    (ln,) = struct.unpack("<I", recv_exact(sock, 4))
    if ln < 1:
        raise FrameError("empty frame (no opcode)")
    if ln > MAX_FRAME_BYTES:
        raise FrameError(f"frame length {ln} exceeds {MAX_FRAME_BYTES}")
    payload = recv_exact(sock, ln)
    return payload[:1], payload[1:]


def pack_error(exc: BaseException, fatal: bool) -> bytes:
    """Typed error-frame body: exception type + message + the full remote
    traceback, plus whether the backend considers itself dying."""
    return json.dumps({
        "type": type(exc).__name__,
        "msg": str(exc),
        "traceback": _traceback.format_exc(),
        "fatal": bool(fatal),
    }, separators=(",", ":")).encode()


def remote_error(host_id: str, body: bytes) -> BackendError:
    """Rebuild the typed exception from an error-frame body. Fatal errors
    (the server is closing the connection) surface as
    ``BackendUnavailable``; everything else is a ``RemoteRequestError``
    carrying the remote traceback."""
    try:
        d = json.loads(body)
        rtype, msg = str(d["type"]), str(d["msg"])
        tb, fatal = str(d.get("traceback", "")), bool(d.get("fatal"))
    except (ValueError, KeyError, TypeError):
        # pre-typed-frame peer (or garbage): treat as per-request
        return RemoteRequestError(host_id, "RemoteError",
                                  body.decode(errors="replace"))
    if fatal:
        return BackendUnavailable(
            f"backend {host_id} fatal {rtype}: {msg}\n"
            f"--- remote traceback ---\n{tb}")
    return RemoteRequestError(host_id, rtype, msg, tb)
