"""Measured wire-byte accounting for the serving path (DESIGN.md §10).

The paper's rate numbers are model entropies H_Q, "achievable through
entropy coding". This module closes the loop in the serving layer: when a
request opts in (``SolveRequest.measure_wire``), each round's per-processor
quantizer symbol stream from the engine trace is actually rANS-coded
(``core.entropy_code.RansCodec``, static per-stream model) host-side and
the *measured* byte count is reported next to the model rate.

Accounting per (round, processor) packet:

  * coded rounds (finite bin size): rANS payload bytes + the model cost of
    shipping the static table (12-bit quantized frequencies per alphabet
    symbol + a 4-byte symbol offset) + the link-layer header,
  * lossless rounds: raw fixed-width payload (``WireModel.lossless_bits``
    per element — the paper's 32-bit baseline) + header; no table.

Erasure interacts through the recovery policy: a dropped packet *was
transmitted* (its bytes and airtime are spent either way), and under
``"retransmit"`` it crosses the wire a second time next round, so its
bytes are counted twice.  Under ``"rate_up"`` nothing is re-sent — the
loss is absorbed by the survivors' finer bins, which the measured payload
bytes already reflect.

The time-on-air / energy model is deliberately simple (bytes / link rate,
times radio power): enough to rank transports and recovery policies, not
a radio simulation.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.entropy_code import RansCodec

__all__ = ["WireModel", "measure_wire"]

_FREQ_BITS = 12   # rANS quantized-frequency width (entropy_code._SCALE_BITS)


@dataclasses.dataclass(frozen=True)
class WireModel:
    """Link parameters for the time-on-air / energy estimate."""

    bitrate_bps: float = 1e6      # link throughput
    tx_power_w: float = 0.1       # radio power while transmitting
    overhead_bytes: float = 8.0   # per-packet header (seq + length + crc)
    lossless_bits: float = 32.0   # wire width of an uncoded lossless round


def measure_wire(symbols, deltas, n_elem: int, drop=None,
                 recovery: str = "retransmit",
                 model: WireModel | None = None) -> dict:
    """rANS-code one request's symbol trace and account the wire bytes.

    ``symbols`` is the engine trace slice (T, P, L_pad) of quantizer
    indices (midtread, so integers around 0), ``deltas`` the (T,) realized
    bin sizes (non-finite = lossless round), ``n_elem`` the real payload
    length (N for row messages, M for column residual contributions —
    padding beyond it is sliced off).  ``drop`` is the (T, P) erasure mask
    actually applied (None = lossless link).

    Returns a dict with

      * ``payload_bytes``  — rANS payload only (the number comparable to
        the model entropy: ``H_Q * n_elem / 8`` per packet),
      * ``bytes_on_wire``  — payload + table + headers, with retransmitted
        packets double-counted under ``recovery="retransmit"``,
      * ``bytes_by_round`` — (T,) single-transmission bytes per round,
      * ``time_on_air_s``, ``energy_j`` — from the ``WireModel``.
    """
    model = model or WireModel()
    symbols = np.asarray(symbols)
    assert symbols.ndim == 3, symbols.shape
    t_n, p_n = symbols.shape[0], symbols.shape[1]
    assert n_elem <= symbols.shape[2], (n_elem, symbols.shape)
    pkt = np.zeros((t_n, p_n))          # full packet bytes, one transmission
    payload = np.zeros((t_n, p_n))      # rANS payload bytes only
    for t in range(t_n):
        if not np.isfinite(float(deltas[t])):
            raw = model.lossless_bits * n_elem / 8.0
            pkt[t, :] = raw + model.overhead_bytes
            payload[t, :] = raw
            continue
        for pi in range(p_n):
            stream = symbols[t, pi, :n_elem].astype(np.int64)
            shifted = stream - stream.min()
            counts = np.bincount(shifted)
            body = len(RansCodec(counts).encode(shifted))
            table = len(counts) * _FREQ_BITS / 8.0 + 4.0  # freqs + offset
            payload[t, pi] = body
            pkt[t, pi] = body + table + model.overhead_bytes
    total = float(pkt.sum())
    if drop is not None and recovery == "retransmit":
        # a dropped packet is re-sent next round: same bytes, twice on air
        d = np.asarray(drop, np.float64)[:t_n, :p_n]
        total += float((pkt * d).sum())
    time_s = total * 8.0 / model.bitrate_bps
    return {
        "payload_bytes": float(payload.sum()),
        "bytes_on_wire": total,
        "bytes_by_round": pkt.sum(axis=1),
        "time_on_air_s": time_s,
        "energy_j": time_s * model.tx_power_w,
    }
