"""Continuous batching: group submitted requests by bucket, dispatch full
batches eagerly, flush stragglers on demand (DESIGN.md §5).

The batcher owns no compute — it only decides *which* requests form the
next ``solve_het`` call. A group dispatches as soon as it reaches
``policy.max_batch`` (so a steady stream of same-bucket requests runs at
the full batch width without waiting for a flush), and ``drain`` hands
back whatever is left, largest groups first (they amortize best).
"""
from __future__ import annotations

from collections import OrderedDict

from .buckets import BucketKey, BucketPolicy

__all__ = ["Batcher"]


class Batcher:
    def __init__(self, policy: BucketPolicy):
        self.policy = policy
        # insertion-ordered so flush keeps request arrival order stable
        # within a bucket
        self._groups: "OrderedDict[BucketKey, list]" = OrderedDict()
        # lifetime per-bucket admission counts — the demand signal the
        # prewarm menu (and later, elastic replica scaling) reads
        self._demand: dict[BucketKey, int] = {}

    def __len__(self) -> int:
        return sum(len(g) for g in self._groups.values())

    def demand(self) -> dict:
        """Requests ever admitted per bucket (not reset by drain)."""
        return dict(self._demand)

    def add(self, key: BucketKey, req):
        """Queue one request; returns (key, batch) if its group is now full,
        else None."""
        self._demand[key] = self._demand.get(key, 0) + 1
        group = self._groups.setdefault(key, [])
        group.append(req)
        if len(group) >= self.policy.max_batch:
            del self._groups[key]
            return key, group
        return None

    def drain(self):
        """Yield all remaining (key, batch) groups, largest first."""
        groups = sorted(self._groups.items(), key=lambda kv: -len(kv[1]))
        self._groups.clear()
        yield from groups
