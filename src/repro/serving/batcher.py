"""Continuous batching: group submitted requests by bucket, dispatch full
batches eagerly, flush stragglers on demand (DESIGN.md §5).

The batcher owns no compute — it only decides *which* requests form the
next ``solve_het`` call. A group dispatches as soon as it reaches
``policy.max_batch`` (so a steady stream of same-bucket requests runs at
the full batch width without waiting for a flush), and ``drain`` hands
back whatever is left, largest groups first (they amortize best).

Demand accounting (DESIGN.md §11): every admission bumps two per-bucket
counters — a *lifetime* total (``demand()``, the prewarm-menu signal) and
a *window* counter (``take_demand()``, deltas since the previous take).
The window is what the cluster autoscaler scrapes: successive takes
partition the admission stream, so EWMA rates built from them never
double- or under-count a request. ``clear_demand()`` resets the window
mark without rewriting history (the ``OperandCache.clear``/``since_clear``
idiom); with ``lifetime=True`` it also zeroes the lifetime totals.
All entry points are thread-safe — admission may run concurrently with a
scrape (frontend thread vs. autoscaler tick).
"""
from __future__ import annotations

import threading
from collections import OrderedDict

from .buckets import BucketKey, BucketPolicy

__all__ = ["Batcher"]


class Batcher:
    def __init__(self, policy: BucketPolicy):
        self.policy = policy
        # insertion-ordered so flush keeps request arrival order stable
        # within a bucket
        self._groups: "OrderedDict[BucketKey, list]" = OrderedDict()
        # lifetime per-bucket admission counts — the demand signal the
        # prewarm menu (and the elastic replica scaling) reads
        self._demand: dict[BucketKey, int] = {}
        # lifetime counts at the last take_demand()/clear_demand(): the
        # window delta is lifetime - mark
        self._mark: dict[BucketKey, int] = {}
        # admission vs. demand-scrape threads (frontend / autoscaler)
        self._lock = threading.Lock()

    def __len__(self) -> int:
        with self._lock:
            return sum(len(g) for g in self._groups.values())

    def demand(self) -> dict:
        """Lifetime requests ever admitted per bucket (not reset by
        drain; ``clear_demand(lifetime=True)`` restarts it)."""
        with self._lock:
            return dict(self._demand)

    def take_demand(self) -> dict:
        """Per-bucket admissions since the previous ``take_demand`` (or
        ``clear_demand``), then advance the mark — successive takes
        partition the admission stream, so a rate built from them counts
        every request exactly once. Buckets with a zero delta are
        omitted."""
        with self._lock:
            out = {}
            for key, total in self._demand.items():
                delta = total - self._mark.get(key, 0)
                if delta:
                    out[key] = delta
                self._mark[key] = total
            return out

    def clear_demand(self, lifetime: bool = False) -> None:
        """Reset the ``take_demand`` window (the next take describes only
        post-clear admissions). With ``lifetime=True`` the historical
        totals restart too — ``demand()`` then reports the post-clear
        stream only."""
        with self._lock:
            if lifetime:
                self._demand.clear()
                self._mark.clear()
            else:
                self._mark = dict(self._demand)

    def add(self, key: BucketKey, req):
        """Queue one request; returns (key, batch) if its group is now full,
        else None."""
        with self._lock:
            self._demand[key] = self._demand.get(key, 0) + 1
            group = self._groups.setdefault(key, [])
            group.append(req)
            if len(group) >= self.policy.max_batch:
                del self._groups[key]
                return key, group
        return None

    def drain(self):
        """Yield all remaining (key, batch) groups, largest first."""
        with self._lock:
            groups = sorted(self._groups.items(), key=lambda kv: -len(kv[1]))
            self._groups.clear()
        yield from groups
