"""Deterministic chaos harness for the cluster plane (DESIGN.md §13).

Fault tolerance that is only exercised by real outages is untested
code. This module injects failures *deterministically* — a seeded
``FaultPlan`` decides in advance which backend call dies, errors,
freezes, or slows — so the chaos gate in the test suite and
``bench_serve --chaos`` is reproducible: the same seed kills the same
host at the same call every run, and the recovery path (detection ->
eviction -> bit-identical replay) can be asserted, not eyeballed.

Two injection points, matching the two places reality fails:

* ``ChaosBackend`` wraps any backend object (``LocalBackend`` or
  ``TcpBackend``) and fires faults at the *call* boundary — the shape
  the frontend actually sees (``BackendUnavailable`` on a dead or
  frozen host, ``RemoteRequestError`` on a transient server-side
  error). This is the in-process harness: exact call-indexed timing,
  so a kill can be placed mid-batch with requests provably stranded.

* ``ChaosProxy`` sits on the real TCP path between a ``TcpBackend``
  and a ``BackendServer`` and corrupts the *byte stream* — stalling
  (client blocks until its recv timeout), severing (connection reset),
  or truncating mid-frame. This exercises the wire-level hardening
  (socket timeouts, ``FrameError`` on desync) that call-level wrapping
  cannot reach.

Fault kinds (``FaultSpec.kind``):

    kill     the host is dead from ``at_call`` on: every later call
             raises ``BackendUnavailable`` (permanent)
    error    one transient server-side failure: ``RemoteRequestError``
             at ``at_call`` only (the host itself is fine)
    freeze   the call hangs ``duration_s`` then fails like a timeout
             (``BackendUnavailable``); later calls proceed normally
    delay    the call is slowed by ``duration_s`` then proceeds

``at_call`` counts the wrapped backend's guarded calls from 1, across
all operations (or only those in ``ops`` when given), which is what
makes "kill host1 on its 3rd submit" expressible.
"""
from __future__ import annotations

import dataclasses
import random
import socket
import threading
import time
from typing import Optional, Sequence

from .wire import BackendUnavailable, RemoteRequestError

__all__ = ["FaultSpec", "FaultPlan", "ChaosBackend", "ChaosProxy"]

_KINDS = ("kill", "error", "freeze", "delay")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: ``kind`` fires at the ``at_call``-th guarded
    call (1-based), optionally restricted to operations named in
    ``ops`` (method names: "submit", "poll", "flush", "ping", ...)."""

    kind: str
    at_call: int
    duration_s: float = 0.0
    ops: Optional[tuple] = None

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.at_call < 1:
            raise ValueError("at_call counts from 1")

    def matches(self, op: str, call_no: int) -> bool:
        if self.ops is not None and op not in self.ops:
            return False
        return call_no == self.at_call


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, immutable schedule of faults. Equality of (seed,
    faults) is equality of behaviour — the plan is the whole experiment
    description, so benches record it next to their results."""

    seed: int = 0
    faults: tuple = ()

    @classmethod
    def kill_at(cls, at_call: int, *, ops: Sequence[str] | None = None,
                seed: int = 0) -> "FaultPlan":
        """The chaos-gate plan: one permanent kill at ``at_call``."""
        return cls(seed=seed, faults=(
            FaultSpec("kill", at_call,
                      ops=tuple(ops) if ops is not None else None),))

    @classmethod
    def random(cls, seed: int, n_faults: int = 3, horizon: int = 50,
               kinds: Sequence[str] = ("error", "freeze", "delay"),
               max_duration_s: float = 0.05) -> "FaultPlan":
        """``n_faults`` transient faults at distinct calls in
        [2, horizon], drawn from ``random.Random(seed)`` — same seed,
        same storm. Permanent kills are excluded by default so a random
        storm stresses retries without guaranteeing a failover."""
        rng = random.Random(seed)
        lo, hi = 2, max(2, horizon)
        calls = rng.sample(range(lo, hi + 1),
                           k=min(n_faults, hi - lo + 1))
        faults = tuple(
            FaultSpec(rng.choice(tuple(kinds)), at,
                      duration_s=rng.uniform(0.0, max_duration_s))
            for at in sorted(calls))
        return cls(seed=seed, faults=faults)

    def fault_for(self, op: str, call_no: int) -> Optional[FaultSpec]:
        for f in self.faults:
            if f.matches(op, call_no):
                return f
        return None


class ChaosBackend:
    """Wrap a backend so a ``FaultPlan`` fires at its call boundary.

    Delegates the full backend protocol; ``host_id`` / ``n_devices``
    pass through, so the frontend cannot tell it apart from the real
    thing — which is the point. After a ``kill`` fault every call
    raises ``BackendUnavailable`` forever (``revive()`` undoes it, for
    recovery-after-replacement tests)."""

    _GUARDED = ("submit", "poll", "flush", "prewarm", "take_demand",
                "stats", "metrics", "compile_count", "ping")

    def __init__(self, inner, plan: FaultPlan, sleep=time.sleep):
        self.inner = inner
        self.plan = plan
        self.calls = 0
        self.killed = False
        self.faults_fired: list = []
        self._sleep = sleep

    @property
    def host_id(self) -> str:
        return self.inner.host_id

    @property
    def n_devices(self) -> int:
        return self.inner.n_devices

    def revive(self) -> None:
        self.killed = False

    def _guard(self, op: str) -> None:
        self.calls += 1
        if self.killed:
            raise BackendUnavailable(
                f"chaos: host {self.host_id} is dead")
        f = self.plan.fault_for(op, self.calls)
        if f is None:
            return
        self.faults_fired.append((self.calls, op, f.kind))
        if f.kind == "kill":
            self.killed = True
            raise BackendUnavailable(
                f"chaos: host {self.host_id} killed at call {self.calls}")
        if f.kind == "error":
            raise RemoteRequestError(
                self.host_id, "ChaosError",
                f"chaos: transient error at call {self.calls}")
        if f.kind == "freeze":
            if f.duration_s > 0:
                self._sleep(f.duration_s)
            raise BackendUnavailable(
                f"chaos: host {self.host_id} frozen "
                f"{f.duration_s:.3f}s at call {self.calls} (timeout)")
        if f.kind == "delay" and f.duration_s > 0:
            self._sleep(f.duration_s)

    def __getattr__(self, name):
        attr = getattr(self.inner, name)
        if name in self._GUARDED and callable(attr):
            def guarded(*args, _attr=attr, _name=name, **kwargs):
                self._guard(_name)
                return _attr(*args, **kwargs)
            return guarded
        return attr

    def close(self) -> None:
        self.inner.close()


class ChaosProxy:
    """A byte-level TCP fault injector between a ``TcpBackend`` and a
    ``BackendServer``.

    Forwards both directions transparently until armed; then, once
    ``after_bytes`` of server->client traffic have passed, it either
    stalls (stops forwarding — the client blocks until its recv
    timeout fires) or severs (closes both sockets mid-frame — the
    client sees a reset / truncated frame). Arming at construction or
    later via ``trip()`` makes "let N replies through, then fail"
    scenarios deterministic at frame granularity.

        proxy = ChaosProxy(server_addr).start()
        backend = TcpBackend(proxy.address, recv_timeout_s=0.5)
        proxy.trip("stall")           # next reply never completes
    """

    def __init__(self, upstream, mode: str = "pass",
                 after_bytes: int = 0):
        if mode not in ("pass", "stall", "sever"):
            raise ValueError(f"unknown proxy mode {mode!r}")
        self.upstream = upstream
        self.mode = mode
        self.after_bytes = int(after_bytes)
        self.bytes_s2c = 0
        self.bytes_c2s = 0
        self.address = None
        self._lsock = None
        self._threads: list = []
        self._socks: list = []
        self._stop = threading.Event()
        self._lock = threading.Lock()

    def start(self) -> "ChaosProxy":
        self._lsock = socket.create_server(("127.0.0.1", 0))
        self._lsock.settimeout(0.2)
        self.address = self._lsock.getsockname()
        th = threading.Thread(target=self._accept_loop,
                              name="chaos-proxy", daemon=True)
        th.start()
        self._threads.append(th)
        return self

    def trip(self, mode: str, after_bytes: int | None = None) -> None:
        """Arm (or re-arm) the fault at runtime; counting is relative
        to the moment of arming."""
        if mode not in ("pass", "stall", "sever"):
            raise ValueError(f"unknown proxy mode {mode!r}")
        with self._lock:
            self.mode = mode
            if after_bytes is not None:
                self.after_bytes = int(after_bytes)
            self.bytes_s2c = 0

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._lsock.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            try:
                up = socket.create_connection(self.upstream, timeout=5.0)
            except OSError:
                conn.close()
                continue
            self._socks += [conn, up]
            for src, dst, s2c in ((conn, up, False), (up, conn, True)):
                th = threading.Thread(
                    target=self._pump, args=(src, dst, s2c),
                    daemon=True)
                th.start()
                self._threads.append(th)

    def _pump(self, src, dst, s2c: bool) -> None:
        src.settimeout(0.2)
        while not self._stop.is_set():
            try:
                data = src.recv(65536)
            except socket.timeout:
                continue
            except OSError:
                break
            if not data:
                break
            if s2c:
                with self._lock:
                    self.bytes_s2c += len(data)
                    mode = self.mode
                    tripped = (mode != "pass"
                               and self.bytes_s2c > self.after_bytes)
                if tripped and mode == "stall":
                    # swallow bytes until stopped: the client's recv
                    # timeout is now the only way out
                    self._stop.wait()
                    break
                if tripped and mode == "sever":
                    for s in (src, dst):
                        try:
                            s.close()
                        except OSError:
                            pass
                    break
            else:
                self.bytes_c2s += len(data)
            try:
                dst.sendall(data)
            except OSError:
                break
        for s in (src, dst):
            try:
                s.close()
            except OSError:
                pass

    def stop(self) -> None:
        self._stop.set()
        if self._lsock is not None:
            try:
                self._lsock.close()
            except OSError:
                pass
        for s in self._socks:
            try:
                s.close()
            except OSError:
                pass
        for th in self._threads:
            th.join(timeout=1.0)

    def __enter__(self) -> "ChaosProxy":
        return self.start() if self.address is None else self

    def __exit__(self, *exc) -> None:
        self.stop()
