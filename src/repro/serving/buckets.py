"""Shape bucketing for the AMP solve service (DESIGN.md §5).

Heterogeneous solve requests arrive with arbitrary (N, M, P, T). XLA
compiles one program per shape, so the service pads every request up to a
small set of canonical shapes — the *buckets* — and reuses one compiled
``AmpEngine.solve_het`` per bucket. The bucket key is exactly the set of
*structural* parameters (things that change array shapes or the traced
program); everything else (prior, SNR, schedule, BT tables, iteration
count) rides as vmapped per-instance operands inside the batch.

Padding semantics (must preserve single-solve results bit-near-exactly):

  * columns: N -> n_pad with zero columns of A; the engine masks the
    denoiser/Onsager to the real columns, so padded entries stay 0.
  * rows: padded *per processor shard* (each processor keeps exactly its
    unpadded rows plus zeros), so the row->processor partition — and with
    it each f^p message and its quantization error — matches the unpadded
    solve. Zero rows keep z = 0 forever and sigma2_hat normalizes by the
    real M.
  * iterations: T -> t_max with masked early-exit (t_active per instance).
  * batch: B -> next power of two (recompile amortization); the batcher
    fills the pad slots by repeating real requests and drops the copies.

For block-quantized transports, ``n_quantum`` must divide the transport
block size: then ceil(n_pad/block) == ceil(n/block) and the per-block
scales (hence the injected-noise accounting) match the unpadded solve.

Placement (DESIGN.md §6): on a multi-device mesh the bucket additionally
records *where* it runs — ``"local"`` (single device), ``"data"``
(batch axis sharded across devices, processors emulated per-device) or
``"proc"`` (mesh axis = the paper's P, compressed fusion on the wire).
``placement_for`` chooses by a simple size threshold: requests whose
sensing matrix reaches ``policy.shard_elems`` elements are worth paying
collective latency per iteration; everything smaller batches better.

Layout (DESIGN.md §7): the bucket also records *how* the problem is
partitioned — ``"row"`` (the paper's scheme) or ``"col"`` (C-MP-AMP,
each processor owns N/P signal columns and the fusion exchanges length-M
residual contributions).  ``placement_for`` routes tall requests whose
aspect ratio N/M reaches ``policy.col_aspect`` to the column layout: in
that regime the row scheme would put the full length-N denoiser messages
on the wire while the column scheme exchanges only length-M residuals.
Column padding mirrors the row semantics with the axes swapped: the
quantized payload axis (M) takes ``n_quantum`` (keeping the transport
scale-block layout pad-invariant) and the per-processor column slices
take ``mp_quantum``.
"""
from __future__ import annotations

import dataclasses

__all__ = ["BucketPolicy", "BucketKey", "bucket_for", "pad_batch_size",
           "batch_width_ladder", "placement_for", "round_up",
           "TRANSPORT_BLOCK"]

# scale-block length of the block-quantized transports (QuantConfig.block
# as instantiated by serving/service.py); "ecsq" has no block structure
TRANSPORT_BLOCK = {"block8": 512, "block4": 512}


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """Rounding quanta that trade padding waste against compile-cache size."""

    n_quantum: int = 256     # signal length padded to a multiple
    mp_quantum: int = 16     # per-processor measurement rows padded to a multiple
    t_quantum: int = 4       # scan length padded to a multiple
    max_batch: int = 128     # dispatch threshold for continuous batching
    shard_elems: int = 1 << 21  # A size (M*N) at which a single request
    #                             runs processor-sharded instead of batching
    col_aspect: float = 4.0  # N/M at which a request routes to the column
    #                          layout (tall-N regime, DESIGN.md §7)


@dataclasses.dataclass(frozen=True)
class BucketKey:
    """Structural shape of one compiled solve (the compile-cache key).

    ``n_pad``/``mp_pad`` are layout-dependent: row buckets pad the signal
    length and the per-processor measurement rows (M_pad = P * mp_pad);
    column buckets pad the per-processor column slices (n_pad = P * the
    padded slice) and ``mp_pad`` holds the padded *full* measurement
    count (rows are shared, not split, in the column layout)."""

    n_pad: int               # padded signal length
    mp_pad: int              # padded rows per processor (row) / padded M (col)
    n_proc: int              # processor count (partition structure)
    t_max: int               # scan length (iterations / outer rounds)
    transport: str           # "ecsq" | "block8" | "block4"
    placement: str = "local"  # "local" | "data" | "proc" (DESIGN.md §6)
    layout: str = "row"       # "row" | "col" (DESIGN.md §7)

    @property
    def m_pad(self) -> int:
        return self.mp_pad if self.layout == "col" \
            else self.n_proc * self.mp_pad


def round_up(v: int, q: int) -> int:
    """Smallest multiple of ``q`` >= ``v`` (shape/batch padding quantum)."""
    return -(-v // q) * q


def bucket_for(n: int, m: int, n_proc: int, n_iter: int, transport: str,
               policy: BucketPolicy, placement: str = "local",
               layout: str = "row") -> BucketKey:
    """Map a request's structural parameters to its bucket."""
    block = TRANSPORT_BLOCK.get(transport)
    if block is not None:
        # otherwise padding the quantized axis can add scale blocks the
        # unpadded solve does not have, silently skewing quant_noise_var
        # (module docstring); the quantized axis is N for row layouts
        # (messages) and M for column layouts (residual contributions),
        # and both take n_quantum
        assert block % policy.n_quantum == 0, \
            f"n_quantum={policy.n_quantum} must divide the {transport} " \
            f"scale block ({block}) to keep noise accounting pad-invariant"
    if layout == "col":
        assert n % n_proc == 0, f"N={n} not divisible by P={n_proc} (col)"
        return BucketKey(
            n_pad=n_proc * round_up(n // n_proc, policy.mp_quantum),
            mp_pad=round_up(m, policy.n_quantum),
            n_proc=n_proc,
            t_max=round_up(n_iter, policy.t_quantum),
            transport=transport,
            placement=placement,
            layout=layout,
        )
    assert m % n_proc == 0, f"M={m} not divisible by P={n_proc}"
    return BucketKey(
        n_pad=round_up(n, policy.n_quantum),
        mp_pad=round_up(m // n_proc, policy.mp_quantum),
        n_proc=n_proc,
        t_max=round_up(n_iter, policy.t_quantum),
        transport=transport,
        placement=placement,
        layout=layout,
    )


def placement_for(n: int, m: int, n_proc: int, n_devices: int,
                  policy: BucketPolicy) -> tuple[str, str]:
    """Placement *and* layout for a request: ``(placement, layout)``.

    Size-threshold placement (DESIGN.md §6): large single solves shard
    the processors across the mesh; everything else batches
    data-parallel.  Processor sharding additionally needs P to split
    evenly over the devices (each device emulates P/D processors, keeping
    the partition — and the noise accounting — independent of the mesh
    size); requests that don't satisfy it fall back to data-parallel.

    Aspect-ratio layout (DESIGN.md §7): tall requests (N/M >=
    ``policy.col_aspect``) whose N splits evenly over the processors run
    column-partitioned — the fusion then exchanges length-M residual
    contributions instead of length-N messages.
    """
    layout = "col" if (n >= policy.col_aspect * m
                       and n % n_proc == 0) else "row"
    if n_devices <= 1:
        return "local", layout
    if n * m >= policy.shard_elems and n_proc % n_devices == 0:
        return "proc", layout
    return "data", layout


def pad_batch_size(b: int, policy: BucketPolicy) -> int:
    """Next power of two >= b (capped at max_batch), so the vmapped solve
    compiles for O(log max_batch) distinct batch sizes per bucket."""
    assert 1 <= b <= policy.max_batch
    p = 1
    while p < b:
        p <<= 1
    return min(p, policy.max_batch)


def batch_width_ladder(policy: BucketPolicy, n_devices: int = 1) -> tuple:
    """Every batch width the service can actually dispatch for one bucket:
    the ``pad_batch_size`` power-of-two ladder, rounded to device
    multiples under the data-parallel placement. This is the width grid
    ``SolveService.prewarm`` compiles — exactly the reachable programs, no
    more."""
    widths, w = set(), 1
    while True:
        wp = round_up(w, n_devices) if n_devices > 1 else w
        widths.add(min(wp, policy.max_batch))
        if w >= policy.max_batch:
            break
        w <<= 1
    return tuple(sorted(widths))
