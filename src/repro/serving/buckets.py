"""Shape bucketing for the AMP solve service (DESIGN.md §5).

Heterogeneous solve requests arrive with arbitrary (N, M, P, T). XLA
compiles one program per shape, so the service pads every request up to a
small set of canonical shapes — the *buckets* — and reuses one compiled
``AmpEngine.solve_het`` per bucket. The bucket key is exactly the set of
*structural* parameters (things that change array shapes or the traced
program); everything else (prior, SNR, schedule, BT tables, iteration
count) rides as vmapped per-instance operands inside the batch.

Padding semantics (must preserve single-solve results bit-near-exactly):

  * columns: N -> n_pad with zero columns of A; the engine masks the
    denoiser/Onsager to the real columns, so padded entries stay 0.
  * rows: padded *per processor shard* (each processor keeps exactly its
    unpadded rows plus zeros), so the row->processor partition — and with
    it each f^p message and its quantization error — matches the unpadded
    solve. Zero rows keep z = 0 forever and sigma2_hat normalizes by the
    real M.
  * iterations: T -> t_max with masked early-exit (t_active per instance).
  * batch: B -> next power of two (recompile amortization); the batcher
    fills the pad slots by repeating real requests and drops the copies.

For block-quantized transports, ``n_quantum`` must divide the transport
block size: then ceil(n_pad/block) == ceil(n/block) and the per-block
scales (hence the injected-noise accounting) match the unpadded solve.

Placement (DESIGN.md §6): on a multi-device mesh the bucket additionally
records *where* it runs — ``"local"`` (single device), ``"data"``
(batch axis sharded across devices, processors emulated per-device) or
``"proc"`` (mesh axis = the paper's P, compressed fusion on the wire).
``placement_for`` chooses by a simple size threshold: requests whose
sensing matrix reaches ``policy.shard_elems`` elements are worth paying
collective latency per iteration; everything smaller batches better.
"""
from __future__ import annotations

import dataclasses

__all__ = ["BucketPolicy", "BucketKey", "bucket_for", "pad_batch_size",
           "placement_for", "round_up", "TRANSPORT_BLOCK"]

# scale-block length of the block-quantized transports (QuantConfig.block
# as instantiated by serving/service.py); "ecsq" has no block structure
TRANSPORT_BLOCK = {"block8": 512, "block4": 512}


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """Rounding quanta that trade padding waste against compile-cache size."""

    n_quantum: int = 256     # signal length padded to a multiple
    mp_quantum: int = 16     # per-processor measurement rows padded to a multiple
    t_quantum: int = 4       # scan length padded to a multiple
    max_batch: int = 128     # dispatch threshold for continuous batching
    shard_elems: int = 1 << 21  # A size (M*N) at which a single request
    #                             runs processor-sharded instead of batching


@dataclasses.dataclass(frozen=True)
class BucketKey:
    """Structural shape of one compiled solve (the compile-cache key)."""

    n_pad: int               # padded signal length
    mp_pad: int              # padded rows per processor (M_pad = P * mp_pad)
    n_proc: int              # processor count (partition structure)
    t_max: int               # scan length
    transport: str           # "ecsq" | "block8" | "block4"
    placement: str = "local"  # "local" | "data" | "proc" (DESIGN.md §6)

    @property
    def m_pad(self) -> int:
        return self.n_proc * self.mp_pad


def round_up(v: int, q: int) -> int:
    """Smallest multiple of ``q`` >= ``v`` (shape/batch padding quantum)."""
    return -(-v // q) * q


def bucket_for(n: int, m: int, n_proc: int, n_iter: int, transport: str,
               policy: BucketPolicy, placement: str = "local") -> BucketKey:
    """Map a request's structural parameters to its bucket."""
    assert m % n_proc == 0, f"M={m} not divisible by P={n_proc}"
    block = TRANSPORT_BLOCK.get(transport)
    if block is not None:
        # otherwise column padding can add scale blocks the unpadded solve
        # does not have, silently skewing quant_noise_var (module docstring)
        assert block % policy.n_quantum == 0, \
            f"n_quantum={policy.n_quantum} must divide the {transport} " \
            f"scale block ({block}) to keep noise accounting pad-invariant"
    return BucketKey(
        n_pad=round_up(n, policy.n_quantum),
        mp_pad=round_up(m // n_proc, policy.mp_quantum),
        n_proc=n_proc,
        t_max=round_up(n_iter, policy.t_quantum),
        transport=transport,
        placement=placement,
    )


def placement_for(n: int, m: int, n_proc: int, n_devices: int,
                  policy: BucketPolicy) -> str:
    """Size-threshold placement: large single solves shard the processors
    across the mesh; everything else batches data-parallel.

    Processor sharding additionally needs P to split evenly over the
    devices (each device emulates P/D processors, keeping the paper's
    partition — and the noise accounting — independent of the mesh size);
    requests that don't satisfy it fall back to data-parallel.
    """
    if n_devices <= 1:
        return "local"
    if n * m >= policy.shard_elems and n_proc % n_devices == 0:
        return "proc"
    return "data"


def pad_batch_size(b: int, policy: BucketPolicy) -> int:
    """Next power of two >= b (capped at max_batch), so the vmapped solve
    compiles for O(log max_batch) distinct batch sizes per bucket."""
    assert 1 <= b <= policy.max_batch
    p = 1
    while p < b:
        p <<= 1
    return min(p, policy.max_batch)
