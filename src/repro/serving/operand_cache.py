"""Device-resident operand cache for the serving hot path (DESIGN.md §9).

CS workloads reuse sensing matrices heavily — a stream of requests over
the same A differs only in y (and schedule). Before this cache the
service re-padded and re-uploaded O(B*P*M*N) operand bytes per flush;
with it, each distinct A is split/padded/cast/`device_put` **once per
(bucket shape, layout, dtype)** and the per-flush batch assembly becomes
a device-side ``jnp.stack`` over resident shards.

Identity is content, not object: ``fingerprint`` hashes the full A
buffer (blake2b), so in-place mutation of a caller's array is a cache
*miss*, never a stale hit. Callers that manage matrix identity
themselves (a sensing-matrix registry) can skip hashing by passing a
stable ``a_id`` on the request — that is the "id" half of the
fingerprint; the content hash is the default.

Eviction is plain LRU under a byte budget, newest entry always kept
(a single over-budget entry still serves its own stream; it just evicts
everything else). Hit/miss/evict counters feed ``SolveService.stats()``.

Cached values must never be passed into *donating* jit programs — the
XLA runtime would invalidate the resident buffer (engine.py wires
donation only on the per-flush stacked temporaries for exactly this
reason).
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict

import jax
import numpy as np

__all__ = ["OperandCache", "fingerprint"]


def fingerprint(arr) -> tuple:
    """Content fingerprint of an operand array: (shape, dtype, blake2b).

    Hashes the full buffer so mutated arrays never alias a cached entry;
    at ~1 GB/s this is noise next to the pad+upload it saves (a bench-
    scale 64x128 f32 A hashes in ~10us).
    """
    a = np.asarray(arr)
    if not a.flags.c_contiguous:
        a = np.ascontiguousarray(a)
    digest = hashlib.blake2b(a, digest_size=16).hexdigest()
    return (a.shape, str(a.dtype), digest)


def _nbytes(value) -> int:
    return sum(int(np.prod(np.shape(x))) * np.dtype(x.dtype).itemsize
               for x in jax.tree_util.tree_leaves(value))


class OperandCache:
    """LRU map fingerprint-key -> device-resident operand (jax array or
    pytree of them), bounded by a byte budget."""

    def __init__(self, max_bytes: int = 256 << 20):
        self.max_bytes = int(max_bytes)
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._entries: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._bytes = 0
        # counter snapshot taken at the last clear(): stats()'s
        # ``since_clear`` numbers describe the post-clear stream only
        self._cleared_at = (0, 0, 0)

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def nbytes(self) -> int:
        return self._bytes

    def get(self, key: tuple, build):
        """Return the cached value for ``key``, building (and admitting)
        it via ``build()`` on a miss. Dropped entries release their device
        buffers once no in-flight computation references them (jax
        refcounting)."""
        entry = self._entries.get(key)
        if entry is not None:
            self._entries.move_to_end(key)
            self.hits += 1
            return entry[0]
        self.misses += 1
        value = build()
        nb = _nbytes(value)
        self._entries[key] = (value, nb)
        self._bytes += nb
        while self._bytes > self.max_bytes and len(self._entries) > 1:
            _, (_, old_nb) = self._entries.popitem(last=False)
            self._bytes -= old_nb
            self.evictions += 1
        return value

    def clear(self, reset_stats: bool = False) -> None:
        """Drop every entry. With ``reset_stats`` the hit/miss/eviction
        counters restart too, so subsequent ``stats()`` rates describe the
        post-clear stream instead of blending in the discarded one; the
        default preserves the historical lifetime counters."""
        self._entries.clear()
        self._bytes = 0
        if reset_stats:
            self.hits = 0
            self.misses = 0
            self.evictions = 0
        self._cleared_at = (self.hits, self.misses, self.evictions)

    def stats(self) -> dict:
        """Lifetime counters at the top level (stable consumers key on
        them), plus ``since_clear`` deltas relative to the last ``clear``
        — equal to the lifetime numbers when never cleared."""
        h0, m0, e0 = self._cleared_at
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "entries": len(self._entries),
            "bytes": self._bytes,
            "max_bytes": self.max_bytes,
            "since_clear": {
                "hits": self.hits - h0,
                "misses": self.misses - m0,
                "evictions": self.evictions - e0,
            },
        }
