"""Cluster scheduler: bucket -> host routing and per-bucket autoscaling
(DESIGN.md §11).

The paper's MP-AMP is a joint communication/computation trade-off; the
cluster tier is the serving-layer instance of it — *where* a bucket runs
decides both the compute a host burns and the bytes that cross host
boundaries. This module is the scheduler half of the frontend/scheduler/
backend split (Ray Serve's router/autoscaler structure):

  * ``routing_key`` — the placement-agnostic structural identity of a
    request (its ``BucketKey`` with placement pinned to "local"): each
    backend host re-derives its own mesh placement, the router only
    decides *which host*.
  * ``ClusterRouter`` — per-bucket replica sets over a static host list.
    Routing is load × shape aware: among a bucket's replicas it picks the
    host with the least outstanding *cost-weighted* work (``shape_cost``,
    a relative FLOP estimate, so one giant solve counts like many small
    ones), preferring hosts that have already compiled the bucket
    (prewarmed or previously served — a cold host pays XLA compilation
    on first dispatch).
  * ``Autoscaler`` — consumes per-bucket admission-rate EWMAs
    (``DemandTracker`` fed from ``Batcher.take_demand`` scrape deltas)
    and moves each bucket's replica count toward
    ``ceil(rate * cost / target_load)``, clamped to
    [min_replicas, max_replicas]. Scale-up is immediate (under-provision
    costs latency now); scale-down waits ``down_patience`` consecutive
    low scrapes (hysteresis, so a demand dip doesn't thrash replicas and
    re-pay prewarm). Decisions are returned as events — the frontend
    applies them (prewarming the new host) and logs them.

Everything here is deterministic given the scrape timestamps: tests
drive ``observe``/``step`` with synthetic clocks.

Thread-safety: all router/autoscaler state is guarded by one reentrant
``ClusterRouter.lock`` (the autoscaler shares it — ``step`` calls back
into ``add_replica``/``remove_replica``, so the lock must nest).  The
frontend routes from caller threads while the scraper daemon steps the
autoscaler, and ``stats()``/``imbalance()`` must never observe a
half-applied route (outstanding bumped, served not yet) — the same
torn-read guarantee ``SolveService.stats()`` got in PR 8.
"""
from __future__ import annotations

import dataclasses
import math
import threading

from .buckets import BucketKey, BucketPolicy, bucket_for, placement_for

__all__ = ["routing_key", "shape_cost", "HostInfo", "RouterPolicy",
           "DemandTracker", "ClusterRouter", "Autoscaler", "Overloaded"]


class Overloaded(RuntimeError):
    """Admission refused: every replica of the bucket is at its
    outstanding-work cap (``RouterPolicy.max_outstanding``)."""


def routing_key(req, policy: BucketPolicy) -> BucketKey:
    """Placement-agnostic bucket identity of a request: layout resolves
    exactly as ``SolveService._prepare`` would (honoring an explicit
    ``req.layout``), placement is pinned to "local" — the chosen host's
    service re-derives data-parallel/processor-sharded placement for its
    own mesh."""
    layout = req.layout or placement_for(req.n, req.m, req.n_proc, 1,
                                         policy)[1]
    return bucket_for(req.n, req.m, req.n_proc, req.n_iter, req.transport,
                      policy, placement="local", layout=layout)


def shape_cost(key: BucketKey) -> float:
    """Relative per-request compute cost of a bucket: the dominant
    A-streaming work is 2 passes over the padded operand per iteration,
    so cost ∝ m_pad * n_pad * t_max (scaled to ~1.0 for a small serving
    bucket). Only ratios matter — the router balances cost-weighted
    outstanding work, the autoscaler prices demand in cost/s."""
    return key.m_pad * key.n_pad * key.t_max / 1e6


@dataclasses.dataclass(frozen=True)
class HostInfo:
    """One backend host as the router sees it. ``weight`` scales the
    host's capacity (device count by default): outstanding work is
    divided by it when comparing load across heterogeneous hosts."""

    host_id: str
    n_devices: int = 1

    @property
    def weight(self) -> float:
        return float(max(1, self.n_devices))


@dataclasses.dataclass(frozen=True)
class RouterPolicy:
    """Scheduler knobs (router + autoscaler + fault tolerance)."""

    ewma_halflife_s: float = 10.0   # demand-rate smoothing
    target_load: float = 50.0       # cost-units/s one replica should absorb
    min_replicas: int = 1
    max_replicas: int = 0           # 0 = every host
    down_patience: int = 3          # consecutive low scrapes before scale-down
    max_outstanding: float = 0.0    # per-host cost-weighted admission cap
    #                                 (0 = unbounded); breaching it on every
    #                                 replica sheds the request (Overloaded)
    prefer_prewarmed: bool = True   # cold hosts lose routing ties
    scrape_every_s: float = 0.0     # frontend auto-scrape period (0 = manual)
    # fault tolerance (DESIGN.md §13): consecutive connection-level
    # failures (BackendUnavailable from calls or health probes) walk a
    # host healthy -> suspect -> dead; dead hosts are evicted from every
    # replica set and their in-flight requests re-admitted elsewhere
    suspect_after: int = 1          # failures before a host turns suspect
    dead_after: int = 3             # failures before a host is declared dead
    retry_limit: int = 2            # re-admissions per request before lost
    retry_backoff_s: float = 0.05   # base backoff between submit retries
    hedge_p99_mult: float = 0.0     # duplicate in-flight requests older
    #                                 than mult * p99 latency (0 = off)
    shed_ladder: bool = False       # graceful-degradation ladder: strip
    #                                 wire/telemetry extras, then degrade
    #                                 the schedule (SE-quoted), before
    #                                 shedding with Overloaded


class DemandTracker:
    """Per-bucket admission-rate EWMAs from scrape deltas.

    ``update(deltas, now)`` folds one scrape window in: every tracked
    rate decays by 2^(-dt/halflife) and the window's mean rate
    (delta/dt) contributes the complementary weight — so a bucket that
    stops arriving decays toward zero (the autoscaler's scale-down
    signal) instead of pinning its peak forever."""

    def __init__(self, halflife_s: float):
        self.halflife_s = float(halflife_s)
        self._rate: dict[BucketKey, float] = {}
        self._t_last: float | None = None

    def update(self, deltas: dict, now: float) -> None:
        if self._t_last is None:
            # first scrape has no window length: seed rates at 0 and
            # start the clock (a huge bogus dt would swamp the EWMA)
            self._t_last = float(now)
            for key in deltas:
                self._rate.setdefault(key, 0.0)
            return
        dt = float(now) - self._t_last
        if dt <= 0.0:
            return
        self._t_last = float(now)
        decay = 2.0 ** (-dt / self.halflife_s)
        for key in set(self._rate) | set(deltas):
            inst = deltas.get(key, 0) / dt
            self._rate[key] = (self._rate.get(key, 0.0) * decay
                               + inst * (1.0 - decay))

    def rate(self, key: BucketKey) -> float:
        return self._rate.get(key, 0.0)

    def rates(self) -> dict:
        return dict(self._rate)


class ClusterRouter:
    """Assigns buckets to hosts: replica sets + least-loaded routing."""

    def __init__(self, hosts: "list[HostInfo]",
                 policy: RouterPolicy | None = None):
        assert hosts, "router needs at least one host"
        self.hosts = list(hosts)
        self.policy = policy or RouterPolicy()
        # One reentrant lock over ALL router + autoscaler mutable state
        # (module docstring): reentrant because Autoscaler.step ->
        # add_replica nests, shared so cross-object invariants
        # (replica sets vs demand rates) snapshot consistently.
        self.lock = threading.RLock()
        self._by_id = {h.host_id: h for h in hosts}
        assert len(self._by_id) == len(hosts), "duplicate host ids"
        self._replicas: dict[BucketKey, list[str]] = {}
        self._outstanding: dict[str, float] = {h.host_id: 0.0
                                               for h in hosts}
        # lifetime routed requests / cost per host — the imbalance metric
        self._served: dict[str, int] = {h.host_id: 0 for h in hosts}
        self._served_cost: dict[str, float] = {h.host_id: 0.0
                                               for h in hosts}
        # (host, key) pairs known to hold a compiled program (prewarmed
        # or served at least once): routing prefers them
        self._warm: set = set()
        # host state machine (DESIGN.md §13): healthy -> suspect (probe
        # failures, deprioritized at routing ties) -> dead (evicted from
        # every replica set, in-flight failed over) and draining (planned
        # removal: no new routes, outstanding work finishes). The
        # *frontend* counts failures and calls the mark_* transitions —
        # it sees the typed errors; the router only holds the state.
        self._state: dict[str, str] = {h.host_id: "healthy" for h in hosts}

    # -- host state machine --------------------------------------------------

    def host_state(self, host_id: str) -> str:
        with self.lock:
            return self._state[host_id]

    def host_states(self) -> "dict[str, str]":
        with self.lock:
            return dict(self._state)

    def _routable(self, host_id: str) -> bool:
        return self._state[host_id] in ("healthy", "suspect")

    def alive_hosts(self) -> "list[str]":
        """Hosts new work may route to (healthy or suspect)."""
        with self.lock:
            return [h.host_id for h in self.hosts
                    if self._routable(h.host_id)]

    def mark_suspect(self, host_id: str) -> None:
        """Healthy -> suspect (failed probes below the dead threshold).
        Suspect hosts still route — they lose ties to healthy replicas —
        but hedging targets their in-flight tail."""
        with self.lock:
            if self._state[host_id] == "healthy":
                self._state[host_id] = "suspect"

    def mark_healthy(self, host_id: str) -> None:
        """Probe succeeded: suspect hosts recover; a dead host revives
        (it rejoins routing and the autoscaler may re-add replicas)."""
        with self.lock:
            self._state[host_id] = "healthy"

    def mark_dead(self, host_id: str) -> "list[BucketKey]":
        """Declare a host dead: evict it from every replica set, zero its
        outstanding work (those requests are being failed over — their
        cost re-enters on the host that re-admits them), and return the
        bucket keys that lost a replica so the frontend can re-plan.
        Buckets left with no live replica refill lazily on the next
        ``route``/``add_replica`` (which skip dead hosts)."""
        with self.lock:
            self._state[host_id] = "dead"
            self._outstanding[host_id] = 0.0
            affected = []
            for key, reps in self._replicas.items():
                if host_id in reps:
                    reps.remove(host_id)
                    affected.append(key)
            return affected

    def drain(self, host_id: str) -> None:
        """Graceful removal: no new routes; in-flight work completes."""
        with self.lock:
            if self._state[host_id] != "dead":
                self._state[host_id] = "draining"

    # -- replica sets --------------------------------------------------------

    def replicas(self, key: BucketKey) -> "list[str]":
        with self.lock:
            return list(self._ensure(key))

    def _max_replicas(self) -> int:
        mr = self.policy.max_replicas
        return len(self.hosts) if mr <= 0 else min(mr, len(self.hosts))

    def _load(self, host_id: str) -> float:
        return self._outstanding[host_id] / self._by_id[host_id].weight

    def _ensure(self, key: BucketKey) -> "list[str]":
        reps = self._replicas.get(key)
        if reps is None:
            # first sight: min_replicas live hosts, least loaded first
            # (stable host order breaks ties so assignment is
            # deterministic); dead/draining hosts never join
            n = min(max(1, self.policy.min_replicas), len(self.hosts))
            pool = [h for h in self.hosts if self._routable(h.host_id)]
            order = sorted(pool,
                           key=lambda h: (self._load(h.host_id),
                                          self.hosts.index(h)))
            reps = self._replicas[key] = [h.host_id for h in order[:n]]
        return reps

    def _grow_locked(self, key: BucketKey, reps: "list[str]",
                     avoid=()) -> str | None:
        """Append the least-loaded live non-member host to ``reps``;
        None when no live host is available or the set is saturated."""
        if len(reps) >= self._max_replicas():
            return None
        candidates = [h for h in self.hosts
                      if h.host_id not in reps and h.host_id not in avoid
                      and self._routable(h.host_id)]
        if not candidates:
            return None
        host = min(candidates, key=lambda h: (self._load(h.host_id),
                                              self.hosts.index(h)))
        reps.append(host.host_id)
        return host.host_id

    def add_replica(self, key: BucketKey) -> str | None:
        """Grow the bucket's replica set by the least-loaded live
        non-member host; returns its id (None when saturated)."""
        with self.lock:
            return self._grow_locked(key, self._ensure(key))

    def remove_replica(self, key: BucketKey) -> str | None:
        """Shrink the bucket's replica set (never below min_replicas):
        drops the most recently added member — the longest-standing
        replicas hold the warmest caches."""
        with self.lock:
            reps = self._ensure(key)
            if len(reps) <= max(1, self.policy.min_replicas):
                return None
            return reps.pop()

    # -- routing -------------------------------------------------------------

    def route(self, key: BucketKey, cost: float,
              prefer: str | None = None, avoid=()) -> str:
        """Pick the host for one request and account its outstanding
        cost. A ``prefer`` replica under the admission cap wins outright
        — the frontend passes the host holding the bucket's open partial
        batch, so a filling batch is not split across hosts mid-stream
        (splitting costs an extra program dispatch AND changes padded
        widths, breaking bit-identity with a single-host service).
        Otherwise, among the bucket's *live* replicas (dead/draining
        hosts never route; ``avoid`` lists hosts the caller is retrying
        away from): least cost-weighted outstanding work first, then —
        at equal load — healthy before suspect, then prewarmed/
        previously-served hosts before cold ones (a cold host pays an XLA
        compile on first dispatch; warmth must only break ties, or the
        first-served host would win every route and capacity added by
        the autoscaler would never drain load), then stable host order.
        A bucket whose replicas all died refills from the surviving
        hosts here (the autoscaler replaces capacity on its next step;
        this keeps the *next request* routable immediately). Raises
        ``Overloaded`` when no live replica exists or an admission cap is
        set and every live replica is at it."""
        with self.lock:
            reps = self._ensure(key)
            # a death may have shrunk the set below min_replicas: top it
            # back up from survivors (membership ignores ``avoid`` — the
            # pick below still honors it)
            target = min(max(1, self.policy.min_replicas),
                         sum(1 for h in self.hosts
                             if self._routable(h.host_id)))
            while sum(1 for hid in reps if self._routable(hid)) < target:
                if self._grow_locked(key, reps) is None:
                    break
            live = [hid for hid in reps
                    if self._routable(hid) and hid not in avoid]
            if not live:
                grown = self._grow_locked(key, reps, avoid)
                if grown is None:
                    # an avoided host is better than failing outright
                    live = [hid for hid in reps if self._routable(hid)]
                    if not live:
                        raise Overloaded(f"no live replica for {key}")
                else:
                    live = [grown]
            cap = self.policy.max_outstanding
            if (prefer in live
                    and (cap <= 0.0 or self._outstanding[prefer] < cap)):
                self._outstanding[prefer] += cost
                self._served[prefer] += 1
                self._served_cost[prefer] += cost
                self._warm.add((prefer, key))
                return prefer
            ranked = sorted(
                live,
                key=lambda hid: (self._load(hid),
                                 self._state[hid] == "suspect",
                                 (hid, key) not in self._warm
                                 if self.policy.prefer_prewarmed else False,
                                 self.hosts.index(self._by_id[hid])))
            if cap > 0.0:
                ranked = [hid for hid in ranked
                          if self._outstanding[hid] < cap]
                if not ranked:
                    raise Overloaded(
                        f"all {len(reps)} replica(s) of {key} at the "
                        f"outstanding cap {cap}")
            host_id = ranked[0]
            self._outstanding[host_id] += cost
            self._served[host_id] += 1
            self._served_cost[host_id] += cost
            self._warm.add((host_id, key))
            return host_id

    def complete(self, host_id: str, cost: float) -> None:
        """Return one routed request's cost (result delivered). Snaps
        tiny float residue to exactly zero so a fully drained host ties
        (and loses to host order) instead of ranking on leftover eps."""
        with self.lock:
            left = self._outstanding[host_id] - cost
            self._outstanding[host_id] = 0.0 if left < 1e-9 else left

    def mark_warm(self, host_id: str, key: BucketKey) -> None:
        """Record a prewarmed (host, bucket) pair (frontend prewarm)."""
        with self.lock:
            self._warm.add((host_id, key))

    # -- observability -------------------------------------------------------

    def imbalance(self) -> float:
        """Cost-weighted served-work ratio max/min across hosts (1.0 =
        perfectly balanced; hosts that served nothing count as the
        smallest share). Dead hosts are excluded — a mid-run host death
        is a fault, not a balance failure. The cluster bench's balance
        gate."""
        with self.lock:
            shares = [self._served_cost[h.host_id]
                      / self._by_id[h.host_id].weight for h in self.hosts
                      if self._state[h.host_id] != "dead"]
            if not shares:
                return 1.0
            hi = max(shares)
            if hi <= 0.0:
                return 1.0
            lo = min(shares)
            return math.inf if lo <= 0.0 else hi / lo

    def stats(self) -> dict:
        with self.lock:
            return {
                "hosts": [h.host_id for h in self.hosts],
                "outstanding": dict(self._outstanding),
                "served": dict(self._served),
                "served_cost": {k: round(v, 3)
                                for k, v in self._served_cost.items()},
                "imbalance": self.imbalance(),
                "replicas": {str(k): list(v)
                             for k, v in self._replicas.items()},
                "warm_programs": len(self._warm),
                "states": dict(self._state),
            }


class Autoscaler:
    """Per-bucket replica scaling from demand EWMAs (Ray Serve style:
    the router owns placement state, the autoscaler only moves replica
    counts and reports events)."""

    def __init__(self, router: ClusterRouter,
                 policy: RouterPolicy | None = None):
        self.router = router
        self.policy = policy or router.policy
        # Shares the router's reentrant lock: step() mutates replica sets
        # through router methods, and stats scrapes must not tear across
        # the rates/events pair while a step is mid-flight.
        self.lock = router.lock
        self.tracker = DemandTracker(self.policy.ewma_halflife_s)
        self._below: dict[BucketKey, int] = {}
        self.events: list = []

    def observe(self, deltas: dict, now: float) -> None:
        """Feed one scrape window of per-bucket admission deltas."""
        with self.lock:
            self.tracker.update(deltas, now)

    def desired_replicas(self, key: BucketKey) -> int:
        """ceil(rate * cost / target_load), clamped — the replica count
        whose per-replica load sits at or under the target."""
        with self.lock:
            load = self.tracker.rate(key) * shape_cost(key)
            want = math.ceil(load / self.policy.target_load)
            lo = max(1, self.policy.min_replicas)
            hi = self.router._max_replicas()
            return min(max(want, lo), hi)

    def step(self, now: float | None = None) -> list:
        """One autoscaling pass over every tracked bucket; returns the
        applied events as ``("scale_up"|"scale_down", key, host_id)``
        tuples (also appended to ``self.events``). Scale-up applies
        immediately; scale-down needs ``down_patience`` consecutive
        passes below the threshold."""
        with self.lock:
            return self._step_locked()

    def _step_locked(self) -> list:
        events = []
        for key in self.tracker.rates():
            current = len(self.router.replicas(key))
            desired = self.desired_replicas(key)
            if desired > current:
                self._below.pop(key, None)
                for _ in range(desired - current):
                    host = self.router.add_replica(key)
                    if host is None:
                        break
                    events.append(("scale_up", key, host))
            elif desired < current:
                seen = self._below.get(key, 0) + 1
                self._below[key] = seen
                if seen >= max(1, self.policy.down_patience):
                    self._below[key] = 0
                    host = self.router.remove_replica(key)
                    if host is not None:
                        events.append(("scale_down", key, host))
            else:
                self._below.pop(key, None)
        self.events.extend(events)
        return events

    def stats(self) -> dict:
        with self.lock:
            return {
                "rates": {str(k): round(v, 4)
                          for k, v in self.tracker.rates().items()},
                "events": [(kind, str(k), host)
                           for kind, k, host in self.events],
            }
