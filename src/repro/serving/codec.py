"""Pytree/bytes serialization for the cluster frontend (DESIGN.md §11).

``SolveRequest``/``SolveResult`` cross host boundaries as bytes — never
pickle: the backend server decodes attacker-reachable payloads, and a
pickle there is remote code execution. The format is a fixed-magic,
versioned frame of

    b"AMP1" | u32 header_len | JSON header | raw array buffers

where the header carries every scalar field plus an ``arrays`` manifest
(name, dtype string, shape) and the buffers follow concatenated in
manifest order, C-contiguous little-endian. JSON covers all scalar field
types we ship (str/int/float/bool/None); arrays go raw, so the round
trip is bit-exact — including NaN/inf payloads and float rate columns —
which the property test pins.

Only fields of the public dataclasses are encoded: decode constructs
``SolveRequest``/``SolveResult``/``BucketKey``/``PrewarmSpec`` by
keyword, so unknown header keys (a newer peer) fail loudly instead of
smuggling state.
"""
from __future__ import annotations

import dataclasses
import json
import struct

import numpy as np

from ..core.denoisers import BernoulliGauss
from .buckets import BucketKey

__all__ = [
    "encode_request", "decode_request", "encode_result", "decode_result",
    "encode_metrics", "decode_metrics",
    "bucket_to_dict", "bucket_from_dict", "spec_to_dict", "spec_from_dict",
    "CodecError",
]

_MAGIC = b"AMP1"


class CodecError(ValueError):
    """Malformed or foreign frame (bad magic, truncated, unknown keys)."""


# -- framing ----------------------------------------------------------------

def _pack(header: dict, arrays: "dict[str, np.ndarray]") -> bytes:
    manifest = []
    bufs = []
    for name, arr in arrays.items():
        a = np.ascontiguousarray(arr)
        if a.dtype.byteorder == ">":          # wire format is little-endian
            a = a.astype(a.dtype.newbyteorder("<"))
        manifest.append({"name": name, "dtype": a.dtype.str,
                         "shape": list(a.shape)})
        bufs.append(a.tobytes())
    header = dict(header, arrays=manifest)
    hj = json.dumps(header, separators=(",", ":")).encode()
    return b"".join([_MAGIC, struct.pack("<I", len(hj)), hj] + bufs)


def _unpack(buf: bytes) -> "tuple[dict, dict[str, np.ndarray]]":
    if len(buf) < 8 or buf[:4] != _MAGIC:
        raise CodecError(f"bad frame magic {buf[:4]!r}")
    (hlen,) = struct.unpack("<I", buf[4:8])
    if len(buf) < 8 + hlen:
        raise CodecError("truncated header")
    try:
        header = json.loads(buf[8:8 + hlen])
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CodecError(f"bad header: {e}") from e
    if not isinstance(header, dict):
        raise CodecError(f"header is {type(header).__name__}, not object")
    manifest = header.pop("arrays", [])
    if not isinstance(manifest, list):
        raise CodecError("bad arrays manifest")
    arrays = {}
    off = 8 + hlen
    for ent in manifest:
        # a corrupted or hostile manifest must fail *here*, not as a
        # numpy crash (bad dtype string) or a giant allocation (negative
        # or overflowing dims) deeper in
        if not isinstance(ent, dict):
            raise CodecError("bad manifest entry")
        try:
            name, dts, shape = ent["name"], ent["dtype"], ent["shape"]
        except (KeyError, TypeError) as e:
            raise CodecError(f"bad manifest entry: {e}") from e
        if not (isinstance(shape, list)
                and all(isinstance(d, int) and 0 <= d < (1 << 40)
                        for d in shape)):
            raise CodecError(f"bad shape {shape!r} for array {name!r}")
        try:
            dt = np.dtype(dts)
        except TypeError as e:
            raise CodecError(f"bad dtype {dts!r}: {e}") from e
        nb = dt.itemsize
        for d in shape:
            nb *= d
        if len(buf) < off + nb:
            raise CodecError(f"truncated array {name!r}")
        try:
            arrays[str(name)] = np.frombuffer(
                buf[off:off + nb], dt).reshape(tuple(shape)).copy()
        except ValueError as e:   # object/zero-width dtypes and kin
            raise CodecError(f"bad array {name!r}: {e}") from e
        off += nb
    if off != len(buf):
        raise CodecError(f"{len(buf) - off} trailing bytes")
    return header, arrays


def _take(header: dict, key: str):
    try:
        return header.pop(key)
    except KeyError:
        raise CodecError(f"missing header field {key!r}") from None


def _done(header: dict, kind: str) -> None:
    if header:
        raise CodecError(f"unknown {kind} fields {sorted(header)}")


# -- small pieces -----------------------------------------------------------

def _prior_to_dict(p: BernoulliGauss) -> dict:
    return {"eps": float(p.eps), "mu_s": float(p.mu_s),
            "sigma_s": float(p.sigma_s)}


def _prior_from_dict(d: dict) -> BernoulliGauss:
    return BernoulliGauss(**d)


def bucket_to_dict(key: BucketKey) -> dict:
    return dataclasses.asdict(key)


def bucket_from_dict(d: dict) -> BucketKey:
    try:
        return BucketKey(**d)
    except TypeError as e:
        raise CodecError(f"bad bucket: {e}") from e


def spec_to_dict(spec) -> dict:
    """``PrewarmSpec`` as a JSON-able dict (remote-prewarm directives)."""
    d = dataclasses.asdict(spec)
    d["prior"] = _prior_to_dict(spec.prior)
    if d.get("batch_widths") is not None:
        d["batch_widths"] = list(d["batch_widths"])
    return d


def spec_from_dict(d: dict):
    from .service import PrewarmSpec
    d = dict(d)
    d["prior"] = _prior_from_dict(d["prior"])
    if d.get("batch_widths") is not None:
        d["batch_widths"] = tuple(d["batch_widths"])
    try:
        return PrewarmSpec(**d)
    except TypeError as e:
        raise CodecError(f"bad prewarm spec: {e}") from e


# -- SolveRequest / SolveResult --------------------------------------------

def encode_request(req) -> bytes:
    header = {
        "kind": "request",
        "prior": _prior_to_dict(req.prior),
        "snr_db": req.snr_db, "n_proc": req.n_proc, "n_iter": req.n_iter,
        "policy": req.policy, "dp_total_bits": req.dp_total_bits,
        "bt_c_ratio": req.bt_c_ratio, "bt_r_max": req.bt_r_max,
        "transport": req.transport, "layout": req.layout,
        "erasure_rate": req.erasure_rate,
        "erasure_model": req.erasure_model,
        "erasure_burst": req.erasure_burst,
        "erasure_seed": req.erasure_seed,
        "recovery": req.recovery, "measure_wire": req.measure_wire,
        "a_id": req.a_id, "request_id": req.request_id,
        "spans": req.spans,
    }
    arrays = {"y": np.asarray(req.y), "a": np.asarray(req.a)}
    if req.deltas is not None:
        arrays["deltas"] = np.asarray(req.deltas)
    return _pack(header, arrays)


def decode_request(buf: bytes):
    from .service import SolveRequest
    header, arrays = _unpack(buf)
    if _take(header, "kind") != "request":
        raise CodecError("not a request frame")
    header["prior"] = _prior_from_dict(_take(header, "prior"))
    try:
        return SolveRequest(y=arrays["y"], a=arrays["a"],
                            deltas=arrays.get("deltas"), **header)
    except TypeError as e:   # unknown field from a newer peer: fail loudly
        raise CodecError(f"bad request: {e}") from e


def encode_result(res) -> bytes:
    header = {
        "kind": "result",
        "request_id": res.request_id,
        "total_bits": res.total_bits,
        "bucket": bucket_to_dict(res.bucket),
        "batch_size": res.batch_size,
        "bytes_on_wire": res.bytes_on_wire,
        "payload_bytes": res.payload_bytes,
        "time_on_air_s": res.time_on_air_s,
        "energy_j": res.energy_j,
        "se_drift": res.se_drift,
        "spans": res.spans,
    }
    arrays = {"x": np.asarray(res.x),
              "sigma2_hat": np.asarray(res.sigma2_hat),
              "deltas": np.asarray(res.deltas),
              "extra_var": np.asarray(res.extra_var),
              "rates": np.asarray(res.rates)}
    return _pack(header, arrays)


def decode_result(buf: bytes):
    from .service import SolveResult
    header, arrays = _unpack(buf)
    if _take(header, "kind") != "result":
        raise CodecError("not a result frame")
    header["bucket"] = bucket_from_dict(_take(header, "bucket"))
    try:
        return SolveResult(**header, **arrays)
    except TypeError as e:
        raise CodecError(f"bad result: {e}") from e


# -- telemetry metrics frames ----------------------------------------------

def encode_metrics(host, snapshot: dict) -> bytes:
    """Metrics registry snapshot as a codec frame (DESIGN.md §12): pure
    JSON header, no array segments — snapshots are small and already
    plain data, and reusing the frame keeps the no-pickle invariant."""
    return _pack({"kind": "metrics", "host": str(host),
                  "metrics": snapshot}, {})


def decode_metrics(buf: bytes) -> "tuple[str, dict]":
    header, arrays = _unpack(buf)
    if _take(header, "kind") != "metrics":
        raise CodecError("not a metrics frame")
    if arrays:
        raise CodecError(f"unexpected arrays {sorted(arrays)}")
    host = _take(header, "host")
    snap = _take(header, "metrics")
    if not isinstance(snap, dict) or not isinstance(snap.get("metrics"), list):
        raise CodecError("bad metrics payload")
    _done(header, "metrics")
    return host, snap
