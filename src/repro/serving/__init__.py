"""Serving front for the unified AMP engine (DESIGN.md §5).

Heterogeneous CS solve requests -> shape buckets -> vmapped batched engine
calls -> per-request results with realized-rate accounting. The hot path
(DESIGN.md §9) runs on a device-resident operand cache, AOT-prewarmed
programs, and donated batch operands.
"""
from .batcher import Batcher
from .buckets import (BucketKey, BucketPolicy, batch_width_ladder,
                      bucket_for, pad_batch_size, placement_for)
from .operand_cache import OperandCache, fingerprint
from .service import PrewarmSpec, SolveRequest, SolveResult, SolveService

__all__ = [
    "Batcher", "BucketKey", "BucketPolicy", "batch_width_ladder",
    "bucket_for", "pad_batch_size", "placement_for", "OperandCache",
    "fingerprint", "PrewarmSpec", "SolveRequest", "SolveResult",
    "SolveService",
]
