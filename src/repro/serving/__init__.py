"""Serving front for the unified AMP engine (DESIGN.md §5).

Heterogeneous CS solve requests -> shape buckets -> vmapped batched engine
calls -> per-request results with realized-rate accounting. The hot path
(DESIGN.md §9) runs on a device-resident operand cache, AOT-prewarmed
programs, and donated batch operands. The cluster tier (DESIGN.md §11)
splits into a frontend (``ClusterService`` admission + host backends), a
scheduler (``ClusterRouter`` + ``Autoscaler``), and per-host
``SolveService`` backends, with ``serving.codec`` bytes on the wire
between hosts. The telemetry plane (DESIGN.md §12) threads a metrics
registry, per-request trace spans, and a live SE-drift monitor through
all of it (``repro.telemetry``; metrics snapshots cross hosts as their
own codec frame kind). The fault-tolerance plane (DESIGN.md §13) adds
failure detection (health probes walking hosts through
healthy/suspect/dead), bit-identical failover replay, tail hedging, a
graceful-degradation ladder, and a deterministic chaos harness
(``serving.chaos``) that proves all of it under injected faults.
"""
from .batcher import Batcher
from .buckets import (BucketKey, BucketPolicy, batch_width_ladder,
                      bucket_for, pad_batch_size, placement_for)
from .chaos import ChaosBackend, ChaosProxy, FaultPlan, FaultSpec
from .codec import (CodecError, decode_metrics, decode_request,
                    decode_result, encode_metrics, encode_request,
                    encode_result)
from .frontend import (BackendServer, ClusterService, LocalBackend,
                       ShedLadder, TcpBackend)
from .operand_cache import OperandCache, fingerprint
from .router import (Autoscaler, ClusterRouter, DemandTracker, HostInfo,
                     Overloaded, RouterPolicy, routing_key, shape_cost)
from .service import PrewarmSpec, SolveRequest, SolveResult, SolveService
from .wire import (BackendError, BackendUnavailable, FrameError,
                   RemoteRequestError)

__all__ = [
    "Batcher", "BucketKey", "BucketPolicy", "batch_width_ladder",
    "bucket_for", "pad_batch_size", "placement_for", "OperandCache",
    "fingerprint", "PrewarmSpec", "SolveRequest", "SolveResult",
    "SolveService",
    # cluster tier (DESIGN.md §11)
    "ClusterService", "LocalBackend", "BackendServer", "TcpBackend",
    "ClusterRouter", "Autoscaler", "DemandTracker", "HostInfo",
    "RouterPolicy", "Overloaded", "routing_key", "shape_cost",
    "encode_request", "decode_request", "encode_result", "decode_result",
    "encode_metrics", "decode_metrics", "CodecError",
    # fault-tolerance plane (DESIGN.md §13)
    "BackendError", "BackendUnavailable", "RemoteRequestError",
    "FrameError", "ShedLadder", "FaultSpec", "FaultPlan", "ChaosBackend",
    "ChaosProxy",
]
