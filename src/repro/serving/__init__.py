"""Serving front for the unified AMP engine (DESIGN.md §5).

Heterogeneous CS solve requests -> shape buckets -> vmapped batched engine
calls -> per-request results with realized-rate accounting.
"""
from .batcher import Batcher
from .buckets import (BucketKey, BucketPolicy, bucket_for, pad_batch_size,
                      placement_for)
from .service import SolveRequest, SolveResult, SolveService

__all__ = [
    "Batcher", "BucketKey", "BucketPolicy", "bucket_for", "pad_batch_size",
    "placement_for", "SolveRequest", "SolveResult", "SolveService",
]
