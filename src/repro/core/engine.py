"""Unified scan-compiled MP-AMP engine (DESIGN.md §3).

The paper's algorithm family — centralized AMP, lossless MP-AMP, ECSQ
MP-AMP with fixed / DP / BT rate schedules, int8/int4 block-quantized
fusion — is one iteration body parameterized by

  * a **Transport**: how the per-processor fusion messages f_t^p are
    compressed before the sum at the fusion center
    (``ExactFusion`` | ``EcsqTransport`` | ``BlockQuantTransport``), and
  * a **RateController**: how the quantizer resolution is chosen per
    iteration (``FixedSchedule`` | ``DPSchedule`` | ``BTRateControl``).

``AmpEngine`` runs the full T-iteration solve as a *single* ``lax.scan``
over that body — including BT back-tracking rate control, re-expressed as a
fixed-count in-graph bisection against precomputed MMSE/rate tables — so
there is no per-iteration host round-trip (the ``float(s2)`` syncs of the
pre-engine ``mp_amp.py`` host loop). A ``vmap``-batched ``solve_many``
solves many CS instances at once (the serving scenario), and the local
computation routes through the ``kernels/amp_fused`` suite (DESIGN.md §8)
on TPU: batched Pallas grids covering the whole (batch, P) stack in one
launch with the sigma2_hat reduction fused, fused column-layout kernels,
tile padding hoisted to solve entry, and optional bf16 A-streaming
(``EngineConfig.a_dtype``) with f32 accumulation.

The mesh is an engine axis, not a separate code path (DESIGN.md §6):
``solve_sharded`` runs the *same* scan body inside ``shard_map`` over a
mesh axis, with the per-processor (A, y) shards as sharded operands and
schedules / BT tables riding replicated. Device-collective transports
(``PsumFusion``, ``CompressedPsumTransport``) make the paper's fusion
``f_t = sum_p Q(f_t^p)`` an actual (optionally lossy-compressed) collective
on the device links, with straggler ``drop`` rescaling folded in.

The partition **layout** is a third engine axis (DESIGN.md §7): the paper's
row-wise scheme (``RowPartition``, each processor owns M/P rows and the
fusion sums denoiser messages) and the column-wise C-MP-AMP of
arXiv:1701.02578 (``ColumnPartition``, each processor owns N/P signal
columns and the fusion sums *residual contributions* ``r^p = A_p x_p``,
length M — the natural layout for tall-N problems where N >> M). Both run
the same scan/transport/controller machinery: a Transport fuses a (P, L)
stack into (L,) either way, so ``ExactFusion``/``EcsqTransport``/
``BlockQuantTransport`` and the device collectives apply to residual
contributions unchanged. Column rate control gets its own in-graph tables
(``ColumnBTRateControl``: the quantized payload is ~Gaussian, so the rate
table is one-dimensional) driven by the column-wise two-stage state
evolution (``state_evolution.se_trajectory_col``).

``core/amp.py`` (centralized), ``core/mp_amp.py`` (emulated multi-processor)
and ``launch/solver.py`` (mesh-distributed) are thin frontends over this
module; arbitrary Python rate-controller callables are still supported via
``solve_host_loop``, which reuses the exact same jitted iteration body one
step at a time.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import warnings
from typing import NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec, SingleDeviceSharding

from ..compat import axis_size, shard_map
from ..kernels.amp_fused.ops import (amp_local_grid, col_inner_step,
                                     col_residual, pad_col_shards,
                                     pad_row_shards)
from .compression import (QuantConfig, compressed_psum, dequantize_blocks,
                          quant_noise_var, quantize_blocks)
from .denoisers import BernoulliGauss, eta, eta_bg
from .quantize import (GaussMixture, dequantize_midtread, ecsq_entropy,
                       message_mixture, quantize_midtread)
from .rate_alloc import BTController, rate_for_sigma_q2
from .rate_distortion import RDModel
from .state_evolution import CSProblem, se_trajectory_col

__all__ = [
    "AmpEngine", "EngineConfig", "EngineTrace", "ErasureSpec",
    "RowPartition", "ColumnPartition",
    "Transport", "ExactFusion", "EcsqTransport", "BlockQuantTransport",
    "PsumFusion", "CompressedPsumTransport",
    "RateController", "FixedSchedule", "DPSchedule", "BTRateControl",
    "ColDPSchedule", "ColumnBTRateControl", "ColBTTables", "col_bt_delta_for",
    "BTTables", "HetParams", "bt_delta_for", "stack_bt_tables",
    "pad_bt_tables", "amp_gc_step", "split_problem", "split_problem_cols",
]


# ---------------------------------------------------------------------------
# shared iteration pieces
# ---------------------------------------------------------------------------

def split_problem(a_mat: np.ndarray, y: np.ndarray, n_proc: int):
    """Row-partition (A, y) across processors: (P, M/P, N), (P, M/P)."""
    m, n = a_mat.shape
    assert m % n_proc == 0, f"M={m} not divisible by P={n_proc}"
    mp = m // n_proc
    return a_mat.reshape(n_proc, mp, n), y.reshape(n_proc, mp)


def split_problem_cols(a_mat: np.ndarray, n_proc: int) -> np.ndarray:
    """Column-partition A across processors: (M, N) -> (P, M, N/P).

    Processor p owns the contiguous column block ``A[:, p*N/P:(p+1)*N/P]``
    and the matching slice of the signal (C-MP-AMP, DESIGN.md §7); y is
    shared, not split — the measurements are common to every processor.
    """
    m, n = a_mat.shape
    assert n % n_proc == 0, f"N={n} not divisible by P={n_proc}"
    np_ = n // n_proc
    return np.ascontiguousarray(
        a_mat.reshape(m, n_proc, np_).transpose(1, 0, 2))


@dataclasses.dataclass(frozen=True)
class RowPartition:
    """The source paper's layout: each processor owns M/P measurement rows;
    the fusion sums the per-processor denoiser messages f^p (length N)."""


@dataclasses.dataclass(frozen=True)
class ColumnPartition:
    """C-MP-AMP layout (arXiv:1701.02578): each processor owns N/P signal
    columns; the fusion sums quantized residual contributions A_p x_p
    (length M).  ``EngineConfig.n_iter`` counts *outer rounds* (one fusion
    exchange each); every round runs ``n_inner`` local AMP iterations.

    The Onsager memory must survive the fusion boundary — a bare restart
    (``z <- g`` with no correction) measurably breaks the two-stage state
    evolution (the SE-oracle tests would catch a ~20x drift).  Every
    block jumps *simultaneously* at a fusion, so the joint correction is
    the sum of every processor's final Onsager term: the next round's
    residual starts from

        g^{s+1} + sum_q c_q * z_q^{last},

    where ``z_q^{last}`` is the residual that fed processor q's final
    denoise and ``c_q = sum(eta')/M`` its coefficient (a per-processor
    correction alone — each block treating the others as a frozen
    observation — visibly under-corrects and stalls).  At ``n_inner == 1``
    every ``z_q^{last}`` *is* the previous fused residual, the correction
    collapses to the scalar ``(sum_q c_q) * g^s``, and C-MP-AMP becomes
    *identical* to centralized AMP under exact fusion — which is what the
    layout-parity tests pin; the engine then carries only that scalar
    (one extra number per processor on the wire).  At ``n_inner > 1`` the
    correction is a second length-M exchange riding with the residual
    contributions (uncompressed: it is an Onsager correction, not a
    payload — DESIGN.md §7 discusses the traffic accounting).
    """

    n_inner: int = 1

    @property
    def carry_fused(self) -> bool:
        """Scalar-carry fast path: at one inner iteration per round the
        joint boundary correction is a scalar times the previous fused
        residual (docstring), so nothing vector-valued crosses rounds."""
        return self.n_inner == 1


def amp_gc_step(f, denoise_var, prior: BernoulliGauss, kappa):
    """GC tail shared by every frontend: denoise + Onsager coefficient."""
    eta_fn = lambda v: eta(v, denoise_var, prior, xp=jnp)
    x_new = eta_fn(f)
    onsager_new = jax.grad(lambda v: jnp.sum(eta_fn(v)))(f).mean() / kappa
    return x_new, onsager_new


# ---------------------------------------------------------------------------
# erasure (lossy-wire realism; DESIGN.md §10)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ErasureSpec:
    """Per-round, per-processor fusion-packet loss model.

    ``sample_mask`` draws the concrete (T, P) 0/1 drop schedule host-side;
    the engine threads it through the solve as an ordinary scan operand,
    so erasure is *data*, not a recompile — one erasure-enabled program
    serves every loss realization of its shape.

    ``bernoulli``: each packet lost i.i.d. with probability ``rate``.
    ``gilbert``: two-state Gilbert-Elliott channel per processor — a bad
    state drops every packet, mean bad-state sojourn ``burst_len`` rounds,
    transition probabilities chosen so the stationary loss probability is
    ``rate`` (p_bg = 1/burst_len, p_gb = rate*p_bg/(1-rate), clipped to
    1). Chains start in their stationary distribution so the first round
    is already representative.
    """

    rate: float = 0.0
    model: str = "bernoulli"          # "bernoulli" | "gilbert"
    burst_len: float = 4.0            # gilbert: mean bad-state rounds
    seed: int = 0

    def __post_init__(self):
        assert 0.0 <= self.rate < 1.0, self.rate
        assert self.model in ("bernoulli", "gilbert"), self.model
        assert self.burst_len >= 1.0, self.burst_len

    def sample_mask(self, n_iter: int, n_proc: int,
                    seed: int | None = None) -> np.ndarray:
        """Draw a (n_iter, n_proc) float32 drop mask (1 = packet lost)."""
        rng = np.random.default_rng(self.seed if seed is None else seed)
        if self.rate == 0.0:
            return np.zeros((n_iter, n_proc), np.float32)
        if self.model == "bernoulli":
            return (rng.random((n_iter, n_proc))
                    < self.rate).astype(np.float32)
        p_bg = 1.0 / self.burst_len
        p_gb = min(self.rate * p_bg / (1.0 - self.rate), 1.0)
        bad = rng.random(n_proc) < self.rate
        mask = np.zeros((n_iter, n_proc), np.float32)
        for t in range(n_iter):
            mask[t] = bad
            flip = rng.random(n_proc)
            bad = np.where(bad, flip >= p_bg, flip < p_gb)
        return mask


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------

@runtime_checkable
class Transport(Protocol):
    """Fusion-message compression: (P, N) messages -> fused (N,) estimate.

    ``fuse`` must be pure jnp (it runs inside jit/scan/vmap) and returns
    ``(f, extra_var, symbols)`` where ``extra_var`` is the additional
    denoiser variance injected by compression (the paper's P*sigma_Q^2
    accounting) and ``symbols`` the per-processor quantizer indices for
    empirical-rate accounting (all-zeros when not applicable).

    ``drop`` is the erasure/straggler mask: a per-processor (P,) 0/1
    vector for the emulated transports (survivor rescale via
    ``_erasure_rescale``), a per-device scalar for the device collectives
    (``_drop_rescale``). ``None`` (emulated only) compiles the drop-free
    program — byte-identical to the pre-erasure engine.
    """

    def fuse(self, f_p, delta, drop=None): ...  # pragma: no cover - protocol


def _erasure_rescale(f_q, extra_per, drop):
    """Emulated counterpart of ``_drop_rescale``: per-processor erasure of
    the row-layout fusion packets. ``drop`` is a (P,) 0/1 mask; survivors
    are rescaled by P/k so the fusion stays an unbiased estimate of the
    full sum, and their embedded quantization noise (``extra_per`` per
    delivered packet) amplifies by the same scale^2 — exactly the noise
    bookkeeping the erasure-extended SE integrates over k
    (``state_evolution.erasure_amplification``)."""
    keep = 1.0 - drop
    n_surv = jnp.maximum(jnp.sum(keep), 1.0)
    scale = f_q.shape[0] / n_surv
    f = jnp.sum(f_q * keep[:, None], axis=0) * scale
    extra = extra_per * n_surv * scale**2
    return f, extra


@dataclasses.dataclass(frozen=True)
class ExactFusion:
    """Lossless fusion (centralized AMP / the paper's 32-bit baseline)."""

    def fuse(self, f_p, delta, drop=None):
        if drop is None:
            return jnp.sum(f_p, axis=0), jnp.zeros(()), jnp.zeros_like(f_p)
        f, extra = _erasure_rescale(f_p, jnp.zeros(()), drop)
        return f, extra, jnp.zeros_like(f_p)


@dataclasses.dataclass(frozen=True)
class EcsqTransport:
    """Midtread uniform quantizer per message (paper Sec. 3.2).

    ``delta`` is the bin size chosen by the rate controller; non-finite
    delta means lossless fusion at that iteration. Rate accounting is the
    ECSQ entropy H_Q (analytic) plus the empirical entropy of ``symbols``
    — both computed by the frontends from the returned trace.
    """

    def fuse(self, f_p, delta, drop=None):
        n_proc = f_p.shape[0]
        lossless = ~jnp.isfinite(delta)
        safe_delta = jnp.where(lossless, 1.0, delta)
        q = quantize_midtread(f_p, safe_delta)
        f_q = jnp.where(lossless, f_p, dequantize_midtread(q, safe_delta))
        if drop is None:
            f = jnp.sum(f_q, axis=0)
            extra = jnp.where(lossless, 0.0, n_proc * safe_delta**2 / 12.0)
            return f, extra, q
        per = jnp.where(lossless, 0.0, safe_delta**2 / 12.0)
        f, extra = _erasure_rescale(f_q, per, drop)
        return f, extra, q


@dataclasses.dataclass(frozen=True)
class BlockQuantTransport:
    """Per-block max-abs int8/int4 quantization (the compressed_psum wire
    format of core/compression.py, emulated over the leading P axis).

    The rate is fixed by the wire width (``bits`` + bf16 scale per block)
    instead of a controller, so ``delta`` is ignored; noise accounting uses
    the realized per-block bin sizes exactly like ``compressed_psum``.
    """

    bits: int = 8
    block: int = 512

    @property
    def qc(self) -> QuantConfig:
        return QuantConfig(bits=self.bits, block=self.block)

    def fuse(self, f_p, delta, drop=None):
        n_proc, n = f_p.shape
        qc = self.qc
        q, scale = quantize_blocks(f_p, qc)
        deq = dequantize_blocks(q, scale, qc, orig_len=n)
        if drop is None:
            f = jnp.sum(deq, axis=0)
            extra = quant_noise_var(scale, qc) * n_proc
        else:
            f, extra = _erasure_rescale(deq, quant_noise_var(scale, qc),
                                        drop)
        return f, extra, q[..., :n].astype(jnp.float32)


# -- device-collective transports (run inside shard_map; DESIGN.md §6) ------

def _drop_rescale(f_local, drop, axis: str):
    """Straggler mitigation as a transport option: zero this shard when
    ``drop`` is set and rescale the survivors so the fusion stays an
    unbiased estimate of the full sum (the modified SE absorbs the extra
    variance exactly like quantization noise). Returns ``(rescaled, keep,
    scale)`` so callers can apply the matching factors to their own noise
    accounting."""
    keep = 1.0 - drop
    n_dev = axis_size(axis)
    scale = n_dev / jnp.maximum(lax.psum(keep, axis), 1.0)
    return f_local * keep * scale, keep, scale


@dataclasses.dataclass(frozen=True)
class PsumFusion:
    """Exact-wire fusion over a mesh axis: per-device messages are summed
    locally (optionally through an emulated per-processor ``local``
    transport, e.g. ``EcsqTransport`` for the paper's quantize-at-each-
    processor scenario) and psum'd across ``axis``.

    ``fuse`` takes the extra ``drop`` operand (per-iteration straggler flag
    for this shard); device transports always receive it — the engine's
    sharded scan threads it as a sharded scan operand.
    """

    axis: str = "data"
    local: Transport = dataclasses.field(default_factory=ExactFusion)

    def fuse(self, f_p, delta, drop):
        f_loc, extra_loc, _ = self.local.fuse(f_p, delta)
        f_loc, keep, scale = _drop_rescale(f_loc, drop, self.axis)
        f = lax.psum(f_loc, self.axis)
        # local fuse saw only this device's emulated processors: psum turns
        # p_local * sigma_Q^2 into the paper's global P * sigma_Q^2. Under
        # straggler rescale the survivors' embedded quantization noise is
        # amplified by scale^2 (dropped shards contribute none), so the
        # accounting follows the same keep/scale as the messages.
        extra = lax.psum(extra_loc * keep, self.axis) * scale**2
        return f, extra, jnp.zeros(())


@dataclasses.dataclass(frozen=True)
class CompressedPsumTransport:
    """Lossy-compressed wire fusion: the device sum itself runs as the
    two-phase int8/int4 ``compressed_psum`` collective over ``axis``
    (DESIGN.md §2) — wire bytes drop 4x/8x versus a bf16 ring all-reduce,
    visible as s8/u8 collective operands in the lowered HLO."""

    axis: str = "data"
    bits: int = 8
    block: int = 512

    @property
    def qc(self) -> QuantConfig:
        return QuantConfig(bits=self.bits, block=self.block)

    def fuse(self, f_p, delta, drop):
        f_loc, _, _ = _drop_rescale(jnp.sum(f_p, axis=0), drop, self.axis)
        # quantization happens after the rescale, so compressed_psum's
        # realized-scale noise measurement already includes its effect
        f, noise = compressed_psum(f_loc, self.axis, self.qc)
        # each device computed the noise from its own send-side scales;
        # pmean makes the reported accounting a well-defined replicated value
        return f, lax.pmean(noise, self.axis), jnp.zeros(())


# ---------------------------------------------------------------------------
# rate controllers
# ---------------------------------------------------------------------------

@runtime_checkable
class RateController(Protocol):
    """Chooses the quantizer bin size for iteration ``t``.

    ``delta_for`` must be pure jnp; it receives the traced iteration index
    and the post-LC plug-in estimate sigma_hat_{t,D}^2 and returns
    ``(delta, rate_bits)`` (rate = +inf when the controller does not track
    a coding rate, e.g. fixed schedules whose H_Q is computed offline).
    """

    n_iter: int

    def delta_for(self, t, sigma2_hat): ...  # pragma: no cover - protocol


class FixedSchedule:
    """Predetermined per-iteration bin sizes (np.inf = lossless)."""

    def __init__(self, deltas):
        self.deltas = np.asarray(deltas, np.float32)
        self.n_iter = len(self.deltas)

    def delta_for(self, t, sigma2_hat):
        return jnp.asarray(self.deltas)[t], jnp.float32(jnp.inf)


class DPSchedule(FixedSchedule):
    """Offline-optimal DP allocation realized as ECSQ bin sizes.

    Converts a ``dp_allocate`` result to the bin sizes hitting the DP's
    predicted per-iteration distortions (paper's "+0.255 bits" ECSQ
    implementation; mirrors benchmarks/paper_repro.py).
    """

    def __init__(self, dp_result, rd: RDModel, n_proc: int):
        sq2 = np.maximum(
            rd.distortion_msg(dp_result.rates, dp_result.sigma2_d[:-1],
                              n_proc), 1e-30)
        super().__init__(np.sqrt(12.0 * sq2))
        self.rates = np.asarray(dp_result.rates)
        self.sigma2_d = np.asarray(dp_result.sigma2_d)


class BTTables(NamedTuple):
    """The in-graph BT controller's state as a pure array pytree.

    Everything ``bt_delta_for`` needs — MMSE interpolation table, SE
    targets, rate table, r_max cap curve, and the scalar problem
    parameters (sigma_e2, kappa, prior, P) — lives here as jnp arrays, so
    per-request controllers can be *stacked* (leading batch axis via
    ``stack_bt_tables``) and ride through ``vmap`` as ordinary operands.
    This is how one compiled heterogeneous-batch solve serves requests
    with different SNR / sparsity / rate budgets simultaneously.
    """

    log_v: jnp.ndarray        # (400,) MMSE interp grid, log variance
    log_m: jnp.ndarray        # (400,) log mmse values
    targets: jnp.ndarray      # (T,) c_ratio * sigma_{t+1,C}^2
    log_s2_grid: jnp.ndarray  # (n_s2,) rate-table axis 0
    log2u_grid: jnp.ndarray   # (n_u,) rate-table axis 1
    gap_tab: jnp.ndarray      # (n_s2, n_u) G = R + log2(u)
    cap_ls2: jnp.ndarray      # (512,) cap curve axis
    cap_lsq2: jnp.ndarray     # (512,) log sigma_Q^2 at r_max
    sigma_e2: jnp.ndarray     # () problem scalars -------------------
    inv_kappa: jnp.ndarray    # ()
    n_proc: jnp.ndarray       # () float
    eps: jnp.ndarray          # () prior
    mu_s: jnp.ndarray         # ()
    sigma_s2: jnp.ndarray     # ()
    r_max: jnp.ndarray        # () delivered-rate cap (erasure-adjusted)
    amp: jnp.ndarray          # () erasure survivor-rescale amplification
                              #    E[P/max(k,1)]; exactly 1.0 when lossless

    _dummies = {}  # class-level memo for dummy tables (not a field)

    @classmethod
    def dummy(cls, n_iter: int, n_s2: int = 25, n_u: int = 61) -> "BTTables":
        """Benign finite tables for non-BT instances inside a mixed batch.

        When any instance of the batch uses BT, ``bt_delta_for`` is
        evaluated for *every* instance (its output is discarded through
        ``jnp.where`` for fixed-schedule requests), so the tables must
        produce finite values — the actual numbers are irrelevant.
        Memoized: the serving hot path requests one per bucket dispatch.
        """
        key = (n_iter, n_s2, n_u)
        if key in cls._dummies:
            return cls._dummies[key]
        f = lambda v: jnp.asarray(v, jnp.float32)
        lin = np.linspace(-20.0, 7.0, 400).astype(np.float32)
        tb = cls(
            log_v=jnp.asarray(lin), log_m=jnp.asarray(lin),
            targets=jnp.ones(n_iter, jnp.float32),
            log_s2_grid=jnp.asarray(np.linspace(-20.0, 2.0, n_s2),
                                    jnp.float32),
            log2u_grid=jnp.asarray(np.linspace(-12.0, 5.0, n_u), jnp.float32),
            gap_tab=jnp.ones((n_s2, n_u), jnp.float32),
            cap_ls2=jnp.asarray(np.linspace(-20.0, 2.0, 512), jnp.float32),
            cap_lsq2=jnp.zeros(512, jnp.float32),
            sigma_e2=f(1e-3), inv_kappa=f(1.0), n_proc=f(1.0),
            eps=f(0.1), mu_s=f(0.0), sigma_s2=f(1.0), r_max=f(6.0),
            amp=f(1.0),
        )
        cls._dummies[key] = tb
        return tb


def _bt_mmse(tb: BTTables, v):
    lv = jnp.clip(jnp.log(jnp.maximum(v, 1e-30)), tb.log_v[0], tb.log_v[-1])
    return jnp.exp(jnp.interp(lv, tb.log_v, tb.log_m))


def _bt_predict_next(tb: BTTables, sigma2_d, sigma_q2):
    # tb.amp is exactly 1.0 on a lossless link, so the multiply is a
    # bit-exact no-op there (IEEE: 1.0 * x == x)
    eff = tb.amp * (sigma2_d + tb.n_proc * sigma_q2)
    return tb.sigma_e2 + _bt_mmse(tb, eff) * tb.inv_kappa


def _bt_msg_sd(tb: BTTables, sigma2_hat):
    """sqrt(Var F^p) for the message mixture, closed form, in-graph."""
    p = tb.n_proc
    w1, mu1 = tb.eps, tb.mu_s / p
    var1 = (tb.sigma_s2 + p * sigma2_hat) / p**2
    var0 = sigma2_hat / p
    mean = w1 * mu1
    var = (w1 * (var1 + (mu1 - mean) ** 2)
           + (1.0 - w1) * (var0 + mean**2))
    return jnp.sqrt(var)


def _bt_rate_lookup(tb: BTTables, sigma2_hat, sigma_q2):
    """R(s2, sigma_q2) = bilinear G(log s2, log2 u) - log2 u."""
    delta = jnp.sqrt(12.0 * jnp.maximum(sigma_q2, 1e-30))
    lu = jnp.log2(delta / _bt_msg_sd(tb, sigma2_hat))
    ls = jnp.log(sigma2_hat)
    gi, gj = tb.log_s2_grid, tb.log2u_grid
    i = jnp.clip(jnp.searchsorted(gi, ls) - 1, 0, gi.shape[0] - 2)
    j = jnp.clip(jnp.searchsorted(gj, lu) - 1, 0, gj.shape[0] - 2)
    wi = jnp.clip((ls - gi[i]) / (gi[i + 1] - gi[i]), 0.0, 1.0)
    wj = jnp.clip((lu - gj[j]) / (gj[j + 1] - gj[j]), 0.0, 1.0)
    t00 = tb.gap_tab[i, j]
    t01 = tb.gap_tab[i, j + 1]
    t10 = tb.gap_tab[i + 1, j]
    t11 = tb.gap_tab[i + 1, j + 1]
    gap = ((1 - wi) * ((1 - wj) * t00 + wj * t01)
           + wi * ((1 - wj) * t10 + wj * t11))
    return gap - jnp.clip(lu, gj[0], gj[-1])


def _bt_cap_sq2(tb: BTTables, sigma2_hat):
    """sigma_Q^2 achieving rate r_max (dedicated dense 1D curve)."""
    ls = jnp.clip(jnp.log(sigma2_hat), tb.cap_ls2[0], tb.cap_ls2[-1])
    return jnp.exp(jnp.interp(ls, tb.cap_ls2, tb.cap_lsq2))


def bt_delta_for(tb: BTTables, t, sigma2_hat):
    """One in-graph BT decision: (tables, t, sigma2_hat) -> (delta, rate).

    Pure jnp over the ``BTTables`` pytree — the function ``vmap``s over a
    stacked-tables batch axis, which is what lets a heterogeneous batch mix
    per-request BT controllers inside one compiled solve.
    """
    sigma2_hat = jnp.maximum(sigma2_hat, 1e-30)
    target = tb.targets[t]
    base = _bt_predict_next(tb, sigma2_hat, 0.0)

    # bracket growth (host: hi *= 4 while predicted < target, cap 1e6)
    def grow(_, hi):
        ok = (_bt_predict_next(tb, sigma2_hat, hi) < target) & (hi <= 1e6)
        return jnp.where(ok, hi * 4.0, hi)

    hi0 = sigma2_hat / tb.n_proc + 1e-12
    hi = jax.lax.fori_loop(0, 30, grow, hi0)

    # 80-step bisection for the largest admissible sigma_Q^2
    def bisect(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        ok = _bt_predict_next(tb, sigma2_hat, mid) <= target
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    lo, _ = jax.lax.fori_loop(0, 80, bisect, (jnp.zeros_like(hi), hi))
    rate_bis = _bt_rate_lookup(tb, sigma2_hat, lo)

    sq2_cap = _bt_cap_sq2(tb, sigma2_hat)
    use_cap = (base >= target) | (rate_bis > tb.r_max)
    sq2 = jnp.where(use_cap, sq2_cap, lo)
    rate = jnp.where(use_cap, tb.r_max, rate_bis)
    return jnp.sqrt(12.0 * sq2), rate


def stack_bt_tables(tables: "list[BTTables]") -> BTTables:
    """Stack per-request tables into one leading-batch-axis pytree.

    All entries must share ``targets`` length (pad with ``pad_bt_tables``)
    and grid sizes (the constructor defaults). When every entry is the
    same object (the all-dummy / all-same-operating-point fast path) the
    batch axis is a zero-copy broadcast; otherwise the leaves are stacked
    in numpy (one host pass instead of 15*B device ops).
    """
    b = len(tables)
    if all(t is tables[0] for t in tables):
        return jax.tree.map(
            lambda x: np.broadcast_to(np.asarray(x), (b,) + x.shape),
            tables[0])
    return jax.tree.map(lambda *xs: np.stack([np.asarray(x) for x in xs]),
                        *tables)


def pad_bt_tables(tb: BTTables, n_iter: int) -> BTTables:
    """Extend the SE target vector to ``n_iter`` (bucket T_max) by repeating
    the steady-state target; iterations past the request's t_active are
    masked out in the scan, so the padding values are never acted on."""
    cur = tb.targets.shape[0]
    if cur >= n_iter:
        return tb._replace(targets=tb.targets[:n_iter])
    pad = jnp.broadcast_to(tb.targets[-1], (n_iter - cur,))
    return tb._replace(targets=jnp.concatenate([tb.targets, pad]))


class BTRateControl:
    """In-graph BT back-tracking (paper Sec. 3.3), scan/jit/vmap-safe.

    Re-expresses ``rate_alloc.BTController`` as fixed-count jittable loops:

      * the MMSE SE map is a log-log interpolation table (same 400-point
        grid as ``make_mmse_interp``),
      * the bracket-growth ``while`` and the 80-step bisection for the
        largest admissible sigma_Q^2 become ``lax.fori_loop``s,
      * the rate model (ECSQ entropy or RD function) is a bilinear table
        over (log sigma_t^2, log2 u), u = Delta/sd(F^p), built from the
        same ``rate_alloc`` helpers the host controller calls, with a
        fixed-count bisection for the r_max cap inversion.

    Tables are built once at construction (host side) into a ``BTTables``
    pytree (``self.tables``); the per-iteration decision then runs entirely
    inside the solver scan via the pure ``bt_delta_for``.
    """

    def __init__(self, prob: CSProblem, n_proc: int, n_iter: int,
                 c_ratio: float = 1.05, r_max: float = 6.0,
                 rate_model: str = "ecsq", rd: RDModel | None = None,
                 mmse_fn=None, n_s2_grid: int = 25, n_u_grid: int = 61,
                 erasure_rate: float = 0.0, recovery: str = "retransmit"):
        host = BTController(prob, n_proc, n_iter, c_ratio, r_max,
                            rate_model, rd, mmse_fn,
                            erasure_rate=erasure_rate, recovery=recovery)
        self.host = host
        self.prob = prob
        self.n_proc = n_proc
        self.n_iter = n_iter
        self.c_ratio = c_ratio
        self.r_max = r_max
        self.erasure_rate = erasure_rate
        self.recovery = recovery
        # delivered-rate cap under the recovery policy (== r_max when
        # lossless); the in-graph tables work in delivered-rate space and
        # the serving layer applies host._wire_f for wire accounting
        eff_r_max = host._r_cap

        # (1) MMSE interp table — same grid as make_mmse_interp, evaluated
        # through the host controller's own mmse_fn so both agree.
        grid_v = np.geomspace(1e-9, 1e3, 400)
        grid_m = np.maximum(np.asarray(host.mmse_fn(grid_v), np.float64),
                            1e-300)
        log_v = jnp.asarray(np.log(grid_v), jnp.float32)
        log_m = jnp.asarray(np.log(grid_m), jnp.float32)

        # (2) per-iteration targets c * sigma_{t+1,C}^2
        targets = jnp.asarray(c_ratio * host.sigma2_c[1:], jnp.float32)

        # (3) rate table R(log s2, log2 u), u = Delta / sd(F^p | s2)
        s2_lo = max(prob.sigma_e2 * 1e-2, 1e-9)
        s2_hi = prob.sigma0_2 * 8.0
        s2_grid = np.geomspace(s2_lo, s2_hi, n_s2_grid)
        log2u_grid = np.linspace(-12.0, 5.0, n_u_grid)
        tab = np.empty((n_s2_grid, n_u_grid))
        sds = np.empty(n_s2_grid)
        for i, s2 in enumerate(s2_grid):
            sds[i] = math.sqrt(message_mixture(prob.prior, float(s2),
                                               n_proc).variance)
            for j, lu in enumerate(log2u_grid):
                delta = sds[i] * 2.0**lu
                tab[i, j] = rate_for_sigma_q2(delta**2 / 12.0, float(s2),
                                              prob, n_proc, host.rate_model,
                                              host.rd)
        log_s2_grid = jnp.asarray(np.log(s2_grid), jnp.float32)
        log2u_grid_j = jnp.asarray(log2u_grid, jnp.float32)
        # store the excess over the high-rate line, G = R + log2(u): G is
        # nearly flat where the quantizer is fine (R ~ h - log2 Delta), so
        # bilinear interpolation of G is far more accurate than of R itself
        gap_tab = jnp.asarray(tab + log2u_grid[None, :], jnp.float32)

        # (4) dedicated 1D cap curve sigma_Q^2(r_max; s2): per-row inversion
        # of the table (G is ~flat in u, so in-row accuracy ~ the host
        # inverter's own tolerance), cubic-resampled along log s2 — the
        # r_max-binding branch is where BT spends most iterations, so it
        # gets its own high-accuracy path instead of the bilinear lookup.
        from scipy.interpolate import CubicSpline
        cap_lsq2 = np.empty(n_s2_grid)
        for i in range(n_s2_grid):
            g_row = CubicSpline(log2u_grid, tab[i] + log2u_grid)
            lo, hi = log2u_grid[0], log2u_grid[-1]
            for _ in range(60):
                mid = 0.5 * (lo + hi)
                if g_row(mid) - mid > eff_r_max:
                    lo = mid
                else:
                    hi = mid
            lu_star = 0.5 * (lo + hi)
            cap_lsq2[i] = (2.0 * math.log(sds[i] * 2.0**lu_star)
                           - math.log(12.0))
        dense_ls2 = np.linspace(math.log(s2_grid[0]), math.log(s2_grid[-1]),
                                512)
        cap_dense = CubicSpline(np.log(s2_grid), cap_lsq2)(dense_ls2)

        f32 = lambda v: jnp.asarray(v, jnp.float32)
        self.tables = BTTables(
            log_v=log_v, log_m=log_m, targets=targets,
            log_s2_grid=log_s2_grid, log2u_grid=log2u_grid_j,
            gap_tab=gap_tab,
            cap_ls2=jnp.asarray(dense_ls2, jnp.float32),
            cap_lsq2=jnp.asarray(cap_dense, jnp.float32),
            sigma_e2=f32(prob.sigma_e2), inv_kappa=f32(1.0 / prob.kappa),
            n_proc=f32(float(n_proc)), eps=f32(prob.prior.eps),
            mu_s=f32(prob.prior.mu_s), sigma_s2=f32(prob.prior.sigma_s**2),
            r_max=f32(eff_r_max), amp=f32(host._amp),
        )

    def delta_for(self, t, sigma2_hat):
        return bt_delta_for(self.tables, t, sigma2_hat)


# ---------------------------------------------------------------------------
# column-layout rate control (C-MP-AMP, DESIGN.md §7)
# ---------------------------------------------------------------------------

class ColBTTables(NamedTuple):
    """In-graph state of the column-layout BT controller (pure pytree,
    stackable/vmappable exactly like ``BTTables``).

    The quantized payload is the residual contribution r^p = A_p x_p whose
    entries are ~ N(0, v_r) (``quantize.residual_mixture``), so the rate
    model collapses to a *one-dimensional* table: H_Q of a unit Gaussian
    as a function of the normalized bin u = Delta / sd(r^p).
    """

    log_v: jnp.ndarray        # (400,) MMSE interp grid, log variance
    log_m: jnp.ndarray        # (400,) log mmse values
    targets: jnp.ndarray      # (S,) c_ratio * tau_C^{s} (lossless column SE)
    log2u_grid: jnp.ndarray   # (n_u,) rate-table axis
    hq_tab: jnp.ndarray       # (n_u,) H_Q(u) of the unit Gaussian
    u_cap: jnp.ndarray        # () log2 u achieving the delivered-rate cap
    sigma_e2: jnp.ndarray     # () problem scalars -------------------
    inv_kappa: jnp.ndarray    # ()
    n_proc: jnp.ndarray       # () float
    eps: jnp.ndarray          # () prior
    mu_s: jnp.ndarray         # ()
    sigma_s2: jnp.ndarray     # ()
    r_max: jnp.ndarray        # () delivered-rate cap (erasure-adjusted)
    surv: jnp.ndarray         # () survival probability 1 - erasure_rate;
                              #    exactly 1.0 on a lossless link

    _dummies = {}  # class-level memo for dummy tables (not a field)

    @classmethod
    def dummy(cls, n_iter: int, n_u: int = 256) -> "ColBTTables":
        """Benign finite tables for non-BT instances of a mixed column
        bucket (same contract as ``BTTables.dummy``)."""
        key = (n_iter, n_u)
        if key in cls._dummies:
            return cls._dummies[key]
        f = lambda v: jnp.asarray(v, jnp.float32)
        lin = np.linspace(-20.0, 7.0, 400).astype(np.float32)
        tb = cls(
            log_v=jnp.asarray(lin), log_m=jnp.asarray(lin),
            targets=jnp.ones(n_iter, jnp.float32),
            log2u_grid=jnp.asarray(np.linspace(-12.0, 5.0, n_u), jnp.float32),
            hq_tab=jnp.ones(n_u, jnp.float32),
            u_cap=f(0.0), sigma_e2=f(1e-3), inv_kappa=f(1.0), n_proc=f(1.0),
            eps=f(0.1), mu_s=f(0.0), sigma_s2=f(1.0), r_max=f(6.0),
            surv=f(1.0),
        )
        cls._dummies[key] = tb
        return tb


def col_bt_delta_for(tb: ColBTTables, t, v_prev):
    """One in-graph column-BT decision: (tables, round, v̂_{s-1}) -> (delta,
    rate).  Pure jnp over the pytree, vmappable over stacked tables.

    The rule mirrors the row-wise BT (paper Sec. 3.3) through the column
    SE: from the previous round's fused-residual plug-in v̂ the predicted
    block MSE is d = mmse(v̂); pick the largest admissible quantizer MSE
    such that the predicted variance of this round's fused residual,

        sigma_e^2 + d / kappa  +  P * sigma_Q^2,

    stays within the target c * tau_C^{s}.  Quantization noise enters
    *additively outside* the mmse map here (it lands on g itself), so the
    admissible sigma_Q^2 is closed-form — no bisection.  The r_max cap
    inverts the 1-D Gaussian H_Q table.  Round 0 is lossless for free
    (the exchanged contributions are identically zero): delta = inf,
    rate = 0.
    """
    v_prev = jnp.maximum(v_prev, 1e-30)
    d = _bt_mmse(tb, v_prev)
    sm = tb.eps * (tb.mu_s**2 + tb.sigma_s2)
    v_r = jnp.maximum(sm - d, 1e-30) * tb.inv_kappa / tb.n_proc
    sd_r = jnp.sqrt(v_r)

    # erasure reset semantics (tb.surv == 1.0 is a bit-exact no-op): an
    # erased contribution leaves its block at x = 0, so the expected block
    # MSE entering the round is surv*d + (1-surv)*E[S0^2], and only the
    # surviving fraction injects quantization noise onto g
    d_in = tb.surv * d + (1.0 - tb.surv) * sm
    base = tb.sigma_e2 + d_in * tb.inv_kappa
    target = tb.targets[t]
    sq2_adm = jnp.maximum(target - base, 0.0) / (tb.n_proc * tb.surv)
    sq2_cap = (jnp.exp2(tb.u_cap) * sd_r) ** 2 / 12.0
    # the cap binds when the admissible bin is finer than r_max affords
    sq2 = jnp.minimum(jnp.maximum(sq2_adm, sq2_cap), v_r)
    lu = 0.5 * jnp.log2(12.0 * sq2 / v_r)
    lu_c = jnp.clip(lu, tb.log2u_grid[0], tb.log2u_grid[-1])
    rate = jnp.minimum(jnp.interp(lu_c, tb.log2u_grid, tb.hq_tab), tb.r_max)
    first = t == 0
    delta = jnp.where(first, jnp.float32(jnp.inf), jnp.sqrt(12.0 * sq2))
    return delta, jnp.where(first, 0.0, rate)


class ColumnBTRateControl:
    """In-graph BT back-tracking for the column layout, scan/jit/vmap-safe.

    Tables are built once at construction: the MMSE interp grid (same
    400-point log-log grid as ``BTRateControl``), per-round targets from
    the lossless column-wise SE reference (``se_trajectory_col``), and the
    1-D unit-Gaussian ECSQ entropy table H_Q(u) with its r_max inversion.
    Supports ``n_inner == 1`` (the serving default), where the measured
    plug-in v̂_{s-1} determines the predicted block MSE exactly; multi-
    inner-round schedules use offline allocation (``dp_allocate_col``)
    instead.
    """

    def __init__(self, prob: CSProblem, n_proc: int, n_iter: int,
                 c_ratio: float = 1.05, r_max: float = 6.0,
                 n_inner: int = 1, mmse_fn=None, n_u_grid: int = 256,
                 erasure_rate: float = 0.0, recovery: str = "retransmit"):
        assert n_inner == 1, \
            "in-graph column BT tracks the measured plug-in, which pins " \
            "the block MSE only at n_inner=1; use dp_allocate_col for " \
            "multi-inner-round rate schedules"
        from .denoisers import make_mmse_interp
        from .rate_alloc import erasure_rate_factors
        self.prob = prob
        self.n_proc = n_proc
        self.n_iter = n_iter
        self.n_inner = n_inner
        self.c_ratio = c_ratio
        self.r_max = r_max
        self.erasure_rate = erasure_rate
        self.recovery = recovery
        self.mmse_fn = mmse_fn or make_mmse_interp(prob.prior)
        budget_f, boost, wire_f = erasure_rate_factors(erasure_rate, recovery)
        self._wire_f = wire_f
        # delivered-rate cap under the recovery policy (== r_max lossless)
        eff_r_max = r_max * budget_f * boost

        grid_v = np.geomspace(1e-9, 1e3, 400)
        grid_m = np.maximum(np.asarray(self.mmse_fn(grid_v), np.float64),
                            1e-300)

        tau_c, _ = se_trajectory_col(prob, n_proc, n_iter, n_inner,
                                     mmse_fn=self.mmse_fn,
                                     erasure_rate=erasure_rate)
        targets = np.asarray(c_ratio * tau_c, np.float32)

        log2u_grid = np.linspace(-12.0, 5.0, n_u_grid)
        unit = GaussMixture(w=(1.0,), mu=(0.0,), var=(1.0,))
        hq = ecsq_entropy(2.0 ** log2u_grid, unit)
        # H_Q(u) is strictly decreasing: invert for the cap-rate bin
        u_cap = float(np.interp(eff_r_max, hq[::-1], log2u_grid[::-1]))

        f32 = lambda v: jnp.asarray(v, jnp.float32)
        self.tables = ColBTTables(
            log_v=f32(np.log(grid_v)), log_m=f32(np.log(grid_m)),
            targets=jnp.asarray(targets),
            log2u_grid=f32(log2u_grid), hq_tab=f32(hq), u_cap=f32(u_cap),
            sigma_e2=f32(prob.sigma_e2), inv_kappa=f32(1.0 / prob.kappa),
            n_proc=f32(float(n_proc)), eps=f32(prob.prior.eps),
            mu_s=f32(prob.prior.mu_s), sigma_s2=f32(prob.prior.sigma_s**2),
            r_max=f32(eff_r_max), surv=f32(1.0 - erasure_rate),
        )

    def delta_for(self, t, v_prev):
        return col_bt_delta_for(self.tables, t, v_prev)


class ColDPSchedule(FixedSchedule):
    """``dp_allocate_col`` result realized as per-round ECSQ bin sizes for
    the column layout (the column counterpart of ``DPSchedule``)."""

    def __init__(self, dp_result, prob: CSProblem, n_proc: int,
                 ecsq_gap: bool = True):
        from .rate_alloc import col_sigma_q2_for_rate
        sq2 = np.atleast_1d(col_sigma_q2_for_rate(
            dp_result.rates[1:], dp_result.sigma2_d[1:-1], prob, n_proc,
            ecsq_gap))
        super().__init__(np.concatenate([[np.inf], np.sqrt(12.0 * sq2)]))
        self.rates = np.asarray(dp_result.rates)
        self.d_traj = np.asarray(dp_result.sigma2_d)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_proc: int = 30
    n_iter: int = 10                  # iterations (row) / outer rounds (col)
    use_kernel: bool | None = None    # None = Pallas on TPU, jnp elsewhere
    kernel_interpret: bool = False    # Pallas interpret mode (CPU parity/CI)
    collect_symbols: bool = True      # trace quantizer indices (T, P, N|M)
    collect_xs: bool = True           # trace per-iteration estimates (T, N)
    layout: RowPartition | ColumnPartition = RowPartition()
    a_dtype: str = "float32"          # A storage/streaming dtype (DESIGN §8):
                                      # "bfloat16" halves HBM traffic on the
                                      # dominant operand, accumulation stays
                                      # f32 (MXU preferred_element_type)
    donate: bool = False              # donate batch operands (a_b, y_b) into
                                      # the het programs so large buckets stop
                                      # double-buffering HBM (DESIGN §9). Only
                                      # safe when callers pass temporaries —
                                      # the serving layer stacks a fresh batch
                                      # per flush, so it opts in; cached /
                                      # long-lived buffers must stay out of
                                      # donating programs.

    @property
    def is_col(self) -> bool:
        return isinstance(self.layout, ColumnPartition)

    @property
    def a_jdtype(self):
        assert self.a_dtype in ("float32", "bfloat16"), self.a_dtype
        return jnp.bfloat16 if self.a_dtype == "bfloat16" else jnp.float32

    @property
    def kernel_on(self) -> bool:
        """Whether the LC step routes through the Pallas kernel suite
        (compiled on TPU, interpret mode anywhere when asked)."""
        if self.use_kernel is None:
            return jax.default_backend() == "tpu"
        return self.use_kernel


class HetParams(NamedTuple):
    """Per-instance operands of a heterogeneous batch (``solve_het``).

    Every field carries a leading batch axis B when passed to ``solve_het``
    (shapes below are per-instance). Together with the per-instance sensing
    shards, these are the quantities the serving layer varies *inside* one
    compiled solve; everything structural (padded M/N, P, T_max, transport)
    is part of the bucket key instead.
    """

    sched: jnp.ndarray     # (T,) fixed/DP bin sizes (inf = lossless)
    t_active: jnp.ndarray  # () int32: iterations to run (masked early-exit)
    m_real: jnp.ndarray    # () f32: true measurement count (sigma2_hat norm)
    n_real: jnp.ndarray    # () int32: true signal length (column mask)
    eps: jnp.ndarray       # () f32 prior sparsity
    mu_s: jnp.ndarray      # () f32 prior mean
    sigma_s: jnp.ndarray   # () f32 prior std
    use_bt: jnp.ndarray    # () bool: BT controller vs fixed schedule
    bt: BTTables           # stacked in-graph BT tables (dummy when !use_bt)
    drop: jnp.ndarray | None = None
                           # (T, P) erasure mask, 1 = fusion packet lost
                           # (sharded placement: (T, n_dev), replicated).
                           # None is an *empty pytree node*, so drop-free
                           # batches keep the pre-erasure operand avals
                           # and programs byte-identical.


@dataclasses.dataclass
class EngineTrace:
    """Per-iteration record of one engine solve (arrays are numpy on exit)."""

    x: np.ndarray                 # final estimate (N,) / (B, N)
    sigma2_hat: np.ndarray        # plug-in sigma_{t,D}^2, post-LC (T,)
    deltas: np.ndarray            # realized bin sizes (T,)
    extra_var: np.ndarray         # transport-injected variance P*sigma_Q^2 (T,)
    rates: np.ndarray             # controller-chosen rate (T,), inf = untracked
    symbols: np.ndarray | None    # quantizer indices (T, P, N)
    xs: np.ndarray | None         # per-iteration estimates (T, N)

    def mse(self, s0: np.ndarray) -> np.ndarray:
        """Per-iteration MSE against ground truth (batched-aware)."""
        assert self.xs is not None, "solve with collect_xs=True"
        return np.mean((self.xs - np.asarray(s0)[..., None, :]) ** 2, axis=-1)


class AmpEngine:
    """One scan-compiled MP-AMP solver core with pluggable transports and
    in-graph rate control. See module docstring."""

    def __init__(self, prior: BernoulliGauss, cfg: EngineConfig,
                 transport: Transport | None = None,
                 controller=None):
        self.prior = prior
        self.cfg = cfg
        self.transport = transport if transport is not None else ExactFusion()
        if controller is None:
            controller = FixedSchedule(np.full(cfg.n_iter, np.inf))
        self.controller = controller
        self._jit_cache: dict = {}
        # program-builder cache lock: builders nest (solve_many's vmap
        # build calls _scan_fn), hence re-entrant. Background prewarm and
        # foreground flush() race these dicts otherwise — see _cached.
        self._build_lock = threading.RLock()
        # AOT executable cache (DESIGN §9): (program key, operand-aval key)
        # -> jax Compiled. Owning the cache (instead of leaning on jit's
        # internal one) makes compiles *observable* — ``compile_count`` is
        # the serving layer's zero-steady-state-recompile invariant — and
        # lets ``prewarm``/``compile_het`` populate it ahead of traffic.
        self._exec_cache: dict = {}
        self._exec_lock = threading.Lock()
        self.compile_count = 0
        # executed dispatches (compile_only excluded): the per-engine load
        # signal the cluster router's imbalance accounting reads. Guarded
        # by _exec_lock together with compile_count so ``counters()`` can
        # hand out a consistent (compiles, dispatches) pair even while a
        # background prewarm thread is mid-compile.
        self.dispatch_count = 0

    # -- AOT executable cache (DESIGN §9) ------------------------------------

    @staticmethod
    def _exec_key(args) -> tuple:
        """Aval fingerprint of a concrete operand pytree: (shape, dtype,
        weak_type, sharding token) per leaf. numpy operands and default
        single-device jax arrays share the ``None`` sharding token — a
        program compiled from numpy dummies at prewarm serves jnp runtime
        operands of the same avals; explicitly sharded operands (the
        data-parallel placement) key on ``str(sharding)``."""
        toks = []
        for x in jax.tree_util.tree_leaves(args):
            sh = getattr(x, "sharding", None)
            tok = None if sh is None or isinstance(sh, SingleDeviceSharding) \
                else str(sh)
            dt = getattr(x, "dtype", None)
            toks.append((tuple(np.shape(x)),
                         str(dt) if dt is not None else str(np.result_type(x)),
                         bool(getattr(x, "weak_type", False)), tok))
        return tuple(toks)

    def _run(self, base_key, fn, args, compile_only: bool = False):
        """Execute ``fn(*args)`` through the AOT cache: first sight of a
        (program, avals) pair pays ``lower().compile()`` exactly once (and
        bumps ``compile_count``); every later call reuses the Compiled.
        ``compile_only`` returns the executable without running it — the
        prewarm path. Thread-safe: background prewarm and foreground
        dispatch serialize on the compile lock, never duplicate work."""
        key = (base_key, self._exec_key(args))
        ex = self._exec_cache.get(key)
        if ex is None:
            with self._exec_lock:
                ex = self._exec_cache.get(key)
                if ex is None:
                    with warnings.catch_warnings():
                        # donation feasibility is a compile-time XLA note
                        # (e.g. scalar operands can't alias outputs); it
                        # is expected, not actionable
                        warnings.filterwarnings(
                            "ignore", message=".*[Dd]onat.*")
                        ex = fn.lower(*args).compile()
                    self._exec_cache[key] = ex
                    self.compile_count += 1
        if compile_only:
            return ex
        with self._exec_lock:
            self.dispatch_count += 1
        return ex(*args)

    def counters(self) -> dict:
        """Atomic snapshot of the engine's observable counters. Taken
        under the executable-cache lock, so a concurrent compile (e.g. a
        background ``SolveService.prewarm`` thread) can never be observed
        half-way — ``SolveService.stats()`` aggregates through here."""
        with self._exec_lock:
            return {"compiles": self.compile_count,
                    "dispatches": self.dispatch_count}

    def _cached(self, key, build):
        """Double-checked admission into the jit-program cache.

        Every program builder routes here so a background ``prewarm``
        thread and a foreground dispatch can never observe a half-built
        entry, build the same program twice, or drop each other's insert
        (plain ``if key not in dict`` admission loses one of two racing
        builds). The lock is re-entrant because builders nest — the
        vmapped solve builds wrap ``_scan_fn``/``_col_scan_fn``."""
        fn = self._jit_cache.get(key)
        if fn is None:
            with self._build_lock:
                fn = self._jit_cache.get(key)
                if fn is None:
                    fn = build()
                    self._jit_cache[key] = fn
        return fn

    # -- shared iteration body ----------------------------------------------

    def _local(self, x, z_p, onsager, a_p, y_p, m_eff=None, axis=None):
        """LC: the whole processor stack through one batched-grid fused op.

        ``a_p`` may be tile-padded (kernel path; ``pad_row_shards`` at
        solve entry) and/or stored in ``cfg.a_dtype``: the carry ``x``
        stays at the true N, so the body pads only the (N,) message vector
        (never the (M, N) operand) and slices ``f_p`` back — padded rows/
        columns are exactly zero end-to-end, so the fused sum-of-squares
        is the true sigma2_hat numerator. ``m_eff`` overrides the
        normalizer (the heterogeneous path passes the *real* measurement
        count); ``axis`` (sharded mode) makes the plug-in a psum over the
        mesh axis — one kernel launch per device covers its P/D emulated
        processors.
        """
        cfg = self.cfg
        m = a_p.shape[0] * a_p.shape[1] if m_eff is None else m_eff
        n, n_pad = x.shape[0], a_p.shape[2]
        x_in = jnp.pad(x, (0, n_pad - n)) if n_pad != n else x
        z_new, f_p, ss = amp_local_grid(
            a_p, x_in, y_p, z_p, onsager, cfg.n_proc,
            use_pallas=cfg.kernel_on, interpret=cfg.kernel_interpret)
        if n_pad != n:
            f_p = f_p[:, :n]
        if axis is not None:
            ss = lax.psum(ss, axis)
        sigma2_hat = ss / m
        return z_new, f_p, sigma2_hat

    def _fuse(self, f_p, delta, drop=None):
        """Transport dispatch. ``drop`` None compiles the drop-free
        program (emulated transports only — byte-identical to the
        pre-erasure engine); non-None it is the erasure/straggler mask:
        per-device scalar for device-collective transports, per-processor
        (P,) for the emulated ones."""
        if drop is None:
            assert not hasattr(self.transport, "axis"), \
                f"{type(self.transport).__name__} is a device-collective " \
                "transport: solve via solve_sharded/solve_sharded_het, " \
                "not the emulated entry points"
            return self.transport.fuse(f_p, delta)
        return self.transport.fuse(f_p, delta, drop)

    def _gc(self, f_p, sigma2_hat, delta, kappa, drop=None):
        """GC: compress + fuse + denoise. Returns (x, onsager, extra, syms)."""
        f, extra, syms = self._fuse(f_p, delta, drop)
        x_new, onsager_new = amp_gc_step(f, sigma2_hat + extra, self.prior,
                                         kappa)
        return x_new, onsager_new, extra, syms

    def _body(self, carry, xs_t, a_p, y_p, kappa, axis=None, m_eff=None):
        if axis is None:
            # erasure-enabled emulated programs thread a (P,) drop mask as
            # a third scan operand; the 2-tuple form is the drop-free
            # program, byte-identical to the pre-erasure engine
            if len(xs_t) == 3:
                t, sched_delta, drop = xs_t
            else:
                (t, sched_delta), drop = xs_t, None
        else:
            t, sched_delta, drop = xs_t
        x, z_p, onsager = carry
        z_p, f_p, s2 = self._local(x, z_p, onsager, a_p, y_p, m_eff=m_eff,
                                   axis=axis)
        if isinstance(self.controller, FixedSchedule):
            # fixed schedules arrive as a scan operand, so one compiled
            # solve serves every schedule of the same length
            delta, rate = sched_delta, jnp.float32(jnp.inf)
        else:
            delta, rate = self.controller.delta_for(t, s2)
        x_new, onsager_new, extra, syms = self._gc(f_p, s2, delta, kappa,
                                                   drop=drop)
        cfg = self.cfg
        out = (s2, delta, extra, rate,
               x_new if cfg.collect_xs else jnp.zeros(()),
               syms if cfg.collect_symbols else jnp.zeros(()))
        return (x_new, z_p, onsager_new), out

    def _sched_operand(self):
        if isinstance(self.controller, FixedSchedule):
            deltas = self.controller.deltas[:self.cfg.n_iter]
            assert len(deltas) == self.cfg.n_iter, \
                f"schedule has {len(self.controller.deltas)} entries, " \
                f"need {self.cfg.n_iter}"
            return jnp.asarray(deltas, jnp.float32)
        return jnp.zeros(self.cfg.n_iter, jnp.float32)

    # -- column-layout iteration body (C-MP-AMP, DESIGN.md §7) ---------------

    def _check_col_controller(self):
        assert isinstance(self.controller,
                          (FixedSchedule, ColumnBTRateControl)), \
            "the column layout takes a FixedSchedule/ColDPSchedule or a " \
            "ColumnBTRateControl (row-wise controllers predict through " \
            f"the wrong SE), got {type(self.controller).__name__}"

    def _col_gather_x(self, x, axis):
        """Local (P, N/P) signal slices -> the flat (N,) estimate; in
        sharded mode the slices are gathered across the mesh axis first."""
        if axis is not None:
            x = lax.all_gather(x, axis)
        return x.reshape(-1)

    def _col_init(self, p_loc: int, np_: int, y, v0):
        """Initial column scan carry ``(x, mem, coef, v_prev)``.

        ``mem``/``coef`` are the Onsager boundary memory: the previous
        fused residual (M,) + summed coefficients () in fused mode, the
        per-processor residuals (P, M) + own coefficients (P,) in
        per-processor mode (``ColumnPartition`` docstring)."""
        x = jnp.zeros((p_loc, np_), jnp.float32)
        if self.cfg.layout.carry_fused:
            return (x, jnp.zeros_like(y), jnp.zeros(()), v0)
        return (x, jnp.zeros((p_loc,) + y.shape, jnp.float32),
                jnp.zeros(p_loc, jnp.float32), v0)

    def _col_prior_params(self, hp: HetParams | None = None):
        """(eps, mu_s, sigma_s^2) as traced/array scalars for the fused
        column kernels — from ``HetParams`` when given, else the engine's
        static prior."""
        if hp is not None:
            return hp.eps, hp.mu_s, hp.sigma_s**2
        pr = self.prior
        # the fused kernel evaluates the BG conditional mean in closed
        # form in-kernel — it cannot honor an arbitrary denoiser, so make
        # the coupling explicit rather than silently diverging from the
        # eta_fn the jnp path would have used
        assert isinstance(pr, BernoulliGauss), \
            f"column kernel path requires a BernoulliGauss prior, got " \
            f"{type(pr).__name__}; solve with use_kernel=False"
        return (jnp.float32(pr.eps), jnp.float32(pr.mu_s),
                jnp.float32(pr.sigma_s**2))

    def _col_inner_kernels(self, x, g, z_p, a_cp, m_eff, pp, n_mask):
        """Kernel-path counterpart of ``_col_inner``: ``layout.n_inner``
        fused ``col_inner_step`` launches (message + in-kernel denoise +
        residual update; DESIGN.md §8). ``pp`` is ``_col_prior_params``;
        ``n_mask`` a (Np,) real-column mask (all-ones when unpadded)."""
        cfg = self.cfg
        n_inner = cfg.layout.n_inner
        x0 = x
        c_p = None
        for t in range(n_inner):
            x, c_p, z_p = col_inner_step(
                a_cp, x, x0, z_p, g, n_mask, m_eff, *pp,
                update_z=t + 1 < n_inner, use_pallas=cfg.kernel_on,
                interpret=cfg.kernel_interpret)
        return x, c_p, z_p

    def _col_inner(self, x, g, z_p, a_cp, m_eff, eta_fn, n_mask=None):
        """``layout.n_inner`` local AMP iterations at each processor on the
        fused residual ``g`` (C-MP-AMP inner stage).

        Per inner step at processor p (all pure per-processor math):
            sigma_p^2 = ||z_p||^2 / M            (plug-in)
            f_p = x_p + A_p^T z_p
            x_p <- eta(f_p, sigma_p^2)
            z_p <- g - A_p (x_p - x_p^0) + c_p z_p,  c_p = sum(eta') / M

        ``z_p`` is the round's starting residual stack (P, M).  Returns
        ``(x, c_p, z_last)`` with ``z_last`` the residual that *fed* the
        final denoise — the quantity AMP's Onsager term multiplies, which
        is what the per-processor boundary carry needs (the fused boundary
        mode discards it).  ``n_inner`` is static, so the loop unrolls
        into the round's scan body.
        """
        n_inner = self.cfg.layout.n_inner
        x0 = x
        for t in range(n_inner):
            s2_p = jnp.sum(z_p * z_p, axis=-1, keepdims=True) / m_eff
            fn = lambda v, s2=s2_p: eta_fn(v, s2)
            f_p = x + jnp.einsum("pmn,pm->pn", a_cp, z_p)
            if n_mask is None:
                x_new = fn(f_p)
                deriv = jax.grad(lambda v: jnp.sum(fn(v)))(f_p)
            else:
                x_new = fn(f_p) * n_mask
                deriv = jax.grad(lambda v: jnp.sum(fn(v) * n_mask))(f_p)
            c_p = jnp.sum(deriv, axis=-1) / m_eff
            if t + 1 < n_inner:
                z_p = (g[None, :]
                       - jnp.einsum("pmn,pn->pm", a_cp, x_new - x0)
                       + c_p[:, None] * z_p)
            x = x_new
        return x, c_p, z_p

    def _col_round(self, x, mem, coef, delta, a_cp, y, m_eff, eta_fn,
                   n_mask=None, drop=None, axis=None, pp=None):
        """Shared round computation: fuse, apply the boundary Onsager
        memory, run the inner stage.  Returns the new carry pieces plus
        the round's trace quantities ``(v_hat, extra, syms)``.

        On the kernel path (``cfg.kernel_on``) the residual contributions
        and the inner stage run as fused Pallas launches (``col_residual``
        / ``col_inner_step``); ``pp`` carries the prior scalars the
        in-kernel denoiser needs and ``n_mask`` must then be a (Np,)
        real-column mask. M may be tile-padded: padded rows of A/y are
        zero, so every padded entry of r/g/z is exactly zero and the
        transports (0 -> 0) and the v_hat sum are unaffected.
        """
        kern = self.cfg.kernel_on
        er_keep = None
        if drop is not None:
            # Column erasure is a *reset*, not a rescale (DESIGN.md §10):
            # an erased contribution leaves its whole signal block
            # unexplained in the fused residual, so zeroing the block's
            # estimate before forming r_p is the only self-consistent
            # round — r_p vanishes exactly, the inner stage restarts the
            # block from x = 0 against the fused residual, and the next
            # round re-fuses it in full. A survivor rescale would be both
            # biased (the r_p are independent zero-mean blocks, not
            # estimates of r/P) and higher-variance than zeroing. The
            # boundary Onsager coefficient scales with the surviving
            # fraction: an erased block's jump correction never crossed
            # the wire.
            er_keep = 1.0 - drop
            if axis is None:
                x = x * er_keep[:, None]
                coef = (coef * jnp.mean(er_keep)
                        if self.cfg.layout.carry_fused else coef * er_keep)
                # the emulated transports' row-style survivor rescale must
                # not trigger on the already-zeroed contributions
                drop = None
            else:
                x = x * er_keep
                if self.cfg.layout.carry_fused:
                    coef = coef * (lax.psum(er_keep, axis)
                                   / axis_size(axis))
                else:
                    coef = coef * er_keep
                # likewise neutralize the device collectives' rescale
                drop = drop * 0.0
        if kern:
            r_p = col_residual(a_cp, x, use_pallas=True,
                               interpret=self.cfg.kernel_interpret)
        else:
            r_p = jnp.einsum("pmn,pn->pm", a_cp.astype(jnp.float32), x)
        r, extra, syms = self._fuse(r_p, delta, drop)
        if er_keep is not None:
            # only the delivered packets inject quantization noise (an
            # erased processor's zero block quantizes to exactly zero)
            if axis is None:
                extra = extra * (jnp.sum(er_keep) / r_p.shape[0])
            else:
                extra = extra * (lax.psum(er_keep, axis) / axis_size(axis))
        g = y - r
        # boundary Onsager correction sum_q c_q z_q^last (ColumnPartition
        # docstring); scalar * previous-g on the n_inner == 1 fast path
        if self.cfg.layout.carry_fused:
            g = g + coef * mem
        else:
            corr = jnp.einsum("p,pm->m", coef, mem)
            if axis is not None:
                corr = lax.psum(corr, axis)
            g = g + corr
        # g is replicated across shards post-fusion: no psum needed
        v_hat = jnp.sum(g * g) / m_eff
        z0 = jnp.broadcast_to(g, x.shape[:1] + g.shape)
        if kern:
            km = (jnp.ones(a_cp.shape[2], jnp.float32) if n_mask is None
                  else n_mask.reshape(-1))
            x_new, c_p, z_last = self._col_inner_kernels(
                x, g, z0, a_cp, m_eff,
                self._col_prior_params() if pp is None else pp, km)
        else:
            x_new, c_p, z_last = self._col_inner(x, g, z0, a_cp, m_eff,
                                                 eta_fn, n_mask=n_mask)
        if self.cfg.layout.carry_fused:
            coef_new = jnp.sum(c_p)
            if axis is not None:
                coef_new = lax.psum(coef_new, axis)
            mem_new = g
        else:
            mem_new, coef_new = z_last, c_p
        return x_new, mem_new, coef_new, v_hat, extra, syms

    def _col_body(self, carry, xs_t, a_cp, y, m_eff, axis=None):
        """One C-MP-AMP outer round: fuse quantized residual contributions,
        then run the inner stage.

        The scan carry is ``(x, mem, coef, v_prev)``: the per-processor
        signal slices, the Onsager boundary memory (``_col_init``), and
        the previous round's plug-in ``||g||^2/M`` — the column controller
        input (round 0 is lossless for free, so the controller always has
        a measured variance to act on).
        """
        if axis is None:
            if len(xs_t) == 3:
                s, sched_delta, drop = xs_t
            else:
                (s, sched_delta), drop = xs_t, None
        else:
            s, sched_delta, drop = xs_t
        x, mem, coef, v_prev = carry
        if isinstance(self.controller, FixedSchedule):
            delta, rate = sched_delta, jnp.float32(jnp.inf)
        else:
            delta, rate = self.controller.delta_for(s, v_prev)
        prior = self.prior
        x_new, mem_new, coef_new, v_hat, extra, syms = self._col_round(
            x, mem, coef, delta, a_cp, y, m_eff,
            lambda v, s2: eta(v, s2, prior, xp=jnp), drop=drop, axis=axis)
        # round 0 quantizes all-zero contributions exactly: no noise
        # actually enters g, whatever bin the schedule names — keep the
        # trace's accounting truthful
        extra = jnp.where(s == 0, 0.0, extra)
        cfg = self.cfg
        out = (v_hat, delta, extra, rate,
               self._col_gather_x(x_new, axis) if cfg.collect_xs
               else jnp.zeros(()),
               syms if cfg.collect_symbols else jnp.zeros(()))
        return (x_new, mem_new, coef_new, v_hat), out

    # -- compiled entry points ----------------------------------------------

    def _scan_fn(self, m: int, n: int, erasure: bool = False):
        """Build (once per shape) the jitted full-solve scan. ``m``/``n``
        are the *true* problem dims; operands may arrive tile-padded.
        ``erasure`` programs take a (T, P) drop mask as a fourth operand
        (threaded as a third scan input); the drop-free program stays
        byte-identical to the pre-erasure engine."""

        def build():
            cfg, kappa = self.cfg, m / n

            def solve_core(a_p, y_p, sched, drops=None):
                init = (jnp.zeros(n, jnp.float32), jnp.zeros_like(y_p),
                        jnp.zeros(()))
                body = lambda c, xs: self._body(c, xs, a_p, y_p, kappa,
                                                m_eff=jnp.float32(m))
                xs = (jnp.arange(cfg.n_iter), sched)
                if drops is not None:
                    xs = xs + (drops,)
                (x, _, _), outs = jax.lax.scan(body, init, xs)
                return x, outs

            if erasure:
                return jax.jit(lambda a_p, y_p, sched, drops:
                               solve_core(a_p, y_p, sched, drops))
            return jax.jit(solve_core)

        return self._cached(("scan", m, n, erasure), build)

    def _step_fns(self, m: int, n: int):
        """Jitted single-iteration (LC, GC) pair for host-loop mode — the
        same body as the scan, sliced at the LC/GC boundary so an online
        host-side controller can observe sigma_hat_{t,D}^2."""

        def build():
            kappa = m / n
            local = jax.jit(lambda x, z_p, ons, a_p, y_p: self._local(
                x, z_p, ons, a_p, y_p, m_eff=jnp.float32(m)))
            gc = jax.jit(lambda f_p, s2, delta: self._gc(f_p, s2, delta,
                                                         kappa))
            return (local, gc)

        return self._cached(("step", m, n), build)

    def _split(self, y, a_mat):
        """Row-split (A, y); on the kernel path, tile-align once here —
        host-side, so no pad of the (M, N) operand enters the program."""
        a_p, y_p = split_problem(np.asarray(a_mat, np.float32),
                                 np.asarray(y, np.float32), self.cfg.n_proc)
        if self.cfg.kernel_on:
            a_p, y_p = pad_row_shards(a_p, y_p)
        return (jnp.asarray(a_p, self.cfg.a_jdtype), jnp.asarray(y_p))

    def _split_col(self, y, a_mat):
        """Column-split A (shared y); kernel path tile-aligns M here."""
        a_cp = split_problem_cols(np.asarray(a_mat, np.float32),
                                  self.cfg.n_proc)
        y = np.asarray(y, np.float32)
        if self.cfg.kernel_on:
            a_cp, y = pad_col_shards(a_cp, y)
        return jnp.asarray(a_cp, self.cfg.a_jdtype), jnp.asarray(y)

    def _col_scan_fn(self, m: int, n: int, erasure: bool = False):
        """Build (once per shape) the jitted full-solve column scan.
        ``erasure`` as in ``_scan_fn`` (mask shape (T, P); column reset
        semantics — ``_col_round``)."""

        def build():
            cfg = self.cfg
            p = cfg.n_proc

            def solve_core(a_cp, y, sched, drops=None):
                np_ = a_cp.shape[2]
                init = self._col_init(p, np_, y, jnp.sum(y * y) / m)
                body = lambda c, xs: self._col_body(c, xs, a_cp, y,
                                                    jnp.float32(m))
                xs = (jnp.arange(cfg.n_iter), sched)
                if drops is not None:
                    xs = xs + (drops,)
                (x, _, _, _), outs = jax.lax.scan(body, init, xs)
                return x.reshape(-1), outs

            if erasure:
                return jax.jit(lambda a_cp, y, sched, drops:
                               solve_core(a_cp, y, sched, drops))
            return jax.jit(solve_core)

        return self._cached(("col", m, n, erasure), build)

    def _solve_col(self, y, a_mat, drop_sched=None) -> EngineTrace:
        self._check_col_controller()
        m, n = np.shape(a_mat)             # true dims; _split_col may pad M
        a_cp, yj = self._split_col(y, a_mat)
        if drop_sched is None:
            x, outs = self._col_scan_fn(m, n)(a_cp, yj,
                                              self._sched_operand())
        else:
            drop_sched = np.asarray(drop_sched, np.float32)
            assert drop_sched.shape == (self.cfg.n_iter, self.cfg.n_proc), \
                drop_sched.shape
            x, outs = self._col_scan_fn(m, n, erasure=True)(
                a_cp, yj, self._sched_operand(), jnp.asarray(drop_sched))
        return self._trace(x, outs)

    def _solve_many_col(self, ys, a_mats) -> EngineTrace:
        self._check_col_controller()
        ys = np.asarray(ys, np.float32)
        a_mats = np.asarray(a_mats, np.float32)
        shared_a = a_mats.ndim == 2
        b = ys.shape[0]
        p = self.cfg.n_proc
        m, n = a_mats.shape[-2:]
        if shared_a:
            a_b = split_problem_cols(a_mats, p)
        else:
            assert a_mats.shape[0] == b
            a_b = np.stack(
                [split_problem_cols(a_mats[i], p) for i in range(b)])
        if self.cfg.kernel_on:
            a_b, ys = pad_col_shards(a_b, ys)
        a_b = jnp.asarray(a_b, self.cfg.a_jdtype)
        y_b = jnp.asarray(ys)
        def build():
            fn = self._col_scan_fn(m, n)
            in_axes = (None, 0, None) if shared_a else (0, 0, None)
            return jax.jit(jax.vmap(fn, in_axes=in_axes))

        vfn = self._cached(("col_vmap", m, n, shared_a), build)
        x, outs = vfn(a_b, y_b, self._sched_operand())
        return self._trace(x, outs)

    def _trace(self, x, outs) -> EngineTrace:
        cfg = self.cfg
        s2, deltas, extra, rates, xs, syms = outs
        return EngineTrace(
            x=np.asarray(x),
            sigma2_hat=np.asarray(s2),
            deltas=np.asarray(deltas),
            extra_var=np.asarray(extra),
            rates=np.asarray(rates),
            symbols=np.asarray(syms) if cfg.collect_symbols else None,
            xs=np.asarray(xs) if cfg.collect_xs else None,
        )

    def dispatch_single(self, a_p, y_p, m: int, n: int, sched=None,
                        drop_sched=None, compile_only: bool = False):
        """Launch one plain (row-layout, homogeneous) solve from pre-split
        operands, returning raw ``(x, outs)`` — the serving layer's
        singleton fast path: a lone request skips batch padding and
        het-operand assembly entirely and runs the true-dims ``_scan_fn``
        program through the AOT executable cache. ``sched`` overrides the
        engine controller's schedule operand (lossless/fixed/DP deltas ride
        here); ``drop_sched`` a (T, P) erasure mask (``ErasureSpec``),
        routed to the erasure-enabled program variant; ``a_p`` may be a
        long-lived cached device buffer — this path never donates."""
        assert not self.cfg.is_col, \
            "dispatch_single is a row-layout entry point"
        # keep host operands as numpy: the compiled call's shard_args path
        # uploads them cheaper than an eager device_put per operand, and
        # an already-resident cached a_p passes through untouched
        if getattr(a_p, "dtype", None) != self.cfg.a_jdtype:
            a_p = np.asarray(a_p, np.float32) \
                if isinstance(a_p, np.ndarray) and self.cfg.a_dtype == "float32" \
                else jnp.asarray(a_p, self.cfg.a_jdtype)
        y_p = np.asarray(y_p, np.float32)
        if sched is None:
            sched = self._sched_operand()
        sched = np.asarray(sched, np.float32)
        assert sched.shape == (self.cfg.n_iter,), \
            (sched.shape, self.cfg.n_iter)
        erasure = drop_sched is not None
        args = (a_p, y_p, sched)
        if erasure:
            drop_sched = np.asarray(drop_sched, np.float32)
            assert drop_sched.shape == (self.cfg.n_iter, self.cfg.n_proc), \
                drop_sched.shape
            args = args + (drop_sched,)
        return self._run(("scan", m, n, erasure),
                         self._scan_fn(m, n, erasure), args, compile_only)

    def solve(self, y, a_mat, drop_sched=None) -> EngineTrace:
        """Full T-iteration solve as one scan-compiled call (no host sync).

        Under a ``ColumnPartition`` layout this is the full outer-round
        C-MP-AMP solve (``cfg.n_iter`` fusion exchanges).

        ``drop_sched`` (T, P) optionally marks erased fusion packets per
        iteration (sample one with ``ErasureSpec.sample_mask``): the row
        layout rescales the survivors unbiasedly, the column layout resets
        the erased signal blocks (DESIGN.md §10). ``None`` runs the
        pre-erasure program unchanged."""
        if self.cfg.is_col:
            return self._solve_col(y, a_mat, drop_sched)
        m, n = np.shape(a_mat)             # true dims; _split may tile-pad
        a_p, y_p = self._split(y, a_mat)
        return self._trace(*self.dispatch_single(a_p, y_p, m, n,
                                                 drop_sched=drop_sched))

    def solve_many(self, ys, a_mats) -> EngineTrace:
        """vmap-batched solve of B independent CS instances.

        ys (B, M); a_mats (B, M, N) or a single shared (M, N) matrix.
        Symbol collection is typically disabled for batches (memory).
        """
        if self.cfg.is_col:
            return self._solve_many_col(ys, a_mats)
        ys = np.asarray(ys, np.float32)
        a_mats = np.asarray(a_mats, np.float32)
        shared_a = a_mats.ndim == 2
        b = ys.shape[0]
        p = self.cfg.n_proc
        m, n = a_mats.shape[-2:]
        assert m % p == 0, f"M={m} not divisible by P={p}"
        mp_ = m // p
        if shared_a:
            a_b = a_mats.reshape(p, mp_, n)
        else:
            assert a_mats.shape[0] == b
            a_b = a_mats.reshape(b, p, mp_, n)
        y_b = ys.reshape(b, p, mp_)
        if self.cfg.kernel_on:
            a_b, _ = pad_row_shards(a_b, None)
            if a_b.shape[-2] != mp_:
                y_b = np.pad(y_b,
                             ((0, 0), (0, 0), (0, a_b.shape[-2] - mp_)))
        a_b = jnp.asarray(a_b, self.cfg.a_jdtype)
        y_b = jnp.asarray(y_b)

        def build():
            fn = self._scan_fn(m, n)
            in_axes = (None, 0, None) if shared_a else (0, 0, None)
            return jax.jit(jax.vmap(fn, in_axes=in_axes))

        vfn = self._cached(("vmap", m, n, shared_a), build)
        x, outs = vfn(a_b, y_b, self._sched_operand())
        return self._trace(x, outs)

    # -- heterogeneous batches (the serving path) -----------------------------

    def _body_het(self, carry, xs_t, a_p, y_p, hp: HetParams, n_mask,
                  has_bt: bool, axis=None):
        """One masked iteration with per-instance (traced) problem params.

        Same LC/GC split as ``_body``; differences: sigma2_hat normalizes by
        the real M, the denoiser runs with traced prior parameters, the
        Onsager mean covers only real columns, the quantizer bin comes from
        either the per-instance schedule operand or the per-instance BT
        tables, and the carry freezes once ``t >= t_active`` (masked
        early-exit: short requests return their own T-iteration fixpoint
        regardless of the bucket's T_max). ``has_bt`` is static: batches
        with no BT request compile without the in-graph controller.
        ``axis`` runs the body processor-sharded (the same shard_map mode as
        ``_body``; HetParams ride replicated).
        """
        if axis is None:
            if len(xs_t) == 3:
                t, sched_delta, drop = xs_t
            else:
                (t, sched_delta), drop = xs_t, None
        else:
            t, sched_delta, drop = xs_t
        x, z_p, onsager = carry
        z_new, f_p, s2 = self._local(x, z_p, onsager, a_p, y_p,
                                     m_eff=hp.m_real, axis=axis)

        if has_bt:
            bt_delta, bt_rate = bt_delta_for(hp.bt, t, s2)
            delta = jnp.where(hp.use_bt, bt_delta, sched_delta)
            rate = jnp.where(hp.use_bt, bt_rate, jnp.float32(jnp.inf))
        else:
            delta, rate = sched_delta, jnp.float32(jnp.inf)

        f, extra, syms = self._fuse(f_p, delta, drop)
        v = s2 + extra
        eta_fn = lambda g: eta_bg(g, v, hp.eps, hp.mu_s, hp.sigma_s**2)
        x_new = eta_fn(f) * n_mask
        # Onsager: mean(eta') over real columns / kappa == sum(eta'*mask)/M
        deriv = jax.grad(lambda g: jnp.sum(eta_fn(g) * n_mask))(f)
        onsager_new = jnp.sum(deriv) / hp.m_real

        act = t < hp.t_active
        x1 = jnp.where(act, x_new, x)
        z1 = jnp.where(act, z_new, z_p)
        ons1 = jnp.where(act, onsager_new, onsager)
        cfg = self.cfg
        out = (jnp.where(act, s2, 0.0), jnp.where(act, delta, 0.0),
               jnp.where(act, extra, 0.0),
               jnp.where(act, rate, jnp.float32(jnp.inf)),
               x1 if cfg.collect_xs else jnp.zeros(()),
               syms if cfg.collect_symbols else jnp.zeros(()))
        return (x1, z1, ons1), out

    def _scan_fn_het(self, mp_: int, n: int, has_bt: bool,
                     has_er: bool = False):
        """Jitted vmapped heterogeneous-batch solve for one padded shape.

        On the kernel path the bucket-shaped operands are tile-aligned
        *once here* — one pad at solve entry, outside the vmapped scan —
        and ``A`` is cast to ``cfg.a_dtype``. The carry rides at the
        bucket's n, so results keep their bucket shapes. ``has_er``
        (static, derived from ``params.drop is not None``) threads the
        per-instance (T, P) erasure masks as a third scan operand; the
        drop-free program is byte-identical to the pre-erasure engine."""

        def build():
            cfg = self.cfg

            def solve_one(a_p, y_p, hp: HetParams):
                n_mask = (jnp.arange(n) < hp.n_real).astype(jnp.float32)
                init = (jnp.zeros(n, jnp.float32), jnp.zeros_like(y_p),
                        jnp.zeros(()))
                body = lambda c, xs: self._body_het(c, xs, a_p, y_p, hp,
                                                    n_mask, has_bt)
                xs = (jnp.arange(cfg.n_iter), hp.sched)
                if has_er:
                    xs = xs + (hp.drop,)
                (x, _, _), outs = jax.lax.scan(body, init, xs)
                return x, outs

            def solve_batch(a_b, y_b, hp: HetParams):
                if cfg.kernel_on:
                    a_b, y_b = pad_row_shards(a_b, y_b)
                return jax.vmap(solve_one)(a_b.astype(cfg.a_jdtype), y_b,
                                           hp)

            return jax.jit(
                solve_batch, donate_argnums=(0, 1) if cfg.donate else ())

        return self._cached(("het", mp_, n, has_bt, has_er), build)

    def _col_body_het(self, carry, xs_t, a_cp, y, hp: HetParams, n_mask,
                      has_bt: bool, axis=None):
        """One masked C-MP-AMP outer round with per-instance (traced)
        problem params — the column counterpart of ``_body_het``.  Same
        carry as ``_col_body`` plus the ``t_active`` freeze; ``hp.bt``
        holds stacked ``ColBTTables`` for column buckets."""
        if axis is None:
            if len(xs_t) == 3:
                s, sched_delta, drop = xs_t
            else:
                (s, sched_delta), drop = xs_t, None
        else:
            s, sched_delta, drop = xs_t
        x, mem, coef, v_prev = carry
        if has_bt:
            bt_delta, bt_rate = col_bt_delta_for(hp.bt, s, v_prev)
            delta = jnp.where(hp.use_bt, bt_delta, sched_delta)
            rate = jnp.where(hp.use_bt, bt_rate, jnp.float32(jnp.inf))
        else:
            delta, rate = sched_delta, jnp.float32(jnp.inf)
        x_new, mem_new, coef_new, v_hat, extra, syms = self._col_round(
            x, mem, coef, delta, a_cp, y, hp.m_real,
            lambda v, s2: eta_bg(v, s2, hp.eps, hp.mu_s, hp.sigma_s**2),
            n_mask=n_mask, drop=drop, axis=axis,
            pp=self._col_prior_params(hp))
        extra = jnp.where(s == 0, 0.0, extra)   # zero round-0 payload
        act = s < hp.t_active
        x1 = jnp.where(act, x_new, x)
        mem1 = jnp.where(act, mem_new, mem)
        coef1 = jnp.where(act, coef_new, coef)
        v1 = jnp.where(act, v_hat, v_prev)
        cfg = self.cfg
        out = (jnp.where(act, v_hat, 0.0), jnp.where(act, delta, 0.0),
               jnp.where(act, extra, 0.0),
               jnp.where(act, rate, jnp.float32(jnp.inf)),
               self._col_gather_x(x1, axis) if cfg.collect_xs
               else jnp.zeros(()),
               syms if cfg.collect_symbols else jnp.zeros(()))
        return (x1, mem1, coef1, v1), out

    def _col_scan_fn_het(self, m_pad: int, np_pad: int, has_bt: bool,
                         has_er: bool = False):
        """Jitted vmapped heterogeneous column-batch solve for one padded
        shape: a (B, P, M_pad, Np_pad) column shards, y (B, M_pad)."""

        def build():
            cfg = self.cfg
            p = cfg.n_proc

            def solve_one(a_cp, y, hp: HetParams):
                # every processor owns n_real/P real columns of its slice
                n_mask = (jnp.arange(np_pad) < hp.n_real // p
                          ).astype(jnp.float32)[None, :]
                init = self._col_init(p, np_pad, y,
                                      jnp.sum(y * y) / hp.m_real)
                body = lambda c, xs: self._col_body_het(c, xs, a_cp, y, hp,
                                                        n_mask, has_bt)
                xs = (jnp.arange(cfg.n_iter), hp.sched)
                if has_er:
                    xs = xs + (hp.drop,)
                (x, _, _, _), outs = jax.lax.scan(body, init, xs)
                return x.reshape(-1), outs

            def solve_batch(a_b, y_b, hp: HetParams):
                if cfg.kernel_on:
                    a_b, y_b = pad_col_shards(a_b, y_b)
                return jax.vmap(solve_one)(a_b.astype(cfg.a_jdtype), y_b,
                                           hp)

            return jax.jit(
                solve_batch, donate_argnums=(0, 1) if cfg.donate else ())

        return self._cached(("col_het", m_pad, np_pad, has_bt, has_er),
                            build)

    def dispatch_het(self, a_b, y_b, params: HetParams,
                     has_bt: bool | None = None,
                     compile_only: bool = False):
        """Launch the compiled het solve, returning raw ``(x, outs)`` device
        arrays without materializing them on host. jax dispatch is async, so
        a caller (the serving dispatcher) can prepare the next batch while
        this one computes; build the trace later with ``trace_of``.

        When the operands arrive batch-sharded over a mesh (leading-axis
        ``NamedSharding``), jit partitions the same vmapped program across
        the devices — the serving layer's data-parallel placement.

        Runs through the AOT executable cache: the first (shape, sharding)
        sighting compiles once, everything after is a cached-Compiled call.
        ``compile_only=True`` (the prewarm path) stops after populating the
        cache and returns the executable.

        With ``cfg.donate`` the batch operands are donated into the
        program: a_b/y_b are **consumed** — pass per-flush temporaries, not
        buffers you intend to reuse.
        """
        # cast A at the entry boundary so a bf16 a_dtype transfers (and
        # stays resident) at half width; the in-graph astype is then a no-op
        a_b = jnp.asarray(a_b, self.cfg.a_jdtype)
        y_b = jnp.asarray(y_b, jnp.float32)
        if has_bt is None:
            has_bt = bool(np.any(np.asarray(params.use_bt)))
        has_er = params.drop is not None
        if self.cfg.is_col:
            # column layout: a_b (B, P, M_pad, Np_pad), y_b (B, M_pad) —
            # y is shared across processors, not row-split
            b, p, m_pad, np_pad = a_b.shape
            assert p == self.cfg.n_proc, (p, self.cfg.n_proc)
            assert y_b.shape == (b, m_pad), (y_b.shape, (b, m_pad))
            return self._run(
                ("col_het", m_pad, np_pad, has_bt, has_er),
                self._col_scan_fn_het(m_pad, np_pad, has_bt, has_er),
                (a_b, y_b, params), compile_only)
        b, p, mp_, n = a_b.shape
        assert p == self.cfg.n_proc, (p, self.cfg.n_proc)
        assert y_b.shape == (b, p, mp_)
        return self._run(("het", mp_, n, has_bt, has_er),
                         self._scan_fn_het(mp_, n, has_bt, has_er),
                         (a_b, y_b, params), compile_only)

    def lower_het(self, a_b, y_b, params: HetParams,
                  has_bt: bool | None = None):
        """AOT entry: trace + lower the het program for these operands
        without compiling or executing (inspection / offline compile).
        Does not touch the executable cache; pair with ``compile_het`` for
        the cached pipeline."""
        a_b = jnp.asarray(a_b, self.cfg.a_jdtype)
        y_b = jnp.asarray(y_b, jnp.float32)
        if has_bt is None:
            has_bt = bool(np.any(np.asarray(params.use_bt)))
        has_er = params.drop is not None
        if self.cfg.is_col:
            _, _, m_pad, np_pad = a_b.shape
            fn = self._col_scan_fn_het(m_pad, np_pad, has_bt, has_er)
        else:
            _, _, mp_, n = a_b.shape
            fn = self._scan_fn_het(mp_, n, has_bt, has_er)
        return fn.lower(a_b, y_b, params)

    def compile_het(self, a_b, y_b, params: HetParams,
                    has_bt: bool | None = None):
        """AOT entry: compile the het program for these operand avals into
        the executable cache (idempotent) and return the executable.
        Subsequent ``dispatch_het`` calls with matching shapes/shardings
        run with zero new compiles."""
        return self.dispatch_het(a_b, y_b, params, has_bt,
                                 compile_only=True)

    def trace_of(self, x_outs) -> EngineTrace:
        """Materialize a ``dispatch_het``/``dispatch_sharded`` result."""
        return self._trace(*x_outs)

    def solve_het(self, a_b, y_b, params: HetParams,
                  has_bt: bool | None = None) -> EngineTrace:
        """Solve a heterogeneous batch of B padded CS instances.

        a_b (B, P, M_pad/P, N_pad) — per-processor shards, each processor's
        real rows padded with zero rows *within its own shard* (so the
        row->processor partition matches the unpadded single solve exactly);
        y_b (B, P, M_pad/P) zero-padded the same way. ``params`` carries the
        per-instance operands with a leading B axis. Results for instance i
        are valid on the first ``n_real[i]`` columns / ``t_active[i]``
        iterations of the trace. ``has_bt`` (static) may be passed by
        callers that know no instance uses BT; None derives it from
        ``params.use_bt``.
        """
        return self._trace(*self.dispatch_het(a_b, y_b, params, has_bt))

    # -- device-sharded solves (the mesh as an engine axis, DESIGN.md §6) ----

    def _sharded_axis(self, mesh):
        axis = getattr(self.transport, "axis", None)
        assert axis is not None, \
            "solve_sharded needs a device-collective transport " \
            "(PsumFusion / CompressedPsumTransport), got " \
            f"{type(self.transport).__name__}"
        assert not self.cfg.collect_symbols, \
            "symbols are per-device in sharded mode; build the engine with " \
            "collect_symbols=False"
        n_dev = mesh.shape[axis]
        assert self.cfg.n_proc % n_dev == 0, \
            f"P={self.cfg.n_proc} must be a multiple of the mesh " \
            f"'{axis}' axis ({n_dev})"
        return axis, n_dev

    def _sharded_fn(self, m: int, n: int, mesh, axis: str):
        """Jitted full-solve scan under shard_map: the same iteration body
        as ``_scan_fn``, with (A, y) row-sharded over ``axis`` (each device
        carries P/D emulated processors) and the schedule replicated."""

        def build():
            cfg, kappa = self.cfg, m / n

            def solve_fn(a_p, y_p, sched, drops):
                # local: a_p (P/D, M/P, N), y_p (P/D, M/P), drops (T, 1)
                init = (jnp.zeros(n, jnp.float32), jnp.zeros_like(y_p),
                        jnp.zeros(()))
                body = lambda c, xs: self._body(c, xs, a_p, y_p, kappa,
                                                axis=axis,
                                                m_eff=jnp.float32(m))
                (x, _, _), outs = jax.lax.scan(
                    body, init, (jnp.arange(cfg.n_iter), sched, drops[:, 0]))
                return x, outs

            fn = shard_map(
                solve_fn, mesh=mesh,
                in_specs=(PartitionSpec(axis, None, None),
                          PartitionSpec(axis, None), PartitionSpec(),
                          PartitionSpec(None, axis)),
                out_specs=PartitionSpec(), axis_names={axis}, check=False)
            return jax.jit(fn)

        return self._cached(("sharded", m, n, mesh, axis), build)

    def _col_sharded_fn(self, m: int, n: int, mesh, axis: str):
        """Jitted column-layout solve under shard_map: each device owns P/D
        column blocks; the fusion psums residual contributions (length M)
        and the boundary Onsager scalar across the mesh axis; y and the
        fused residual are replicated. ``drops`` (T, n_dev) marks erased
        device shards per round — column reset semantics
        (``_col_round``); an all-zeros schedule is bit-exact with the
        drop-free solve (every adjustment multiplies by exactly 1.0)."""

        def build():
            cfg = self.cfg

            def solve_fn(a_cp, y, sched, drops):
                # local: a_cp (P/D, M, N/P); y (M,) replicated
                p_loc, _, np_ = a_cp.shape
                init = self._col_init(p_loc, np_, y, jnp.sum(y * y) / m)
                body = lambda c, xs: self._col_body(c, xs, a_cp, y,
                                                    jnp.float32(m),
                                                    axis=axis)
                (x, _, _, _), outs = jax.lax.scan(
                    body, init, (jnp.arange(cfg.n_iter), sched, drops[:, 0]))
                return self._col_gather_x(x, axis), outs

            fn = shard_map(
                solve_fn, mesh=mesh,
                in_specs=(PartitionSpec(axis, None, None), PartitionSpec(),
                          PartitionSpec(), PartitionSpec(None, axis)),
                out_specs=PartitionSpec(), axis_names={axis}, check=False)
            return jax.jit(fn)

        return self._cached(("col_sharded", m, n, mesh, axis), build)

    def _solve_sharded_col(self, y, a_mat, mesh, drop_sched=None
                           ) -> EngineTrace:
        axis, n_dev = self._sharded_axis(mesh)
        self._check_col_controller()
        m, n = np.shape(a_mat)
        a_cp, yj = self._split_col(y, a_mat)
        if drop_sched is None:
            drop_sched = np.zeros((self.cfg.n_iter, n_dev), np.float32)
        drop_sched = np.asarray(drop_sched, np.float32)
        assert drop_sched.shape == (self.cfg.n_iter, n_dev), drop_sched.shape
        x, outs = self._col_sharded_fn(m, n, mesh, axis)(
            a_cp, yj, self._sched_operand(), jnp.asarray(drop_sched))
        return self._trace(x, outs)

    def solve_sharded(self, y, a_mat, mesh, drop_sched=None) -> EngineTrace:
        """Device-sharded solve: row-partitioned (A, y) across the mesh axis
        of the engine's device-collective transport, fusion on the wire.

        The iteration body, controller, and trace semantics are identical to
        ``solve`` — only the fusion sum (and the sigma2_hat reduction) cross
        device links. ``drop_sched`` (T, n_dev) optionally marks straggler/
        erased shards per iteration; the transport rescales the survivors
        unbiasedly instead of stalling the solve.

        Under a ``ColumnPartition`` layout the mesh axis carries the column
        blocks and the fusion psums residual contributions; a dropped shard
        there is handled by *reset*, not rescale — its signal blocks
        restart from zero and re-fuse next round (``_col_round``,
        DESIGN.md §10), since rescaling the other blocks cannot stand in
        for the missing one.
        """
        if self.cfg.is_col:
            return self._solve_sharded_col(y, a_mat, mesh, drop_sched)
        axis, n_dev = self._sharded_axis(mesh)
        m, n = np.shape(a_mat)
        a_p, y_p = self._split(y, a_mat)
        if drop_sched is None:
            drop_sched = np.zeros((self.cfg.n_iter, n_dev), np.float32)
        drop_sched = np.asarray(drop_sched, np.float32)
        assert drop_sched.shape == (self.cfg.n_iter, n_dev), drop_sched.shape
        x, outs = self._sharded_fn(m, n, mesh, axis)(
            a_p, y_p, self._sched_operand(), jnp.asarray(drop_sched))
        return self._trace(x, outs)

    def _sharded_het_fn(self, mp_: int, n: int, has_bt: bool, mesh,
                        axis: str, has_er: bool = False):

        def build():
            cfg = self.cfg

            def solve_one(a_p, y_p, hp: HetParams):
                n_mask = (jnp.arange(n) < hp.n_real).astype(jnp.float32)
                init = (jnp.zeros(n, jnp.float32), jnp.zeros_like(y_p),
                        jnp.zeros(()))
                # hp.drop rides replicated as (T, n_dev); each device
                # slices its own column of the mask
                drops = (hp.drop[:, lax.axis_index(axis)] if has_er
                         else jnp.zeros(cfg.n_iter, jnp.float32))
                body = lambda c, xs: self._body_het(c, xs, a_p, y_p, hp,
                                                    n_mask, has_bt,
                                                    axis=axis)
                (x, _, _), outs = jax.lax.scan(
                    body, init, (jnp.arange(cfg.n_iter), hp.sched, drops))
                return x, outs

            fn = shard_map(
                solve_one, mesh=mesh,
                in_specs=(PartitionSpec(axis, None, None),
                          PartitionSpec(axis, None), PartitionSpec()),
                out_specs=PartitionSpec(), axis_names={axis}, check=False)

            def solve_padded(a_p, y_p, hp: HetParams):
                # tile-align the global operands once, before shard_map
                if cfg.kernel_on:
                    a_p, y_p = pad_row_shards(a_p, y_p)
                return fn(a_p.astype(cfg.a_jdtype), y_p, hp)

            # donate y only: the sharded A may be a long-lived cached
            # device buffer (serving operand cache) and must survive
            return jax.jit(
                solve_padded, donate_argnums=(1,) if cfg.donate else ())

        return self._cached(("sharded_het", mp_, n, has_bt, has_er, mesh,
                             axis), build)

    def _col_sharded_het_fn(self, m_pad: int, np_pad: int, has_bt: bool,
                            mesh, axis: str, has_er: bool = False):

        def build():
            cfg = self.cfg
            p = cfg.n_proc

            def solve_one(a_cp, y, hp: HetParams):
                n_mask = (jnp.arange(np_pad) < hp.n_real // p
                          ).astype(jnp.float32)[None, :]
                p_loc = a_cp.shape[0]
                init = self._col_init(p_loc, np_pad, y,
                                      jnp.sum(y * y) / hp.m_real)
                drops = (hp.drop[:, lax.axis_index(axis)] if has_er
                         else jnp.zeros(cfg.n_iter, jnp.float32))
                body = lambda c, xs: self._col_body_het(c, xs, a_cp, y, hp,
                                                        n_mask, has_bt,
                                                        axis=axis)
                (x, _, _, _), outs = jax.lax.scan(
                    body, init, (jnp.arange(cfg.n_iter), hp.sched, drops))
                return self._col_gather_x(x, axis), outs

            fn = shard_map(
                solve_one, mesh=mesh,
                in_specs=(PartitionSpec(axis, None, None), PartitionSpec(),
                          PartitionSpec()),
                out_specs=PartitionSpec(), axis_names={axis}, check=False)

            def solve_padded(a_cp, y, hp: HetParams):
                # tile-align the global operands once, before shard_map
                if cfg.kernel_on:
                    a_cp, y = pad_col_shards(a_cp, y)
                return fn(a_cp.astype(cfg.a_jdtype), y, hp)

            # donate y only (see _sharded_het_fn): A may be cache-resident
            return jax.jit(
                solve_padded, donate_argnums=(1,) if cfg.donate else ())

        return self._cached(("col_sharded_het", m_pad, np_pad, has_bt,
                             has_er, mesh, axis), build)

    def dispatch_sharded(self, a_p, y_p, params: HetParams, mesh,
                         has_bt: bool | None = None,
                         compile_only: bool = False):
        """Processor-sharded het solve of ONE padded instance (no batch
        axis): a_p (P, M_pad/P, N_pad), y_p (P, M_pad/P), ``params`` the
        per-instance operands *without* a leading batch axis (replicated
        into the shard_map). This is the serving layer's placement for
        large single requests: the mesh axis is the paper's P, the fusion a
        (possibly compressed) collective. Returns raw (x, outs); see
        ``dispatch_het`` for the async rationale.

        Column layout: a_p (P, M_pad, Np_pad) column shards, y_p the
        shared (M_pad,) measurements."""
        axis, n_dev = self._sharded_axis(mesh)
        a_p = jnp.asarray(a_p, self.cfg.a_jdtype)
        y_p = jnp.asarray(y_p, jnp.float32)
        if has_bt is None:
            has_bt = bool(np.any(np.asarray(params.use_bt)))
        has_er = params.drop is not None
        if has_er:
            # per-*device* mask here: the mesh axis is the processor axis
            assert np.shape(params.drop) == (self.cfg.n_iter, n_dev), \
                (np.shape(params.drop), (self.cfg.n_iter, n_dev))
        if self.cfg.is_col:
            p, m_pad, np_pad = a_p.shape
            assert p == self.cfg.n_proc, (p, self.cfg.n_proc)
            assert y_p.shape == (m_pad,), (y_p.shape, m_pad)
            return self._run(
                ("col_sharded_het", m_pad, np_pad, has_bt, has_er, mesh,
                 axis),
                self._col_sharded_het_fn(m_pad, np_pad, has_bt, mesh, axis,
                                         has_er),
                (a_p, y_p, params), compile_only)
        p, mp_, n = a_p.shape
        assert p == self.cfg.n_proc, (p, self.cfg.n_proc)
        assert y_p.shape == (p, mp_)
        return self._run(("sharded_het", mp_, n, has_bt, has_er, mesh,
                          axis),
                         self._sharded_het_fn(mp_, n, has_bt, mesh, axis,
                                              has_er),
                         (a_p, y_p, params), compile_only)

    def solve_sharded_het(self, a_p, y_p, params: HetParams, mesh,
                          has_bt: bool | None = None) -> EngineTrace:
        return self._trace(*self.dispatch_sharded(a_p, y_p, params, mesh,
                                                  has_bt))

    def solve_host_loop(self, y, a_mat, host_schedule=None) -> EngineTrace:
        """Per-iteration host loop over the same jitted body.

        Exists for (a) arbitrary Python rate-controller callables and
        (b) the engine benchmark's host-sync baseline. ``host_schedule``
        is ``(t, sigma2_hat) -> delta``; defaults to the engine's
        controller evaluated on host.
        """
        assert not self.cfg.is_col, \
            "solve_host_loop is a row-layout entry point; column solves " \
            "are scan-only (their controllers are in-graph by design)"
        cfg = self.cfg
        m, n = np.shape(a_mat)
        a_p, y_p = self._split(y, a_mat)
        local, gc = self._step_fns(m, n)

        if host_schedule is None:
            ctrl = self.controller
            if isinstance(ctrl, FixedSchedule):
                host_schedule = lambda t, s2: float(ctrl.deltas[t])
            else:
                host_schedule = lambda t, s2: float(
                    ctrl.delta_for(jnp.asarray(t), jnp.asarray(s2, jnp.float32))[0])

        x = jnp.zeros(n, jnp.float32)
        z_p = jnp.zeros_like(y_p)
        onsager = jnp.zeros(())
        s2s, deltas, extras, xs, syms = [], [], [], [], []
        for t in range(cfg.n_iter):
            z_p, f_p, s2 = local(x, z_p, onsager, a_p, y_p)
            delta_t = float(host_schedule(t, float(s2)))   # the host sync
            x, onsager, extra, q = gc(f_p, s2, jnp.asarray(delta_t))
            s2s.append(float(s2))
            deltas.append(delta_t)
            extras.append(float(extra))
            if cfg.collect_xs:
                xs.append(np.asarray(x))
            if cfg.collect_symbols:
                syms.append(np.asarray(q))
        return EngineTrace(
            x=np.asarray(x), sigma2_hat=np.asarray(s2s),
            deltas=np.asarray(deltas), extra_var=np.asarray(extras),
            rates=np.full(cfg.n_iter, np.inf, np.float32),
            symbols=np.asarray(syms) if cfg.collect_symbols else None,
            xs=np.asarray(xs) if cfg.collect_xs else None,
        )
