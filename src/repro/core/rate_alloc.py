"""Coding-rate allocation across AMP iterations (paper Secs. 3.3-3.4).

BT-MP-AMP (online back-tracking): at iteration t, given the plug-in estimate
sigma_hat_{t,D}^2, find the largest quantizer MSE sigma_Q^2 such that the
predicted next-iteration variance stays within a factor ``c_ratio`` of the
offline centralized-SE value:

    sigma_e^2 + mmse(sigma_hat_{t,D}^2 + P sigma_Q^2)/kappa
        <= c_ratio * sigma_{t+1,C}^2,

subject to rate(sigma_Q^2) <= r_max bits/element.

DP-MP-AMP (offline optimal, eqs. 9-12): given a total budget R over T
iterations discretized at dR (=0.1 in the paper), dynamic programming over
the table sigma_D^2(s, t) = best variance using R^{(s)} bits in the first t
iterations, with transition
    sigma_D^2(s,t) = min_r f1(sigma_D^2(r, t-1), R^{(s-r+1)}).

Erasure recovery policies (DESIGN.md §10): under per-packet loss rate p the
allocators support two bit-accounting disciplines, selectable via
``recovery``:

  * ``"retransmit"`` — lost packets are re-sent next round, so of a wire
    budget R only (1-p)*R lands as fused payload: the DP allocates the
    shrunk budget, BT caps the delivered per-packet rate at r_max*(1-p),
    and the wire rate is the delivered rate / (1-p).
  * ``"rate_up"`` — the dropped processors' bit share is re-allocated to
    the survivors: the fused-payload budget is unchanged, each survivor
    spends rate/(1-p) (finer bins), and the per-processor-slot wire rate
    equals the allocated rate.

Either way the SE step amplifies the denoiser input by
``erasure_amplification`` (the survivor-rescale noise blow-up); the
``DPResult.wire_rates`` column reports what actually crosses the wire.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .denoisers import BernoulliGauss, make_mmse_interp
from .quantize import (delta_for_rate_ecsq, delta_for_sigma_q2, ecsq_entropy,
                       message_mixture)
from .rate_distortion import RDModel
from .state_evolution import (CSProblem, erasure_amplification,
                              se_trajectory, se_trajectory_erasure)

__all__ = ["BTController", "bt_schedule_offline", "dp_allocate",
           "dp_allocate_col", "col_sigma_q2_for_rate", "DPResult",
           "rate_for_sigma_q2", "sigma_q2_for_rate", "stack_schedules",
           "erasure_rate_factors"]


def erasure_rate_factors(erasure_rate: float, recovery: str):
    """(budget_factor, survivor_boost, wire_factor) for a recovery policy.

    ``budget_factor`` scales the allocatable payload budget,
    ``survivor_boost`` the per-delivered-packet rate actually spent
    relative to the allocated slot rate, and ``wire_factor`` maps
    delivered rates to on-the-wire rates (module docstring). At
    ``erasure_rate = 0`` all three are exactly 1.0.
    """
    assert 0.0 <= erasure_rate < 1.0, erasure_rate
    assert recovery in ("retransmit", "rate_up"), recovery
    if erasure_rate == 0.0:
        return 1.0, 1.0, 1.0
    keep = 1.0 - erasure_rate
    if recovery == "retransmit":
        return keep, 1.0, 1.0 / keep
    return 1.0, 1.0 / keep, keep


def stack_schedules(schedules, n_iter: int) -> np.ndarray:
    """Stack variable-length per-request delta schedules into (B, n_iter).

    The serving layer buckets requests with different iteration counts into
    one scan of length ``n_iter`` (the bucket's T_max); shorter schedules
    are padded with +inf — lossless no-op bins that sit beyond the
    request's ``t_active`` early-exit mask, so they are never acted on.
    """
    out = np.full((len(schedules), n_iter), np.inf, np.float32)
    for i, sched in enumerate(schedules):
        sched = np.asarray(sched, np.float32)
        assert sched.ndim == 1 and len(sched) <= n_iter, \
            f"schedule {i}: {sched.shape} exceeds bucket T_max={n_iter}"
        out[i, :len(sched)] = sched
    return out


# ---------------------------------------------------------------------------
# shared helpers (public: core/engine.py builds its in-graph BT rate tables
# from these, so the scan-compiled controller and this host-loop controller
# share one rate model)
# ---------------------------------------------------------------------------

def rate_for_sigma_q2(sigma_q2: float, sigma_t2: float, prob: CSProblem,
                      n_proc: int, rate_model: str, rd: RDModel | None) -> float:
    """Bits/element needed for per-message quantizer MSE sigma_q2."""
    if rate_model == "rd":
        return rd.rate_for_msg_distortion(sigma_q2, sigma_t2, n_proc)
    mix = message_mixture(prob.prior, sigma_t2, n_proc)
    return float(ecsq_entropy(delta_for_sigma_q2(sigma_q2), mix)[0])


def sigma_q2_for_rate(rate: float, sigma_t2: float, prob: CSProblem,
                      n_proc: int, rate_model: str, rd: RDModel | None) -> float:
    if rate_model == "rd":
        return float(rd.distortion_msg(rate, sigma_t2, n_proc))
    mix = message_mixture(prob.prior, sigma_t2, n_proc)
    return delta_for_rate_ecsq(rate, mix) ** 2 / 12.0


# legacy private aliases (pre-engine callers)
_rate_for_sigma_q2 = rate_for_sigma_q2
_sigma_q2_for_rate = sigma_q2_for_rate


# ---------------------------------------------------------------------------
# BT-MP-AMP
# ---------------------------------------------------------------------------

class BTController:
    """Online back-tracking rate controller (paper Sec. 3.3).

    Usable directly as the ``delta_schedule`` callable of mp_amp_solve.
    Records per-iteration (rate, sigma_q2, delta) decisions.
    """

    def __init__(self, prob: CSProblem, n_proc: int, n_iter: int,
                 c_ratio: float = 1.05, r_max: float = 6.0,
                 rate_model: str = "ecsq", rd: RDModel | None = None,
                 mmse_fn=None, erasure_rate: float = 0.0,
                 recovery: str = "retransmit"):
        self.prob = prob
        self.n_proc = n_proc
        self.c_ratio = c_ratio
        self.r_max = r_max
        self.rate_model = rate_model
        self.rd = rd if (rd is not None or rate_model != "rd") else RDModel(prob.prior)
        self.mmse_fn = mmse_fn or make_mmse_interp(prob.prior)
        self.erasure_rate = erasure_rate
        self.recovery = recovery
        budget_f, boost, wire_f = erasure_rate_factors(erasure_rate, recovery)
        self._amp = erasure_amplification(erasure_rate, n_proc)
        self._wire_f = wire_f
        # delivered-rate cap implied by the wire cap r_max: retransmit
        # loses p of the wire budget, rate_up hands the dropped share to
        # survivors (r_max * budget_f * boost; exactly r_max at rate 0)
        self._r_cap = r_max * budget_f * boost
        # offline SE reference sigma_{t,C}^2, t = 0..n_iter — under
        # erasure the reachable reference is the zero-quantization SE with
        # the survivor-rescale amplification, not the lossless-link one
        if erasure_rate > 0.0:
            self.sigma2_c = se_trajectory_erasure(
                prob, np.zeros(n_iter), n_proc, erasure_rate,
                mmse_fn=self.mmse_fn)
        else:
            self.sigma2_c = se_trajectory(prob, n_iter, mmse_fn=self.mmse_fn)
        self.rates: list[float] = []        # delivered bits/element
        self.wire_rates: list[float] = []   # on-the-wire bits/element/slot
        self.sigma_q2s: list[float] = []

    def _predict_next(self, sigma2_d: float, sigma_q2: float) -> float:
        eff = sigma2_d + self.n_proc * sigma_q2
        if self._amp != 1.0:
            eff = self._amp * eff
        return self.prob.sigma_e2 + float(self.mmse_fn(eff)) / self.prob.kappa

    def __call__(self, t: int, sigma2_hat: float) -> float:
        prob, p = self.prob, self.n_proc
        target = self.c_ratio * self.sigma2_c[t + 1]
        # feasibility at zero quantization noise (plug-in may exceed SE ref)
        base = self._predict_next(sigma2_hat, 0.0)
        if base >= target:
            # cannot meet the ratio even losslessly -> spend the cap
            rate = self._r_cap
            sq2 = sigma_q2_for_rate(rate, sigma2_hat, prob, p,
                                     self.rate_model, self.rd)
        else:
            # largest sigma_Q^2 with predicted variance <= target (bisection;
            # _predict_next is increasing in sigma_Q^2)
            lo, hi = 0.0, sigma2_hat / p + 1e-12
            while self._predict_next(sigma2_hat, hi) < target:
                hi *= 4.0
                if hi > 1e6:
                    break
            for _ in range(80):
                mid = 0.5 * (lo + hi)
                if self._predict_next(sigma2_hat, mid) <= target:
                    lo = mid
                else:
                    hi = mid
            sq2 = lo
            rate = rate_for_sigma_q2(sq2, sigma2_hat, prob, p,
                                      self.rate_model, self.rd)
            if rate > self._r_cap:
                rate = self._r_cap
                sq2 = sigma_q2_for_rate(rate, sigma2_hat, prob, p,
                                         self.rate_model, self.rd)
        self.rates.append(rate)
        self.wire_rates.append(rate * self._wire_f)
        self.sigma_q2s.append(sq2)
        return delta_for_sigma_q2(sq2)


def bt_schedule_offline(prob: CSProblem, n_proc: int, n_iter: int,
                        c_ratio: float = 1.05, r_max: float = 6.0,
                        rate_model: str = "rd", rd: RDModel | None = None,
                        mmse_fn=None, erasure_rate: float = 0.0,
                        recovery: str = "retransmit"):
    """Pure-SE BT prediction (no data): returns (rates, sigma2_D trajectory).

    This is the paper's "BT-MP-AMP (RD prediction)" row: run the BT rule on
    the quantized SE recursion itself, using the RD function as rate model.
    """
    ctrl = BTController(prob, n_proc, n_iter, c_ratio, r_max, rate_model, rd,
                        mmse_fn, erasure_rate=erasure_rate, recovery=recovery)
    sigma2_d = [prob.sigma0_2]
    for t in range(n_iter):
        ctrl(t, sigma2_d[-1])
        sigma2_d.append(ctrl._predict_next(sigma2_d[-1], ctrl.sigma_q2s[-1]))
    return np.asarray(ctrl.rates), np.asarray(sigma2_d)


# ---------------------------------------------------------------------------
# DP-MP-AMP
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DPResult:
    rates: np.ndarray          # optimal R_t, t = 1..T (bits/element)
    sigma2_d: np.ndarray       # predicted variance trajectory (T+1,)
    sigma2_table: np.ndarray   # full DP table Sigma (S, T)
    r_grid: np.ndarray         # R^{(s)} grid
    wire_rates: np.ndarray | None = None
                               # on-the-wire bits/element/processor-slot
                               # under an erasure recovery policy (None =
                               # lossless link, wire == rates)


def dp_allocate(prob: CSProblem, n_proc: int, n_iter: int, r_total: float,
                dr: float = 0.1, rd: RDModel | None = None,
                mmse_fn=None, erasure_rate: float = 0.0,
                recovery: str = "retransmit") -> DPResult:
    """Optimal rate allocation by dynamic programming (paper eqs. 10-12).

    ``erasure_rate``/``recovery`` allocate for a lossy link (module
    docstring): the SE transition amplifies by the survivor-rescale
    factor, ``retransmit`` shrinks the allocatable budget to
    (1-p)*r_total, ``rate_up`` lets survivors spend the dropped share.
    ``erasure_rate = 0`` reproduces the published allocator exactly.
    """
    rd = rd or RDModel(prob.prior)
    mmse_fn = mmse_fn or make_mmse_interp(prob.prior)
    p = n_proc
    budget_f, boost, wire_f = erasure_rate_factors(erasure_rate, recovery)
    amp = erasure_amplification(erasure_rate, n_proc)
    s_count = int(round(r_total * budget_f / dr)) + 1
    r_grid = np.arange(s_count) * dr  # R^{(s)}, s = 1..S (0-indexed)

    def f1_matrix(v_prev: np.ndarray, rates: np.ndarray) -> np.ndarray:
        """f1(v_prev[r], rates[k]) for all (r, k): (S, S) array."""
        sigma_p = np.sqrt(p * v_prev)[:, None]          # (S, 1)
        # survivors deliver at boost * the allocated slot rate
        d_g = rd.distortion_g(rates[None, :] * boost, sigma_p)  # (S, S)
        eff = v_prev[:, None] + d_g / p                 # + P * sigma_Q^2
        if amp != 1.0:
            eff = amp * eff
        return prob.sigma_e2 + mmse_fn(eff) / prob.kappa

    big = np.inf
    sigma_tab = np.full((s_count, n_iter), big)
    choice = np.zeros((s_count, n_iter), dtype=np.int64)

    # t = 1 (column 0): all budget R^{(s)} spent here
    v0 = np.full(s_count, prob.sigma0_2)
    sigma_tab[:, 0] = f1_matrix(v0[:1], r_grid)[0]
    choice[:, 0] = np.arange(s_count)

    for t in range(1, n_iter):
        v_prev = sigma_tab[:, t - 1]                    # (S,) indexed by r
        m = f1_matrix(v_prev, r_grid)                   # m[r, k] = f1(prev_r, k*dr)
        # sigma(s, t) = min over r <= s of m[r, s - r]
        r_idx = np.arange(s_count)[:, None]             # (S, 1)
        s_idx = np.arange(s_count)[None, :]             # (1, S)
        k_idx = s_idx - r_idx
        valid = k_idx >= 0
        vals = np.where(valid, m[r_idx, np.clip(k_idx, 0, s_count - 1)], big)
        best_r = np.argmin(vals, axis=0)                # (S,)
        sigma_tab[:, t] = vals[best_r, np.arange(s_count)]
        choice[:, t] = np.arange(s_count) - best_r      # rate index spent at t

    # backtrack from (S-1, T-1)
    rates = np.zeros(n_iter)
    s = s_count - 1
    for t in range(n_iter - 1, -1, -1):
        k = choice[s, t]
        rates[t] = r_grid[k]
        s = s - k

    # predicted trajectory under the optimal schedule
    sigma2_d = [prob.sigma0_2]
    for t in range(n_iter):
        sq2 = float(rd.distortion_msg(rates[t] * boost, sigma2_d[-1], p))
        eff = sigma2_d[-1] + p * sq2
        if amp != 1.0:
            eff = amp * eff
        sigma2_d.append(prob.sigma_e2 + float(mmse_fn(eff)) / prob.kappa)

    wire = rates * boost * wire_f if erasure_rate > 0.0 else None
    return DPResult(rates=rates, sigma2_d=np.asarray(sigma2_d),
                    sigma2_table=sigma_tab, r_grid=r_grid, wire_rates=wire)


# ---------------------------------------------------------------------------
# DP-C-MP-AMP (column layout, DESIGN.md §7)
# ---------------------------------------------------------------------------

def col_sigma_q2_for_rate(rate, block_mse, prob: CSProblem, n_proc: int,
                          ecsq_gap: bool = True):
    """Quantizer MSE on one exchanged residual contribution at ``rate``
    bits/entry (Gaussian model; vectorized over ``rate``/``block_mse``).

    Column-layout residual entries are ~ N(0, v_r) (``residual_mixture``),
    so the rate-distortion law is the Gaussian one, D = v_r 2^{-2R},
    shifted by the high-rate ECSQ gap when the realized quantizer is a
    midtread scalar one.  Capped at v_r: spending less than the gap cannot
    do worse than sending nothing (the FC substitutes zero).
    """
    from .quantize import HIGH_RATE_ECSQ_GAP_BITS
    gap = HIGH_RATE_ECSQ_GAP_BITS if ecsq_gap else 0.0
    sm = prob.prior.second_moment
    v_r = np.maximum(sm - np.asarray(block_mse, np.float64), 1e-30) \
        / (prob.kappa * n_proc)
    return v_r * np.minimum(1.0, 2.0 ** (-2.0 * (np.asarray(rate) - gap)))


def _col_round_map(d_prev, sigma_q2, prob: CSProblem, n_proc: int,
                   n_inner: int, mmse_fn, erasure_rate: float = 0.0):
    """One outer-round map of the two-stage column SE, vectorized over a
    (d_prev, sigma_q2) grid: returns the block MSE after the round.

    ``erasure_rate`` applies the column *reset* semantics
    (state_evolution module docstring): the block MSE entering the round
    averages to (1-p)*d + p*E[S0^2] and only the surviving fraction
    injects quantization noise.  ``0.0`` is bit-exact with the
    lossless-link map.
    """
    d_prev = np.asarray(d_prev, np.float64)
    if erasure_rate > 0.0:
        keep = 1.0 - erasure_rate
        d_in = keep * d_prev + erasure_rate * prob.prior.second_moment
    else:
        keep = 1.0
        d_in = d_prev
    tau0 = prob.sigma_e2 + keep * n_proc * sigma_q2 + d_in / prob.kappa
    e = d_in
    tau_t = tau0
    for _ in range(n_inner):
        e = mmse_fn(tau_t)
        tau_t = tau0 + (e - d_in) / (prob.kappa * n_proc)
    return e


def dp_allocate_col(prob: CSProblem, n_proc: int, n_outer: int,
                    r_total: float, n_inner: int = 1, dr: float = 0.1,
                    mmse_fn=None, ecsq_gap: bool = True,
                    erasure_rate: float = 0.0,
                    recovery: str = "retransmit") -> DPResult:
    """Offline-optimal rate allocation across C-MP-AMP outer rounds.

    Same DP recursion as ``dp_allocate`` (paper eqs. 10-12) with the
    column-layout round map in place of the row-wise SE step: the state is
    the block MSE d^s, a round at rate R injects P * sigma_Q^2(R, d) onto
    the fused residual, and the inner recursion runs ``n_inner`` mmse
    steps.  Round 0 is excluded from the allocation — its exchanged
    contributions are identically zero, so it is lossless for free.

    ``erasure_rate``/``recovery`` follow the module-docstring accounting;
    the SE step is the column *reset* map rather than the row-wise
    survivor-rescale amplification.

    Returns a ``DPResult`` whose ``rates`` has length ``n_outer``
    (``rates[0] = 0``) and whose ``sigma2_d`` is the predicted block-MSE
    trajectory d^0..d^{n_outer} (length n_outer+1).
    """
    mmse_fn = mmse_fn or make_mmse_interp(prob.prior)
    budget_f, boost, wire_f = erasure_rate_factors(erasure_rate, recovery)
    p_e = erasure_rate
    s_count = int(round(r_total * budget_f / dr)) + 1
    r_grid = np.arange(s_count) * dr
    n_alloc = n_outer - 1   # rounds 1..n_outer-1 spend the budget

    def f1_matrix(d_prev: np.ndarray, rates: np.ndarray) -> np.ndarray:
        """round_map(d_prev[r], rates[k]) for all (r, k): (S, S) array."""
        dp_col = d_prev[:, None]
        # survivors deliver at boost * the allocated slot rate
        sq2 = col_sigma_q2_for_rate(rates[None, :] * boost, dp_col, prob,
                                    n_proc, ecsq_gap)
        return _col_round_map(dp_col, sq2, prob, n_proc, n_inner, mmse_fn,
                              erasure_rate=p_e)

    # round 0: lossless, no budget spent (an erased all-zeros contribution
    # resets a block to the x = 0 it already holds, so the reset map is
    # exact here too)
    d0 = _col_round_map(np.asarray([prob.prior.second_moment]), 0.0, prob,
                        n_proc, n_inner, mmse_fn, erasure_rate=p_e)[0]

    big = np.inf
    if n_alloc == 0:
        return DPResult(rates=np.zeros(n_outer),
                        sigma2_d=np.asarray([prob.prior.second_moment, d0]),
                        sigma2_table=np.full((s_count, 1), d0),
                        r_grid=r_grid,
                        wire_rates=(np.zeros(n_outer) if p_e > 0.0
                                    else None))

    sigma_tab = np.full((s_count, n_alloc), big)
    choice = np.zeros((s_count, n_alloc), dtype=np.int64)

    v0 = np.full(s_count, d0)
    sigma_tab[:, 0] = f1_matrix(v0[:1], r_grid)[0]
    choice[:, 0] = np.arange(s_count)

    for t in range(1, n_alloc):
        d_prev = sigma_tab[:, t - 1]
        m = f1_matrix(d_prev, r_grid)
        r_idx = np.arange(s_count)[:, None]
        s_idx = np.arange(s_count)[None, :]
        k_idx = s_idx - r_idx
        valid = k_idx >= 0
        vals = np.where(valid, m[r_idx, np.clip(k_idx, 0, s_count - 1)], big)
        best_r = np.argmin(vals, axis=0)
        sigma_tab[:, t] = vals[best_r, np.arange(s_count)]
        choice[:, t] = np.arange(s_count) - best_r

    rates = np.zeros(n_outer)
    s = s_count - 1
    for t in range(n_alloc - 1, -1, -1):
        k = choice[s, t]
        rates[t + 1] = r_grid[k]
        s = s - k

    # predicted block-MSE trajectory under the optimal schedule
    d_traj = [prob.prior.second_moment, d0]
    for t in range(1, n_outer):
        sq2 = float(col_sigma_q2_for_rate(rates[t] * boost, d_traj[-1], prob,
                                          n_proc, ecsq_gap))
        d_traj.append(float(_col_round_map(np.asarray([d_traj[-1]]), sq2,
                                           prob, n_proc, n_inner, mmse_fn,
                                           erasure_rate=p_e)[0]))

    wire = rates * boost * wire_f if p_e > 0.0 else None
    return DPResult(rates=rates, sigma2_d=np.asarray(d_traj),
                    sigma2_table=sigma_tab, r_grid=r_grid, wire_rates=wire)
