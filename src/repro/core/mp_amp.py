"""Multi-processor AMP with lossy fusion compression (paper Sec. 3).

Row-partitioned model: processor p holds A^p (M/P rows) and y^p. Per iteration

    LC:  z_t^p = y^p - A^p x_t + (1/kappa) * mean(eta'_{t-1}) * z_{t-1}^p
         f_t^p = x_t / P + (A^p)^T z_t^p
    GC:  f_t = sum_p Q_t(f_t^p)        <- lossy fusion (midtread quantizer)
         x_{t+1} = eta_t^Q(f_t),  denoiser variance sigma_hat_t^2 + P Delta^2/12

The LC and GC stages are split exactly as in the paper so that an *online*
rate controller (BT-MP-AMP, Sec. 3.3) can observe the current plug-in noise
estimate sigma_hat_{t,D}^2 = sum_p ||z_t^p||^2 / M — which is available after
LC — before choosing the quantizer for this iteration's fusion.

This module is the *emulated* multi-processor solver: the processor axis is a
leading array axis and fusion is a sum over it — bit-exact to the physical
cluster algorithm (quantization included), independent of device count. The
mesh/shard_map production version (fusion = compressed psum over the 'data'
axis) lives in repro/core/compression.py + repro/launch/solver.py and is
cross-checked against this one in tests.

Rate accounting per iteration: analytic ECSQ entropy H_Q of the model message
distribution, plus the empirical entropy of the realized symbol stream (and,
in tests, exact rANS bitstream length).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .denoisers import BernoulliGauss, eta
from .quantize import (dequantize_midtread, ecsq_entropy, message_mixture,
                       quantize_midtread)

__all__ = ["MPAMPConfig", "MPAMPResult", "mp_amp_solve", "split_problem",
           "mp_local_step", "mp_fusion_step"]


@dataclasses.dataclass(frozen=True)
class MPAMPConfig:
    n_proc: int = 30
    n_iter: int = 10


@dataclasses.dataclass
class MPAMPResult:
    x: np.ndarray
    mse: np.ndarray | None        # per-iteration MSE vs s0 (if s0 given)
    sigma2_hat: np.ndarray        # plug-in sigma_t^2 estimates (post-LC)
    rates_analytic: np.ndarray    # H_Q from the model mixture (bits/elem/proc)
    rates_empirical: np.ndarray   # empirical entropy of realized symbols
    deltas: np.ndarray            # quantizer bin sizes used (inf = lossless)

    @property
    def total_bits_analytic(self) -> float:
        r = self.rates_analytic
        return float(np.sum(r[np.isfinite(r)]))

    @property
    def total_bits_empirical(self) -> float:
        r = self.rates_empirical
        return float(np.sum(r[np.isfinite(r)]))


def split_problem(a_mat: np.ndarray, y: np.ndarray, n_proc: int):
    """Row-partition (A, y) across processors: (P, M/P, N), (P, M/P)."""
    m, n = a_mat.shape
    assert m % n_proc == 0, f"M={m} not divisible by P={n_proc}"
    mp = m // n_proc
    return a_mat.reshape(n_proc, mp, n), y.reshape(n_proc, mp)


@jax.jit
def mp_local_step(x, z_p, onsager_coef, a_p, y_p):
    """LC: residual update + per-processor message. Returns (z_new, f_p, s2)."""
    n_proc = a_p.shape[0]
    m = a_p.shape[0] * a_p.shape[1]
    z_new = y_p - jnp.einsum("pmn,n->pm", a_p, x) + onsager_coef * z_p
    f_p = x[None, :] / n_proc + jnp.einsum("pmn,pm->pn", a_p, z_new)
    sigma2_hat = jnp.sum(z_new * z_new) / m
    return z_new, f_p, sigma2_hat


@partial(jax.jit, static_argnames=("prior",))
def mp_fusion_step(f_p, sigma2_hat, delta, prior: BernoulliGauss, kappa):
    """GC: quantize messages, fuse, denoise. Returns (x_new, onsager, q_syms)."""
    n_proc = f_p.shape[0]
    lossless = ~jnp.isfinite(delta)
    safe_delta = jnp.where(lossless, 1.0, delta)
    q = quantize_midtread(f_p, safe_delta)
    f_q = jnp.where(lossless, f_p, dequantize_midtread(q, safe_delta))
    f = jnp.sum(f_q, axis=0)

    sigma_q2 = jnp.where(lossless, 0.0, safe_delta**2 / 12.0)
    denoise_var = sigma2_hat + n_proc * sigma_q2

    eta_fn = lambda v: eta(v, denoise_var, prior, xp=jnp)
    x_new = eta_fn(f)
    onsager_new = jax.grad(lambda v: jnp.sum(eta_fn(v)))(f).mean() / kappa
    return x_new, onsager_new, q


def _empirical_entropy(q: np.ndarray) -> float:
    """Empirical entropy (bits/symbol) of the quantized index stream."""
    _, counts = np.unique(q.astype(np.int64), return_counts=True)
    p = counts / counts.sum()
    return float(-(p * np.log2(p)).sum())


def mp_amp_solve(y, a_mat, prior: BernoulliGauss, cfg: MPAMPConfig,
                 delta_schedule, s0: np.ndarray | None = None,
                 sigma2_for_model=None) -> MPAMPResult:
    """Run MP-AMP with a per-iteration quantizer schedule.

    delta_schedule: either a sequence of bin sizes (len n_iter; np.inf =>
      lossless fusion at that iteration), or an online controller callable
      ``delta_schedule(t, sigma2_hat) -> delta`` receiving this iteration's
      post-LC plug-in estimate (BT-MP-AMP).
    sigma2_for_model: optional per-iteration channel variances for the
      *analytic* rate accounting (defaults to the online plug-in estimates).
    """
    a_p, y_p = split_problem(np.asarray(a_mat, np.float32), np.asarray(y, np.float32),
                             cfg.n_proc)
    a_p = jnp.asarray(a_p)
    y_p = jnp.asarray(y_p)
    n = a_p.shape[2]
    m = a_p.shape[0] * a_p.shape[1]
    kappa = m / n

    x = jnp.zeros(n, jnp.float32)
    z_p = jnp.zeros_like(y_p)
    onsager = jnp.zeros(())

    callable_sched = callable(delta_schedule)
    mses, s2s, r_ana, r_emp, deltas_used = [], [], [], [], []
    for t in range(cfg.n_iter):
        z_p, f_p, s2 = mp_local_step(x, z_p, onsager, a_p, y_p)
        s2_host = float(s2)
        if callable_sched:
            delta_t = float(delta_schedule(t, s2_host))
        else:
            delta_t = float(delta_schedule[t])
        x, onsager, q = mp_fusion_step(f_p, s2, jnp.asarray(delta_t), prior, kappa)

        s2s.append(s2_host)
        deltas_used.append(delta_t)
        if math.isfinite(delta_t):
            model_s2 = (sigma2_for_model[t] if sigma2_for_model is not None
                        else s2_host)
            mix = message_mixture(prior, model_s2, cfg.n_proc)
            r_ana.append(float(ecsq_entropy(delta_t, mix)[0]))
            r_emp.append(_empirical_entropy(np.asarray(q)))
        else:
            r_ana.append(np.inf)
            r_emp.append(np.inf)
        if s0 is not None:
            mses.append(float(np.mean((np.asarray(x) - s0) ** 2)))

    return MPAMPResult(
        x=np.asarray(x),
        mse=np.asarray(mses) if s0 is not None else None,
        sigma2_hat=np.asarray(s2s),
        rates_analytic=np.asarray(r_ana),
        rates_empirical=np.asarray(r_emp),
        deltas=np.asarray(deltas_used),
    )
