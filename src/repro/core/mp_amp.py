"""Multi-processor AMP with lossy fusion compression (paper Sec. 3).

Row-partitioned model: processor p holds A^p (M/P rows) and y^p. Per iteration

    LC:  z_t^p = y^p - A^p x_t + (1/kappa) * mean(eta'_{t-1}) * z_{t-1}^p
         f_t^p = x_t / P + (A^p)^T z_t^p
    GC:  f_t = sum_p Q_t(f_t^p)        <- lossy fusion (midtread quantizer)
         x_{t+1} = eta_t^Q(f_t),  denoiser variance sigma_hat_t^2 + P Delta^2/12

The LC and GC stages are split exactly as in the paper so that an *online*
rate controller (BT-MP-AMP, Sec. 3.3) can observe the current plug-in noise
estimate sigma_hat_{t,D}^2 = sum_p ||z_t^p||^2 / M — which is available after
LC — before choosing the quantizer for this iteration's fusion.

This module is the *emulated* multi-processor frontend of the unified
``core/engine.py`` solver: the processor axis is a leading array axis and
fusion is a sum over it — bit-exact to the physical cluster algorithm
(quantization included), independent of device count. Fixed schedules and
``BTController`` instances run as a single scan-compiled engine solve (the
BT rule runs in-graph; no per-iteration host sync); arbitrary Python
schedule callables fall back to the engine's host-loop mode. The
mesh/shard_map production version (fusion = compressed psum over the 'data'
axis) lives in repro/core/compression.py + repro/launch/solver.py and is
cross-checked against this one in tests.

Rate accounting per iteration: analytic ECSQ entropy H_Q of the model message
distribution, plus the empirical entropy of the realized symbol stream (and,
in tests, exact rANS bitstream length).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .denoisers import BernoulliGauss
from .engine import (AmpEngine, BTRateControl, EcsqTransport, EngineConfig,
                     EngineTrace, FixedSchedule, amp_gc_step, split_problem)
from .quantize import ecsq_entropy, message_mixture
from .rate_alloc import BTController

__all__ = ["MPAMPConfig", "MPAMPResult", "mp_amp_solve", "split_problem",
           "mp_local_step", "mp_fusion_step"]


@dataclasses.dataclass(frozen=True)
class MPAMPConfig:
    n_proc: int = 30
    n_iter: int = 10


@dataclasses.dataclass
class MPAMPResult:
    x: np.ndarray
    mse: np.ndarray | None        # per-iteration MSE vs s0 (if s0 given)
    sigma2_hat: np.ndarray        # plug-in sigma_t^2 estimates (post-LC)
    rates_analytic: np.ndarray    # H_Q from the model mixture (bits/elem/proc)
    rates_empirical: np.ndarray   # empirical entropy of realized symbols
    deltas: np.ndarray            # quantizer bin sizes used (inf = lossless)

    @property
    def total_bits_analytic(self) -> float:
        r = self.rates_analytic
        return float(np.sum(r[np.isfinite(r)]))

    @property
    def total_bits_empirical(self) -> float:
        r = self.rates_empirical
        return float(np.sum(r[np.isfinite(r)]))


# ---------------------------------------------------------------------------
# single-iteration pieces (public API; thin over the engine's shared body)
# ---------------------------------------------------------------------------

@jax.jit
def mp_local_step(x, z_p, onsager_coef, a_p, y_p):
    """LC: residual update + per-processor message. Returns (z_new, f_p, s2)."""
    n_proc = a_p.shape[0]
    m = a_p.shape[0] * a_p.shape[1]
    z_new = y_p - jnp.einsum("pmn,n->pm", a_p, x) + onsager_coef * z_p
    f_p = x[None, :] / n_proc + jnp.einsum("pmn,pm->pn", a_p, z_new)
    sigma2_hat = jnp.sum(z_new * z_new) / m
    return z_new, f_p, sigma2_hat


@partial(jax.jit, static_argnames=("prior",))
def mp_fusion_step(f_p, sigma2_hat, delta, prior: BernoulliGauss, kappa):
    """GC: quantize messages, fuse, denoise. Returns (x_new, onsager, q_syms)."""
    f, extra, q = EcsqTransport().fuse(f_p, delta)
    x_new, onsager_new = amp_gc_step(f, sigma2_hat + extra, prior, kappa)
    return x_new, onsager_new, q


# per-(prior, P, T) engines for fixed-schedule / host-loop solves (schedules
# are scan operands, so these engines' compiled scans are shape-reusable)
_FIXED_ENGINES: dict = {}


def _empirical_entropy(q: np.ndarray) -> float:
    """Empirical entropy (bits/symbol) of the quantized index stream."""
    _, counts = np.unique(q.astype(np.int64), return_counts=True)
    p = counts / counts.sum()
    return float(-(p * np.log2(p)).sum())


def _result_from_trace(trace: EngineTrace, prior: BernoulliGauss,
                       cfg: MPAMPConfig, s0, sigma2_for_model) -> MPAMPResult:
    """Host-side rate accounting + MSE curve from an engine trace."""
    r_ana, r_emp = [], []
    for t in range(cfg.n_iter):
        delta_t = float(trace.deltas[t])
        if math.isfinite(delta_t):
            model_s2 = (sigma2_for_model[t] if sigma2_for_model is not None
                        else float(trace.sigma2_hat[t]))
            mix = message_mixture(prior, model_s2, cfg.n_proc)
            r_ana.append(float(ecsq_entropy(delta_t, mix)[0]))
            r_emp.append(_empirical_entropy(np.asarray(trace.symbols[t])))
        else:
            r_ana.append(np.inf)
            r_emp.append(np.inf)
    mse = trace.mse(s0) if s0 is not None else None
    return MPAMPResult(
        x=trace.x, mse=mse, sigma2_hat=trace.sigma2_hat,
        rates_analytic=np.asarray(r_ana), rates_empirical=np.asarray(r_emp),
        deltas=trace.deltas,
    )


def mp_amp_solve(y, a_mat, prior: BernoulliGauss, cfg: MPAMPConfig,
                 delta_schedule, s0: np.ndarray | None = None,
                 sigma2_for_model=None) -> MPAMPResult:
    """Run MP-AMP with a per-iteration quantizer schedule.

    delta_schedule: either a sequence of bin sizes (len n_iter; np.inf =>
      lossless fusion at that iteration), an online controller callable
      ``delta_schedule(t, sigma2_hat) -> delta`` receiving this iteration's
      post-LC plug-in estimate (BT-MP-AMP), or an engine RateController.
      Sequences, ``rate_alloc.BTController`` instances and engine
      controllers run as one scan-compiled solve; other callables use the
      per-iteration host loop.
    sigma2_for_model: optional per-iteration channel variances for the
      *analytic* rate accounting (defaults to the online plug-in estimates).
    """
    ecfg = EngineConfig(n_proc=cfg.n_proc, n_iter=cfg.n_iter)

    bt_host: BTController | None = None
    if isinstance(delta_schedule, BTController):
        bt_host = delta_schedule
        # in-graph tables are cached on the controller instance (their build
        # is the expensive part; the controller's params + (P, T) fix them)
        controller = getattr(bt_host, "_in_graph", None)
        if (controller is None or controller.n_iter != cfg.n_iter
                or controller.n_proc != cfg.n_proc):
            controller = BTRateControl(
                bt_host.prob, cfg.n_proc, cfg.n_iter, bt_host.c_ratio,
                bt_host.r_max, bt_host.rate_model, bt_host.rd,
                bt_host.mmse_fn)
            bt_host._in_graph = controller
        host_fallback = None
    elif callable(delta_schedule):
        controller, host_fallback = None, delta_schedule
    elif hasattr(delta_schedule, "delta_for"):
        controller, host_fallback = delta_schedule, None
    else:
        # longer schedules are valid (legacy contract): first n_iter entries
        controller = FixedSchedule(
            np.asarray(delta_schedule, np.float64)[:cfg.n_iter])
        host_fallback = None

    # fixed schedules share one engine per (prior, P, T): the schedule is a
    # scan operand, so repeated solves hit the same compiled scan
    if type(controller) is FixedSchedule or host_fallback is not None:
        cache_key = (prior, cfg.n_proc, cfg.n_iter)
        engine = _FIXED_ENGINES.get(cache_key)
        if engine is None:
            engine = AmpEngine(prior, ecfg, EcsqTransport(),
                               FixedSchedule(np.full(cfg.n_iter, np.inf)))
            _FIXED_ENGINES[cache_key] = engine
        if type(controller) is FixedSchedule:
            engine.controller = controller
    else:
        # engine (and with it the compiled scan) rides on the controller so
        # repeated solves of same-shape problems don't re-trace
        engine = getattr(controller, "_engine", None)
        if engine is None or engine.prior != prior or engine.cfg != ecfg:
            engine = AmpEngine(prior, ecfg, EcsqTransport(), controller)
            try:
                controller._engine = engine
            except AttributeError:
                pass
        engine.controller = controller
    if host_fallback is not None:
        trace = engine.solve_host_loop(y, a_mat, host_schedule=host_fallback)
    else:
        trace = engine.solve(y, a_mat)

    if bt_host is not None:
        # preserve the host controller's record-keeping contract
        for t in range(cfg.n_iter):
            bt_host.rates.append(float(trace.rates[t]))
            bt_host.sigma_q2s.append(float(trace.deltas[t]) ** 2 / 12.0)

    return _result_from_trace(trace, prior, cfg, s0, sigma2_for_model)
