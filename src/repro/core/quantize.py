"""Uniform (midtread) scalar quantization + ECSQ rate model (paper Sec. 3.2).

The per-processor fusion message obeys the scalar channel
    F_t^p = S0/P + (sigma_t/sqrt(P)) Z_p,
i.e. the Gaussian mixture
    F_t^p ~ eps * N(mu_s/P, (sigma_s^2 + P sigma_t^2)/P^2)
          + (1-eps) * N(0, sigma_t^2/P).

A midtread uniform quantizer with bin size Delta has
    q(f) = Delta * round(f / Delta),    sigma_Q^2 = Delta^2 / 12,
and (Widrow; paper's bandlimited-characteristic-function argument) the error is
~U[-Delta/2, Delta/2] and uncorrelated with F as long as
Delta <= 2 sigma_t / sqrt(P).

The ECSQ coding rate is the entropy H_Q of the quantized symbol; we compute it
from mixture CDF differences over the bins. All rate/entropy functions are
host-side numpy (they feed rate allocation); the quantizer itself has a jnp
path used inside MP-AMP and the compressed collectives.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np
from scipy.special import ndtr  # Gaussian CDF, vectorized

from .denoisers import BernoulliGauss

__all__ = [
    "GaussMixture",
    "message_mixture",
    "residual_mixture",
    "quantize_midtread",
    "dequantize_midtread",
    "ecsq_entropy",
    "delta_for_rate_ecsq",
    "delta_for_sigma_q2",
    "HIGH_RATE_ECSQ_GAP_BITS",
]

# High-rate gap between ECSQ entropy and the RD function (Gersho & Gray;
# = 0.5*log2(2*pi*e/12) ~ 0.2546 bits). The paper rounds to 0.255.
HIGH_RATE_ECSQ_GAP_BITS = 0.5 * math.log2(2.0 * math.pi * math.e / 12.0)


@dataclasses.dataclass(frozen=True)
class GaussMixture:
    """Two-component Gaussian mixture sum_k w_k N(mu_k, var_k)."""

    w: tuple[float, ...]
    mu: tuple[float, ...]
    var: tuple[float, ...]

    @property
    def mean(self) -> float:
        return sum(wk * mk for wk, mk in zip(self.w, self.mu))

    @property
    def variance(self) -> float:
        m = self.mean
        return sum(wk * (vk + (mk - m) ** 2) for wk, mk, vk in zip(self.w, self.mu, self.var))

    def cdf(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, dtype=np.float64)[..., None]
        mu = np.asarray(self.mu)
        sd = np.sqrt(np.asarray(self.var))
        return (np.asarray(self.w) * ndtr((x - mu) / sd)).sum(-1)

    def std_span(self, k: float = 10.0) -> tuple[float, float]:
        lo = min(m - k * math.sqrt(v) for m, v in zip(self.mu, self.var))
        hi = max(m + k * math.sqrt(v) for m, v in zip(self.mu, self.var))
        return lo, hi


def message_mixture(prior: BernoulliGauss, sigma_t2: float, n_proc: int) -> GaussMixture:
    """Distribution of the per-processor message F_t^p (paper Sec. 3.2)."""
    p = float(n_proc)
    return GaussMixture(
        w=(prior.eps, 1.0 - prior.eps),
        mu=(prior.mu_s / p, 0.0),
        var=((prior.sigma_s**2 + p * sigma_t2) / p**2, sigma_t2 / p),
    )


def residual_mixture(prior: BernoulliGauss, block_mse: float, kappa: float,
                     n_proc: int) -> GaussMixture:
    """Distribution of one entry of the column-layout residual contribution
    r^p = A_p x_p (C-MP-AMP fusion payload, DESIGN.md §7).

    Each entry is a length-(N/P) inner product of i.i.d. N(0, 1/M) sensing
    rows with the block estimate, hence ~ N(0, ||x_p||^2/M); with block MSE
    ``d`` the estimator second moment is E[S0^2] - d (orthogonality), so

        Var r^p = (N/P) * (E[S0^2] - d) / M = (E[S0^2] - d) / (kappa * P).

    Returned as a (single-component) ``GaussMixture`` so the ECSQ entropy
    and bin-inversion helpers apply unchanged.
    """
    v_r = max(prior.second_moment - block_mse, 1e-30) / (kappa * n_proc)
    return GaussMixture(w=(1.0,), mu=(0.0,), var=(v_r,))


def quantize_midtread(x, delta, xp=jnp):
    """Integer symbols of the midtread quantizer (round-half-even)."""
    return xp.round(x / delta)


def dequantize_midtread(q, delta):
    return q * delta


def ecsq_entropy(delta: np.ndarray, mix: GaussMixture) -> np.ndarray:
    """Entropy (bits/element) of the midtread-quantized mixture, vectorized over delta.

    Bin i covers [ (i-1/2) delta, (i+1/2) delta ); p_i from CDF differences.
    """
    delta = np.atleast_1d(np.asarray(delta, dtype=np.float64))
    lo, hi = mix.std_span(10.0)
    out = np.empty_like(delta)
    for k, d in enumerate(delta):
        i_lo = math.floor(lo / d) - 1
        i_hi = math.ceil(hi / d) + 1
        n_bins = i_hi - i_lo + 1
        if n_bins > 4_000_000:  # degenerate tiny delta; entropy ~ log2 span/d
            out[k] = math.log2((hi - lo) / d)
            continue
        edges = (np.arange(i_lo, i_hi + 2) - 0.5) * d
        cdf = mix.cdf(edges)
        p = np.diff(cdf)
        p = p[p > 1e-300]
        out[k] = float(-(p * np.log2(p)).sum())
    return out


def delta_for_sigma_q2(sigma_q2: float) -> float:
    """Bin size achieving quantizer MSE sigma_Q^2 = Delta^2/12."""
    return math.sqrt(12.0 * sigma_q2)


def delta_for_rate_ecsq(rate_bits: float, mix: GaussMixture,
                        tol: float = 1e-4) -> float:
    """Invert H_Q(Delta) = rate via bisection (H_Q is decreasing in Delta)."""
    sd = math.sqrt(mix.variance)
    lo, hi = sd * 2.0 ** (-40.0), sd * 2.0**12
    # make sure the bracket covers the target
    for _ in range(100):
        if ecsq_entropy(lo, mix)[0] < rate_bits:
            lo /= 4.0
        else:
            break
    for _ in range(100):
        if ecsq_entropy(hi, mix)[0] > rate_bits:
            hi *= 4.0
        else:
            break
    for _ in range(200):
        mid = math.sqrt(lo * hi)
        if ecsq_entropy(mid, mix)[0] > rate_bits:
            lo = mid
        else:
            hi = mid
        if hi / lo < 1.0 + tol:
            break
    return math.sqrt(lo * hi)
