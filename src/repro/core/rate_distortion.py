"""Rate-distortion function of the MP-AMP fusion message, via Blahut-Arimoto.

The per-processor message is F_t^p = G/P with G = S0 + sigma' Z and
sigma' = sqrt(P) * sigma_t, so by the scaling property of RD functions under
squared-error distortion,

    R_{F^p}(D) = R_G(P^2 D)   and   D_{F^p}(R) = D_G(R) / P^2.

We therefore only ever tabulate the one-parameter family R_G(D; sigma')
(prior fixed), which the DP/BT allocators query thousands of times through a
bilinear interpolant in (log sigma', R).

Numerics: Blahut-Arimoto [Blahut'72, Arimoto'72] on a discretized source is
exact up to grid resolution, but saturates at the discrete entropy in the
high-rate limit. The Shannon lower bound

    D_SLB(R) = 2^{2 h(G)} 2^{-2R} / (2 pi e)

is asymptotically tight for this smooth mixture source, so we return
max(D_BA, D_SLB): in the BA-valid (low-rate) region D_BA >= D_SLB picks BA,
and where the grid can no longer resolve the distortion the SLB takes over.
Tests validate both against the closed-form Gaussian R(D).
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import os

import numpy as np

from .denoisers import BernoulliGauss

__all__ = ["ba_rd_curve", "gauss_mixture_entropy", "RDModel"]

_LN2 = math.log(2.0)


def _source_pdf(x: np.ndarray, prior: BernoulliGauss, sigma_p: float) -> np.ndarray:
    """pdf of G = S0 + sigma' Z (two-component Gaussian mixture)."""
    v1 = prior.sigma_s**2 + sigma_p**2
    v0 = sigma_p**2
    g1 = np.exp(-0.5 * (x - prior.mu_s) ** 2 / v1) / math.sqrt(2 * math.pi * v1)
    g0 = np.exp(-0.5 * x**2 / v0) / math.sqrt(2 * math.pi * v0)
    return prior.eps * g1 + (1 - prior.eps) * g0


def gauss_mixture_entropy(prior: BernoulliGauss, sigma_p: float,
                          n_grid: int = 20_001) -> float:
    """Differential entropy h(G) in bits, by quadrature."""
    span = prior.mu_s, math.sqrt(prior.sigma_s**2 + sigma_p**2)
    lo = min(-12 * sigma_p, span[0] - 12 * span[1])
    hi = max(12 * sigma_p, span[0] + 12 * span[1])
    x = np.linspace(lo, hi, n_grid)
    p = _source_pdf(x, prior, sigma_p)
    dx = x[1] - x[0]
    mask = p > 1e-300
    return float(-(p[mask] * np.log2(p[mask])).sum() * dx)


def ba_rd_curve(prior: BernoulliGauss, sigma_p: float, n_grid: int = 769,
                n_beta: int = 48, max_iter: int = 400, tol: float = 1e-7):
    """Blahut-Arimoto sweep -> (R bits, D) samples of R(D) for G = S0 + sigma' Z.

    Returns (R, D) arrays, R increasing, restricted to the grid-valid region
    D >= 30 * dx^2 (below that the discrete grid can't represent the
    reproduction density and the SLB branch of RDModel takes over).
    """
    var_g = prior.second_moment + sigma_p**2  # E[G^2] (mu offsets inside moments)
    hi = prior.mu_s + 8 * math.sqrt(prior.sigma_s**2 + sigma_p**2)
    lo = prior.mu_s - 8 * math.sqrt(prior.sigma_s**2 + sigma_p**2)
    lo, hi = min(lo, -8 * sigma_p), max(hi, 8 * sigma_p)
    x = np.linspace(lo, hi, n_grid)
    dx = x[1] - x[0]
    p = _source_pdf(x, prior, sigma_p)
    p = p / p.sum()

    d = (x[:, None] - x[None, :]) ** 2
    # beta ~ 1/(2 D): sweep distortions from ~var_g down past the grid-validity
    # floor (D ~ 30 dx^2); larger beta only produces points the filter drops.
    betas = np.geomspace(0.05 / var_g, 0.5 / (dx * dx), n_beta)
    q = p.copy()
    rates, dists = [], []
    for beta in betas:
        a = np.exp(-beta * d)
        for _ in range(max_iter):
            c = np.maximum(a @ q, 1e-300)
            t = a.T @ (p / c)
            q = np.maximum(q * t, 0.0)
            q = q / q.sum()
            mask = q > 1e-15
            if not mask.any() or np.abs(np.log(np.maximum(t[mask], 1e-300))).max() < tol:
                break
        c = np.maximum(a @ q, 1e-300)
        pc = p / c
        dist = float(pc @ ((a * d) @ q))
        rate = -beta * dist / _LN2 - float(p @ np.log2(np.maximum(c, 1e-300)))
        rates.append(max(rate, 0.0))
        dists.append(dist)
    r = np.asarray(rates)
    dv = np.asarray(dists)
    valid = dv >= 30.0 * dx * dx
    order = np.argsort(r[valid])
    return r[valid][order], dv[valid][order]


def _cache_dir() -> str:
    d = os.environ.get("REPRO_CACHE", os.path.join(os.path.dirname(__file__), "..", "..", "..", ".cache"))
    os.makedirs(d, exist_ok=True)
    return d


@dataclasses.dataclass
class RDModel:
    """Tabulated D_G(R; sigma') with disk cache, plus per-processor helpers.

    ``distortion_msg(rate, sigma_t2, n_proc)`` returns the quantization MSE
    sigma_Q^2 of one message F_t^p when coded at ``rate`` bits/element.
    """

    prior: BernoulliGauss
    sigma_min: float = 5e-3
    sigma_max: float = 8.0
    n_sigma: int = 25
    r_max: float = 12.0
    dr: float = 0.05
    n_grid: int = 769

    def __post_init__(self):
        self.sigmas = np.geomspace(self.sigma_min, self.sigma_max, self.n_sigma)
        self.r_grid = np.arange(0.0, self.r_max + self.dr / 2, self.dr)
        key = f"rd|{self.prior}|{self.sigma_min}|{self.sigma_max}|{self.n_sigma}|{self.r_max}|{self.dr}|{self.n_grid}|v3"
        h = hashlib.sha1(key.encode()).hexdigest()[:16]
        path = os.path.join(_cache_dir(), f"rd_{h}.npz")
        if os.path.exists(path):
            z = np.load(path)
            self.log_d = z["log_d"]
        else:
            self.log_d = self._build()
            np.savez(path, log_d=self.log_d)

    def _build(self) -> np.ndarray:
        """Hybrid D(R) table per sigma'.

        Low rate: Blahut-Arimoto (exact up to grid resolution). High rate
        (beyond BA's grid validity): the true D(R) is sandwiched between the
        Shannon lower bound (converse) and the ECSQ curve (achievable), and
        asymptotically sits 0.2546 bits left of ECSQ; we use
        clip(D_ECSQ(R + 0.2546), D_SLB(R), D_ECSQ(R)), which is exact in the
        high-rate limit and bounded by information-theoretic limits always.
        """
        from .quantize import GaussMixture, ecsq_entropy, HIGH_RATE_ECSQ_GAP_BITS

        tab = np.empty((self.n_sigma, len(self.r_grid)))
        for i, sp in enumerate(self.sigmas):
            sp = float(sp)
            var_g = self.prior.second_moment + sp**2
            h_g = gauss_mixture_entropy(self.prior, sp)
            d_slb = 2.0 ** (2.0 * (h_g - self.r_grid)) / (2 * math.pi * math.e)

            # -- ECSQ achievability curve D_ECSQ(R) for source G ------------
            mix = GaussMixture(w=(self.prior.eps, 1 - self.prior.eps),
                               mu=(self.prior.mu_s, 0.0),
                               var=(self.prior.sigma_s**2 + sp**2, sp**2))
            sd_g = math.sqrt(var_g)
            deltas = np.geomspace(sd_g * 2.0**-14, sd_g * 8.0, 72)
            h_q = ecsq_entropy(deltas, mix)      # decreasing in delta
            d_q = deltas**2 / 12.0
            order = np.argsort(h_q)

            def d_ecsq(r):
                ld = np.interp(r, h_q[order], np.log(d_q[order]))
                return np.exp(ld)

            # -- BA exact low-rate curve ------------------------------------
            # adaptive grid: small sigma' compresses the interesting D range,
            # so the BA validity window (D >= 30 dx^2) needs finer resolution
            # to keep the exact branch covering rates up to ~3.5 bits.
            n_grid = self.n_grid * 2 + 1 if sp < 1.5 else self.n_grid
            r_ba, d_ba = ba_rd_curve(self.prior, sp, n_grid=n_grid)
            gap = HIGH_RATE_ECSQ_GAP_BITS
            d_hi = np.clip(d_ecsq(self.r_grid + gap),
                           d_slb, d_ecsq(self.r_grid))
            if len(r_ba) >= 2:
                r_valid_max = float(r_ba[-1])
                ld = np.interp(self.r_grid, np.concatenate([[0.0], r_ba]),
                               np.log(np.concatenate([[var_g], d_ba])))
                d_lo = np.exp(ld)
                d_hat = np.where(self.r_grid <= r_valid_max, d_lo, d_hi)
            else:
                d_hat = d_hi
            d_hat = np.minimum(np.maximum(d_hat, d_slb), var_g)
            # enforce monotone decreasing in R
            d_hat = np.minimum.accumulate(d_hat)
            tab[i] = np.log(np.maximum(d_hat, 1e-300))
        return tab

    # ---- queries ------------------------------------------------------------

    def distortion_g(self, rate, sigma_p):
        """D_G(rate; sigma'), vectorized; bilinear in (log sigma', R)."""
        rate = np.asarray(rate, dtype=np.float64)
        sigma_p = np.asarray(sigma_p, dtype=np.float64)
        rate_b = np.broadcast_to(rate, np.broadcast_shapes(rate.shape, sigma_p.shape)).ravel()
        sig_b = np.broadcast_to(sigma_p, np.broadcast_shapes(rate.shape, sigma_p.shape)).ravel()

        ls = np.log(np.clip(sig_b, self.sigmas[0], self.sigmas[-1]))
        lgrid = np.log(self.sigmas)
        i = np.clip(np.searchsorted(lgrid, ls) - 1, 0, self.n_sigma - 2)
        ws = (ls - lgrid[i]) / (lgrid[i + 1] - lgrid[i])

        r = np.clip(rate_b, 0.0, self.r_grid[-1])
        j = np.clip((r / self.dr).astype(int), 0, len(self.r_grid) - 2)
        wr = (r - self.r_grid[j]) / self.dr

        ld = ((1 - ws) * (1 - wr) * self.log_d[i, j]
              + (1 - ws) * wr * self.log_d[i, j + 1]
              + ws * (1 - wr) * self.log_d[i + 1, j]
              + ws * wr * self.log_d[i + 1, j + 1])
        out = np.exp(ld)
        return out.reshape(np.broadcast_shapes(rate.shape, sigma_p.shape))

    def distortion_msg(self, rate, sigma_t2, n_proc: int):
        """Quantization MSE sigma_Q^2 of one message F_t^p at ``rate`` bits/elem."""
        sigma_p = np.sqrt(n_proc * np.asarray(sigma_t2, dtype=np.float64))
        return self.distortion_g(rate, sigma_p) / n_proc**2

    def rate_for_msg_distortion(self, sigma_q2: float, sigma_t2: float, n_proc: int) -> float:
        """Inverse query: bits/element needed for message MSE sigma_q2."""
        d_g = sigma_q2 * n_proc**2
        sigma_p = math.sqrt(n_proc * sigma_t2)
        rates = self.r_grid
        d_curve = self.distortion_g(rates, np.full_like(rates, sigma_p))
        if d_g >= d_curve[0]:
            return 0.0
        if d_g <= d_curve[-1]:
            return float(rates[-1])
        # d_curve decreasing: find crossing
        k = int(np.searchsorted(-d_curve, -d_g))
        k = min(max(k, 1), len(rates) - 1)
        # log-linear inverse interpolation
        l0, l1 = math.log(d_curve[k - 1]), math.log(d_curve[k])
        w = (math.log(d_g) - l0) / (l1 - l0) if l1 != l0 else 0.0
        return float(rates[k - 1] + w * self.dr)
