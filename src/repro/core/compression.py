"""Lossy-compressed collectives — the paper's technique as a TPU-native
transport layer (DESIGN.md §2).

The paper quantizes the per-processor fusion messages f_t^p before the sum at
the fusion center. On a TPU mesh the fusion *is* an all-reduce, so the
equivalent is a two-phase compressed psum executed inside shard_map:

  phase 1 (reduce-scatter equivalent): each device splits its summand into
     P chunks, quantizes (per-block max-abs midtread, int8 or packed int4)
     and all_to_all's them; every device dequantizes + sums its own chunk.
  phase 2 (all-gather equivalent): the reduced chunk is re-quantized and
     all_gather'd; devices dequantize into the full result.

Wire bytes per device drop from ~2 * 2 * N (bf16 ring all-reduce) to
~2 * N * bits/8 — 4x at int8, 8x at int4 — visible in the lowered HLO as
int8/uint8 collective operand types (this is what the roofline's collective
term reads).

Quantization-noise accounting follows the paper's modified SE: a P-summand
fusion at per-block bin width Delta_b injects variance sum_p Delta_{b,p}^2/12;
``quant_noise_var`` reports it so training-side controllers (BT analogue) can
pick bit widths against a noise budget. Error feedback (residual carry) is
provided for optimizer integration.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size

__all__ = ["QuantConfig", "quantize_blocks", "dequantize_blocks",
           "pack_int4", "unpack_int4", "compressed_psum", "quant_noise_var",
           "compressed_grad_transform"]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    bits: int = 8             # 8 or 4 (packed)
    block: int = 512          # elements per scale block
    stochastic: bool = False  # stochastic rounding (decode-side unbiasedness)

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1


def _pad_to(x, k):
    r = (-x.shape[-1]) % k
    if r:
        x = jnp.concatenate([x, jnp.zeros(x.shape[:-1] + (r,), x.dtype)], -1)
    return x, r


def quantize_blocks(x, qc: QuantConfig, key=None):
    """x (..., N) -> (q int8 (..., N), scale bf16 (..., N/block)).

    Midtread symmetric: q = round(x / Delta), Delta = max|block| / qmax.
    """
    orig = x.shape[-1]
    x, _ = _pad_to(x.astype(jnp.float32), qc.block)
    blocks = x.reshape(*x.shape[:-1], -1, qc.block)
    amax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    # round the scale to its bf16 wire format *before* use so the encoder and
    # decoder agree exactly (otherwise the scale mismatch adds ~0.4% * q error);
    # the 1.004 nudge makes the bf16 rounding an upper bound, so the max
    # element never clips and |err| <= Delta/2 holds exactly
    delta = jnp.maximum(amax / qc.qmax, 1e-30) * 1.004
    delta = delta.astype(jnp.bfloat16).astype(jnp.float32)
    scaled = blocks / delta
    if qc.stochastic and key is not None:
        noise = jax.random.uniform(key, scaled.shape) - 0.5
        q = jnp.floor(scaled + 0.5 + noise)
    else:
        q = jnp.round(scaled)
    q = jnp.clip(q, -qc.qmax, qc.qmax).astype(jnp.int8)
    # returned q keeps the block padding; dequantize_blocks(orig_len=...)
    # truncates back (orig recorded by callers)
    return q.reshape(*x.shape), delta[..., 0].astype(jnp.bfloat16)


def dequantize_blocks(q, scale, qc: QuantConfig, orig_len: int | None = None):
    n = q.shape[-1]
    blocks = q.reshape(*q.shape[:-1], -1, qc.block).astype(jnp.float32)
    out = blocks * scale.astype(jnp.float32)[..., None]
    out = out.reshape(*q.shape[:-1], n)
    if orig_len is not None and orig_len != n:
        out = out[..., :orig_len]
    return out


def pack_int4(q):
    """int8 values in [-7, 7] -> packed uint8, two nibbles per byte.

    Pairing via reshape (not strided slices): strided-slice partitioning
    inside a manual-axis shard_map trips an XLA SPMD CHECK at 512 devices.
    """
    u = (q.astype(jnp.int32) & 0xF).astype(jnp.uint8)
    pairs = u.reshape(*u.shape[:-1], u.shape[-1] // 2, 2)
    return pairs[..., 0] | (pairs[..., 1] << 4)


def unpack_int4(p):
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    # sign-extend 4-bit two's complement
    sext = lambda v: jnp.where(v > 7, v - 16, v)
    out = jnp.stack([sext(lo), sext(hi)], axis=-1)
    return out.reshape(*p.shape[:-1], p.shape[-1] * 2)


def quant_noise_var(scale, qc: QuantConfig):
    """Per-element quantization noise variance Delta^2/12 (paper Sec. 3.2)."""
    d = scale.astype(jnp.float32)
    return jnp.mean(d * d) / 12.0


def _wire_encode(q, qc: QuantConfig):
    return pack_int4(q) if qc.bits == 4 else q


def _wire_decode(w, qc: QuantConfig):
    return unpack_int4(w) if qc.bits == 4 else w


def compressed_psum(x, axis_name: str, qc: QuantConfig = QuantConfig()):
    """Sum ``x`` over ``axis_name`` with lossy-compressed transport.

    Must run inside shard_map with ``axis_name`` manual. Exact semantics of
    psum up to quantization error; returns (sum, injected_noise_var) where
    injected_noise_var follows the paper's P * sigma_Q^2 accounting.
    """
    n = axis_size(axis_name)
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    # chunk so every device owns flat_len/n contiguous elements
    flat, _ = _pad_to(flat[None], n * qc.block * 2)
    flat = flat[0]
    chunks = flat.reshape(n, -1)

    # phase 1: quantize per-destination chunks, exchange, reduce own chunk
    q, scale = quantize_blocks(chunks, qc)
    noise1 = quant_noise_var(scale, qc) * n       # n summands -> n * sigma_Q^2
    wire = _wire_encode(q, qc)
    wire_r = lax.all_to_all(wire, axis_name, split_axis=0, concat_axis=0,
                            tiled=True)
    scale_r = lax.all_to_all(scale, axis_name, split_axis=0, concat_axis=0,
                             tiled=True)
    q_r = _wire_decode(wire_r, qc)
    own = dequantize_blocks(q_r, scale_r, qc).sum(axis=0)   # (chunk,)

    # phase 2: re-quantize the reduced chunk, gather everyone's
    q2, scale2 = quantize_blocks(own[None], qc)
    noise2 = quant_noise_var(scale2, qc)
    wire2 = _wire_encode(q2[0], qc)
    wire_g = lax.all_gather(wire2, axis_name, axis=0, tiled=False)
    scale_g = lax.all_gather(scale2, axis_name, axis=0, tiled=False)
    q_g = _wire_decode(wire_g, qc)
    full = dequantize_blocks(q_g, scale_g.reshape(q_g.shape[0], -1), qc)
    out = full.reshape(-1)[: x.size].reshape(shape)
    return out.astype(x.dtype), noise1 + noise2


def compressed_grad_transform(grads, residual, axis_name: str,
                              qc: QuantConfig = QuantConfig()):
    """Per-leaf compressed psum with error feedback.

    grads: pytree of *local* (unreduced over axis_name) gradients.
    residual: same-structure pytree carrying quantization residue (error
    feedback keeps the compression bias from accumulating across steps —
    beyond-paper, standard in gradient-compression practice).
    Returns (reduced grads, new residual, total noise var).
    """
    leaves, treedef = jax.tree.flatten(grads)
    res_leaves = jax.tree.leaves(residual)
    out, new_res, noise = [], [], jnp.zeros(())
    for g, r in zip(leaves, res_leaves):
        g_fb = g.astype(jnp.float32) + r.astype(jnp.float32)
        red, nv = compressed_psum(g_fb, axis_name, qc)
        # residual = what compression lost locally (recomputed against the
        # locally-quantized contribution, cheap proxy: requantize g_fb)
        q, s = quantize_blocks(g_fb.reshape(1, -1), qc)
        deq = dequantize_blocks(q, s, qc, orig_len=g_fb.size).reshape(g.shape)
        new_res.append((g_fb - deq).astype(r.dtype))
        out.append(red.astype(g.dtype))
        noise = noise + nv
    return (jax.tree.unflatten(treedef, out),
            jax.tree.unflatten(treedef, new_res), noise)
