from .amp import amp_solve, sample_problem
from .denoisers import BernoulliGauss, eta, mmse, make_mmse_interp
from .state_evolution import CSProblem, PAPER_T, sdr, se_trajectory
from .mp_amp import MPAMPConfig, MPAMPResult, mp_amp_solve
from .rate_alloc import BTController, bt_schedule_offline, dp_allocate
from .rate_distortion import RDModel
from .compression import QuantConfig, compressed_psum
