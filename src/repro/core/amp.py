"""Centralized Bayesian AMP (paper Sec. 2, eqs. 1-3).

    f_t     = x_t + A^T z_t
    x_{t+1} = eta_t(f_t)
    z_{t+1} = y - A x_{t+1} + (N/M) * mean(eta_t'(f_t)) * z_t

The channel variance fed to the conditional-mean denoiser is the standard
plug-in estimate  sigma_hat_t^2 = ||z_t||^2 / M  [Bayati-Montanari; paper
Sec. 3.3], making the solver fully data-driven.

This is the P=1, lossless-fusion frontend of the unified ``core/engine.py``
solver: with one processor the LC/GC split reduces exactly to the
centralized recursion above (same iterates, bit-for-bit math), so the whole
solve is one scan-compiled engine call. ``amp_iteration`` is kept as the
public single-step API.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .denoisers import BernoulliGauss, eta
from .engine import AmpEngine, EngineConfig, ExactFusion

__all__ = ["AMPState", "amp_iteration", "amp_solve", "sample_problem"]


@dataclasses.dataclass
class AMPTrace:
    x: np.ndarray                # final estimate (N,)
    sigma2_hat: np.ndarray       # per-iteration plug-in variance (T,)
    mse: np.ndarray | None       # per-iteration MSE vs ground truth (T,) if s0 given


class AMPState(dict):
    """Carry pytree for lax.scan: {'x': (N,), 'z': (M,)}."""


@partial(jax.jit, static_argnames=("prior",))
def amp_iteration(x, z, y, a_mat, prior: BernoulliGauss):
    """One centralized AMP iteration. Returns (x_new, z_new, sigma2_hat)."""
    m = y.shape[0]
    n = x.shape[0]
    f = x + a_mat.T @ z
    sigma2_hat = jnp.sum(z * z) / m
    eta_fn = lambda v: eta(v, sigma2_hat, prior, xp=jnp)
    x_new = eta_fn(f)
    eta_mean_deriv = jax.grad(lambda v: jnp.sum(eta_fn(v)))(f).mean()
    z_new = y - a_mat @ x_new + (n / m) * eta_mean_deriv * z
    return x_new, z_new, sigma2_hat


def amp_solve(y, a_mat, prior: BernoulliGauss, n_iter: int,
              s0: np.ndarray | None = None) -> AMPTrace:
    """Run centralized AMP for ``n_iter`` iterations (one engine scan)."""
    engine = AmpEngine(
        prior, EngineConfig(n_proc=1, n_iter=n_iter, collect_symbols=False,
                            collect_xs=s0 is not None),
        ExactFusion())
    trace = engine.solve(y, a_mat)
    mse = trace.mse(s0) if s0 is not None else None
    return AMPTrace(x=trace.x, sigma2_hat=trace.sigma2_hat, mse=mse)


def sample_problem(key, n: int, m: int, prior: BernoulliGauss, sigma_e2: float):
    """Draw (s0, A, y) per the paper's model: A_ij ~ N(0, 1/M), e ~ N(0, sigma_e^2)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    support = jax.random.bernoulli(k1, prior.eps, (n,))
    gauss = prior.mu_s + prior.sigma_s * jax.random.normal(k2, (n,))
    s0 = jnp.where(support, gauss, 0.0)
    a = jax.random.normal(k3, (m, n)) / jnp.sqrt(m * 1.0)
    e = jnp.sqrt(sigma_e2) * jax.random.normal(k4, (m,))
    y = a @ s0 + e
    return np.asarray(s0), np.asarray(a), np.asarray(y)
