"""Centralized Bayesian AMP (paper Sec. 2, eqs. 1-3).

    f_t     = x_t + A^T z_t
    x_{t+1} = eta_t(f_t)
    z_{t+1} = y - A x_{t+1} + (N/M) * mean(eta_t'(f_t)) * z_t

The channel variance fed to the conditional-mean denoiser is the standard
plug-in estimate  sigma_hat_t^2 = ||z_t||^2 / M  [Bayati-Montanari; paper
Sec. 3.3], making the solver fully data-driven.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .denoisers import BernoulliGauss, eta

__all__ = ["AMPState", "amp_iteration", "amp_solve", "sample_problem"]


@dataclasses.dataclass
class AMPTrace:
    x: np.ndarray                # final estimate (N,)
    sigma2_hat: np.ndarray       # per-iteration plug-in variance (T,)
    mse: np.ndarray | None       # per-iteration MSE vs ground truth (T,) if s0 given


class AMPState(dict):
    """Carry pytree for lax.scan: {'x': (N,), 'z': (M,)}."""


@partial(jax.jit, static_argnames=("prior",))
def amp_iteration(x, z, y, a_mat, prior: BernoulliGauss):
    """One centralized AMP iteration. Returns (x_new, z_new, sigma2_hat)."""
    m = y.shape[0]
    n = x.shape[0]
    f = x + a_mat.T @ z
    sigma2_hat = jnp.sum(z * z) / m
    eta_fn = lambda v: eta(v, sigma2_hat, prior, xp=jnp)
    x_new = eta_fn(f)
    eta_mean_deriv = jax.grad(lambda v: jnp.sum(eta_fn(v)))(f).mean()
    z_new = y - a_mat @ x_new + (n / m) * eta_mean_deriv * z
    return x_new, z_new, sigma2_hat


def amp_solve(y, a_mat, prior: BernoulliGauss, n_iter: int,
              s0: np.ndarray | None = None) -> AMPTrace:
    """Run centralized AMP for ``n_iter`` iterations (jit-scanned)."""
    m, n = a_mat.shape
    y = jnp.asarray(y, dtype=jnp.float32)
    a = jnp.asarray(a_mat, dtype=jnp.float32)

    def step(carry, _):
        x, z = carry
        x_new, z_new, s2 = amp_iteration(x, z, y, a, prior)
        return (x_new, z_new), (s2, x_new if s0 is not None else jnp.zeros(()))

    init = (jnp.zeros(n, jnp.float32), y)
    (x, _), (s2s, xs) = jax.lax.scan(step, init, None, length=n_iter)
    mse = None
    if s0 is not None:
        s0 = np.asarray(s0)
        mse = np.asarray([float(np.mean((np.asarray(xi) - s0) ** 2)) for xi in xs])
    return AMPTrace(x=np.asarray(x), sigma2_hat=np.asarray(s2s), mse=mse)


def sample_problem(key, n: int, m: int, prior: BernoulliGauss, sigma_e2: float):
    """Draw (s0, A, y) per the paper's model: A_ij ~ N(0, 1/M), e ~ N(0, sigma_e^2)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    support = jax.random.bernoulli(k1, prior.eps, (n,))
    gauss = prior.mu_s + prior.sigma_s * jax.random.normal(k2, (n,))
    s0 = jnp.where(support, gauss, 0.0)
    a = jax.random.normal(k3, (m, n)) / jnp.sqrt(m * 1.0)
    e = jnp.sqrt(sigma_e2) * jax.random.normal(k4, (m,))
    y = a @ s0 + e
    return np.asarray(s0), np.asarray(a), np.asarray(y)
