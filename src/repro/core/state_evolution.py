"""State evolution (SE) for centralized and quantized multi-processor AMP.

Centralized SE (paper eq. 4):
    sigma_{t+1}^2 = sigma_e^2 + (1/kappa) * mmse(sigma_t^2)
with  sigma_0^2 = sigma_e^2 + E[S0^2]/kappa.

Quantized SE (paper eq. 8): the fusion sum of P independently-quantized
messages adds ~N(0, P*sigma_Q^2), so the denoiser sees effective variance
sigma_t^2 + P*sigma_Q^2:
    sigma_{t+1}^2 = sigma_e^2 + (1/kappa) * mmse(sigma_t^2 + P*sigma_Q^2).

Column-wise two-stage SE (C-MP-AMP, arXiv:1701.02578; DESIGN.md §7): each
processor owns N/P signal columns and the fusion exchanges residual
contributions r^p = A_p x_p (length M).  With d^s the per-entry block MSE
after outer round s and sigma_q2[s] the per-processor quantization MSE on
the exchanged residuals, the fused residual g^s has variance

    tau^{s,0} = sigma_e^2 + P*sigma_q2[s] + (1/kappa) * d^{s-1}          (fusion stage)

and the inner (per-processor) recursion freezes the other blocks' errors
while the own-block term e updates:

    tau^{s,t} = tau^{s,0} + (e_t - d^{s-1}) / (kappa * P)
    e_{t+1}   = mmse(tau^{s,t}),   e_0 = d^{s-1},   d^s = e_{t_inner}.

At n_inner = 1 the round map collapses to the centralized recursion with
the quantization noise entering *additively on the fused residual*:
tau^{s+1} = sigma_e^2 + P*sigma_q2[s+1] + mmse(tau^s)/kappa.

Erasure-extended SE (DESIGN.md §10): when each processor's fusion packet is
lost i.i.d. with probability p and the fusion center rescales the k
survivors by P/k (the transport's unbiased survivor rescale), both the
message noise and the survivors' embedded quantization noise are amplified
by P/k.  Taking the expectation over k ~ Binomial(P, 1-p),

    sigma_{t+1}^2 = sigma_e^2
        + mmse( amp * (sigma_t^2 + P*sigma_Q^2) ) / kappa,
    amp = E[ P / max(k, 1) ]      (``erasure_amplification``),

where the k = 0 event (all packets lost, the fused message collapses to
zero) is folded in through the max.  Column-layout erasure is a *reset*,
not a rescale (an erased contribution leaves its whole signal block
unexplained in g): the block MSE entering a round averages to
(1-p) * d + p * E[S0^2] and only the (1-p) fraction of survivors injects
quantization noise.  Bursty (Gilbert-Elliott) losses share the same
single-round marginals at the stationary loss rate; their temporal
correlation is not tracked here (bursty-loss DP tables are a named
follow-up in ROADMAP.md).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .denoisers import BernoulliGauss, mmse

__all__ = ["CSProblem", "se_trajectory", "se_trajectory_quantized",
           "se_trajectory_col", "erasure_amplification",
           "se_trajectory_erasure", "sdr", "steady_state_iters",
           "sigma_e2_for_snr", "PAPER_T"]


@dataclasses.dataclass(frozen=True)
class CSProblem:
    """Compressed-sensing problem spec (paper Sec. 4): y = A s0 + e."""

    n: int = 10_000
    m: int = 3_000
    prior: BernoulliGauss = dataclasses.field(default_factory=BernoulliGauss)
    snr_db: float = 20.0

    @property
    def kappa(self) -> float:
        return self.m / self.n

    @property
    def rho(self) -> float:
        """E[||s0||^2]/(N*kappa); equals eps/kappa when mu_s=0, sigma_s=1."""
        return self.prior.second_moment / self.kappa

    @property
    def sigma_e2(self) -> float:
        return sigma_e2_for_snr(self.snr_db, self.rho)

    @property
    def sigma0_2(self) -> float:
        """Initial SE variance (x_0 = 0)."""
        return self.sigma_e2 + self.prior.second_moment / self.kappa


def sigma_e2_for_snr(snr_db: float, rho: float) -> float:
    """Invert SNR = 10 log10(rho / sigma_e^2)."""
    return rho / (10.0 ** (snr_db / 10.0))


def sdr(sigma_t2, prob: CSProblem) -> np.ndarray:
    """Signal-to-distortion ratio SDR(t) = 10 log10(rho / (sigma_t^2 - sigma_e^2))."""
    sigma_t2 = np.asarray(sigma_t2, dtype=np.float64)
    return 10.0 * np.log10(prob.rho / np.maximum(sigma_t2 - prob.sigma_e2, 1e-300))


def se_trajectory(prob: CSProblem, n_iter: int, mmse_fn=None) -> np.ndarray:
    """Centralized SE: returns [sigma_0^2, ..., sigma_T^2] (length n_iter+1)."""
    if mmse_fn is None:
        mmse_fn = lambda v: mmse(v, prob.prior)
    out = [prob.sigma0_2]
    for _ in range(n_iter):
        out.append(prob.sigma_e2 + float(mmse_fn(np.asarray([out[-1]]))[0]) / prob.kappa)
    return np.asarray(out)


def se_trajectory_quantized(prob: CSProblem, sigma_q2: np.ndarray, n_proc: int,
                            mmse_fn=None) -> np.ndarray:
    """Quantized SE (eq. 8) for a per-iteration quantizer-MSE schedule.

    ``sigma_q2[t]`` is the per-processor quantization MSE applied at iteration
    t (0-indexed); the fusion sum injects n_proc * sigma_q2[t].
    """
    if mmse_fn is None:
        mmse_fn = lambda v: mmse(v, prob.prior)
    sigma_q2 = np.asarray(sigma_q2, dtype=np.float64)
    out = [prob.sigma0_2]
    for t in range(len(sigma_q2)):
        eff = out[-1] + n_proc * sigma_q2[t]
        out.append(prob.sigma_e2 + float(mmse_fn(np.asarray([eff]))[0]) / prob.kappa)
    return np.asarray(out)


def erasure_amplification(rate: float, n_proc: int) -> float:
    """E[P / max(k, 1)] for k ~ Binomial(P, 1 - rate): the expected noise
    amplification of the P/k survivor rescale at the fusion center.

    Exact binomial sum (P is small — tens of processors). ``rate = 0``
    returns exactly 1.0, so erasure-aware formulas degrade to the
    published SE without even a float rounding difference.
    """
    if rate <= 0.0:
        return 1.0
    assert 0.0 <= rate < 1.0, rate
    p_keep = 1.0 - rate
    amp = 0.0
    for k in range(n_proc + 1):
        pmf = math.comb(n_proc, k) * p_keep**k * rate**(n_proc - k)
        amp += pmf * n_proc / max(k, 1)
    return amp


def se_trajectory_erasure(prob: CSProblem, sigma_q2, n_proc: int,
                          erasure_rate: float, mmse_fn=None) -> np.ndarray:
    """Row-layout quantized SE under Bernoulli per-processor erasure.

    Each iteration the denoiser input variance is amplified by
    ``erasure_amplification`` (module docstring): survivors are rescaled
    by P/k, inflating both the message noise and the surviving
    quantization noise.  ``erasure_rate = 0`` reproduces
    ``se_trajectory_quantized`` exactly.  Gilbert-Elliott losses are
    evaluated at their stationary rate (marginals match; temporal
    correlation untracked).
    """
    if mmse_fn is None:
        mmse_fn = lambda v: mmse(v, prob.prior)
    sigma_q2 = np.asarray(sigma_q2, dtype=np.float64)
    amp = erasure_amplification(erasure_rate, n_proc)
    out = [prob.sigma0_2]
    for t in range(len(sigma_q2)):
        eff = amp * (out[-1] + n_proc * sigma_q2[t])
        out.append(prob.sigma_e2 + float(mmse_fn(np.asarray([eff]))[0]) / prob.kappa)
    return np.asarray(out)


def se_trajectory_col(prob: CSProblem, n_proc: int, n_outer: int,
                      n_inner: int = 1, sigma_q2=None, mmse_fn=None,
                      erasure_rate: float = 0.0):
    """Two-stage column-wise SE (module docstring). Returns ``(tau, d)``.

    ``tau[s]`` is the start-of-round variance of the fused residual g^s
    (``s = 0..n_outer-1``, quantization noise of round s included) —
    the quantity the engine's plug-in ``||g^s||^2/M`` estimates.  ``d[s]``
    is the per-entry block MSE entering round s (``d[0] = E[S0^2]``,
    ``d[s+1]`` = MSE of the estimate after round s, length n_outer+1).

    ``sigma_q2[s]`` is the per-processor quantizer MSE on the exchanged
    residual contributions at round s (entry 0 is conventionally 0: the
    round-0 contributions are identically zero, so their exchange is exact
    at any bin size).  ``None`` means lossless fusion throughout.

    ``erasure_rate`` models per-round, per-processor Bernoulli erasure of
    the residual contributions with the engine's *reset* semantics
    (module docstring): an erased block restarts from x = 0, so the block
    MSE entering the round averages to (1-p)*d + p*E[S0^2] and only the
    surviving (1-p) fraction injects quantization noise.  ``0.0``
    reproduces the lossless-link recursion exactly.
    """
    if mmse_fn is None:
        mmse_fn = lambda v: mmse(v, prob.prior)
    if sigma_q2 is None:
        sigma_q2 = np.zeros(n_outer)
    sigma_q2 = np.asarray(sigma_q2, dtype=np.float64)
    assert len(sigma_q2) == n_outer, (len(sigma_q2), n_outer)
    assert 0.0 <= erasure_rate < 1.0, erasure_rate
    p_e = erasure_rate
    sm = prob.prior.second_moment
    kappa = prob.kappa
    d = [sm]
    tau = []
    for s in range(n_outer):
        d_in = d[-1] if p_e == 0.0 else (1.0 - p_e) * d[-1] + p_e * sm
        tau_s0 = (prob.sigma_e2 + (1.0 - p_e) * n_proc * sigma_q2[s]
                  + d_in / kappa)
        tau.append(tau_s0)
        e = d_in
        tau_t = tau_s0
        for _ in range(n_inner):
            e = float(mmse_fn(np.asarray([tau_t]))[0])
            tau_t = tau_s0 + (e - d_in) / (kappa * n_proc)
        d.append(e)
    return np.asarray(tau), np.asarray(d)


# Steady-state horizons as stated in the paper (Sec. 4, Fig. 1). Our SE with
# the corrected MMSE quadrature reads off 8/10/18 at a 0.15 dB threshold —
# the eps=0.1 curve's last ~2 iterations gain <0.15 dB each, a visual-read
# ambiguity; Table-1 reproduction adopts the paper's own T values.
PAPER_T = {0.03: 8, 0.05: 10, 0.10: 20}


def steady_state_iters(prob: CSProblem, tol_db: float = 0.15, max_iter: int = 200,
                       mmse_fn=None) -> int:
    """Iterations until the SDR gain per iteration drops below ``tol_db``."""
    if mmse_fn is None:
        mmse_fn = lambda v: mmse(v, prob.prior)
    prev = prob.sigma0_2
    prev_sdr = sdr(prev, prob)
    for t in range(1, max_iter + 1):
        cur = prob.sigma_e2 + float(mmse_fn(np.asarray([prev]))[0]) / prob.kappa
        cur_sdr = sdr(cur, prob)
        if cur_sdr - prev_sdr < tol_db:
            # t-1 -> t gained < tol, so iteration t is the first one inside
            # the plateau; the paper counts it ("steady state after T itns").
            return t + 1 if t + 1 <= max_iter else t
        prev, prev_sdr = cur, cur_sdr
    return max_iter
