"""Bayesian denoisers for AMP.

The paper (Sec. 2) assumes a Bernoulli-Gaussian prior

    p_{S0}(s) = eps * N(s; mu_s, sigma_s^2) + (1 - eps) * delta(s)

observed through the AMP scalar channel F = S0 + sigma * Z, Z ~ N(0,1).
The MMSE denoiser is the conditional mean  eta(f) = E[S0 | S0 + sigma Z = f]
(paper eq. 5), which has the closed form

    eta(f) = pi(f) * (mu_s * sigma^2 + f * sigma_s^2) / (sigma_s^2 + sigma^2)

with spike/slab responsibility

    pi(f) = sigmoid( logit(eps) + log N(f; mu_s, sigma_s^2 + sigma^2)
                               - log N(f; 0, sigma^2) ).

Everything is written against an array-namespace argument ``xp`` so the same
formulas serve (a) the jitted JAX AMP loop and (b) fast numpy host-side table
building for state evolution / rate allocation.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "BernoulliGauss",
    "eta",
    "eta_bg",
    "eta_bg_and_deriv",
    "eta_and_deriv",
    "mmse",
    "make_mmse_interp",
]


@dataclasses.dataclass(frozen=True)
class BernoulliGauss:
    """Bernoulli-Gaussian prior (paper eq. 6)."""

    eps: float = 0.1
    mu_s: float = 0.0
    sigma_s: float = 1.0

    @property
    def second_moment(self) -> float:
        """E[S0^2] = eps * (mu_s^2 + sigma_s^2)."""
        return self.eps * (self.mu_s**2 + self.sigma_s**2)

    def scaled(self, a: float) -> "BernoulliGauss":
        """Prior of a*S0."""
        return BernoulliGauss(self.eps, a * self.mu_s, abs(a) * self.sigma_s)


def _log_norm_pdf(xp, x, mu, var):
    return -0.5 * ((x - mu) ** 2 / var) - 0.5 * xp.log(2.0 * math.pi * var)


def _sigmoid(xp, x):
    # numerically stable logistic for both numpy and jnp (no overflow branches)
    e = xp.exp(-xp.abs(x))
    return xp.where(x >= 0, 1.0 / (1.0 + e), e / (1.0 + e))


def eta_bg(f, sigma2, eps, mu_s, sigma_s2, xp=jnp):
    """``eta`` with *array-valued* prior parameters (vmap/scan-safe).

    Identical formula to ``eta`` but every prior parameter may be a traced
    scalar, so one compiled solve can serve per-instance priors (the
    heterogeneous-batch engine path). Requires 0 < eps < 1.
    """
    log_g1 = _log_norm_pdf(xp, f, mu_s, sigma_s2 + sigma2)
    log_g0 = _log_norm_pdf(xp, f, 0.0, sigma2)
    logit_eps = xp.log(eps) - xp.log1p(-eps)
    pi = _sigmoid(xp, logit_eps + log_g1 - log_g0)
    cond_mean = (mu_s * sigma2 + f * sigma_s2) / (sigma_s2 + sigma2)
    return pi * cond_mean


def eta_bg_and_deriv(f, sigma2, eps, mu_s, sigma_s2, xp=jnp):
    """Closed-form ``(eta_bg(f), eta_bg'(f))`` — no autodiff.

    The derivative of the conditional mean ``eta = pi(f) * cm(f)``:

        cm  = (mu_s sigma2 + f sigma_s2) / (sigma_s2 + sigma2)
        L   = logit(eps) + log N(f; mu_s, sigma_s2+sigma2) - log N(f; 0, sigma2)
        eta'= pi (1-pi) L'(f) cm + pi sigma_s2/(sigma_s2+sigma2),
        L'  = f/sigma2 - (f - mu_s)/(sigma_s2 + sigma2).

    Exists because the Pallas column kernels evaluate the denoiser
    *inside* the kernel (``kernels/amp_fused/col.py``), where ``jax.grad``
    is unavailable; pinned elementwise against ``jax.grad`` of ``eta_bg``
    in tests/test_kernels_col.py. Parameters may be traced scalars.
    """
    v1 = sigma_s2 + sigma2
    log_g1 = _log_norm_pdf(xp, f, mu_s, v1)
    log_g0 = _log_norm_pdf(xp, f, 0.0, sigma2)
    lo = xp.log(eps) - xp.log1p(-eps) + log_g1 - log_g0
    pi = _sigmoid(xp, lo)
    cm = (mu_s * sigma2 + f * sigma_s2) / v1
    d_lo = f / sigma2 - (f - mu_s) / v1
    val = pi * cm
    deriv = pi * (1.0 - pi) * d_lo * cm + pi * (sigma_s2 / v1)
    return val, deriv


def eta(f, sigma2, prior: BernoulliGauss, xp=jnp):
    """Conditional-mean denoiser E[S0 | F=f] for channel variance ``sigma2``."""
    eps, mu, s2 = prior.eps, prior.mu_s, prior.sigma_s**2
    sigma2 = xp.asarray(sigma2, dtype=f.dtype) if hasattr(f, "dtype") else sigma2
    log_g1 = _log_norm_pdf(xp, f, mu, s2 + sigma2)
    log_g0 = _log_norm_pdf(xp, f, 0.0, sigma2)
    logit_eps = math.log(eps) - math.log1p(-eps) if 0.0 < eps < 1.0 else (math.inf if eps >= 1.0 else -math.inf)
    pi = _sigmoid(xp, logit_eps + log_g1 - log_g0)
    cond_mean = (mu * sigma2 + f * s2) / (s2 + sigma2)
    return pi * cond_mean


def eta_and_deriv(f, sigma2, prior: BernoulliGauss):
    """eta(f) and the empirical mean of eta'(f), via one JVP-free grad pass.

    AMP's Onsager term (paper eq. 3) needs mean(eta'(f)). Since eta acts
    elementwise, grad of sum(eta) returns the elementwise derivative vector.
    """
    fn = lambda v: eta(v, sigma2, prior, xp=jnp)
    val = fn(f)
    deriv = jax.grad(lambda v: jnp.sum(fn(v)))(f)
    return val, deriv


_GH_CACHE: dict[int, tuple[np.ndarray, np.ndarray]] = {}


def _gauss_hermite(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Nodes/weights for E[h(X)], X~N(0,1) (probabilists' Hermite)."""
    if n not in _GH_CACHE:
        x, w = np.polynomial.hermite_e.hermegauss(n)
        _GH_CACHE[n] = (x, w / math.sqrt(2.0 * math.pi))
    return _GH_CACHE[n]


def mmse(sigma2, prior: BernoulliGauss, n_nodes: int = 4001) -> np.ndarray:
    """MMSE of the scalar channel  E[(eta(S0 + sigma Z) - S0)^2].

    Vectorized over an array of channel variances ``sigma2`` (host-side,
    numpy). This is the kernel of state evolution (paper eqs. 4 and 8).

    Uses the conditional-mean identity  mmse = E[S0^2] - E[eta(F)^2]  so only
    a single smooth 1D integral over the marginal p_F is needed. The marginal
    has two scales (spike width ~sigma, slab width ~sigma_G), so the grid is
    the union of dense windows at both scales (n_nodes points each).
    """
    sigma2 = np.atleast_1d(np.asarray(sigma2, dtype=np.float64))
    e_s2 = prior.second_moment
    eps, mu, s2s = prior.eps, prior.mu_s, prior.sigma_s**2
    out = np.empty_like(sigma2)
    for i, v in enumerate(sigma2):
        sg = math.sqrt(v)
        sg_g = math.sqrt(s2s + v)
        inner = np.linspace(-14 * sg, 14 * sg, n_nodes)
        outer = np.linspace(mu - 14 * sg_g, mu + 14 * sg_g, n_nodes)
        f = np.unique(np.concatenate([inner, outer]))
        p_f = (eps * np.exp(-0.5 * (f - mu) ** 2 / (s2s + v))
               / math.sqrt(2 * math.pi * (s2s + v))
               + (1 - eps) * np.exp(-0.5 * f * f / v)
               / math.sqrt(2 * math.pi * v))
        ef = eta(f, v, prior, xp=np)
        out[i] = max(e_s2 - float(np.trapezoid(ef * ef * p_f, f)), 1e-300)
    return out


def make_mmse_interp(prior: BernoulliGauss, v_min: float = 1e-9, v_max: float = 1e3,
                     n_grid: int = 400):
    """Precompute mmse() on a log grid and return a fast vectorized interpolant.

    Rate allocation (DP) evaluates the SE map ~1e6 times; quadrature each call
    would dominate, so we build log-log linear interpolation once. mmse is
    smooth and monotone in the channel variance, making this accurate to
    <0.1% at 400 points.
    """
    grid_v = np.geomspace(v_min, v_max, n_grid)
    grid_m = mmse(grid_v, prior)
    log_v, log_m = np.log(grid_v), np.log(np.maximum(grid_m, 1e-300))

    def interp(v):
        v = np.asarray(v, dtype=np.float64)
        lv = np.log(np.clip(v, v_min, v_max))
        return np.exp(np.interp(lv, log_v, log_m))

    return interp
