"""rANS entropy coder (host-side, numpy) for the ECSQ symbol streams.

The paper's rate accounting is the entropy H_Q of the quantized messages,
"achievable through entropy coding". This module provides the actual coder so
the claim is *demonstrated*, not assumed: tests check

    H_Q * n  <=  len(bitstream)  <=  H_hat * n + overhead,

with overhead a few bytes (state flush + table). Static-model range-variant
ANS (rANS) with 12-bit quantized frequencies and byte renormalization.

On the TPU transport path entropy coding is not expressible inside an XLA
collective (fixed-width lanes); see DESIGN.md §2. There we transport at
int8/int4 width and report H_Q alongside; this coder is used by the CS-solver
examples and benchmarks running on hosts.
"""
from __future__ import annotations

import numpy as np

__all__ = ["RansCodec"]

_SCALE_BITS = 12
_SCALE = 1 << _SCALE_BITS
_RANS_L = 1 << 23          # lower bound of the normalization interval
_MASK = (1 << 32) - 1


def _quantize_freqs(counts: np.ndarray) -> np.ndarray:
    """Quantize symbol counts to frequencies summing to 2^12, all >= 1."""
    counts = np.asarray(counts, dtype=np.float64)
    if counts.sum() <= 0:
        raise ValueError("empty model")
    if len(counts) > _SCALE:
        # every symbol needs a frequency slot >= 1, so an alphabet larger
        # than the frequency scale cannot be normalized: the adjustment
        # loop below would spin forever trying to shed an irreducible
        # surplus. ECSQ alphabets here are ~2*clip/delta, far below 4096.
        raise ValueError(
            f"alphabet of {len(counts)} symbols exceeds the rANS frequency "
            f"scale ({_SCALE}); re-bin the symbols or raise _SCALE_BITS")
    freqs = np.maximum(1, np.round(counts / counts.sum() * _SCALE)).astype(np.int64)
    # fix rounding drift by adjusting the largest entries
    diff = int(freqs.sum() - _SCALE)
    while diff != 0:
        idx = int(np.argmax(freqs)) if diff > 0 else int(np.argmax(counts - freqs))
        step = min(abs(diff), max(int(freqs[idx]) - 1, 1)) * (1 if diff > 0 else -1)
        if diff > 0 and freqs[idx] - step < 1:
            step = freqs[idx] - 1
        freqs[idx] -= step if diff > 0 else -abs(step)
        diff = int(freqs.sum() - _SCALE)
    return freqs


class RansCodec:
    """Static-model rANS over a contiguous alphabet [0, n_symbols)."""

    def __init__(self, counts: np.ndarray):
        self.freqs = _quantize_freqs(counts)
        self.cum = np.zeros(len(self.freqs) + 1, dtype=np.int64)
        np.cumsum(self.freqs, out=self.cum[1:])
        # decoding table: slot -> symbol
        self.slot2sym = np.repeat(np.arange(len(self.freqs)), self.freqs).astype(np.int64)

    def encode(self, symbols: np.ndarray) -> bytes:
        """Encode int symbols (values in [0, n_symbols)). Returns bytestream."""
        syms = np.asarray(symbols, dtype=np.int64).ravel()
        freqs, cum = self.freqs, self.cum
        out = bytearray()
        x = _RANS_L
        # encode in reverse so the decoder emits in forward order
        for s in syms[::-1]:
            f = int(freqs[s])
            # renormalize: keep x < (L/scale) * 256 * f after the step
            x_max = ((_RANS_L >> _SCALE_BITS) << 8) * f
            while x >= x_max:
                out.append(x & 0xFF)
                x >>= 8
            x = ((x // f) << _SCALE_BITS) + (x % f) + int(cum[s])
        for _ in range(4):
            out.append(x & 0xFF)
            x >>= 8
        return bytes(out[::-1])

    def decode(self, data: bytes, n: int) -> np.ndarray:
        freqs, cum, slot2sym = self.freqs, self.cum, self.slot2sym
        pos = 0
        x = 0
        for _ in range(4):
            x = (x << 8) | data[pos]
            pos += 1
        out = np.empty(n, dtype=np.int64)
        for i in range(n):
            slot = x & (_SCALE - 1)
            s = int(slot2sym[slot])
            out[i] = s
            x = int(freqs[s]) * (x >> _SCALE_BITS) + slot - int(cum[s])
            while x < _RANS_L and pos < len(data):
                x = (x << 8) | data[pos]
                pos += 1
        return out

    def encoded_bits(self, symbols: np.ndarray) -> int:
        return 8 * len(self.encode(symbols))
