"""Pure-jnp oracle for the fused block-quantize kernel."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_ref(x, qmax: int, block: int):
    """x (R, N) with N % block == 0 -> (q int8 (R, N), scale bf16 (R, N/block)).

    Matches core.compression.quantize_blocks numerics (bf16-rounded scale
    with the 1.004 no-clip nudge).
    """
    r, n = x.shape
    xb = x.astype(jnp.float32).reshape(r, n // block, block)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    delta = (jnp.maximum(amax / qmax, 1e-30) * 1.004)
    delta = delta.astype(jnp.bfloat16).astype(jnp.float32)
    q = jnp.clip(jnp.round(xb / delta), -qmax, qmax).astype(jnp.int8)
    return q.reshape(r, n), delta[..., 0].astype(jnp.bfloat16)


def dequantize_ref(q, scale, block: int):
    r, n = q.shape
    qb = q.reshape(r, n // block, block).astype(jnp.float32)
    return (qb * scale.astype(jnp.float32)[..., None]).reshape(r, n)
