"""Public wrapper for the quantize kernel: padding + CPU/TPU dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .quantize import BLOCK, N_TILE, TILE_R, dequantize_pallas, quantize_pallas
from .ref import dequantize_ref, quantize_ref

__all__ = ["quantize", "dequantize", "BLOCK"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad2(x, tr, tn):
    r, n = x.shape
    pr, pn = (-r) % tr, (-n) % tn
    if pr or pn:
        x = jnp.pad(x, ((0, pr), (0, pn)))
    return x, (r, n)


def quantize(x, qmax: int = 127, use_pallas: bool | None = None,
             interpret: bool = False):
    """Block-quantize a 2D array. Returns (q int8, scale bf16, orig shape)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    xp, orig = _pad2(x, TILE_R, N_TILE)
    if use_pallas:
        q, s = quantize_pallas(xp, qmax=qmax, interpret=interpret)
    else:
        q, s = quantize_ref(xp, qmax, BLOCK)
    return q, s, orig


def dequantize(q, scale, orig, use_pallas: bool | None = None,
               interpret: bool = False):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        out = dequantize_pallas(q, scale, interpret=interpret)
    else:
        out = dequantize_ref(q, scale, BLOCK)
    r, n = orig
    return out[:r, :n]
