"""Pallas TPU kernel: fused block-wise quantization (the paper's ECSQ hot
spot, adapted to the TPU transport path of compressed_psum).

One pass over the tensor in VMEM tiles computes per-block max-abs scale,
midtread quantization, and the int8 symbols — avoiding the three separate
HBM round-trips (amax read, scale apply, round/clip) of the naive lowering.

Tiling: rows of blocks. Input viewed as (R, N); each grid step loads a
(TILE_R, N_TILE) tile with N_TILE a multiple of the scale block (512 lanes =
4x128, MXU/VPU aligned), computes scales for the TILE_R x (N_TILE/block)
sub-blocks and writes q + scales. VMEM footprint per step:
TILE_R * N_TILE * (4 + 1) bytes + scales — 256x2048 ~ 2.6 MB << 16 MB VMEM.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 256
BLOCK = 512           # elements per scale block (matches QuantConfig.block)
N_TILE = 2048         # lanes per grid step (4 scale blocks)


def _quant_kernel(x_ref, q_ref, s_ref, *, qmax: int):
    x = x_ref[...].astype(jnp.float32)                   # (TILE_R, N_TILE)
    tr, nt = x.shape
    xb = x.reshape(tr, nt // BLOCK, BLOCK)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    delta = jnp.maximum(amax / qmax, 1e-30) * 1.004
    delta = delta.astype(jnp.bfloat16).astype(jnp.float32)
    q = jnp.clip(jnp.round(xb / delta), -qmax, qmax)
    q_ref[...] = q.reshape(tr, nt).astype(jnp.int8)
    s_ref[...] = delta[..., 0].astype(jnp.bfloat16)


@partial(jax.jit, static_argnames=("qmax", "interpret"))
def quantize_pallas(x, qmax: int = 127, interpret: bool = False):
    """x (R, N), N % N_TILE == 0, R % TILE_R == 0 (ops.py pads)."""
    r, n = x.shape
    grid = (r // TILE_R, n // N_TILE)
    return pl.pallas_call(
        partial(_quant_kernel, qmax=qmax),
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_R, N_TILE), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((TILE_R, N_TILE), lambda i, j: (i, j)),
            pl.BlockSpec((TILE_R, N_TILE // BLOCK), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, n), jnp.int8),
            jax.ShapeDtypeStruct((r, n // BLOCK), jnp.bfloat16),
        ],
        interpret=interpret,
    )(x)


def _dequant_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    tr, nt = q.shape
    s = s_ref[...].astype(jnp.float32)                   # (TILE_R, NT/BLOCK)
    xb = q.reshape(tr, nt // BLOCK, BLOCK) * s[..., None]
    o_ref[...] = xb.reshape(tr, nt)


@partial(jax.jit, static_argnames=("interpret",))
def dequantize_pallas(q, scale, interpret: bool = False):
    r, n = q.shape
    grid = (r // TILE_R, n // N_TILE)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_R, N_TILE), lambda i, j: (i, j)),
            pl.BlockSpec((TILE_R, N_TILE // BLOCK), lambda i, j: (i, j)),
        ],
        out_specs=[pl.BlockSpec((TILE_R, N_TILE), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((r, n), jnp.float32)],
        interpret=interpret,
    )(q, scale)[0]
