"""Pallas TPU kernel: RWKV-6 WKV recurrence (chunked linear attention).

TPU adaptation of the Finch CUDA kernel (DESIGN.md §2): instead of one
thread-block per (batch, head) running a serial loop with warp-level
parallelism over channels, the TPU kernel processes the sequence in chunks
of 32 steps held in VMEM; the intra-chunk contribution is an MXU matmul in
the decay-rebased basis (r' = r e^{l}, k' = k e^{-l}) and the (Dh x Dh)
recurrent state lives in VMEM scratch across the sequential chunk grid.

Grid: (B*H, T/CHUNK) — chunk axis fastest (sequential), state scratch
carried across it and re-initialized at chunk 0. Per-step log-decay is
assumed clamped to >= -2 by the caller (rwkv6._time_mix), which bounds the
rebased factors to e^{+-64} — inside fp32 range.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 32


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, s_ref):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref[...])

    r = r_ref[0].astype(jnp.float32)       # (C, Dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)     # per-step log decay (C, Dh)
    u = u_ref[0].astype(jnp.float32)       # (1, Dh) bonus

    l_inc = jnp.cumsum(lw, axis=0)
    l_exc = l_inc - lw
    r_resc = r * jnp.exp(l_exc)
    k_resc = k * jnp.exp(-l_inc)
    l_tot = l_inc[-1]                      # (Dh,)

    cdim = r.shape[0]
    a_mat = jax.lax.dot_general(r_resc, k_resc, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    tri = jax.lax.broadcasted_iota(jnp.int32, (cdim, cdim), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (cdim, cdim), 1)
    a_mat = jnp.where(tri, a_mat, 0.0)
    diag = jnp.sum(r * u * k, axis=1)      # u-bonus for j == t
    y = jax.lax.dot_general(a_mat, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y += diag[:, None] * v
    # inter-chunk: r' sees the carried state
    y += jax.lax.dot_general(r_resc, s_ref[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: S <- diag(e^{l_tot}) S + sum_t (k'_t e^{l_tot}) v_t^T
    k_fold = k_resc * jnp.exp(l_tot)[None, :]
    s_new = jnp.exp(l_tot)[:, None] * s_ref[...] + jax.lax.dot_general(
        k_fold, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    s_ref[...] = s_new


@partial(jax.jit, static_argnames=("interpret",))
def wkv6_pallas(r, k, v, logw, u, interpret: bool = False):
    """r/k/v/logw (B, T, H, Dh) with T % CHUNK == 0; u (H, Dh).

    Returns y (B, T, H, Dh) fp32. (State output handled by the ops wrapper
    via a trailing identity chunk when needed.)"""
    b, t, h, dh = r.shape
    bh = b * h
    resh = lambda a: a.transpose(0, 2, 1, 3).reshape(bh, t, dh)
    rr, kk, vv, lw = resh(r), resh(k), resh(v), resh(logw)
    uu = jnp.broadcast_to(u[None], (b, h, dh)).reshape(bh, 1, dh)

    y = pl.pallas_call(
        _kernel,
        grid=(bh, t // CHUNK),
        in_specs=[
            pl.BlockSpec((1, CHUNK, dh), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, CHUNK, dh), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, CHUNK, dh), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, CHUNK, dh), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, 1, dh), lambda i, c: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, CHUNK, dh), lambda i, c: (i, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, dh), jnp.float32),
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, lw, uu)
    return y.reshape(b, h, t, dh).transpose(0, 2, 1, 3)
