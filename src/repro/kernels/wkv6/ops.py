"""Wrapper for the WKV6 kernel: padding + backend dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .ref import wkv_chunked
from .wkv6 import CHUNK, wkv6_pallas

__all__ = ["wkv6"]


def wkv6(r, k, v, logw, u, use_pallas: bool | None = None,
         interpret: bool = False):
    """WKV6 sequence mix (zero initial state). Returns y (B,T,H,Dh) fp32."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        y, _ = wkv_chunked(r, k, v, logw, u)
        return y
    b, t, h, dh = r.shape
    pad = (-t) % CHUNK
    if pad:
        padf = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, logw = padf(r), padf(k), padf(v), padf(logw)
    y = wkv6_pallas(r, k, v, logw, u, interpret=interpret)
    return y[:, :t]
