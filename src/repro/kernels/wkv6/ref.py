"""Oracle for the WKV6 kernel = the rwkv6 module's scan reference."""
from repro.models.rwkv6 import wkv_chunked, wkv_scan_ref  # noqa: F401
