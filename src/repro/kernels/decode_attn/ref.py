"""Pure-jnp oracle for GQA decode attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attn_ref(q, k_cache, v_cache, pos, window: int = 0):
    """q (B, H, Dh); caches (B, S, KV, Dh); pos () int. Returns (B, H, Dh).

    Causal mask: positions <= pos (and > pos - window if window > 0)."""
    b, h, dh = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    s = k_cache.shape[1]
    qg = q.reshape(b, kv, g, dh).astype(jnp.float32)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg,
                        k_cache.astype(jnp.float32)) / math.sqrt(dh)
    t = jnp.arange(s)
    ok = t <= pos
    if window > 0:
        ok &= t > pos - window
    scores = jnp.where(ok[None, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, dh)
