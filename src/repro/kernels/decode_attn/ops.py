"""Wrapper for flash-decode attention: padding + backend dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .decode_attn import BLOCK_S, decode_attn_pallas
from .ref import decode_attn_ref

__all__ = ["decode_attention"]


def decode_attention(q, k_cache, v_cache, pos, window: int = 0,
                     use_pallas: bool | None = None, interpret: bool = False):
    """q (B,H,Dh) vs caches (B,S,KV,Dh) -> (B,H,Dh)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return decode_attn_ref(q, k_cache, v_cache, pos, window)
    s = k_cache.shape[1]
    pad = (-s) % BLOCK_S
    if pad:
        # padded rows are masked out by the position check (t <= pos < s)
        padf = lambda c: jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_cache, v_cache = padf(k_cache), padf(v_cache)
    return decode_attn_pallas(q, k_cache, v_cache, pos, window=window,
                              interpret=interpret)
