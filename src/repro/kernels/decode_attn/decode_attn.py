"""Pallas TPU kernel: GQA decode attention (flash-decode).

One query token attends over a length-S KV cache. The cache is streamed
through VMEM in (BLOCK_S x Dh) tiles along the sequence; the kernel keeps
running (max, sum, acc) flash accumulators in VMEM scratch, so the scores
never touch HBM — on the jnp path they are materialized per block, which is
exactly the decode-bandwidth overhead this kernel removes.

Grid: (B * KV, S / BLOCK_S); the sequence dim is the fastest (sequential on
TPU), carrying the accumulators across blocks; the (m, l, acc) scratch is
re-initialized whenever the sequence index returns to 0.

Block sizes: BLOCK_S = 512 rows of cache; with Dh <= 256 the K and V tiles
are <= 512 * 256 * 2B = 256 KB each — comfortably inside VMEM with
double-buffering.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_S = 512


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale, window, block_s):
    j = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref[...], -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref[...])
        acc_ref[...] = jnp.zeros_like(acc_ref[...])

    pos = pos_ref[0]
    q = q_ref[0].astype(jnp.float32)            # (G, Dh)
    k = k_ref[0].astype(jnp.float32)            # (block_s, Dh)
    v = v_ref[0].astype(jnp.float32)            # (block_s, Dh)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    t = j * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    ok = t <= pos
    if window > 0:
        ok &= t > pos - window
    s = jnp.where(ok, s, -jnp.inf)              # (G, block_s)

    m_prev = m_ref[...]                         # (G,)
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(ok, p, 0.0)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("window", "interpret"))
def decode_attn_pallas(q, k_cache, v_cache, pos, window: int = 0,
                       interpret: bool = False):
    """q (B, H, Dh); caches (B, S, KV, Dh) with S % BLOCK_S == 0."""
    b, h, dh = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(dh)

    qg = q.reshape(b, kv, g, dh).reshape(b * kv, g, dh)
    kc = k_cache.transpose(0, 2, 1, 3).reshape(b * kv, s, dh)
    vc = v_cache.transpose(0, 2, 1, 3).reshape(b * kv, s, dh)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    out = pl.pallas_call(
        partial(_kernel, scale=scale, window=window, block_s=BLOCK_S),
        grid=(b * kv, s // BLOCK_S),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1, g, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, BLOCK_S, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, BLOCK_S, dh), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, dh), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv, g, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, qg, kc, vc)
    return out.reshape(b, h, dh)
