"""Pure-jnp oracles for the fused AMP local-computation kernels.

``amp_local_ref`` is the original single-processor LC oracle;
``amp_local_ref_grid`` is the batched-grid counterpart (the whole
(P, M/P, N) shard stack in one call, sigma2_hat sum-of-squares fused) and
doubles as the engine's compiled CPU path. The column-layout oracles
mirror ``col.py``'s fused kernels: ``col_residual_ref`` (r_p = A_p x_p)
and ``col_inner_step_ref`` (message + denoise + optional residual
update — one C-MP-AMP inner iteration).

Both contractions are single ``dot_general``s over the whole stack (the
processor axis a batch dim of one op, not a ``vmap`` of P small ops) with
the elementwise tails and the sum-of-squares fused behind one jit — this
is the "batched grid" on CPU, and what ``benchmarks/bench_kernels.py``
measures against the per-processor ``vmap`` baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def amp_local_ref(a, x, y, z, onsager, n_proc: int):
    """Paper Sec. 3.1 LC step for one processor:

        z' = y - A x + onsager * z
        f  = x / P + A^T z'

    a: (M, N); x: (N,); y, z: (M,). Returns (z', f)."""
    z_new = y - a @ x + onsager * z
    f = x / n_proc + a.T @ z_new
    return z_new, f


def amp_local_ref_grid(a_p, x, y_p, z_p, onsager, n_proc: int):
    """Batched-grid LC oracle over the full processor stack.

    a_p (P, Mp, N) — may be stored in bf16 (``EngineConfig.a_dtype``); the
    contraction promotes to f32, modelling bf16 HBM streaming with f32
    accumulation. x (N,); y_p, z_p (P, Mp). Returns
    ``(z_new (P, Mp), f_p (P, N), ss ())`` with ``ss = sum(z_new**2)``
    (the sigma2_hat numerator, fused exactly like the Pallas kernels).
    """
    a32 = a_p.astype(jnp.float32)
    z_new = y_p - jnp.einsum("pmn,n->pm", a32, x) + onsager * z_p
    f_p = x / n_proc + jnp.einsum("pmn,pm->pn", a32, z_new)
    return z_new, f_p, jnp.sum(z_new * z_new)


def col_residual_ref(a_cp, x):
    """Column-layout residual contributions r_p = A_p x_p.

    a_cp (P, M, Np) column shards; x (P, Np). Returns (P, M)."""
    return jnp.einsum("pmn,pn->pm", a_cp.astype(jnp.float32), x)


def col_inner_step_ref(a_cp, x, x0, z_p, g, n_mask, m_eff,
                       eps, mu_s, sigma_s2, update_z: bool):
    """One C-MP-AMP inner iteration (engine ``_col_inner`` body), oracle.

    Per processor p (a_cp (P, M, Np), x/x0 (P, Np), z_p (P, M), g (M,)):

        s2_p = ||z_p||^2 / m_eff
        f_p  = x_p + A_p^T z_p
        x'   = eta(f_p; s2_p) * mask,  c_p = sum(eta' * mask) / m_eff
        z'   = g - A_p (x' - x0) + c_p z_p        (only when ``update_z``)

    Returns ``(x_new, c_p, z_new)`` with ``z_new = z_p`` when the update
    is skipped (the final inner iteration: ``z_p`` is the residual that
    fed the denoise, which is what the Onsager boundary carry needs).
    """
    from .col import eta_bg_and_deriv

    a32 = a_cp.astype(jnp.float32)
    s2_p = jnp.sum(z_p * z_p, axis=-1, keepdims=True) / m_eff
    f_p = x + jnp.einsum("pmn,pm->pn", a32, z_p)
    val, deriv = eta_bg_and_deriv(f_p, s2_p, eps, mu_s, sigma_s2)
    if n_mask is not None:
        val = val * n_mask
        deriv = deriv * n_mask
    c_p = jnp.sum(deriv, axis=-1) / m_eff
    if update_z:
        z_new = (g[None, :] - jnp.einsum("pmn,pn->pm", a32, val - x0)
                 + c_p[:, None] * z_p)
    else:
        z_new = z_p
    return val, c_p, z_new


def amp_local_ref_vmap(a_p, x, y_p, z_p, onsager, n_proc: int):
    """The pre-v2 engine path: per-processor LC ``vmap``ed over P, the
    sum-of-squares reduction separate. Kept as the benchmark baseline
    (``bench_kernels.py``) — not used by the engine."""
    z_new, f_p = jax.vmap(
        lambda ap, yp, zp: amp_local_ref(ap, x, yp, zp, onsager, n_proc)
    )(a_p.astype(jnp.float32), y_p, z_p)
    return z_new, f_p, jnp.sum(z_new * z_new)
