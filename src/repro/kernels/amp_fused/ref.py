"""Pure-jnp oracle for the fused AMP local-computation step."""
from __future__ import annotations

import jax.numpy as jnp


def amp_local_ref(a, x, y, z, onsager, n_proc: int):
    """Paper Sec. 3.1 LC step for one processor:

        z' = y - A x + onsager * z
        f  = x / P + A^T z'

    a: (M, N); x: (N,); y, z: (M,). Returns (z', f)."""
    z_new = y - a @ x + onsager * z
    f = x / n_proc + a.T @ z_new
    return z_new, f
