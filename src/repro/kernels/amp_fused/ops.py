"""Wrapper for the fused AMP LC kernel: padding + backend dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .amp_fused import BM, BN, amp_local_pallas
from .ref import amp_local_ref

__all__ = ["amp_local_step"]


def amp_local_step(a, x, y, z, onsager, n_proc: int,
                   use_pallas: bool | None = None, interpret: bool = False):
    """Fused z'/f computation for one processor's LC step (padded+dispatched)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return amp_local_ref(a, x, y, z, onsager, n_proc)
    m, n = a.shape
    pm, pn = (-m) % BM, (-n) % BN
    ap = jnp.pad(a, ((0, pm), (0, pn)))
    xp = jnp.pad(x, (0, pn))
    yp = jnp.pad(y, (0, pm))
    zp = jnp.pad(z, (0, pm))
    z_new, f = amp_local_pallas(ap, xp, yp, zp, onsager, n_proc,
                                interpret=interpret)
    # padded x rows contribute x/P to padded f entries only; slice them away
    return z_new[:m], f[:n]
