"""Dispatch + tile alignment for the fused AMP LC kernel suite.

The engine calls the ``*_grid`` entry points with *pre-aligned* operands:
padding of the (M, N)-sized sensing operand happens once at solve entry
(``pad_row_shards`` / ``pad_col_shards`` — host-side numpy for the
homogeneous paths, one jnp pad outside the scan for the heterogeneous
wrappers), never inside the scanned iteration body (tests assert the
jaxpr). Zero-padding is exact end-to-end: padded rows/columns of A are
zero, so residuals/messages in the padded region are identically zero and
every transport maps 0 -> 0.

Tile sizes adapt to the problem (``row_tiles``): full 128 x 512 MXU tiles
when the shard is big enough, shrinking to the (8, 128) f32 minimum so
serving-sized shards (e.g. Mp = 32) do not pay 4x padded compute.

``amp_local_step`` keeps the v1 single-shard signature (pads per call) for
per-op tests and external callers; the engine no longer uses it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .amp_fused import BM, BN, amp_local_pallas_grid
from .col import col_inner_pallas, col_residual_pallas, eta_bg_and_deriv
from .ref import (amp_local_ref, amp_local_ref_grid, amp_local_ref_vmap,
                  col_inner_step_ref, col_residual_ref)

__all__ = [
    "amp_local_step", "amp_local_grid", "col_residual", "col_inner_step",
    "row_tiles", "col_tiles", "pad_row_shards", "pad_col_shards",
    "eta_bg_and_deriv",
]


def _round_up(v: int, q: int) -> int:
    return -(-v // q) * q


def _balanced_tile(dim: int, full: int, quantum: int) -> int:
    """Largest-tile-<= ``full`` split of ``dim`` into near-equal
    ``quantum``-aligned tiles: k = ceil(dim/full) tiles of
    round_up(dim/k, quantum). Caps padding waste at quantum-1 rows per
    tile instead of up to full-1 (e.g. Mp=150 pads to 160, not 256)."""
    dim = max(dim, 1)
    k = -(-dim // full)
    return _round_up(-(-dim // k), quantum)


def row_tiles(mp: int, n: int) -> tuple[int, int]:
    """(bm, bn) for a (P, Mp, N) row-shard stack: (128, 512) MXU tiles at
    large shards, balanced smaller tiles (8/128-aligned minimum) so small
    or slightly-off serving shards pad by at most one quantum per tile."""
    return _balanced_tile(mp, BM, 8), _balanced_tile(n, BN, 128)


def col_tiles(m: int) -> int:
    """bm for a (P, M, Np) column-shard stack (Np rides untiled)."""
    return _balanced_tile(m, BM, 8)


def pad_row_shards(a_p, y_p):
    """Align a (..., P, Mp, N) row-shard stack (+ matching y, or None) to
    kernel tiles with zero padding. Works on numpy or jax arrays; no-op
    (returns the inputs unchanged) when already aligned."""
    mp_, n = a_p.shape[-2], a_p.shape[-1]
    bm, bn = row_tiles(mp_, n)
    dm, dn = _round_up(mp_, bm) - mp_, _round_up(n, bn) - n
    if dm == 0 and dn == 0:
        return a_p, y_p
    xp = np if isinstance(a_p, np.ndarray) else jnp
    nd = a_p.ndim
    a_p = xp.pad(a_p, [(0, 0)] * (nd - 2) + [(0, dm), (0, dn)])
    if y_p is not None:
        y_p = xp.pad(y_p, [(0, 0)] * (y_p.ndim - 1) + [(0, dm)])
    return a_p, y_p


def pad_col_shards(a_cp, y):
    """Align a (..., P, M, Np) column-shard stack (+ shared y) to kernel
    tiles: M is zero-padded to the tile multiple, Np rides untiled."""
    m = a_cp.shape[-2]
    dm = _round_up(m, col_tiles(m)) - m
    if dm == 0:
        return a_cp, y
    xp = np if isinstance(a_cp, np.ndarray) else jnp
    nd = a_cp.ndim
    a_cp = xp.pad(a_cp, [(0, 0)] * (nd - 2) + [(0, dm), (0, 0)])
    y = xp.pad(y, [(0, 0)] * (y.ndim - 1) + [(0, dm)])
    return a_cp, y


def amp_local_grid(a_p, x, y_p, z_p, onsager, n_proc: int,
                   use_pallas: bool | None = None, interpret: bool = False):
    """Batched-grid fused LC step over the whole (P, Mp, N) shard stack.

    Returns ``(z_new (P, Mp), f_p (P, N), ss ())`` — ``ss`` the fused
    sigma2_hat numerator ``sum(z_new**2)``. Pallas path requires
    tile-aligned shards (``pad_row_shards``); x must match N. A may be
    bf16 (upcast in VMEM / promoted by the reference einsum).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return amp_local_ref_grid(a_p, x, y_p, z_p, onsager, n_proc)
    bm, bn = row_tiles(a_p.shape[1], a_p.shape[2])
    return amp_local_pallas_grid(a_p, x, y_p, z_p, onsager, n_proc,
                                 interpret=interpret, bm=bm, bn=bn)


def col_residual(a_cp, x, use_pallas: bool | None = None,
                 interpret: bool = False):
    """Column-layout residual contributions ``r_p = A_p x_p`` (P, M)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return col_residual_ref(a_cp, x)
    return col_residual_pallas(a_cp, x, interpret=interpret,
                               bm=col_tiles(a_cp.shape[1]))


def col_inner_step(a_cp, x, x0, z_p, g, n_mask, m_eff, eps, mu_s, sigma_s2,
                   update_z: bool, use_pallas: bool | None = None,
                   interpret: bool = False):
    """One fused C-MP-AMP inner iteration (message + denoise + optional
    residual update); see ``col.col_inner_pallas``. ``n_mask`` is a
    (Np,) 0/1 mask of real columns (pass all-ones when unpadded)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return col_inner_step_ref(a_cp, x, x0, z_p, g, n_mask, m_eff,
                                  eps, mu_s, sigma_s2, update_z)
    return col_inner_pallas(a_cp, x, x0, z_p, g, n_mask, m_eff, eps, mu_s,
                            sigma_s2, update_z, interpret=interpret,
                            bm=col_tiles(a_cp.shape[1]))


def amp_local_step(a, x, y, z, onsager, n_proc: int,
                   use_pallas: bool | None = None, interpret: bool = False):
    """Fused z'/f computation for one processor's LC step (v1 signature:
    pads per call, single (M, N) shard)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return amp_local_ref(a, x, y, z, onsager, n_proc)
    m, n = a.shape
    ap, yp = pad_row_shards(a[None], y[None])
    xp_ = jnp.pad(x, (0, ap.shape[2] - n))
    zp = jnp.pad(z, (0, ap.shape[1] - m))[None]
    bm, bn = row_tiles(ap.shape[1], ap.shape[2])
    z_new, f, _ = amp_local_pallas_grid(jnp.asarray(ap), xp_,
                                        jnp.asarray(yp), zp, onsager, n_proc,
                                        interpret=interpret, bm=bm, bn=bn)
    # padded x rows contribute x/P to padded f entries only; slice them away
    return z_new[0, :m], f[0, :n]
