"""Pallas TPU kernels for the AMP local-computation (LC) step.

The LC step is two matvecs against the same sensing-matrix shard A^p:
    z' = y - A x + b z          (contraction over N)
    f  = x/P + A^T z'           (contraction over M)

TPU adaptation (DESIGN.md §2): the CS literature runs this as two BLAS calls
with A read from HBM twice. Here each kernel streams A through VMEM in
MXU-aligned (128 x 512) tiles and fuses the residual elementwise work
(y - . + b*z, x/P + .) into the same pass, so A is read exactly twice per
iteration (information-theoretic minimum for the two contraction orders) and
z'/f never round-trip to HBM in between tiles.

Grid conventions: the reduction axis is the *last* grid dim (sequential on
TPU), accumulating into the output tile with an init at step 0.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 128   # rows of A per tile (M axis)
BN = 512   # cols of A per tile (N axis)


def _z_kernel(ons_ref, a_ref, x_ref, y_ref, z_ref, o_ref):
    """o[m] = y[m] - sum_n A[m,n] x[n] + onsager * z[m]; grid (M/BM, N/BN)."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = y_ref[...] + ons_ref[0] * z_ref[...]

    a = a_ref[...]
    x = x_ref[...]
    o_ref[...] -= jax.lax.dot_general(
        a, x[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]


def _f_kernel(a_ref, z_ref, x_ref, o_ref, *, inv_p):
    """o[n] = x[n]/P + sum_m A[m,n] z'[m]; grid (N/BN, M/BM)."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = inv_p * x_ref[...]

    a = a_ref[...]          # (BM, BN) tile
    z = z_ref[...]          # (BM,)
    o_ref[...] += jax.lax.dot_general(
        z[None, :], a, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[0]


@partial(jax.jit, static_argnames=("n_proc", "interpret"))
def amp_local_pallas(a, x, y, z, onsager, n_proc: int, interpret: bool = False):
    """Fused LC step. a (M, N) with M % BM == 0, N % BN == 0 (ops.py pads)."""
    m, n = a.shape
    ons = jnp.asarray(onsager, jnp.float32).reshape(1)

    z_new = pl.pallas_call(
        _z_kernel,
        grid=(m // BM, n // BN),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
            pl.BlockSpec((BN,), lambda i, j: (j,)),
            pl.BlockSpec((BM,), lambda i, j: (i,)),
            pl.BlockSpec((BM,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((BM,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=interpret,
    )(ons, a, x, y, z)

    f = pl.pallas_call(
        partial(_f_kernel, inv_p=1.0 / n_proc),
        grid=(n // BN, m // BM),
        in_specs=[
            pl.BlockSpec((BM, BN), lambda i, j: (j, i)),
            pl.BlockSpec((BM,), lambda i, j: (j,)),
            pl.BlockSpec((BN,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((BN,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(a, z_new, x)
    return z_new, f
