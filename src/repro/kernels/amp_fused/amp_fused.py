"""Pallas TPU kernels for the AMP local-computation (LC) step — batched
grids over the full processor stack (kernel suite v2).

The LC step is two matvecs against the same sensing-matrix shard A^p:
    z' = y - A x + b z          (contraction over N)
    f  = x/P + A^T z'           (contraction over M/P)

v1 ran one (M, N) shard per ``pallas_call`` and the engine ``vmap``ed it
over the processor axis P (and again over the request batch B), so each
(b, p) cell was its own grid. v2 folds P into the Pallas grid as a leading
parallel dimension — one launch covers the whole (P, M/P, N) stack with
the same VMEM tiling — and fuses the sigma2_hat sum-of-squares reduction
into the z-pass (the plug-in numerator accumulates into a scalar output as
each z tile completes, so z' is never re-read from HBM for the reduction).
The request batch B enters the grid through the ``pallas_call`` vmap
batching rule, which prepends a grid axis: a ``solve_many``/``solve_het``
batch is still a single kernel launch.

A may be stored in bf16 (``EngineConfig.a_dtype``): tiles stream from HBM
at half width and are upcast to f32 in VMEM before hitting the MXU, so
accumulation precision is unchanged while HBM traffic on the dominant
operand halves.

Grid conventions: the reduction axis is the *last* grid dim (sequential on
TPU), accumulating into the output tile with an init at step 0. The scalar
``ss`` output maps every grid step to the same (1,) block, which is only
race-free because no grid dimension is declared parallel — revisit this if
``dimension_semantics`` ever marks P parallel on real hardware.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 128   # rows of A per tile (M axis)
BN = 512   # cols of A per tile (N axis)


def _z_kernel(ons_ref, a_ref, x_ref, y_ref, z_ref, o_ref, ss_ref, *, nj):
    """o[p,m] = y[p,m] - sum_n A[p,m,n] x[n] + onsager * z[p,m];
    grid (P, Mp/BM, N/BN); ss accumulates sum(o**2) as tiles complete."""
    p, i, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[0] = y_ref[0] + ons_ref[0] * z_ref[0]

    a = a_ref[0].astype(jnp.float32)
    x = x_ref[...]
    o_ref[0] -= jax.lax.dot_general(
        a, x[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]

    @pl.when(j == nj - 1)
    def _reduce():
        zb = o_ref[0]
        s = jnp.sum(zb * zb)
        first = (p == 0) & (i == 0)

        @pl.when(first)
        def _first():
            ss_ref[0] = s

        @pl.when(~first)
        def _acc():
            ss_ref[0] += s


def _f_kernel(a_ref, z_ref, x_ref, o_ref, *, inv_p):
    """o[p,n] = x[n]/P + sum_m A[p,m,n] z'[p,m]; grid (P, N/BN, Mp/BM)."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[0] = inv_p * x_ref[...]

    a = a_ref[0].astype(jnp.float32)    # (BM, BN) tile
    z = z_ref[0]                         # (BM,)
    o_ref[0] += jax.lax.dot_general(
        z[None, :], a, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[0]


@partial(jax.jit, static_argnames=("n_proc", "interpret", "bm", "bn"))
def amp_local_pallas_grid(a_p, x, y_p, z_p, onsager, n_proc: int,
                          interpret: bool = False,
                          bm: int = BM, bn: int = BN):
    """Batched-grid fused LC step over the full processor stack.

    a_p (P, Mp, N) with Mp % bm == 0 and N % bn == 0 (``ops.py`` aligns),
    f32 or bf16; x (N,); y_p, z_p (P, Mp) f32. Returns
    ``(z_new (P, Mp), f (P, N), ss ())`` with ``ss = sum(z_new**2)``.
    """
    p, mp_, n = a_p.shape
    assert mp_ % bm == 0 and n % bn == 0, (a_p.shape, bm, bn)
    ni, nj = mp_ // bm, n // bn
    ons = jnp.asarray(onsager, jnp.float32).reshape(1)

    z_new, ss = pl.pallas_call(
        partial(_z_kernel, nj=nj),
        grid=(p, ni, nj),
        in_specs=[
            pl.BlockSpec((1,), lambda p, i, j: (0,)),
            pl.BlockSpec((1, bm, bn), lambda p, i, j: (p, i, j)),
            pl.BlockSpec((bn,), lambda p, i, j: (j,)),
            pl.BlockSpec((1, bm), lambda p, i, j: (p, i)),
            pl.BlockSpec((1, bm), lambda p, i, j: (p, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm), lambda p, i, j: (p, i)),
            pl.BlockSpec((1,), lambda p, i, j: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, mp_), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=interpret,
    )(ons, a_p, x, y_p, z_p)

    f = pl.pallas_call(
        partial(_f_kernel, inv_p=1.0 / n_proc),
        grid=(p, n // bn, ni),
        in_specs=[
            pl.BlockSpec((1, bm, bn), lambda p, i, j: (p, j, i)),
            pl.BlockSpec((1, bm), lambda p, i, j: (p, j)),
            pl.BlockSpec((bn,), lambda p, i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda p, i, j: (p, i)),
        out_shape=jax.ShapeDtypeStruct((p, n), jnp.float32),
        interpret=interpret,
    )(a_p, z_new, x)
    return z_new, f, ss[0]


@partial(jax.jit, static_argnames=("n_proc", "interpret"))
def amp_local_pallas(a, x, y, z, onsager, n_proc: int, interpret: bool = False):
    """Single-shard fused LC step (v1 signature, kept for the per-op tests
    and external callers). a (M, N) with M % BM == 0, N % BN == 0."""
    z_new, f, _ = amp_local_pallas_grid(a[None], x, y[None], z[None],
                                        onsager, n_proc, interpret=interpret)
    return z_new[0], f[0]
