"""Pallas kernels for the column-layout (C-MP-AMP) LC hot path.

Two kernels cover the per-round A-touching work of ``engine._col_round``
/ ``_col_inner`` (DESIGN.md §7/§8):

* ``col_residual_pallas`` — the fused residual contributions
  ``r_p = A_p x_p`` over (M, N/P) column blocks, P folded into the grid.
* ``col_inner_pallas`` — one C-MP-AMP inner iteration in a single VMEM
  pass over A_p per contraction: stage 0 streams A_p once accumulating
  the message ``f_p = x_p + A_p^T z_p`` *and* the plug-in numerator
  ``||z_p||^2``, then (at the final M tile, with f_p still in VMEM)
  applies the Bernoulli-Gauss conditional-mean denoiser and its
  derivative sum ``c_p`` in closed form; stage 1 streams A_p a second
  time for the residual update ``z_p <- g - A_p (x' - x_p^0) + c_p z_p``.
  A is read exactly twice per inner iteration — the same
  information-theoretic minimum as the row kernels — and f_p / x' / c_p
  never round-trip to HBM between the stages' tiles (they live in
  revisited output blocks).

The denoiser runs *in-kernel*, so its derivative cannot come from
``jax.grad``: the closed form lives beside the prior math as
``denoisers.eta_bg_and_deriv`` (one home for the Bernoulli-Gauss
formulas; pinned against ``jax.grad`` in tests/test_kernels_col.py) and
is re-exported here for kernel callers.

Blocking: A_p tiles are (BM, Np) — the full per-processor column slice
rides in VMEM (Np * BM * 4B per tile; Np beyond ~16k would need a second
tiling level, far past the serving shapes). Scalar parameters travel as a
packed (4,) operand ``[m_eff, eps, mu_s, sigma_s2]`` so the same compiled
kernel serves traced per-instance priors (the heterogeneous path). A may
be bf16 (upcast in VMEM, f32 accumulation).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .amp_fused import BM


def eta_bg_and_deriv(f, sigma2, eps, mu_s, sigma_s2):
    """Re-export of ``denoisers.eta_bg_and_deriv`` (the single home of
    the Bernoulli-Gauss closed forms) for kernel callers. Imported
    lazily: ``core.engine`` imports this package at module load, so a
    top-level ``core.denoisers`` import here would be circular."""
    from ...core.denoisers import eta_bg_and_deriv as _impl
    return _impl(f, sigma2, eps, mu_s, sigma_s2)


def _col_r_kernel(a_ref, x_ref, o_ref):
    """o[p,m] = sum_n A[p,m,n] x[p,n]; grid (P, M/BM), full-Np tiles."""
    a = a_ref[0].astype(jnp.float32)     # (BM, Np)
    x = x_ref[0]                          # (Np,)
    o_ref[0] = jax.lax.dot_general(
        a, x[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]


@partial(jax.jit, static_argnames=("interpret", "bm"))
def col_residual_pallas(a_cp, x, interpret: bool = False, bm: int = BM):
    """r_p = A_p x_p. a_cp (P, M, Np) with M % bm == 0; x (P, Np)."""
    p, m, np_ = a_cp.shape
    assert m % bm == 0, (a_cp.shape, bm)
    return pl.pallas_call(
        _col_r_kernel,
        grid=(p, m // bm),
        in_specs=[
            pl.BlockSpec((1, bm, np_), lambda p, i: (p, i, 0)),
            pl.BlockSpec((1, np_), lambda p, i: (p, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm), lambda p, i: (p, i)),
        out_shape=jax.ShapeDtypeStruct((p, m), jnp.float32),
        interpret=interpret,
    )(a_cp, x)


def _col_inner_kernel(par_ref, a_ref, x_ref, x0_ref, z_ref, g_ref, mask_ref,
                      xo_ref, co_ref, fo_ref, sso_ref, zo_ref=None,
                      *, ni, update_z):
    """One inner iteration; grid (P, 2, M/BM) (stage axis dropped when
    ``update_z`` is False). Stage 0 accumulates f/||z||^2 over M tiles and
    denoises at the last; stage 1 writes the updated residual tiles."""
    if update_z:
        s, i = pl.program_id(1), pl.program_id(2)
    else:
        s, i = 0, pl.program_id(1)
    a = a_ref[0].astype(jnp.float32)      # (BM, Np)

    @pl.when((s == 0) & (i == 0))
    def _init():
        fo_ref[0] = x_ref[0]
        sso_ref[0] = 0.0

    @pl.when(s == 0)
    def _accumulate():
        z = z_ref[0]                       # (BM,)
        fo_ref[0] += jax.lax.dot_general(
            z[None, :], a, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[0]
        sso_ref[0] += jnp.sum(z * z)

    @pl.when((s == 0) & (i == ni - 1))
    def _denoise():
        m_eff, eps, mu_s, s2s = (par_ref[0], par_ref[1], par_ref[2],
                                 par_ref[3])
        s2 = jnp.maximum(sso_ref[0] / m_eff, 1e-30)
        val, deriv = eta_bg_and_deriv(fo_ref[0], s2, eps, mu_s, s2s)
        mask = mask_ref[...]
        xo_ref[0] = val * mask
        co_ref[0] = jnp.sum(deriv * mask) / m_eff

    if update_z:
        @pl.when(s == 1)
        def _residual():
            dx = xo_ref[0] - x0_ref[0]
            zo_ref[0] = (g_ref[...] - jax.lax.dot_general(
                a, dx[:, None], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)[:, 0]
                + co_ref[0] * z_ref[0])


@partial(jax.jit, static_argnames=("update_z", "interpret", "bm"))
def col_inner_pallas(a_cp, x, x0, z_p, g, n_mask, m_eff, eps, mu_s, sigma_s2,
                     update_z: bool, interpret: bool = False, bm: int = BM):
    """Fused C-MP-AMP inner iteration (see module docstring).

    a_cp (P, M, Np), M % bm == 0; x, x0 (P, Np); z_p (P, M); g (M,);
    n_mask (Np,). Scalars may be traced. Returns ``(x_new (P, Np),
    c_p (P,), z_new (P, M))``; ``z_new`` is ``z_p`` unchanged when
    ``update_z`` is False (the final inner iteration keeps the residual
    that fed the denoise — the Onsager boundary carry).
    """
    p, m, np_ = a_cp.shape
    assert m % bm == 0, (a_cp.shape, bm)
    ni = m // bm
    par = jnp.stack([jnp.asarray(v, jnp.float32).reshape(())
                     for v in (m_eff, eps, mu_s, sigma_s2)])

    if update_z:
        ix = lambda fn: fn                 # index maps take (p, s, i)
        grid = (p, 2, ni)
    else:
        # no stage axis: wrap the 3-arg index maps with s pinned to 0
        ix = lambda fn: (lambda p, i, fn=fn: fn(p, 0, i))
        grid = (p, ni)

    in_specs = [
        pl.BlockSpec((4,), ix(lambda p, s, i: (0,))),
        pl.BlockSpec((1, bm, np_), ix(lambda p, s, i: (p, i, 0))),
        pl.BlockSpec((1, np_), ix(lambda p, s, i: (p, 0))),
        pl.BlockSpec((1, np_), ix(lambda p, s, i: (p, 0))),
        pl.BlockSpec((1, bm), ix(lambda p, s, i: (p, i))),
        pl.BlockSpec((bm,), ix(lambda p, s, i: (i,))),
        pl.BlockSpec((np_,), ix(lambda p, s, i: (0,))),
    ]
    out_specs = [
        pl.BlockSpec((1, np_), ix(lambda p, s, i: (p, 0))),
        pl.BlockSpec((1,), ix(lambda p, s, i: (p,))),
        pl.BlockSpec((1, np_), ix(lambda p, s, i: (p, 0))),
        pl.BlockSpec((1,), ix(lambda p, s, i: (p,))),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((p, np_), jnp.float32),   # x_new
        jax.ShapeDtypeStruct((p,), jnp.float32),       # c_p
        jax.ShapeDtypeStruct((p, np_), jnp.float32),   # f accumulator
        jax.ShapeDtypeStruct((p,), jnp.float32),       # ||z||^2 accumulator
    ]
    if update_z:
        out_specs.append(pl.BlockSpec((1, bm), lambda p, s, i: (p, i)))
        out_shape.append(jax.ShapeDtypeStruct((p, m), jnp.float32))

    outs = pl.pallas_call(
        partial(_col_inner_kernel, ni=ni, update_z=update_z),
        grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=interpret,
    )(par, a_cp, x, x0, z_p, g, n_mask)
    x_new, c_p = outs[0], outs[1]
    z_new = outs[4] if update_z else z_p
    return x_new, c_p, z_new
