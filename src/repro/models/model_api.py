"""Unified model interface over the zoo families.

Every architecture exposes:
    schema(cfg)                         -> flat param Schema
    forward(params, tokens, cfg, mode, **aux) -> (hidden, caches/state)
    decode_step(params, tokens, state, pos, cfg) -> (hidden, state)
    init_state(cfg, batch, max_len)     -> decode cache/state pytree
    logits(params, hidden, cfg)         -> vocab logits (or use chunked loss)
plus ``aux_inputs(cfg, batch, seq)`` describing extra stub-frontend inputs
(whisper frames / vlm patch embeddings) as ShapeDtypeStructs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import rglru, rwkv6, transformer, whisper
from .layers import init_from_schema, specs_from_schema

__all__ = ["ModelBundle", "get_model", "lm_logits", "chunked_xent_loss"]


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    schema: dict
    forward: Callable
    decode_step: Callable
    init_state: Callable

    def init_params(self, key):
        return init_from_schema(self.schema, key)

    def param_specs(self):
        return specs_from_schema(self.schema)

    def aux_inputs(self, batch: int, seq: int) -> dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        if cfg.family == "whisper":
            return {"frames": jax.ShapeDtypeStruct(
                (batch, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)}
        if cfg.n_vision_tokens:
            return {"vision_embeds": jax.ShapeDtypeStruct(
                (batch, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)}
        return {}


def get_model(cfg: ModelConfig) -> ModelBundle:
    if cfg.family in ("dense", "moe"):
        return ModelBundle(
            cfg=cfg, schema=transformer.dense_schema(cfg),
            forward=transformer.dense_forward,
            decode_step=transformer.dense_decode_step,
            init_state=lambda c, b, s: transformer.init_cache(c, b, s))
    if cfg.family == "rwkv6":
        return ModelBundle(
            cfg=cfg, schema=rwkv6.rwkv6_schema(cfg),
            forward=rwkv6.rwkv6_forward,
            decode_step=rwkv6.rwkv6_decode_step,
            init_state=lambda c, b, s: rwkv6.rwkv6_init_state(c, b))
    if cfg.family == "rglru":
        return ModelBundle(
            cfg=cfg, schema=rglru.rglru_schema(cfg),
            forward=rglru.rglru_forward,
            decode_step=rglru.rglru_decode_step,
            init_state=rglru.rglru_init_state)
    if cfg.family == "whisper":
        return ModelBundle(
            cfg=cfg, schema=whisper.whisper_schema(cfg),
            forward=whisper.whisper_forward,
            decode_step=whisper.whisper_decode_step,
            init_state=lambda c, b, s: whisper.whisper_init_cache(c, b, s))
    raise ValueError(f"unknown family {cfg.family}")


def lm_logits(params, hidden, cfg: ModelConfig):
    """Full logits — only for small vocab / smoke paths."""
    table = params.get("lm_head/table", params["embed/table"])
    return jnp.einsum("bsd,vd->bsv", hidden, table,
                      preferred_element_type=jnp.float32)


def chunked_xent_loss(params, hidden, labels, cfg: ModelConfig,
                      chunk: int = 512, label_mask=None):
    """Cross-entropy without materializing (B, S, V) logits.

    Scans over sequence chunks; per chunk, logits (B, chunk, V) live briefly
    (sharded over 'vocab'); padded-vocab logits are masked to -inf.
    """
    from ..sharding import shard

    table = params.get("lm_head/table", params["embed/table"])
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    n_chunks = s // chunk
    h = hidden.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    lab = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)
    if label_mask is None:
        label_mask = jnp.ones_like(labels, jnp.float32)
    msk = label_mask.reshape(b, n_chunks, chunk).swapaxes(0, 1)
    vocab_ok = (jnp.arange(cfg.vocab_padded) < cfg.vocab)

    def body(acc, xs):
        hc, lc, mc = xs
        logits = jnp.einsum("bcd,vd->bcv", hc.astype(jnp.bfloat16), table,
                            preferred_element_type=jnp.float32)
        logits = shard(logits, "batch", "seq", "vocab")
        logits = jnp.where(vocab_ok[None, None, :], logits, -jnp.inf)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        loss = ((lse - gold) * mc).sum()
        return (acc[0] + loss, acc[1] + mc.sum()), ()

    # checkpoint: without it, autodiff saves every chunk's (B, c, V) logits
    # as scan residuals — exactly the materialization chunking exists to
    # avoid (found via HLO attribution; EXPERIMENTS.md §Perf gemma3 cell).
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable,
                          prevent_cse=False)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (h, lab, msk))
    return tot / jnp.maximum(cnt, 1.0)
