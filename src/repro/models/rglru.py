"""RecurrentGemma (Griffin) — RG-LRU recurrent blocks + local attention
[arXiv:2402.19427].

Block pattern (recurrent, recurrent, local-attn) repeating; 26 layers =
8 full macro-blocks + 2 trailing recurrent layers. The macro-blocks are
scanned (params stacked on a leading axis of 8); the tail has its own params.

RG-LRU: a_t = exp(c * softplus-free log sigmoid(Lambda) * r_t),
        h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
computed with jax.lax.associative_scan (log-depth, TPU friendly) for
train/prefill and a single step for decode. A depthwise conv1d (width 4)
precedes the recurrence, as in Griffin.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..sharding import shard
from .layers import (ParamSchema, Schema, apply_rope, embed_tokens, rms_norm,
                     rope_cache, swiglu)
from .transformer import _attention_flagged, _decode_attention_flagged

__all__ = ["rglru_schema", "rglru_forward", "rglru_decode_step",
           "rglru_init_state", "rg_lru_scan"]

_C_FACTOR = 8.0


def _macro_count(cfg):
    n_macro = cfg.n_layers // 3
    n_tail = cfg.n_layers - 3 * n_macro  # trailing recurrent layers
    return n_macro, n_tail


def _rec_schema(l: int, cfg, prefix: str, stacked: bool = True) -> Schema:
    d, w = cfg.d_model, cfg.lru_width
    cw = cfg.conv1d_width
    sh = (lambda *s: (l, *s)) if stacked else (lambda *s: s)
    ax = (lambda *a: ("layers", *a)) if stacked else (lambda *a: a)
    return {
        f"{prefix}/pre_norm": ParamSchema(sh(d), ax(None), init="zeros"),
        f"{prefix}/w_gate": ParamSchema(sh(d, w), ax("embed", "mlp")),
        f"{prefix}/w_in": ParamSchema(sh(d, w), ax("embed", "mlp")),
        f"{prefix}/conv_w": ParamSchema(sh(cw, w), ax(None, "mlp")),
        f"{prefix}/conv_b": ParamSchema(sh(w), ax("mlp"), init="zeros"),
        f"{prefix}/lambda": ParamSchema(sh(w), ax("mlp"), init="ones"),
        f"{prefix}/wa": ParamSchema(sh(w, w), ax("mlp", None)),
        f"{prefix}/wx": ParamSchema(sh(w, w), ax("mlp", None)),
        f"{prefix}/w_out": ParamSchema(sh(w, d), ax("mlp", "embed")),
        f"{prefix}/mlp_pre_norm": ParamSchema(sh(d), ax(None), init="zeros"),
        f"{prefix}/mlp_gate": ParamSchema(sh(d, cfg.d_ff), ax("embed", "mlp")),
        f"{prefix}/mlp_up": ParamSchema(sh(d, cfg.d_ff), ax("embed", "mlp")),
        f"{prefix}/mlp_down": ParamSchema(sh(cfg.d_ff, d), ax("mlp", "embed")),
    }


def rglru_schema(cfg) -> Schema:
    n_macro, n_tail = _macro_count(cfg)
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    vp = cfg.vocab_padded
    s: Schema = {
        "embed/table": ParamSchema((vp, d), ("vocab", "embed")),
        "final_norm/w": ParamSchema((d,), (None,), init="zeros"),
    }
    # two recurrent sub-layers per macro-block (stacked n_macro)
    for sub in ("rec0", "rec1"):
        s.update(_rec_schema(n_macro, cfg, f"macro/{sub}"))
    # one local-attention sub-layer per macro-block
    s.update({
        "macro/attn/pre_norm": ParamSchema((n_macro, d), ("layers", None), init="zeros"),
        "macro/attn/wq": ParamSchema((n_macro, d, h, dh), ("layers", "embed", "heads", "head_dim")),
        "macro/attn/wk": ParamSchema((n_macro, d, kv, dh), ("layers", "embed", "kv_heads", "head_dim")),
        "macro/attn/wv": ParamSchema((n_macro, d, kv, dh), ("layers", "embed", "kv_heads", "head_dim")),
        "macro/attn/wo": ParamSchema((n_macro, h, dh, d), ("layers", "heads", "head_dim", "embed")),
        "macro/attn/mlp_pre_norm": ParamSchema((n_macro, d), ("layers", None), init="zeros"),
        "macro/attn/mlp_gate": ParamSchema((n_macro, d, cfg.d_ff), ("layers", "embed", "mlp")),
        "macro/attn/mlp_up": ParamSchema((n_macro, d, cfg.d_ff), ("layers", "embed", "mlp")),
        "macro/attn/mlp_down": ParamSchema((n_macro, cfg.d_ff, d), ("layers", "mlp", "embed")),
    })
    for i in range(n_tail):
        s.update(_rec_schema(0, cfg, f"tail{i}", stacked=False))
    return s


def rg_lru_scan(x, a_log, gate_in):
    """h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t * x_t) via associative scan.

    x, a_log (=log a_t), gate_in: (B, T, W). Returns (h, last_h)."""
    a = jnp.exp(a_log)
    b_term = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gate_in * x

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, b_term), axis=1)
    return h, h[:, -1]


def _rec_block(x, p, cfg, state, decode: bool = False):
    """Griffin recurrent block + MLP. state: (conv_buf (B,cw-1,W), h (B,W))."""
    conv_buf, h_prev = state
    u = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", u, p["w_gate"],
                                  preferred_element_type=jnp.float32))
    xin = jnp.einsum("btd,dw->btw", u, p["w_in"],
                     preferred_element_type=jnp.bfloat16)
    xin = shard(xin, "batch", "seq", "mlp")

    # depthwise causal conv1d (width cw)
    cw = p["conv_w"].shape[0]
    seq = jnp.concatenate([conv_buf.astype(xin.dtype), xin], axis=1)
    conv = sum(seq[:, i:i + xin.shape[1]] * p["conv_w"][i] for i in range(cw))
    conv = conv + p["conv_b"]
    new_conv_buf = seq[:, -(cw - 1):] if cw > 1 else conv_buf

    # RG-LRU gates
    conv_f = conv.astype(jnp.float32)
    r_gate = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", conv, p["wa"],
                                       preferred_element_type=jnp.float32))
    i_gate = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", conv, p["wx"],
                                       preferred_element_type=jnp.float32))
    log_a_base = -_C_FACTOR * jax.nn.softplus(p["lambda"].astype(jnp.float32))
    a_log = log_a_base[None, None] * r_gate

    if decode:
        a = jnp.exp(a_log[:, 0])
        h_new = a * h_prev + jnp.sqrt(jnp.maximum(1 - a * a, 1e-12)) * \
            (i_gate[:, 0] * conv_f[:, 0])
        h_seq = h_new[:, None]
    else:
        h_seq, h_new = rg_lru_scan(conv_f, a_log, i_gate)
        # fold in carried state: h_t += (prod_{s<=t} a_s) * h_prev
        cum_a = jnp.exp(jnp.cumsum(a_log, axis=1))
        h_seq = h_seq + cum_a * h_prev[:, None]
        h_new = h_seq[:, -1]

    y = (gate * h_seq).astype(x.dtype)
    y = shard(y, "batch", "seq", "mlp")
    out = jnp.einsum("btw,wd->btd", y, p["w_out"],
                     preferred_element_type=jnp.bfloat16)
    x = x + out.astype(x.dtype)
    x = shard(x, "batch", "residual_seq", "residual_embed")
    # MLP
    u = rms_norm(x, p["mlp_pre_norm"], cfg.norm_eps)
    x = x + swiglu(u, p["mlp_gate"], p["mlp_up"], p["mlp_down"])
    x = shard(x, "batch", "residual_seq", "residual_embed")
    return x, (new_conv_buf, h_new)


def _sub(params, prefix):
    plen = len(prefix) + 1
    return {k[plen:]: v for k, v in params.items() if k.startswith(prefix + "/")}


def rglru_init_state(cfg, batch: int, max_len: int):
    n_macro, n_tail = _macro_count(cfg)
    w, cw = cfg.lru_width, cfg.conv1d_width
    rec = lambda n: {
        "conv": jnp.zeros((n, batch, cw - 1, w), jnp.bfloat16),
        "h": jnp.zeros((n, batch, w), jnp.float32),
    }
    return {
        "rec0": rec(n_macro), "rec1": rec(n_macro),
        "k": jnp.zeros((n_macro, batch, max_len, cfg.n_kv_heads, cfg.d_head), jnp.bfloat16),
        "v": jnp.zeros((n_macro, batch, max_len, cfg.n_kv_heads, cfg.d_head), jnp.bfloat16),
        "tail": rec(n_tail),
    }


def rglru_forward(params, tokens, cfg, mode: str = "train", state=None,
                  remat: bool = True, **_):
    b, t = tokens.shape
    n_macro, n_tail = _macro_count(cfg)
    x = embed_tokens(params["embed/table"], tokens, scale=True)
    sin, cos = rope_cache(t, cfg.d_head, cfg.rope_theta)
    ropes = (sin, cos, None, None)
    if state is None:
        state = rglru_init_state(cfg, b, 1 if mode == "train" else t)

    rec0, rec1 = _sub(params, "macro/rec0"), _sub(params, "macro/rec1")
    attn = _sub(params, "macro/attn")

    def macro_body(x, sl):
        p0, p1, pa, s0c, s0h, s1c, s1h = sl
        x, st0 = _rec_block(x, p0, cfg, (s0c, s0h))
        x, st1 = _rec_block(x, p1, cfg, (s1c, s1h))
        h = rms_norm(x, pa["pre_norm"], cfg.norm_eps)
        lp = {"wq": pa["wq"], "wk": pa["wk"], "wv": pa["wv"], "wo": pa["wo"]}
        a_out, kv = _attention_flagged(h, lp, cfg, jnp.asarray(True), sin, cos, None)
        x = x + a_out
        x = shard(x, "batch", "residual_seq", "residual_embed")
        u = rms_norm(x, pa["mlp_pre_norm"], cfg.norm_eps)
        x = x + swiglu(u, pa["mlp_gate"], pa["mlp_up"], pa["mlp_down"])
        x = shard(x, "batch", "residual_seq", "residual_embed")
        if mode == "train":  # don't stack KV caches during training
            return x, (st0, st1)
        return x, (st0, st1, kv)

    if mode == "train" and remat:
        macro_body = jax.checkpoint(macro_body,
                                    policy=jax.checkpoint_policies.nothing_saveable,
                                    prevent_cse=False)
    xs = (rec0, rec1, attn, state["rec0"]["conv"], state["rec0"]["h"],
          state["rec1"]["conv"], state["rec1"]["h"])
    if mode == "train":
        x, (st0, st1) = jax.lax.scan(macro_body, x, xs)
        kv = (None, None)
    else:
        x, (st0, st1, kv) = jax.lax.scan(macro_body, x, xs)

    tail_states = []
    for i in range(n_tail):
        x, sti = _rec_block(x, _sub(params, f"tail{i}"), cfg,
                            (state["tail"]["conv"][i], state["tail"]["h"][i]))
        tail_states.append(sti)
    x = rms_norm(x, params["final_norm/w"], cfg.norm_eps)
    if mode == "train":
        return x, None
    new_state = {
        "rec0": {"conv": st0[0], "h": st0[1]},
        "rec1": {"conv": st1[0], "h": st1[1]},
        "k": kv[0], "v": kv[1],
        "tail": {"conv": jnp.stack([s[0] for s in tail_states]) if n_tail else state["tail"]["conv"],
                 "h": jnp.stack([s[1] for s in tail_states]) if n_tail else state["tail"]["h"]},
    }
    return x, new_state


def rglru_decode_step(params, tokens, state, pos, cfg, **_):
    b = tokens.shape[0]
    n_macro, n_tail = _macro_count(cfg)
    x = embed_tokens(params["embed/table"], tokens, scale=True)
    pos_arr = jnp.asarray([pos])
    sin, cos = rope_cache(1, cfg.d_head, cfg.rope_theta, positions=pos_arr)

    rec0, rec1 = _sub(params, "macro/rec0"), _sub(params, "macro/rec1")
    attn = _sub(params, "macro/attn")

    def macro_body(x, sl):
        p0, p1, pa, s0c, s0h, s1c, s1h, k_c, v_c = sl
        x, st0 = _rec_block(x, p0, cfg, (s0c, s0h), decode=True)
        x, st1 = _rec_block(x, p1, cfg, (s1c, s1h), decode=True)
        h = rms_norm(x, pa["pre_norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, pa["wq"], preferred_element_type=jnp.bfloat16)
        k = jnp.einsum("bsd,dhk->bshk", h, pa["wk"], preferred_element_type=jnp.bfloat16)
        v = jnp.einsum("bsd,dhk->bshk", h, pa["wv"], preferred_element_type=jnp.bfloat16)
        q, k = apply_rope(q, sin, cos), apply_rope(k, sin, cos)
        k_c = jax.lax.dynamic_update_slice(k_c, k.astype(k_c.dtype), (0, pos, 0, 0))
        v_c = jax.lax.dynamic_update_slice(v_c, v.astype(v_c.dtype), (0, pos, 0, 0))
        k_c = shard(k_c, "batch", "kv_seq", "kv_heads", "head_dim")
        v_c = shard(v_c, "batch", "kv_seq", "kv_heads", "head_dim")
        ctx = _decode_attention_flagged(q, k_c, v_c, pos, cfg, jnp.asarray(True))
        a_out = jnp.einsum("bshk,hkd->bsd", ctx, pa["wo"],
                           preferred_element_type=jnp.bfloat16)
        x = x + a_out.astype(x.dtype)
        u = rms_norm(x, pa["mlp_pre_norm"], cfg.norm_eps)
        x = x + swiglu(u, pa["mlp_gate"], pa["mlp_up"], pa["mlp_down"])
        return x, (st0, st1, (k_c, v_c))

    xs = (rec0, rec1, attn, state["rec0"]["conv"], state["rec0"]["h"],
          state["rec1"]["conv"], state["rec1"]["h"], state["k"], state["v"])
    x, (st0, st1, kv) = jax.lax.scan(macro_body, x, xs)

    tail_states = []
    for i in range(n_tail):
        x, sti = _rec_block(x, _sub(params, f"tail{i}"), cfg,
                            (state["tail"]["conv"][i], state["tail"]["h"][i]),
                            decode=True)
        tail_states.append(sti)
    x = rms_norm(x, params["final_norm/w"], cfg.norm_eps)
    new_state = {
        "rec0": {"conv": st0[0], "h": st0[1]},
        "rec1": {"conv": st1[0], "h": st1[1]},
        "k": kv[0], "v": kv[1],
        "tail": {"conv": jnp.stack([s[0] for s in tail_states]) if n_tail else state["tail"]["conv"],
                 "h": jnp.stack([s[1] for s in tail_states]) if n_tail else state["tail"]["h"]},
    }
    return x, new_state
