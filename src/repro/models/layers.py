"""Shared neural layers for the model zoo (pure JAX, bf16 + fp32 numerics).

Conventions:
  * Params live in a *flat* dict[str, Array] with '/'-joined paths; per-layer
    weights are stacked on a leading `layers` axis and consumed via lax.scan.
  * Tensor layout: activations (B, S, D); attention heads (B, S, H, Dh);
    KV caches (B, S_max, KV, Dh).
  * Norms/softmax in fp32, matmuls in bf16 with fp32 accumulation
    (preferred_element_type).
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..sharding import shard

__all__ = ["ParamSchema", "init_from_schema", "specs_from_schema", "rms_norm",
           "rope_cache", "apply_rope", "mrope_positions", "attention",
           "decode_attention", "swiglu", "embed_tokens", "Schema"]

Schema = dict  # path -> ParamSchema


class ParamSchema(NamedTuple):
    shape: tuple
    axes: tuple            # logical axis names, len == len(shape)
    std: float = 0.02
    init: str = "normal"   # normal | zeros | ones


def init_from_schema(schema: Schema, key, dtype=jnp.bfloat16):
    """Materialize a flat param dict from a schema (deterministic per path)."""
    params = {}
    for i, (path, ps) in enumerate(sorted(schema.items())):
        k = jax.random.fold_in(key, i)
        if ps.init == "zeros":
            params[path] = jnp.zeros(ps.shape, dtype)
        elif ps.init == "ones":
            params[path] = jnp.ones(ps.shape, dtype)
        else:
            params[path] = (ps.std * jax.random.normal(k, ps.shape, jnp.float32)).astype(dtype)
    return params


def specs_from_schema(schema: Schema) -> dict:
    return {path: ps.axes for path, ps in schema.items()}


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------

def head_mask(cfg, dtype=jnp.float32):
    """Activity mask (h_eff,) for padded attention heads.

    Heads are grouped (KV-major); within each effective group of
    g_eff = h_eff/kv_eff slots, the first g_real = n_heads/n_kv_heads are
    real. Zeroing padded slots after PV makes the padded model exactly the
    unpadded function (dead params get zero gradients)."""
    h_eff, kv_eff = cfg.h_eff, cfg.kv_eff
    if h_eff == cfg.n_heads and kv_eff == cfg.n_kv_heads:
        return None
    g_eff = h_eff // kv_eff
    g_real = cfg.n_heads // cfg.n_kv_heads
    idx = jnp.arange(h_eff)
    active = ((idx // g_eff) < cfg.n_kv_heads) & ((idx % g_eff) < g_real)
    return active.astype(dtype)


def rms_norm(x, weight, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + weight.astype(jnp.float32))
    return out.astype(x.dtype)


def rope_cache(seq_len: int, d_head: int, theta: float, dtype=jnp.float32,
               positions=None):
    """(sin, cos) of shape (S, Dh/2) — split-half rotary convention."""
    half = d_head // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions is None:
        positions = jnp.arange(seq_len, dtype=jnp.float32)
    ang = positions[..., None] * freqs  # (..., S, half)
    return jnp.sin(ang).astype(dtype), jnp.cos(ang).astype(dtype)


def apply_rope(x, sin, cos):
    """x: (B, S, H, Dh); sin/cos: (S, Dh/2) or (B, S, Dh/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if sin.ndim == 2:
        sin = sin[None, :, None, :]
        cos = cos[None, :, None, :]
    else:  # (B, S, half) — m-rope merged
        sin = sin[:, :, None, :]
        cos = cos[:, :, None, :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


def mrope_positions(batch: int, seq_len: int, n_vision: int, grid: int | None = None):
    """Qwen2-VL M-RoPE position ids (3, B, S): (temporal, height, width).

    Vision tokens occupy the first ``n_vision`` positions as a sqrt grid;
    text follows sequentially (all three components equal, offset past the
    max vision position) — matching the M-RoPE text continuation rule.
    """
    if n_vision == 0:
        pos = jnp.arange(seq_len, dtype=jnp.float32)
        return jnp.broadcast_to(pos, (3, batch, seq_len))
    g = grid or max(int(math.sqrt(n_vision)), 1)
    idx = jnp.arange(n_vision)
    t_vis = jnp.zeros(n_vision, jnp.float32)
    h_vis = (idx // g).astype(jnp.float32)
    w_vis = (idx % g).astype(jnp.float32)
    text_start = float(g)  # max(h,w) + 1
    t_txt = text_start + jnp.arange(seq_len - n_vision, dtype=jnp.float32)
    pos3 = jnp.stack([
        jnp.concatenate([t_vis, t_txt]),
        jnp.concatenate([h_vis, t_txt]),
        jnp.concatenate([w_vis, t_txt]),
    ])  # (3, S)
    return jnp.broadcast_to(pos3[:, None, :], (3, batch, seq_len))


def mrope_cache(positions3, d_head: int, theta: float, sections=(16, 24, 24)):
    """Merge 3-component positions into per-token (sin, cos) of (B, S, Dh/2)."""
    half = d_head // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # component id per frequency slot -> gather per-slot positions (B, S, half)
    comp = jnp.repeat(jnp.arange(3), jnp.asarray(sections), total_repeat_length=half)
    per_slot = jnp.einsum("cbs,ch->bsh", positions3,
                          jax.nn.one_hot(comp, 3).T.astype(positions3.dtype))
    ang = per_slot * freqs[None, None, :]
    return jnp.sin(ang), jnp.cos(ang)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _mask_bias(seq_q: int, seq_k: int, kind: str, window: int,
               q_offset=0, dtype=jnp.float32):
    """Additive attention bias (S_q, S_k): causal, optionally banded (local)."""
    qi = jnp.arange(seq_q)[:, None] + q_offset
    kj = jnp.arange(seq_k)[None, :]
    ok = kj <= qi
    if kind == "local" and window > 0:
        ok &= kj > qi - window
    return jnp.where(ok, 0.0, -jnp.inf).astype(dtype)


def attention(x, wq, wk, wv, wo, cfg, kind: str, sin, cos,
              qk_norm_scales=None, bias_mode: str = "causal"):
    """Full-sequence attention (train / prefill). Returns (out, (k, v)).

    x: (B,S,D). Weights: wq (D,H,Dh), wk/wv (D,KV,Dh), wo (H,Dh,D).
    bias_mode: 'causal' (LM) or 'full' (encoder self-attention).
    """
    b, s, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    g = h // kv

    q = jnp.einsum("bsd,dhk->bshk", x, wq, preferred_element_type=jnp.bfloat16)
    k = jnp.einsum("bsd,dhk->bshk", x, wk, preferred_element_type=jnp.bfloat16)
    v = jnp.einsum("bsd,dhk->bshk", x, wv, preferred_element_type=jnp.bfloat16)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")

    if qk_norm_scales is not None:
        qn, kn = qk_norm_scales
        q = rms_norm(q, qn, cfg.norm_eps)
        k = rms_norm(k, kn, cfg.norm_eps)
    if sin is not None:
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)

    qg = q.reshape(b, s, kv, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    scores *= 1.0 / math.sqrt(dh)
    if bias_mode == "causal":
        scores += _mask_bias(s, s, kind, cfg.window)[None, None, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bkgst,btkd->bskgd", probs, v,
                     preferred_element_type=jnp.bfloat16)
    ctx = ctx.reshape(b, s, h, dh)
    out = jnp.einsum("bshk,hkd->bsd", ctx, wo, preferred_element_type=jnp.bfloat16)
    return out.astype(x.dtype), (k, v)


def cross_attention(x, enc_kv, wq, wo, cfg):
    """Decoder cross-attention against precomputed encoder (k, v)."""
    b, s, _ = x.shape
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    g = h // kvh
    k, v = enc_kv
    q = jnp.einsum("bsd,dhk->bshk", x, wq, preferred_element_type=jnp.bfloat16)
    qg = q.reshape(b, s, kvh, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32) / math.sqrt(dh)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bkgst,btkd->bskgd", probs, v,
                     preferred_element_type=jnp.bfloat16).reshape(b, s, h, dh)
    return jnp.einsum("bshk,hkd->bsd", ctx, wo, preferred_element_type=jnp.bfloat16)


def decode_attention(q, k_cache, v_cache, pos, cfg, kind: str):
    """One-token attention against a KV cache.

    q: (B, 1, H, Dh); caches (B, S_max, KV, Dh); pos: () current index.
    Softmax over the cache axis works under sequence-sharded caches — GSPMD
    turns the max/sum reductions into collectives.
    """
    b, _, h, dh = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    s_max = k_cache.shape[1]

    qg = q.reshape(b, kv, g, dh)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache,
                        preferred_element_type=jnp.float32) / math.sqrt(dh)
    t = jnp.arange(s_max)
    ok = t <= pos
    if kind == "local" and cfg.window > 0:
        ok &= t > pos - cfg.window
    scores = jnp.where(ok[None, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bkgt,btkd->bkgd", probs, v_cache,
                     preferred_element_type=jnp.bfloat16)
    return ctx.reshape(b, 1, h, dh)


def streaming_attention(qg, k, v, is_local, window: int, scale: float,
                        q_chunk: int = 512, kv_chunk: int = 1024,
                        causal: bool = True, scores_bf16: bool = False):
    """Memory-efficient attention (Rabe-Staats / flash-style streaming softmax).

    qg: (B, S, KV, G, Dh) grouped queries; k, v: (B, T, KV, Dh).
    Scans over query chunks (outer) and KV chunks (inner, checkpointed), so
    peak memory is O(q_chunk * kv_chunk) instead of O(S*T). The local/global
    choice (``is_local``, traced bool) folds into the per-block mask. This is
    also the pure-jnp oracle for the Pallas flash kernel (kernels/decode_attn).
    """
    b, s, kvh, g, dh = qg.shape
    t = k.shape[1]
    q_chunk = min(q_chunk, s)
    while s % q_chunk:
        q_chunk -= 1
    kv_chunk = min(kv_chunk, t)
    while t % kv_chunk:
        kv_chunk -= 1
    nq, nk = s // q_chunk, t // kv_chunk

    qs = qg.reshape(b, nq, q_chunk, kvh, g, dh).swapaxes(0, 1)
    ks = k.reshape(b, nk, kv_chunk, kvh, dh).swapaxes(0, 1)
    vs = v.reshape(b, nk, kv_chunk, kvh, dh).swapaxes(0, 1)

    def q_block(carry, xs):
        del carry
        qi_blk, i0 = xs  # (B, qc, KV, G, Dh), scalar base index
        qidx = i0 + jnp.arange(q_chunk)

        def kv_block(state, ys):
            m, l, acc = state
            kj_blk, vj_blk, j0 = ys
            kidx = j0 + jnp.arange(kv_chunk)
            sc = jnp.einsum("bqkgd,btkd->bkgqt", qi_blk, kj_blk,
                            preferred_element_type=(
                                jnp.bfloat16 if scores_bf16 else jnp.float32))
            sc = sc.astype(jnp.float32) * scale
            ok = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                ok &= kidx[None, :] <= qidx[:, None]
            if window > 0:
                band = ok & (kidx[None, :] > qidx[:, None] - window)
                ok = jnp.where(is_local, band, ok)
            sc = jnp.where(ok[None, None, None], sc, -jnp.inf)
            m_new = jnp.maximum(m, sc.max(-1))
            # guard fully-masked rows (m_new = -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(sc - m_safe[..., None])
            p = jnp.where(ok[None, None, None], p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p.astype(vj_blk.dtype), vj_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), ()

        kv_body = jax.checkpoint(kv_block, prevent_cse=False)
        init = (jnp.full((b, kvh, g, q_chunk), -jnp.inf, jnp.float32),
                jnp.zeros((b, kvh, g, q_chunk), jnp.float32),
                jnp.zeros((b, kvh, g, q_chunk, dh), jnp.float32))
        j0s = jnp.arange(nk) * kv_chunk
        (m, l, acc), _ = jax.lax.scan(kv_body, init, (ks, vs, j0s))
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # (B,KV,G,qc,Dh)
        return None, out.transpose(0, 3, 1, 2, 4)

    i0s = jnp.arange(nq) * q_chunk
    _, outs = jax.lax.scan(q_block, None, (qs, i0s))        # (nq,B,qc,KV,G,Dh)
    out = outs.swapaxes(0, 1).reshape(b, s, kvh, g, dh)
    return out


# ---------------------------------------------------------------------------
# mlp / embedding
# ---------------------------------------------------------------------------

def swiglu(x, w_gate, w_up, w_down):
    gate = jnp.einsum("bsd,df->bsf", x, w_gate, preferred_element_type=jnp.bfloat16)
    up = jnp.einsum("bsd,df->bsf", x, w_up, preferred_element_type=jnp.bfloat16)
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    act = shard(act, "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", act, w_down, preferred_element_type=jnp.bfloat16)


def embed_tokens(table, tokens, scale: bool = False):
    out = jnp.take(table, tokens, axis=0)
    if scale:
        out = out * math.sqrt(table.shape[1])
    return shard(out, "batch", "residual_seq", "residual_embed")
