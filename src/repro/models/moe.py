"""Token-choice top-k MoE layer (qwen3-moe, mixtral).

Dispatch is the sort-based capacity scheme (dropless up to a capacity factor):
tokens are processed in groups (one group per data shard so all routing math
is shard-local), each group scatters its tokens into a per-expert buffer
(G, E, C, D), the expert FFN runs with experts sharded over the 'model' mesh
axis (GSPMD materializes the token all-to-all at the G/E resharding), and
rows are gathered back and combined with the top-k gates.

When n_experts doesn't divide the model axis (mixtral: 8 experts, 16-way
axis) the axis-rule table falls back to tensor parallelism *inside* each
expert (d_ff sharded), in which case no expert all-to-all exists and the only
collective is the usual down-projection reduce — the same code path, driven
entirely by the sharding rules.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding import shard

__all__ = ["moe_mlp"]


def _dispatch_group(x_g, e_idx_g, capacity: int, n_experts: int):
    """Group-local dispatch. x_g (T, D); e_idx_g (T, k) -> buf (E*C+1, D),
    dest (T*k,), keep (T*k,)."""
    t, k = e_idx_g.shape
    ef = e_idx_g.reshape(t * k)
    order = jnp.argsort(ef, stable=True)
    sorted_e = ef[order]
    # position of each routed slot within its expert
    start = jnp.searchsorted(sorted_e, jnp.arange(n_experts), side="left")
    pos_within = jnp.arange(t * k) - start[sorted_e]
    keep_sorted = pos_within < capacity
    dest_sorted = jnp.where(keep_sorted, sorted_e * capacity + pos_within,
                            n_experts * capacity)
    # invert the sort: dest[j] for original flat slot j
    dest = jnp.zeros(t * k, jnp.int32).at[order].set(dest_sorted.astype(jnp.int32))
    keep = jnp.zeros(t * k, bool).at[order].set(keep_sorted)
    tok_idx = order // k
    buf = jnp.zeros((n_experts * capacity + 1, x_g.shape[-1]), x_g.dtype)
    buf = buf.at[dest_sorted].set(x_g[tok_idx], mode="drop")
    return buf, dest, keep


def moe_mlp(x, router_w, w_gate, w_up, w_down, cfg, n_groups: int):
    """x: (B, S, D) -> (B, S, D). Expert weights (E, D, F) / (E, F, D)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    tokens = b * s
    g = max(min(n_groups, tokens), 1)
    while tokens % g:
        g -= 1
    t_g = tokens // g
    capacity = max(int(cfg.capacity_factor * k * t_g / e), 1)

    xf = x.reshape(g, t_g, d)
    xf = shard(xf, "batch", None, None)

    logits = jnp.einsum("gtd,de->gte", xf, router_w,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, e_idx = jax.lax.top_k(probs, k)                 # (G, T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    buf, dest, keep = jax.vmap(
        lambda xg, eg: _dispatch_group(xg, eg, capacity, e))(xf, e_idx)
    buf = buf[:, :-1].reshape(g, e, capacity, d)           # drop dummy row
    buf = shard(buf, "batch", "experts", None, None)

    # expert FFN (SwiGLU) — experts over 'model' (EP) or d_ff over 'model' (TP)
    gate_act = jnp.einsum("gecd,edf->gecf", buf, w_gate,
                          preferred_element_type=jnp.bfloat16)
    up_act = jnp.einsum("gecd,edf->gecf", buf, w_up,
                        preferred_element_type=jnp.bfloat16)
    gate_act = shard(gate_act, "batch", "experts", None, "expert_mlp")
    act = jax.nn.silu(gate_act.astype(jnp.float32)).astype(x.dtype) * up_act
    out_buf = jnp.einsum("gecf,efd->gecd", act, w_down,
                         preferred_element_type=jnp.bfloat16)
    out_buf = shard(out_buf, "batch", "experts", None, None)

    # gather back + combine
    flat = out_buf.reshape(g, e * capacity, d)
    flat = jnp.concatenate([flat, jnp.zeros((g, 1, d), flat.dtype)], axis=1)
    rows = jnp.take_along_axis(flat, dest[..., None], axis=1)  # (G, T*k, D)
    w = (gates.reshape(g, t_g * k) * keep.astype(gates.dtype)).astype(x.dtype)
    y = (rows * w[..., None]).reshape(g, t_g, k, d).sum(axis=2)
    return y.reshape(b, s, d)
