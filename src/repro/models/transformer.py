"""Decoder-only transformer (dense + MoE families): gemma3, glm4, granite,
yi, qwen2-vl (M-RoPE), qwen3-moe, mixtral (SWA).

Layers are stacked on a leading axis and consumed with lax.scan; per-layer
heterogeneity (gemma3's 5:1 local:global pattern, mixtral's SWA) is carried
as scanned boolean/float flags selecting the attention mask and RoPE table —
the computation structure is identical across layers, which keeps the HLO
small and compile times tractable at 512 devices.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from ..sharding import shard
from .layers import (ParamSchema, Schema, apply_rope, attention,
                     decode_attention, embed_tokens, head_mask, mrope_cache,
                     mrope_positions, rms_norm, rope_cache,
                     streaming_attention, swiglu)
from .moe import moe_mlp

__all__ = ["dense_schema", "dense_forward", "dense_decode_step", "init_cache"]


def dense_schema(cfg) -> Schema:
    l, d, h, kv, dh, f, vp = (cfg.n_layers, cfg.d_model, cfg.h_eff,
                              cfg.kv_eff, cfg.d_head, cfg.d_ff,
                              cfg.vocab_padded)
    s: Schema = {
        "embed/table": ParamSchema((vp, d), ("vocab", "embed")),
        "final_norm/w": ParamSchema((d,), (None,), init="zeros"),
        "layers/pre_attn_norm": ParamSchema((l, d), ("layers", None), init="zeros"),
        "layers/pre_mlp_norm": ParamSchema((l, d), ("layers", None), init="zeros"),
        "layers/wq": ParamSchema((l, d, h, dh), ("layers", "embed", "heads", "head_dim"),
                                 std=0.02),
        "layers/wk": ParamSchema((l, d, kv, dh), ("layers", "embed", "kv_heads", "head_dim")),
        "layers/wv": ParamSchema((l, d, kv, dh), ("layers", "embed", "kv_heads", "head_dim")),
        "layers/wo": ParamSchema((l, h, dh, d), ("layers", "heads", "head_dim", "embed"),
                                 std=0.02 / math.sqrt(2 * l)),
    }
    if cfg.n_experts:
        e, fe = cfg.n_experts, cfg.d_ff
        s.update({
            "layers/router": ParamSchema((l, d, e), ("layers", "embed", None)),
            "layers/we_gate": ParamSchema((l, e, d, fe), ("layers", "experts", "embed", "expert_mlp")),
            "layers/we_up": ParamSchema((l, e, d, fe), ("layers", "experts", "embed", "expert_mlp")),
            "layers/we_down": ParamSchema((l, e, fe, d), ("layers", "experts", "expert_mlp", "embed"),
                                          std=0.02 / math.sqrt(2 * l)),
        })
    else:
        s.update({
            "layers/w_gate": ParamSchema((l, d, f), ("layers", "embed", "mlp")),
            "layers/w_up": ParamSchema((l, d, f), ("layers", "embed", "mlp")),
            "layers/w_down": ParamSchema((l, f, d), ("layers", "mlp", "embed"),
                                         std=0.02 / math.sqrt(2 * l)),
        })
    if cfg.qk_norm:
        s["layers/q_norm"] = ParamSchema((l, dh), ("layers", None), init="zeros")
        s["layers/k_norm"] = ParamSchema((l, dh), ("layers", None), init="zeros")
    if not cfg.tie_embeddings:
        s["lm_head/table"] = ParamSchema((vp, d), ("vocab", "embed"))
    return s


def _layer_params(params, prefix="layers/"):
    return {k[len(prefix):]: v for k, v in params.items() if k.startswith(prefix)}


def _is_local_flags(cfg):
    return jnp.asarray([k == "local" for k in cfg.attn_kinds], dtype=bool)


def _mlp(x, lp, cfg, n_groups):
    if cfg.n_experts:
        return moe_mlp(x, lp["router"], lp["we_gate"], lp["we_up"],
                       lp["we_down"], cfg, n_groups)
    return swiglu(x, lp["w_gate"], lp["w_up"], lp["w_down"])


def _layer_body(x, lp, cfg, is_local, ropes, n_groups, mode):
    """One transformer block. x (B,S,D). Returns (x', (k, v))."""
    sin_g, cos_g, sin_l, cos_l = ropes
    sin = jnp.where(is_local, sin_l, sin_g) if sin_l is not None else sin_g
    cos = jnp.where(is_local, cos_l, cos_g) if cos_l is not None else cos_g

    qk_scales = (lp["q_norm"], lp["k_norm"]) if cfg.qk_norm else None
    h = rms_norm(x, lp["pre_attn_norm"], cfg.norm_eps)
    attn_out, kv_out = _attention_flagged(h, lp, cfg, is_local, sin, cos,
                                          qk_scales)
    x = x + attn_out
    x = shard(x, "batch", "residual_seq", "residual_embed")
    h = rms_norm(x, lp["pre_mlp_norm"], cfg.norm_eps)
    x = x + _mlp(h, lp, cfg, n_groups)
    x = shard(x, "batch", "residual_seq", "residual_embed")
    return x, kv_out


def _attention_flagged(h, lp, cfg, is_local, sin, cos, qk_scales):
    """attention() with the local/global choice as a traced flag: the band
    constraint is ANDed into the causal mask weighted by the flag."""
    b, s, _ = h.shape
    nh, kv, dh = cfg.h_eff, cfg.kv_eff, cfg.d_head
    g = nh // kv
    q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"], preferred_element_type=jnp.bfloat16)
    k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"], preferred_element_type=jnp.bfloat16)
    v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"], preferred_element_type=jnp.bfloat16)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    if qk_scales is not None:
        q = rms_norm(q, qk_scales[0], cfg.norm_eps)
        k = rms_norm(k, qk_scales[1], cfg.norm_eps)
    if sin is not None:
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    qg = q.reshape(b, s, kv, g, dh)
    if s > 2048:
        # flash-style streaming path: O(chunk^2) memory instead of O(S^2)
        ctx = streaming_attention(qg, k, v, is_local, cfg.window,
                                  1.0 / math.sqrt(dh),
                                  q_chunk=cfg.attn_q_chunk,
                                  kv_chunk=cfg.attn_kv_chunk,
                                  scores_bf16=cfg.scores_bf16)
        ctx = ctx.astype(h.dtype).reshape(b, s, nh, dh)
    else:
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                            preferred_element_type=jnp.float32) / math.sqrt(dh)
        qi = jnp.arange(s)[:, None]
        kj = jnp.arange(s)[None, :]
        causal = kj <= qi
        band = causal & (kj > qi - cfg.window) if cfg.window > 0 else causal
        ok = jnp.where(is_local, band, causal)
        scores = jnp.where(ok[None, None, None], scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1).astype(h.dtype)
        ctx = jnp.einsum("bkgst,btkd->bskgd", probs, v,
                         preferred_element_type=jnp.bfloat16).reshape(b, s, nh, dh)
    hm = head_mask(cfg, ctx.dtype)
    if hm is not None:
        ctx = ctx * hm[None, None, :, None]
    out = jnp.einsum("bshk,hkd->bsd", ctx, lp["wo"],
                     preferred_element_type=jnp.bfloat16)
    return out.astype(h.dtype), (k, v)


def _decode_attention_flagged(q, k_cache, v_cache, pos, cfg, is_local):
    """decode_attention with the local/global kind as a traced flag."""
    b, _, h, dh = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    s_max = k_cache.shape[1]
    qg = q.reshape(b, kv, g, dh)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache,
                        preferred_element_type=jnp.float32) / math.sqrt(dh)
    t = jnp.arange(s_max)
    ok = t <= pos
    if cfg.window > 0:
        ok &= ~is_local | (t > pos - cfg.window)
    scores = jnp.where(ok[None, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bkgt,btkd->bkgd", probs, v_cache,
                     preferred_element_type=jnp.bfloat16)
    return ctx.reshape(b, 1, h, dh)


def _ropes_for(cfg, positions, batch: int, seq: int):
    """RoPE tables; gemma3-style dual theta (local layers may use 1e4)."""
    if cfg.m_rope:
        if positions is not None:  # decode: all three components equal
            pos3 = jnp.broadcast_to(positions.astype(jnp.float32),
                                    (3, batch, seq))
        else:
            pos3 = mrope_positions(batch, seq, cfg.n_vision_tokens)
        half = cfg.d_head // 2
        sec = (half - 2 * (half * 3 // 8), half * 3 // 8, half * 3 // 8)
        sin, cos = mrope_cache(pos3, cfg.d_head, cfg.rope_theta, sec)
        return sin, cos, None, None
    pos = positions if positions is not None else jnp.arange(seq)
    sin_g, cos_g = rope_cache(seq, cfg.d_head, cfg.rope_theta, positions=pos)
    theta_l = 1e4 if cfg.rope_theta != 1e4 and "local" in cfg.attn_pattern else None
    if theta_l is not None:
        sin_l, cos_l = rope_cache(seq, cfg.d_head, theta_l, positions=pos)
    else:
        sin_l = cos_l = None
    return sin_g, cos_g, sin_l, cos_l


def dense_forward(params, tokens, cfg, mode: str = "train",
                  vision_embeds=None, n_groups: int = 16, remat: bool = True):
    """Full-sequence forward. Returns (hidden, kv_caches or None).

    mode: 'train' (remat, no cache out) | 'prefill' (cache out).
    """
    b, s = tokens.shape
    x = embed_tokens(params["embed/table"], tokens,
                     scale=cfg.family == "dense" and cfg.vocab > 200_000)
    if vision_embeds is not None and cfg.n_vision_tokens:
        x = jax.lax.dynamic_update_slice(
            x, vision_embeds.astype(x.dtype), (0, 0, 0))

    ropes = _ropes_for(cfg, None, b, s)
    lp_stack = _layer_params(params)
    flags = _is_local_flags(cfg)

    def body(x, sl):
        lp, is_local = sl
        return _layer_body(x, lp, cfg, is_local, ropes, n_groups, mode)

    if mode == "train" and remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable,
                              prevent_cse=False)
    x, kv = jax.lax.scan(body, x, (lp_stack, flags))
    x = rms_norm(x, params["final_norm/w"], cfg.norm_eps)
    return x, (kv if mode == "prefill" else None)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_len, cfg.kv_eff, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def dense_decode_step(params, tokens, cache, pos, cfg, n_groups: int = 16):
    """One decode step. tokens (B, 1); cache dict of (L,B,S,KV,Dh); pos ().

    Returns (hidden (B,1,D), updated cache).
    """
    b = tokens.shape[0]
    x = embed_tokens(params["embed/table"], tokens,
                     scale=cfg.family == "dense" and cfg.vocab > 200_000)
    pos_arr = jnp.asarray([pos]) if jnp.ndim(pos) == 0 else pos
    ropes = _ropes_for(cfg, pos_arr, b, 1)
    lp_stack = _layer_params(params)
    flags = _is_local_flags(cfg)

    def body(x, sl):
        lp, is_local, k_c, v_c = sl
        sin_g, cos_g, sin_l, cos_l = ropes
        sin = jnp.where(is_local, sin_l, sin_g) if sin_l is not None else sin_g
        cos = jnp.where(is_local, cos_l, cos_g) if cos_l is not None else cos_g
        qk_scales = ((lp["q_norm"], lp["k_norm"]) if cfg.qk_norm else None)

        h = rms_norm(x, lp["pre_attn_norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"], preferred_element_type=jnp.bfloat16)
        k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"], preferred_element_type=jnp.bfloat16)
        v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"], preferred_element_type=jnp.bfloat16)
        if qk_scales is not None:
            q = rms_norm(q, qk_scales[0], cfg.norm_eps)
            k = rms_norm(k, qk_scales[1], cfg.norm_eps)
        if sin is not None:
            q = apply_rope(q, sin, cos)
            k = apply_rope(k, sin, cos)
        k_c = jax.lax.dynamic_update_slice(k_c, k.astype(k_c.dtype), (0, pos, 0, 0))
        v_c = jax.lax.dynamic_update_slice(v_c, v.astype(v_c.dtype), (0, pos, 0, 0))
        k_c = shard(k_c, "batch", "kv_seq", "kv_heads", "head_dim")
        v_c = shard(v_c, "batch", "kv_seq", "kv_heads", "head_dim")
        ctx = _decode_attention_flagged(q, k_c, v_c, pos, cfg, is_local)
        hm = head_mask(cfg, ctx.dtype)
        if hm is not None:
            ctx = ctx * hm[None, None, :, None]
        attn_out = jnp.einsum("bshk,hkd->bsd", ctx, lp["wo"],
                              preferred_element_type=jnp.bfloat16)
        x = x + attn_out.astype(x.dtype)
        h2 = rms_norm(x, lp["pre_mlp_norm"], cfg.norm_eps)
        x = x + _mlp(h2, lp, cfg, n_groups)
        return x, (k_c, v_c)

    x, (k_new, v_new) = jax.lax.scan(body, x, (lp_stack, flags, cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm/w"], cfg.norm_eps)
    return x, {"k": k_new, "v": v_new}
