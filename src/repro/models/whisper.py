"""Whisper-small encoder-decoder backbone [arXiv:2212.04356].

The conv frontend is a stub per the assignment brief: input_specs() supplies
precomputed post-conv frame embeddings (B, n_frames, D). Encoder: non-causal
self-attention over frames with fixed sinusoidal positions. Decoder: causal
self-attention (RoPE — a deviation from Whisper's learned 448-position table,
required to make the assigned 32k-token decoder shapes well-defined; noted in
DESIGN.md) + cross-attention into the encoder output + GELU MLP.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..sharding import shard
from .layers import (ParamSchema, Schema, apply_rope, embed_tokens,
                     head_mask, rms_norm, rope_cache, streaming_attention)
from .transformer import _decode_attention_flagged

__all__ = ["whisper_schema", "whisper_encode", "whisper_forward",
           "whisper_decode_step", "whisper_init_cache"]


def _attn_schema(l, d, h, dh, prefix, cross=False) -> Schema:
    s = {
        f"{prefix}/pre_norm": ParamSchema((l, d), ("layers", None), init="zeros"),
        f"{prefix}/wq": ParamSchema((l, d, h, dh), ("layers", "embed", "heads", "head_dim")),
        f"{prefix}/wo": ParamSchema((l, h, dh, d), ("layers", "heads", "head_dim", "embed")),
    }
    if not cross:
        s[f"{prefix}/wk"] = ParamSchema((l, d, h, dh), ("layers", "embed", "heads", "head_dim"))
        s[f"{prefix}/wv"] = ParamSchema((l, d, h, dh), ("layers", "embed", "heads", "head_dim"))
    else:
        # cross K/V projections read the encoder output
        s[f"{prefix}/wk"] = ParamSchema((l, d, h, dh), ("layers", "embed", "heads", "head_dim"))
        s[f"{prefix}/wv"] = ParamSchema((l, d, h, dh), ("layers", "embed", "heads", "head_dim"))
    return s


def _mlp_schema(l, d, f, prefix) -> Schema:
    return {
        f"{prefix}/pre_norm": ParamSchema((l, d), ("layers", None), init="zeros"),
        f"{prefix}/w_up": ParamSchema((l, d, f), ("layers", "embed", "mlp")),
        f"{prefix}/w_down": ParamSchema((l, f, d), ("layers", "mlp", "embed")),
    }


def whisper_schema(cfg) -> Schema:
    d, h, dh, f = cfg.d_model, cfg.h_eff, cfg.d_head, cfg.d_ff
    le, ld, vp = cfg.n_enc_layers, cfg.n_layers, cfg.vocab_padded
    s: Schema = {
        "embed/table": ParamSchema((vp, d), ("vocab", "embed")),
        "enc_final_norm/w": ParamSchema((d,), (None,), init="zeros"),
        "final_norm/w": ParamSchema((d,), (None,), init="zeros"),
    }
    s.update(_attn_schema(le, d, h, dh, "enc/attn"))
    s.update(_mlp_schema(le, d, f, "enc/mlp"))
    s.update(_attn_schema(ld, d, h, dh, "dec/self"))
    s.update(_attn_schema(ld, d, h, dh, "dec/cross", cross=True))
    s.update(_mlp_schema(ld, d, f, "dec/mlp"))
    return s


def _sub(params, prefix):
    plen = len(prefix) + 1
    return {k[plen:]: v for k, v in params.items() if k.startswith(prefix + "/")}


def _sinusoid(n: int, d: int):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _mha(x, p, cfg, mask_bias=None, sin=None, cos=None, kv_src=None):
    """Full MHA (kv=heads for whisper). kv_src overrides the K/V input."""
    b, s, _ = x.shape
    h, dh = cfg.h_eff, cfg.d_head
    src = x if kv_src is None else kv_src
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"], preferred_element_type=jnp.bfloat16)
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"], preferred_element_type=jnp.bfloat16)
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"], preferred_element_type=jnp.bfloat16)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", None, "heads", "head_dim")
    v = shard(v, "batch", None, "heads", "head_dim")
    if sin is not None:
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    if s > 2048 and kv_src is None and mask_bias is not None:
        # long causal self-attention -> streaming path (kv == heads, g = 1)
        ctx = streaming_attention(q[:, :, :, None], k, v, jnp.asarray(False),
                                  0, 1.0 / math.sqrt(dh))
        ctx = ctx[:, :, :, 0].astype(x.dtype)
    else:
        scores = jnp.einsum("bshd,bthd->bhst", q, k,
                            preferred_element_type=jnp.float32) / math.sqrt(dh)
        if mask_bias is not None:
            scores = scores + mask_bias
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bhst,bthd->bshd", probs, v,
                         preferred_element_type=jnp.bfloat16)
    hm = head_mask(cfg, ctx.dtype)
    if hm is not None:
        ctx = ctx * hm[None, None, :, None]
    out = jnp.einsum("bshd,hdk->bsk", ctx, p["wo"],
                     preferred_element_type=jnp.bfloat16)
    return out.astype(x.dtype), (k, v)


def _gelu_mlp(x, p, cfg):
    u = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    hdn = jnp.einsum("bsd,df->bsf", u, p["w_up"], preferred_element_type=jnp.bfloat16)
    hdn = jax.nn.gelu(hdn.astype(jnp.float32)).astype(x.dtype)
    hdn = shard(hdn, "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", hdn, p["w_down"],
                      preferred_element_type=jnp.bfloat16)


def whisper_encode(params, frames, cfg, remat: bool = False):
    """frames: (B, n_frames, D) precomputed post-conv embeddings (stub)."""
    x = frames + _sinusoid(frames.shape[1], cfg.d_model)[None].astype(frames.dtype)
    attn, mlp = _sub(params, "enc/attn"), _sub(params, "enc/mlp")

    def body(x, sl):
        pa, pm = sl
        h = rms_norm(x, pa["pre_norm"], cfg.norm_eps)
        a, _ = _mha(h, pa, cfg)          # bidirectional: no mask
        x = x + a
        x = x + _gelu_mlp(x, pm, cfg)
        return x, ()

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable,
                              prevent_cse=False)
    x, _ = jax.lax.scan(body, x, (attn, mlp))
    return rms_norm(x, params["enc_final_norm/w"], cfg.norm_eps)


def whisper_forward(params, tokens, cfg, mode: str = "train", frames=None,
                    remat: bool = True, **_):
    """Decoder forward over full token sequence. Returns (hidden, caches)."""
    b, s = tokens.shape
    enc = whisper_encode(params, frames, cfg, remat=(mode == "train" and remat))
    x = embed_tokens(params["embed/table"], tokens)
    sin, cos = rope_cache(s, cfg.d_head, cfg.rope_theta)
    causal = jnp.where(jnp.arange(s)[None, :] <= jnp.arange(s)[:, None],
                       0.0, -jnp.inf)[None, None]

    pself, pcross, pmlp = (_sub(params, "dec/self"), _sub(params, "dec/cross"),
                           _sub(params, "dec/mlp"))

    def body(x, sl):
        ps, pc, pm = sl
        h = rms_norm(x, ps["pre_norm"], cfg.norm_eps)
        a, kv = _mha(h, ps, cfg, mask_bias=causal, sin=sin, cos=cos)
        x = x + a
        h = rms_norm(x, pc["pre_norm"], cfg.norm_eps)
        c, ckv = _mha(h, pc, cfg, kv_src=enc)
        x = x + c
        x = x + _gelu_mlp(x, pm, cfg)
        x = shard(x, "batch", "residual_seq", "residual_embed")
        if mode == "train":
            return x, ()
        return x, (kv, ckv)

    if mode == "train" and remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable,
                              prevent_cse=False)
    if mode == "train":
        x, _ = jax.lax.scan(body, x, (pself, pcross, pmlp))
        caches = None
    else:
        x, (kv, ckv) = jax.lax.scan(body, x, (pself, pcross, pmlp))
        caches = {"k": kv[0], "v": kv[1], "ck": ckv[0], "cv": ckv[1]}
    x = rms_norm(x, params["final_norm/w"], cfg.norm_eps)
    return x, caches


def whisper_init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    l, h, dh = cfg.n_layers, cfg.h_eff, cfg.d_head
    return {
        "k": jnp.zeros((l, batch, max_len, h, dh), dtype),
        "v": jnp.zeros((l, batch, max_len, h, dh), dtype),
        "ck": jnp.zeros((l, batch, cfg.n_audio_frames, h, dh), dtype),
        "cv": jnp.zeros((l, batch, cfg.n_audio_frames, h, dh), dtype),
    }


def whisper_decode_step(params, tokens, cache, pos, cfg, **_):
    """One decoder token against self KV cache + precomputed cross KV."""
    b = tokens.shape[0]
    x = embed_tokens(params["embed/table"], tokens)
    pos_arr = jnp.asarray([pos])
    sin, cos = rope_cache(1, cfg.d_head, cfg.rope_theta, positions=pos_arr)
    pself, pcross, pmlp = (_sub(params, "dec/self"), _sub(params, "dec/cross"),
                           _sub(params, "dec/mlp"))

    def body(x, sl):
        ps, pc, pm, k_c, v_c, ck, cv = sl
        h = rms_norm(x, ps["pre_norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, ps["wq"], preferred_element_type=jnp.bfloat16)
        k = jnp.einsum("bsd,dhk->bshk", h, ps["wk"], preferred_element_type=jnp.bfloat16)
        v = jnp.einsum("bsd,dhk->bshk", h, ps["wv"], preferred_element_type=jnp.bfloat16)
        q, k = apply_rope(q, sin, cos), apply_rope(k, sin, cos)
        k_c = jax.lax.dynamic_update_slice(k_c, k.astype(k_c.dtype), (0, pos, 0, 0))
        v_c = jax.lax.dynamic_update_slice(v_c, v.astype(v_c.dtype), (0, pos, 0, 0))
        k_c = shard(k_c, "batch", "kv_seq", "kv_heads", "head_dim")
        v_c = shard(v_c, "batch", "kv_seq", "kv_heads", "head_dim")
        ctx = _decode_attention_flagged(q, k_c, v_c, pos, cfg, jnp.asarray(False))
        x = x + jnp.einsum("bshk,hkd->bsd", ctx, ps["wo"],
                           preferred_element_type=jnp.bfloat16).astype(x.dtype)
        # cross attention against fixed encoder KV
        h = rms_norm(x, pc["pre_norm"], cfg.norm_eps)
        qc = jnp.einsum("bsd,dhk->bshk", h, pc["wq"], preferred_element_type=jnp.bfloat16)
        sc = jnp.einsum("bshd,bthd->bhst", qc, ck,
                        preferred_element_type=jnp.float32) / math.sqrt(cfg.d_head)
        pr = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
        cx = jnp.einsum("bhst,bthd->bshd", pr, cv, preferred_element_type=jnp.bfloat16)
        x = x + jnp.einsum("bshd,hdk->bsk", cx, pc["wo"],
                           preferred_element_type=jnp.bfloat16).astype(x.dtype)
        x = x + _gelu_mlp(x, pm, cfg)
        return x, (k_c, v_c)

    xs = (pself, pcross, pmlp, cache["k"], cache["v"], cache["ck"], cache["cv"])
    x, (k_new, v_new) = jax.lax.scan(body, x, xs)
    x = rms_norm(x, params["final_norm/w"], cfg.norm_eps)
    new_cache = dict(cache, k=k_new, v=v_new)
    return x, new_cache
