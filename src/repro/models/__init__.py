from .model_api import ModelBundle, get_model, lm_logits, chunked_xent_loss
