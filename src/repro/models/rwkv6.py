"""RWKV-6 "Finch" — attention-free LM with data-dependent decay
[arXiv:2404.05892].

Per layer: time-mix (the WKV recurrence with per-channel, per-token decay
w_t = exp(-exp(ww_t)), LoRA-produced from the token stream — Finch's key
feature) and channel-mix (squared-ReLU FFN).

The WKV recurrence  S_t = diag(w_t) S_{t-1} + k_t v_t^T,
                    y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
is computed in *chunked* form for train/prefill (chunk 32, fp32): intra-chunk
terms become an MXU matmul in the decay-rebased basis r' = r * exp(l),
k' = k * exp(-l) (l = cumulative log-decay within the chunk, re-based to the
chunk start), masked causally; inter-chunk state propagates through a scan
over chunks. The per-step log-decay is clamped to >= -2 so the rebased
factors stay inside fp32 range (|l| <= 64 per chunk) — noted in DESIGN.md.
A step-by-step scan oracle (`wkv_scan_ref`) validates the chunked path, and
kernels/wkv6 provides the Pallas TPU kernel for the same contraction.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..sharding import shard
from .layers import ParamSchema, Schema, embed_tokens, rms_norm

__all__ = ["rwkv6_schema", "rwkv6_forward", "rwkv6_decode_step",
           "rwkv6_init_state", "wkv_chunked", "wkv_scan_ref"]

_LORA_MIX = 32
_LORA_W = 64
_CHUNK = 32
_LOGW_MIN = -2.0  # per-step log-decay clamp (fp32 safety of the rebased basis)


def rwkv6_schema(cfg) -> Schema:
    l, d, f, vp = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab_padded
    h, dh = cfg.n_heads, cfg.d_head
    la = ("layers", None)
    s: Schema = {
        "embed/table": ParamSchema((vp, d), ("vocab", "embed")),
        "final_norm/w": ParamSchema((d,), (None,), init="zeros"),
        "lm_head/table": ParamSchema((vp, d), ("vocab", "embed")),
        # time-mix
        "layers/ln1": ParamSchema((l, d), la, init="zeros"),
        "layers/mu_x": ParamSchema((l, d), la),
        "layers/mu_rkvwg": ParamSchema((l, 5, d), ("layers", None, None)),
        "layers/mix_w1": ParamSchema((l, d, 5 * _LORA_MIX), ("layers", "embed", None)),
        "layers/mix_w2": ParamSchema((l, 5, _LORA_MIX, d), ("layers", None, None, "embed")),
        "layers/w0": ParamSchema((l, d), la, init="zeros"),
        "layers/w_lora1": ParamSchema((l, d, _LORA_W), ("layers", "embed", None)),
        "layers/w_lora2": ParamSchema((l, _LORA_W, d), ("layers", None, "embed")),
        "layers/u": ParamSchema((l, h, dh), ("layers", "heads", "head_dim")),
        "layers/wr": ParamSchema((l, d, h, dh), ("layers", "embed", "heads", "head_dim")),
        "layers/wk": ParamSchema((l, d, h, dh), ("layers", "embed", "heads", "head_dim")),
        "layers/wv": ParamSchema((l, d, h, dh), ("layers", "embed", "heads", "head_dim")),
        "layers/wg": ParamSchema((l, d, h, dh), ("layers", "embed", "heads", "head_dim")),
        "layers/wo": ParamSchema((l, h, dh, d), ("layers", "heads", "head_dim", "embed"),
                                 std=0.02 / math.sqrt(2 * l)),
        "layers/ln_x": ParamSchema((l, h, dh), ("layers", "heads", "head_dim"), init="zeros"),
        # channel-mix
        "layers/ln2": ParamSchema((l, d), la, init="zeros"),
        "layers/cmix_mu_k": ParamSchema((l, d), la),
        "layers/cmix_mu_r": ParamSchema((l, d), la),
        "layers/cmix_wk": ParamSchema((l, d, f), ("layers", "embed", "mlp")),
        "layers/cmix_wv": ParamSchema((l, f, d), ("layers", "mlp", "embed"),
                                      std=0.02 / math.sqrt(2 * l)),
        "layers/cmix_wr": ParamSchema((l, d, d), ("layers", "embed", None)),
    }
    return s


# ---------------------------------------------------------------------------
# WKV recurrence
# ---------------------------------------------------------------------------

def wkv_scan_ref(r, k, v, logw, u, state0=None):
    """Step-by-step oracle. r/k/v/logw: (B,T,H,Dh); u: (H,Dh).

    Returns (y (B,T,H,Dh) fp32, final state (B,H,Dh,Dh))."""
    b, t, h, dh = r.shape
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))
    uf = u.astype(jnp.float32)
    s0 = (jnp.zeros((b, h, dh, dh), jnp.float32) if state0 is None
          else state0.astype(jnp.float32))

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,Dh)
        kv = kt[..., :, None] * vt[..., None, :]           # (B,H,Dk,Dv)
        yt = jnp.einsum("bhk,bhkv->bhv", rt, s + uf[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, yt

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, w))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s_fin


def wkv_chunked(r, k, v, logw, u, state0=None, chunk: int = _CHUNK):
    """Chunked WKV (matmul form). Same signature/semantics as wkv_scan_ref."""
    b, t, h, dh = r.shape
    pad = (-t) % chunk
    if pad:
        padf = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = padf(r), padf(k), padf(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    tp = t + pad
    nc = tp // chunk
    shp = (b, nc, chunk, h, dh)
    rf, kf, vf, lw = (a.astype(jnp.float32).reshape(shp)
                      for a in (r, k, v, logw))
    uf = u.astype(jnp.float32)
    s0 = (jnp.zeros((b, h, dh, dh), jnp.float32) if state0 is None
          else state0.astype(jnp.float32))

    l_inc = jnp.cumsum(lw, axis=2)            # inclusive cumulative log decay
    l_exc = l_inc - lw                        # exclusive (decay before step t)
    k_resc = kf * jnp.exp(-l_inc)             # k' basis
    r_resc = rf * jnp.exp(l_exc)              # r' basis
    l_tot = l_inc[:, :, -1]                   # (B,nc,H,Dh)

    # intra-chunk: A[t,j] = sum_d r'_t k'_j  (strictly lower triangular)
    a_mat = jnp.einsum("bnthd,bnjhd->bnhtj", r_resc, k_resc)
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)
    a_mat = a_mat * tri[None, None, None]
    diag = jnp.einsum("bnthd,hd,bnthd->bnth", rf, uf, kf)  # u-bonus (j == t)
    y_intra = jnp.einsum("bnhtj,bnjhd->bnthd", a_mat, vf)
    y_intra += diag[..., None] * vf

    # inter-chunk state scan. Contribution of step t to the end-of-chunk state
    # decays by exp(l_tot - l_inc_t); in the k' = k*exp(-l_inc) basis that is
    # exp(l_tot) * k'. l_tot enters the scan as per-chunk (B, H, Dh) slices.
    def body(s, xs):
        r_r, k_r, v_c, ltot = xs                 # (B,C,H,Dh) x3, (B,H,Dh)
        y_in = jnp.einsum("bthk,bhkv->bthv", r_r, s)
        decay = jnp.exp(ltot)                    # per-Dk-channel chunk decay
        k_fold = k_r * decay[:, None]            # (B,C,H,Dh)
        s_new = decay[..., None] * s + jnp.einsum("bthk,bthv->bhkv", k_fold, v_c)
        return s_new, y_in

    xs = (jnp.moveaxis(r_resc, 1, 0), jnp.moveaxis(k_resc, 1, 0),
          jnp.moveaxis(vf, 1, 0),
          jnp.moveaxis(l_tot, 1, 0))             # l_tot: (B,nc,H,Dh)->(nc,B,H,Dh)
    s_fin, y_inter = jax.lax.scan(body, s0, xs)
    y_inter = jnp.moveaxis(y_inter, 0, 1)
    y = (y_intra + y_inter).reshape(b, tp, h, dh)
    return y[:, :t], s_fin


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _token_shift(x, last):
    """x_{t-1} stream: (B,T,D) with carry-in ``last`` (B,1,D)."""
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _head_norm(y, scale, eps):
    """Per-head RMS norm of (B,T,H,Dh) (RWKV GroupNorm analogue)."""
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return yf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))


def _time_mix(x, lp, cfg, shift_last, wkv_state):
    b, t, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    x_prev = _token_shift(x, shift_last)
    xx = x_prev - x

    xxx = x + xx * lp["mu_x"]
    # LoRA projections in fp32 (small; CPU backend lacks bf16->f32 dots)
    s5 = jnp.tanh(jnp.einsum("btd,dr->btr", xxx.astype(jnp.float32),
                             lp["mix_w1"].astype(jnp.float32)))
    s5 = s5.reshape(b, t, 5, _LORA_MIX)
    mu_dyn = jnp.einsum("btfr,frd->btfd", s5, lp["mix_w2"].astype(jnp.float32))
    mu = lp["mu_rkvwg"].astype(jnp.float32)[None, None] + mu_dyn  # (B,T,5,D)
    xr, xk, xv, xw, xg = (x + xx * mu[:, :, i].astype(x.dtype) for i in range(5))

    proj = lambda inp, w: jnp.einsum("btd,dhk->bthk", inp, w,
                                     preferred_element_type=jnp.bfloat16)
    r, k, v = proj(xr, lp["wr"]), proj(xk, lp["wk"]), proj(xv, lp["wv"])
    g = jax.nn.silu(proj(xg, lp["wg"]).astype(jnp.float32))
    r = shard(r, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "heads", "head_dim")
    v = shard(v, "batch", "seq", "heads", "head_dim")

    # Finch data-dependent decay, clamped for the chunked fp32 basis
    ww = lp["w0"].astype(jnp.float32) + jnp.einsum(
        "btd,dr,re->bte", xw.astype(jnp.float32),
        lp["w_lora1"].astype(jnp.float32), lp["w_lora2"].astype(jnp.float32))
    logw = -jnp.exp(jnp.minimum(ww, math.log(-_LOGW_MIN)))
    logw = logw.reshape(b, t, h, dh)

    wkv_fn = wkv_scan_ref if t <= 2 else wkv_chunked   # decode fast path
    y, wkv_new = wkv_fn(r, k, v, logw, lp["u"], wkv_state)
    y = _head_norm(y, lp["ln_x"], cfg.norm_eps) * g
    out = jnp.einsum("bthk,hkd->btd", y.astype(x.dtype), lp["wo"],
                     preferred_element_type=jnp.bfloat16)
    return out.astype(x.dtype), x[:, -1:], wkv_new


def _channel_mix(x, lp, shift_last):
    x_prev = _token_shift(x, shift_last)
    xx = x_prev - x
    xk = x + xx * lp["cmix_mu_k"]
    xr = x + xx * lp["cmix_mu_r"]
    kk = jnp.einsum("btd,df->btf", xk, lp["cmix_wk"],
                    preferred_element_type=jnp.bfloat16)
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    kk = shard(kk, "batch", "seq", "mlp")
    vv = jnp.einsum("btf,fd->btd", kk, lp["cmix_wv"],
                    preferred_element_type=jnp.bfloat16)
    rr = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, lp["cmix_wr"],
                                   preferred_element_type=jnp.float32))
    return (rr.astype(x.dtype) * vv), x[:, -1:]


def _layer(x, lp, cfg, state):
    shift_t, wkv, shift_c = state
    h, s_t_new, wkv_new = _time_mix(rms_norm(x, lp["ln1"], cfg.norm_eps),
                                    lp, cfg, shift_t, wkv)
    x = x + h
    x = shard(x, "batch", "residual_seq", "residual_embed")
    h, s_c_new = _channel_mix(rms_norm(x, lp["ln2"], cfg.norm_eps), lp, shift_c)
    x = x + h
    x = shard(x, "batch", "residual_seq", "residual_embed")
    return x, (s_t_new, wkv_new, s_c_new)


def _layer_params(params, prefix="layers/"):
    return {k[len(prefix):]: v for k, v in params.items() if k.startswith(prefix)}


def rwkv6_init_state(cfg, batch: int):
    l, d, h, dh = cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.d_head
    return {
        "shift_t": jnp.zeros((l, batch, 1, d), jnp.bfloat16),
        "wkv": jnp.zeros((l, batch, h, dh, dh), jnp.float32),
        "shift_c": jnp.zeros((l, batch, 1, d), jnp.bfloat16),
    }


def rwkv6_forward(params, tokens, cfg, mode: str = "train", state=None,
                  remat: bool = True, **_):
    """Full-sequence forward. Returns (hidden, states or None)."""
    b, t = tokens.shape
    x = embed_tokens(params["embed/table"], tokens)
    if state is None:
        state = rwkv6_init_state(cfg, b)
    lp_stack = _layer_params(params)

    def body(x, sl):
        lp, s_t, wkv, s_c = sl
        x, new_state = _layer(x, lp, cfg, (s_t, wkv, s_c))
        return x, new_state

    if mode == "train" and remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable,
                              prevent_cse=False)
    x, states = jax.lax.scan(
        body, x, (lp_stack, state["shift_t"], state["wkv"], state["shift_c"]))
    x = rms_norm(x, params["final_norm/w"], cfg.norm_eps)
    if mode == "train":
        return x, None
    return x, {"shift_t": states[0], "wkv": states[1], "shift_c": states[2]}


def rwkv6_decode_step(params, tokens, state, pos, cfg, **_):
    """One-token step; the recurrence makes this O(1) in context length."""
    hidden, new_state = rwkv6_forward(params, tokens, cfg, mode="decode",
                                      state=state, remat=False)
    return hidden, new_state
