"""Two-process ``jax.distributed`` cluster driver (DESIGN.md §11).

Run with no cluster env set, this module is the **parent**: it picks
free ports, spawns one child per process (same interpreter, same argv)
with ``AMP_COORDINATOR`` / ``AMP_NUM_PROCESSES`` / ``AMP_PROCESS_ID``
and ``--xla_force_host_platform_device_count`` fake devices, waits, and
propagates the worst child exit code — the CI ``multihost`` job's entry
point.

With ``AMP_PROCESS_ID`` set, it is a **child**: every process joins the
``jax.distributed`` cluster via ``init_cluster`` (real coordinator
handshake, global device discovery), then

  * process 1..K-1 each serve a ``SolveService`` behind a
    ``BackendServer`` — codec frames on TCP, no pickle — until the
    frontend sends the shutdown op, and
  * process 0 (the frontend, ``ClusterInfo.is_frontend``) builds a
    ``ClusterService`` over its own ``LocalBackend`` plus one
    ``TcpBackend`` per remote, prewarms the menu, streams a smoke load,
    and pins the invariants: results bit-identical to a single-host
    ``SolveService`` on the same stream, zero steady-state compiles
    after prewarm, every host actually served.

On CPU the cluster coordinates but cannot run cross-process XLA
computations (``supports_cross_host_collectives`` is False), so this is
exactly the regime the request-level router exists for: the test proves
the TCP + codec path end-to-end under a real multi-process jax runtime.

  PYTHONPATH=src python -m repro.launch.multihost --smoke

``--chaos`` (DESIGN.md §13) is the two-process fault drill: the
frontend submits the full stream, then kills host1's real backend
process mid-flight (the ``X`` frame op — the server stops serving with
computed results still buffered), and the gate is that the flush
recovers everything over the real TCP path: zero lost requests,
failover counted, host1 evicted as dead, recovery latency measured,
and the surviving results bit-identical to a single-host run.
"""
from __future__ import annotations

import argparse
import os
import socket
import subprocess
import sys
import time

_DEVICES_PER_HOST = 4


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def parent(args) -> int:
    coord = _free_port()
    backend_ports = [_free_port() for _ in range(args.processes - 1)]
    env = dict(os.environ)
    env.update({
        "AMP_COORDINATOR": f"127.0.0.1:{coord}",
        "AMP_NUM_PROCESSES": str(args.processes),
        "AMP_BACKEND_PORTS": ",".join(map(str, backend_ports)),
        "XLA_FLAGS": (env.get("XLA_FLAGS", "")
                      + f" --xla_force_host_platform_device_count="
                        f"{_DEVICES_PER_HOST}").strip(),
    })
    procs = []
    for pid in range(args.processes):
        cenv = dict(env, AMP_PROCESS_ID=str(pid))
        procs.append(subprocess.Popen([sys.executable, "-m",
                                       "repro.launch.multihost", *sys.argv[1:]],
                                      env=cenv))
    deadline = time.time() + args.timeout
    codes = []
    try:
        for p in procs:
            left = max(1.0, deadline - time.time())
            try:
                codes.append(p.wait(timeout=left))
            except subprocess.TimeoutExpired:
                p.kill()
                codes.append(124)
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
    worst = max(abs(c) for c in codes)
    print(f"multihost parent: child exit codes {codes}")
    return worst


def _make_load(n_req: int):
    import jax
    import numpy as np

    from ..core.amp import sample_problem
    from ..core.denoisers import BernoulliGauss
    from ..core.state_evolution import CSProblem
    from ..serving import SolveRequest

    n, m, p, t = 128, 64, 4, 8
    prior = BernoulliGauss(eps=0.1)
    prob = CSProblem(n=n, m=m, prior=prior, snr_db=20.0)
    deltas = np.full(t, 0.05, np.float32)
    deltas[0] = np.inf
    reqs = []
    for i in range(n_req):
        _, a, y = sample_problem(jax.random.PRNGKey(i), n, m, prior,
                                 prob.sigma_e2)
        reqs.append(SolveRequest(y=y, a=a, prior=prior, n_proc=p,
                                 n_iter=t, policy="fixed", deltas=deltas))
    return prior, reqs


def child(args) -> int:
    from .mesh import init_cluster, supports_cross_host_collectives

    info = init_cluster()
    ports = [int(p) for p in
             os.environ["AMP_BACKEND_PORTS"].split(",") if p]
    print(f"multihost[{info.process_index}]: {info.process_count} procs, "
          f"{info.local_devices} local / {info.global_devices} global "
          f"devices, cross-host collectives="
          f"{supports_cross_host_collectives()}")
    assert info.process_count == args.processes, info
    assert info.global_devices == args.processes * _DEVICES_PER_HOST, info

    from ..serving import BucketPolicy, PrewarmSpec, SolveService
    from ..serving.frontend import BackendServer, LocalBackend

    policy = BucketPolicy(max_batch=8, n_quantum=64, mp_quantum=8)

    if not info.is_frontend:
        # backend process: serve until the frontend's shutdown op
        port = ports[info.process_index - 1]
        server = BackendServer(
            LocalBackend(f"host{info.process_index}",
                         SolveService(policy=policy,
                                      rate_accounting=False)),
            port=port)
        print(f"multihost[{info.process_index}]: backend on :{port}")
        server.serve_forever()
        return 0

    # frontend process: LocalBackend for host0 + TcpBackend per remote
    import numpy as np

    from ..serving import ClusterService, RouterPolicy
    from ..serving.frontend import TcpBackend

    from ..serving.wire import BackendUnavailable

    backends = [LocalBackend("host0",
                             SolveService(policy=policy,
                                          rate_accounting=False))]
    for i, port in enumerate(ports, start=1):
        for attempt in range(60):   # backend process may still be booting
            try:
                backends.append(TcpBackend(
                    ("127.0.0.1", port), f"host{i}",
                    connect_timeout_s=5.0, recv_timeout_s=60.0))
                break
            except (ConnectionError, OSError, BackendUnavailable):
                time.sleep(0.5)
        else:
            print(f"multihost[0]: backend host{i} on :{port} never came up")
            return 2

    rp = RouterPolicy(min_replicas=len(backends))
    if args.chaos:
        # fast detection: one failed call suspects, two evict
        rp = RouterPolicy(min_replicas=len(backends), suspect_after=1,
                          dead_after=2, retry_limit=2,
                          retry_backoff_s=0.05)
    cluster = ClusterService(backends=backends, policy=policy,
                             router_policy=rp)
    prior, reqs = _make_load(args.requests)
    menu = [PrewarmSpec(n=128, m=64, n_proc=4, n_iter=8, policy="fixed",
                        prior=prior, batch_widths=(8,))]
    cluster.prewarm(menu)
    # per-host warm counts: a host that dies mid-drill drops out of the
    # cluster-wide count, so steady-state compiles compare per survivor
    warm = {hid: b.compile_count()
            for hid, b in cluster.backends.items()}

    t0 = time.time()
    if args.chaos:
        # submit everything, then kill host1's backend PROCESS with
        # its results still buffered server-side: the flush must fail
        # over every stranded request to the survivors
        ids = [cluster.submit(r) for r in reqs]
        stranded = sum(1 for hk in cluster._inflight if hk[0] == "host1")
        cluster.backends["host1"].kill_server()
        print(f"multihost[0]: chaos — killed host1 with {stranded} "
              f"requests in flight there")
        got = list(cluster.flush())
        own = set(ids)
        results = sorted((r for r in got if r.request_id in own),
                         key=lambda r: r.request_id)
    else:
        results = sorted(cluster.solve(reqs), key=lambda r: r.request_id)
    dt = time.time() - t0

    # single-host reference on the same stream: cluster results must be
    # bit-identical (same padded widths -> same compiled programs)
    ref_svc = SolveService(policy=policy, rate_accounting=False)
    ref_svc.prewarm(menu)
    ref = ref_svc.solve(reqs)
    max_dx = max(float(np.max(np.abs(c.x - r.x)))
                 for c, r in zip(results, ref))

    st = cluster.stats()
    served = st["router"]["served"]
    steady = sum(b.compile_count() - warm[hid]
                 for hid, b in cluster.backends.items()
                 if cluster.router.host_state(hid) != "dead")
    print(f"multihost[0]: {len(results)} results in {dt:.2f}s over "
          f"{len(backends)} hosts; served {served}; "
          f"steady-state compiles {steady}; max|dx| {max_dx:.1e}; "
          f"imbalance {st['router']['imbalance']:.2f}x")
    if args.chaos:
        rec = st["recovery"] or {}
        print(f"multihost[0]: chaos — states {st['host_states']}; "
              f"failovers {st['failovers']}, retries {st['retries']}, "
              f"lost {st['lost']}; recovery p95 "
              f"{rec.get('p95_ms', float('nan')):.1f}ms "
              f"(n={rec.get('count', 0)})")
    # measured TCP routing overhead per frame kind (DESIGN.md §12):
    # submits ("S") are the hot path, flush/prewarm amortize
    for host_id, per_op in cluster.rtt_stats().items():
        line = "  ".join(f"{op}: p50 {s['p50_ms']:.2f}ms "
                         f"p95 {s['p95_ms']:.2f}ms (n={s['count']})"
                         for op, s in per_op.items())
        print(f"multihost[0]: {host_id} frame rtt  {line}")
    cluster.close(shutdown_remote=True)

    failures = []
    if len(results) != len(reqs):
        failures.append(f"{len(reqs) - len(results)} results missing")
    if max_dx != 0.0:
        failures.append(f"cluster differs from single-host: "
                        f"max|dx|={max_dx:.2e}")
    if steady != 0:
        failures.append(f"{steady} steady-state compiles after prewarm")
    if any(v == 0 for v in served.values()):
        failures.append(f"idle host in {served}")
    if args.chaos:
        if st["lost"] != 0:
            failures.append(f"{st['lost']} requests lost in failover")
        if st["failovers"] != 1:
            failures.append(f"expected 1 failover, saw {st['failovers']}")
        if st["retries"] == 0:
            failures.append("no retries counted despite a host kill")
        if st["host_states"].get("host1") != "dead":
            failures.append(f"host1 not evicted: {st['host_states']}")
        if not st["recovery"]:
            failures.append("no recovery latency recorded")
    for msg in failures:
        print(f"multihost[0]: FAIL: {msg}")
    return 1 if failures else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--smoke", action="store_true",
                    help="16 requests (CI sanity)")
    ap.add_argument("--chaos", action="store_true",
                    help="kill one backend process mid-stream and gate "
                         "on zero-loss failover (DESIGN.md §13)")
    ap.add_argument("--timeout", type=float, default=420.0,
                    help="parent-side wall clock before children are "
                         "killed (exit 124)")
    args = ap.parse_args()
    if args.smoke:
        args.requests = 16
    if os.environ.get("AMP_PROCESS_ID") is None:
        return parent(args)
    return child(args)


if __name__ == "__main__":
    sys.exit(main())
