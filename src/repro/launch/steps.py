"""train_step / prefill_step / decode_step builders with full sharding specs.

These are the jit roots: everything the dry-run lowers and the trainer runs.
Each builder returns (fn, in_shardings, out_shardings, abstract_inputs) so
callers can either execute or ``jax.jit(fn, ...).lower(...)``.

Gradient fusion across the 'pod' axis optionally runs through the paper's
lossy compression (core/compression.compressed_psum) inside a partial-manual
shard_map (manual: pod; auto: data/model) — wire bytes drop 4x (int8) or 8x
(int4) on exactly the links where the paper's technique targets its savings.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..configs.base import ModelConfig, ShapeSpec
from ..core.compression import QuantConfig, compressed_psum
from ..models import chunked_xent_loss, get_model, lm_logits
from ..optim import AdamWConfig, adamw_init, adamw_update, opt_state_specs
from ..sharding import logical_spec, make_rules, use_sharding

__all__ = ["TrainStepConfig", "build_train_step", "build_serve_step"]


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    microbatches: int = 1
    compression_bits: int | None = None   # None = exact bf16 fusion over pod
    remat: bool = True
    zero1: bool = True
    adamw: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    moe_groups: int = 16
    strategy: str = "tp"                  # 'tp' | 'fsdp' (see make_rules)


def _rules_with_zero(cfg, mesh, mode, decode_batch=None, strategy="tp"):
    rules = make_rules(cfg, mesh, mode, decode_batch, strategy=strategy)
    zero = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if strategy == "fsdp" and "model" in mesh.shape:
        zero = zero + ("model",)
    rules["zero"] = zero or None
    return rules


def _strip_pod(rules):
    """Rules for code running inside a manual-'pod' shard_map body."""
    out = {}
    for k, v in rules.items():
        if isinstance(v, (tuple, list)):
            v = tuple(a for a in v if a != "pod") or None
            if isinstance(v, tuple) and len(v) == 1:
                v = v[0]
        elif v == "pod":
            v = None
        out[k] = v
    return out


def _shardings_for(tree_specs, shapes, mesh):
    out = {}
    for k, axes in tree_specs.items():
        out[k] = NamedSharding(mesh, logical_spec(axes, shapes[k]))
    return out


def build_train_step(cfg: ModelConfig, mesh, shape: ShapeSpec,
                     tcfg: TrainStepConfig = TrainStepConfig()):
    """Returns (train_step, state_shardings, input_shardings, abstract args)."""
    model = get_model(cfg)
    rules = _rules_with_zero(cfg, mesh, "train", strategy=tcfg.strategy)
    pod_axis = "pod" in mesh.shape
    n_pods = mesh.shape.get("pod", 1)

    schema = model.schema
    param_shapes = {k: ps.shape for k, ps in schema.items()}
    p_specs = model.param_specs()

    with use_sharding(mesh, rules):
        param_sh = _shardings_for(p_specs, param_shapes, mesh)
        o_specs = opt_state_specs(p_specs, mesh, param_shapes, tcfg.zero1)
        opt_sh = {
            "master": _shardings_for(o_specs["master"], param_shapes, mesh),
            "m": _shardings_for(o_specs["m"], param_shapes, mesh),
            "v": _shardings_for(o_specs["v"], param_shapes, mesh),
            "step": NamedSharding(mesh, P()),
        }
        batch_sh = NamedSharding(mesh, logical_spec(
            ("batch", "seq"), (shape.global_batch, shape.seq_len)))
        grad_acc_specs = o_specs["m"]  # ZeRO-sharded fp32 accumulator

    aux_abstract = model.aux_inputs(shape.global_batch, shape.seq_len)
    with use_sharding(mesh, rules):
        aux_sh = {k: NamedSharding(mesh, logical_spec(("batch", None, None), v.shape))
                  for k, v in aux_abstract.items()}

    inner_rules = _strip_pod(rules) if pod_axis else rules

    def loss_fn(params, tokens, labels, aux):
        hidden, _ = model.forward(params, tokens, cfg, mode="train",
                                  remat=tcfg.remat, n_groups=tcfg.moe_groups,
                                  **aux)
        return chunked_xent_loss(params, hidden, labels, cfg)

    def grads_microbatched(params, tokens, labels, aux, rules_in):
        """Gradient accumulation over microbatches (fp32, ZeRO-sharded).

        Each microbatch's fp32 grads are constrained to the ZeRO ('zero'
        axis) sharding *at production* — XLA reduce-scatters per leaf instead
        of materializing the full fp32 gradient (at 47B params that fp32
        transient alone is 11.7 GB/device)."""
        mb = tcfg.microbatches

        def rs(tree):
            # constrain in the gradient's native bf16 *first* (the transient
            # full-size buffer stays 2 bytes/elem), cast to fp32 after the
            # reduce-scatter when the per-device shard is 'zero'-sized
            with use_sharding(mesh, rules_in):
                out = {}
                for k, v in tree.items():
                    sh = NamedSharding(mesh, logical_spec(
                        grad_acc_specs[k], param_shapes[k]))
                    v = jax.lax.with_sharding_constraint(v, sh)
                    out[k] = v.astype(jnp.float32)
                return out

        with use_sharding(mesh, rules_in):
            if mb == 1:
                loss, grads = jax.value_and_grad(loss_fn)(params, tokens,
                                                          labels, aux)
                return loss, rs(grads)
            b = tokens.shape[0]
            tok = tokens.reshape(mb, b // mb, -1)
            lab = labels.reshape(mb, b // mb, -1)
            aux_r = {k: v.reshape(mb, b // mb, *v.shape[1:])
                     for k, v in aux.items()}

            def body(carry, xs):
                acc, loss_acc = carry
                tk, lb = xs[0], xs[1]
                aux_i = {k: xs[2 + i] for i, k in enumerate(sorted(aux_r))}
                loss, grads = jax.value_and_grad(loss_fn)(params, tk, lb, aux_i)
                grads = rs(grads)
                acc = {k: acc[k] + grads[k] for k in acc}
                return (acc, loss_acc + loss), ()

            acc0 = rs({k: jnp.zeros(param_shapes[k], jnp.bfloat16)
                       for k in params})
            xs = (tok, lab) + tuple(aux_r[k] for k in sorted(aux_r))
            (grads, loss_sum), _ = jax.lax.scan(body, (acc0, jnp.zeros(())), xs)
            inv = 1.0 / mb
            return loss_sum * inv, {k: g * inv for k, g in grads.items()}

    # the manual-'pod' shard_map exists only to make the *compressed* fusion
    # expressible (int8/int4 collectives in HLO). Uncompressed multi-pod
    # fusion is plain GSPMD: XLA inserts the exact pod all-reduce itself —
    # this is also the paper-faithful "32-bit fusion" baseline. (The MoE
    # dispatch scatter inside a manual-axis shard_map trips an XLA SPMD
    # partitioner CHECK at 512 devices — see EXPERIMENTS.md §Dry-run notes —
    # so MoE archs currently fuse uncompressed across pods.)
    if pod_axis and tcfg.compression_bits is not None:
        qc = QuantConfig(bits=tcfg.compression_bits)

        def pod_body(params, tokens, labels, aux):
            loss, grads = grads_microbatched(params, tokens, labels, aux,
                                             inner_rules)
            fused, noise = {}, jnp.zeros(())
            for k in sorted(grads):
                fused[k], nv = compressed_psum(grads[k], "pod", qc)
                noise = noise + nv
            grads = {k: v / n_pods for k, v in fused.items()}
            loss = jax.lax.psum(loss, "pod") / n_pods
            return loss, grads, noise

        # partial-manual shard_map: specs may only mention the manual axis
        # ('pod'); data/model sharding stays under GSPMD control (auto).
        pod_grads = shard_map(
            pod_body, mesh=mesh,
            in_specs=({k: P() for k in p_specs},
                      P("pod", None), P("pod", None),
                      {k: P("pod", None, None) for k in aux_abstract}),
            out_specs=(P(), {k: P() for k in p_specs}, P()),
            axis_names={"pod"}, check=False)
    else:
        def pod_grads(params, tokens, labels, aux):  # single-pod: plain GSPMD
            loss, grads = grads_microbatched(params, tokens, labels, aux, rules)
            return loss, grads, jnp.zeros(())

    def train_step(params, opt_state, tokens, labels, aux):
        loss, grads, noise = pod_grads(params, tokens, labels, aux)
        with use_sharding(mesh, rules):
            new_params, new_opt, metrics = adamw_update(
                params, grads, opt_state, tcfg.adamw)
        metrics = dict(metrics, loss=loss, quant_noise=noise)
        return new_params, new_opt, metrics

    abstract = {
        "params": {k: jax.ShapeDtypeStruct(ps.shape, jnp.bfloat16)
                   for k, ps in schema.items()},
        "tokens": jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                       jnp.int32),
        "labels": jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                       jnp.int32),
        "aux": aux_abstract,
    }
    opt_abstract = {
        "master": {k: jax.ShapeDtypeStruct(s, jnp.float32)
                   for k, s in param_shapes.items()},
        "m": {k: jax.ShapeDtypeStruct(s, jnp.float32)
              for k, s in param_shapes.items()},
        "v": {k: jax.ShapeDtypeStruct(s, jnp.float32)
              for k, s in param_shapes.items()},
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    abstract["opt_state"] = opt_abstract

    shardings = {
        "params": param_sh, "opt_state": opt_sh,
        "tokens": batch_sh, "labels": batch_sh, "aux": aux_sh,
    }
    return train_step, shardings, abstract


def build_serve_step(cfg: ModelConfig, mesh, shape: ShapeSpec,
                     moe_groups: int = 16):
    """Prefill or decode step per shape.kind. Returns (fn, shardings, abstract)."""
    model = get_model(cfg)
    b, s = shape.global_batch, shape.seq_len
    mode = "decode" if shape.kind == "decode" else "prefill"
    rules = _rules_with_zero(cfg, mesh, mode,
                             decode_batch=b if mode == "decode" else None)
    schema = model.schema
    param_shapes = {k: ps.shape for k, ps in schema.items()}
    p_specs = model.param_specs()

    with use_sharding(mesh, rules):
        param_sh = _shardings_for(p_specs, param_shapes, mesh)
        state_abstract = jax.eval_shape(lambda: model.init_state(cfg, b, s))
        state_sh = jax.tree.map(
            lambda x: NamedSharding(mesh, _state_spec(x.shape, rules, mesh)),
            state_abstract)

    aux_abstract = model.aux_inputs(b, s)
    with use_sharding(mesh, rules):
        aux_sh = {k: NamedSharding(mesh, logical_spec(("batch", None, None), v.shape))
                  for k, v in aux_abstract.items()}
        tok_sh_full = NamedSharding(mesh, logical_spec(("batch", "seq"), (b, s)))
        tok_sh_one = NamedSharding(mesh, logical_spec(("batch", "seq"), (b, 1)))

    if mode == "prefill":
        tok_sh = tok_sh_full

        def prefill_step(params, tokens, aux):
            with use_sharding(mesh, rules):
                hidden, caches = model.forward(params, tokens, cfg,
                                               mode="prefill", remat=False,
                                               n_groups=moe_groups, **aux)
                logits = lm_logits(params, hidden[:, -64:], cfg)
            return logits, caches

        abstract = {"params": {k: jax.ShapeDtypeStruct(ps.shape, jnp.bfloat16)
                               for k, ps in schema.items()},
                    "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                    "aux": aux_abstract}
        return prefill_step, {"params": param_sh, "tokens": tok_sh,
                              "aux": aux_sh}, abstract

    tok_sh = tok_sh_one

    def decode_step(params, tokens, state, pos):
        with use_sharding(mesh, rules):
            hidden, new_state = model.decode_step(params, tokens, state, pos,
                                                  cfg, n_groups=moe_groups)
            logits = lm_logits(params, hidden, cfg)
        return logits, new_state

    abstract = {"params": {k: jax.ShapeDtypeStruct(ps.shape, jnp.bfloat16)
                           for k, ps in schema.items()},
                "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
                "state": state_abstract,
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    return decode_step, {"params": param_sh, "tokens": tok_sh,
                         "state": state_sh,
                         "pos": NamedSharding(mesh, P())}, abstract


def _state_spec(shape, rules, mesh):
    """Heuristic cache/state PartitionSpec: (layers, batch, seq, kv, dh) or
    recurrent-state layouts; batch -> data when divisible, seq -> kv_seq rule."""
    from ..sharding import _axis_size  # noqa

    ndim = len(shape)
    if ndim >= 3:
        # (L, B, S, ...) caches and (L, B, ...) states
        names = ["layers", "batch"]
        if ndim >= 4:
            names.append("kv_seq")
            names += [None] * (ndim - 3)
        else:
            names += [None] * (ndim - 2)
    else:
        names = [None] * ndim
    return logical_spec(names, shape)
