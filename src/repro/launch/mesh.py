"""Production mesh definitions (brief: 16x16 single-pod, 2x16x16 multi-pod).

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

from ..compat import make_mesh

__all__ = ["make_production_mesh", "make_host_mesh", "make_serve_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = jax.device_count()
    data = data or (n // model)
    return make_mesh((data, model), ("data", "model"))


def make_serve_mesh(n_devices: int | None = None):
    """1-D 'data' mesh for the solve service's placement dispatcher.

    Both serving placements run over this one axis: data-parallel buckets
    shard the request batch across it, processor-sharded solves map the
    paper's P onto it (DESIGN.md §6). Defaults to every visible device;
    pass ``n_devices`` to serve from a subset (e.g. to leave devices for a
    co-located training job).
    """
    n = n_devices or jax.device_count()
    return make_mesh((n,), ("data",))
