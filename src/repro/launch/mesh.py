"""Mesh and cluster topology (single host + `jax.distributed` tier).

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).

The cluster tier (DESIGN.md §11): ``init_cluster`` brings a process into
a ``jax.distributed`` cluster (coordinator + process id from args or
``AMP_COORDINATOR`` / ``AMP_NUM_PROCESSES`` / ``AMP_PROCESS_ID`` env),
after which ``jax.devices()`` is the *global* device list.
``make_cluster_mesh`` then builds the widest serve mesh the backend
supports: a global mesh spanning every host's devices where cross-host
collectives exist (TPU/GPU), so processor-sharded large singles span
hosts — and a host-local mesh on backends without multi-process
computations (CPU: jaxlib rejects them), where data-parallel buckets and
proc-sharded singles stay host-local and the cluster router is the only
cross-host axis. ``supports_cross_host_collectives`` is the gate.
"""
from __future__ import annotations

import dataclasses
import os

import jax

from ..compat import make_mesh

__all__ = ["make_production_mesh", "make_host_mesh", "make_serve_mesh",
           "ClusterInfo", "init_cluster",
           "supports_cross_host_collectives", "make_cluster_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = jax.device_count()
    data = data or (n // model)
    return make_mesh((data, model), ("data", "model"))


def make_serve_mesh(n_devices: int | None = None):
    """1-D 'data' mesh for the solve service's placement dispatcher.

    Both serving placements run over this one axis: data-parallel buckets
    shard the request batch across it, processor-sharded solves map the
    paper's P onto it (DESIGN.md §6). Defaults to every *local* device;
    pass ``n_devices`` to serve from a subset (e.g. to leave devices for a
    co-located training job).
    """
    n = n_devices or jax.local_device_count()
    # pin to local devices: under jax.distributed, jax.devices() is the
    # global list, but a host's serve mesh must stay host-local (the
    # cluster router, not the mesh, is the cross-host axis on CPU)
    return make_mesh((n,), ("data",), devices=jax.local_devices()[:n])


# -- cluster tier (DESIGN.md §11) -------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClusterInfo:
    """This process's view of the cluster after ``init_cluster``."""

    process_index: int
    process_count: int
    local_devices: int
    global_devices: int
    coordinator: str | None

    @property
    def is_frontend(self) -> bool:
        """Process 0 hosts the cluster frontend/router by convention."""
        return self.process_index == 0


def init_cluster(coordinator_address: str | None = None,
                 num_processes: int | None = None,
                 process_id: int | None = None) -> ClusterInfo:
    """Join (or stand alone as) a ``jax.distributed`` cluster.

    Arguments fall back to ``AMP_COORDINATOR`` / ``AMP_NUM_PROCESSES`` /
    ``AMP_PROCESS_ID``; with no coordinator configured (or a process
    count of 1) this is a single-process no-op returning the local
    topology. Idempotent: a process already initialized (by a prior call
    or by the launcher) just reports the live topology.

    Call before any other jax API touches the backend — like mesh
    creation, distributed initialization must precede first device use.
    """
    coordinator_address = (coordinator_address
                           or os.environ.get("AMP_COORDINATOR"))
    if num_processes is None:
        env = os.environ.get("AMP_NUM_PROCESSES")
        num_processes = int(env) if env else None
    if process_id is None:
        env = os.environ.get("AMP_PROCESS_ID")
        process_id = int(env) if env else None

    # probe distributed state WITHOUT jax.process_count(): that call
    # instantiates the backend client, after which
    # jax.distributed.initialize refuses ("must be called before any JAX
    # computations are executed")
    try:
        from jax._src import distributed as _dist
        already = _dist.global_state.client is not None
    except Exception:   # private-module layout drift: assume fresh
        already = False
    if (coordinator_address and num_processes and num_processes > 1
            and not already):
        jax.distributed.initialize(
            coordinator_address=coordinator_address,
            num_processes=num_processes, process_id=process_id)
    return ClusterInfo(
        process_index=jax.process_index(),
        process_count=jax.process_count(),
        local_devices=jax.local_device_count(),
        global_devices=jax.device_count(),
        coordinator=coordinator_address,
    )


def supports_cross_host_collectives() -> bool:
    """Whether XLA computations may span this cluster's processes.

    True trivially for a single process. Multi-process CPU clusters
    coordinate (device discovery, process ids) but jaxlib's CPU client
    rejects multi-process *computations* ("Multiprocess computations
    aren't implemented on the CPU backend"), so cross-host
    processor-sharded solves are TPU/GPU-only; CPU clusters route across
    hosts at the request level instead (serving.frontend).
    """
    if jax.process_count() <= 1:
        return True
    return jax.default_backend() != "cpu"


def make_cluster_mesh():
    """The widest 1-D serve mesh this process may dispatch onto:
    all-host global when cross-host collectives are supported (the mesh
    axis then spans every process's devices, so a processor-sharded
    large single maps the paper's P across hosts), else the host-local
    serve mesh (data-parallel buckets were host-local either way —
    request-level routing is the cross-host axis on CPU)."""
    if jax.process_count() > 1 and supports_cross_host_collectives():
        return make_mesh((jax.device_count(),), ("data",))
    return make_serve_mesh()
