"""AMP solve-service launcher: synthetic heterogeneous load -> SolveService.

Generates a stream of CS recovery requests with mixed shapes, priors, SNRs
and rate policies (the "many users, many scenarios" traffic of ROADMAP),
runs them through the shape-bucketed batching service, and reports
per-request quality/rate plus end-to-end throughput.

  PYTHONPATH=src python -m repro.launch.amp_serve --smoke
  PYTHONPATH=src python -m repro.launch.amp_serve --requests 256 \\
      --max-batch 64 --policies fixed,bt,lossless

``--mesh`` serves over all visible devices through the placement
dispatcher (DESIGN.md §6): the bucket column then shows where each
request ran (data-parallel vs processor-sharded).

The shape menu mixes wide (row-partitioned) and tall (column-partitioned
C-MP-AMP, DESIGN.md §7) requests; the layout router batches each family
into its own buckets and the summary reports rate totals *per layout* —
row rates are bits per signal element per processor, column rates are
bits per *measurement* per processor (length-M residual exchanges), so
one aggregate line would add apples to oranges.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..core.amp import sample_problem
from ..core.denoisers import BernoulliGauss
from ..core.state_evolution import CSProblem
from ..serving import (BucketPolicy, PrewarmSpec, SolveRequest,
                       SolveService)

# (N, M, P) menu — wide shapes (N/M ~ 3.2) route row, tall ones (N/M >=
# 4) route column; P divides every M and every N
SHAPES = [(512, 160, 4), (1024, 320, 8), (2048, 512, 8), (4096, 512, 8)]
EPS_MENU = (0.05, 0.1)
SNR_MENU = (15.0, 20.0, 25.0)


def make_request(rng: np.random.Generator, i: int, policies) -> tuple:
    n, m, p = SHAPES[rng.integers(len(SHAPES))]
    # tall shapes undersample harder (kappa = M/N down to 1/8): keep their
    # signals sparse enough to sit inside the AMP recovery region
    eps_menu = (0.02, 0.05) if n >= 4 * m else EPS_MENU
    prior = BernoulliGauss(eps=float(rng.choice(eps_menu)))
    snr = float(rng.choice(SNR_MENU))
    t = int(rng.choice((6, 8, 10)))
    policy = str(rng.choice(policies))
    prob = CSProblem(n=n, m=m, prior=prior, snr_db=snr)
    s0, a, y = sample_problem(jax.random.PRNGKey(i), n, m, prior,
                              prob.sigma_e2)
    kw = {}
    if policy == "fixed":
        deltas = np.full(t, 0.05, np.float32)
        deltas[0] = np.inf
        kw["deltas"] = deltas
    req = SolveRequest(y=y, a=a, prior=prior, snr_db=snr, n_proc=p,
                       n_iter=t, policy=policy, **kw)
    return req, s0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--policies", default="lossless,fixed,bt",
                    help="comma list from lossless,fixed,dp,bt")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="16 requests, small batches, no rate accounting")
    ap.add_argument("--mesh", action="store_true",
                    help="serve over all visible devices (placement "
                         "dispatcher; forced-host devices need XLA_FLAGS "
                         "set before launch)")
    ap.add_argument("--hosts", type=int, default=1,
                    help="serve through the cluster tier (DESIGN.md §11) "
                         "with this many emulated hosts: a ClusterService "
                         "routes buckets across per-host SolveServices "
                         "and autoscales per-bucket replicas from demand "
                         "EWMAs")
    ap.add_argument("--prewarm", action="store_true",
                    help="AOT-compile the SHAPES bucket menu before "
                         "streaming (DESIGN.md §9): compiles move out of "
                         "the serving path, the summary then reports "
                         "steady-state compiles")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="dump per-request trace spans as Chrome "
                         "trace-event JSONL (DESIGN.md §12; wrap the "
                         "lines in [...] for chrome://tracing)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="dump the final metrics snapshot as Prometheus "
                         "text exposition format")
    args = ap.parse_args()

    n_req = 16 if args.smoke else args.requests
    policies = args.policies.split(",")
    rng = np.random.default_rng(args.seed)
    pairs = [make_request(rng, i, policies) for i in range(n_req)]

    mesh = None
    max_batch = args.max_batch
    if args.mesh:
        from ..serving.buckets import round_up
        from .mesh import make_serve_mesh
        mesh = make_serve_mesh()
        # data-parallel dispatch needs a device-multiple batch cap
        max_batch = round_up(max_batch, mesh.shape["data"])
    if args.hosts > 1:
        from ..serving import ClusterService, RouterPolicy
        assert not args.mesh, \
            "--hosts emulates single-device hosts; combine with --mesh " \
            "only on a real multi-host launch (repro.launch.multihost)"
        svc = ClusterService(
            n_hosts=args.hosts, policy=BucketPolicy(max_batch=max_batch),
            router_policy=RouterPolicy(scrape_every_s=0.25,
                                       ewma_halflife_s=2.0),
            rate_accounting=not args.smoke)
    else:
        svc = SolveService(policy=BucketPolicy(max_batch=max_batch),
                           rate_accounting=not args.smoke, mesh=mesh)
    prewarmed = 0
    if args.prewarm:
        # one spec per (shape, t-bucket, program family): T in {6,8} and
        # {10} pad to distinct t_max buckets; BT solves trace a different
        # program (in-graph table controller) than the other policies
        fams = [p for p in ("lossless", "bt") if p == "lossless"
                or "bt" in policies]
        menu = [PrewarmSpec(n=n, m=m, n_proc=p, n_iter=t, policy=fam)
                for (n, m, p) in SHAPES for t in (8, 12) for fam in fams]
        rep = svc.prewarm(menu)
        if args.hosts > 1:
            rep = next(iter(rep.values()))     # per-host reports are equal
        prewarmed = rep["programs"]
        print(f"prewarm: {rep['programs']} programs over "
              f"{len(rep['buckets'])} buckets in {rep['seconds']:.1f}s")
    if args.hosts > 1:
        # production elasticity shape (DESIGN.md §12): the autoscaler
        # scrape loop runs on its own daemon thread at scrape_every_s
        # instead of piggybacking ticks on the submit path
        svc.start_scraper()
    t0 = time.time()
    results = list(svc.stream(r for r, _ in pairs))
    dt = time.time() - t0
    if args.hosts > 1:
        svc.stop_scraper()

    # request ids are assigned in submission order, i.e. pairs[rid]
    print(f"{'id':>4s} {'policy':>9s} {'T':>3s} {'bucket':>22s} {'B':>4s} "
          f"{'mse':>10s} {'bits':>7s}")
    for r in sorted(results, key=lambda res: res.request_id):
        req, s0 = pairs[r.request_id]
        bk = f"({r.bucket.n_pad},{r.bucket.m_pad},{r.bucket.n_proc}," \
             f"{r.bucket.t_max}){r.bucket.placement[0]}" \
             f"{r.bucket.layout[0]}"
        # untracked (no finite per-iteration rate) shows "-"; a genuine
        # 0.00-bit total from finite rates still prints as a number
        bits = f"{r.total_bits:7.2f}" if r.tracked else "      -"
        print(f"{r.request_id:4d} {req.policy:>9s} {req.n_iter:3d} "
              f"{bk:>22s} {r.batch_size:4d} {r.mse(s0):10.3e} {bits}")

    # per-layout rate totals: row rates count bits/signal-element/proc,
    # column rates bits/measurement/proc — never one aggregate number
    unit = {"row": "bits/elem", "col": "bits/meas"}
    for layout in ("row", "col"):
        in_layout = [r for r in results if r.bucket.layout == layout]
        if not in_layout:
            continue
        tracked = [r for r in in_layout if r.tracked]
        tot = sum(r.total_bits for r in tracked)
        print(f"{layout}: {len(in_layout)} requests, "
              f"{len(tracked)} rate-tracked, "
              f"{tot:.1f} {unit[layout]} total"
              + (f" ({tot / len(tracked):.2f} avg)" if tracked else ""))
    st = svc.stats()
    if args.hosts > 1:
        # cluster tier: per-host hot-path stats roll up, plus the
        # scheduler's routing/autoscaling view (DESIGN.md §11)
        hosts = st["hosts"]
        compiles = sum(h["compiles"]["total"] for h in hosts.values())
        hits = sum(h["operand_cache"]["hits"] for h in hosts.values())
        misses = sum(h["operand_cache"]["misses"] for h in hosts.values())
        buckets = sum(len(h["compiles"]["by_bucket"])
                      for h in hosts.values())
        rt = st["router"]
        print(f"\n{n_req} requests in {dt:.2f}s  "
              f"({n_req / dt:.1f} req/s, {len(hosts)} hosts, "
              f"{buckets} compiled buckets)")
        print(f"hot path: {compiles} compiles"
              + (f" ({compiles - prewarmed} after prewarm)"
                 if args.prewarm else "")
              + f", operand cache {hits} hits / {misses} misses")
        print(f"router: served {rt['served']} "
              f"(cost imbalance {rt['imbalance']:.2f}x), "
              f"{st['shed']} shed; autoscaler events: "
              f"{st['autoscaler']['events'] or 'none'}")
        # fault-tolerance plane (DESIGN.md §13): quiet on a healthy run,
        # loud when the drill — or a real fault — fired
        faults = {k: st[k] for k in
                  ("failovers", "retries", "hedges", "lost", "degraded")
                  if st.get(k)}
        unhealthy = {h: s for h, s in st["host_states"].items()
                     if s != "healthy"}
        if faults or unhealthy:
            rec = st.get("recovery") or {}
            print(f"faults: " + ", ".join(f"{k} {v}"
                                          for k, v in faults.items())
                  + (f"; states {unhealthy}" if unhealthy else "")
                  + (f"; recovery p95 {rec['p95_ms']:.1f}ms "
                     f"(n={rec['count']})" if rec else ""))
    else:
        oc = st["operand_cache"]
        print(f"\n{n_req} requests in {dt:.2f}s  "
              f"({n_req / dt:.1f} req/s, "
              f"{len(svc._engines)} compiled buckets)")
        print(f"hot path: {st['compiles']['total']} compiles"
              + (f" ({st['compiles']['total'] - prewarmed} after prewarm)"
                 if args.prewarm else "")
              + f", operand cache {oc['hits']} hits / {oc['misses']} misses"
              f" ({oc['bytes'] / (1 << 20):.1f} MiB), "
              f"{st['singleton_dispatches']} singleton dispatches")

    # telemetry plane (DESIGN.md §12): SE-drift summary + optional dumps
    drifts = [r.se_drift for r in results
              if r.se_drift is not None and np.isfinite(r.se_drift)]
    if drifts:
        from ..telemetry import DRIFT_ALERT
        alerts = sum(1 for d in drifts if d > DRIFT_ALERT)
        print(f"se drift: median {float(np.median(drifts)):.3f}, "
              f"max {max(drifts):.3f}, {alerts} alert(s) over "
              f"{len(drifts)} monitored requests")
    if args.trace_out:
        from ..telemetry import write_trace_jsonl
        with open(args.trace_out, "w") as fp:
            n_ev = write_trace_jsonl(fp, results)
        print(f"trace: {n_ev} span events -> {args.trace_out}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as fp:
            fp.write(svc.metrics_text())
        print(f"metrics: Prometheus snapshot -> {args.metrics_out}")


if __name__ == "__main__":
    main()
