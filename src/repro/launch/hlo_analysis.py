"""Post-SPMD HLO analysis with while-loop trip-count multipliers.

``compiled.cost_analysis()`` counts every while (scan) body exactly once, so
a 60-layer scanned transformer under-reports FLOPs by ~60x. This module
re-derives the three roofline inputs directly from the optimized HLO text:

  * dot FLOPs           — 2 * result_elems * contracted_size per dot op,
  * HBM bytes           — sum of (operand + result) bytes over substantive
                          top-level ops (fusion internals excluded: a fusion's
                          traffic is its operands/outputs, which is exactly
                          how XLA:TPU schedules HBM),
  * collective wire bytes — ring-model factors per op kind,

each multiplied by the product of enclosing while trip counts (parsed from
the loop-condition constants). Shapes in SPMD HLO are per-partition, so all
results are per-device quantities.
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["analyze_hlo", "HLOStats"]

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "s4": 0.5, "u4": 0.5, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1}

# lazy type match: the op kind is the first bare word directly followed by '('
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{$")
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|pred|c64|c128|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%([\w.\-]+), body=%([\w.\-]+)|body=%([\w.\-]+), condition=%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "iota", "partition-id", "replica-id",
               "tuple-element"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start"}


def _type_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(type_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class _Comp:
    name: str
    lines: list
    symbols: dict          # %name -> type str
    fusion_like: bool = False


@dataclasses.dataclass
class HLOStats:
    dot_flops: float = 0.0
    bytes_accessed: float = 0.0
    wire_bytes: float = 0.0
    wire_bytes_crosspod: float = 0.0   # collectives whose groups span pods
    collectives: dict = dataclasses.field(default_factory=dict)
    while_trips: list = dataclasses.field(default_factory=list)
    top_bytes: list = dataclasses.field(default_factory=list)

    def add(self, other: "HLOStats", mult: float = 1.0):
        self.dot_flops += other.dot_flops * mult
        self.bytes_accessed += other.bytes_accessed * mult
        self.wire_bytes += other.wire_bytes * mult
        self.wire_bytes_crosspod += other.wire_bytes_crosspod * mult
        for k, v in other.collectives.items():
            d = self.collectives.setdefault(
                k, {"count": 0.0, "wire_bytes": 0.0, "crosspod_bytes": 0.0})
            d["count"] += v["count"] * mult
            d["wire_bytes"] += v["wire_bytes"] * mult
            d["crosspod_bytes"] += v.get("crosspod_bytes", 0.0) * mult


def _parse_computations(text: str) -> tuple[dict, str]:
    comps: dict[str, _Comp] = {}
    entry = None
    cur: _Comp | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m and line.endswith("{"):
                name = m.group(2)
                cur = _Comp(name, [], {})
                if m.group(1):
                    entry = name
        else:
            if line.startswith("}"):
                comps[cur.name] = cur
                cur = None
                continue
            cur.lines.append(line)
            d = _DEF_RE.match(line)
            if d:
                cur.symbols[d.group(1)] = d.group(2).strip()
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


def _trip_count(cond: _Comp | None) -> int:
    if cond is None:
        return 1
    best = 1
    for line in cond.lines:
        for c in _CONST_RE.findall(line):
            best = max(best, int(c))
    return best


def analyze_hlo(text: str, top_k: int = 0) -> HLOStats:
    comps, entry = _parse_computations(text)
    # mark fusion-like computations (bytes counted at call site, not inside)
    for comp in comps.values():
        for line in comp.lines:
            for callee in _CALLS_RE.findall(line):
                if callee in comps:
                    comps[callee].fusion_like = True

    memo: dict[str, HLOStats] = {}
    visiting: set[str] = set()

    def local_and_children(comp: _Comp) -> HLOStats:
        if comp.name in memo:
            return memo[comp.name]
        if comp.name in visiting:  # defensive: HLO call graphs are acyclic
            return HLOStats()
        visiting.add(comp.name)
        st = HLOStats()
        for line in comp.lines:
            d = _DEF_RE.match(line)
            if not d:
                continue
            _, rtype, kind = d.groups()
            rest = line[d.end():]

            if kind == "dot":
                lhs_m = _OPERAND_RE.search(rest)
                contract = _CONTRACT_RE.search(line)
                c_size = 1
                if lhs_m and contract and lhs_m.group(1) in comp.symbols:
                    dims = _shape_dims(comp.symbols[lhs_m.group(1)])
                    if contract.group(1):
                        for ci in contract.group(1).split(","):
                            ci = int(ci)
                            if ci < len(dims):
                                c_size *= dims[ci]
                st.dot_flops += 2.0 * _type_elems(rtype) * c_size

            base_kind = kind.replace("-start", "")
            if base_kind in {"all-reduce", "all-gather", "reduce-scatter",
                             "all-to-all", "collective-permute"}:
                res_bytes = _type_bytes(rtype)
                ops = [comp.symbols.get(o) for o in
                       _OPERAND_RE.findall(rest.split(", ")[0] if ", " in rest
                                           else rest)]
                op_bytes = sum(_type_bytes(t) for t in ops if t) or res_bytes
                gm = re.search(r"replica_groups=\{?\{([0-9, ]+)\}", line)
                crosspod = False
                if gm:
                    ids = [int(i) for i in gm.group(1).split(",")]
                    n = len(ids)
                    crosspod = any(i >= 256 for i in ids) and any(i < 256 for i in ids)
                else:
                    # iota form: reconstruct the exact groups —
                    # arange(prod(dims)).reshape(dims).transpose(perm)
                    # .reshape(G, S); rows are the groups
                    gm2 = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\]"
                                    r"(?:T\(([0-9,]+)\))?", line)
                    n = int(gm2.group(2)) if gm2 else 0
                    if gm2:
                        import numpy as _np
                        g_cnt, s_cnt = int(gm2.group(1)), int(gm2.group(2))
                        dims = [int(d) for d in gm2.group(3).split(",")]
                        ids = _np.arange(int(_np.prod(dims))).reshape(dims)
                        if gm2.group(4):
                            perm = [int(p) for p in gm2.group(4).split(",")]
                            ids = ids.transpose(perm)
                        rows = ids.reshape(g_cnt, s_cnt)
                        crosspod = bool(((rows >= 256).any(axis=1)
                                         & (rows < 256).any(axis=1)).any())
                eff = (n - 1) / n if n > 1 else 1.0
                if base_kind == "all-reduce":
                    wire = 2.0 * res_bytes * eff
                elif base_kind == "all-gather":
                    wire = res_bytes * eff
                elif base_kind in ("reduce-scatter", "all-to-all"):
                    wire = op_bytes * eff
                else:
                    wire = res_bytes
                st.wire_bytes += wire
                if crosspod:
                    st.wire_bytes_crosspod += wire
                dd = st.collectives.setdefault(
                    base_kind, {"count": 0.0, "wire_bytes": 0.0,
                                "crosspod_bytes": 0.0})
                dd["count"] += 1
                dd["wire_bytes"] += wire
                if crosspod:
                    dd["crosspod_bytes"] += wire

            if (not comp.fusion_like and kind not in _SKIP_BYTES
                    and not kind.endswith("-done")):
                b = _type_bytes(rtype)
                for o in _OPERAND_RE.findall(rest.split(" metadata=")[0]):
                    t = comp.symbols.get(o)
                    if t:
                        b += _type_bytes(t)
                st.bytes_accessed += b

            wm = _WHILE_RE.search(line)
            if kind == "while" and wm:
                cond_name = wm.group(1) or wm.group(4)
                body_name = wm.group(2) or wm.group(3)
                trips = _trip_count(comps.get(cond_name))
                st.while_trips.append(trips)
                if body_name in comps:
                    st.add(local_and_children(comps[body_name]), trips)
                if cond_name in comps:
                    st.add(local_and_children(comps[cond_name]), trips)
            else:
                for callee in _CALLS_RE.findall(line):
                    child = comps.get(callee)
                    if child is None:
                        continue
                    ch = local_and_children(child)
                    # fusion internals: count dots (flops) but not bytes
                    st.dot_flops += ch.dot_flops
                    st.wire_bytes += ch.wire_bytes
                    st.wire_bytes_crosspod += ch.wire_bytes_crosspod
                    for k, v in ch.collectives.items():
                        ddd = st.collectives.setdefault(
                            k, {"count": 0.0, "wire_bytes": 0.0,
                                "crosspod_bytes": 0.0})
                        ddd["count"] += v["count"]
                        ddd["wire_bytes"] += v["wire_bytes"]
                        ddd["crosspod_bytes"] += v.get("crosspod_bytes", 0.0)

        visiting.discard(comp.name)
        memo[comp.name] = st
        return st

    if entry is None:
        return HLOStats()
    stats = local_and_children(comps[entry])

    if top_k:
        # effective execution multiplier per computation (reverse-topo walk)
        mult: dict[str, float] = {entry: 1.0}
        order = [entry]
        seen = {entry}
        i = 0
        while i < len(order):
            comp = comps[order[i]]
            m = mult[order[i]]
            i += 1
            for line in comp.lines:
                d = _DEF_RE.match(line)
                if not d:
                    continue
                wm = _WHILE_RE.search(line)
                if d.group(3) == "while" and wm:
                    cond = wm.group(1) or wm.group(4)
                    body = wm.group(2) or wm.group(3)
                    trips = _trip_count(comps.get(cond))
                    for callee, factor in ((body, trips), (cond, trips)):
                        if callee in comps:
                            mult[callee] = mult.get(callee, 0.0) + m * factor
                            if callee not in seen:
                                seen.add(callee)
                                order.append(callee)
                else:
                    for callee in _CALLS_RE.findall(line):
                        if callee in comps:
                            mult[callee] = mult.get(callee, 0.0) + m
                            if callee not in seen:
                                seen.add(callee)
                                order.append(callee)
        heavy = []
        for name, comp in comps.items():
            if comp.fusion_like or name not in mult:
                continue
            for line in comp.lines:
                d = _DEF_RE.match(line)
                if not d or d.group(3) in _SKIP_BYTES or d.group(3).endswith("-done"):
                    continue
                b = _type_bytes(d.group(2))
                for o in _OPERAND_RE.findall(line[d.end():].split(" metadata=")[0]):
                    t = comp.symbols.get(o)
                    if t:
                        b += _type_bytes(t)
                heavy.append((b * mult[name], d.group(3), mult[name],
                              line.strip()[:140]))
        heavy.sort(key=lambda x: -x[0])
        stats.top_bytes = heavy[:top_k]
    return stats
