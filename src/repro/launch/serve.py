"""Serving launcher: batched prefill + decode with the KV-cache runtime.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import get_model, lm_logits


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke_config()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))

    b, pl_len, gen = args.batch, args.prompt_len, args.gen
    max_len = pl_len + gen
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (b, pl_len)), jnp.int32)
    aux = {k: jnp.ones((b,) + v.shape[1:], v.dtype)
           for k, v in model.aux_inputs(b, pl_len).items()}

    # prefill
    t0 = time.time()
    hidden, caches = model.forward(params, prompts, cfg, mode="prefill", **aux)
    state = model.init_state(cfg, b, max_len)
    # place prefill KV into the decode cache where the family uses one
    if cfg.family in ("dense", "moe"):
        state["k"] = state["k"].at[:, :, :pl_len].set(caches[0])
        state["v"] = state["v"].at[:, :, :pl_len].set(caches[1])
    elif cfg.family == "whisper":
        state["k"] = state["k"].at[:, :, :pl_len].set(caches["k"])
        state["v"] = state["v"].at[:, :, :pl_len].set(caches["v"])
        state["ck"], state["cv"] = caches["ck"], caches["cv"]
    else:
        state = caches  # recurrent families carry their own state
    t_prefill = time.time() - t0

    # greedy decode
    step_fn = jax.jit(lambda p, t, s, i: model.decode_step(p, t, s, i, cfg))
    tok = prompts[:, -1:]
    out_tokens = []
    t1 = time.time()
    for i in range(gen):
        hidden, state = step_fn(params, tok, state, pl_len + i)
        logits = lm_logits(params, hidden, cfg)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok[:, 0]))
    t_decode = time.time() - t1

    gen_arr = np.stack(out_tokens, axis=1)
    print(f"prefill: {b}x{pl_len} tokens in {t_prefill:.2f}s")
    print(f"decode : {gen} steps in {t_decode:.2f}s "
          f"({b * gen / max(t_decode, 1e-9):.1f} tok/s)")
    print("generated token ids (first row):", gen_arr[0].tolist())


if __name__ == "__main__":
    main()
