"""Mesh-distributed MP-AMP solver: the paper's algorithm under shard_map.

Processors = the mesh 'data' axis (the paper's P=30 maps onto however many
shards the mesh provides; the quantization analysis depends on P only through
P*sigma_Q^2, which we track at runtime). The fusion sum f_t = sum_p Q(f_t^p)
is a ``compressed_psum`` over 'data' — int8 wire transport standing in for
the paper's ECSQ+entropy-coded stream (DESIGN.md §2; H_Q is reported so the
entropy-coded rate is visible even though XLA lanes are fixed-width).

This is a thin frontend over ``AmpEngine.solve_sharded`` (DESIGN.md §6):
the engine runs its one scan-compiled iteration body inside shard_map, with
``CompressedPsumTransport`` (int8/int4 wire) or ``PsumFusion`` (exact) as
the device-collective fusion. There is no per-iteration Python loop here —
the last pre-engine survivor of the solver triplication is gone.

Straggler mitigation (beyond-paper, enabled by the paper's own analysis):
``drop_rate`` simulates P' < P responsive processors per iteration. The
transport rescales: f = (P/P') * sum_{responsive} f^p is an unbiased
estimate of the full fusion whose extra noise the modified SE absorbs
exactly like quantization noise — the solver keeps iterating through
stragglers instead of stalling on the slowest shard.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.denoisers import BernoulliGauss
from ..core.engine import (AmpEngine, ColumnPartition, CompressedPsumTransport,
                           EngineConfig, PsumFusion, RowPartition)

__all__ = ["DistributedMPAMP", "SolverConfig"]


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    n_iter: int = 15              # iterations (row) / outer rounds (col)
    bits: int | None = 8          # None = exact (bf16/f32) fusion
    block: int = 512
    drop_rate: float = 0.0        # simulated straggler drop fraction
    use_kernel: bool | None = None  # None = Pallas LC on TPU
    layout: str = "row"           # "row" | "col" (C-MP-AMP, DESIGN.md §7)
    n_inner: int = 1              # col: local AMP iterations per fusion


class DistributedMPAMP:
    """Partitioned AMP over the mesh 'data' axis: row-wise (the source
    paper, fusion = compressed psum of denoiser messages) or column-wise
    (C-MP-AMP, fusion = compressed psum of length-M residual
    contributions — the tall-N regime's wire-efficient layout)."""

    def __init__(self, mesh, prior: BernoulliGauss, cfg: SolverConfig):
        self.mesh = mesh
        self.prior = prior
        self.cfg = cfg
        self.n_proc = mesh.shape["data"]
        assert cfg.layout in ("row", "col"), cfg.layout
        if cfg.layout == "col":
            assert cfg.drop_rate == 0.0, \
                "straggler drop does not apply to the column layout " \
                "(a dropped shard removes its signal block, not noise)"
            layout = ColumnPartition(n_inner=cfg.n_inner)
        else:
            layout = RowPartition()
        if cfg.bits is not None:
            transport = CompressedPsumTransport(axis="data", bits=cfg.bits,
                                                block=cfg.block)
        else:
            transport = PsumFusion(axis="data")
        self._engine = AmpEngine(
            prior,
            EngineConfig(n_proc=self.n_proc, n_iter=cfg.n_iter,
                         use_kernel=cfg.use_kernel,
                         collect_symbols=False, collect_xs=False,
                         layout=layout),
            transport)

    def _drop_sched(self, key) -> np.ndarray | None:
        if self.cfg.layout == "col":
            return None
        p = self.n_proc
        drop = np.zeros((self.cfg.n_iter, p), np.float32)
        if self.cfg.drop_rate > 0:
            rng = np.random.default_rng(0 if key is None else key)
            drop = (rng.random((self.cfg.n_iter, p))
                    < self.cfg.drop_rate).astype(np.float32)
            drop[:, 0] = 0.0  # shard 0 always responsive
        return drop

    def solve(self, a_mat: np.ndarray, y: np.ndarray, key=None):
        """Run n_iter iterations. Returns (x, per-iter sigma2_hat, noise)."""
        tr = self._engine.solve_sharded(y, a_mat, self.mesh,
                                        drop_sched=self._drop_sched(key))
        return tr.x, tr.sigma2_hat, tr.extra_var
