"""Mesh-distributed MP-AMP solver: the paper's algorithm under shard_map.

Processors = the mesh 'data' axis (the paper's P=30 maps onto however many
shards the mesh provides; the quantization analysis depends on P only through
P*sigma_Q^2, which we track at runtime). The fusion sum f_t = sum_p Q(f_t^p)
is a ``compressed_psum`` over 'data' — int8 wire transport standing in for
the paper's ECSQ+entropy-coded stream (DESIGN.md §2; H_Q is reported so the
entropy-coded rate is visible even though XLA lanes are fixed-width).

This is the distributed frontend of the unified ``core/engine.py`` solver:
the per-shard LC step is the same ``kernels/amp_fused`` op the engine scans
over, and the denoise/Onsager tail is the engine's shared ``amp_gc_step`` —
only the fusion differs (collective over 'data' instead of a sum over the
emulated leading axis).

Straggler mitigation (beyond-paper, enabled by the paper's own analysis):
``drop_mask`` simulates P' < P responsive processors. The fusion then
rescales: f = (P/P') * sum_{responsive} f^p is an unbiased estimate of the
full fusion whose extra noise the modified SE absorbs exactly like
quantization noise — the solver keeps iterating through stragglers instead
of stalling on the slowest shard.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import axis_size, shard_map
from ..core.compression import QuantConfig, compressed_psum
from ..core.denoisers import BernoulliGauss
from ..core.engine import amp_gc_step
from ..kernels.amp_fused.ops import amp_local_step

__all__ = ["DistributedMPAMP", "SolverConfig"]


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    n_iter: int = 15
    bits: int | None = 8          # None = exact (bf16/f32) fusion
    block: int = 512
    drop_rate: float = 0.0        # simulated straggler drop fraction
    use_kernel: bool | None = None  # None = Pallas LC on TPU


class DistributedMPAMP:
    """Row-partitioned AMP over the mesh 'data' axis."""

    def __init__(self, mesh, prior: BernoulliGauss, cfg: SolverConfig):
        self.mesh = mesh
        self.prior = prior
        self.cfg = cfg
        self.n_proc = mesh.shape["data"]

    def _iteration(self, a_p, y_p, x, z_p, onsager, drop, kappa):
        """One iteration; runs per-processor under shard_map (manual 'data')."""
        cfg, prior = self.cfg, self.prior
        p = axis_size("data")

        z_new, f_p = amp_local_step(a_p, x, y_p, z_p, onsager, p,
                                    use_pallas=cfg.use_kernel)

        sigma2_hat = lax.psum(jnp.sum(z_new * z_new), "data") / (
            lax.psum(jnp.asarray(z_new.shape[0], jnp.float32), "data"))

        # straggler simulation: responsive shards only, unbiased rescale
        keep = 1.0 - drop
        n_keep = lax.psum(keep, "data")
        f_p = f_p * keep * (p / jnp.maximum(n_keep, 1.0))

        if cfg.bits is not None:
            f, noise = compressed_psum(
                f_p, "data", QuantConfig(bits=cfg.bits, block=cfg.block))
        else:
            f = lax.psum(f_p, "data")
            noise = jnp.zeros(())

        x_new, onsager_new = amp_gc_step(f, sigma2_hat + noise, prior, kappa)
        return x_new, z_new, onsager_new, sigma2_hat, noise

    def solve(self, a_mat: np.ndarray, y: np.ndarray, key=None):
        """Run n_iter iterations. Returns (x, per-iter sigma2_hat, noise)."""
        m, n = a_mat.shape
        kappa = m / n
        mesh = self.mesh
        p = self.n_proc
        assert m % p == 0

        a = jnp.asarray(a_mat, jnp.float32)
        yj = jnp.asarray(y, jnp.float32)

        drop_sched = np.zeros((self.cfg.n_iter, p), np.float32)
        if self.cfg.drop_rate > 0:
            rng = np.random.default_rng(0 if key is None else key)
            drop_sched = (rng.random((self.cfg.n_iter, p))
                          < self.cfg.drop_rate).astype(np.float32)
            drop_sched[:, 0] = 0.0  # shard 0 always responsive

        def body(a_p, y_p, drops):
            # a_p (M/P, N), y_p (M/P,), drops (n_iter, 1) per shard
            x = jnp.zeros(n, jnp.float32)
            z_p = jnp.zeros_like(y_p)
            onsager = jnp.zeros(())

            def step(carry, drop_t):
                x, z_p, onsager = carry
                x, z_p, onsager, s2, nv = self._iteration(
                    a_p, y_p, x, z_p, onsager, drop_t[0], kappa)
                return (x, z_p, onsager), (s2, nv)

            (x, _, _), (s2s, nvs) = lax.scan(step, (x, z_p, onsager), drops)
            return x, s2s, nvs

        fn = shard_map(
            body, mesh=mesh,
            in_specs=(P("data", None), P("data"), P(None, "data")),
            out_specs=(P(), P(), P()),
            axis_names={"data"}, check=False)
        x, s2s, nvs = jax.jit(fn)(a, yj, jnp.asarray(drop_sched))
        return np.asarray(x), np.asarray(s2s), np.asarray(nvs)
