import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

512 placeholder host devices stand in for 2 TPU v5e pods; the compile proves
the sharding config is coherent end-to-end (no sharding mismatches, no
unsupported collectives, memory fits). Per cell we record:

  * compiled.memory_analysis()  — per-device argument/output/temp bytes
  * compiled.cost_analysis()    — per-device HLO FLOPs and bytes accessed
  * the collective wire bytes parsed from the optimized (post-SPMD) HLO,
    with ring-model factors: all-reduce 2(n-1)/n, all-gather / reduce-scatter
    / all-to-all (n-1)/n, collective-permute 1 — shapes in SPMD HLO are
    already per-partition, so these are per-device wire bytes.

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k --mesh pod1
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from ..configs import get_config, list_archs, shape_for, SHAPES
from ..configs.base import ModelConfig, ShapeSpec
from .mesh import make_production_mesh
from .steps import TrainStepConfig, build_serve_step, build_train_step

# long_500k runs only for sub-quadratic-attention families (DESIGN.md §5)
LONG_OK = {"rwkv6-3b", "recurrentgemma-2b", "gemma3-1b", "mixtral-8x7b"}

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "s4": 0.5, "u4": 0.5}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*) = (\([^)]*\)|\S+) (all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(-start)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|pred)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")


def _type_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-op-kind {count, result_bytes, wire_bytes} from post-SPMD HLO."""
    out: dict[str, dict] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        _, result_type, kind, _ = m.groups()
        res_bytes = _type_bytes(result_type)
        # operand types appear inside the call parens
        paren = line[m.end():]
        op_bytes = _type_bytes(paren.split(", replica_groups")[0]
                               .split(", channel_id")[0])
        gm = _GROUPS_RE.search(line)
        n = len(gm.group(1).split(",")) if gm else 0
        eff = (n - 1) / n if n > 1 else 1.0
        if kind == "all-reduce":
            wire = 2.0 * res_bytes * eff
        elif kind == "all-gather":
            wire = res_bytes * eff
        elif kind == "reduce-scatter":
            wire = op_bytes * eff
        elif kind == "all-to-all":
            wire = op_bytes * eff
        else:  # collective-permute
            wire = res_bytes
        d = out.setdefault(kind, {"count": 0, "result_bytes": 0.0,
                                  "wire_bytes": 0.0})
        d["count"] += 1
        d["result_bytes"] += res_bytes
        d["wire_bytes"] += wire
    return out


def cell_config(cfg: ModelConfig, shape: ShapeSpec) -> TrainStepConfig:
    mb = 8 if shape.kind == "train" else 1
    return TrainStepConfig(microbatches=mb, moe_groups=64)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             compression: str = "default", pad_heads: int = 0,
             scores_bf16: bool = False, strategy: str = "tp",
             microbatches: int | None = None, q_chunk: int = 0) -> dict:
    cfg = get_config(arch)
    if pad_heads:
        cfg = cfg.padded_heads(pad_heads)
    if scores_bf16:
        cfg = dataclasses_replace(cfg, scores_bf16=True)
    if q_chunk:
        cfg = dataclasses_replace(cfg, attn_q_chunk=q_chunk)
    shape = shape_for(shape_name)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                 "compression": compression, "pad_heads": pad_heads,
                 "scores_bf16": scores_bf16, "strategy": strategy,
                 "ok": False}
    if shape_name == "long_500k" and arch not in LONG_OK:
        rec.update(skipped=True,
                   reason="full-attention arch; long_500k skipped per DESIGN.md §5")
        return rec
    multi = mesh_kind == "pod2"
    n_dev = 512 if multi else 256
    mesh = make_production_mesh(multi_pod=multi) if multi else None
    if mesh is None:
        from jax.sharding import AxisType
        mesh = jax.make_mesh((16, 16), ("data", "model"),
                             devices=jax.devices()[:256],
                             axis_types=(AxisType.Auto,) * 2)

    t0 = time.time()
    try:
        if shape.kind == "train":
            bits = {"default": 8 if multi else None, "none": None,
                    "int8": 8, "int4": 4}[compression]
            tcfg = cell_config(cfg, shape)
            mb = microbatches
            if mb is None:
                # FSDP shards the batch over the whole mesh and multi-pod
                # leaves <= 8 samples/device: microbatching is pointless
                # there (and the grad-accum scan inside the manual-pod
                # shard_map trips an XLA partitioner CHECK at 512 devices —
                # see EXPERIMENTS.md §Dry-run notes).
                mb = 1 if (strategy == "fsdp" or multi) else tcfg.microbatches
            tcfg = dataclasses_replace(tcfg, compression_bits=bits,
                                       strategy=strategy, microbatches=mb)
            fn, shardings, abstract = build_train_step(cfg, mesh, shape, tcfg)
            args = (abstract["params"], abstract["opt_state"],
                    abstract["tokens"], abstract["labels"], abstract["aux"])
            in_sh = (shardings["params"], shardings["opt_state"],
                     shardings["tokens"], shardings["labels"], shardings["aux"])
            out_sh = (shardings["params"], shardings["opt_state"],
                      _replicated_tree(mesh))
            # donate params/opt state so memory_analysis reflects the real
            # training peak (outputs alias arguments, as in the Trainer)
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=(0, 1))
            lowered = jitted.lower(*args)
        else:
            fn, shardings, abstract = build_serve_step(cfg, mesh, shape)
            if shape.kind == "prefill":
                args = (abstract["params"], abstract["tokens"], abstract["aux"])
                in_sh = (shardings["params"], shardings["tokens"],
                         shardings["aux"])
            else:
                args = (abstract["params"], abstract["tokens"],
                        abstract["state"], abstract["pos"])
                in_sh = (shardings["params"], shardings["tokens"],
                         shardings["state"], shardings["pos"])
            jitted = jax.jit(fn, in_shardings=in_sh)
            lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)

        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        ca = compiled.cost_analysis() or {}
        rec["flops_per_device"] = float(ca.get("flops", 0.0))
        rec["bytes_accessed_per_device"] = float(ca.get("bytes accessed", 0.0))
        try:
            ma = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
            }
        except Exception as e:  # pragma: no cover
            rec["memory"] = {"error": str(e)}
        txt = compiled.as_text()
        from .hlo_analysis import analyze_hlo
        stats = analyze_hlo(txt, top_k=12)
        rec["collectives"] = stats.collectives
        rec["wire_bytes_per_device"] = stats.wire_bytes
        rec["wire_bytes_crosspod"] = stats.wire_bytes_crosspod
        rec["dot_flops_per_device"] = stats.dot_flops
        rec["hbm_bytes_per_device"] = stats.bytes_accessed
        rec["while_trips"] = stats.while_trips[:16]
        rec["top_bytes"] = [
            {"bytes": b, "kind": k, "mult": m, "line": ln}
            for b, k, m, ln in stats.top_bytes]
        rec["n_devices"] = n_dev
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def dataclasses_replace(obj, **kw):
    import dataclasses
    return dataclasses.replace(obj, **kw)


def _replicated_tree(mesh):
    from jax.sharding import NamedSharding, PartitionSpec
    rep = NamedSharding(mesh, PartitionSpec())
    return {"grad_norm": rep, "clip": rep, "loss": rep, "quant_noise": rep}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", type=str, default="pod1",
                    choices=["pod1", "pod2", "both"])
    ap.add_argument("--compression", type=str, default="default",
                    choices=["default", "none", "int8", "int4"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="results/dryrun")
    ap.add_argument("--pad-heads", type=int, default=0)
    ap.add_argument("--scores-bf16", action="store_true")
    ap.add_argument("--strategy", type=str, default="tp",
                    choices=["tp", "tp_sp", "fsdp"])
    ap.add_argument("--tag", type=str, default="")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--q-chunk", type=int, default=0)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = ["pod1", "pod2"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch in list_archs():
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        cells.append((args.arch, args.shape))

    for arch, shape in cells:
        for mk in meshes:
            rec = run_cell(arch, shape, mk, args.compression,
                           pad_heads=args.pad_heads,
                           scores_bf16=args.scores_bf16,
                           strategy=args.strategy,
                           microbatches=args.microbatches,
                           q_chunk=args.q_chunk)
            tag = f"{arch}_{shape}_{mk}" + (
                f"_{args.compression}" if args.compression != "default" else "") + (
                f"_{args.tag}" if args.tag else "")
            path = os.path.join(args.out, tag + ".json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            status = ("SKIP" if rec.get("skipped")
                      else "OK" if rec["ok"] else "FAIL")
            print(f"[{status}] {tag} ({rec.get('total_s', 0)}s) "
                  f"flops/dev={rec.get('flops_per_device', 0):.3g} "
                  f"wire/dev={rec.get('wire_bytes_per_device', 0):.3g}",
                  flush=True)
            if not rec["ok"] and not rec.get("skipped"):
                print(rec.get("error", ""), flush=True)


if __name__ == "__main__":
    main()
