"""Training launcher: --arch <id> --shape <name> [--steps N] [--smoke].

On real hardware this is the multi-host entry point (jax.distributed
initializes from the cluster env; the mesh comes from make_production_mesh).
On the CPU container, --smoke runs the reduced config end-to-end through the
identical code path: data pipeline, sharded train_step, checkpoints, resume.

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --smoke --steps 20
"""
from __future__ import annotations

import argparse
import dataclasses

from ..configs import get_config, shape_for
from ..configs.base import ShapeSpec
from ..launch.mesh import make_host_mesh, make_production_mesh
from ..launch.steps import TrainStepConfig
from ..optim import AdamWConfig
from ..runtime import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + host mesh (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", type=int, default=None,
                    help="gradient-fusion bits over the pod axis (8/4)")
    ap.add_argument("--strategy", default="tp", choices=["tp", "tp_sp", "fsdp"])
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke_config()
        shape = ShapeSpec("smoke", 64, 4, "train")
        mesh = make_host_mesh(model=1)
    else:
        shape = shape_for(args.shape)
        mesh = make_production_mesh()

    tcfg = TrainerConfig(
        total_steps=args.steps, ckpt_every=max(args.steps // 5, 10),
        ckpt_dir=args.ckpt_dir,
        step_cfg=TrainStepConfig(
            microbatches=args.microbatches,
            compression_bits=args.compression,
            strategy=args.strategy,
            moe_groups=2 if args.smoke else 64,
            adamw=AdamWConfig(lr=args.lr)))
    trainer = Trainer(cfg, shape, mesh, tcfg)
    trainer.run(resume=True)


if __name__ == "__main__":
    main()
