"""Qwen2-VL 7B — M-RoPE, dynamic-resolution ViT stubbed (precomputed patch
embeddings via input_specs) [arXiv:2409.12191]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="qwen2-vl-7b", family="dense",
    n_layers=28, d_model=3584,
    n_heads=28, n_kv_heads=4, d_head=128,
    d_ff=18944, vocab=152064,
    m_rope=True, n_vision_tokens=1024,
    rope_theta=1e6, tie_embeddings=False,
))
