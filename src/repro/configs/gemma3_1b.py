"""Gemma-3 1B — 5:1 local:global attention, 128k ctx [hf:google/gemma-3-1b-pt]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152,
    n_heads=4, n_kv_heads=1, d_head=256,
    d_ff=6912, vocab=262144,
    attn_pattern=("local",) * 5 + ("global",),
    window=512, qk_norm=True, rope_theta=1e6,
))
