"""Model configuration schema + registry for the assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Callable

__all__ = ["ModelConfig", "register", "get_config", "list_archs", "SHAPES",
           "ShapeSpec", "shape_for"]


def _round_up(n: int, k: int) -> int:
    return (n + k - 1) // k * k


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | rwkv6 | rglru | whisper
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int

    # attention
    rope_theta: float = 1e4
    attn_pattern: tuple[str, ...] = ("global",)   # cycled over layers
    window: int = 0                               # local / SWA window size
    qk_norm: bool = False
    logits_softcap: float = 0.0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # hybrid (rglru)
    lru_width: int = 0
    conv1d_width: int = 4

    # enc-dec (whisper)
    n_enc_layers: int = 0
    n_audio_frames: int = 1500

    # vlm
    m_rope: bool = False
    n_vision_tokens: int = 0

    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # numerics
    dtype: str = "bfloat16"

    # ---- performance knobs (hillclimb levers; defaults = paper-faithful
    # baseline). See EXPERIMENTS.md §Perf. -------------------------------
    # pad attention heads up to a multiple (0 = off). Padded heads are
    # masked to zero after PV, so the function is exactly the unpadded
    # model's; the win is head-sharding divisibility on 16-way meshes
    # (vs. head_dim sharding, whose contractions all-reduce every score
    # tensor).
    n_heads_padded: int = 0
    n_kv_heads_padded: int = 0
    # compute attention scores in bf16 on the HBM path (fp32 accumulate
    # stays in the PV matmul) — halves the dominant attention HBM traffic.
    scores_bf16: bool = False
    # streaming-attention block sizes. Larger q_chunk cuts the KV re-read
    # amplification (total KV traffic = (S/q_chunk) * T * Dh).
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024

    # reduced smoke-test override factory (set by register())
    _smoke: Callable | None = dataclasses.field(default=None, repr=False, compare=False)

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to 256 so the logit head shards over any mesh."""
        return _round_up(self.vocab, 256)

    @property
    def h_eff(self) -> int:
        return self.n_heads_padded or self.n_heads

    @property
    def kv_eff(self) -> int:
        return self.n_kv_heads_padded or self.n_kv_heads

    def padded_heads(self, multiple: int) -> "ModelConfig":
        """Head-padding transform: round the q-head count up to ``multiple``
        (and kv too when grouping requires it). Padded slots are masked to
        zero after PV (see layers.head_mask), so the realized function family
        is the unpadded model's — only the sharding divisibility changes.

        Grouping invariant: h_eff must be kv_eff * g_eff with g_eff >= the
        real group size, so every real kv group keeps its real q heads. When
        kv itself needs padding, h_eff is re-derived as kvp * g_real (which
        stays a multiple of ``multiple``)."""
        g_real = self.n_heads // self.n_kv_heads
        hp = _round_up(self.n_heads, multiple)
        if hp % self.n_kv_heads == 0:
            kvp = self.n_kv_heads
        else:
            kvp = _round_up(self.n_kv_heads, multiple)
            hp = kvp * g_real
        return dataclasses.replace(self, n_heads_padded=hp,
                                   n_kv_heads_padded=kvp)

    @property
    def attn_kinds(self) -> tuple[str, ...]:
        """Per-layer attention kind, pattern cycled to n_layers."""
        pat = self.attn_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_padded
        h, kv, dh = self.n_heads, self.n_kv_heads, self.d_head
        if self.family == "rwkv6":
            per_layer = 4 * d * d + 2 * d * f + d * d  # tmix (r,k,v,o,g) + cmix
            per_layer += 6 * 32 * d * 2 + d * dh  # lora decay/mix params (approx)
            return v * d + self.n_layers * per_layer + (0 if self.tie_embeddings else v * d)
        attn = d * h * dh + 2 * d * kv * dh + h * dh * d
        if self.family == "moe":
            mlp = self.n_experts * 3 * d * f + d * self.n_experts
        else:
            mlp = 3 * d * f
        layers = self.n_layers * (attn + mlp + 2 * d)
        if self.family == "whisper":
            layers += self.n_enc_layers * (attn + mlp + 2 * d)  # encoder
            layers += self.n_layers * (attn + 2 * d)            # cross-attn
        if self.family == "rglru":
            # 2 of 3 layers replace attn with RG-LRU block (rough: same order)
            pass
        embed = v * d * (1 if self.tie_embeddings else 2)
        return layers + embed

    def active_param_count(self) -> int:
        """Active (per-token) params — differs from total only for MoE."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_like = self.param_count() - self.n_layers * (self.n_experts - self.top_k) * 3 * d * f
        return dense_like

    def smoke_config(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        if self._smoke is not None:
            return self._smoke(self)
        # enough layers to exercise the full attention pattern (and, for
        # rglru, at least one macro-block plus the recurrent tail)
        n_layers = max(2, len(self.attn_pattern))
        if self.family == "rglru":
            n_layers = 5
        return dataclasses.replace(
            self, name=self.name + "-smoke", n_layers=n_layers,
            d_model=64, n_heads=4, n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_head=16, d_ff=128, vocab=512,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            lru_width=64 if self.lru_width else 0,
            window=min(self.window, 32) if self.window else 0,
            n_enc_layers=2 if self.n_enc_layers else 0,
            n_audio_frames=24 if self.n_enc_layers else 0,
            n_vision_tokens=16 if self.n_vision_tokens else 0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_for(name: str) -> ShapeSpec:
    return SHAPES[name]


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from . import _ensure_loaded  # populate registry lazily
    _ensure_loaded()
    return _REGISTRY[name]


def list_archs() -> list[str]:
    from . import _ensure_loaded
    _ensure_loaded()
    return sorted(_REGISTRY)
