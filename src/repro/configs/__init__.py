"""Architecture registry: one module per assigned arch (+ the paper's CS setup)."""
from .base import (ModelConfig, ShapeSpec, SHAPES, get_config, list_archs,
                   register, shape_for)

_LOADED = False

_ARCH_MODULES = [
    "rwkv6_3b", "gemma3_1b", "glm4_9b", "granite_3_8b", "yi_34b",
    "whisper_small", "qwen3_moe_30b_a3b", "mixtral_8x7b",
    "recurrentgemma_2b", "qwen2_vl_7b",
]


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    import importlib
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")
    _LOADED = True
