"""Qwen3-MoE 30B-A3B — 128 experts top-8, GQA kv=4 [hf:Qwen/Qwen3-30B-A3B]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=768, vocab=151936,
    n_experts=128, top_k=8,
    qk_norm=True, rope_theta=1e6,
    tie_embeddings=False,
))
