"""Mixtral 8x7B — 8 experts top-2, sliding-window attention [arXiv:2401.04088]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab=32000,
    n_experts=8, top_k=2,
    attn_pattern=("local",), window=4096,   # SWA on every layer
    tie_embeddings=False,
))
