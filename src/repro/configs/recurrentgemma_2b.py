"""RecurrentGemma-2B — Griffin: RG-LRU blocks + local attention, 2:1 pattern
(recurrent, recurrent, local-attn) [arXiv:2402.19427]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="recurrentgemma-2b", family="rglru",
    n_layers=26, d_model=2560,
    n_heads=10, n_kv_heads=1, d_head=256,
    d_ff=7680, vocab=256000,
    attn_pattern=("recurrent", "recurrent", "local"),
    window=2048, lru_width=2560, conv1d_width=4,
))
