"""Granite-3 8B — GQA kv=8 [hf:ibm-granite/granite-3.0 family]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=12800, vocab=49155,
))
