"""Whisper-small — enc-dec audio; conv frontend stubbed (precomputed frame
embeddings via input_specs) [arXiv:2212.04356]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="whisper-small", family="whisper",
    n_layers=12, n_enc_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_head=64,
    d_ff=3072, vocab=51865,
    n_audio_frames=1500,
    tie_embeddings=True,
))
