"""RWKV-6 "Finch" 3B — attention-free, data-dependent decay [arXiv:2404.05892]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="rwkv6-3b", family="rwkv6",
    n_layers=32, d_model=2560,
    n_heads=40, n_kv_heads=40, d_head=64,   # wkv head size 64
    d_ff=8960, vocab=65536,
    tie_embeddings=False,
))
