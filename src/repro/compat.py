"""Version-compat shims for the jax API surface this repo uses.

The repo targets the current jax API (``jax.shard_map`` with ``axis_names``/
``check_vma``, ``jax.make_mesh(..., axis_types=...)``). Older installs — the
CI/container image pins jax 0.4.x — expose the same machinery under
``jax.experimental.shard_map.shard_map`` (with ``check_rep``/``auto``) and a
``make_mesh`` without ``axis_types``. Everything in-repo goes through these
two wrappers so version skew is handled in exactly one place.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 explicit-sharding API
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - older jax
    AxisType = None

__all__ = ["AxisType", "axis_size", "make_mesh", "shard_map",
           "supports_partial_manual"]


def supports_partial_manual() -> bool:
    """Whether shard_map with *partial* manual axes (manual: some, auto:
    the rest) is usable on the installed jax.

    jax 0.4.x exposes the pattern via the experimental ``auto=`` argument,
    but the XLA SPMD partitioner it ships trips an ``IsManualSubgroup``
    CHECK (a process abort, so this cannot be probed at runtime) on the
    compressed pod-axis fusion pattern; the capability arrived with the
    jax >= 0.5 explicit AxisType machinery. Fully-manual shard_map — all
    of the solver paths, ``compressed_psum``, ``AmpEngine.solve_sharded``
    — works on both lines and needs no gate.
    """
    return AxisType is not None


def axis_size(axis_name):
    """Static size of a manual mesh axis inside shard_map, on any jax.

    ``lax.axis_size`` post-dates 0.4.x; ``psum`` of a unit literal is the
    long-standing equivalent (evaluated eagerly to a Python int).
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """jax.make_mesh with Auto axis_types when the installed jax has them."""
    if AxisType is not None:
        return jax.make_mesh(axis_shapes, axis_names, devices=devices,
                             axis_types=(AxisType.Auto,) * len(axis_shapes))
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check=False):
    """New-style shard_map on any jax version.

    ``axis_names`` is the set of *manual* mesh axes (None = all of them);
    ``check`` maps to ``check_vma`` (new) / ``check_rep`` (old).
    """
    if hasattr(jax, "shard_map"):
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check, auto=auto)
