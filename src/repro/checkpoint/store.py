"""Sharded checkpointing with async writes, keep-k GC, and elastic restore.

Layout per step:
    <dir>/step_000123/
        manifest.json          # global shapes/dtypes, tree structure, meta
        shard_<i>_of_<n>.npz   # per-writer shard files (leaf slices)

Writes: every leaf is split along its first divisible axis into ``writers``
slices (one per host in a real deployment; configurable here), written by a
background thread (training continues — async checkpointing), then the
manifest is atomically renamed into place (a crash mid-write never yields a
"valid" partial checkpoint).

Restore is *elastic*: the loader reassembles global arrays from however many
shard files exist and re-places them under the *current* mesh/sharding —
restoring a 512-chip checkpoint onto a 256-chip mesh (or the CPU tests' 1
device) is the same code path (DESIGN.md §4 fault tolerance).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "CheckpointManager"]

_FLAT_SEP = "|"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_FLAT_SEP}"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat):
    tree: dict = {}
    for k, v in flat.items():
        parts = k.split(_FLAT_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def _to_storable(v: np.ndarray) -> np.ndarray:
    """npz can't hold bfloat16 (ml_dtypes): store as a uint16 view; the
    manifest records the logical dtype for the loader."""
    if v.dtype.name == "bfloat16":
        return v.view(np.uint16)
    return v


def _from_storable(v: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name == "bfloat16":
        import ml_dtypes
        return v.view(ml_dtypes.bfloat16)
    return v


def save_checkpoint(path: str, step: int, tree, writers: int = 4,
                    meta: dict | None = None):
    """Write checkpoint synchronously. Returns the final directory."""
    flat = _flatten(tree)
    host = {k: np.asarray(v) for k, v in flat.items()}
    final = os.path.join(path, f"step_{step:09d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    manifest = {
        "step": step,
        "writers": writers,
        "meta": meta or {},
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in host.items()},
    }
    for w in range(writers):
        shard = {}
        for k, v in host.items():
            if v.ndim and v.shape[0] % writers == 0:
                n = v.shape[0] // writers
                shard[k] = _to_storable(v[w * n:(w + 1) * n])
            elif w == 0:  # undivisible / scalar leaves go to writer 0
                shard[k] = _to_storable(v)
        np.savez(os.path.join(tmp, f"shard_{w}_of_{writers}.npz"), **shard)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_checkpoint(path: str, step: int | None = None, shardings=None):
    """Load (tree, step, meta). Elastic: re-places under ``shardings`` if
    given (same flat-path structure), else returns numpy arrays."""
    if step is None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(path)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {path}")
        step = steps[-1]
    d = os.path.join(path, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    writers = manifest["writers"]
    parts: dict[str, list] = {k: [] for k in manifest["leaves"]}
    for w in range(writers):
        with np.load(os.path.join(d, f"shard_{w}_of_{writers}.npz")) as z:
            for k in z.files:
                parts[k].append(z[k])
    flat = {}
    for k, info in manifest["leaves"].items():
        arrs = parts[k]
        full = arrs[0] if len(arrs) == 1 else np.concatenate(arrs, axis=0)
        full = _from_storable(full, info["dtype"])
        assert list(full.shape) == info["shape"], (k, full.shape, info["shape"])
        flat[k] = full
    if shardings is not None:
        flat_sh = _flatten(shardings)
        flat = {k: jax.device_put(v, flat_sh[k]) if k in flat_sh else v
                for k, v in flat.items()}
    return _unflatten(flat), step, manifest["meta"]


class CheckpointManager:
    """Async keep-k checkpointing driver for the training loop."""

    def __init__(self, path: str, keep: int = 3, writers: int = 4):
        self.path = path
        self.keep = keep
        self.writers = writers
        self._thread: threading.Thread | None = None
        os.makedirs(path, exist_ok=True)

    def save_async(self, step: int, tree, meta=None):
        # fetch to host synchronously (cheap vs device compute), write async
        flat = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}
        self.wait()

        def work():
            save_checkpoint(self.path, step, _unflatten(host),
                            writers=self.writers, meta=meta)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.path)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:09d}"),
                          ignore_errors=True)

    def latest_step(self) -> int | None:
        steps = [int(d.split("_")[1]) for d in os.listdir(self.path)
                 if d.startswith("step_") and not d.endswith(".tmp")]
        return max(steps) if steps else None
