from .store import CheckpointManager, save_checkpoint, load_checkpoint
