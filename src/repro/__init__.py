"""repro: Multi-Processor AMP with lossy compression, at TPU-pod scale."""
__version__ = "1.0.0"
