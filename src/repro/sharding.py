"""Logical-axis sharding system (MaxText-style) for the model zoo.

Model code annotates tensors with *logical* axis names; a per-(config, mesh,
mode) rule table maps them to physical mesh axes. Rules degrade gracefully:
a logical axis only maps to a mesh axis when the dimension is divisible by the
axis size (checked at annotation time with the actual shape), else it is left
replicated — this is what makes e.g. yi-34b (56 heads, 16-way model axis)
lower cleanly by falling back to head_dim sharding.

No global jax state: the active (mesh, rules) pair lives in a module-level
context set by the trainer / dryrun; when unset, ``shard`` is the identity so
single-device smoke tests never touch device placement.
"""
from __future__ import annotations

import dataclasses
from contextlib import contextmanager
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["AxisRules", "shard", "logical_spec", "use_sharding", "make_rules",
           "named_sharding", "current_mesh"]


@dataclasses.dataclass
class _Ctx:
    mesh: Mesh | None = None
    rules: dict | None = None


_CTX = _Ctx()


# mapping: logical axis name -> mesh axis name, tuple of names, or None
AxisRules = dict


@contextmanager
def use_sharding(mesh: Mesh, rules: AxisRules):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Mesh | None:
    return _CTX.mesh


def _axis_size(mesh: Mesh, phys) -> int:
    if phys is None:
        return 1
    if isinstance(phys, (tuple, list)):
        n = 1
        for a in phys:
            n *= mesh.shape[a]
        return n
    return mesh.shape[phys]


def logical_spec(names: Sequence[str | None], shape: Sequence[int] | None = None) -> P:
    """Resolve logical names to a PartitionSpec under the active rules.

    With ``shape`` given, any mapping whose mesh-axis size does not divide the
    dimension is dropped (replicated) rather than erroring.
    """
    mesh, rules = _CTX.mesh, _CTX.rules
    assert mesh is not None and rules is not None, "no active sharding context"
    out = []
    used: set = set()
    for i, name in enumerate(names):
        phys = rules.get(name) if name is not None else None
        if phys is not None:
            flat = tuple(phys) if isinstance(phys, (tuple, list)) else (phys,)
            if any(a in used for a in flat):
                phys = None  # mesh axis already consumed by an earlier dim
            elif shape is not None and shape[i] % _axis_size(mesh, phys) != 0:
                phys = None
            else:
                used.update(flat)
        out.append(tuple(phys) if isinstance(phys, list) else phys)
    return P(*out)


def shard(x, *names: str | None):
    """Annotate ``x`` with logical axes (identity when no context is active)."""
    if _CTX.mesh is None:
        return x
    spec = logical_spec(names, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))


def named_sharding(names: Sequence[str | None], shape=None) -> NamedSharding:
    return NamedSharding(_CTX.mesh, logical_spec(names, shape))


def make_rules(cfg, mesh: Mesh, mode: str = "train",
               decode_batch: int | None = None,
               strategy: str = "tp") -> AxisRules:
    """Build the logical->physical table for a model config on a mesh.

    mode: 'train' / 'prefill' -> heads (or head_dim) sharded over 'model';
          'decode'            -> KV-cache sequence sharded over 'model'
                                 (cache dominates memory; attention math is
                                 sequence-parallel through GSPMD reductions).
    Long-context decode with batch==1 additionally routes 'kv_seq' over
    ('data','model') via the divisibility fallback in logical_spec.

    strategy 'tp' (default): megatron-style tensor parallelism over 'model'.
    strategy 'tp_sp': TP + sequence parallelism — the inter-layer residual
    stream shards its *sequence* dim over 'model' (instead of d_model), so
    layer entry/exit become all-gather + reduce-scatter instead of
    all-gather + all-reduce: ~1/3 less activation wire volume.
    strategy 'fsdp': no tensor parallelism — batch shards over the *whole*
    mesh and parameters/optimizer shard over it too (gathered per layer by
    GSPMD). For small models (gemma3-1b at TP=16 spends 100x more time in
    activation collectives than compute) this is the right point on the
    same physical mesh; see EXPERIMENTS.md §Perf.
    """
    axes = dict(mesh.shape)
    model = "model" if "model" in axes else None
    data: tuple[str, ...] = tuple(a for a in ("pod", "data") if a in axes)
    msize = axes.get("model", 1)

    if strategy == "fsdp" and mode == "train":
        full = data + ((model,) if model else ())
        return {
            "batch": full, "seq": None, "embed": None,
            "residual_embed": None,
            # params/grads/opt state shard over everything ('zero' is set by
            # the caller to the same full tuple); weights gather per layer.
            "vocab": model, "mlp": None, "heads": None, "kv_heads": None,
            "head_dim": None, "experts": None, "expert_mlp": None,
            "layers": None, "kv_seq": None, "state": None, "frames": None,
        }

    def div(n: int) -> bool:
        return model is not None and n > 0 and n % msize == 0

    heads_sharded = div(getattr(cfg, "h_eff", getattr(cfg, "n_heads", 0)))
    rules: AxisRules = {
        "batch": data,
        "seq": None,
        "embed": None,
        # inter-layer residual stream: shard d_model over 'model' ('tp',
        # ZeRO-R style) or the sequence dim ('tp_sp', megatron-SP style);
        # layers gather as needed.
        "residual_seq": model if strategy == "tp_sp" else None,
        "residual_embed": (model if (strategy != "tp_sp"
                                     and div(getattr(cfg, "d_model", 0)))
                           else None),
        "vocab": model if div(getattr(cfg, "vocab_padded", 0)) else None,
        "mlp": model if div(getattr(cfg, "d_ff", 0)) else None,
        "heads": model if heads_sharded else None,
        "kv_heads": model if div(getattr(cfg, "kv_eff", getattr(cfg, "n_kv_heads", 0))) else None,
        "head_dim": (model if (not heads_sharded and div(getattr(cfg, "d_head", 0)))
                     else None),
        "experts": model if div(getattr(cfg, "n_experts", 0)) else None,
        "expert_mlp": None,
        "layers": None,
        "kv_seq": None,
        "state": None,
        "frames": None,
    }
    if getattr(cfg, "n_experts", 0) and not div(cfg.n_experts):
        # e.g. mixtral 8 experts on a 16-way model axis: TP inside the expert
        rules["expert_mlp"] = model if div(cfg.d_ff) else None
    if mode == "decode":
        # KV-cache length dominates decode memory: shard it over 'model'
        # (plus 'data' first when batch=1 long-context decode can't use it).
        # Weight sharding (heads/head_dim/mlp/vocab) stays as in train —
        # GSPMD reshards the single new KV row into the cache layout.
        bsz = decode_batch
        if bsz is not None and data and bsz % _axis_size(mesh, data) != 0:
            rules["batch"] = None
            rules["kv_seq"] = tuple(data) + ((model,) if model else ())
        else:
            rules["kv_seq"] = model
    return rules
