from .pipeline import SyntheticLMData, make_global_batch
