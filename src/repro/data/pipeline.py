"""Deterministic synthetic LM data pipeline.

Generates a reproducible token stream (hash-counter based, independent of
step order — a restarted job regenerates identical batches), shards the
global batch across the data mesh axes via make_array_from_callback (each
host/device materializes only its slice — at a real cluster scale this is
what keeps the input pipeline O(local batch)), and produces (tokens, labels)
next-token pairs.

The stream is Zipf-distributed over the vocab with a short Markov flavor so
losses decrease meaningfully during the example runs (pure uniform tokens
give a flat loss at log V).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding import logical_spec

__all__ = ["SyntheticLMData", "make_global_batch"]


def _philox_tokens(seed: int, step: int, lo: int, hi: int, seq: int,
                   vocab: int):
    """Deterministic tokens for rows [lo, hi) of the global batch.

    Seeded per (seed, step, row) so any device can materialize any slice and
    agree bit-for-bit with every other slicing (restart/elastic safety)."""
    out = np.empty((hi - lo, seq), np.int32)
    for r in range(lo, hi):
        rng = np.random.default_rng(np.random.SeedSequence([seed, step, r]))
        base = rng.zipf(1.3, size=seq).astype(np.int64)
        tok = (base - 1) % vocab
        stay = rng.random(seq) < 0.3
        tok = np.where(stay, np.roll(tok, 1), tok)
        out[r - lo] = tok.astype(np.int32)
    return out


@dataclasses.dataclass
class SyntheticLMData:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_np(self, step: int, lo: int = 0, hi: int | None = None):
        """Rows [lo, hi) of the global batch at ``step`` (+1 token for labels)."""
        hi = self.global_batch if hi is None else hi
        return _philox_tokens(self.seed, step, lo, hi, self.seq_len + 1,
                              self.vocab)

    def global_arrays(self, step: int, mesh):
        """Sharded device arrays for (tokens, labels) on ``mesh``."""
        tokens = make_global_batch(
            mesh, (self.global_batch, self.seq_len), jnp.int32,
            lambda lo, hi: self.batch_np(step, lo, hi)[:, :-1])
        labels = make_global_batch(
            mesh, (self.global_batch, self.seq_len), jnp.int32,
            lambda lo, hi: self.batch_np(step, lo, hi)[:, 1:])
        return tokens, labels


def make_global_batch(mesh, shape, dtype, row_fn):
    """Build a ('batch','seq')-sharded global array; each device shard is
    produced locally by ``row_fn(lo, hi)`` over its batch rows."""
    sharding = jax.sharding.NamedSharding(
        mesh, logical_spec(("batch", "seq"), shape))

    def cb(index):
        rows = index[0]
        lo = rows.start or 0
        hi = rows.stop if rows.stop is not None else shape[0]
        data = np.asarray(row_fn(lo, hi), dtype=dtype)
        cols = index[1]
        return data[:, cols]

    return jax.make_array_from_callback(shape, sharding, cb)
