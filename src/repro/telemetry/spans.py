"""Per-request trace spans (DESIGN.md §12).

A span is a plain JSON-able list ``[name, host, t0, t1]`` with
``time.perf_counter()`` timestamps (monotonic *per host*; hosts are not
clock-synchronized, which is why the Chrome-trace export maps each host
to its own ``pid`` instead of fabricating a global timeline).

Span vocabulary along the request path:

    admit        submit() entry -> request prepared/admitted
    route        cluster frontend routing decision (cluster only)
    retry        failure detected -> re-admission on a surviving host
                 (failover/hedge only; precedes a fresh route span)
    batch_wait   admitted -> the request's bucket batch dispatched
    operands     operand build / device upload (cache hit makes it short)
    compute      dispatch -> device results materialized
    wire_measure rANS coding + wire-model accounting (measure_wire only)
    complete     result finalization (slice-out, drift, wire fields)

Spans ride on ``SolveRequest.spans`` / ``SolveResult.spans`` and cross
host boundaries inside codec JSON headers (floats round-trip exactly
through Python's ``json``).
"""
from __future__ import annotations

import json
import time
from typing import IO, Iterable, List, Optional, Sequence

__all__ = [
    "now", "span", "span_names", "spans_monotonic", "missing_spans",
    "expected_spans", "tag_host", "chrome_trace_events", "write_trace_jsonl",
]

Span = List  # [name: str, host: str | None, t0: float, t1: float]

CORE_SPANS = ("admit", "batch_wait", "operands", "compute", "complete")


def now() -> float:
    return time.perf_counter()


def span(name: str, t0: float, t1: Optional[float] = None,
         host: Optional[str] = None) -> Span:
    return [name, host, float(t0), float(t1 if t1 is not None else now())]


def span_names(spans: Optional[Sequence[Span]]) -> List[str]:
    return [s[0] for s in (spans or [])]


def tag_host(spans: Optional[Sequence[Span]], host: str) -> List[Span]:
    """Fill in the host field on spans that don't have one yet (the
    backend emits host=None; the frontend knows which host it routed to)."""
    return [[s[0], s[1] if s[1] is not None else host, s[2], s[3]]
            for s in (spans or [])]


def expected_spans(*, wire: bool = False, cluster: bool = False) -> List[str]:
    names = list(CORE_SPANS)
    if wire:
        names.insert(names.index("complete"), "wire_measure")
    if cluster:
        names.insert(1, "route")
    return names


def missing_spans(spans: Optional[Sequence[Span]], *, wire: bool = False,
                  cluster: bool = False) -> List[str]:
    """Names from the expected vocabulary absent from ``spans`` — an
    incomplete span tree means some plane dropped instrumentation."""
    have = set(span_names(spans))
    return [n for n in expected_spans(wire=wire, cluster=cluster)
            if n not in have]


def spans_monotonic(spans: Optional[Sequence[Span]]) -> bool:
    """Every span well-formed (t1 >= t0) and, per host, span start times
    non-decreasing in list order (the order the planes appended them)."""
    last_t0: dict = {}
    for s in (spans or []):
        name, host, t0, t1 = s[0], s[1], float(s[2]), float(s[3])
        if t1 < t0:
            return False
        if t0 < last_t0.get(host, -float("inf")):
            return False
        last_t0[host] = t0
    return True


def chrome_trace_events(request_id: int, spans: Sequence[Span]) -> List[dict]:
    """Chrome trace-event ``"X"`` (complete) events for one request.

    pid = host (hosts have independent clocks — keeping them in separate
    pid lanes is honest about skew), tid = request id, ts/dur in us.
    """
    out = []
    for s in (spans or []):
        name, host, t0, t1 = s[0], s[1], float(s[2]), float(s[3])
        out.append({
            "name": name, "ph": "X", "pid": str(host or "local"),
            "tid": int(request_id), "ts": t0 * 1e6,
            "dur": max(t1 - t0, 0.0) * 1e6, "cat": "amp",
        })
    return out


def write_trace_jsonl(fp: IO[str], results: Iterable) -> int:
    """Append one Chrome trace event per line for each result carrying
    spans. Returns the number of events written. The file is valid JSONL;
    ``[`` + join(lines, ",") + ``]`` is a loadable Chrome trace."""
    n = 0
    for r in results:
        spans = getattr(r, "spans", None)
        if not spans:
            continue
        rid = getattr(r, "request_id", -1)
        for ev in chrome_trace_events(rid, spans):
            fp.write(json.dumps(ev, separators=(",", ":")) + "\n")
            n += 1
    return n
