"""Live SE-drift monitor (DESIGN.md §12).

The paper's promise is analytic predictability: quantized SE (eq. 8) and
its column/erasure extensions say what per-iteration variance a solve
*should* realize.  The engine already computes the realized plug-in
trajectory in-graph (``EngineTrace.sigma2_hat`` — no extra FLOPs), so
comparing the two per request is nearly free and turns mis-modeled
quantization error, erasure bursts, or stale RD tables into an alert
instead of a silent MSE regression.

Alignment with the engine's plug-in (verified against core/engine.py):

- Row layout: ``sigma2_hat[t] = ||z_t||^2 / m`` estimates the SE message
  variance *before* iteration t's transport noise is injected, i.e.
  ``se_trajectory_erasure(...)[t]`` (which starts at sigma_0^2).  The
  transport-injected variance rides separately as
  ``extra_var[t] = P * sigma_Q^2[t]``, which is exactly the schedule the
  SE recursion consumes.
- Column layout: ``sigma2_hat[s] = ||g^s||^2 / M`` post-fusion *includes*
  round-s quantization noise and matches ``tau[s]`` from
  ``se_trajectory_col`` directly.

Drift statistic: ``mean_t | ln(realized[t] / predicted[t]) |`` — a
symmetric, scale-free multiplicative error.  Clean solves measure
well under 0.5 (finite-N fluctuation at the paper's sizes); a mis-rated
solve (e.g. the request declares the wrong SNR, or the quantizer's true
MSE is not what the RD table claims) lands decades off on the log scale.

Predictions are memoized on the operating point (prior, shape, SNR,
layout, P, T, erasure rate, rounded quantizer schedule): a steady-state
request stream pays one dict hit per request, not an SE recursion.
"""
from __future__ import annotations

import math
import threading
from typing import Optional, Tuple

import numpy as np

from ..core.state_evolution import (CSProblem, se_trajectory_col,
                                    se_trajectory_erasure)

__all__ = ["se_drift", "se_drift_batch", "se_prediction", "DRIFT_ALERT"]

# Above this, flag the request (service increments amp_se_drift_alerts_total).
DRIFT_ALERT = 1.0

_cache_lock = threading.Lock()
_cache: dict = {}
_CACHE_MAX = 4096
# second-level cache in front of ``se_prediction``: keyed by the raw
# float32 schedule bytes instead of the 5-sig-digit rounded tuple, so a
# steady stream pays ~1us of key construction per request instead of
# ~5us of per-element string formatting (the <=2% telemetry-overhead
# budget, DESIGN.md §12). Bit-identical schedules — the steady-state
# case, since they come from the same compiled program — always hit.
_fast_cache: dict = {}


def _sched_key(extra_var: Optional[np.ndarray], t: int) -> tuple:
    if extra_var is None:
        return (0.0,) * t
    # 5 significant digits: identical requests hit; real schedule changes miss.
    return tuple(float(f"{float(v):.5e}") for v in extra_var[:t])


def se_prediction(prob: CSProblem, t_max: int, extra_var,
                  *, layout: str = "row", n_proc: int = 1,
                  erasure_rate: float = 0.0, n_inner: int = 1) -> np.ndarray:
    """Predicted per-iteration variance trajectory (length ``t_max``) for
    the operating point, memoized process-wide."""
    key = (prob.n, prob.m, prob.snr_db,
           prob.prior.eps, prob.prior.mu_s, prob.prior.sigma_s,
           layout, int(n_proc), int(n_inner), float(erasure_rate),
           int(t_max), _sched_key(extra_var, t_max))
    with _cache_lock:
        pred = _cache.get(key)
    if pred is not None:
        return pred
    sq = (np.zeros(t_max) if extra_var is None
          else np.asarray(extra_var, dtype=np.float64)[:t_max] / max(n_proc, 1))
    if layout == "col":
        tau, _ = se_trajectory_col(prob, n_proc, n_outer=t_max,
                                   n_inner=n_inner, sigma_q2=sq,
                                   erasure_rate=erasure_rate)
        pred = np.asarray(tau[:t_max])
    else:
        pred = se_trajectory_erasure(prob, sq, n_proc, erasure_rate)[:t_max]
    with _cache_lock:
        if len(_cache) >= _CACHE_MAX:
            _cache.clear()
        _cache[key] = pred
    return pred


def _fast_prediction(prob: CSProblem, t_max: int, extra_var, layout: str,
                     n_proc: int, erasure_rate: float,
                     n_inner: int) -> tuple:
    """Returns ``(pred, log_pred, ok, ok_all)`` — the prediction plus its
    precomputed log and validity mask (``pred > 0`` and finite), so the
    batched drift stat pays only the realized-side numpy ops per call."""
    ev_b = (None if extra_var is None else
            np.ascontiguousarray(extra_var[:t_max],
                                 dtype=np.float32).tobytes())
    key = (prob.n, prob.m, prob.snr_db,
           prob.prior.eps, prob.prior.mu_s, prob.prior.sigma_s,
           layout, int(n_proc), int(n_inner), float(erasure_rate),
           int(t_max), ev_b)
    entry = _fast_cache.get(key)    # GIL-atomic read; no lock on the hit
    if entry is None:
        pred = se_prediction(prob, t_max, extra_var, layout=layout,
                             n_proc=n_proc, erasure_rate=erasure_rate,
                             n_inner=n_inner)
        ok = (pred > 0.0) & np.isfinite(pred)
        with np.errstate(divide="ignore", invalid="ignore"):
            log_pred = np.where(ok, np.log(np.where(ok, pred, 1.0)), 0.0)
        entry = (pred, log_pred, ok, bool(ok.all()))
        with _cache_lock:
            if len(_fast_cache) >= _CACHE_MAX:
                _fast_cache.clear()
            _fast_cache[key] = entry
    return entry


def se_drift(prob: CSProblem, sigma2_hat, extra_var=None,
             *, layout: str = "row", n_proc: int = 1,
             erasure_rate: float = 0.0, n_inner: int = 1
             ) -> Tuple[float, np.ndarray]:
    """Compare a realized ``sigma2_hat`` trajectory against its SE
    prediction.  Returns ``(drift, predicted)`` with
    ``drift = mean_t |ln(realized[t]/predicted[t])|``; NaN when no
    iteration admits a well-defined ratio."""
    s2 = np.asarray(sigma2_hat, dtype=np.float64)
    t_max = len(s2)
    pred = _fast_prediction(prob, t_max, extra_var, layout, n_proc,
                            erasure_rate, n_inner)[0]
    # T is small (<= a few dozen): a scalar loop beats the ~8 numpy-op
    # masked pipeline by an order of magnitude on the hot path
    tot, k = 0.0, 0
    for r, p in zip(s2.tolist(), pred.tolist()):
        if r > 0.0 and p > 0.0 and math.isfinite(r) and math.isfinite(p):
            tot += abs(math.log(r / p))
            k += 1
    if k == 0:
        return float("nan"), pred
    return tot / k, pred


def se_drift_batch(prob: CSProblem, sigma2_hat, extra_var=None,
                   *, layout: str = "row", n_proc: int = 1,
                   erasure_rate: float = 0.0, n_inner: int = 1
                   ) -> np.ndarray:
    """Vectorized ``se_drift`` over a batch sharing one operating point:
    ``sigma2_hat`` is ``(B, T)``; ``extra_var`` is either one length-T
    realized quantizer schedule shared by every row, or a ``(B, T)``
    matrix of per-request schedules (one memoized prediction lookup per
    *distinct* schedule — requests with per-request rate allocations
    stay on the vectorized path instead of degrading to B scalar
    ``se_drift`` calls). One masked log-ratio pass covers every row —
    the batched dispatch path's telemetry tail (DESIGN.md §12). Rows
    with no well-defined ratio come back NaN."""
    s2 = np.asarray(sigma2_hat, dtype=np.float64)
    ev = None if extra_var is None else np.asarray(extra_var)
    if ev is not None and ev.ndim == 2:
        t_max = s2.shape[1]
        log_pred = np.empty_like(s2)
        ok_pred = np.empty(s2.shape, dtype=bool)
        ok_all = True
        for i in range(s2.shape[0]):
            _, lp, okp, oa = _fast_prediction(prob, t_max, ev[i], layout,
                                              n_proc, erasure_rate, n_inner)
            log_pred[i] = lp
            ok_pred[i] = okp
            ok_all = ok_all and oa
    else:
        _, log_pred, ok_pred, ok_all = _fast_prediction(
            prob, s2.shape[1], ev, layout, n_proc, erasure_rate, n_inner)
    # clean-trace fast path (the steady-state common case): every entry
    # strictly positive and finite on both sides, so the mask machinery
    # — masked ufuncs are markedly slower than plain ones — and the
    # per-row count bookkeeping all collapse away
    if ok_all and s2.size and s2.min() > 0.0 and math.isfinite(s2.max()):
        buf = np.log(s2)
        buf -= log_pred
        np.abs(buf, out=buf)
        return buf.sum(axis=1) / s2.shape[1]
    ok = (s2 > 0.0) & np.isfinite(s2)
    if not ok_all:
        ok &= ok_pred
    # log only where valid (masked entries stay 0), subtract the cached
    # log-prediction in place, zero the masked residue, reduce
    buf = np.log(s2, out=np.zeros_like(s2), where=ok)
    np.subtract(buf, log_pred, out=buf, where=ok)
    np.abs(buf, out=buf)
    k = ok.sum(axis=1)
    tot = buf.sum(axis=1)
    return np.where(k > 0, tot / np.maximum(k, 1), np.nan)
