"""Unified telemetry plane: metrics registry, request trace spans, and
live SE-drift monitoring (DESIGN.md §12).

Dependency-free by design — snapshots and spans are plain JSON-able
structures that ride the serving plane's no-pickle codec across host
boundaries and render as Prometheus text or Chrome trace-event JSONL.
"""
from .drift import DRIFT_ALERT, se_drift, se_drift_batch, se_prediction
from .metrics import (DRIFT_BUCKETS, LATENCY_BUCKETS, Counter, Gauge,
                      Histogram, MetricsRegistry, hist_quantile,
                      merge_snapshots, prometheus_text)
from .spans import (chrome_trace_events, expected_spans, missing_spans,
                    now, span, span_names, spans_monotonic, tag_host,
                    write_trace_jsonl)

__all__ = [
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "prometheus_text", "merge_snapshots", "hist_quantile",
    "LATENCY_BUCKETS", "DRIFT_BUCKETS",
    "now", "span", "span_names", "spans_monotonic", "missing_spans",
    "expected_spans", "tag_host", "chrome_trace_events",
    "write_trace_jsonl",
    "se_drift", "se_drift_batch", "se_prediction", "DRIFT_ALERT",
]
