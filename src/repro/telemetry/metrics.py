"""Thread-safe metrics registry (DESIGN.md §12).

Dependency-free observability primitives for the serving plane: counters,
gauges, and fixed-bucket histograms keyed by label tuples, with atomic
snapshots rendered as JSON-able dicts or Prometheus text exposition
format.

Design notes (why this is not prometheus_client):

- No background server, no pip dependency; snapshots travel over the
  cluster's no-pickle codec (``serving/codec.py`` frame ``kind:
  "metrics"``) and merge at the frontend with a ``host`` label.
- Hot-path cost is one dict lookup + float add under a per-registry
  lock.  Expensive sources (engine counters, cache stats, router state)
  are *pulled* by collector callbacks at snapshot time, not pushed per
  request, which is what keeps enabled-telemetry overhead inside the 2%
  budget (``BENCH_serve.json`` ``telemetry_overhead``).
- Naming scheme: ``amp_<plane>_<what>_<unit>`` — e.g.
  ``amp_engine_compiles_total``, ``amp_request_latency_seconds``,
  ``amp_se_drift``.  Suffixes follow Prometheus conventions
  (``_total`` for counters, ``_seconds``/``_bytes`` for units).
"""
from __future__ import annotations

import math
import threading
from bisect import bisect_left
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "prometheus_text", "merge_snapshots", "hist_quantile",
    "LATENCY_BUCKETS", "DRIFT_BUCKETS", "RECOVERY_BUCKETS", "HOST_STATES",
]

# Request latencies span ~100us (cached singleton) to seconds (cold batch).
LATENCY_BUCKETS: Tuple[float, ...] = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)
# SE drift is mean |log(realized/predicted)|: clean solves sit well below
# 0.5; a mis-rated solve (wrong SNR / stale RD table) lands above 1.
DRIFT_BUCKETS: Tuple[float, ...] = (
    0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0,
)
# Failover recovery (failure detected -> re-admitted request completed,
# DESIGN.md §13): dominated by the surviving host's batch+compute time,
# so the grid extends past LATENCY_BUCKETS into the tens of seconds a
# cold re-dispatch under load can take.
RECOVERY_BUCKETS: Tuple[float, ...] = (
    0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)
# amp_host_state gauge encoding (the router's host state machine)
HOST_STATES: Tuple[str, ...] = ("healthy", "suspect", "dead", "draining")

_LabelKey = Tuple[str, ...]


def _label_key(labelnames: Sequence[str], labels: Dict[str, str]) -> _LabelKey:
    # hot path: build the key straight from the declared order, catching
    # missing names via KeyError — two set() builds per observe would
    # double the cost of every counter bump
    try:
        key = tuple(str(labels[k]) for k in labelnames)
    except KeyError:
        key = None
    if key is None or len(labels) != len(labelnames):
        raise ValueError(
            f"labels {sorted(labels)} != declared {sorted(labelnames)}")
    return key


class _Child:
    """Label-bound handle (prometheus_client's ``.labels()`` idiom): hot
    paths resolve the label key once and keep the child, turning every
    subsequent bump into a lock + dict update with no per-call label
    validation (the <=2% telemetry-overhead budget, DESIGN.md §12)."""

    __slots__ = ("_metric", "_key")

    def __init__(self, metric: "_Metric", key: _LabelKey):
        self._metric = metric
        self._key = key

    def inc(self, amount: float = 1.0) -> None:
        m = self._metric
        with m._lock:
            m._series[self._key] = m._series.get(self._key, 0.0) + amount

    def set(self, value: float) -> None:
        m = self._metric
        with m._lock:
            m._series[self._key] = float(value)

    def observe(self, value: float) -> None:
        self._metric._observe_key(self._key, (value,))

    def observe_many(self, values: Iterable[float]) -> None:
        self._metric._observe_key(self._key, values)


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Sequence[str],
                 lock: threading.RLock):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = lock
        self._series: Dict[_LabelKey, object] = {}

    def labels(self, **labels: str) -> _Child:
        return _Child(self, _label_key(self.labelnames, labels))


class Counter(_Metric):
    """Monotone float counter, one series per label-value tuple."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def set_total(self, value: float, **labels: str) -> None:
        """Pull-time absolute assignment — for collector callbacks that
        mirror an external monotone counter (engine compiles, cache hits)
        instead of double-counting events."""
        key = _label_key(self.labelnames, labels)
        with self._lock:
            self._series[key] = float(value)

    def _snapshot(self) -> List[dict]:
        return [{"labels": dict(zip(self.labelnames, k)), "value": v}
                for k, v in sorted(self._series.items())]


class Gauge(_Metric):
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def set(self, value: float, **labels: str) -> None:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            self._series[key] = float(value)

    _snapshot = Counter._snapshot


class Histogram(_Metric):
    """Fixed-bound cumulative-bucket histogram (Prometheus semantics).

    Each series stores per-bucket counts (le = upper bound, +Inf
    implicit), plus sum and count; quantiles are estimated from the
    bucket upper bounds (``hist_quantile``) — conservative, never
    under-reports.
    """

    kind = "histogram"

    def __init__(self, name, help, labelnames, lock,
                 buckets: Sequence[float] = LATENCY_BUCKETS):
        super().__init__(name, help, labelnames, lock)
        b = tuple(sorted(float(x) for x in buckets))
        if not b or b != tuple(dict.fromkeys(b)):
            raise ValueError(f"bad histogram buckets {buckets}")
        self.buckets = b

    def observe(self, value: float, **labels: str) -> None:
        self.observe_many((value,), **labels)

    def observe_many(self, values: Iterable[float], **labels: str) -> None:
        """Bulk observation under one lock acquisition / label-key build —
        the batched dispatch path records a whole bucket group's
        latencies and drifts in one call (the <=2% telemetry-overhead
        budget, DESIGN.md §12)."""
        self._observe_key(_label_key(self.labelnames, labels), values)

    def _observe_key(self, key: _LabelKey, values: Iterable[float]) -> None:
        bounds = self.buckets
        overflow = len(bounds)
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = self._series[key] = {
                    "counts": [0] * (overflow + 1),
                    "sum": 0.0, "count": 0,
                }
            counts = s["counts"]
            tot, n = 0.0, 0
            for v in values:
                # bisect_left == first bound >= v, i.e. the `value <= le`
                # Prometheus bucket; NaN compares false everywhere ->
                # route it to +Inf explicitly
                counts[overflow if v != v else bisect_left(bounds, v)] += 1
                tot += v
                n += 1
            # one float() per flush (not per value) keeps sums JSON-able
            # even when callers hand in numpy scalars
            s["sum"] += float(tot)
            s["count"] += n

    def _snapshot(self) -> List[dict]:
        out = []
        for k, s in sorted(self._series.items()):
            out.append({"labels": dict(zip(self.labelnames, k)),
                        "bounds": list(self.buckets),
                        "counts": list(s["counts"]),
                        "sum": s["sum"], "count": s["count"]})
        return out


def hist_quantile(sample: dict, q: float) -> Optional[float]:
    """Quantile estimate from one histogram snapshot sample.

    Returns the upper bound of the bucket containing the q-quantile
    (+Inf bucket reports the largest finite bound — an underestimate
    flagged by the caller if it matters). None when the series is empty.
    """
    count = sample.get("count", 0)
    if count <= 0:
        return None
    rank = q * count
    seen = 0
    for bound, c in zip(sample["bounds"], sample["counts"]):
        seen += c
        if seen >= rank:
            return float(bound)
    return float(sample["bounds"][-1])


class MetricsRegistry:
    """Registry of named metrics plus pull-time collector callbacks.

    ``collect(fn)`` registers a callback run inside ``snapshot()`` —
    used by the service to fold in sources that already keep their own
    atomic counters (engine, operand cache, batcher, router) without
    adding hot-path writes.
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: Dict[str, _Metric] = {}
        self._collectors: List[Callable[["MetricsRegistry"], None]] = []

    def _get(self, cls, name: str, help: str, labelnames: Sequence[str],
             **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, labelnames,
                                              self._lock, **kw)
            elif type(m) is not cls or m.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} re-registered as {cls.kind} "
                    f"labels={tuple(labelnames)} (was {m.kind} {m.labelnames})")
            return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = LATENCY_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, labelnames, buckets=buckets)

    def collect(self, fn: Callable[["MetricsRegistry"], None]) -> None:
        with self._lock:
            self._collectors.append(fn)

    def snapshot(self) -> dict:
        """Atomic JSON-able snapshot: runs collectors, then copies every
        series under the registry lock."""
        for fn in list(self._collectors):
            fn(self)
        with self._lock:
            return {"metrics": [
                {"name": m.name, "kind": m.kind, "help": m.help,
                 "labelnames": list(m.labelnames), "samples": m._snapshot()}
                for m in sorted(self._metrics.values(), key=lambda m: m.name)
            ]}


def merge_snapshots(snaps: Sequence[Tuple[str, dict]]) -> dict:
    """Merge per-host snapshots into one, adding a ``host`` label to every
    sample (Prometheus-style per-host series; no cross-host summing, so
    nothing is lost and histograms stay exact)."""
    merged: Dict[str, dict] = {}
    for host, snap in snaps:
        for m in snap.get("metrics", []):
            name = m["name"]
            dst = merged.get(name)
            if dst is None:
                dst = merged[name] = {
                    "name": name, "kind": m["kind"], "help": m.get("help", ""),
                    "labelnames": ["host"] + list(m.get("labelnames", [])),
                    "samples": [],
                }
            for s in m.get("samples", []):
                s2 = dict(s)
                s2["labels"] = {"host": str(host), **s.get("labels", {})}
                dst["samples"].append(s2)
    return {"metrics": [merged[k] for k in sorted(merged)]}


def _fmt_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    esc = lambda v: str(v).replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")
    return "{" + ",".join(f'{k}="{esc(v)}"' for k, v in sorted(labels.items())) + "}"


def _fmt_num(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def prometheus_text(snapshot: dict) -> str:
    """Render a snapshot (or ``merge_snapshots`` output) as Prometheus
    text exposition format v0.0.4."""
    lines: List[str] = []
    for m in snapshot.get("metrics", []):
        name, kind = m["name"], m.get("kind", "untyped")
        if m.get("help"):
            lines.append(f"# HELP {name} {m['help']}")
        lines.append(f"# TYPE {name} {kind}")
        for s in m.get("samples", []):
            labels = s.get("labels", {})
            if kind == "histogram":
                cum = 0
                for bound, c in zip(s["bounds"], s["counts"]):
                    cum += c
                    lab = _fmt_labels({**labels, "le": _fmt_num(bound)})
                    lines.append(f"{name}_bucket{lab} {cum}")
                cum += s["counts"][len(s["bounds"])]
                lab = _fmt_labels({**labels, "le": "+Inf"})
                lines.append(f"{name}_bucket{lab} {cum}")
                lines.append(f"{name}_sum{_fmt_labels(labels)} {_fmt_num(s['sum'])}")
                lines.append(f"{name}_count{_fmt_labels(labels)} {s['count']}")
            else:
                lines.append(f"{name}{_fmt_labels(labels)} {_fmt_num(s['value'])}")
    return "\n".join(lines) + ("\n" if lines else "")
