"""Fault-tolerant training loop.

Production behaviors implemented here (DESIGN.md §4):
  * async checkpoint every N steps (keep-k, atomic rename) + resume-on-start
    from the newest valid checkpoint (crash/preemption restart);
  * non-finite loss/grad-norm step rejection: the step's updates are
    discarded (params/opt re-used), a strike counter triggers rollback to
    the last checkpoint after K consecutive bad steps;
  * deterministic data: batches are a pure function of (seed, step), so a
    restarted run consumes identical data with no input-pipeline state;
  * simulated preemption hook (``fail_at_step``) used by the fault-tolerance
    tests to kill and resume a run mid-flight;
  * straggler note: gradient fusion is a collective, so per-step stragglers
    manifest as collective latency; the MP-AMP solver (launch/solver.py)
    implements partial-P fusion with SE-corrected denoising, and training
    uses bounded-staleness microbatch buckets (see DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import math
import time

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs.base import ModelConfig, ShapeSpec
from ..data import SyntheticLMData
from ..launch.steps import TrainStepConfig, build_train_step
from ..models import get_model
from ..optim import adamw_init
from ..sharding import use_sharding

__all__ = ["Trainer", "TrainerConfig"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_keep: int = 3
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
    max_bad_steps: int = 3
    log_every: int = 10
    fail_at_step: int | None = None     # simulated preemption (tests)
    step_cfg: TrainStepConfig = dataclasses.field(default_factory=TrainStepConfig)


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeSpec, mesh,
                 tcfg: TrainerConfig):
        self.cfg, self.shape, self.mesh, self.tcfg = cfg, shape, mesh, tcfg
        self.model = get_model(cfg)
        fn, shardings, abstract = build_train_step(cfg, mesh, shape,
                                                   tcfg.step_cfg)
        from jax.sharding import NamedSharding, PartitionSpec
        rep = NamedSharding(mesh, PartitionSpec())
        metrics_sh = {"grad_norm": rep, "clip": rep, "loss": rep,
                      "quant_noise": rep}
        self.step_fn = jax.jit(
            fn,
            in_shardings=(shardings["params"], shardings["opt_state"],
                          shardings["tokens"], shardings["labels"],
                          shardings["aux"]),
            # pin outputs so params/opt round-trip with stable shardings
            # across steps (donation + XLA's own choice would drift)
            out_shardings=(shardings["params"], shardings["opt_state"],
                           metrics_sh),
            donate_argnums=(0, 1))
        self.shardings = shardings
        self.data = SyntheticLMData(cfg.vocab, shape.seq_len,
                                    shape.global_batch, seed=tcfg.seed)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.ckpt_keep)
        self.history: list[dict] = []

    # -- state ---------------------------------------------------------------

    def init_state(self):
        params = self.model.init_params(jax.random.PRNGKey(self.tcfg.seed))
        params = jax.device_put(params, self.shardings["params"])
        opt = adamw_init(params)
        opt = jax.device_put(opt, self.shardings["opt_state"])
        return params, opt, 0

    def restore_or_init(self):
        latest = self.ckpt.latest_step()
        if latest is None:
            return self.init_state()
        state, step, _ = _load(self.ckpt.path, self.shardings)
        return state["params"], state["opt"], step

    # -- loop ----------------------------------------------------------------

    def run(self, resume: bool = True):
        params, opt, start = (self.restore_or_init() if resume
                              else self.init_state())
        bad_streak = 0
        step = start
        t0 = time.time()
        while step < self.tcfg.total_steps:
            if self.tcfg.fail_at_step is not None and step == self.tcfg.fail_at_step:
                self.ckpt.wait()
                raise RuntimeError(f"simulated preemption at step {step}")
            with use_sharding(self.mesh, self._rules()):
                tokens, labels = self.data.global_arrays(step, self.mesh)
            new_params, new_opt, metrics = self.step_fn(params, opt, tokens,
                                                        labels, {})
            loss = float(metrics["loss"])
            gnorm = float(metrics["grad_norm"])
            if not (math.isfinite(loss) and math.isfinite(gnorm)):
                # reject the step: donated buffers are gone, so rebuild from
                # the rejected output is NOT safe -> rollback path
                bad_streak += 1
                if bad_streak >= self.tcfg.max_bad_steps:
                    params, opt, step = self.restore_or_init()
                    bad_streak = 0
                    continue
                params, opt = new_params, new_opt  # best effort continue
                step += 1
                continue
            bad_streak = 0
            params, opt = new_params, new_opt
            self.history.append({"step": step, "loss": loss,
                                 "grad_norm": gnorm})
            if self.tcfg.log_every and step % self.tcfg.log_every == 0:
                dt = time.time() - t0
                print(f"step {step:5d} loss {loss:8.4f} gnorm {gnorm:7.3f} "
                      f"({dt:.1f}s)", flush=True)
            step += 1
            if step % self.tcfg.ckpt_every == 0:
                self.ckpt.save_async(step, {"params": params, "opt": opt},
                                     meta={"loss": loss})
        self.ckpt.wait()
        self.ckpt.save_async(step, {"params": params, "opt": opt},
                             meta={"final": True})
        self.ckpt.wait()
        return params, opt, self.history

    def _rules(self):
        from ..sharding import make_rules
        rules = make_rules(self.cfg, self.mesh, "train")
        return rules


def _load(path, shardings):
    from ..checkpoint import load_checkpoint
    tree, step, meta = load_checkpoint(
        path, shardings={"params": shardings["params"],
                         "opt": shardings["opt_state"]})
    return tree, step, meta
