"""AdamW (from scratch, flat-dict pytrees) with mixed precision + ZeRO-1.

Params live in bf16; the optimizer keeps fp32 master weights and moments.
``opt_state_specs`` extends each param's logical axes with 'data' on the
largest still-unsharded divisible dimension, sharding the fp32 state over the
data axis as well (ZeRO-1): at 34B params this is the difference between
17 GB and ~1 GB of optimizer bytes per chip. GSPMD inserts the corresponding
gather when the updated master weights are cast back to the bf16 replicas.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "opt_state_specs"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    f32 = lambda p: p.astype(jnp.float32)
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(jnp.zeros_like, jax.tree.map(f32, params)),
        "v": jax.tree.map(jnp.zeros_like, jax.tree.map(f32, params)),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """One AdamW step. Returns (new bf16 params, new state, metrics)."""
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    new_master, new_m, new_v, new_p = {}, {}, {}, {}
    for k in params:
        g = grads[k].astype(jnp.float32) * clip
        m = cfg.b1 * state["m"][k] + (1 - cfg.b1) * g
        v = cfg.b2 * state["v"][k] + (1 - cfg.b2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        master = state["master"][k] * (1.0 - lr * cfg.weight_decay) - lr * upd
        new_master[k], new_m[k], new_v[k] = master, m, v
        new_p[k] = master.astype(params[k].dtype)
    new_state = {"master": new_master, "m": new_m, "v": new_v, "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "clip": clip}


def opt_state_specs(param_specs: dict, mesh, param_shapes: dict,
                    zero1: bool = True) -> dict:
    """Logical-axis specs for the optimizer state (ZeRO-1 data sharding).

    Must be called inside a use_sharding context: a dim is eligible for the
    'zero' axis when its logical name *resolves* to no physical mesh axis
    under the active rules (checking the logical name against None is wrong —
    every dim has a logical name; what matters is whether it ended up
    sharded)."""
    from ..sharding import logical_spec

    data_size = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            data_size *= mesh.shape[a]

    def extend(path, axes):
        if not zero1:
            return axes
        shape = param_shapes[path]
        resolved = logical_spec(axes, shape)
        best, best_dim = None, 0
        for i, dim in enumerate(shape):
            phys = resolved[i] if i < len(resolved) else None
            if phys is None and dim % data_size == 0 and dim > best_dim:
                best, best_dim = i, dim
        if best is None:
            return axes
        out = list(axes)
        out[best] = "zero"  # logical axis mapped to ('pod','data')
        return tuple(out)

    per_param = {k: extend(k, v) for k, v in param_specs.items()}
    return {"master": per_param, "m": per_param, "v": per_param,
            "step": ()}
