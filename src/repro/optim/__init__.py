from .adamw import AdamWConfig, adamw_init, adamw_update, opt_state_specs
from .schedules import cosine_schedule, linear_warmup_cosine
