"""Mesh-distributed MP-AMP solver tests (8 fake devices, subprocess).

All solver paths are *fully-manual* shard_map and run on every supported
jax line; only the partial-manual train-step tests below carry a skip,
gated on the capability probe in ``repro/compat.py``.
"""
import pytest

from repro.compat import supports_partial_manual

# The compressed pod-axis gradient fusion uses *partial-manual* shard_map
# (manual: pod; auto: data/model) — see compat.supports_partial_manual for
# why jax 0.4.x cannot run (or even safely probe) that pattern.
partial_manual = pytest.mark.skipif(
    not supports_partial_manual(),
    reason="partial-manual shard_map needs jax >= 0.5 (explicit AxisType)")


def test_distributed_solver_matches_centralized(multidev):
    multidev("""
import jax, numpy as np
from repro.compat import make_mesh
from repro.core.denoisers import BernoulliGauss
from repro.core.engine import AmpEngine, CompressedPsumTransport
from repro.core.state_evolution import CSProblem
from repro.core.amp import sample_problem, amp_solve
from repro.launch.solver import DistributedMPAMP, SolverConfig

prior = BernoulliGauss(eps=0.1)
prob = CSProblem(n=2000, m=600, prior=prior)
s0, a, y = sample_problem(jax.random.PRNGKey(1), prob.n, prob.m, prior, prob.sigma_e2)
mesh = make_mesh((8,), ('data',))

sv = DistributedMPAMP(mesh, prior, SolverConfig(n_iter=12, bits=None))
x, s2s, _ = sv.solve(a, y)
ref = amp_solve(y, a, prior, 12, s0=s0)
assert abs(np.mean((x - s0)**2) - ref.mse[-1]) < 1e-6

# int8 fusion: near-centralized quality (paper claim at the mesh scale)
sv8 = DistributedMPAMP(mesh, prior, SolverConfig(n_iter=12, bits=8))
x8, _, nv = sv8.solve(a, y)
mse8 = np.mean((x8 - s0)**2)
assert mse8 < ref.mse[-1] * 1.25, (mse8, ref.mse[-1])
assert np.all(nv > 0)   # noise accounting active

# the solver is a frontend over the engine's sharded scan: one engine,
# one compiled solve_sharded program, no per-iteration Python loop
assert isinstance(sv8._engine, AmpEngine)
assert isinstance(sv8._engine.transport, CompressedPsumTransport)
assert [k[0] for k in sv8._engine._jit_cache] == ['sharded']

# straggler mode still converges to a usable solution
svd = DistributedMPAMP(mesh, prior, SolverConfig(n_iter=12, bits=8, drop_rate=0.15))
xd, _, _ = svd.solve(a, y)
assert np.mean((xd - s0)**2) < 0.5 * prior.second_moment
print('ok')
""", 8, timeout=900)


@partial_manual
def test_train_step_lowers_on_small_mesh(multidev):
    """CI-scale version of the dry-run: 2x4 mesh, smoke config, pod axis."""
    multidev("""
import jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.launch.steps import build_train_step, build_serve_step, TrainStepConfig

cfg = get_config('granite-3-8b').smoke_config()
shape = ShapeSpec('t', 64, 8, 'train')
mesh = make_mesh((2, 2, 2), ('pod', 'data', 'model'))
fn, sh, ab = build_train_step(cfg, mesh, shape,
                              TrainStepConfig(microbatches=2, moe_groups=2,
                                              compression_bits=8))
jitted = jax.jit(fn, in_shardings=(sh['params'], sh['opt_state'], sh['tokens'],
                                   sh['labels'], sh['aux']))
comp = jitted.lower(ab['params'], ab['opt_state'], ab['tokens'], ab['labels'],
                    ab['aux']).compile()
txt = comp.as_text()
assert any(('s8[' in l or 'u8[' in l) and ('all-to-all' in l or 'all-gather' in l)
           for l in txt.splitlines()), 'compressed pod fusion not visible'

# decode step lowers too
shape_d = ShapeSpec('d', 128, 8, 'decode')
fn2, sh2, ab2 = build_serve_step(cfg, mesh, shape_d)
jax.jit(fn2, in_shardings=(sh2['params'], sh2['tokens'], sh2['state'],
                           sh2['pos'])).lower(
    ab2['params'], ab2['tokens'], ab2['state'], ab2['pos']).compile()
print('ok')
""", 8, timeout=900)


@partial_manual
def test_compressed_gradient_training_converges(multidev):
    """End-to-end: the paper's technique applied to training — int8 pod-axis
    gradient fusion trains a smoke LM and the loss decreases like exact
    fusion (within noise)."""
    multidev("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.data import SyntheticLMData
from repro.launch.steps import build_train_step, TrainStepConfig
from repro.optim import adamw_init, AdamWConfig
from repro.sharding import make_rules, use_sharding

cfg = get_config('granite-3-8b').smoke_config()
shape = ShapeSpec('t', 32, 8, 'train')
mesh = make_mesh((2, 2, 2), ('pod', 'data', 'model'))
data = SyntheticLMData(cfg.vocab, shape.seq_len, shape.global_batch, seed=1)

def run(bits):
    fn, sh, ab = build_train_step(cfg, mesh, shape, TrainStepConfig(
        microbatches=1, moe_groups=2, compression_bits=bits,
        adamw=AdamWConfig(lr=2e-3)))
    from jax.sharding import NamedSharding, PartitionSpec
    rep = NamedSharding(mesh, PartitionSpec())
    met = {'grad_norm': rep, 'clip': rep, 'loss': rep, 'quant_noise': rep}
    step = jax.jit(fn, in_shardings=(sh['params'], sh['opt_state'],
                                     sh['tokens'], sh['labels'], sh['aux']),
                   out_shardings=(sh['params'], sh['opt_state'], met),
                   donate_argnums=(0, 1))
    from repro.models import get_model
    params = jax.device_put(get_model(cfg).init_params(jax.random.PRNGKey(0)),
                            sh['params'])
    opt = jax.device_put(adamw_init(params), sh['opt_state'])
    losses = []
    for i in range(12):
        with use_sharding(mesh, make_rules(cfg, mesh, 'train')):
            tok, lab = data.global_arrays(i, mesh)
        params, opt, m = step(params, opt, tok, lab, {})
        losses.append(float(m['loss']))
    return losses

l_exact = run(None)
l_int8 = run(8)
assert l_exact[-1] < l_exact[0] - 0.3, l_exact
assert l_int8[-1] < l_int8[0] - 0.3, l_int8
# int8-compressed training tracks exact within a modest margin
assert abs(l_int8[-1] - l_exact[-1]) < 0.5, (l_exact[-1], l_int8[-1])
print('ok', l_exact[-1], l_int8[-1])
""", 8, timeout=1200)
