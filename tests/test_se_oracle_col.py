"""Column-layout SE oracle (ISSUE 4): Monte-Carlo C-MP-AMP MSE
trajectories must track the two-stage column state evolution
(``se_trajectory_col``) at every outer round — including the quantization
noise injected on the exchanged residual contributions.

Envelope calibration mirrors ``test_se_oracle``: at N=2000 the MC average
sits systematically above the N->infinity SE value.  At ``n_inner = 1``
the algorithm is exactly centralized AMP (+ per-round fusion noise), so
the row oracle's finite-N envelope applies unchanged.  At ``n_inner = 2``
the inner iterations reuse one realization of the cross-block
interference, which the frozen-cross-term SE idealizes as fresh Gaussian
noise each step; the measured systematic gap (stable across N=2000 vs
N=8000, peaking ~1.36x at mid-trajectory, decaying at steady state) gets
its own calibrated envelope with ~50% headroom.  A real accounting bug —
dropping the P*sigma_Q^2 residual-fusion noise — shifts the quantized
trajectory far outside either envelope (a bare Onsager restart at the
fusion boundary is a ~20x drift; see ``ColumnPartition``).
"""
import jax
import numpy as np
import pytest

from repro.core.amp import sample_problem
from repro.core.denoisers import BernoulliGauss, make_mmse_interp
from repro.core.engine import (AmpEngine, ColumnPartition, EcsqTransport,
                               EngineConfig, ExactFusion, FixedSchedule)
from repro.core.state_evolution import CSProblem, se_trajectory_col

pytestmark = pytest.mark.tier2

N, M, P, B = 2000, 600, 4, 24
S1 = 8                                  # outer rounds at n_inner = 1
# measured finite-N bias at this (eps=0.05, N=2000) operating point peaks
# ~0.31 mid-trajectory and decays into steady state (unlike the eps=0.1
# row envelope's monotone growth); ~30% headroom over the measurement
REL_TOL_1 = np.array([0.20, 0.27, 0.34, 0.40, 0.44, 0.36, 0.20, 0.12])
S2 = 6                                  # outer rounds at n_inner = 2
REL_TOL_2 = np.array([0.25, 0.45, 0.60, 0.70, 0.65, 0.55])


@pytest.fixture(scope="module")
def mc_ctx():
    prior = BernoulliGauss(eps=0.05)
    prob = CSProblem(n=N, m=M, prior=prior, snr_db=20.0)
    insts = [sample_problem(jax.random.PRNGKey(i), N, M, prior,
                            prob.sigma_e2) for i in range(B)]
    s0s = np.stack([i[0] for i in insts])
    a_mats = np.stack([i[1] for i in insts])
    ys = np.stack([i[2] for i in insts])
    mm = make_mmse_interp(prior)
    return prob, mm, s0s, a_mats, ys


def _mc_mse(prob, transport, deltas, s0s, a_mats, ys, n_inner, n_outer):
    eng = AmpEngine(
        prob.prior,
        EngineConfig(n_proc=P, n_iter=n_outer, collect_symbols=False,
                     layout=ColumnPartition(n_inner=n_inner)),
        transport, FixedSchedule(deltas) if deltas is not None else None)
    return eng.solve_many(ys, a_mats).mse(s0s).mean(axis=0)


def test_column_exact_tracks_two_stage_se(mc_ctx):
    """Lossless residual fusion at n_inner = 1: MC MSE == column SE block
    trajectory d^s (== centralized SE, the exact-identity regime)."""
    prob, mm, s0s, a_mats, ys = mc_ctx
    mc = _mc_mse(prob, ExactFusion(), None, s0s, a_mats, ys, 1, S1)
    _, d = se_trajectory_col(prob, P, S1, 1, mmse_fn=mm)
    rel = np.abs(mc - d[1:]) / d[1:]
    assert (rel < REL_TOL_1).all(), list(zip(rel, REL_TOL_1))


def test_column_quantized_tracks_two_stage_se(mc_ctx):
    """ECSQ residual exchange at fixed bins: MC == SE with the
    P * Delta^2/12 noise injected on the fused residual each round."""
    prob, mm, s0s, a_mats, ys = mc_ctx
    delta = 0.03
    deltas = np.concatenate([[np.inf],
                             np.full(S1 - 1, delta)]).astype(np.float32)
    mc = _mc_mse(prob, EcsqTransport(), deltas, s0s, a_mats, ys, 1, S1)
    sigma_q2 = np.where(np.isfinite(deltas), deltas**2 / 12.0, 0.0)
    _, d = se_trajectory_col(prob, P, S1, 1, sigma_q2=sigma_q2, mmse_fn=mm)
    rel = np.abs(mc - d[1:]) / d[1:]
    assert (rel < REL_TOL_1).all(), list(zip(rel, REL_TOL_1))

    # teeth: the quantized trajectory must separate from the lossless one
    # by far more than the envelope at steady state
    mc_exact = _mc_mse(prob, ExactFusion(), None, s0s, a_mats, ys, 1, S1)
    assert mc[-1] > 1.2 * mc_exact[-1], (mc[-1], mc_exact[-1])
    _, d_exact = se_trajectory_col(prob, P, S1, 1, mmse_fn=mm)
    assert d[-1] > 1.2 * d_exact[-1]


def test_column_two_inner_tracks_two_stage_se(mc_ctx):
    """The genuinely two-stage regime (n_inner = 2): per-processor inner
    recursion + fusion-stage refresh, within its calibrated envelope."""
    prob, mm, s0s, a_mats, ys = mc_ctx
    mc = _mc_mse(prob, ExactFusion(), None, s0s, a_mats, ys, 2, S2)
    _, d = se_trajectory_col(prob, P, S2, 2, mmse_fn=mm)
    rel = np.abs(mc - d[1:]) / d[1:]
    assert (rel < REL_TOL_2).all(), list(zip(rel, REL_TOL_2))
    # and the SE itself is meaningful: 2 inner iterations per round beat
    # 1 at equal round count, in both MC and SE
    mc1 = _mc_mse(prob, ExactFusion(), None, s0s, a_mats, ys, 1, S2)
    _, d1 = se_trajectory_col(prob, P, S2, 1, mmse_fn=mm)
    assert mc[-1] < mc1[-1]
    assert d[-1] < d1[-1]
