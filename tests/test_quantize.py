import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.denoisers import BernoulliGauss
from repro.core.quantize import (GaussMixture, HIGH_RATE_ECSQ_GAP_BITS,
                                 delta_for_rate_ecsq, delta_for_sigma_q2,
                                 dequantize_midtread, ecsq_entropy,
                                 message_mixture, quantize_midtread)

MIX = message_mixture(BernoulliGauss(eps=0.1), sigma_t2=0.05, n_proc=30)


def test_entropy_decreasing_in_delta():
    deltas = np.geomspace(1e-4, 1.0, 30) * math.sqrt(MIX.variance)
    h = ecsq_entropy(deltas, MIX)
    assert np.all(np.diff(h) <= 1e-9)


def test_high_rate_entropy_formula():
    """H_Q(Delta) -> h(F) - log2(Delta) in the fine-quantization limit."""
    from repro.core.rate_distortion import gauss_mixture_entropy
    # build the equivalent scaled source: F^p has a two-component mixture pdf
    sd = math.sqrt(MIX.variance)
    delta = sd * 2.0**-8
    h_q = ecsq_entropy(delta, MIX)[0]
    # differential entropy via the same mixture (numerical)
    import scipy.integrate as si
    xs = np.linspace(*MIX.std_span(12.0), 400001)
    from scipy.stats import norm
    pdf = sum(w * norm.pdf(xs, m, math.sqrt(v))
              for w, m, v in zip(MIX.w, MIX.mu, MIX.var))
    h_diff = -si.simpson(pdf * np.log2(np.maximum(pdf, 1e-300)), x=xs)
    assert abs(h_q - (h_diff - math.log2(delta))) < 2e-2


def test_rate_inversion_roundtrip():
    for rate in (1.0, 2.5, 5.0):
        d = delta_for_rate_ecsq(rate, MIX)
        h = ecsq_entropy(d, MIX)[0]
        assert abs(h - rate) < 5e-3


def test_delta_sigma_q2_relation():
    assert abs(delta_for_sigma_q2(1.0 / 12.0) - 1.0) < 1e-12


@settings(max_examples=20, deadline=None)
@given(delta=st.floats(1e-3, 10.0),
       seed=st.integers(0, 2**31 - 1))
def test_midtread_error_bound(delta, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=1000) * 3.0
    q = quantize_midtread(x, delta, xp=np)
    xr = dequantize_midtread(q, delta)
    assert np.all(np.abs(xr - x) <= delta / 2 + 1e-12)


def test_quantization_error_statistics():
    """Widrow condition: Delta <= 2 sigma -> error ~ U[-D/2, D/2], uncorrelated."""
    rng = np.random.default_rng(0)
    sigma = math.sqrt(MIX.variance)
    delta = 1.0 * sigma
    comp = rng.random(200_000) < MIX.w[0]
    x = np.where(comp,
                 rng.normal(MIX.mu[0], math.sqrt(MIX.var[0]), 200_000),
                 rng.normal(MIX.mu[1], math.sqrt(MIX.var[1]), 200_000))
    err = dequantize_midtread(quantize_midtread(x, delta, xp=np), delta) - x
    assert abs(err.var() - delta**2 / 12) / (delta**2 / 12) < 0.03
    corr = np.corrcoef(err, x)[0, 1]
    assert abs(corr) < 0.02


def test_ecsq_gap_constant():
    assert abs(HIGH_RATE_ECSQ_GAP_BITS - 0.2546) < 1e-3
