import os

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": rng.normal(size=(8, 4)).astype(np.float32),
                   "b": rng.normal(size=(3,)).astype(np.float32)},
        "opt": {"m": {"w": rng.normal(size=(8, 4)).astype(np.float32)},
                "step": np.asarray(7, np.int32)},
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 5, tree, writers=4)
    loaded, step, _ = load_checkpoint(str(tmp_path))
    assert step == 5
    np.testing.assert_array_equal(loaded["params"]["w"], tree["params"]["w"])
    np.testing.assert_array_equal(loaded["opt"]["m"]["w"], tree["opt"]["m"]["w"])
    assert int(loaded["opt"]["step"]) == 7


def test_elastic_writer_counts(tmp_path):
    """A checkpoint written with 8 shards restores identically to 1 shard —
    the restore path is mesh/topology independent (elastic restart)."""
    tree = _tree(1)
    save_checkpoint(str(tmp_path / "a"), 1, tree, writers=8)
    save_checkpoint(str(tmp_path / "b"), 1, tree, writers=1)
    la, _, _ = load_checkpoint(str(tmp_path / "a"))
    lb, _, _ = load_checkpoint(str(tmp_path / "b"))
    np.testing.assert_array_equal(la["params"]["w"], lb["params"]["w"])


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, writers=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _tree(s))
        mgr.wait()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_000000003", "step_000000004"]
    assert mgr.latest_step() == 4


def test_partial_write_invisible(tmp_path):
    """A .tmp directory (crash mid-write) must not be picked up."""
    save_checkpoint(str(tmp_path), 1, _tree())
    os.makedirs(tmp_path / "step_000000009.tmp")
    _, step, _ = load_checkpoint(str(tmp_path))
    assert step == 1
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.latest_step() == 1
