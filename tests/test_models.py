import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import chunked_xent_loss, get_model, lm_logits

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_loss(arch):
    """Reduced config: one train forward on CPU, shape + finiteness."""
    cfg = get_config(arch).smoke_config()
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    aux = {k: jnp.ones(v.shape, v.dtype) for k, v in m.aux_inputs(2, 64).items()}
    hidden, _ = m.forward(params, tokens, cfg, mode="train", **aux)
    assert hidden.shape == (2, 64, cfg.d_model)
    assert not np.isnan(np.asarray(hidden, np.float32)).any()
    loss = chunked_xent_loss(params, hidden, tokens, cfg, chunk=32)
    assert np.isfinite(float(loss))
    # random init ~ uniform prediction: loss near log(vocab)
    assert float(loss) < np.log(cfg.vocab_padded) + 1.0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_runs(arch):
    cfg = get_config(arch).smoke_config()
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    state = m.init_state(cfg, 2, 32)
    tok = jnp.ones((2, 1), jnp.int32)
    h, state = m.decode_step(params, tok, state, 0, cfg)
    assert h.shape == (2, 1, cfg.d_model)
    assert not np.isnan(np.asarray(h, np.float32)).any()


@pytest.mark.parametrize("arch", ["gemma3-1b", "glm4-9b", "rwkv6-3b",
                                  "recurrentgemma-2b", "whisper-small",
                                  "mixtral-8x7b"])
def test_prefill_decode_consistency(arch):
    """Token-by-token decode with cache must match the full forward."""
    cfg = get_config(arch).smoke_config()
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    t = 8
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, t), 0, cfg.vocab)
    aux = {k: jnp.ones((1,) + v.shape[1:], v.dtype)
           for k, v in m.aux_inputs(1, t).items()}

    full_hidden, _ = m.forward(params, tokens, cfg, mode="prefill", **aux)
    full_logits = lm_logits(params, full_hidden, cfg)

    state = m.init_state(cfg, 1, t)
    if cfg.family == "whisper":  # cross-attn cache needs the encoder pass
        _, caches = m.forward(params, tokens[:, :1], cfg, mode="prefill", **aux)
        state["ck"], state["cv"] = caches["ck"], caches["cv"]
    step_logits = []
    for i in range(t):
        h, state = m.decode_step(params, tokens[:, i:i + 1], state, i, cfg)
        step_logits.append(lm_logits(params, h, cfg)[:, 0])
    step_logits = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(np.asarray(step_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=0.05, atol=0.15)


def test_streaming_attention_matches_dense():
    from repro.models.layers import streaming_attention
    import math
    rng = jax.random.PRNGKey(0)
    b, s, kv, g, dh = 2, 128, 2, 3, 16
    q = jax.random.normal(rng, (b, s, kv, g, dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (b, s, kv, dh))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (b, s, kv, dh))
    scale = 1.0 / math.sqrt(dh)
    for is_local, window in ((False, 0), (True, 17)):
        out = streaming_attention(q, k, v, jnp.asarray(is_local), window,
                                  scale, q_chunk=32, kv_chunk=16)
        # dense reference
        qi = jnp.arange(s)[:, None]
        kj = jnp.arange(s)[None, :]
        ok = kj <= qi
        if is_local and window:
            ok &= kj > qi - window
        sc = jnp.einsum("bskgd,btkd->bkgst", q, k) * scale
        sc = jnp.where(ok[None, None, None], sc, -jnp.inf)
        pr = jax.nn.softmax(sc, axis=-1)
        ref = jnp.einsum("bkgst,btkd->bskgd", pr, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-5)


def test_wkv_chunked_matches_scan():
    from repro.models.rwkv6 import wkv_chunked, wkv_scan_ref
    key = jax.random.PRNGKey(3)
    b, t, h, dh = 2, 50, 2, 8
    ks = jax.random.split(key, 5)
    r, k, v = (0.6 * jax.random.normal(ks[i], (b, t, h, dh)) for i in range(3))
    logw = jnp.maximum(-jnp.exp(jax.random.normal(ks[3], (b, t, h, dh)) - 1), -2.0)
    u = 0.2 * jax.random.normal(ks[4], (h, dh))
    s0 = jax.random.normal(ks[0], (b, h, dh, dh)) * 0.3
    y1, f1 = wkv_scan_ref(r, k, v, logw, u, state0=s0)
    y2, f2 = wkv_chunked(r, k, v, logw, u, state0=s0, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=3e-4, atol=3e-4)


def test_moe_routing_correctness():
    """MoE output == per-token sum of gated expert FFNs (naive reference)."""
    from repro.models.moe import moe_mlp
    cfg = get_config("mixtral-8x7b").smoke_config()
    d, e, f, k = cfg.d_model, cfg.n_experts, cfg.d_ff, cfg.top_k
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (1, 16, d), jnp.float32) * 0.5
    router = jax.random.normal(ks[1], (d, e), jnp.float32) * 0.2
    wg = jax.random.normal(ks[2], (e, d, f), jnp.float32) * 0.1
    wu = jax.random.normal(ks[3], (e, d, f), jnp.float32) * 0.1
    wd = jax.random.normal(ks[4], (e, f, d), jnp.float32) * 0.1
    out = moe_mlp(x, router, wg, wu, wd, cfg, n_groups=1)

    # naive reference, replicating the dispatcher's capacity-drop rule
    # (stable sort by expert, keep the first `capacity` slots per expert)
    # so the comparison is exact rather than "most rows survive"
    logits = x.reshape(-1, d) @ router
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / gates.sum(-1, keepdims=True)
    n_tok = 16
    capacity = max(int(cfg.capacity_factor * k * n_tok / e), 1)
    ef = np.asarray(idx).reshape(-1)
    order = np.argsort(ef, kind="stable")
    sorted_e = ef[order]
    start = np.searchsorted(sorted_e, np.arange(e), side="left")
    pos_within = np.arange(n_tok * k) - start[sorted_e]
    keep = np.zeros(n_tok * k, bool)
    keep[order] = pos_within < capacity
    assert keep.sum() >= n_tok * k - 4, "unexpectedly heavy capacity pressure"

    ref = np.zeros((n_tok, d), np.float32)
    xf = np.asarray(x.reshape(-1, d))
    for t in range(n_tok):
        for j in range(k):
            if not keep[t * k + j]:
                continue
            ei = int(idx[t, j])
            hdn = np.asarray(jax.nn.silu(xf[t] @ wg[ei]) * (xf[t] @ wu[ei]))
            ref[t] += float(gates[t, j]) * hdn @ np.asarray(wd[ei])
    got = np.asarray(out.reshape(-1, d))
    for t in range(n_tok):
        assert np.abs(got[t] - ref[t]).max() < 5e-3 * max(1, np.abs(ref[t]).max()), t


def test_head_padding_dead_head_invariance():
    """padded_heads() must not change the realized function: perturbing
    dead-slot params leaves the output bit-unchanged (exactness of the
    §Perf head-padding optimization)."""
    import dataclasses
    cfg = dataclasses.replace(get_config("yi-34b").smoke_config(),
                              n_heads=7, n_kv_heads=1, d_head=16)
    cfgp = cfg.padded_heads(4)
    assert cfgp.h_eff == 8 and cfgp.kv_eff == 1
    m = get_model(cfgp)
    params = m.init_params(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    h1, _ = m.forward(params, tokens, cfgp, mode="train")
    p2 = dict(params)
    p2["layers/wq"] = params["layers/wq"].at[:, :, 7, :].set(99.0)
    p2["layers/wo"] = params["layers/wo"].at[:, 7, :, :].set(-55.0)
    h2, _ = m.forward(p2, tokens, cfgp, mode="train")
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), atol=1e-5)
