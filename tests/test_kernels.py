"""Per-kernel interpret-mode validation against the pure-jnp oracles,
sweeping shapes and dtypes (assignment requirement (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.quantize.ops import dequantize, quantize
from repro.kernels.amp_fused.ops import amp_local_step


@pytest.mark.parametrize("shape", [(1, 512), (100, 1000), (256, 2048),
                                   (257, 2049), (3, 4096)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_quantize_kernel_vs_ref(shape, dtype):
    rng = np.random.default_rng(hash(shape) % 2**31)
    x = jnp.asarray((rng.normal(size=shape) * 7).astype(dtype))
    xf = x.astype(jnp.float32)
    q1, s1, orig = quantize(xf, use_pallas=True, interpret=True)
    q2, s2, _ = quantize(xf, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(s1, np.float32),
                                  np.asarray(s2, np.float32))
    x1 = dequantize(q1, s1, orig, use_pallas=True, interpret=True)
    x2 = dequantize(q2, s2, orig, use_pallas=False)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2), rtol=1e-6)
    # reconstruction error bound
    err = np.abs(np.asarray(x1) - np.asarray(xf))
    assert err.max() <= float(np.asarray(s1, np.float32).max()) * 0.5 + 1e-6


@pytest.mark.parametrize("qmax", [127, 7])
def test_quantize_kernel_qmax(qmax):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 2048)).astype(np.float32))
    q, s, orig = quantize(x, qmax=qmax, use_pallas=True, interpret=True)
    assert int(np.abs(np.asarray(q)).max()) <= qmax


@pytest.mark.parametrize("m,n", [(100, 1000), (128, 512), (130, 700),
                                 (512, 2048)])
def test_amp_fused_kernel_vs_ref(m, n):
    rng = np.random.default_rng(m * n)
    a = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32)) / np.sqrt(m)
    x = jnp.asarray(rng.normal(size=n).astype(np.float32))
    y = jnp.asarray(rng.normal(size=m).astype(np.float32))
    z = jnp.asarray(rng.normal(size=m).astype(np.float32))
    for ons in (0.0, 0.45):
        z1, f1 = amp_local_step(a, x, y, z, ons, 30, use_pallas=False)
        z2, f2 = amp_local_step(a, x, y, z, jnp.float32(ons), 30,
                                use_pallas=True, interpret=True)
        np.testing.assert_allclose(np.asarray(z1), np.asarray(z2),
                                   rtol=3e-5, atol=3e-6)
        np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                                   rtol=3e-5, atol=3e-6)


def test_amp_solver_with_kernel_matches_plain():
    """Full MP-AMP iteration built on the fused kernel == einsum solver."""
    from repro.core.amp import sample_problem
    from repro.core.denoisers import BernoulliGauss
    from repro.core.state_evolution import CSProblem
    prior = BernoulliGauss(eps=0.1)
    prob = CSProblem(n=1000, m=300, prior=prior)
    s0, a, y = sample_problem(jax.random.PRNGKey(0), prob.n, prob.m, prior,
                              prob.sigma_e2)
    # one LC step on processor 0's shard, kernel vs ref
    a0, y0 = a[:30], y[:30]
    x = jnp.asarray(np.random.default_rng(1).normal(size=prob.n).astype(np.float32)) * 0.1
    z = jnp.asarray(y0)
    z1, f1 = amp_local_step(jnp.asarray(a0), x, jnp.asarray(y0), z, 0.3, 10,
                            use_pallas=False)
    z2, f2 = amp_local_step(jnp.asarray(a0), x, jnp.asarray(y0), z,
                            jnp.float32(0.3), 10, use_pallas=True,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-4,
                               atol=1e-5)


def test_engine_pallas_path_interpret_matches_ref():
    """The engine's ``use_kernel`` path runs the fused Pallas LC kernel in
    interpret mode on CPU — a full scan-compiled solve, not just the
    per-op parity above — so kernel regressions surface in CI without TPU
    hardware (previously this path was untestable off-TPU)."""
    from repro.core.amp import sample_problem
    from repro.core.denoisers import BernoulliGauss
    from repro.core.engine import (AmpEngine, EcsqTransport, EngineConfig,
                                   FixedSchedule)
    from repro.core.state_evolution import CSProblem
    prior = BernoulliGauss(eps=0.1)
    prob = CSProblem(n=512, m=128, prior=prior)
    s0, a, y = sample_problem(jax.random.PRNGKey(5), prob.n, prob.m, prior,
                              prob.sigma_e2)
    deltas = np.full(3, np.inf, np.float32)
    mk = lambda use, interp: AmpEngine(
        prior, EngineConfig(n_proc=2, n_iter=3, use_kernel=use,
                            kernel_interpret=interp, collect_symbols=False),
        EcsqTransport(), FixedSchedule(deltas))
    ref = mk(False, False).solve(y, a)
    pal = mk(True, True).solve(y, a)
    np.testing.assert_allclose(pal.x, ref.x, atol=5e-6)
    np.testing.assert_allclose(pal.sigma2_hat, ref.sigma2_hat, rtol=1e-5)


def test_serving_pallas_path_interpret_matches_ref():
    """The serving het-batch path (vmapped scan over the Pallas kernel,
    interpret mode) matches the jnp reference for a mixed-shape batch."""
    from repro.core.amp import sample_problem
    from repro.core.denoisers import BernoulliGauss
    from repro.core.state_evolution import CSProblem
    from repro.serving import BucketPolicy, SolveRequest, SolveService
    prior = BernoulliGauss(eps=0.1)
    reqs = []
    for i, (n, m) in enumerate([(256, 64), (200, 64)]):
        prob = CSProblem(n=n, m=m, prior=prior)
        _, a, y = sample_problem(jax.random.PRNGKey(i), n, m, prior,
                                 prob.sigma_e2)
        reqs.append(SolveRequest(y=y, a=a, prior=prior, n_proc=2, n_iter=3,
                                 policy="lossless"))
    pol = BucketPolicy(max_batch=2, n_quantum=256, mp_quantum=32)
    ref = SolveService(policy=pol, rate_accounting=False,
                       use_kernel=False).solve(reqs)
    pal = SolveService(policy=pol, rate_accounting=False, use_kernel=True,
                       kernel_interpret=True).solve(reqs)
    for r, p in zip(ref, pal):
        np.testing.assert_allclose(p.x, r.x, atol=5e-6)


@pytest.mark.parametrize("p,mp,n", [(1, 128, 512), (4, 64, 500),
                                    (3, 150, 1000)])
@pytest.mark.parametrize("a_dtype", ["float32", "bfloat16"])
def test_amp_local_grid_matches_ref(p, mp, n, a_dtype):
    """Batched-grid kernel (P folded into the grid, sigma2_hat numerator
    fused into the z-pass) == the batched reference, for f32 and bf16
    A-streaming (both sides stream the same bf16 A; accumulation f32)."""
    import jax.numpy as jnp
    from repro.kernels.amp_fused.ops import amp_local_grid, pad_row_shards
    rng = np.random.default_rng(p * mp * n)
    a = jnp.asarray((rng.normal(size=(p, mp, n)) / np.sqrt(p * mp))
                    .astype(np.float32)).astype(a_dtype)
    x = jnp.asarray(rng.normal(size=n).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(p, mp)).astype(np.float32))
    z = jnp.asarray(rng.normal(size=(p, mp)).astype(np.float32))
    ap, yp = pad_row_shards(a, y)
    zp = jnp.pad(z, ((0, 0), (0, ap.shape[1] - mp)))
    xp_ = jnp.pad(x, (0, ap.shape[2] - n))
    z1, f1, ss1 = amp_local_grid(ap, xp_, yp, zp, 0.37, 10,
                                 use_pallas=True, interpret=True)
    z0, f0, ss0 = amp_local_grid(a, x, y, z, 0.37, 10, use_pallas=False)
    np.testing.assert_allclose(np.asarray(z1)[:, :mp], np.asarray(z0),
                               rtol=3e-5, atol=3e-6)
    np.testing.assert_allclose(np.asarray(f1)[:, :n], np.asarray(f0),
                               rtol=3e-5, atol=3e-5)
    # padded rows/cols are exactly zero, so the fused ss is the true sum
    assert np.all(np.asarray(z1)[:, mp:] == 0.0)
    np.testing.assert_allclose(float(ss1), float(ss0), rtol=1e-5)


def _walk_eqns(jaxpr):
    """All eqns of a jaxpr, recursing into sub-jaxprs (scan/pjit/...)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", None)
            if sub is not None:
                yield from _walk_eqns(sub)
            elif isinstance(v, (list, tuple)):
                for vv in v:
                    sub = getattr(vv, "jaxpr", None)
                    if sub is not None:
                        yield from _walk_eqns(sub)


def test_no_matrix_pad_inside_scan_body():
    """ISSUE 5 satellite: tile-alignment of the (M, N) operand happens
    once at solve entry, never per iteration — the scanned body's jaxpr
    contains no rank>=2 ``pad`` (only the cheap (N,) message-vector pad
    is allowed inside the scan)."""
    from repro.core.denoisers import BernoulliGauss
    from repro.core.engine import AmpEngine, EngineConfig

    prior = BernoulliGauss(eps=0.1)
    eng = AmpEngine(prior, EngineConfig(n_proc=2, n_iter=3, use_kernel=True,
                                        kernel_interpret=True,
                                        collect_symbols=False))
    m, n = 300, 1000                      # forces tile padding (150 -> 256)
    a = np.zeros((m, n), np.float32)
    y = np.zeros(m, np.float32)
    a_p, y_p = eng._split(y, a)
    assert a_p.shape != (2, 150, 1000), "test expects a padded shard stack"
    jaxpr = jax.make_jaxpr(
        lambda ap, yp, sched: eng._scan_fn(m, n)(ap, yp, sched))(
            a_p, y_p, eng._sched_operand())
    scans = [e for e in _walk_eqns(jaxpr.jaxpr) if e.primitive.name == "scan"]
    assert scans, "solve should be scan-compiled"
    for scan in scans:
        for eqn in _walk_eqns(scan.params["jaxpr"].jaxpr):
            if eqn.primitive.name == "pad":
                assert eqn.outvars[0].aval.ndim < 2, (
                    f"matrix-sized pad inside the scanned body: "
                    f"{eqn.outvars[0].aval}")


def test_engine_bf16_kernel_interpret_matches_bf16_ref():
    """bf16 A-streaming through the Pallas path (interpret) == bf16
    through the reference path: the dtype is a storage/streaming choice,
    not a kernel-specific numeric."""
    from repro.core.amp import sample_problem
    from repro.core.denoisers import BernoulliGauss
    from repro.core.engine import AmpEngine, EngineConfig
    from repro.core.state_evolution import CSProblem
    prior = BernoulliGauss(eps=0.1)
    prob = CSProblem(n=512, m=128, prior=prior)
    s0, a, y = sample_problem(jax.random.PRNGKey(11), prob.n, prob.m, prior,
                              prob.sigma_e2)
    mk = lambda use, interp: AmpEngine(
        prior, EngineConfig(n_proc=2, n_iter=4, use_kernel=use,
                            kernel_interpret=interp, collect_symbols=False,
                            a_dtype="bfloat16"))
    ref = mk(False, False).solve(y, a)
    pal = mk(True, True).solve(y, a)
    assert float(np.mean((pal.x - ref.x) ** 2)) <= 1e-10
    np.testing.assert_allclose(pal.sigma2_hat, ref.sigma2_hat, rtol=1e-4)


@pytest.mark.parametrize("b,h,kv,dh,s,pos,win",
                         [(2, 8, 2, 64, 1024, 700, 0),
                          (1, 4, 4, 32, 512, 511, 0),
                          (2, 6, 2, 64, 1000, 600, 128)])
def test_decode_attn_kernel_vs_ref(b, h, kv, dh, s, pos, win):
    from repro.kernels.decode_attn.ops import decode_attention
    rng = np.random.default_rng(b * s)
    q = jnp.asarray(rng.normal(size=(b, h, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kv, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kv, dh)).astype(np.float32))
    o1 = decode_attention(q, k, v, pos, win, use_pallas=False)
    o2 = decode_attention(q, k, v, jnp.int32(pos), win, use_pallas=True,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,t,h,dh", [(2, 64, 3, 16), (1, 100, 2, 64),
                                      (2, 32, 4, 8)])
def test_wkv6_kernel_vs_scan(b, t, h, dh):
    from repro.kernels.wkv6.ops import wkv6
    from repro.models.rwkv6 import wkv_scan_ref
    key = jax.random.PRNGKey(b * t)
    ks = jax.random.split(key, 5)
    r, k, v = (0.5 * jax.random.normal(ks[i], (b, t, h, dh)) for i in range(3))
    logw = jnp.maximum(-jnp.exp(jax.random.normal(ks[3], (b, t, h, dh)) * 0.5
                                - 1.0), -2.0)
    u = 0.3 * jax.random.normal(ks[4], (h, dh))
    y_ref, _ = wkv_scan_ref(r, k, v, logw, u)
    y_pal = wkv6(r, k, v, logw, u, use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               rtol=3e-4, atol=3e-4)
