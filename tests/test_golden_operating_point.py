"""Golden operating-point regression (ISSUE 2): DP vs BT total coding rate
and final SDR at the paper's Sec. 4 settings (kappa = 0.3, 20 dB SNR,
eps = 0.05, P = 30, T = PAPER_T[0.05] = 10), pinned in a committed JSON.

The point of the pin: the BT/DP controllers, the ECSQ rate model, the RD
table, and the scan-compiled engine all feed these four numbers; >2% drift
in any of them means a behavioral change in the paper reproduction, not
noise (the simulation is fully seeded and the table builds are
deterministic).

Regenerate after an *intentional* change with:
    REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_golden_operating_point.py -m tier2
N is scaled to 4000 (vs the paper's 10000) to keep tier-2 runtime sane;
kappa, SNR, eps, P, T are the paper's.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.core.amp import sample_problem
from repro.core.denoisers import BernoulliGauss, make_mmse_interp
from repro.core.engine import DPSchedule
from repro.core.mp_amp import MPAMPConfig, mp_amp_solve
from repro.core.rate_alloc import BTController, dp_allocate
from repro.core.rate_distortion import RDModel
from repro.core.state_evolution import CSProblem

pytestmark = pytest.mark.tier2

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "operating_point.json")
N, M, P, T = 4000, 1200, 30, 10   # kappa = 0.3 (paper Sec. 4), T = PAPER_T
EPS, SNR_DB = 0.05, 20.0
RTOL = 0.02                       # fail on >2% drift


def _sdr_db(prior, mse: float) -> float:
    return float(10.0 * np.log10(prior.second_moment / max(mse, 1e-30)))


@pytest.fixture(scope="module")
def operating_point():
    prior = BernoulliGauss(eps=EPS)
    prob = CSProblem(n=N, m=M, prior=prior, snr_db=SNR_DB)
    mm = make_mmse_interp(prior)
    rd = RDModel(prior)  # table ships in .cache
    s0, a, y = sample_problem(jax.random.PRNGKey(42), N, M, prior,
                              prob.sigma_e2)

    bt = BTController(prob, P, T, c_ratio=1.005, r_max=6.0,
                      rate_model="ecsq", mmse_fn=mm)
    bt_sim = mp_amp_solve(y, a, prior, MPAMPConfig(P, T), bt, s0=s0)

    dp = dp_allocate(prob, P, T, 2.0 * T, rd=rd, mmse_fn=mm)
    dp_sched = DPSchedule(dp, rd, P)
    dp_sim = mp_amp_solve(y, a, prior, MPAMPConfig(P, T), dp_sched.deltas,
                          s0=s0, sigma2_for_model=dp.sigma2_d[:-1])

    return {
        "bt_total_bits": bt_sim.total_bits_analytic,
        "bt_final_sdr_db": _sdr_db(prior, float(bt_sim.mse[-1])),
        "dp_total_bits": dp_sim.total_bits_analytic,
        "dp_final_sdr_db": _sdr_db(prior, float(dp_sim.mse[-1])),
        "dp_rd_budget_bits": float(np.sum(dp.rates)),
    }


def test_golden_operating_point(operating_point):
    if os.environ.get("REGEN_GOLDEN"):
        os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
        with open(GOLDEN_PATH, "w") as fh:
            json.dump(operating_point, fh, indent=2, sort_keys=True)
            fh.write("\n")
        pytest.skip(f"regenerated {GOLDEN_PATH}")
    assert os.path.exists(GOLDEN_PATH), \
        "golden file missing; run with REGEN_GOLDEN=1"
    with open(GOLDEN_PATH) as fh:
        golden = json.load(fh)
    assert set(golden) == set(operating_point)
    for key, want in golden.items():
        got = operating_point[key]
        assert abs(got - want) <= RTOL * abs(want), \
            f"{key}: got {got:.4f}, golden {want:.4f} (>2% drift)"


def test_dp_beats_bt_at_equal_quality_claim(operating_point):
    """The paper's headline comparison at this operating point: DP spends
    less total rate than BT while landing within ~1.5 dB of its SDR."""
    op = operating_point
    assert op["dp_total_bits"] < op["bt_total_bits"]
    assert abs(op["dp_final_sdr_db"] - op["bt_final_sdr_db"]) < 1.5
