import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import SyntheticLMData
from repro.launch.mesh import make_host_mesh
from repro.sharding import logical_spec, make_rules, use_sharding


def test_data_deterministic_and_slice_consistent():
    d = SyntheticLMData(vocab=1000, seq_len=16, global_batch=8, seed=3)
    full = d.batch_np(step=7)
    again = d.batch_np(step=7)
    np.testing.assert_array_equal(full, again)
    # arbitrary row slices match the full batch (device-local materialization)
    part = d.batch_np(step=7, lo=2, hi=5)
    np.testing.assert_array_equal(part, full[2:5])
    # different steps differ
    assert not np.array_equal(full, d.batch_np(step=8))


def test_data_sharded_arrays():
    d = SyntheticLMData(vocab=1000, seq_len=16, global_batch=8, seed=0)
    mesh = make_host_mesh(model=1)
    cfg = get_config("granite-3-8b").smoke_config()
    with use_sharding(mesh, make_rules(cfg, mesh, "train")):
        tok, lab = d.global_arrays(0, mesh)
    ref = d.batch_np(0)
    np.testing.assert_array_equal(np.asarray(tok), ref[:, :-1])
    np.testing.assert_array_equal(np.asarray(lab), ref[:, 1:])


def test_rules_divisibility_fallbacks(multidev):
    multidev("""
import jax
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh
from repro.configs import get_config
from repro.sharding import make_rules, logical_spec, use_sharding
mesh = make_mesh((2, 4), ('data', 'model'))

# yi-34b: 56 heads %% 4 == 0 -> heads sharded on a 4-way model axis
cfg = get_config('yi-34b')
r = make_rules(cfg, mesh, 'train')
assert r['heads'] == 'model', r['heads']

# gemma3-1b: 4 heads -> heads sharded; but on 16-way it would fall back
cfg2 = get_config('gemma3-1b')
r2 = make_rules(cfg2, mesh, 'train')
assert r2['heads'] == 'model'

# shape-aware drop: 6 not divisible by 4 -> axis dropped
with use_sharding(mesh, r):
    spec = logical_spec(('batch', 'heads'), (6, 56))
    assert spec == P('data', 'model') or spec[1] == 'model'
    spec2 = logical_spec(('batch', 'heads'), (6, 54))   # 54 %% 4 != 0
    assert spec2[1] is None
    # conflict: same mesh axis used twice -> second use dropped
    spec3 = logical_spec(('heads', 'kv_heads'), (56, 8))
    assert spec3[0] == 'model' and spec3[1] is None
print('ok')
""")


def test_decode_rules_long_context(multidev):
    multidev("""
import jax
from repro.compat import make_mesh
from repro.configs import get_config
from repro.sharding import make_rules
mesh = make_mesh((2, 4), ('data', 'model'))
cfg = get_config('gemma3-1b')
# batch=1 long-context decode: kv_seq takes data + model
r = make_rules(cfg, mesh, 'decode', decode_batch=1)
assert r['batch'] is None
assert r['kv_seq'] == ('data', 'model'), r['kv_seq']
# batched decode: batch -> data, kv_seq -> model
r2 = make_rules(cfg, mesh, 'decode', decode_batch=8)
assert r2['kv_seq'] == 'model'
print('ok')
""")


def test_hlo_analysis_trip_counts(multidev):
    """Analyzer flops == analytic for a scanned matmul (trip multiplication)."""
    multidev("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import make_mesh
from repro.launch.hlo_analysis import analyze_hlo
mesh = make_mesh((2, 4), ('data', 'model'))
L, B, D = 7, 32, 64
def f(w, x):
    def body(c, wi):
        return jnp.tanh(c @ wi), ()
    out, _ = jax.lax.scan(body, x, w)
    return out.sum()
ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
comp = jax.jit(f, in_shardings=(NamedSharding(mesh, P()),
                                NamedSharding(mesh, P('data', None)))).lower(ws, xs).compile()
stats = analyze_hlo(comp.as_text())
# per-device: B/2 rows x D x D x 2 flops x L trips
expect = (B // 2) * D * D * 2 * L
assert abs(stats.dot_flops - expect) / expect < 0.01, (stats.dot_flops, expect)
assert L in stats.while_trips or any(abs(t - L) <= 1 for t in stats.while_trips)
print('ok')
""", 8)
