import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.entropy_code import RansCodec


@settings(max_examples=15, deadline=None)
@given(n_sym=st.integers(2, 40), n=st.integers(100, 5000),
       seed=st.integers(0, 2**31 - 1), conc=st.floats(0.1, 5.0))
def test_rans_roundtrip_and_rate(n_sym, n, seed, conc):
    rng = np.random.default_rng(seed)
    p = rng.dirichlet(np.full(n_sym, conc))
    syms = rng.choice(n_sym, size=n, p=p)
    codec = RansCodec(np.bincount(syms, minlength=n_sym))
    enc = codec.encode(syms)
    dec = codec.decode(enc, n)
    np.testing.assert_array_equal(dec, syms)
    # rate within a few % of the empirical entropy + small constant
    counts = np.bincount(syms, minlength=n_sym)
    q = counts[counts > 0] / n
    h_emp = float(-(q * np.log2(q)).sum())
    bits = 8 * len(enc)
    assert bits <= h_emp * n * 1.02 + 96, (bits, h_emp * n)


def test_rans_matches_ecsq_entropy_on_amp_messages():
    """End-to-end: quantized AMP fusion messages entropy-code at ~H_Q
    (the paper's 'achievable through entropy coding' claim, demonstrated)."""
    import math
    from repro.core.denoisers import BernoulliGauss
    from repro.core.quantize import (ecsq_entropy, message_mixture,
                                     quantize_midtread)
    rng = np.random.default_rng(1)
    prior = BernoulliGauss(eps=0.1)
    mix = message_mixture(prior, sigma_t2=0.05, n_proc=30)
    comp = rng.random(60_000) < mix.w[0]
    f = np.where(comp, rng.normal(mix.mu[0], math.sqrt(mix.var[0]), 60_000),
                 rng.normal(mix.mu[1], math.sqrt(mix.var[1]), 60_000))
    delta = math.sqrt(mix.variance) / 4
    q = quantize_midtread(f, delta, xp=np).astype(np.int64)
    h_model = ecsq_entropy(delta, mix)[0]
    offset = q.min()
    codec = RansCodec(np.bincount(q - offset))
    bits_per_sym = codec.encoded_bits(q - offset) / len(q)
    assert abs(bits_per_sym - h_model) < 0.05 * h_model + 0.02


def test_oversized_alphabet_raises():
    """Regression: >4096 distinct symbols used to spin forever inside the
    frequency-quantization rebalance loop; it must fail fast instead."""
    with pytest.raises(ValueError, match="exceeds the rANS frequency"):
        RansCodec(np.ones(5000))
    # the largest admissible alphabet still round-trips
    n = 4096
    codec = RansCodec(np.ones(n))
    syms = np.arange(n) % n
    np.testing.assert_array_equal(codec.decode(codec.encode(syms), n), syms)


def test_single_symbol_alphabet_roundtrip():
    """A degenerate one-symbol model (zero entropy) encodes to ~nothing
    and still round-trips."""
    codec = RansCodec(np.asarray([123]))
    syms = np.zeros(500, np.int64)
    enc = codec.encode(syms)
    np.testing.assert_array_equal(codec.decode(enc, 500), syms)
    assert len(enc) <= 16, len(enc)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(64, 2000), seed=st.integers(0, 2**31 - 1),
       skew=st.floats(4.0, 12.0))
def test_rans_roundtrip_highly_skewed(n, seed, skew):
    """Near-deterministic streams (one symbol carries ~all the mass) stress
    the 1-count clamping in the quantized frequency table."""
    rng = np.random.default_rng(seed)
    p = np.asarray([1.0 - 2.0**-skew, 2.0**-skew / 2, 2.0**-skew / 2])
    syms = rng.choice(3, size=n, p=p)
    codec = RansCodec(np.bincount(syms, minlength=3) + 1)
    enc = codec.encode(syms)
    np.testing.assert_array_equal(codec.decode(enc, n), syms)
