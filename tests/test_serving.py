"""Solve-service tests: bucketing, continuous batching, and the ISSUE 2
acceptance criterion — a heterogeneous batch (different SNR, eps, P, and
fixed/DP/BT policies per request) matches single-request solves."""
import jax
import numpy as np
import pytest

from repro.core.amp import sample_problem
from repro.core.denoisers import BernoulliGauss
from repro.core.engine import (AmpEngine, BTRateControl, DPSchedule,
                               EcsqTransport, EngineConfig, FixedSchedule)
from repro.core.rate_alloc import dp_allocate, stack_schedules
from repro.core.rate_distortion import RDModel
from repro.core.state_evolution import CSProblem
from repro.serving import (Batcher, BucketPolicy, SolveRequest, SolveService,
                           bucket_for, pad_batch_size, placement_for)


# ---------------------------------------------------------------------------
# bucketing / batching units
# ---------------------------------------------------------------------------

def test_bucket_rounding():
    pol = BucketPolicy(n_quantum=256, mp_quantum=16, t_quantum=4)
    k = bucket_for(600, 180, 5, 6, "ecsq", pol)
    assert (k.n_pad, k.mp_pad, k.n_proc, k.t_max) == (768, 48, 5, 8)
    assert k.m_pad == 240
    # exact multiples stay unpadded
    k2 = bucket_for(512, 160, 5, 8, "ecsq", pol)
    assert (k2.n_pad, k2.mp_pad, k2.t_max) == (512, 32, 8)
    # P and transport are structural: distinct buckets
    assert bucket_for(512, 160, 5, 8, "ecsq", pol) != \
        bucket_for(512, 160, 10, 8, "ecsq", pol)
    assert bucket_for(512, 160, 5, 8, "block8", pol) != k2
    with pytest.raises(AssertionError):
        bucket_for(512, 161, 5, 8, "ecsq", pol)  # M not divisible by P


def test_pad_batch_size():
    pol = BucketPolicy(max_batch=128)
    assert [pad_batch_size(b, pol) for b in (1, 2, 3, 8, 9, 128)] == \
        [1, 2, 4, 8, 16, 128]


def test_placement_selection():
    """Size-threshold placement (DESIGN.md §6): local off-mesh, data for
    small requests, proc for large ones whose P splits over the devices;
    aspect-ratio layout routing (DESIGN.md §7) rides along."""
    pol = BucketPolicy(shard_elems=1 << 20)
    assert placement_for(512, 160, 4, 1, pol) == ("local", "row")
    assert placement_for(512, 160, 4, 8, pol) == ("data", "row")
    assert placement_for(4096, 1280, 8, 8, pol) == ("proc", "row")
    # P not divisible by the device count: falls back to data-parallel
    assert placement_for(4096, 1280, 6, 8, pol) == ("data", "row")
    # placement is part of the compile-cache key
    k_d = bucket_for(512, 128, 4, 8, "ecsq", pol, "data")
    k_l = bucket_for(512, 128, 4, 8, "ecsq", pol, "local")
    assert k_d != k_l and k_d.placement == "data"
    # default stays "local" so single-device keys are unchanged
    assert bucket_for(512, 128, 4, 8, "ecsq", pol).placement == "local"


def test_placement_routes_tall_n_to_column():
    """Acceptance (ISSUE 4): a tall-N request (N/M >= col_aspect, N*M >=
    shard_elems) routes to the column layout — processor-sharded on a
    mesh, column-partitioned locally off-mesh."""
    pol = BucketPolicy(shard_elems=1 << 20)
    assert pol.col_aspect == 4.0
    # N/M = 8 >= 4 and N*M = 2^21 >= shard_elems: proc placement, col layout
    assert placement_for(4096, 512, 8, 8, pol) == ("proc", "col")
    assert placement_for(4096, 512, 8, 1, pol) == ("local", "col")
    # small tall requests batch data-parallel but stay column-partitioned
    assert placement_for(1024, 128, 4, 8, pol) == ("data", "col")
    # N not divisible by P: the column layout cannot slice evenly -> row
    assert placement_for(4098, 512, 8, 1, pol)[1] == "row"
    # just under the aspect threshold -> row
    assert placement_for(2044, 512, 4, 1, pol)[1] == "row"
    # layout is part of the compile-cache key; column m_pad is the padded
    # full M (rows are shared, not split) and n_pad pads per-slice
    k_c = bucket_for(4096, 500, 8, 8, "ecsq", pol, "local", "col")
    assert k_c.layout == "col"
    assert k_c.m_pad == 512 and k_c.mp_pad == 512   # round_up(500, 256)
    assert k_c.n_pad == 4096                        # slices already padded
    k_r = bucket_for(4096, 512, 8, 8, "ecsq", pol, "local", "row")
    assert k_c != k_r and k_r.m_pad == 512


def test_batcher_dispatch_and_drain():
    pol = BucketPolicy(max_batch=4)
    b = Batcher(pol)
    k1 = bucket_for(512, 160, 5, 8, "ecsq", pol)
    k2 = bucket_for(256, 80, 5, 8, "ecsq", pol)
    # group dispatches exactly at max_batch
    for i in range(3):
        assert b.add(k1, f"a{i}") is None
    assert b.add(k2, "b0") is None
    key, group = b.add(k1, "a3")
    assert key == k1 and group == ["a0", "a1", "a2", "a3"]
    assert len(b) == 1
    rest = list(b.drain())
    assert rest == [(k2, ["b0"])] and len(b) == 0


def test_stack_schedules_padding():
    out = stack_schedules([np.array([0.1, 0.2]), np.array([0.3])], 4)
    assert out.shape == (2, 4)
    assert np.allclose(out[0, :2], [0.1, 0.2]) and np.isinf(out[0, 2:]).all()
    assert out[1, 0] == np.float32(0.3) and np.isinf(out[1, 1:]).all()


# ---------------------------------------------------------------------------
# heterogeneous batch correctness (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mixed_ctx():
    """Five requests spanning eps, SNR, P, shapes, T, and all policies,
    with the single-request reference solve for each."""
    specs = [
        # (eps, snr_db, n, m, p, t, policy)
        (0.10, 20.0, 600, 180, 5, 6, "fixed"),
        (0.05, 20.0, 768, 240, 5, 8, "lossless"),
        (0.10, 15.0, 500, 150, 5, 5, "bt"),
        (0.10, 20.0, 600, 180, 5, 6, "dp"),
        (0.05, 20.0, 512, 160, 4, 8, "fixed"),   # aspect 3.2: stays row
    ]
    reqs, refs = [], []
    for i, (eps, snr, n, m, p, t, policy) in enumerate(specs):
        prior = BernoulliGauss(eps=eps)
        prob = CSProblem(n=n, m=m, prior=prior, snr_db=snr)
        s0, a, y = sample_problem(jax.random.PRNGKey(i), n, m, prior,
                                  prob.sigma_e2)
        kw = {}
        if policy == "fixed":
            deltas = np.full(t, 0.05, np.float32)
            deltas[0] = np.inf
            kw["deltas"] = deltas
            ctrl = FixedSchedule(deltas)
        elif policy == "lossless":
            ctrl = FixedSchedule(np.full(t, np.inf, np.float32))
        elif policy == "dp":
            # the RD table for this prior ships in .cache (repo-committed)
            rd = RDModel(prior)
            dp = dp_allocate(prob, p, t, 2.0 * t, rd=rd)
            sched = DPSchedule(dp, rd, p)
            kw["deltas"] = sched.deltas
            ctrl = sched
        else:  # bt — service builds identical tables (same ctor args)
            ctrl = BTRateControl(prob, p, t, 1.005, 6.0, "ecsq")
        reqs.append(SolveRequest(y=y, a=a, prior=prior, snr_db=snr,
                                 n_proc=p, n_iter=t, policy=policy, **kw))
        eng = AmpEngine(prior,
                        EngineConfig(n_proc=p, n_iter=t,
                                     collect_symbols=False),
                        EcsqTransport(), ctrl)
        refs.append((eng.solve(y, a), s0))
    return specs, reqs, refs


def test_heterogeneous_batch_matches_single(mixed_ctx):
    """Acceptance: mixed (SNR, eps, P, policy) batch == single solves to
    <= 1e-5 MSE difference."""
    specs, reqs, refs = mixed_ctx
    svc = SolveService(policy=BucketPolicy(max_batch=8))
    results = svc.solve(reqs)
    assert [r.request_id for r in results] == list(range(len(reqs)))
    for (res, (ref, s0), spec) in zip(results, refs, specs):
        mse_diff = float(np.mean((res.x - ref.x) ** 2))
        assert mse_diff <= 1e-5, (spec, mse_diff)
        # trace agreement on the request's own iteration range
        np.testing.assert_allclose(res.sigma2_hat, ref.sigma2_hat,
                                   rtol=1e-4)
        np.testing.assert_allclose(res.deltas, ref.deltas, rtol=1e-4)


def test_bt_rate_accounting_matches_controller(mixed_ctx):
    """The BT request's realized rates equal the in-graph controller's
    decisions from the reference solve."""
    specs, reqs, refs = mixed_ctx
    svc = SolveService(policy=BucketPolicy(max_batch=8))
    results = svc.solve(reqs)
    i_bt = next(i for i, s in enumerate(specs) if s[-1] == "bt")
    ref, _ = refs[i_bt]
    np.testing.assert_allclose(results[i_bt].rates, ref.rates, atol=5e-3)
    assert np.isfinite(results[i_bt].total_bits)
    # lossless requests report zero tracked bits
    i_ll = next(i for i, s in enumerate(specs) if s[-1] == "lossless")
    assert results[i_ll].total_bits == 0.0
    assert np.isinf(results[i_ll].rates).all()


def test_masked_early_exit_is_exact():
    """A short-T request inside a long-T bucket returns exactly its own
    T-iteration solve (the masked scan freezes, not truncates).

    The 512/128 shape sits exactly at the aspect threshold, so this rides
    the *column* bucket — and pins it against a row-layout reference
    (both are exactly centralized AMP under lossless fusion)."""
    prior = BernoulliGauss(eps=0.1)
    prob = CSProblem(n=512, m=128, prior=prior)
    s0, a, y = sample_problem(jax.random.PRNGKey(9), prob.n, prob.m, prior,
                              prob.sigma_e2)
    svc = SolveService(policy=BucketPolicy(max_batch=4, t_quantum=8))
    short = SolveRequest(y=y, a=a, prior=prior, n_proc=4, n_iter=3,
                         policy="lossless")
    long_ = SolveRequest(y=y, a=a, prior=prior, n_proc=4, n_iter=8,
                         policy="lossless")
    res_short, res_long = svc.solve([short, long_])
    # both in one bucket (t_max=8), short frozen after 3 iterations
    assert res_short.bucket == res_long.bucket
    eng = AmpEngine(prior, EngineConfig(n_proc=4, n_iter=3,
                                        collect_symbols=False),
                    EcsqTransport(),
                    FixedSchedule(np.full(3, np.inf)))
    ref3 = eng.solve(y, a)
    assert float(np.mean((res_short.x - ref3.x) ** 2)) <= 1e-10
    assert res_short.sigma2_hat.shape == (3,)
    # and the long one kept iterating (strictly better fit)
    assert float(np.mean((res_long.x - s0) ** 2)) < \
        float(np.mean((res_short.x - s0) ** 2))


def test_block_transport_bucket_matches_single():
    """block8 transport: separate bucket, matches the single-request
    BlockQuantTransport solve, and reports the fixed wire rate."""
    from repro.core.engine import BlockQuantTransport
    prior = BernoulliGauss(eps=0.1)
    prob = CSProblem(n=600, m=180, prior=prior)
    s0, a, y = sample_problem(jax.random.PRNGKey(3), prob.n, prob.m, prior,
                              prob.sigma_e2)
    svc = SolveService(policy=BucketPolicy(max_batch=4))
    res, = svc.solve([SolveRequest(y=y, a=a, prior=prior, n_proc=5,
                                   n_iter=6, policy="lossless",
                                   transport="block8")])
    eng = AmpEngine(prior, EngineConfig(n_proc=5, n_iter=6,
                                        collect_symbols=False),
                    BlockQuantTransport(bits=8, block=512),
                    FixedSchedule(np.full(6, np.inf)))
    ref = eng.solve(y, a)
    assert float(np.mean((res.x - ref.x) ** 2)) <= 1e-5
    np.testing.assert_allclose(res.rates, 8.0 + 16.0 / 512)
    assert res.bucket.transport == "block8"
    # rate policies are meaningless under a fixed-width wire: rejected
    with pytest.raises(AssertionError, match="no effect under"):
        svc.solve([SolveRequest(y=y, a=a, prior=prior, n_proc=5, n_iter=6,
                                policy="bt", transport="block8")])


def test_resubmitting_same_request_object():
    """Template reuse: the same SolveRequest object submitted twice yields
    two distinct results (no id aliasing)."""
    prior = BernoulliGauss(eps=0.1)
    prob = CSProblem(n=256, m=64, prior=prior)
    _, a, y = sample_problem(jax.random.PRNGKey(4), prob.n, prob.m, prior,
                             prob.sigma_e2)
    svc = SolveService(policy=BucketPolicy(max_batch=4),
                       rate_accounting=False)
    req = SolveRequest(y=y, a=a, prior=prior, n_proc=4, n_iter=4,
                       policy="lossless")
    r1, r2 = svc.solve([req, req])
    assert r1.request_id != r2.request_id
    np.testing.assert_allclose(r1.x, r2.x)


def test_stream_continuous_batching():
    """stream() dispatches full groups eagerly and flushes stragglers."""
    prior = BernoulliGauss(eps=0.1)
    prob = CSProblem(n=256, m=64, prior=prior)
    insts = [sample_problem(jax.random.PRNGKey(i), prob.n, prob.m, prior,
                            prob.sigma_e2) for i in range(5)]
    svc = SolveService(policy=BucketPolicy(max_batch=2),
                       rate_accounting=False)
    reqs = [SolveRequest(y=i[2], a=i[1], prior=prior, n_proc=4, n_iter=4,
                         policy="lossless") for i in insts]

    pulled = []

    def feed():
        for i, r in enumerate(reqs):
            pulled.append(i)
            yield r

    # (request_id, requests submitted so far, executed batch width)
    events = [(res.request_id, len(pulled), res.batch_size)
              for res in svc.stream(feed())]
    # ids 0,1 dispatched as a full width-2 group the moment the group
    # filled — before request 2 was even pulled from the input
    assert events[0] == (0, 2, 2) and events[1] == (1, 2, 2)
    assert events[2] == (2, 4, 2) and events[3] == (3, 4, 2)
    # the straggler flushes at end of input as a width-1 batch
    assert events[4] == (4, 5, 1)


def test_solve_preserves_foreign_buffered_results():
    """solve() must not swallow results of earlier submit() calls that its
    flush happens to complete."""
    prior = BernoulliGauss(eps=0.1)
    prob = CSProblem(n=256, m=64, prior=prior)
    insts = [sample_problem(jax.random.PRNGKey(i), prob.n, prob.m, prior,
                            prob.sigma_e2) for i in range(2)]
    svc = SolveService(policy=BucketPolicy(max_batch=8),
                       rate_accounting=False)
    early_id = svc.submit(SolveRequest(y=insts[0][2], a=insts[0][1],
                                       prior=prior, n_proc=4, n_iter=4,
                                       policy="lossless"))
    out = svc.solve([SolveRequest(y=insts[1][2], a=insts[1][1], prior=prior,
                                  n_proc=4, n_iter=4, policy="lossless")])
    assert [r.request_id for r in out] == [early_id + 1]
    later = svc.flush()
    assert [r.request_id for r in later] == [early_id]

    # stream() honors the same contract
    early2 = svc.submit(SolveRequest(y=insts[0][2], a=insts[0][1],
                                     prior=prior, n_proc=4, n_iter=4,
                                     policy="lossless"))
    streamed = list(svc.stream([SolveRequest(y=insts[1][2], a=insts[1][1],
                                             prior=prior, n_proc=4,
                                             n_iter=4, policy="lossless")]))
    assert [r.request_id for r in streamed] == [early2 + 1]
    assert [r.request_id for r in svc.flush()] == [early2]


# ---------------------------------------------------------------------------
# demand windows (DESIGN.md §11: the autoscaler's scrape signal)
# ---------------------------------------------------------------------------

def test_batcher_demand_windows_partition_the_stream():
    pol = BucketPolicy(max_batch=4)
    b = Batcher(pol)
    k1 = bucket_for(512, 160, 5, 8, "ecsq", pol)
    k2 = bucket_for(256, 80, 5, 8, "ecsq", pol)
    for i in range(5):
        b.add(k1, i)
    b.add(k2, "x")
    # lifetime counts survive dispatch/drain (5 admissions dispatched one
    # full group already)
    assert b.demand() == {k1: 5, k2: 1}
    # first take returns everything, second only the delta, zero-delta
    # buckets are omitted
    assert b.take_demand() == {k1: 5, k2: 1}
    assert b.take_demand() == {}
    b.add(k1, 5)
    assert b.take_demand() == {k1: 1}
    # successive windows partition the stream: sum == lifetime
    assert b.demand() == {k1: 6, k2: 1}


def test_batcher_clear_demand_semantics():
    pol = BucketPolicy(max_batch=4)
    b = Batcher(pol)
    k = bucket_for(512, 160, 5, 8, "ecsq", pol)
    for i in range(3):
        b.add(k, i)
    # mark-only clear: window restarts, history stays
    b.clear_demand()
    assert b.take_demand() == {}
    assert b.demand() == {k: 3}
    b.add(k, 3)
    assert b.take_demand() == {k: 1}
    # lifetime clear: both restart
    b.clear_demand(lifetime=True)
    assert b.demand() == {} and b.take_demand() == {}
    b.add(k, 4)
    assert b.demand() == {k: 1} and b.take_demand() == {k: 1}


def test_batcher_demand_concurrent_admission():
    """Admissions racing a scrape thread: every request lands in exactly
    one take window (no double- or under-counting across takes)."""
    import threading

    pol = BucketPolicy(max_batch=1 << 30)   # no dispatch, pure counting
    b = Batcher(pol)
    k = bucket_for(512, 160, 5, 8, "ecsq", pol)
    n_threads, per_thread = 8, 500
    taken = []
    stop = threading.Event()

    def scraper():
        while not stop.is_set():
            taken.append(b.take_demand())
        taken.append(b.take_demand())        # final sweep

    def admit():
        for i in range(per_thread):
            b.add(k, i)

    scr = threading.Thread(target=scraper)
    scr.start()
    workers = [threading.Thread(target=admit) for _ in range(n_threads)]
    for w in workers:
        w.start()
    for w in workers:
        w.join()
    stop.set()
    scr.join()
    total = sum(d.get(k, 0) for d in taken)
    assert total == n_threads * per_thread
    assert b.demand() == {k: n_threads * per_thread}


def test_stats_consistent_under_background_prewarm():
    """stats() snapshots under the service lock via atomic engine
    counters: while a background prewarm thread compiles the menu and
    mutates the engine maps, every stats() read must be internally
    consistent (compiles.total equals the sum of its own by_bucket
    entries — never a torn count)."""
    from repro.serving import PrewarmSpec

    prior = BernoulliGauss(eps=0.1)
    svc = SolveService(policy=BucketPolicy(max_batch=8),
                       rate_accounting=False)
    menu = [PrewarmSpec(n=128, m=64, n_proc=4, n_iter=t, policy="fixed",
                        prior=prior, batch_widths=(1, 2))
            for t in (4, 8, 12)]
    th = svc.prewarm(menu, background=True)
    while th.is_alive():
        st = svc.stats()
        assert st["compiles"]["total"] == sum(st["compiles"]["by_bucket"]
                                              .values())
        assert st["dispatches"]["total"] == sum(st["dispatches"]
                                                ["by_bucket"].values())
    th.join()
    st = svc.stats()
    assert st["compiles"]["total"] == svc.compile_count() > 0
