"""Column-layout (C-MP-AMP) kernel suite tests — ISSUE 5.

Interpret-mode parity of the fused column kernels (``col_residual``,
``col_inner_step``) against the einsum references, the in-kernel analytic
Bernoulli-Gauss denoiser derivative against ``jax.grad``, and the bf16
A-streaming accuracy envelope (hypothesis property).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.amp_fused.col import eta_bg_and_deriv
from repro.kernels.amp_fused.ops import (col_inner_step, col_residual,
                                         pad_col_shards)
from repro.kernels.amp_fused.ref import col_inner_step_ref, col_residual_ref


@pytest.mark.parametrize("sigma2,eps,mu_s,sigma_s2",
                         [(0.05, 0.1, 0.0, 1.0), (1e-3, 0.05, 0.3, 2.0),
                          (0.5, 0.3, -0.7, 0.25)])
def test_eta_bg_analytic_deriv_matches_grad(sigma2, eps, mu_s, sigma_s2):
    """The in-kernel closed-form eta'/eta must match denoisers.eta_bg and
    its jax.grad elementwise (the kernel cannot autodiff)."""
    from repro.core.denoisers import eta_bg
    f = jnp.asarray(np.random.default_rng(0).normal(size=2000) * 2.0,
                    jnp.float32)
    val, deriv = eta_bg_and_deriv(f, sigma2, eps, mu_s, sigma_s2)
    val_ref = eta_bg(f, sigma2, eps, mu_s, sigma_s2)
    deriv_ref = jax.grad(
        lambda u: jnp.sum(eta_bg(u, sigma2, eps, mu_s, sigma_s2)))(f)
    np.testing.assert_allclose(np.asarray(val), np.asarray(val_ref),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(deriv), np.asarray(deriv_ref),
                               rtol=1e-4, atol=1e-5)


def _col_operands(p, m, np_, seed=0):
    rng = np.random.default_rng(seed)
    a = (rng.normal(size=(p, m, np_)) / np.sqrt(m)).astype(np.float32)
    x = (rng.normal(size=(p, np_)) * 0.1).astype(np.float32)
    x0 = (rng.normal(size=(p, np_)) * 0.1).astype(np.float32)
    z = rng.normal(size=(p, m)).astype(np.float32)
    g = rng.normal(size=m).astype(np.float32)
    return a, x, x0, z, g


@pytest.mark.parametrize("p,m,np_", [(4, 256, 512), (3, 200, 300),
                                     (8, 100, 64)])
def test_col_residual_interpret_matches_ref(p, m, np_):
    a, x, _, _, _ = _col_operands(p, m, np_)
    ap, _ = pad_col_shards(a, np.zeros(m, np.float32))
    r_pal = col_residual(jnp.asarray(ap), jnp.asarray(x), use_pallas=True,
                         interpret=True)
    r_ref = col_residual_ref(jnp.asarray(a), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(r_pal)[:, :m], np.asarray(r_ref),
                               rtol=3e-5, atol=3e-6)
    # padded rows of A are zero -> padded residual entries exactly zero
    assert np.all(np.asarray(r_pal)[:, m:] == 0.0)


@pytest.mark.parametrize("p,m,np_", [(4, 256, 512), (3, 200, 300)])
@pytest.mark.parametrize("update_z", [False, True])
@pytest.mark.parametrize("masked", [False, True])
def test_col_inner_step_interpret_matches_ref(p, m, np_, update_z, masked):
    """The fused inner-step kernel (message + in-kernel denoise +
    derivative sum + optional residual update, one VMEM pass per
    contraction) == the einsum reference, with and without the het
    column mask."""
    a, x, x0, z, g = _col_operands(p, m, np_, seed=update_z + 2 * masked)
    mask = np.ones(np_, np.float32)
    if masked:
        mask[np_ // 2:] = 0.0
    pri = (float(m), 0.08, 0.1, 1.0)   # m_eff, eps, mu_s, sigma_s2
    ap, gp = pad_col_shards(a, g)
    zp = np.pad(z, ((0, 0), (0, ap.shape[1] - m)))
    xn_p, c_p, zn_p = col_inner_step(
        jnp.asarray(ap), jnp.asarray(x), jnp.asarray(x0), jnp.asarray(zp),
        jnp.asarray(gp), jnp.asarray(mask), *pri, update_z=update_z,
        use_pallas=True, interpret=True)
    xn_r, c_r, zn_r = col_inner_step_ref(
        jnp.asarray(a), jnp.asarray(x), jnp.asarray(x0), jnp.asarray(z),
        jnp.asarray(g), jnp.asarray(mask), *pri, update_z)
    np.testing.assert_allclose(np.asarray(xn_p), np.asarray(xn_r),
                               rtol=3e-5, atol=3e-6)
    np.testing.assert_allclose(np.asarray(c_p), np.asarray(c_r),
                               rtol=3e-5, atol=3e-6)
    np.testing.assert_allclose(np.asarray(zn_p)[:, :m], np.asarray(zn_r),
                               rtol=3e-5, atol=3e-5)


def test_col_inner_step_two_inner_iterations():
    """Chaining two fused inner steps (update_z then final) reproduces the
    engine's n_inner=2 einsum loop — the exact composition ``_col_round``
    dispatches on the kernel path."""
    p, m, np_ = 4, 192, 256
    a, x, x0, z, g = _col_operands(p, m, np_, seed=7)
    mask = np.ones(np_, np.float32)
    pri = (float(m), 0.08, 0.0, 1.0)
    aj, xj, x0j = jnp.asarray(a), jnp.asarray(x), jnp.asarray(x)
    zj, gj, mj = jnp.asarray(z), jnp.asarray(g), jnp.asarray(mask)

    x1r, _, z1r = col_inner_step_ref(aj, xj, x0j, zj, gj, mj, *pri, True)
    x2r, c2r, z2r = col_inner_step_ref(aj, x1r, x0j, z1r, gj, mj, *pri,
                                       False)

    ap, gp = pad_col_shards(a, g)
    zp = jnp.asarray(np.pad(z, ((0, 0), (0, ap.shape[1] - m))))
    apj, gpj = jnp.asarray(ap), jnp.asarray(gp)
    x1, _, z1 = col_inner_step(apj, xj, x0j, zp, gpj, mj, *pri,
                               update_z=True, use_pallas=True,
                               interpret=True)
    x2, c2, z2 = col_inner_step(apj, x1, x0j, z1, gpj, mj, *pri,
                                update_z=False, use_pallas=True,
                                interpret=True)
    np.testing.assert_allclose(np.asarray(x2), np.asarray(x2r), rtol=3e-5,
                               atol=3e-6)
    np.testing.assert_allclose(np.asarray(c2), np.asarray(c2r), rtol=3e-5,
                               atol=3e-6)
    # z_last (the residual that fed the final denoise) matches too
    np.testing.assert_allclose(np.asarray(z2)[:, :m], np.asarray(z2r),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# bf16 A-streaming envelope (DESIGN.md §8)
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), eps=st.floats(0.03, 0.15),
       p=st.sampled_from([2, 4]))
def test_bf16_a_streaming_envelope(seed, eps, p):
    """Documented envelope: storing/streaming A in bf16 (f32 accumulation)
    perturbs the solution by less than the AMP noise floor — the
    engine-level MSE difference vs the f32 solve stays below 1% of the
    f32 solve's own MSE against ground truth, and below 1e-4 absolutely.
    bf16 has an ~2^-8 relative mantissa: each matvec entry moves by
    ~0.4%, but AMP recomputes the residual from y every iteration, so the
    perturbation does not accumulate across iterations.
    """
    from repro.core.amp import sample_problem
    from repro.core.denoisers import BernoulliGauss
    from repro.core.engine import AmpEngine, EngineConfig
    from repro.core.state_evolution import CSProblem

    prior = BernoulliGauss(eps=float(eps))
    prob = CSProblem(n=512, m=128, prior=prior, snr_db=20.0)
    s0, a, y = sample_problem(jax.random.PRNGKey(seed), prob.n, prob.m,
                              prior, prob.sigma_e2)
    mk = lambda adt: AmpEngine(
        prior, EngineConfig(n_proc=p, n_iter=6, collect_symbols=False,
                            a_dtype=adt))
    tr32 = mk("float32").solve(y, a)
    tr16 = mk("bfloat16").solve(y, a)
    d = float(np.mean((tr16.x - tr32.x) ** 2))
    mse32 = float(np.mean((tr32.x - np.asarray(s0)) ** 2))
    assert d <= 0.01 * mse32 + 1e-9, (d, mse32)
    assert d <= 1e-4, d
