import math

import numpy as np
import pytest

from repro.core.denoisers import BernoulliGauss
from repro.core.rate_distortion import (RDModel, ba_rd_curve,
                                        gauss_mixture_entropy)


def test_ba_matches_gaussian_closed_form():
    """eps=1 reduces the source to a pure Gaussian: R(D) = 1/2 log2(var/D)."""
    prior = BernoulliGauss(eps=1.0, mu_s=0.0, sigma_s=1.0)
    r, d = ba_rd_curve(prior, 0.5, n_grid=513, n_beta=24)
    var = 1.25
    mask = r > 0.25
    d_true = var * 2.0 ** (-2 * r[mask])
    # 7%: the last valid BA point sits at the D >= 30 dx^2 grid boundary,
    # where the discretized R(D) deviates by ~6% at this grid size
    np.testing.assert_allclose(d[mask], d_true, rtol=0.07)


def test_gaussian_entropy_quadrature():
    prior = BernoulliGauss(eps=1.0, mu_s=0.0, sigma_s=1.0)
    h = gauss_mixture_entropy(prior, 0.5)
    h_true = 0.5 * math.log2(2 * math.pi * math.e * 1.25)
    assert abs(h - h_true) < 1e-4


def test_rd_model_monotone_and_bounded():
    prior = BernoulliGauss(eps=0.1)
    rd = RDModel(prior)
    rates = np.linspace(0, 8, 81)
    for sp in (0.2, 1.0, 3.0):
        d = rd.distortion_g(rates, np.full_like(rates, sp))
        assert np.all(np.diff(d) <= 1e-12), sp
        # 0.5% slack: the BA grid's discretized source variance slightly
        # exceeds the continuous one (~dx^2/12 + interpolation in sigma')
        assert d[0] <= (prior.second_moment + sp**2) * 1.005 + 1e-6
        # Shannon lower bound holds
        h = gauss_mixture_entropy(prior, sp)
        slb = 2.0 ** (2 * (h - rates)) / (2 * math.pi * math.e)
        assert np.all(d >= slb * 0.999)


def test_distortion_msg_scaling():
    """D_{F^p}(R) = D_G(R) / P^2 with sigma' = sqrt(P sigma_t^2)."""
    prior = BernoulliGauss(eps=0.1)
    rd = RDModel(prior)
    p, s2 = 30, 0.04
    got = rd.distortion_msg(2.0, s2, p)
    expect = rd.distortion_g(np.asarray(2.0),
                             np.asarray(math.sqrt(p * s2))) / p**2
    np.testing.assert_allclose(got, expect, rtol=1e-12)


def test_rate_inverse_consistency():
    prior = BernoulliGauss(eps=0.1)
    rd = RDModel(prior)
    s2, p = 0.04, 30
    for rate in (0.8, 2.0, 3.5):
        d = float(rd.distortion_msg(rate, s2, p))
        r_back = rd.rate_for_msg_distortion(d, s2, p)
        assert abs(r_back - rate) < 0.06, (rate, r_back)
