"""Column-layout (C-MP-AMP) engine tests — ISSUE 4 acceptance criteria.

The layout-parity pin rests on an exact identity: at ``n_inner == 1`` the
fused boundary Onsager carry makes column-partitioned C-MP-AMP with exact
fusion *identical* to centralized AMP (``ColumnPartition`` docstring), so
the column code path — column splits, residual fusion, boundary carry,
per-slice einsums — must reproduce the single-processor
``AmpEngine.solve`` to float-reassociation accuracy.
"""
import jax
import numpy as np
import pytest

from repro.core.amp import sample_problem
from repro.core.denoisers import BernoulliGauss, make_mmse_interp
from repro.core.engine import (AmpEngine, BlockQuantTransport, ColBTTables,
                               ColDPSchedule, ColumnBTRateControl,
                               ColumnPartition, EcsqTransport, EngineConfig,
                               ExactFusion, FixedSchedule, HetParams,
                               col_bt_delta_for, split_problem_cols,
                               stack_bt_tables)
from repro.core.rate_alloc import col_sigma_q2_for_rate, dp_allocate_col
from repro.core.state_evolution import CSProblem, se_trajectory_col
from repro.serving import BucketPolicy, SolveRequest, SolveService


@pytest.fixture(scope="module")
def golden_point():
    """The paper's Sec. 4 operating point (kappa=0.3, 20dB, eps=0.05)."""
    prior = BernoulliGauss(eps=0.05)
    prob = CSProblem(n=2000, m=600, prior=prior, snr_db=20.0)
    s0, a, y = sample_problem(jax.random.PRNGKey(0), prob.n, prob.m, prior,
                              prob.sigma_e2)
    return prob, s0, a, y


def _col_engine(prior, p, t, transport=None, controller=None, n_inner=1,
                **cfg_kw):
    return AmpEngine(
        prior,
        EngineConfig(n_proc=p, n_iter=t, collect_symbols=False,
                     layout=ColumnPartition(n_inner=n_inner), **cfg_kw),
        transport if transport is not None else ExactFusion(),
        controller)


def test_column_exact_matches_single_processor_solve(golden_point):
    """Acceptance: column-layout exact transport == single-processor
    ``solve`` to <= 1e-10 MSE at the golden operating point."""
    prob, s0, a, y = golden_point
    t = 10
    ref = AmpEngine(prob.prior,
                    EngineConfig(n_proc=1, n_iter=t,
                                 collect_symbols=False)).solve(y, a)
    for p in (4, 8):
        col = _col_engine(prob.prior, p, t).solve(y, a)
        d = float(np.mean((col.x - ref.x) ** 2))
        assert d <= 1e-10, (p, d)
        np.testing.assert_allclose(col.sigma2_hat, ref.sigma2_hat,
                                   rtol=1e-5)
        # and it actually recovers the signal
        assert float(col.mse(s0)[-1]) < 5e-4


def test_column_quantized_envelope(golden_point):
    """ECSQ on the exchanged residuals: noise accounting reports exactly
    P * Delta^2 / 12 per round and quality degrades gracefully."""
    prob, s0, a, y = golden_point
    t, p = 10, 4
    exact = _col_engine(prob.prior, p, t).solve(y, a)
    deltas = np.full(t, 0.02, np.float32)
    deltas[0] = np.inf   # round 0 exchanges zeros: conventionally lossless
    q = _col_engine(prob.prior, p, t, EcsqTransport(),
                    FixedSchedule(deltas)).solve(y, a)
    np.testing.assert_allclose(q.extra_var[1:], p * 0.02**2 / 12.0,
                               rtol=1e-6)
    assert q.extra_var[0] == 0.0
    mse_e, mse_q = float(exact.mse(s0)[-1]), float(q.mse(s0)[-1])
    assert mse_q < 1.5 * mse_e, (mse_q, mse_e)
    # coarse bins must visibly hurt (the accounting has teeth)
    coarse = np.full(t, 0.2, np.float32)
    coarse[0] = np.inf
    qc = _col_engine(prob.prior, p, t, EcsqTransport(),
                     FixedSchedule(coarse)).solve(y, a)
    assert float(qc.mse(s0)[-1]) > 2.0 * mse_e


def test_column_block_transport(golden_point):
    """int8 block quantization of the residual exchange: near-exact
    quality; zero contributions (round 0) inject zero noise."""
    prob, s0, a, y = golden_point
    t, p = 10, 4
    exact = _col_engine(prob.prior, p, t).solve(y, a)
    b8 = _col_engine(prob.prior, p, t,
                     BlockQuantTransport(bits=8, block=512)).solve(y, a)
    assert b8.extra_var[0] == 0.0
    assert np.all(b8.extra_var[1:] > 0)
    assert float(b8.mse(s0)[-1]) < 1.3 * float(exact.mse(s0)[-1])


def test_column_multi_inner_rounds(golden_point):
    """n_inner > 1 (the communication-saving regime): 5 rounds x 2 inner
    iterations converge close to 10 lossless fused rounds while fusing
    half as often."""
    prob, s0, a, y = golden_point
    ref = _col_engine(prob.prior, 4, 10).solve(y, a)
    two = _col_engine(prob.prior, 4, 5, n_inner=2).solve(y, a)
    mse_ref, mse_two = float(ref.mse(s0)[-1]), float(two.mse(s0)[-1])
    assert mse_two < 3.0 * mse_ref, (mse_two, mse_ref)
    # per-round progress is monotone
    assert np.all(np.diff(two.mse(s0)) < 0)


def test_column_bt_controller(golden_point):
    """In-graph column BT: round 0 is free, later rounds spend finite
    rates bounded by r_max, and the quantized trajectory tracks the
    lossless one within the c_ratio discipline's intent."""
    prob, s0, a, y = golden_point
    t, p = 10, 4
    mm = make_mmse_interp(prob.prior)
    ctrl = ColumnBTRateControl(prob, p, t, c_ratio=1.05, r_max=6.0,
                               mmse_fn=mm)
    tr = _col_engine(prob.prior, p, t, EcsqTransport(), ctrl).solve(y, a)
    assert np.isinf(tr.deltas[0]) and tr.rates[0] == 0.0
    assert np.all(np.isfinite(tr.deltas[1:]))
    assert np.all(tr.rates[1:] <= 6.0 + 1e-6)
    assert np.all(tr.rates[1:] > 0)
    exact = _col_engine(prob.prior, p, t).solve(y, a)
    assert float(tr.mse(s0)[-1]) < 1.5 * float(exact.mse(s0)[-1])

    # the pure decision function agrees with a host-side re-evaluation of
    # the same rule: base + P*sigma_Q^2 <= target, admissible bin closed
    # form (quantization noise lands additively on the fused residual)
    tb = ctrl.tables
    for s, v in ((3, float(tb.targets[3]) / 1.05), (5, 0.01)):
        delta, rate = col_bt_delta_for(tb, s, np.float32(v))
        d_blk = float(np.interp(np.log(v), tb.log_v, tb.log_m))
        d_blk = float(np.exp(d_blk))
        base = prob.sigma_e2 + d_blk / prob.kappa
        target = float(tb.targets[s])
        v_r = (prob.prior.second_moment - d_blk) / (prob.kappa * p)
        sq2_adm = max(target - base, 0.0) / p
        sq2_cap = (2.0 ** float(tb.u_cap)) ** 2 * v_r / 12.0
        sq2 = min(max(sq2_adm, sq2_cap), v_r)
        assert abs(float(delta) - np.sqrt(12.0 * sq2)) < 1e-3 * float(delta)


def test_dp_allocate_col():
    """Column DP: budget respected, more budget -> no worse final MSE,
    and the realized ColDPSchedule starts lossless."""
    prob = CSProblem(n=2000, m=600, prior=BernoulliGauss(eps=0.05),
                     snr_db=20.0)
    mm = make_mmse_interp(prob.prior)
    t, p = 8, 4
    dp_lo = dp_allocate_col(prob, p, t, r_total=7.0, mmse_fn=mm)
    dp_hi = dp_allocate_col(prob, p, t, r_total=28.0, mmse_fn=mm)
    for dp, budget in ((dp_lo, 7.0), (dp_hi, 28.0)):
        assert dp.rates[0] == 0.0
        assert dp.rates.sum() <= budget + 1e-9
        assert np.all(np.diff(dp.sigma2_d) <= 1e-12)   # block MSE decreases
    assert dp_hi.sigma2_d[-1] <= dp_lo.sigma2_d[-1]
    sched = ColDPSchedule(dp_hi, prob, p)
    assert np.isinf(sched.deltas[0])
    assert np.all(np.isfinite(sched.deltas[1:]))
    # rate -> distortion model is monotone and capped at the source var
    sq2 = col_sigma_q2_for_rate(np.array([0.0, 1.0, 4.0]), 1e-3, prob, p)
    assert sq2[0] >= sq2[1] >= sq2[2]


def test_column_se_properties():
    """Two-stage column SE: lossless decreasing, quantization dominates
    clean, vanishing noise recovers it, and n_inner=1 lossless equals the
    centralized recursion."""
    from repro.core.state_evolution import se_trajectory
    prob = CSProblem(n=2000, m=600, prior=BernoulliGauss(eps=0.05),
                     snr_db=20.0)
    mm = make_mmse_interp(prob.prior)
    tau, d = se_trajectory_col(prob, 4, 10, 1, mmse_fn=mm)
    assert np.all(np.diff(d) <= 1e-12)
    # n_inner=1 lossless column SE == centralized SE (same recursion)
    cen = se_trajectory(prob, 10, mmse_fn=mm)
    np.testing.assert_allclose(tau, cen[:-1], rtol=1e-9)
    sq2 = np.full(10, 1e-4)
    sq2[0] = 0.0
    tau_q, d_q = se_trajectory_col(prob, 4, 10, 1, sigma_q2=sq2, mmse_fn=mm)
    assert np.all(d_q >= d - 1e-15)
    tau_t, d_t = se_trajectory_col(prob, 4, 10, 1, sigma_q2=sq2 * 1e-9,
                                   mmse_fn=mm)
    np.testing.assert_allclose(d_t, d, rtol=1e-6)
    # more inner iterations per round -> no worse end point per round
    _, d2 = se_trajectory_col(prob, 4, 10, 2, mmse_fn=mm)
    assert np.all(d2 <= d + 1e-15)


def test_column_solve_many_matches_solve(golden_point):
    """vmap-batched column solves match per-instance column solves."""
    prob, _, a, _ = golden_point
    prior = prob.prior
    t, p, b = 6, 4, 3
    insts = [sample_problem(jax.random.PRNGKey(i + 1), prob.n, prob.m,
                            prior, prob.sigma_e2) for i in range(b)]
    ys = np.stack([i[2] for i in insts])
    a_mats = np.stack([i[1] for i in insts])
    eng = _col_engine(prior, p, t)
    batch = eng.solve_many(ys, a_mats)
    for i in range(b):
        single = _col_engine(prior, p, t).solve(ys[i], a_mats[i])
        np.testing.assert_allclose(batch.x[i], single.x, atol=5e-5)
    shared = eng.solve_many(ys, a_mats[0])
    single0 = _col_engine(prior, p, t).solve(ys[0], a_mats[0])
    np.testing.assert_allclose(shared.x[0], single0.x, atol=5e-5)


def test_serving_column_bucket_matches_single(golden_point):
    """A tall request through the service (auto-routed to a column
    bucket, padded columns/rows/rounds) == the direct column engine
    solve; mixed row+column streams batch side by side."""
    prior = BernoulliGauss(eps=0.02)
    n, m, p, t = 2048, 256, 8, 8   # aspect 8: column layout
    prob = CSProblem(n=n, m=m, prior=prior, snr_db=20.0)
    s0, a, y = sample_problem(jax.random.PRNGKey(7), n, m, prior,
                              prob.sigma_e2)
    svc = SolveService(policy=BucketPolicy(max_batch=8))
    row_req = SolveRequest(y=y[:160], a=np.asarray(a)[:160, :512],
                           prior=prior, n_proc=4, n_iter=6,
                           policy="lossless")   # aspect 3.2: row bucket
    col_req = SolveRequest(y=y, a=a, prior=prior, n_proc=p, n_iter=t,
                           policy="lossless")
    col_bt = SolveRequest(y=y, a=a, prior=prior, n_proc=p, n_iter=t,
                          policy="bt")
    res = svc.solve([col_req, row_req, col_bt])
    assert res[0].bucket.layout == "col"
    assert res[1].bucket.layout == "row"
    assert res[2].bucket.layout == "col"

    ref = _col_engine(prior, p, t).solve(y, a)
    d = float(np.mean((res[0].x - ref.x) ** 2))
    assert d <= 1e-10, d
    np.testing.assert_allclose(res[0].sigma2_hat, ref.sigma2_hat, rtol=1e-4)

    ctrl = ColumnBTRateControl(prob, p, t, 1.005, 6.0)
    ref_bt = _col_engine(prior, p, t, EcsqTransport(), ctrl).solve(y, a)
    d_bt = float(np.mean((res[2].x - ref_bt.x) ** 2))
    assert d_bt <= 1e-8, d_bt
    np.testing.assert_allclose(res[2].rates, ref_bt.rates, atol=5e-3)
    assert res[2].tracked and np.isfinite(res[2].total_bits)
    # recovery quality sanity on the tall problem
    assert float(np.mean((res[0].x - s0) ** 2)) < 5e-4


def test_column_het_padding_is_exact(golden_point):
    """Direct het call with padded columns (per-slice), padded rows and a
    frozen tail: instance results equal the unpadded single solves."""
    prob, _, a, y = golden_point
    prior = prob.prior
    p, t_max = 4, 12
    m_pad, np_pad = 640, 512
    s1, a1, y1 = sample_problem(jax.random.PRNGKey(3), 1800, 560, prior,
                                prob.sigma_e2)
    a_b = np.zeros((2, p, m_pad, np_pad), np.float32)
    y_b = np.zeros((2, m_pad), np.float32)
    a_b[0, :, :600, :500] = split_problem_cols(np.asarray(a, np.float32), p)
    y_b[0, :600] = y
    a_b[1, :, :560, :450] = split_problem_cols(np.asarray(a1, np.float32),
                                               p)
    y_b[1, :560] = y1
    from repro.core.rate_alloc import stack_schedules
    params = HetParams(
        sched=stack_schedules(
            [np.full(10, np.inf, np.float32),
             np.concatenate([[np.inf],
                             np.full(7, 0.02)]).astype(np.float32)], t_max),
        t_active=np.asarray([10, 8], np.int32),
        m_real=np.asarray([600, 560], np.float32),
        n_real=np.asarray([2000, 1800], np.int32),
        eps=np.full(2, prior.eps, np.float32),
        mu_s=np.zeros(2, np.float32), sigma_s=np.ones(2, np.float32),
        use_bt=np.asarray([False, False]),
        bt=stack_bt_tables([ColBTTables.dummy(t_max)] * 2),
    )
    eng = _col_engine(prior, p, t_max, EcsqTransport(), collect_xs=False)
    tr = eng.solve_het(a_b, y_b, params)

    ref0 = _col_engine(prior, p, 10).solve(y, a)
    x0 = tr.x[0].reshape(p, np_pad)[:, :500].reshape(-1)
    assert float(np.mean((x0 - ref0.x) ** 2)) <= 1e-10
    deltas1 = np.concatenate([[np.inf], np.full(7, 0.02)]).astype(np.float32)
    ref1 = _col_engine(prior, p, 8, EcsqTransport(),
                       FixedSchedule(deltas1)).solve(y1, a1)
    x1 = tr.x[1].reshape(p, np_pad)[:, :450].reshape(-1)
    assert float(np.mean((x1 - ref1.x) ** 2)) <= 1e-8
    np.testing.assert_allclose(tr.sigma2_hat[1][:8], ref1.sigma2_hat,
                               rtol=1e-4)
    assert np.all(tr.sigma2_hat[1][8:] == 0.0)   # frozen tail masked out


def test_auto_layout_does_not_mutate_request_template():
    """Auto layout routing is pinned on the service's copy, not on the
    caller's request object — the same layout=None template submitted to
    services with different aspect policies routes per-policy."""
    prior = BernoulliGauss(eps=0.05)
    prob = CSProblem(n=1024, m=256, prior=prior, snr_db=20.0)
    _, a, y = sample_problem(jax.random.PRNGKey(2), prob.n, prob.m, prior,
                             prob.sigma_e2)
    req = SolveRequest(y=y, a=a, prior=prior, n_proc=4, n_iter=4,
                       policy="lossless")
    svc_col = SolveService(policy=BucketPolicy(max_batch=4))
    r_col, = svc_col.solve([req])
    assert r_col.bucket.layout == "col"          # aspect 4 >= default 4.0
    assert req.layout is None                    # template untouched
    svc_row = SolveService(policy=BucketPolicy(max_batch=4,
                                               col_aspect=16.0))
    r_row, = svc_row.solve([req])
    assert r_row.bucket.layout == "row"
    np.testing.assert_allclose(r_col.x, r_row.x, atol=5e-5)


def test_column_rate_accounting_round_indexing():
    """Realized rates for column fixed/DP schedules: round 0 counts 0.0
    bits (zero contributions), round 1 models the payload built from the
    *post-round-0* estimate — a one-round-stale readoff would collapse
    the round-1 residual variance to ~0 and report ~0 bits."""
    prior = BernoulliGauss(eps=0.05)
    n, m, p, t = 2048, 512, 8, 6
    prob = CSProblem(n=n, m=m, prior=prior, snr_db=20.0)
    _, a, y = sample_problem(jax.random.PRNGKey(4), n, m, prior,
                             prob.sigma_e2)
    svc = SolveService(policy=BucketPolicy(max_batch=4))
    deltas = np.concatenate([[np.inf],
                             np.full(t - 1, 0.02)]).astype(np.float32)
    res, = svc.solve([SolveRequest(y=y, a=a, prior=prior, n_proc=p,
                                   n_iter=t, policy="fixed",
                                   deltas=deltas)])
    assert res.bucket.layout == "col"
    assert res.rates[0] == 0.0
    assert np.all(np.isfinite(res.rates))
    # the first real exchange is the largest payload: several bits, and
    # rates stay within the same order across rounds (no collapse)
    assert res.rates[1] > 1.0, res.rates
    assert res.tracked and res.total_bits == res.rates[1:].sum()
    # fully lossless column requests stay untracked (no spurious 0.0)
    res_ll, = svc.solve([SolveRequest(y=y, a=a, prior=prior, n_proc=p,
                                      n_iter=t, policy="lossless")])
    assert not res_ll.tracked and np.isinf(res_ll.rates).all()


@pytest.mark.parametrize("p,n_inner", [(4, 1), (4, 2), (8, 2)])
def test_column_kernel_interpret_matches_einsum(golden_point, p, n_inner):
    """ISSUE 5 acceptance: column solves with ``use_kernel`` +
    ``kernel_interpret=True`` (fused residual + fused inner-step Pallas
    kernels, M tile-padded) match the einsum reference to <= 1e-6 MSE on
    the parity grid — exact fusion and ECSQ, n_inner 1 and 2."""
    prob, s0, a, y = golden_point
    t = 6
    deltas = np.full(t, 0.02, np.float32)
    deltas[0] = np.inf
    for transport, ctrl in ((ExactFusion(), None),
                            (EcsqTransport(), FixedSchedule(deltas))):
        ref = _col_engine(prob.prior, p, t, transport, ctrl,
                          n_inner=n_inner).solve(y, a)
        pal = _col_engine(prob.prior, p, t, transport, ctrl,
                          n_inner=n_inner, use_kernel=True,
                          kernel_interpret=True).solve(y, a)
        d = float(np.mean((pal.x - ref.x) ** 2))
        assert d <= 1e-6, (type(transport).__name__, d)
        np.testing.assert_allclose(pal.sigma2_hat, ref.sigma2_hat,
                                   rtol=1e-4)
        np.testing.assert_allclose(pal.extra_var, ref.extra_var, rtol=1e-5)


def test_column_het_kernel_interpret_matches_ref(golden_point):
    """The heterogeneous column path (padded columns via ``n_mask``,
    padded rows, frozen tail) through the kernel suite == the einsum het
    solve — the mask rides into the fused kernel's in-kernel denoise."""
    prob, _, a, y = golden_point
    prior = prob.prior
    p, t_max = 4, 8
    m_pad, np_pad = 640, 512
    a_b = np.zeros((1, p, m_pad, np_pad), np.float32)
    y_b = np.zeros((1, m_pad), np.float32)
    a_b[0, :, :600, :500] = split_problem_cols(np.asarray(a, np.float32), p)
    y_b[0, :600] = y
    from repro.core.rate_alloc import stack_schedules
    params = HetParams(
        sched=stack_schedules([np.full(6, np.inf, np.float32)], t_max),
        t_active=np.asarray([6], np.int32),
        m_real=np.asarray([600], np.float32),
        n_real=np.asarray([2000], np.int32),
        eps=np.full(1, prior.eps, np.float32),
        mu_s=np.zeros(1, np.float32), sigma_s=np.ones(1, np.float32),
        use_bt=np.asarray([False]),
        bt=stack_bt_tables([ColBTTables.dummy(t_max)]),
    )
    ref = _col_engine(prior, p, t_max, EcsqTransport(),
                      collect_xs=False).solve_het(a_b, y_b, params)
    pal = _col_engine(prior, p, t_max, EcsqTransport(), collect_xs=False,
                      use_kernel=True,
                      kernel_interpret=True).solve_het(a_b, y_b, params)
    assert pal.x.shape == ref.x.shape     # bucket shapes preserved
    d = float(np.mean((pal.x - ref.x) ** 2))
    assert d <= 1e-6, d
    np.testing.assert_allclose(pal.sigma2_hat, ref.sigma2_hat, rtol=1e-4)


def test_column_rejects_row_controller(golden_point):
    """A row-wise BT controller predicts through the wrong SE: refused."""
    from repro.core.engine import BTRateControl
    prob, _, a, y = golden_point
    ctrl = BTRateControl(prob, 4, 8, 1.005, 6.0, "ecsq")
    eng = _col_engine(prob.prior, 4, 8, EcsqTransport(), ctrl)
    with pytest.raises(AssertionError, match="ColumnBTRateControl"):
        eng.solve(y, a)


def test_service_col_proc_placement_matches_local(multidev):
    """A tall request big enough for processor sharding: the column mesh
    placement (column blocks across devices, het path) must reproduce the
    local column bucket exactly (ISSUE 4 acceptance: tall-N requests with
    N*M >= shard_elems route to ('proc', 'col'))."""
    multidev("""
import jax, numpy as np
from repro.compat import make_mesh
from repro.core.amp import sample_problem
from repro.core.denoisers import BernoulliGauss
from repro.core.state_evolution import CSProblem
from repro.serving import BucketPolicy, SolveRequest, SolveService

prior = BernoulliGauss(eps=0.02)
prob = CSProblem(n=4096, m=512, prior=prior, snr_db=20.0)
s0, a, y = sample_problem(jax.random.PRNGKey(5), prob.n, prob.m, prior,
                          prob.sigma_e2)
mesh = make_mesh((8,), ('data',))

svc_proc = SolveService(policy=BucketPolicy(shard_elems=1), mesh=mesh)
svc_loc = SolveService(policy=BucketPolicy())
req = lambda policy: SolveRequest(y=y, a=a, prior=prior, snr_db=20.0,
                                  n_proc=8, n_iter=7, policy=policy)
for policy in ('lossless', 'bt'):
    rp, = svc_proc.solve([req(policy)])
    rl, = svc_loc.solve([req(policy)])
    assert rp.bucket.placement == 'proc' and rp.bucket.layout == 'col'
    assert rl.bucket.placement == 'local' and rl.bucket.layout == 'col'
    d = float(np.mean((rp.x - rl.x) ** 2))
    if policy == 'lossless':
        assert d <= 1e-12, d
        np.testing.assert_allclose(rp.sigma2_hat, rl.sigma2_hat, rtol=1e-5)
    else:
        # BT decisions are discontinuous in the plug-in: behavioral compare
        mse_p = float(np.mean((rp.x - s0) ** 2))
        mse_l = float(np.mean((rl.x - s0) ** 2))
        assert mse_p <= 1.3 * mse_l + 1e-8, (mse_p, mse_l)
        assert np.isfinite(rp.total_bits)
print('ok')
""", 8, timeout=900)


def test_solve_sharded_col_matches_emulated(multidev):
    """Device-sharded column solve (column blocks across the mesh, psum of
    residual contributions + boundary Onsager scalar) == the emulated
    column solve, exact transport bitwise-close (ISSUE 4 multidev)."""
    multidev("""
import jax, numpy as np
from repro.compat import make_mesh
from repro.core.amp import sample_problem
from repro.core.denoisers import BernoulliGauss
from repro.core.engine import (AmpEngine, ColumnPartition, EngineConfig,
                               EcsqTransport, ExactFusion, FixedSchedule,
                               PsumFusion)
from repro.core.state_evolution import CSProblem

prior = BernoulliGauss(eps=0.05)
prob = CSProblem(n=2048, m=512, prior=prior, snr_db=20.0)
s0, a, y = sample_problem(jax.random.PRNGKey(1), prob.n, prob.m, prior,
                          prob.sigma_e2)
mesh = make_mesh((8,), ('data',))

for p in (8, 16):
    lay = ColumnPartition(n_inner=1)
    cfg = EngineConfig(n_proc=p, n_iter=8, collect_symbols=False, layout=lay)
    em = AmpEngine(prior, cfg, ExactFusion()).solve(y, a)
    sh = AmpEngine(prior, cfg, PsumFusion(axis='data')).solve_sharded(
        y, a, mesh)
    d = float(np.mean((em.x - sh.x) ** 2))
    assert d <= 1e-12, (p, d)
    np.testing.assert_allclose(sh.sigma2_hat, em.sigma2_hat, rtol=1e-6)

# quantized residual exchange across the mesh: same accounting
deltas = np.full(8, 0.02, np.float32); deltas[0] = np.inf
cfg = EngineConfig(n_proc=8, n_iter=8, collect_symbols=False,
                   layout=ColumnPartition(n_inner=1))
em = AmpEngine(prior, cfg, EcsqTransport(),
               FixedSchedule(deltas)).solve(y, a)
sh = AmpEngine(prior, cfg, PsumFusion(axis='data', local=EcsqTransport()),
               FixedSchedule(deltas)).solve_sharded(y, a, mesh)
np.testing.assert_allclose(sh.extra_var, em.extra_var, rtol=1e-6)
np.testing.assert_allclose(sh.sigma2_hat, em.sigma2_hat, rtol=0.02)
mse_em = float(em.mse(s0)[-1]); mse_sh = float(np.mean((sh.x - s0) ** 2))
assert abs(mse_sh - mse_em) <= 0.05 * mse_em + 1e-8, (mse_sh, mse_em)
print('ok')
""", 8, timeout=900)
