import numpy as np
import pytest

from repro.core.denoisers import BernoulliGauss, make_mmse_interp
from repro.core.state_evolution import (CSProblem, sdr, se_trajectory,
                                        se_trajectory_quantized,
                                        steady_state_iters)


@pytest.mark.parametrize("eps,expected_T", [(0.03, 8), (0.05, 10), (0.10, 18)])
def test_steady_state_read_off(eps, expected_T):
    """Paper Sec. 4 reads T = 8/10/20 off Fig. 1. Our SE (corrected MMSE
    quadrature, validated against Monte Carlo + AMP simulation) reads
    8/10/18 at 0.15 dB/iter: the eps=0.1 curve's last two iterations each
    gain <0.15 dB. Table-1 reproduction uses the paper's own T (PAPER_T)."""
    prob = CSProblem(prior=BernoulliGauss(eps=eps))
    assert steady_state_iters(prob) == expected_T


def test_paper_t_constants():
    from repro.core.state_evolution import PAPER_T
    assert PAPER_T == {0.03: 8, 0.05: 10, 0.10: 20}


def test_se_monotone_decreasing():
    prob = CSProblem(prior=BernoulliGauss(eps=0.05))
    traj = se_trajectory(prob, 30)
    assert np.all(np.diff(traj) <= 1e-12)
    assert traj[-1] >= prob.sigma_e2  # bounded below by the noise floor


def test_quantized_se_dominates_clean_se():
    """Quantization noise can only hurt: sigma_{t,D} >= sigma_{t,C}."""
    prob = CSProblem(prior=BernoulliGauss(eps=0.05))
    mm = make_mmse_interp(prob.prior)
    clean = se_trajectory(prob, 10, mmse_fn=mm)
    noisy = se_trajectory_quantized(prob, np.full(10, 1e-4), 30, mmse_fn=mm)
    assert np.all(noisy >= clean - 1e-12)
    # and vanishing quantization noise recovers the clean SE
    tiny = se_trajectory_quantized(prob, np.full(10, 1e-12), 30, mmse_fn=mm)
    np.testing.assert_allclose(tiny, clean, rtol=1e-6)


def test_sdr_snr_consistency():
    prob = CSProblem(prior=BernoulliGauss(eps=0.1), snr_db=20.0)
    # at sigma_t^2 = sigma_0^2 (x=0), SDR = 0 dB by construction
    assert abs(sdr(prob.sigma0_2, prob)) < 1e-9
    assert abs(10 * np.log10(prob.rho / prob.sigma_e2) - 20.0) < 1e-12
