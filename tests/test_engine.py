"""Unified AMP engine tests: scan-vs-host equivalence, batching, transports,
in-graph BT rate control (ISSUE 1 acceptance criteria)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.amp import amp_iteration, amp_solve, sample_problem
from repro.core.compression import pack_int4, unpack_int4
from repro.core.denoisers import BernoulliGauss, make_mmse_interp
from repro.core.engine import (AmpEngine, BlockQuantTransport, BTRateControl,
                               EcsqTransport, EngineConfig, ExactFusion,
                               FixedSchedule)
from repro.core.mp_amp import MPAMPConfig, mp_amp_solve
from repro.core.rate_alloc import BTController
from repro.core.state_evolution import CSProblem


@pytest.fixture(scope="module")
def problem():
    prior = BernoulliGauss(eps=0.1)
    prob = CSProblem(n=2000, m=600, prior=prior)
    s0, a, y = sample_problem(jax.random.PRNGKey(0), prob.n, prob.m, prior,
                              prob.sigma_e2)
    return prob, s0, a, y


@pytest.fixture(scope="module")
def bt_ctx():
    """Module-scoped BT context (table builds are the expensive part)."""
    prior = BernoulliGauss(eps=0.05)
    prob = CSProblem(n=5000, m=1500, prior=prior)
    mm = make_mmse_interp(prior)
    s0, a, y = sample_problem(jax.random.PRNGKey(3), prob.n, prob.m, prior,
                              prob.sigma_e2)
    return prob, mm, s0, a, y


def test_scan_matches_host_loop_fixed_schedule(problem):
    """Acceptance: the scan-compiled solve (no per-iteration host sync)
    reproduces the host-loop MSE trajectory within 1e-5 (fixed schedule)."""
    prob, s0, a, y = problem
    t = 8
    deltas = np.full(t, 0.04, np.float32)
    deltas[0] = np.inf
    eng = AmpEngine(prob.prior, EngineConfig(n_proc=10, n_iter=t),
                    EcsqTransport(), FixedSchedule(deltas))
    scan = eng.solve(y, a)
    host = eng.solve_host_loop(y, a)
    np.testing.assert_allclose(scan.x, host.x, atol=1e-6)
    assert np.abs(scan.mse(s0) - host.mse(s0)).max() < 1e-5
    np.testing.assert_allclose(scan.sigma2_hat, host.sigma2_hat, rtol=1e-5)


def test_solve_many_matches_per_instance(problem):
    """Acceptance: batched solve_many matches per-instance solves.

    Lossless fusion agrees to float32 ulp accumulation (XLA lowers the
    batched matmuls differently; the steep spike/slab responsibility then
    amplifies ulps — same 5e-5 class of tolerance the centralized-vs-MP
    tests use). The quantized path additionally crosses round-half-even
    boundaries, where a 1-ulp difference legitimately flips a symbol, so it
    is compared behaviorally (per-iteration MSE trajectory)."""
    prob, _, a, _ = problem
    prior = prob.prior
    t, p, b = 6, 10, 4

    insts = [sample_problem(jax.random.PRNGKey(i + 1), prob.n, prob.m, prior,
                            prob.sigma_e2) for i in range(b)]
    s0s = np.stack([inst[0] for inst in insts])
    ys = np.stack([inst[2] for inst in insts])
    a_mats = np.stack([inst[1] for inst in insts])

    # --- lossless: bit-level agreement, per-instance and shared-A ---------
    lossless = np.full(t, np.inf, np.float32)
    eng = AmpEngine(prior,
                    EngineConfig(n_proc=p, n_iter=t, collect_symbols=False),
                    EcsqTransport(), FixedSchedule(lossless))
    batch = eng.solve_many(ys, a_mats)
    for i in range(b):
        single = mp_amp_solve(ys[i], a_mats[i], prior, MPAMPConfig(p, t),
                              lossless)
        np.testing.assert_allclose(batch.x[i], single.x, atol=5e-5)
        np.testing.assert_allclose(batch.deltas[i], single.deltas)
    shared = eng.solve_many(ys, a_mats[0])
    single0 = mp_amp_solve(ys[0], a_mats[0], prior, MPAMPConfig(p, t),
                           lossless)
    np.testing.assert_allclose(shared.x[0], single0.x, atol=5e-5)

    # --- quantized: trajectory-level agreement ----------------------------
    deltas = np.full(t, 0.05, np.float32)
    deltas[0] = np.inf
    engq = AmpEngine(prior,
                     EngineConfig(n_proc=p, n_iter=t, collect_symbols=False),
                     EcsqTransport(), FixedSchedule(deltas))
    batchq = engq.solve_many(ys, a_mats)
    mse_b = batchq.mse(s0s)
    for i in range(b):
        singleq = mp_amp_solve(ys[i], a_mats[i], prior, MPAMPConfig(p, t),
                               deltas, s0=s0s[i])
        np.testing.assert_allclose(mse_b[i], singleq.mse, rtol=0.02)
        np.testing.assert_allclose(batchq.sigma2_hat[i], singleq.sigma2_hat,
                                   rtol=0.02)


def test_int4_pack_roundtrip_negative_values():
    """pack_int4/unpack_int4 roundtrip, explicitly covering negatives."""
    q = jnp.asarray([-7, -6, -5, -4, -3, -2, -1, 0, 1, 2, 3, 4, 5, 6, -7, 7],
                    jnp.int8)
    assert (unpack_int4(pack_int4(q)) == q).all()
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.integers(-7, 8, 4096), jnp.int8)
    packed = pack_int4(q)
    assert packed.dtype == jnp.uint8 and packed.shape == (2048,)
    assert (unpack_int4(packed) == q).all()


def test_scan_bt_matches_host_controller_rates(bt_ctx):
    """Acceptance: in-graph BT decisions match the host-loop BTController
    when fed identical (t, sigma2_hat) inputs."""
    prob, mm, _, _, _ = bt_ctx
    t_iter, p = 8, 10
    host = BTController(prob, p, t_iter, 1.005, 6.0, "ecsq", mmse_fn=mm)
    graph = BTRateControl(prob, p, t_iter, 1.005, 6.0, "ecsq", mmse_fn=mm)

    # probe both the bisection branch (s2 near SE) and the r_max cap branch
    probes = [(t, float(host.sigma2_c[t]) * f)
              for t in range(t_iter) for f in (1.02, 2.5)]
    for t, s2 in probes:
        d_host = host(t, s2)
        r_host = host.rates[-1]
        d_g, r_g = graph.delta_for(jnp.asarray(t),
                                   jnp.asarray(s2, jnp.float32))
        assert abs(float(r_g) - r_host) < 5e-3, (t, s2)
        assert abs(float(d_g) / d_host - 1.0) < 2e-3, (t, s2)


def test_scan_bt_mse_trajectory_matches_host_loop(bt_ctx):
    """Acceptance: scan-compiled BT-MP-AMP reproduces the host-loop
    mp_amp_solve MSE trajectory within 1e-5."""
    prob, mm, s0, a, y = bt_ctx
    t_iter, p = 8, 10
    ctrl_host = BTController(prob, p, t_iter, 1.005, 6.0, "ecsq", mmse_fn=mm)
    host = mp_amp_solve(y, a, prob.prior, MPAMPConfig(p, t_iter),
                        lambda t, s2: ctrl_host(t, s2), s0=s0)
    ctrl_scan = BTController(prob, p, t_iter, 1.005, 6.0, "ecsq", mmse_fn=mm)
    scan = mp_amp_solve(y, a, prob.prior, MPAMPConfig(p, t_iter), ctrl_scan,
                        s0=s0)
    assert np.abs(host.mse - scan.mse).max() < 1e-5
    # the scan path must have recorded its in-graph decisions on the ctrl
    np.testing.assert_allclose(ctrl_scan.rates, ctrl_host.rates, atol=5e-3)


def test_amp_solve_is_engine_p1(problem):
    """The centralized frontend equals the hand-rolled amp_iteration loop."""
    prob, s0, a, y = problem
    t = 8
    tr = amp_solve(y, a, prob.prior, t, s0=s0)
    x = jnp.zeros(prob.n, jnp.float32)
    z = jnp.asarray(y, jnp.float32)
    aj = jnp.asarray(a, jnp.float32)
    yj = jnp.asarray(y, jnp.float32)
    for _ in range(t):
        x, z, _ = amp_iteration(x, z, yj, aj, prob.prior)
    np.testing.assert_allclose(tr.x, np.asarray(x), atol=2e-5)


def test_block_quant_transport_near_exact(problem):
    """int8 block transport: near-centralized quality, noise accounted."""
    prob, s0, a, y = problem
    t, p = 10, 10
    exact = AmpEngine(prob.prior,
                      EngineConfig(n_proc=p, n_iter=t, collect_symbols=False),
                      ExactFusion()).solve(y, a)
    qeng = AmpEngine(prob.prior,
                     EngineConfig(n_proc=p, n_iter=t, collect_symbols=False),
                     BlockQuantTransport(bits=8, block=256))
    q = qeng.solve(y, a)
    mse_e = float(exact.mse(s0)[-1])
    mse_q = float(q.mse(s0)[-1])
    assert mse_q < mse_e * 1.3, (mse_q, mse_e)
    assert np.all(q.extra_var > 0)   # paper's P*sigma_Q^2 accounting active
