"""Erasure-transport tests (DESIGN.md §10): mask sampling, SE
amplification, recovery-policy factors, allocator wire budgets, drop-0
bit-exactness through the engine, measured-wire accounting through the
service, prewarm thread safety, and a tier2 MC-vs-SE oracle under loss.
"""
import threading

import jax
import numpy as np
import pytest

from repro.core.amp import sample_problem
from repro.core.denoisers import BernoulliGauss, make_mmse_interp
from repro.core.engine import (AmpEngine, EcsqTransport, EngineConfig,
                               ErasureSpec, ExactFusion, FixedSchedule)
from repro.core.rate_alloc import (bt_schedule_offline, dp_allocate,
                                   dp_allocate_col, erasure_rate_factors)
from repro.core.state_evolution import (CSProblem, erasure_amplification,
                                        se_trajectory_erasure)
from repro.serving import (BucketPolicy, PrewarmSpec, SolveRequest,
                           SolveService)

N, M, P, T = 192, 64, 4, 4
POLICY = BucketPolicy(max_batch=4, n_quantum=64, mp_quantum=8)


@pytest.fixture(scope="module")
def inst():
    prior = BernoulliGauss(eps=0.1)
    prob = CSProblem(n=N, m=M, prior=prior)
    s0, a, y = sample_problem(jax.random.PRNGKey(0), N, M, prior,
                              prob.sigma_e2)
    return prior, prob, np.asarray(a), np.asarray(y), np.asarray(s0)


# ---------------------------------------------------------------------------
# units: masks, amplification, recovery factors
# ---------------------------------------------------------------------------

def test_erasure_spec_masks():
    m = ErasureSpec(rate=0.3, seed=7).sample_mask(50, 16)
    assert m.shape == (50, 16) and m.dtype == np.float32
    assert set(np.unique(m)) <= {0.0, 1.0}
    # deterministic from the spec seed; overridable per draw
    np.testing.assert_array_equal(
        m, ErasureSpec(rate=0.3, seed=7).sample_mask(50, 16))
    assert not np.array_equal(
        m, ErasureSpec(rate=0.3, seed=8).sample_mask(50, 16))
    assert abs(m.mean() - 0.3) < 0.05
    # rate 0 is the all-keep mask regardless of model
    assert ErasureSpec(rate=0.0).sample_mask(10, 4).sum() == 0.0
    # gilbert: stationary marginal matches the requested rate, and losses
    # cluster (mean run length > iid's 1/(1-rate))
    g = ErasureSpec(rate=0.2, model="gilbert", burst_len=6.0,
                    seed=3).sample_mask(4000, 2)
    assert abs(g.mean() - 0.2) < 0.04
    col = g[:, 0]
    runs = np.diff(np.flatnonzero(np.diff(np.concatenate(
        [[0.0], col, [0.0]]))))[::2]
    assert runs.mean() > 2.0, runs.mean()


def test_erasure_amplification():
    assert erasure_amplification(0.0, 10) == 1.0       # exact, not approx
    # monotone in the drop rate, always >= 1
    amps = [erasure_amplification(r, 10) for r in (0.05, 0.1, 0.3, 0.6)]
    assert all(a > 1.0 for a in amps)
    assert all(b > a for a, b in zip(amps, amps[1:]))
    # matches a direct Monte-Carlo estimate of E[P / max(k, 1)]
    rng = np.random.default_rng(0)
    k = rng.binomial(10, 0.7, size=200_000)
    mc = (10.0 / np.maximum(k, 1)).mean()
    assert abs(erasure_amplification(0.3, 10) - mc) < 0.01 * mc


def test_erasure_rate_factors():
    assert erasure_rate_factors(0.0, "retransmit") == (1.0, 1.0, 1.0)
    assert erasure_rate_factors(0.0, "rate_up") == (1.0, 1.0, 1.0)
    b, s, w = erasure_rate_factors(0.2, "retransmit")
    assert (b, s) == (0.8, 1.0) and abs(w - 1.25) < 1e-12
    b, s, w = erasure_rate_factors(0.2, "rate_up")
    assert b == 1.0 and abs(s - 1.25) < 1e-12 and w == 0.8
    # either policy conserves wire bits: delivered * boost * wire == total
    for rec in ("retransmit", "rate_up"):
        b, s, w = erasure_rate_factors(0.35, rec)
        assert abs(b * s * w - 1.0) < 1e-12
    with pytest.raises(AssertionError):
        erasure_rate_factors(0.1, "ignore")


# ---------------------------------------------------------------------------
# allocators: rate-0 bit-exactness and wire-budget conservation
# ---------------------------------------------------------------------------

def test_allocators_rate0_bit_exact(inst):
    prior, prob, *_ = inst
    base = dp_allocate(prob, P, T, r_total=6.0, dr=0.25)
    zero = dp_allocate(prob, P, T, r_total=6.0, dr=0.25, erasure_rate=0.0,
                       recovery="rate_up")
    np.testing.assert_array_equal(base.rates, zero.rates)
    np.testing.assert_array_equal(base.sigma2_d, zero.sigma2_d)
    assert zero.wire_rates is None
    cb = dp_allocate_col(prob, P, T, r_total=6.0, dr=0.25)
    cz = dp_allocate_col(prob, P, T, r_total=6.0, dr=0.25, erasure_rate=0.0)
    np.testing.assert_array_equal(cb.rates, cz.rates)
    rb, db = bt_schedule_offline(prob, P, T, c_ratio=1.01)
    rz, dz = bt_schedule_offline(prob, P, T, c_ratio=1.01, erasure_rate=0.0)
    np.testing.assert_array_equal(np.asarray(rb), np.asarray(rz))
    np.testing.assert_array_equal(np.asarray(db), np.asarray(dz))


@pytest.mark.parametrize("recovery", ["retransmit", "rate_up"])
def test_dp_wire_budget_conservation(inst, recovery):
    """Erasure-aware DP spends exactly the caller's bit budget *on the
    wire* regardless of recovery policy — losses shift where the bits go
    (re-sends vs finer survivor bins), never how many are spent."""
    prior, prob, *_ = inst
    r_total = 8.0
    dp = dp_allocate(prob, P, T, r_total=r_total, dr=0.1, erasure_rate=0.2,
                     recovery=recovery)
    assert dp.wire_rates is not None
    assert abs(dp.wire_rates.sum() - r_total) < 1e-9
    dpc = dp_allocate_col(prob, P, T, r_total=r_total, dr=0.1,
                          erasure_rate=0.2, recovery=recovery)
    assert dpc.wire_rates is not None
    assert abs(dpc.wire_rates.sum() - r_total) < 1e-9
    # planning for loss costs fidelity: clean allocation does at least
    # as well at the same wire budget
    clean = dp_allocate(prob, P, T, r_total=r_total, dr=0.1)
    assert dp.sigma2_d[-1] >= clean.sigma2_d[-1] - 1e-12


# ---------------------------------------------------------------------------
# engine: the all-survivors mask is the pre-erasure program, bit for bit
# ---------------------------------------------------------------------------

def test_engine_drop_zero_bit_exact(inst):
    prior, prob, a, y, s0 = inst
    zeros = np.zeros((T, P), np.float32)
    for transport, deltas in [(ExactFusion(), np.full(T, np.inf, np.float32)),
                              (EcsqTransport(),
                               np.full(T, 0.08, np.float32))]:
        eng = AmpEngine(prior, EngineConfig(n_proc=P, n_iter=T),
                        transport, FixedSchedule(deltas))
        ref = eng.solve(y, a)
        got = eng.solve(y, a, drop_sched=zeros)
        np.testing.assert_array_equal(np.asarray(got.x),
                                      np.asarray(ref.x))
        np.testing.assert_array_equal(np.asarray(got.sigma2_hat),
                                      np.asarray(ref.sigma2_hat))


def test_engine_erasure_degrades_not_destroys(inst):
    prior, prob, a, y, s0 = inst
    eng = AmpEngine(prior, EngineConfig(n_proc=P, n_iter=T), ExactFusion(),
                    FixedSchedule(np.full(T, np.inf, np.float32)))
    clean = float(eng.solve(y, a).mse(s0)[-1])
    mask = ErasureSpec(rate=0.25, seed=1).sample_mask(T, P)
    lossy = float(eng.solve(y, a, drop_sched=mask).mse(s0)[-1])
    assert np.isfinite(lossy)
    assert lossy > clean                       # erasure hurts ...
    assert lossy < 50 * max(clean, 1e-6)       # ... but stays bounded


# ---------------------------------------------------------------------------
# service: erasure requests, measured wire bytes, on-the-wire rates
# ---------------------------------------------------------------------------

def _req(a, y, prior, **kw):
    kw.setdefault("policy", "fixed")
    if kw["policy"] == "fixed" and "deltas" not in kw:
        kw["deltas"] = np.full(T, 0.05, np.float32)
    return SolveRequest(y=y, a=a, prior=prior, n_proc=P, n_iter=T, **kw)


def test_service_wire_accounting(inst):
    prior, prob, a, y, s0 = inst
    svc = SolveService(policy=POLICY)
    plain, = svc.solve([_req(a, y, prior)])
    assert plain.bytes_on_wire is None         # accounting is opt-in
    wired, = svc.solve([_req(a, y, prior, measure_wire=True)])
    # the accounting twin runs the same math; only XLA fusion order differs
    # between the symbol-collecting and plain program families
    np.testing.assert_allclose(wired.x, plain.x, atol=2e-6)
    assert wired.bytes_on_wire > wired.payload_bytes > 0
    assert wired.energy_j > 0 and wired.time_on_air_s > 0
    # measured rANS payload lands within ~5% above the model entropy
    # (paper's "achievable through entropy coding"), and not absurdly below
    model_bytes = float(np.sum(plain.rates)) * P * N / 8.0
    assert wired.payload_bytes < 1.05 * model_bytes, \
        (wired.payload_bytes, model_bytes)
    assert wired.payload_bytes > 0.5 * model_bytes


def test_service_erasure_requests(inst):
    prior, prob, a, y, s0 = inst
    svc = SolveService(policy=POLICY)
    clean, = svc.solve([_req(a, y, prior)])
    # a clean request co-batched with an erasure request is unaffected
    got_cl, got_er = svc.solve([
        _req(a, y, prior),
        _req(a, y, prior, erasure_rate=0.2, erasure_seed=3),
    ])
    np.testing.assert_allclose(got_cl.x, clean.x, atol=2e-6)
    assert np.isfinite(got_er.x).all()
    # retransmit re-sends lost packets: measured bytes exceed the clean run
    w_clean, w_lossy = svc.solve([
        _req(a, y, prior, measure_wire=True),
        _req(a, y, prior, erasure_rate=0.3, erasure_seed=5,
             recovery="retransmit", measure_wire=True),
    ])
    assert w_lossy.bytes_on_wire > w_clean.bytes_on_wire
    # reported rates are on-the-wire: with identical bins and mask, the
    # recovery policies share compute and delivered rate, and differ only
    # in accounting — retransmit bills rate/(1-p), rate_up rate*(1-p)
    rt, = svc.solve([_req(a, y, prior, erasure_rate=0.2, erasure_seed=5,
                          recovery="retransmit")])
    ru, = svc.solve([_req(a, y, prior, erasure_rate=0.2, erasure_seed=5,
                          recovery="rate_up")])
    np.testing.assert_array_equal(rt.x, ru.x)
    fin = np.isfinite(rt.rates) & (rt.rates > 0)
    assert fin.any()
    np.testing.assert_allclose(rt.rates[fin],
                               ru.rates[fin] * (1.25 / 0.8), rtol=1e-9)


def test_erasure_requests_bucket_with_seed(inst):
    """Erasure masks are drawn from request fields, so dispatch and
    finalize see the same mask and reruns are reproducible."""
    prior, prob, a, y, s0 = inst
    svc = SolveService(policy=POLICY)
    r1, = svc.solve([_req(a, y, prior, erasure_rate=0.3, erasure_seed=11)])
    r2, = svc.solve([_req(a, y, prior, erasure_rate=0.3, erasure_seed=11)])
    np.testing.assert_array_equal(r1.x, r2.x)
    r3, = svc.solve([_req(a, y, prior, erasure_rate=0.3, erasure_seed=12)])
    assert not np.array_equal(r1.x, r3.x)


# ---------------------------------------------------------------------------
# satellites: prewarm thread race, operand-cache since_clear
# ---------------------------------------------------------------------------

def test_prewarm_concurrent_no_double_compile(inst):
    """Two threads racing the same prewarm menu compile each program
    exactly once (engine build caches are lock-guarded); a reference
    single-threaded service lands on the identical program count."""
    prior, prob, a, y, s0 = inst
    menu = [PrewarmSpec(n=N, m=M, n_proc=P, n_iter=T, policy="fixed",
                        prior=prior, batch_widths=(1, 2))]
    ref = SolveService(policy=POLICY)
    ref.prewarm(menu)
    expected = ref.compile_count()

    svc = SolveService(policy=POLICY)
    barrier = threading.Barrier(2)
    errs = []

    def warm():
        try:
            barrier.wait(timeout=60)
            svc.prewarm(menu)
        except Exception as e:          # pragma: no cover - failure path
            errs.append(e)

    threads = [threading.Thread(target=warm) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    assert not errs, errs
    assert svc.compile_count() == expected, (svc.compile_count(), expected)
    # the cache serves, not recompiles, under subsequent traffic
    svc.solve([_req(a, y, prior), _req(a, y, prior)])
    assert svc.compile_count() == expected


def test_operand_cache_since_clear(inst):
    prior, prob, a, y, s0 = inst
    svc = SolveService(policy=POLICY)
    svc.solve([_req(a, y, prior)])
    svc.solve([_req(a, y, prior)])
    oc = svc.stats()["operand_cache"]
    assert oc["hits"] >= 1 and oc["since_clear"]["hits"] == oc["hits"]
    lifetime = (oc["hits"], oc["misses"])
    svc._opcache.clear()
    oc = svc.stats()["operand_cache"]
    # lifetime counters survive the clear; since_clear restarts at zero
    assert (oc["hits"], oc["misses"]) == lifetime
    assert oc["since_clear"] == {"hits": 0, "misses": 0, "evictions": 0}
    svc.solve([_req(a, y, prior)])
    oc = svc.stats()["operand_cache"]
    assert oc["since_clear"]["misses"] >= 1
    svc._opcache.clear(reset_stats=True)
    oc = svc.stats()["operand_cache"]
    assert oc["hits"] == 0 and oc["misses"] == 0
    assert oc["since_clear"] == {"hits": 0, "misses": 0, "evictions": 0}


# ---------------------------------------------------------------------------
# tier2: MC engine MSE under erasure tracks the erasure-extended SE
# ---------------------------------------------------------------------------

MC_N, MC_M, MC_P, MC_T, MC_B = 1500, 448, 8, 6, 32
# Calibrated per-rate envelopes over the erasure-SE prediction.  At rate 0
# this is the usual finite-N bias band; with loss the engine sits
# systematically *above* the mean-amplification SE (the plug-in denoiser
# is tuned for the unamplified variance, and per-round amplification
# compounds through the nonlinear recursion — measured excess ~0.8x the
# SE value at rate 0.2, t=5), so the band widens with the drop rate.
MC_TOL = {
    0.0: 0.15 + 0.06 * np.arange(MC_T),
    0.05: 0.20 + 0.10 * np.arange(MC_T),
    0.2: 0.40 + 0.25 * np.arange(MC_T),
}


@pytest.mark.tier2
def test_mc_tracks_erasure_se():
    """Monte-Carlo engine MSE under Bernoulli packet loss tracks
    ``se_trajectory_erasure`` (survivor-rescale amplification) within a
    calibrated envelope at every iteration, degrades to the published SE
    at rate 0, and separates cleanly from the lossless trajectory — an
    engine that ignored its drop masks (or amplified twice) fails."""
    prior = BernoulliGauss(eps=0.1)
    prob = CSProblem(n=MC_N, m=MC_M, prior=prior, snr_db=20.0)
    mm = make_mmse_interp(prior)
    deltas = np.full(MC_T, np.inf, np.float32)
    eng = AmpEngine(prior, EngineConfig(n_proc=MC_P, n_iter=MC_T,
                                        collect_symbols=False),
                    ExactFusion(), FixedSchedule(deltas))
    insts = [sample_problem(jax.random.PRNGKey(i), MC_N, MC_M, prior,
                            prob.sigma_e2) for i in range(MC_B)]
    mc = {}
    for rate in MC_TOL:
        mses = []
        for i, (s0, a, y) in enumerate(insts):
            drop = None
            if rate > 0.0:
                drop = ErasureSpec(rate=rate, seed=1000 + i).sample_mask(
                    MC_T, MC_P)
            mses.append(np.asarray(eng.solve(y, a, drop_sched=drop)
                                   .mse(s0)))
        mc[rate] = np.stack(mses).mean(axis=0)
        traj = se_trajectory_erasure(prob, np.zeros(MC_T), MC_P, rate,
                                     mmse_fn=mm)
        se = prob.kappa * (traj[1:] - prob.sigma_e2)
        rel = (mc[rate] - se) / se
        tol = MC_TOL[rate]
        assert (rel < tol).all(), (rate, list(zip(rel, tol)))
        assert (rel > -0.5 * tol).all(), (rate, list(zip(rel, tol)))
    # teeth: loss must actually cost fidelity at steady state
    assert mc[0.05][-1] > 1.05 * mc[0.0][-1], (mc[0.05][-1], mc[0.0][-1])
    assert mc[0.2][-1] > 1.4 * mc[0.0][-1], (mc[0.2][-1], mc[0.0][-1])
