import jax
import numpy as np
import pytest

from repro.core.amp import amp_solve, sample_problem
from repro.core.denoisers import BernoulliGauss
from repro.core.mp_amp import MPAMPConfig, mp_amp_solve, split_problem
from repro.core.state_evolution import CSProblem, sdr, se_trajectory


@pytest.fixture(scope="module")
def problem():
    prior = BernoulliGauss(eps=0.1)
    prob = CSProblem(n=5000, m=1500, prior=prior)
    s0, a, y = sample_problem(jax.random.PRNGKey(0), prob.n, prob.m, prior,
                              prob.sigma_e2)
    return prob, s0, a, y


def test_amp_matches_state_evolution(problem):
    """Finite-N AMP tracks the SE prediction (paper eq. 4).

    At N=5000 the mid-trajectory knee shifts by ±1 iteration between
    realizations, which blows up *pointwise* MSE ratios while the curve
    shape matches; so the check allows a one-iteration lag band plus a
    tight plateau check at t=T."""
    prob, s0, a, y = problem
    t = 15
    tr = amp_solve(y, a, prob.prior, t, s0=s0)
    se = se_trajectory(prob, t)
    se_mse = prob.kappa * (se[1:] - prob.sigma_e2)
    lo = 0.6 * np.minimum.reduce([se_mse,
                                  np.append(se_mse[1:], se_mse[-1])])
    hi = 1.7 * np.maximum.reduce([se_mse,
                                  np.insert(se_mse[:-1], 0, se_mse[0])])
    assert np.all(tr.mse >= lo) and np.all(tr.mse <= hi), tr.mse / se_mse
    # plateau: final MSE within 35% of the SE fixed point
    assert 0.65 < tr.mse[-1] / se_mse[-1] < 1.6


def test_mp_amp_lossless_equals_centralized(problem):
    prob, s0, a, y = problem
    t = 12
    cen = amp_solve(y, a, prob.prior, t, s0=s0)
    mp = mp_amp_solve(y, a, prob.prior, MPAMPConfig(n_proc=30, n_iter=t),
                      [np.inf] * t, s0=s0)
    np.testing.assert_allclose(mp.x, cen.x, atol=5e-5)


@pytest.mark.parametrize("n_proc", [2, 10, 30])
def test_mp_amp_invariant_to_processor_count_lossless(problem, n_proc):
    prob, s0, a, y = problem
    t = 8
    mp = mp_amp_solve(y, a, prob.prior, MPAMPConfig(n_proc=n_proc, n_iter=t),
                      [np.inf] * t, s0=s0)
    cen = amp_solve(y, a, prob.prior, t, s0=s0)
    np.testing.assert_allclose(mp.x, cen.x, atol=5e-5)


def test_mp_amp_quantized_minor_degradation(problem):
    """Paper's central claim: coarse fusion, near-centralized SDR."""
    prob, s0, a, y = problem
    t = 12

    def ctrl(tt, s2):  # ~4-bit uniform quantizer, Delta = 2 sigma_t/sqrt(P)/8
        return 2.0 * np.sqrt(s2 / 30.0) / 8.0

    cen = amp_solve(y, a, prob.prior, t, s0=s0)
    mp = mp_amp_solve(y, a, prob.prior, MPAMPConfig(30, t), ctrl, s0=s0)
    sdr_c = 10 * np.log10(prob.prior.second_moment / cen.mse[-1])
    sdr_q = 10 * np.log10(prob.prior.second_moment / mp.mse[-1])
    assert sdr_c - sdr_q < 0.6                       # <0.6 dB loss
    assert np.all(mp.rates_empirical < 6.0)          # paper: <6 bits/iter
    # 32-bit floats -> >80% communication savings claim
    assert mp.total_bits_empirical < 0.2 * 32 * t


def test_message_statistics(problem):
    """f_t^p - s0/P ~ N(0, sigma_t^2/P), independent across processors
    (the property justifying the scalar-channel model, paper Sec. 3.2)."""
    prob, s0, a, y = problem
    from repro.core.mp_amp import mp_local_step
    import jax.numpy as jnp
    p = 30
    a_p, y_p = split_problem(np.asarray(a, np.float32),
                             np.asarray(y, np.float32), p)
    z, f_p, s2 = mp_local_step(jnp.zeros(prob.n), jnp.zeros_like(jnp.asarray(y_p)),
                               jnp.zeros(()), jnp.asarray(a_p), jnp.asarray(y_p))
    err = np.asarray(f_p) - s0[None, :] / p
    # variance per processor ~ sigma_0^2 / P
    v = err.var(axis=1)
    np.testing.assert_allclose(v.mean(), float(s2) / p, rtol=0.1)
    # cross-processor correlation ~ 0
    c = np.corrcoef(err[:5])
    off = c[np.triu_indices(5, 1)]
    assert np.all(np.abs(off) < 0.08)
