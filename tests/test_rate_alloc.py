import numpy as np
import pytest

from repro.core.denoisers import BernoulliGauss, make_mmse_interp
from repro.core.rate_alloc import BTController, bt_schedule_offline, dp_allocate
from repro.core.rate_distortion import RDModel
from repro.core.state_evolution import CSProblem, se_trajectory


@pytest.fixture(scope="module")
def ctx():
    prob = CSProblem(prior=BernoulliGauss(eps=0.05))
    rd = RDModel(prob.prior)
    mm = make_mmse_interp(prob.prior)
    return prob, rd, mm


def test_dp_uses_full_budget(ctx):
    prob, rd, mm = ctx
    dp = dp_allocate(prob, 30, 10, 20.0, rd=rd, mmse_fn=mm)
    assert abs(dp.rates.sum() - 20.0) < 1e-9
    assert np.all(dp.rates >= 0)


def test_dp_beats_uniform_allocation(ctx):
    """DP optimality: no worse than the uniform 2-bit schedule."""
    prob, rd, mm = ctx
    t, r = 10, 20.0
    dp = dp_allocate(prob, 30, t, r, rd=rd, mmse_fn=mm)
    # simulate uniform schedule through the same quantized-SE recursion
    sig = prob.sigma0_2
    for _ in range(t):
        sq2 = float(rd.distortion_msg(r / t, sig, 30))
        sig = prob.sigma_e2 + float(mm(sig + 30 * sq2)) / prob.kappa
    assert dp.sigma2_d[-1] <= sig + 1e-12


def test_dp_rates_increase_with_iteration(ctx):
    """Paper Fig. 1: optimal allocation spends more bits in later iterations."""
    prob, rd, mm = ctx
    dp = dp_allocate(prob, 30, 10, 20.0, rd=rd, mmse_fn=mm)
    # overall increasing trend (allow small plateaus)
    assert dp.rates[-1] >= dp.rates[0]
    assert np.sum(np.diff(dp.rates) < -0.25) == 0


def test_dp_monotone_in_budget(ctx):
    prob, rd, mm = ctx
    d1 = dp_allocate(prob, 30, 8, 8.0, rd=rd, mmse_fn=mm)
    d2 = dp_allocate(prob, 30, 8, 16.0, rd=rd, mmse_fn=mm)
    assert d2.sigma2_d[-1] <= d1.sigma2_d[-1] + 1e-12


def test_bt_respects_caps_and_ratio(ctx):
    prob, rd, mm = ctx
    t = 10
    rates, sig = bt_schedule_offline(prob, 30, t, c_ratio=1.002, r_max=6.0,
                                     rate_model="rd", rd=rd, mmse_fn=mm)
    assert np.all(rates <= 6.0 + 1e-9)
    cen = se_trajectory(prob, t, mmse_fn=mm)
    # wherever the rate cap did NOT bind, the ratio constraint holds
    unbound = rates < 6.0 - 1e-6
    ratio = sig[1:][unbound] / cen[1:][unbound]
    assert np.all(ratio <= 1.002 + 1e-6)


def test_bt_controller_online_matches_offline_on_se(ctx):
    """Feeding the controller the SE trajectory reproduces the offline rates."""
    prob, rd, mm = ctx
    t = 8
    off_rates, off_sig = bt_schedule_offline(prob, 30, t, 1.002, 6.0, "rd",
                                             rd, mm)
    ctrl = BTController(prob, 30, t, 1.002, 6.0, "rd", rd, mm)
    for i in range(t):
        ctrl(i, float(off_sig[i]))
    np.testing.assert_allclose(ctrl.rates, off_rates, atol=1e-6)
