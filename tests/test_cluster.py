"""Cluster-tier tests (DESIGN.md §11): codec round-trips, router/
autoscaler scheduling, the emulated multi-host ``ClusterService``
(bit-identity + zero steady-state recompiles, the ISSUE 8 acceptance
criteria), and the TCP backend transport on a loopback socket."""
import dataclasses
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.denoisers import BernoulliGauss
from repro.serving import (Autoscaler, BucketPolicy, ClusterRouter,
                           ClusterService, DemandTracker, HostInfo,
                           Overloaded, PrewarmSpec, RouterPolicy,
                           SolveRequest, SolveService, decode_request,
                           decode_result, encode_request, encode_result,
                           routing_key, shape_cost)
from repro.serving.frontend import BackendServer, LocalBackend, TcpBackend

POL = BucketPolicy(max_batch=8, n_quantum=64, mp_quantum=8)


def make_reqs(n_req: int, n: int = 128, m: int = 64, p: int = 4,
              t: int = 8, seed: int = 0):
    import jax

    from repro.core.amp import sample_problem
    from repro.core.state_evolution import CSProblem

    prior = BernoulliGauss(eps=0.1)
    prob = CSProblem(n=n, m=m, prior=prior, snr_db=20.0)
    deltas = np.full(t, 0.05, np.float32)
    deltas[0] = np.inf
    reqs = []
    for i in range(n_req):
        _, a, y = sample_problem(jax.random.PRNGKey(seed + i), n, m, prior,
                                 prob.sigma_e2)
        reqs.append(SolveRequest(y=y, a=a, prior=prior, n_proc=p,
                                 n_iter=t, policy="fixed", deltas=deltas))
    return prior, reqs


# ---------------------------------------------------------------------------
# codec (satellite: no pickle on the wire)
# ---------------------------------------------------------------------------

def assert_request_roundtrip(req):
    back = decode_request(encode_request(req))
    for f in ("request_id", "n_proc", "n_iter", "policy", "transport",
              "snr_db", "layout", "measure_wire", "erasure_rate",
              "erasure_seed", "recovery"):
        assert getattr(back, f) == getattr(req, f), f
    assert type(back.prior) is type(req.prior)
    np.testing.assert_array_equal(np.asarray(back.y), np.asarray(req.y))
    np.testing.assert_array_equal(np.asarray(back.a), np.asarray(req.a))
    if req.deltas is None:
        assert back.deltas is None
    else:
        np.testing.assert_array_equal(np.asarray(back.deltas),
                                      np.asarray(req.deltas))


def test_codec_request_roundtrip():
    _, reqs = make_reqs(1)
    assert_request_roundtrip(reqs[0])
    # no-deltas variant (lossless policy)
    assert_request_roundtrip(dataclasses.replace(
        reqs[0], policy="lossless", deltas=None, request_id=7))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 8), st.integers(1, 8), st.integers(0, 2 ** 31 - 1),
       st.sampled_from(["fixed", "lossless"]),
       st.floats(5.0, 40.0, allow_nan=False))
def test_codec_request_roundtrip_property(nq, mq, rid, policy, snr):
    """Any structurally valid request survives the wire bit-exactly —
    shapes, ids, schedules, and float fields included."""
    rng = np.random.default_rng(rid % 1000)
    n, m, p = 8 * nq, 4 * mq, 4
    deltas = None
    if policy == "fixed":
        deltas = np.full(6, 0.05, np.float32)
        deltas[0] = np.inf
    req = SolveRequest(
        y=rng.standard_normal(m).astype(np.float32),
        a=rng.standard_normal((m, n)).astype(np.float32),
        prior=BernoulliGauss(eps=0.1), snr_db=snr, n_proc=p, n_iter=6,
        policy=policy, deltas=deltas, request_id=rid)
    assert_request_roundtrip(req)


def test_codec_result_roundtrip(cluster_ctx):
    _, _, base_res, *_ = cluster_ctx
    res = base_res[0]
    back = decode_result(encode_result(res))
    assert back.request_id == res.request_id
    assert back.bucket == res.bucket
    assert back.batch_size == res.batch_size
    assert back.total_bits == res.total_bits
    np.testing.assert_array_equal(np.asarray(back.x), np.asarray(res.x))
    np.testing.assert_array_equal(np.asarray(back.rates),
                                  np.asarray(res.rates))


def test_codec_rejects_unknown_fields():
    from repro.serving.codec import CodecError, _pack, _unpack

    _, reqs = make_reqs(1)
    buf = encode_request(reqs[0])
    header, arrays = _unpack(buf)
    header["no_such_field"] = 1
    with pytest.raises(CodecError):
        decode_request(_pack(header, arrays))
    with pytest.raises(CodecError):
        decode_request(b"BAD1" + buf[4:])   # wrong magic


# ---------------------------------------------------------------------------
# scheduler units: router, demand tracker, autoscaler (no jax, synthetic
# clocks — everything deterministic)
# ---------------------------------------------------------------------------

def two_host_router(**kw):
    pol = RouterPolicy(**kw)
    return ClusterRouter([HostInfo("a"), HostInfo("b")], pol), pol


def any_key():
    _, reqs = make_reqs(1)
    return routing_key(reqs[0], POL)


def test_router_least_loaded_spreads_with_replicas():
    r, _ = two_host_router(min_replicas=2)
    key = any_key()
    cost = shape_cost(key)
    picks = [r.route(key, cost) for _ in range(6)]
    assert picks == ["a", "b", "a", "b", "a", "b"]
    assert r.imbalance() == 1.0
    # completes drain outstanding, never below zero
    for hid in picks:
        r.complete(hid, cost)
    r.complete("a", 1e9)
    assert r.stats()["outstanding"] == {"a": 0.0, "b": 0.0}


def test_router_warmth_breaks_ties_only():
    r, _ = two_host_router(min_replicas=2)
    key = any_key()
    r.mark_warm("b", key)
    # both idle: warm host b wins the tie despite host order
    assert r.route(key, 1.0) == "b"
    # b now loaded: cold a wins on load — warmth must not pin routing
    assert r.route(key, 1.0) == "a"


def test_router_replica_lifecycle():
    r, _ = two_host_router(min_replicas=1)
    key = any_key()
    assert r.replicas(key) == ["a"]
    assert r.add_replica(key) == "b"
    assert r.add_replica(key) is None          # saturated
    assert r.remove_replica(key) == "b"        # most recent first
    assert r.remove_replica(key) is None       # never below min
    assert r.replicas(key) == ["a"]


def test_router_sheds_when_all_replicas_capped():
    r, _ = two_host_router(min_replicas=2, max_outstanding=2.0)
    key = any_key()
    for _ in range(4):
        r.route(key, 1.0)                      # both hosts reach the cap
    with pytest.raises(Overloaded):
        r.route(key, 1.0)
    r.complete("b", 1.0)
    assert r.route(key, 1.0) == "b"            # capacity freed -> admits


def test_demand_tracker_ewma_decay():
    tr = DemandTracker(halflife_s=10.0)
    key = any_key()
    tr.update({key: 5}, now=0.0)               # seed scrape: rate 0
    assert tr.rate(key) == 0.0
    tr.update({key: 100}, now=10.0)            # 10 req/s, half blended
    assert tr.rate(key) == pytest.approx(5.0)
    tr.update({}, now=20.0)                    # silence decays, not resets
    assert tr.rate(key) == pytest.approx(2.5)
    assert 0.0 < tr.rate(key) < 5.0


def test_autoscaler_scale_up_then_hysteresis_down():
    r, pol = two_host_router(min_replicas=1, target_load=1.0,
                             down_patience=2, ewma_halflife_s=0.5)
    a = Autoscaler(r, pol)
    key = any_key()
    # short halflife: one 1 s window at 1000 req/s blends to ~750 req/s,
    # far past target_load -> desired clamps to both hosts
    a.observe({key: 0}, now=0.0)
    a.observe({key: 1000}, now=1.0)
    events = a.step(now=1.0)
    assert ("scale_up", key, "b") in events
    assert len(r.replicas(key)) == 2
    # demand vanishes: force the EWMA to the floor to trip scale-down
    a.tracker._rate[key] = 0.0
    assert a.step(now=2.0) == []               # 1st low pass: patience
    assert len(r.replicas(key)) == 2
    assert a.step(now=3.0) == [("scale_down", key, "b")]
    assert len(r.replicas(key)) == 1
    # events ledger keeps everything, in order
    kinds = [k for k, *_ in a.events]
    assert kinds == ["scale_up", "scale_down"]


# ---------------------------------------------------------------------------
# the emulated multi-host service (ISSUE 8 acceptance)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cluster_ctx():
    """One solve of the same 16-request stream through a single-host
    service and a 2-host cluster (shared compile cost across tests)."""
    prior, reqs = make_reqs(16)
    menu = [PrewarmSpec(n=128, m=64, n_proc=4, n_iter=8, policy="fixed",
                        prior=prior, batch_widths=(8,))]

    ref = SolveService(policy=POL, rate_accounting=False)
    ref.prewarm(menu)
    base_res = ref.solve(reqs)

    cl = ClusterService(n_hosts=2, policy=POL,
                        router_policy=RouterPolicy(min_replicas=2),
                        rate_accounting=False)
    cl.prewarm(menu)
    warm = cl.compile_count()
    cl_res = sorted(cl.solve(reqs), key=lambda r: r.request_id)
    # router view right after the reference stream (later tests keep
    # feeding this cluster, so balance asserts read the snapshot)
    stats0 = cl.stats()
    return prior, reqs, base_res, cl, cl_res, warm, stats0


def test_cluster_matches_single_host_bitwise(cluster_ctx):
    """Same stream, same padded widths -> per-request results must be
    bit-identical to the single-host service (vmap lanes are
    independent; the route only picks which host's copy of the same
    compiled program runs)."""
    _, reqs, base_res, _, cl_res, _, _ = cluster_ctx
    assert len(cl_res) == len(reqs)
    for c, b in zip(cl_res, base_res):
        assert c.request_id == b.request_id
        np.testing.assert_array_equal(np.asarray(c.x), np.asarray(b.x))
        np.testing.assert_array_equal(np.asarray(c.sigma2_hat),
                                      np.asarray(b.sigma2_hat))


def test_cluster_zero_steady_state_compiles(cluster_ctx):
    _, reqs, _, cl, _, warm, _ = cluster_ctx
    assert cl.compile_count() == warm
    # further traffic on the prewarmed bucket stays compile-free too
    cl.solve(reqs[:8])
    assert cl.compile_count() == warm


def test_cluster_balances_hosts(cluster_ctx):
    """Batch-affine routing balances at batch granularity: the 16-req
    stream lands as one full batch per host."""
    *_, stats0 = cluster_ctx
    served = stats0["router"]["served"]
    assert served == {"host0": 8, "host1": 8}
    assert stats0["router"]["imbalance"] == pytest.approx(1.0)


def test_cluster_partition_balances_without_executing(cluster_ctx):
    _, reqs, _, cl, _, warm, _ = cluster_ctx
    shares = cl.partition(reqs)
    assert sorted(len(s) for s in shares.values()) == [8, 8]
    assert sum(len(s) for s in shares.values()) == len(reqs)
    assert cl.compile_count() == warm          # routed, never dispatched
    assert cl.router.stats()["outstanding"] == {"host0": 0.0,
                                                "host1": 0.0}


def test_cluster_stream_and_global_ids(cluster_ctx):
    prior, reqs, base_res, cl, _, _, _ = cluster_ctx
    before = cl.submitted
    results = sorted(cl.stream(iter(reqs)), key=lambda r: r.request_id)
    assert [r.request_id for r in results] == \
        list(range(before, before + len(reqs)))
    # stream results carry the same payloads as the reference solve
    for c, b in zip(results, base_res):
        np.testing.assert_array_equal(np.asarray(c.x), np.asarray(b.x))


def test_cluster_sheds_and_counts(cluster_ctx):
    prior, reqs, *_ = cluster_ctx
    key = routing_key(reqs[0], POL)
    cl = ClusterService(
        n_hosts=2, policy=POL,
        router_policy=RouterPolicy(min_replicas=2,
                                   max_outstanding=2.5 * shape_cost(key)),
        rate_accounting=False)
    admitted, shed = 0, 0
    for r in reqs:
        try:
            cl.submit(r)
            admitted += 1
        except Overloaded:
            shed += 1
    assert shed > 0 and admitted == 6          # 3 per host fit under cap
    assert cl.stats()["shed"] == shed
    assert len(cl.flush()) == admitted         # admitted work completes
    cl.submit(reqs[0])                         # drained -> admits again


def test_cluster_autoscaler_prewarms_new_replica(cluster_ctx):
    """A demand spike on a 1-replica bucket scales it out, and the
    scale-up event prewarms the bucket's exemplar spec on the new host
    before traffic lands there (no cold-compile on the routed path)."""
    prior, reqs, *_ = cluster_ctx
    cl = ClusterService(
        n_hosts=2, policy=POL,
        router_policy=RouterPolicy(min_replicas=1, target_load=0.01,
                                   ewma_halflife_s=0.5),
        rate_accounting=False)
    cl.scrape(now=100.0)                       # seed the demand window
    cl.solve(reqs[:8])                         # all on host0 (1 replica)
    key = routing_key(reqs[0], POL)
    assert cl.router.replicas(key) == ["host0"]
    warm_before = cl.backends["host1"].compile_count()
    events = cl.scrape(now=101.0)              # ~8 req/s >> target
    assert ("scale_up", key, "host1") in events
    assert cl.router.replicas(key) == ["host0", "host1"]
    assert cl.backends["host1"].compile_count() > warm_before
    # traffic now spreads batch-granularly (affinity keeps each filling
    # group on one host) — and the prewarmed new host compiles nothing
    # more (its scale-up prewarm covered the full batch-width ladder)
    warm1 = cl.backends["host1"].compile_count()
    cl.solve(reqs)                             # two full batches
    assert cl.router.stats()["served"]["host1"] > 0
    assert cl.backends["host1"].compile_count() == warm1


# ---------------------------------------------------------------------------
# TCP transport (codec frames over loopback)
# ---------------------------------------------------------------------------

def test_tcp_backend_roundtrip(cluster_ctx):
    """A ClusterService whose second host is a real BackendServer behind
    a loopback socket: routing, codec framing, id rewrite, demand
    scrape, prewarm, and shutdown all cross the wire."""
    prior, reqs, base_res, *_ = cluster_ctx
    server = BackendServer(LocalBackend(
        "host1", SolveService(policy=POL, rate_accounting=False)))
    server.start()
    try:
        tcp = TcpBackend((server.host, server.port), "host1")
        assert tcp.n_devices >= 1
        cl = ClusterService(
            backends=[LocalBackend("host0",
                                   SolveService(policy=POL,
                                                rate_accounting=False)),
                      tcp],
            policy=POL, router_policy=RouterPolicy(min_replicas=2))
        menu = [PrewarmSpec(n=128, m=64, n_proc=4, n_iter=8,
                            policy="fixed", prior=prior,
                            batch_widths=(8,))]
        rep = cl.prewarm(menu)
        assert rep["host1"]["programs"] >= 1   # prewarm crossed the wire
        results = sorted(cl.solve(reqs), key=lambda r: r.request_id)
        assert len(results) == len(reqs)
        for c, b in zip(results, base_res):
            np.testing.assert_array_equal(np.asarray(c.x),
                                          np.asarray(b.x))
        served = cl.router.stats()["served"]
        assert served["host1"] > 0             # remote host took traffic
        # stats and demand scrape cross the wire as plain JSON/codec
        assert cl.stats()["hosts"]["host1"]["compiles"]["total"] >= 1
        cl.scrape(now=1.0)
        # server-side errors surface as RuntimeError, not a dead socket
        with pytest.raises(RuntimeError):
            tcp.prewarm([dataclasses.replace(menu[0], n=13, m=7)])
        cl.close(shutdown_remote=True)
    finally:
        server.stop()


def test_tcp_backend_submit_poll_cycle():
    """Raw TcpBackend ops: submit returns the backend-local id, poll is
    empty until the batch dispatches, flush forces stragglers."""
    _, reqs = make_reqs(3, seed=50)
    server = BackendServer(LocalBackend(
        "h", SolveService(policy=POL, rate_accounting=False)))
    server.start()
    try:
        tcp = TcpBackend((server.host, server.port), "h")
        ids = [tcp.submit(r) for r in reqs]
        assert ids == [0, 1, 2]
        res = tcp.flush()
        assert sorted(r.request_id for r in res) == ids
        assert tcp.take_demand() != {}
        assert tcp.take_demand() == {}         # window advanced
        tcp.shutdown_server()
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# telemetry-plane ride-alongs (ISSUE 9): thread-safe scheduler state and
# the daemon scrape loop
# ---------------------------------------------------------------------------

def test_router_stats_safe_under_concurrent_routing():
    """Routing, completion, autoscaler steps, and stats reads from many
    threads: every read is internally consistent (no torn counters, no
    negative outstanding) and the final ledger balances exactly."""
    r, pol = two_host_router(min_replicas=2, target_load=1e9)
    a = Autoscaler(r, pol)
    key = any_key()
    n_threads, per_thread = 6, 400
    errors = []
    stop = threading.Event()

    def worker():
        try:
            for _ in range(per_thread):
                hid = r.route(key, 1.0)
                r.complete(hid, 1.0)
        except Exception as e:  # noqa: BLE001 — surfaced below
            errors.append(repr(e))

    def reader():
        t = 0.0
        while not stop.is_set():
            s = r.stats()
            assert all(v >= -1e-9 for v in s["outstanding"].values())
            assert sum(s["served"].values()) <= n_threads * per_thread
            a.observe({key: 1}, now=t)
            a.step(now=t)
            t += 1.0

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    rd = threading.Thread(target=reader)
    rd.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    rd.join()
    assert errors == []
    s = r.stats()
    assert s["outstanding"] == {"a": 0.0, "b": 0.0}
    assert sum(s["served"].values()) == n_threads * per_thread


def test_scraper_daemon_thread_scales_up():
    """The production shape of elasticity (satellite: amp_serve uses
    ``start_scraper`` instead of piggybacked scrapes): a demand spike is
    picked up by the daemon loop on its own tick, the bucket scales out
    with the new host prewarmed, and shutdown is clean."""
    prior, reqs = make_reqs(8)
    cl = ClusterService(
        n_hosts=2, policy=POL,
        router_policy=RouterPolicy(min_replicas=1, target_load=0.01,
                                   ewma_halflife_s=0.2,
                                   scrape_every_s=0.0),
        rate_accounting=False)
    try:
        key = routing_key(reqs[0], POL)
        th = cl.start_scraper(interval_s=0.05)
        assert th.daemon and th.is_alive()
        assert cl.start_scraper() is th            # idempotent
        # let the loop's first tick seed the demand tracker before
        # traffic arrives (the tracker's seed scrape reads rate 0 by
        # design — production starts the scraper before serving too)
        deadline = time.monotonic() + 5.0
        while (cl.autoscaler.tracker._t_last is None
               and time.monotonic() < deadline):
            time.sleep(0.01)
        assert cl.autoscaler.tracker._t_last is not None
        cl.solve(reqs)                             # demand lands in window
        deadline = time.monotonic() + 60.0
        while time.monotonic() < deadline:
            # wait for the event AND its prewarm (it runs on the scrape
            # thread; compile_count is the "prewarm done" signal)
            if (any(e[0] == "scale_up" for e in cl.autoscaler.events)
                    and cl.backends["host1"].compile_count() > 0):
                break
            time.sleep(0.05)
        assert any(e[0] == "scale_up" and e[1] == key
                   for e in cl.autoscaler.events), cl.autoscaler.events
        assert cl.router.replicas(key) == ["host0", "host1"]
        # the scale-up prewarmed the exemplar spec on the new host
        assert cl.backends["host1"].compile_count() > 0
        assert cl.scrape_errors == []
        cl.stop_scraper()
        assert cl._scrape_thread is None and not th.is_alive()
    finally:
        cl.close()                                 # close is re-entrant


# ---------------------------------------------------------------------------
# cluster topology helpers
# ---------------------------------------------------------------------------

def test_init_cluster_single_process_noop():
    from repro.launch.mesh import (init_cluster,
                                   supports_cross_host_collectives)

    info = init_cluster()                      # no coordinator configured
    assert info.process_count == 1
    assert info.is_frontend
    assert info.local_devices == info.global_devices
    assert supports_cross_host_collectives()   # single process: trivially


def test_make_cluster_mesh_single_host():
    import jax

    from repro.launch.mesh import make_cluster_mesh

    mesh = make_cluster_mesh()
    assert mesh.axis_names == ("data",)
    assert mesh.size == jax.local_device_count()
